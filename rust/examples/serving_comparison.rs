//! End-to-end serving driver (EXPERIMENTS.md §End-to-end): load the
//! trained gpt2-small checkpoint and serve the same workload under every
//! quantization backend, sweeping scheduler mode (static run-to-completion
//! batches vs continuous batching) and shard count — the deployment
//! decision a downstream user actually makes, now including the
//! scheduling discipline.
//!
//!   cargo run --release --example serving_comparison [n_requests] [max_new]
//!
//! Needs PJRT artifacts (`--features xla` + `make artifacts`).

use std::sync::Arc;

use llmeasyquant::coordinator::{Request, SchedulerMode, Server, ServerConfig};
use llmeasyquant::corpus;
use llmeasyquant::quant::Variant;
use llmeasyquant::runtime::Registry;
use llmeasyquant::util::bench::Table;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n_requests: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(16);
    let max_new: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(12);
    let model = "gpt2-small";

    let registry = Arc::new(Registry::open(std::path::Path::new("artifacts"))?);
    let mut table = Table::new(&[
        "variant",
        "mode",
        "shards",
        "tok/s",
        "mean lat (ms)",
        "p99 lat (ms)",
        "ttft (ms)",
        "weights (MB, all shards)",
        "steps",
    ]);

    for &variant in Variant::all() {
        for shards in [1usize, 2] {
            for mode in [SchedulerMode::Static, SchedulerMode::Continuous] {
                let mut cfg = ServerConfig::new(model, variant);
                cfg.shards = shards;
                cfg.mode = mode;
                cfg.policy.max_wait = std::time::Duration::from_millis(500);
                eprintln!(
                    "[{} / {} / {} shards] compiling + serving ...",
                    variant.name(),
                    mode.name(),
                    shards
                );
                let server = Server::start(&registry, cfg)?;
                let requests: Vec<Request> = (0..n_requests)
                    .map(|i| {
                        Request::new(
                            i as u64 + 1,
                            corpus::generate_tokens(32, 31_000 + i as u64),
                            max_new,
                        )
                    })
                    .collect();
                let report = server.run_workload(requests)?;
                table.row(vec![
                    variant.name().into(),
                    mode.name().into(),
                    shards.to_string(),
                    format!("{:.1}", report.tokens_per_s()),
                    format!("{:.1}", report.latency_summary().mean * 1e3),
                    format!("{:.1}", report.latency_percentile(0.99) * 1e3),
                    format!("{:.1}", report.ttft_summary().mean * 1e3),
                    format!("{:.2}", report.weight_storage_bytes as f64 / 1e6),
                    report.decode_steps.to_string(),
                ]);
            }
        }
    }

    println!(
        "\nend-to-end serving comparison — {model}, {n_requests} requests x {max_new} new \
         tokens, static vs continuous x shards (CPU-PJRT measured):"
    );
    table.print();
    println!(
        "\nNote: CPU wallclock favors the fp graphs (interpret-mode Pallas \
         int8 paths pay per-op overhead XLA:CPU cannot fuse); the A100-scale \
         picture comes from `llmeasyquant breakdown` / bench table2_throughput. \
         Weight MB is the sum over shard replicas."
    );
    Ok(())
}
