//! End-to-end serving driver (EXPERIMENTS.md §End-to-end): load the
//! trained gpt2-small checkpoint, serve the same batched workload under
//! every quantization backend across 2 worker shards, and report measured
//! latency / throughput / memory — the deployment decision a downstream
//! user actually makes.
//!
//!   cargo run --release --example serving_comparison [n_requests] [max_new]

use std::sync::Arc;

use llmeasyquant::coordinator::{Request, Server, ServerConfig};
use llmeasyquant::corpus;
use llmeasyquant::quant::Variant;
use llmeasyquant::runtime::Registry;
use llmeasyquant::util::bench::Table;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n_requests: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(16);
    let max_new: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(12);
    let model = "gpt2-small";
    let shards = 2;

    let registry = Arc::new(Registry::open(std::path::Path::new("artifacts"))?);
    let mut table = Table::new(&[
        "variant",
        "tok/s",
        "mean lat (ms)",
        "ttft (ms)",
        "weights (MB)",
        "steps",
    ]);

    for &variant in Variant::all() {
        let mut cfg = ServerConfig::new(model, variant);
        cfg.shards = shards;
        cfg.policy.max_wait = std::time::Duration::from_millis(500);
        eprintln!("[{}] compiling + serving ...", variant.name());
        let server = Server::start(&registry, cfg)?;
        let requests: Vec<Request> = (0..n_requests)
            .map(|i| {
                Request::new(
                    i as u64 + 1,
                    corpus::generate_tokens(32, 31_000 + i as u64),
                    max_new,
                )
            })
            .collect();
        let report = server.run_workload(requests)?;
        table.row(vec![
            variant.name().into(),
            format!("{:.1}", report.tokens_per_s()),
            format!("{:.1}", report.latency_summary().mean * 1e3),
            format!("{:.1}", report.ttft_summary().mean * 1e3),
            format!("{:.2}", report.weight_storage_bytes as f64 / 1e6),
            report.decode_steps.to_string(),
        ]);
    }

    println!(
        "\nend-to-end serving comparison — {model}, {shards} shards, {n_requests} requests x {max_new} new tokens (CPU-PJRT measured):"
    );
    table.print();
    println!(
        "\nNote: CPU wallclock favors the fp graphs (interpret-mode Pallas \
         int8 paths pay per-op overhead XLA:CPU cannot fuse); the A100-scale \
         picture comes from `llmeasyquant breakdown` / bench table2_throughput."
    );
    Ok(())
}
