//! Edge-deployment scenario (paper §3.5 + §5): pick per-layer bitwidths
//! for a memory-constrained edge device, export the ONNX-compatible QDQ
//! graph, verify the round trip, and estimate edge (RTX-4090-class)
//! latency with the cost model under the TCP-fallback transport.
//!
//!   cargo run --release --example edge_deploy

use llmeasyquant::collective::Transport;
use llmeasyquant::coordinator::{search_bitwidths, size_reduction, LayerInfo, SearchPolicy};
use llmeasyquant::memsim::{GpuSpec, PaperModel, PipelineCost};
use llmeasyquant::quant::Variant;
use llmeasyquant::runtime::Registry;
use llmeasyquant::serialize;
use llmeasyquant::util::bench::Table;

fn main() -> anyhow::Result<()> {
    let registry = Registry::open(std::path::Path::new("artifacts"))?;
    let model = "gpt2-med";
    let cfg = registry.model_cfg(model)?.clone();
    let ckpt = registry.checkpoint(model)?;

    // ---- 1. mixed-precision search under an edge memory budget ----------
    let mut layers = Vec::new();
    let mut params = Vec::new();
    for i in 0..cfg.n_layers {
        for lname in ["qkv", "attn_out", "fc1", "fc2"] {
            let full = format!("h{i}.{lname}");
            let w = ckpt.f32(&format!("{full}_w"))?;
            let sens = ckpt
                .f32(&format!("calib.{full}.sqsum"))
                .map(|s| s.iter().sum::<f32>() / s.len() as f32)
                .unwrap_or(1.0);
            params.push(w.len());
            layers.push(LayerInfo { name: full, w, sensitivity: sens });
        }
    }
    // lambda chosen to actually trade accuracy for size on this
    // checkpoint (the sensitivity proxy is a raw sqsum, so the size term
    // needs weight to bite — the ablation bench sweeps this)
    let (choices, sweeps) = search_bitwidths(&layers, 0.08, SearchPolicy::Greedy);
    let mean_bits: f64 =
        choices.iter().map(|c| c.bits as f64).sum::<f64>() / choices.len() as f64;
    println!(
        "bitwidth search ({} layers, {} sweeps): mean {:.2} bits, {:.2}x smaller than f32",
        choices.len(),
        sweeps,
        mean_bits,
        size_reduction(&choices, &params)
    );
    let low_bits = choices.iter().filter(|c| c.bits < 8).count();
    println!("  {low_bits} layers assigned < 8 bits");

    // ---- 2. ONNX-compatible export for the edge runtime ------------------
    let out = std::path::PathBuf::from("target/gpt2-med.smooth.onnx.json");
    let g = serialize::export_model(&cfg, &ckpt, Variant::Smooth)?;
    serialize::save_graph(&g, &out)?;
    let back = serialize::import_model(&out)?;
    assert_eq!(g, back, "QDQ round trip must be exact");
    // Eq. 11 fidelity on the first initializer
    let w_hat = serialize::dequantize_initializer(&g.initializers[0]);
    let w = ckpt.f32("h0.qkv_w")?;
    let mse: f64 = w
        .iter()
        .zip(&w_hat)
        .map(|(a, b)| ((a - b) as f64).powi(2))
        .sum::<f64>()
        / w.len() as f64;
    println!(
        "exported {} ({} initializers); round-trip exact; h0.qkv MSE {:.2e}",
        out.display(),
        g.initializers.len(),
        mse
    );

    // ---- 3. edge latency estimate (RTX 4090, TCP fallback) --------------
    let mut table = Table::new(&["variant", "ms/token", "tok/s", "memory (GB)"]);
    for v in [Variant::Fp, Variant::Int8, Variant::Smooth, Variant::SimQuant] {
        let cost = PipelineCost::from_paper_model(
            &PaperModel::gpt2_345m(),
            1, // single-stream edge decode
            8192,
            1,
            GpuSpec::rtx4090(),
            Transport::Tcp.link(),
        );
        table.row(vec![
            v.name().into(),
            format!("{:.2}", cost.decode_step_s(v) * 1e3),
            format!("{:.0}", cost.decode_tokens_per_s(v)),
            format!("{:.2}", cost.memory_gb_total(v)),
        ]);
    }
    println!("\nedge estimate (GPT-2 345M-class on RTX 4090, 8K ctx, single stream):");
    table.print();
    Ok(())
}
