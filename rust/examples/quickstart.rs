//! Quickstart: load the artifact registry, quantize a model with
//! SmoothQuant, and generate a few completions through the serving stack.
//!
//!   make artifacts            # once: train + AOT-lower the models
//!   cargo run --release --example quickstart
//!
//! Everything here is pure Rust + PJRT: Python only ran at build time.

use std::sync::Arc;

use llmeasyquant::coordinator::{Request, Server, ServerConfig};
use llmeasyquant::corpus;
use llmeasyquant::quant::Variant;
use llmeasyquant::runtime::Registry;

fn main() -> anyhow::Result<()> {
    // 1. open the AOT artifact registry (HLO text + checkpoints + manifest)
    let registry = Arc::new(Registry::open(std::path::Path::new("artifacts"))?);

    // 2. pick a model + quantization backend; the registry quantizes the
    //    f32 checkpoint on load (weights become int8 codes + scales)
    let mut cfg = ServerConfig::new("gpt2-tiny", Variant::Smooth);
    cfg.shards = 1;
    println!("compiling gpt2-tiny / smoothquant ...");
    let server = Server::start(&registry, cfg)?;

    // 3. build a few requests (the tokenizer maps plain text to the
    //    32-symbol corpus alphabet)
    let prompts = ["the quick brown", "hello world", "quantization is"];
    let requests: Vec<Request> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| Request::new(i as u64 + 1, corpus::tokenize(p), 24))
        .collect();

    // 4. serve them
    let report = server.run_workload(requests)?;
    for r in &report.responses {
        println!(
            "prompt {:>2}: {:?}  ({} tokens, {:.0} ms)",
            r.id,
            corpus::detokenize(&r.tokens),
            r.tokens.len(),
            r.latency_s * 1e3
        );
    }
    println!(
        "\n{:.1} tok/s over {} decode steps; weights stored in {:.2} MB (int8)",
        report.tokens_per_s(),
        report.decode_steps,
        report.weight_storage_bytes as f64 / 1e6
    );
    Ok(())
}
