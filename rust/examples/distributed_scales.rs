//! Distributed online quantization (paper Alg. 1 + Eqs. 7-8 + Thm. 4):
//! eight worker shards track activation scales with EMA while decoding
//! different traffic, periodically synchronize through the ring
//! collective — over the *quantized* wire (`all_gather_quant` for the
//! log2-domain delta merge, `all_reduce_sum_q` for zero points; 8-bit
//! codes + per-chunk scales) — and the example verifies every shard ends
//! with identical quantization parameters, under both the NCCL profile
//! and the TCP fallback.
//!
//! A second section demonstrates the wire-byte cut directly: the same
//! payload all-gathered as f32, int8, packed 4-bit, and packed 2-bit,
//! with the per-rank bytes and the ratio vs f32.
//!
//!   cargo run --release --example distributed_scales

use llmeasyquant::collective::{wire_format_rows, Collective, CommStats, Topology, Transport};
use llmeasyquant::coordinator::ScaleSync;
use llmeasyquant::corpus::XorShift64Star;
use llmeasyquant::quant::EmaState;
use llmeasyquant::util::bench::Table;

fn run(transport: Transport, shards: usize, steps: usize) -> (Vec<EmaState>, CommStats) {
    let regions = 24; // e.g. one tracked region per layer input
    let ring = Collective::ring(Topology::new(shards, transport));
    let mut handles = Vec::new();
    for (rank, mut comm) in ring.into_iter().enumerate() {
        handles.push(std::thread::spawn(move || {
            let mut sync = ScaleSync::new(regions, 0.9, 1e-6, 4);
            let mut rng = XorShift64Star::new(777 + rank as u64);
            for step in 0..steps {
                for region in 0..regions {
                    // non-stationary, shard-skewed activations: scale
                    // drifts over time, shard 0 sees the outliers
                    let drift = 1.0 + step as f32 * 0.01;
                    let skew = if rank == 0 { 3.0 } else { 1.0 };
                    let x: Vec<f32> = (0..128)
                        .map(|_| rng.next_normal() as f32 * drift * skew)
                        .collect();
                    sync.observe(region, &x);
                }
                if sync.due() {
                    sync.sync(&mut comm).expect("sync");
                }
            }
            let states = sync.sync(&mut comm).expect("final sync");
            (states, comm.stats())
        }));
    }
    let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    // Thm. 4: all shards identical after sync — the quantized wire keeps
    // this exact, because every shard decodes the same low-bit bytes
    for (states, _) in &results[1..] {
        for (a, b) in results[0].0.iter().zip(states) {
            assert_eq!(a.delta, b.delta);
            assert_eq!(a.zero_point, b.zero_point);
        }
    }
    results.into_iter().next().unwrap()
}

fn main() {
    let (shards, steps) = (8, 64);
    let mut table = Table::new(&[
        "transport",
        "syncs",
        "bytes/shard (KB)",
        "sim wire (ms)",
        "wall (ms)",
    ]);
    for transport in [Transport::NvlinkRdma, Transport::Infiniband, Transport::Tcp] {
        let (states, stats) = run(transport, shards, steps);
        println!(
            "{}: shards converged; shard-0-outlier delta propagated to all (delta[0] = {:.2})",
            transport.name(),
            states[0].delta
        );
        table.row(vec![
            transport.name().into(),
            format!("{}", stats.ops / 2), // 2 collective ops per sync round
            format!("{:.1}", stats.bytes_sent as f64 / 1e3),
            format!("{:.3}", stats.sim_time_s * 1e3),
            format!("{:.3}", stats.wall_time_s * 1e3),
        ]);
    }
    println!("\nscale-sync cost by transport ({shards} shards, {steps} steps, 8-bit wire):");
    table.print();

    // ---- quantized-wire ratio: one all-gather, four wire formats --------
    let payload = 65536;
    let mut wire = Table::new(&["wire", "bytes/rank (KB)", "ratio vs f32"]);
    for row in wire_format_rows(shards, payload, Transport::NvlinkRdma) {
        wire.row(vec![
            row.label,
            format!("{:.1}", row.bytes_per_rank as f64 / 1e3),
            format!("{:.3}", row.ratio_vs_f32),
        ]);
    }
    println!("\nall-gather of {payload} f32 across {shards} shards, by wire format:");
    wire.print();
    println!("\nNCCL-ring vs TCP-fallback: identical results, ~50x wire-time gap —");
    println!("the transparent-fallback path of paper §3.3; the quantized wire");
    println!("cuts the bytes 4x at 8-bit and 8x/16x bit-packed (scales included).");
}
