//! Host tensors: the typed buffers that flow between checkpoints, the
//! quantizers, and PJRT literals.
//!
//! Deliberately minimal — heavy math runs inside the AOT-compiled XLA
//! modules; this type only needs to carry data, shapes and dtypes
//! faithfully across the Rust/Python contract (`file.rs` mirrors
//! `python/compile/tensorfile.py`).

mod array;
mod file;

pub(crate) use array::pod_bytes;
pub use array::{DType, Tensor};
pub use file::{load_tensor_file, save_tensor_file};
