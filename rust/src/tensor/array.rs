//! The `Tensor` type: shape + dtype + contiguous host data.

use anyhow::{bail, Result};

/// Element types shared with the Python tensor file format and PJRT.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    I8,
    U8,
    I32,
}

impl DType {
    pub fn itemsize(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::I8 | DType::U8 => 1,
        }
    }

    pub fn code(self) -> u8 {
        match self {
            DType::F32 => 0,
            DType::I8 => 1,
            DType::U8 => 2,
            DType::I32 => 3,
        }
    }

    pub fn from_code(code: u8) -> Result<Self> {
        Ok(match code {
            0 => DType::F32,
            1 => DType::I8,
            2 => DType::U8,
            3 => DType::I32,
            _ => bail!("unknown dtype code {code}"),
        })
    }

    /// Manifest dtype strings ("f32" / "i8" / "u8" / "i32" and numpy names).
    pub fn from_name(name: &str) -> Result<Self> {
        Ok(match name {
            "f32" | "float32" => DType::F32,
            "i8" | "int8" => DType::I8,
            "u8" | "uint8" => DType::U8,
            "i32" | "int32" => DType::I32,
            _ => bail!("unknown dtype name {name}"),
        })
    }
}

/// A host tensor: contiguous row-major data with shape and dtype.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub dtype: DType,
    pub shape: Vec<usize>,
    data: Vec<u8>,
}

impl Tensor {
    pub fn from_bytes(dtype: DType, shape: Vec<usize>, data: Vec<u8>) -> Result<Self> {
        let want = shape.iter().product::<usize>() * dtype.itemsize();
        if data.len() != want {
            bail!(
                "tensor data length {} does not match shape {:?} ({} bytes)",
                data.len(),
                shape,
                want
            );
        }
        Ok(Tensor { dtype, shape, data })
    }

    pub fn from_f32(shape: Vec<usize>, values: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        let mut data = Vec::with_capacity(values.len() * 4);
        for v in &values {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Tensor { dtype: DType::F32, shape, data }
    }

    pub fn from_i8(shape: Vec<usize>, values: Vec<i8>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        let data = values.iter().map(|v| *v as u8).collect();
        Tensor { dtype: DType::I8, shape, data }
    }

    pub fn from_u8(shape: Vec<usize>, values: Vec<u8>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        Tensor { dtype: DType::U8, shape, data: values }
    }

    pub fn from_i32(shape: Vec<usize>, values: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        let mut data = Vec::with_capacity(values.len() * 4);
        for v in &values {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Tensor { dtype: DType::I32, shape, data }
    }

    pub fn zeros(dtype: DType, shape: Vec<usize>) -> Self {
        let n = shape.iter().product::<usize>() * dtype.itemsize();
        Tensor { dtype, shape, data: vec![0u8; n] }
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn nbytes(&self) -> usize {
        self.data.len()
    }

    pub fn bytes(&self) -> &[u8] {
        &self.data
    }

    pub fn as_f32(&self) -> Result<Vec<f32>> {
        if self.dtype != DType::F32 {
            bail!("expected f32 tensor, got {:?}", self.dtype);
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn as_i8(&self) -> Result<Vec<i8>> {
        if self.dtype != DType::I8 {
            bail!("expected i8 tensor, got {:?}", self.dtype);
        }
        Ok(self.data.iter().map(|b| *b as i8).collect())
    }

    pub fn as_u8(&self) -> Result<Vec<u8>> {
        if self.dtype != DType::U8 {
            bail!("expected u8 tensor, got {:?}", self.dtype);
        }
        Ok(self.data.clone())
    }

    pub fn as_i32(&self) -> Result<Vec<i32>> {
        if self.dtype != DType::I32 {
            bail!("expected i32 tensor, got {:?}", self.dtype);
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Reinterpret with a new shape (same element count).
    pub fn reshape(mut self, shape: Vec<usize>) -> Result<Self> {
        if shape.iter().product::<usize>() != self.len() {
            bail!("cannot reshape {:?} to {:?}", self.shape, shape);
        }
        self.shape = shape;
        Ok(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f32() {
        let t = Tensor::from_f32(vec![2, 3], vec![1.0, -2.5, 3.0, 0.0, 5.5, -6.0]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.nbytes(), 24);
        assert_eq!(t.as_f32().unwrap()[1], -2.5);
    }

    #[test]
    fn roundtrip_i8() {
        let t = Tensor::from_i8(vec![4], vec![-128, -1, 0, 127]);
        assert_eq!(t.as_i8().unwrap(), vec![-128, -1, 0, 127]);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(Tensor::from_bytes(DType::F32, vec![2, 2], vec![0u8; 15]).is_err());
    }

    #[test]
    fn dtype_mismatch_rejected() {
        let t = Tensor::from_i8(vec![1], vec![3]);
        assert!(t.as_f32().is_err());
    }

    #[test]
    fn reshape_checks_count() {
        let t = Tensor::from_f32(vec![2, 3], vec![0.0; 6]);
        assert!(t.clone().reshape(vec![3, 2]).is_ok());
        assert!(t.reshape(vec![4, 2]).is_err());
    }

    #[test]
    fn dtype_names() {
        assert_eq!(DType::from_name("float32").unwrap(), DType::F32);
        assert_eq!(DType::from_name("uint8").unwrap(), DType::U8);
        assert!(DType::from_name("f64").is_err());
    }
}
