//! The `Tensor` type: shape + dtype + contiguous host data.
//!
//! Data lives in an 8-byte-aligned buffer so the typed views
//! (`f32_view` & co.) can reinterpret the bytes in place — the zero-copy
//! contract the serving hot path relies on (`runtime::tensor_to_literal`,
//! the worker's prefill/decode output handling). The owned `as_*`
//! accessors remain for callers that genuinely need a copy.

use anyhow::{bail, Result};

/// Element types shared with the Python tensor file format and PJRT.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    I8,
    U8,
    I32,
}

impl DType {
    pub fn itemsize(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::I8 | DType::U8 => 1,
        }
    }

    pub fn code(self) -> u8 {
        match self {
            DType::F32 => 0,
            DType::I8 => 1,
            DType::U8 => 2,
            DType::I32 => 3,
        }
    }

    pub fn from_code(code: u8) -> Result<Self> {
        Ok(match code {
            0 => DType::F32,
            1 => DType::I8,
            2 => DType::U8,
            3 => DType::I32,
            _ => bail!("unknown dtype code {code}"),
        })
    }

    /// Manifest dtype strings ("f32" / "i8" / "u8" / "i32" and numpy names).
    pub fn from_name(name: &str) -> Result<Self> {
        Ok(match name {
            "f32" | "float32" => DType::F32,
            "i8" | "int8" => DType::I8,
            "u8" | "uint8" => DType::U8,
            "i32" | "int32" => DType::I32,
            _ => bail!("unknown dtype name {name}"),
        })
    }
}

/// View a POD slice as its little-endian bytes (host is LE on all
/// supported targets; PJRT and the tensor file format use the same
/// layout). The crate's single byte-reinterpret site —
/// `runtime::f32_bytes`/`i32_bytes` delegate here.
pub(crate) fn pod_bytes<T: Copy>(v: &[T]) -> &[u8] {
    // SAFETY: u8 has alignment 1 and any bit pattern of a POD element is
    // a valid byte sequence; the length covers exactly the slice.
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, std::mem::size_of_val(v)) }
}

/// 8-byte-aligned byte buffer. A plain `Vec<u8>` only has alignment 1, so
/// reinterpreting it as `&[f32]` would rely on allocator luck; backing the
/// bytes with `u64` words makes the alignment a guarantee, which is what
/// lets the dtype views below be safe unconditionally.
#[derive(Clone)]
struct AlignedBytes {
    buf: Vec<u64>,
    len: usize,
}

impl AlignedBytes {
    fn from_slice(bytes: &[u8]) -> Self {
        // one memcpy into a pre-sized, zero-initialized word buffer (any
        // trailing pad bytes stay zero)
        let mut buf = vec![0u64; bytes.len().div_ceil(8)];
        // SAFETY: buf owns at least bytes.len() initialized, writable
        // bytes; u8 has alignment 1; the regions cannot overlap.
        unsafe {
            std::ptr::copy_nonoverlapping(
                bytes.as_ptr(),
                buf.as_mut_ptr() as *mut u8,
                bytes.len(),
            );
        }
        AlignedBytes { buf, len: bytes.len() }
    }

    fn zeroed(len: usize) -> Self {
        AlignedBytes { buf: vec![0u64; len.div_ceil(8)], len }
    }

    fn as_slice(&self) -> &[u8] {
        // SAFETY: buf holds at least len bytes; u8 has alignment 1.
        unsafe { std::slice::from_raw_parts(self.buf.as_ptr() as *const u8, self.len) }
    }

    /// Reinterpret as a typed slice. Only instantiated with POD element
    /// types of alignment <= 8 (f32 / i32 / i8 / u8); callers guarantee
    /// `len` is a multiple of the element size (enforced by the shape *
    /// itemsize invariant of `Tensor`).
    fn as_typed<T: Copy>(&self) -> &[T] {
        let size = std::mem::size_of::<T>();
        debug_assert!(std::mem::align_of::<T>() <= 8);
        debug_assert_eq!(self.len % size, 0);
        // SAFETY: the buffer is 8-byte aligned by construction, holds at
        // least `len` initialized bytes, and T is POD.
        unsafe { std::slice::from_raw_parts(self.buf.as_ptr() as *const T, self.len / size) }
    }
}

impl std::fmt::Debug for AlignedBytes {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "AlignedBytes({} bytes)", self.len)
    }
}

impl PartialEq for AlignedBytes {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

/// A host tensor: contiguous row-major data with shape and dtype.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub dtype: DType,
    pub shape: Vec<usize>,
    data: AlignedBytes,
}

impl Tensor {
    /// Build from raw bytes (one copy into the aligned storage).
    pub fn from_bytes(dtype: DType, shape: Vec<usize>, data: &[u8]) -> Result<Self> {
        let want = shape.iter().product::<usize>() * dtype.itemsize();
        if data.len() != want {
            bail!(
                "tensor data length {} does not match shape {:?} ({} bytes)",
                data.len(),
                shape,
                want
            );
        }
        Ok(Tensor { dtype, shape, data: AlignedBytes::from_slice(data) })
    }

    pub fn from_f32(shape: Vec<usize>, values: Vec<f32>) -> Self {
        Self::from_f32_slice(shape, &values)
    }

    /// Build from a borrowed slice — one copy, no staging Vec (the
    /// `graph_inputs` hot path).
    pub fn from_f32_slice(shape: Vec<usize>, values: &[f32]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        Tensor { dtype: DType::F32, shape, data: AlignedBytes::from_slice(pod_bytes(values)) }
    }

    pub fn from_i8(shape: Vec<usize>, values: Vec<i8>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        Tensor { dtype: DType::I8, shape, data: AlignedBytes::from_slice(pod_bytes(&values)) }
    }

    pub fn from_u8(shape: Vec<usize>, values: Vec<u8>) -> Self {
        Self::from_u8_slice(shape, &values)
    }

    /// Build from a borrowed slice — one copy, no staging Vec.
    pub fn from_u8_slice(shape: Vec<usize>, values: &[u8]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        Tensor { dtype: DType::U8, shape, data: AlignedBytes::from_slice(values) }
    }

    pub fn from_i32(shape: Vec<usize>, values: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        Tensor { dtype: DType::I32, shape, data: AlignedBytes::from_slice(pod_bytes(&values)) }
    }

    pub fn zeros(dtype: DType, shape: Vec<usize>) -> Self {
        let n = shape.iter().product::<usize>() * dtype.itemsize();
        Tensor { dtype, shape, data: AlignedBytes::zeroed(n) }
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn nbytes(&self) -> usize {
        self.data.len
    }

    pub fn bytes(&self) -> &[u8] {
        self.data.as_slice()
    }

    // -- zero-copy views ----------------------------------------------------

    /// Borrow the elements as `&[f32]` without copying.
    pub fn f32_view(&self) -> Result<&[f32]> {
        if self.dtype != DType::F32 {
            bail!("expected f32 tensor, got {:?}", self.dtype);
        }
        Ok(self.data.as_typed::<f32>())
    }

    /// Borrow the elements as `&[i8]` without copying.
    pub fn i8_view(&self) -> Result<&[i8]> {
        if self.dtype != DType::I8 {
            bail!("expected i8 tensor, got {:?}", self.dtype);
        }
        Ok(self.data.as_typed::<i8>())
    }

    /// Borrow the elements as `&[u8]` without copying.
    pub fn u8_view(&self) -> Result<&[u8]> {
        if self.dtype != DType::U8 {
            bail!("expected u8 tensor, got {:?}", self.dtype);
        }
        Ok(self.data.as_typed::<u8>())
    }

    /// Borrow the elements as `&[i32]` without copying.
    pub fn i32_view(&self) -> Result<&[i32]> {
        if self.dtype != DType::I32 {
            bail!("expected i32 tensor, got {:?}", self.dtype);
        }
        Ok(self.data.as_typed::<i32>())
    }

    // -- owned accessors (copying; prefer the views on hot paths) -----------

    pub fn as_f32(&self) -> Result<Vec<f32>> {
        Ok(self.f32_view()?.to_vec())
    }

    pub fn as_i8(&self) -> Result<Vec<i8>> {
        Ok(self.i8_view()?.to_vec())
    }

    pub fn as_u8(&self) -> Result<Vec<u8>> {
        Ok(self.u8_view()?.to_vec())
    }

    pub fn as_i32(&self) -> Result<Vec<i32>> {
        Ok(self.i32_view()?.to_vec())
    }

    /// Reinterpret with a new shape (same element count).
    pub fn reshape(mut self, shape: Vec<usize>) -> Result<Self> {
        if shape.iter().product::<usize>() != self.len() {
            bail!("cannot reshape {:?} to {:?}", self.shape, shape);
        }
        self.shape = shape;
        Ok(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f32() {
        let t = Tensor::from_f32(vec![2, 3], vec![1.0, -2.5, 3.0, 0.0, 5.5, -6.0]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.nbytes(), 24);
        assert_eq!(t.as_f32().unwrap()[1], -2.5);
    }

    #[test]
    fn roundtrip_i8() {
        let t = Tensor::from_i8(vec![4], vec![-128, -1, 0, 127]);
        assert_eq!(t.as_i8().unwrap(), vec![-128, -1, 0, 127]);
    }

    #[test]
    fn views_are_zero_copy_and_equal_to_owned() {
        let vals = vec![1.0f32, -2.5, 3.25, 0.0, 5.5];
        let t = Tensor::from_f32(vec![5], vals.clone());
        let v = t.f32_view().unwrap();
        assert_eq!(v, &vals[..]);
        // the view points into the tensor's own storage
        assert_eq!(v.as_ptr() as usize, t.bytes().as_ptr() as usize);

        let ti = Tensor::from_i32(vec![3], vec![-7, 0, 9]);
        assert_eq!(ti.i32_view().unwrap(), &[-7, 0, 9]);
        let tu = Tensor::from_u8(vec![3], vec![1, 2, 255]);
        assert_eq!(tu.u8_view().unwrap(), &[1, 2, 255]);
        let tb = Tensor::from_i8(vec![2], vec![-1, 1]);
        assert_eq!(tb.i8_view().unwrap(), &[-1, 1]);
    }

    #[test]
    fn view_buffers_are_aligned() {
        // odd byte counts still yield 8-byte-aligned storage
        for n in [1usize, 3, 5, 7, 9, 1023] {
            let t = Tensor::from_u8(vec![n], vec![7u8; n]);
            assert_eq!(t.bytes().as_ptr() as usize % 8, 0, "n={n}");
        }
        let t = Tensor::from_f32(vec![3], vec![1.0, 2.0, 3.0]);
        assert_eq!(t.f32_view().unwrap().as_ptr() as usize % 4, 0);
    }

    #[test]
    fn bytes_survive_roundtrip_through_file_format() {
        let t = Tensor::from_f32(vec![2], vec![1.5, -2.5]);
        let back = Tensor::from_bytes(DType::F32, vec![2], t.bytes()).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(Tensor::from_bytes(DType::F32, vec![2, 2], &[0u8; 15]).is_err());
    }

    #[test]
    fn dtype_mismatch_rejected() {
        let t = Tensor::from_i8(vec![1], vec![3]);
        assert!(t.as_f32().is_err());
        assert!(t.f32_view().is_err());
    }

    #[test]
    fn reshape_checks_count() {
        let t = Tensor::from_f32(vec![2, 3], vec![0.0; 6]);
        assert!(t.clone().reshape(vec![3, 2]).is_ok());
        assert!(t.reshape(vec![4, 2]).is_err());
    }

    #[test]
    fn dtype_names() {
        assert_eq!(DType::from_name("float32").unwrap(), DType::F32);
        assert_eq!(DType::from_name("uint8").unwrap(), DType::U8);
        assert!(DType::from_name("f64").is_err());
    }
}
