//! Flat tensor container — bit-compatible with python/compile/tensorfile.py.
//!
//! Layout (little-endian):
//!   magic  8B  "LLEQTNSR"
//!   count  u32
//!   per tensor: name_len u16, name, dtype u8, ndim u8, dims u64*, data

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::{DType, Tensor};

const MAGIC: &[u8; 8] = b"LLEQTNSR";

/// Load every tensor in a container file, keyed by name.
pub fn load_tensor_file(path: &Path) -> Result<BTreeMap<String, Tensor>> {
    let mut data = Vec::new();
    std::fs::File::open(path)
        .with_context(|| format!("opening tensor file {}", path.display()))?
        .read_to_end(&mut data)?;
    parse(&data).with_context(|| format!("parsing {}", path.display()))
}

fn parse(data: &[u8]) -> Result<BTreeMap<String, Tensor>> {
    if data.len() < 12 || &data[..8] != MAGIC {
        bail!("bad magic");
    }
    let mut off = 8usize;
    let count = u32::from_le_bytes(data[off..off + 4].try_into()?) as usize;
    off += 4;
    let mut out = BTreeMap::new();
    for _ in 0..count {
        let nlen = u16::from_le_bytes(data[off..off + 2].try_into()?) as usize;
        off += 2;
        let name = std::str::from_utf8(&data[off..off + nlen])?.to_string();
        off += nlen;
        let dtype = DType::from_code(data[off])?;
        let ndim = data[off + 1] as usize;
        off += 2;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(u64::from_le_bytes(data[off..off + 8].try_into()?) as usize);
            off += 8;
        }
        let nbytes = shape.iter().product::<usize>() * dtype.itemsize();
        if off + nbytes > data.len() {
            bail!("truncated tensor data for {name}");
        }
        let t = Tensor::from_bytes(dtype, shape, &data[off..off + nbytes])?;
        off += nbytes;
        out.insert(name, t);
    }
    Ok(out)
}

/// Save tensors in the shared container format (sorted by name).
pub fn save_tensor_file(path: &Path, tensors: &BTreeMap<String, Tensor>) -> Result<()> {
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating tensor file {}", path.display()))?;
    f.write_all(MAGIC)?;
    f.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, t) in tensors {
        let nb = name.as_bytes();
        f.write_all(&(nb.len() as u16).to_le_bytes())?;
        f.write_all(nb)?;
        f.write_all(&[t.dtype.code(), t.shape.len() as u8])?;
        for d in &t.shape {
            f.write_all(&(*d as u64).to_le_bytes())?;
        }
        f.write_all(t.bytes())?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn save_load_roundtrip() {
        let mut m = BTreeMap::new();
        m.insert("a.w".into(), Tensor::from_f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]));
        m.insert("b.q".into(), Tensor::from_i8(vec![3], vec![-1, 0, 1]));
        m.insert("c.u".into(), Tensor::from_u8(vec![2], vec![0, 255]));
        m.insert("d.i".into(), Tensor::from_i32(vec![1], vec![-7]));
        let dir = std::env::temp_dir().join("lleq_test_tensorfile");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.bin");
        save_tensor_file(&p, &m).unwrap();
        let got = load_tensor_file(&p).unwrap();
        assert_eq!(got, m);
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(parse(b"NOTMAGIC\x00\x00\x00\x00").is_err());
    }

    #[test]
    fn rejects_truncated() {
        let mut m = BTreeMap::new();
        m.insert("x".into(), Tensor::from_f32(vec![4], vec![0.0; 4]));
        let dir = std::env::temp_dir().join("lleq_test_tensorfile2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.bin");
        save_tensor_file(&p, &m).unwrap();
        let data = std::fs::read(&p).unwrap();
        assert!(parse(&data[..data.len() - 4]).is_err());
    }
}
