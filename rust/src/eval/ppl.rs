//! Perplexity on the held-out corpus split (Tables 1 & 4, Fig. 2).
//!
//! Both languages generate the identical validation stream
//! (corpus seed 1234, train 200k / valid 20k); windows of CTX tokens run
//! through the batch-8 prefill graph and next-token NLL is averaged over
//! every in-window prediction.

use anyhow::Result;

use crate::corpus;
use crate::quant::Variant;
use crate::runtime::Registry;
use crate::tensor::Tensor;

pub const N_TRAIN: usize = 200_000;
pub const N_VALID: usize = 20_000;
pub const CORPUS_SEED: u64 = 1234;

#[derive(Debug, Clone)]
pub struct PplResult {
    pub model: String,
    pub variant: Variant,
    pub ppl: f64,
    pub nll: f64,
    pub tokens: usize,
    pub windows: usize,
}

/// Evaluate perplexity of (model, variant) over `max_windows` validation
/// windows (0 = all).
pub fn perplexity(
    reg: &Registry,
    model: &str,
    variant: Variant,
    max_windows: usize,
) -> Result<PplResult> {
    let cfg = reg.model_cfg(model)?.clone();
    let ctx = cfg.ctx;
    let v = cfg.vocab;
    let (_, valid) = corpus::train_valid_split(N_TRAIN, N_VALID, CORPUS_SEED);

    // non-overlapping windows of ctx+1 tokens (predict last ctx)
    let mut windows: Vec<&[i32]> = valid.chunks_exact(ctx + 1).collect();
    if max_windows > 0 {
        windows.truncate(max_windows);
    }
    let batch = 8;
    let handle = reg.model_handle(model, variant, batch)?;

    let mut total_nll = 0f64;
    let mut total_tok = 0usize;
    for group in windows.chunks(batch) {
        let mut tokens = vec![corpus::PAD; batch * ctx];
        for (slot, w) in group.iter().enumerate() {
            tokens[slot * ctx..(slot + 1) * ctx].copy_from_slice(&w[..ctx]);
        }
        let outs = handle.prefill(&[Tensor::from_i32(vec![batch, ctx], tokens)])?;
        let logits = outs[0].f32_view()?; // [B, CTX, V] (zero-copy)
        for (slot, w) in group.iter().enumerate() {
            for t in 0..ctx - 1 {
                let target = w[t + 1];
                if target == corpus::PAD {
                    continue;
                }
                let row = &logits[(slot * ctx + t) * v..(slot * ctx + t + 1) * v];
                total_nll += nll_of(row, target as usize);
                total_tok += 1;
            }
        }
    }
    let nll = total_nll / total_tok.max(1) as f64;
    Ok(PplResult {
        model: model.to_string(),
        variant,
        ppl: nll.exp(),
        nll,
        tokens: total_tok,
        windows: windows.len(),
    })
}

/// `-log softmax(row)[target]`, numerically stable.
pub fn nll_of(row: &[f32], target: usize) -> f64 {
    let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let lse: f64 = row.iter().map(|x| ((*x as f64) - mx).exp()).sum::<f64>().ln() + mx;
    lse - row[target] as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nll_uniform_is_log_v() {
        let row = vec![0f32; 32];
        let n = nll_of(&row, 7);
        assert!((n - (32f64).ln()).abs() < 1e-9);
    }

    #[test]
    fn nll_confident_is_small() {
        let mut row = vec![0f32; 8];
        row[3] = 20.0;
        assert!(nll_of(&row, 3) < 1e-6);
        assert!(nll_of(&row, 0) > 10.0);
    }

    #[test]
    fn nll_stable_at_large_magnitudes() {
        let row = vec![1e4f32, 1e4 - 5.0];
        let n = nll_of(&row, 0);
        assert!(n.is_finite() && n < 0.01);
    }
}
