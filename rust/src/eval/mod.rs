//! Evaluation drivers: perplexity over the held-out corpus split, plus
//! weight reconstruction error summaries.

mod ppl;
mod werr;

pub use ppl::{perplexity, PplResult};
pub use werr::{weight_errors, WeightErr};
