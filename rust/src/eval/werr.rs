//! Weight reconstruction error per (linear, variant) — feeds the
//! distribution analysis (Fig. 1) and the t-SNE features (Fig. 7).

use anyhow::Result;

use crate::quant::prepare::{effective_weight, prepare_linear, Checkpoint};
use crate::quant::Variant;
use crate::runtime::ModelCfg;

#[derive(Debug, Clone)]
pub struct WeightErr {
    pub linear: String,
    pub variant: Variant,
    pub mse: f64,
    pub max_abs: f64,
    /// dequantized weights (for histogram/feature extraction)
    pub w_hat: Vec<f32>,
}

/// Linears of a model in manifest order: (name, K, N).
pub fn model_linears(cfg: &ModelCfg) -> Vec<(String, usize, usize)> {
    let d = cfg.d_model;
    let f = cfg.d_ff();
    let mut out = Vec::new();
    for i in 0..cfg.n_layers {
        out.push((format!("h{i}.qkv"), d, 3 * d));
        out.push((format!("h{i}.attn_out"), d, d));
        out.push((format!("h{i}.fc1"), d, f));
        out.push((format!("h{i}.fc2"), f, d));
    }
    out
}

/// Quantize every linear under `variant`, returning reconstruction errors
/// and the effective dequantized weights.
pub fn weight_errors(
    cfg: &ModelCfg,
    ckpt: &Checkpoint,
    variant: Variant,
) -> Result<Vec<WeightErr>> {
    let mut out = Vec::new();
    for (name, k, n) in model_linears(cfg) {
        let prepared = prepare_linear(variant, &name, ckpt, cfg.zq_group, 0.5)?;
        let w_hat = effective_weight(variant, &prepared, k, n, cfg.zq_group)?;
        let w = ckpt.f32(&format!("{name}_w"))?;
        let mut mse = 0f64;
        let mut max_abs = 0f64;
        for (a, b) in w.iter().zip(&w_hat) {
            let e = (*a - *b) as f64;
            mse += e * e;
            max_abs = max_abs.max(e.abs());
        }
        mse /= w.len() as f64;
        out.push(WeightErr { linear: name, variant, mse, max_abs, w_hat });
    }
    Ok(out)
}
