//! Executable registry: lazy compilation + weight-literal caching.
//!
//! One compiled executable per (model, variant, phase, batch); one prepared
//! weight-literal list per (model, graph-variant). Weight literals are
//! built once at load time so the decode hot loop only constructs the small
//! runtime tensors (token ids, positions, KV pages).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::quant::prepare::{self, Checkpoint};
use crate::quant::Variant;
use crate::tensor::{load_tensor_file, Tensor};

use super::engine::{tensor_to_literal, Engine, Executable, Literal};

use super::manifest::{GraphKey, Manifest, ModelCfg};

/// Cached, prepared weight inputs for one (model, graph variant).
struct PreparedWeights {
    literals: Vec<Literal>,
    storage_bytes: usize,
}

// SAFETY: literals are immutable after construction and PJRT copies them
// on execute; see runtime::engine docs.
unsafe impl Send for PreparedWeights {}
unsafe impl Sync for PreparedWeights {}

/// The artifact registry.
pub struct Registry {
    engine: Engine,
    manifest: Manifest,
    dir: PathBuf,
    checkpoints: Mutex<HashMap<String, Arc<Checkpoint>>>,
    executables: Mutex<HashMap<GraphKey, Arc<Executable>>>,
    weights: Mutex<HashMap<(String, Variant), Arc<PreparedWeights>>>,
    pub sq_alpha: f32,
}

impl Registry {
    /// Open an artifacts directory (manifest.json + *.hlo.txt + weights).
    pub fn open(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        Ok(Registry {
            engine: Engine::cpu()?,
            manifest,
            dir: dir.to_path_buf(),
            checkpoints: Mutex::new(HashMap::new()),
            executables: Mutex::new(HashMap::new()),
            weights: Mutex::new(HashMap::new()),
            sq_alpha: 0.5,
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn model_cfg(&self, model: &str) -> Result<&ModelCfg> {
        self.manifest.model(model)
    }

    pub fn checkpoint(&self, model: &str) -> Result<Arc<Checkpoint>> {
        let mut map = self.checkpoints.lock().unwrap();
        if let Some(c) = map.get(model) {
            return Ok(c.clone());
        }
        let path = self.dir.join(format!("{model}.weights.bin"));
        let tensors = load_tensor_file(&path)
            .with_context(|| format!("loading checkpoint for {model}"))?;
        let ckpt = Arc::new(Checkpoint::new(tensors));
        map.insert(model.to_string(), ckpt.clone());
        Ok(ckpt)
    }

    /// Compile (or fetch) an executable for a graph key.
    pub fn executable(&self, key: &GraphKey) -> Result<Arc<Executable>> {
        {
            let map = self.executables.lock().unwrap();
            if let Some(e) = map.get(key) {
                return Ok(e.clone());
            }
        }
        let spec = self.manifest.graph(key)?;
        let exe = Arc::new(self.engine.compile_hlo_file(&self.dir.join(&spec.file))?);
        self.executables
            .lock()
            .unwrap()
            .insert(key.clone(), exe.clone());
        Ok(exe)
    }

    /// Prepare (or fetch) the weight literal list for (model, variant).
    fn prepared(&self, model: &str, variant: Variant) -> Result<Arc<PreparedWeights>> {
        let cache_key = (model.to_string(), variant);
        {
            let map = self.weights.lock().unwrap();
            if let Some(w) = map.get(&cache_key) {
                return Ok(w.clone());
            }
        }
        let cfg = self.manifest.model(model)?;
        // weight specs are identical across phases/batches: use prefill b1
        let gkey = GraphKey::new(model, variant.graph_variant(), "prefill", 1);
        let spec = self.manifest.graph(&gkey)?;
        let (w_specs, _) = spec.split_weights();
        let ckpt = self.checkpoint(model)?;
        let tensors =
            prepare::prepare_inputs(variant, w_specs, &ckpt, cfg.zq_group, self.sq_alpha)?;
        let storage_bytes = prepare::weight_storage_bytes(variant, w_specs);
        let literals = tensors
            .iter()
            .map(tensor_to_literal)
            .collect::<Result<Vec<_>>>()?;
        let out = Arc::new(PreparedWeights { literals, storage_bytes });
        self.weights.lock().unwrap().insert(cache_key, out.clone());
        Ok(out)
    }

    /// Build a ready-to-run handle for (model, method variant, batch).
    pub fn model_handle(
        &self,
        model: &str,
        variant: Variant,
        batch: usize,
    ) -> Result<ModelHandle> {
        let graph_variant = variant.graph_variant();
        let prefill =
            self.executable(&GraphKey::new(model, graph_variant, "prefill", batch))?;
        let decode = self.executable(&GraphKey::new(model, graph_variant, "decode", batch))?;
        let weights = self.prepared(model, variant)?;
        let cfg = self.manifest.model(model)?.clone();
        Ok(ModelHandle { cfg, variant, batch, prefill, decode, weights })
    }
}

/// Everything a worker needs to serve one (model, variant, batch) config.
pub struct ModelHandle {
    pub cfg: ModelCfg,
    pub variant: Variant,
    pub batch: usize,
    prefill: Arc<Executable>,
    decode: Arc<Executable>,
    weights: Arc<PreparedWeights>,
}

impl ModelHandle {
    /// Weight storage footprint (bytes) under this variant.
    pub fn weight_storage_bytes(&self) -> usize {
        self.weights.storage_bytes
    }

    fn run(&self, exe: &Executable, runtime_inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        // weight literals were built once at load time; borrow them and
        // only materialize the (small) runtime inputs per call
        let runtime_lits: Vec<Literal> = runtime_inputs
            .iter()
            .map(tensor_to_literal)
            .collect::<Result<_>>()?;
        let mut refs: Vec<&Literal> =
            Vec::with_capacity(self.weights.literals.len() + runtime_lits.len());
        refs.extend(self.weights.literals.iter());
        refs.extend(runtime_lits.iter());
        exe.run_borrowed(&refs)
    }

    /// Run the prefill graph: `weights ++ [tokens]`.
    pub fn prefill(&self, runtime_inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.run(&self.prefill, runtime_inputs)
    }

    /// Run one decode step: weights ++ [token, pos, caches...].
    pub fn decode(&self, runtime_inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.run(&self.decode, runtime_inputs)
    }

    /// Decode with caller-built literals (the zero-staging-copy hot path:
    /// the KV manager exposes raw byte views and the worker builds
    /// literals straight from them).
    pub fn decode_literals(&self, runtime_lits: &[Literal]) -> Result<Vec<Tensor>> {
        let mut refs: Vec<&Literal> =
            Vec::with_capacity(self.weights.literals.len() + runtime_lits.len());
        refs.extend(self.weights.literals.iter());
        refs.extend(runtime_lits.iter());
        self.decode.run_borrowed(&refs)
    }
}
