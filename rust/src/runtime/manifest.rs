//! Artifact manifest: the graph registry written by python/compile/aot.py.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::quant::prepare::InputSpec;
use crate::tensor::DType;
use crate::util::json::{self, Value};

/// Model configuration exported by the AOT pipeline.
#[derive(Debug, Clone)]
pub struct ModelCfg {
    pub name: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub ctx: usize,
    pub vocab: usize,
    pub zq_group: usize,
    pub n_params: usize,
}

impl ModelCfg {
    pub fn d_ff(&self) -> usize {
        4 * self.d_model
    }

    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }
}

/// Key of one lowered graph: model / variant / phase / batch.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct GraphKey {
    pub model: String,
    pub variant: String,
    pub phase: String,
    pub batch: usize,
}

impl GraphKey {
    pub fn new(model: &str, variant: &str, phase: &str, batch: usize) -> Self {
        GraphKey {
            model: model.into(),
            variant: variant.into(),
            phase: phase.into(),
            batch,
        }
    }

    pub fn manifest_key(&self) -> String {
        format!("{}/{}/{}/b{}", self.model, self.variant, self.phase, self.batch)
    }
}

/// One graph's artifact file + IO signature.
#[derive(Debug, Clone)]
pub struct GraphSpec {
    pub file: String,
    pub inputs: Vec<InputSpec>,
    pub outputs: Vec<(Vec<usize>, DType)>,
}

impl GraphSpec {
    /// Split the input list into (weight inputs, runtime inputs): runtime
    /// inputs are the trailing non-dotted names emitted by aot.py
    /// (`tokens`, `token`, `pos`, `k_cache`, ...).
    pub fn split_weights(&self) -> (&[InputSpec], &[InputSpec]) {
        const RUNTIME_NAMES: [&str; 10] = [
            "tokens", "token", "pos", "k_cache", "v_cache", "k_min", "k_step", "v_min",
            "v_step", "mask",
        ];
        let split = self
            .inputs
            .iter()
            .position(|s| RUNTIME_NAMES.contains(&s.name.as_str()))
            .unwrap_or(self.inputs.len());
        self.inputs.split_at(split)
    }
}

/// Full manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub models: BTreeMap<String, ModelCfg>,
    pub graphs: BTreeMap<String, GraphSpec>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let v = json::parse(text)?;
        let mut models = BTreeMap::new();
        for (name, m) in v
            .get("models")
            .and_then(Value::as_obj)
            .ok_or_else(|| anyhow!("manifest missing models"))?
        {
            let get = |k: &str| -> Result<usize> {
                m.get(k)
                    .and_then(Value::as_usize)
                    .ok_or_else(|| anyhow!("model {name} missing {k}"))
            };
            models.insert(
                name.clone(),
                ModelCfg {
                    name: name.clone(),
                    d_model: get("d_model")?,
                    n_layers: get("n_layers")?,
                    n_heads: get("n_heads")?,
                    ctx: get("ctx")?,
                    vocab: get("vocab")?,
                    zq_group: get("zq_group")?,
                    n_params: get("n_params")?,
                },
            );
        }
        let mut graphs = BTreeMap::new();
        for (key, g) in v
            .get("graphs")
            .and_then(Value::as_obj)
            .ok_or_else(|| anyhow!("manifest missing graphs"))?
        {
            let file = g
                .get("file")
                .and_then(Value::as_str)
                .ok_or_else(|| anyhow!("graph {key} missing file"))?
                .to_string();
            let mut inputs = Vec::new();
            for inp in g
                .get("inputs")
                .and_then(Value::as_arr)
                .ok_or_else(|| anyhow!("graph {key} missing inputs"))?
            {
                inputs.push(InputSpec {
                    name: inp
                        .get("name")
                        .and_then(Value::as_str)
                        .ok_or_else(|| anyhow!("input missing name"))?
                        .to_string(),
                    shape: inp
                        .get("shape")
                        .and_then(Value::as_arr)
                        .ok_or_else(|| anyhow!("input missing shape"))?
                        .iter()
                        .map(|d| d.as_usize().unwrap_or(0))
                        .collect(),
                    dtype: DType::from_name(
                        inp.get("dtype").and_then(Value::as_str).unwrap_or("f32"),
                    )?,
                });
            }
            let mut outputs = Vec::new();
            for out in g.get("outputs").and_then(Value::as_arr).unwrap_or(&[]) {
                let shape: Vec<usize> = out
                    .get("shape")
                    .and_then(Value::as_arr)
                    .unwrap_or(&[])
                    .iter()
                    .map(|d| d.as_usize().unwrap_or(0))
                    .collect();
                let dtype =
                    DType::from_name(out.get("dtype").and_then(Value::as_str).unwrap_or("f32"))?;
                outputs.push((shape, dtype));
            }
            graphs.insert(key.clone(), GraphSpec { file, inputs, outputs });
        }
        Ok(Manifest { models, graphs })
    }

    pub fn graph(&self, key: &GraphKey) -> Result<&GraphSpec> {
        self.graphs
            .get(&key.manifest_key())
            .ok_or_else(|| anyhow!("manifest has no graph {}", key.manifest_key()))
    }

    pub fn model(&self, name: &str) -> Result<&ModelCfg> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("manifest has no model {name}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "models": {"gpt2-tiny": {"d_model": 128, "n_layers": 2, "n_heads": 4,
                  "ctx": 128, "vocab": 32, "zq_group": 64, "n_params": 500000}},
      "graphs": {"gpt2-tiny/fp/prefill/b1": {
        "file": "gpt2-tiny_fp_prefill_b1.hlo.txt",
        "inputs": [
          {"name": "wte", "shape": [32, 128], "dtype": "f32"},
          {"name": "h0.qkv.w", "shape": [128, 384], "dtype": "f32"},
          {"name": "tokens", "shape": [1, 128], "dtype": "i32"}],
        "outputs": [{"shape": [1, 128, 32], "dtype": "float32"}]
      }},
      "corpus": {"seed": 1234}
    }"#;

    #[test]
    fn parses_models_and_graphs() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.model("gpt2-tiny").unwrap().d_model, 128);
        let g = m.graph(&GraphKey::new("gpt2-tiny", "fp", "prefill", 1)).unwrap();
        assert_eq!(g.inputs.len(), 3);
        assert_eq!(g.outputs[0].0, vec![1, 128, 32]);
    }

    #[test]
    fn split_weights_finds_runtime_boundary() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let g = m.graph(&GraphKey::new("gpt2-tiny", "fp", "prefill", 1)).unwrap();
        let (w, r) = g.split_weights();
        assert_eq!(w.len(), 2);
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].name, "tokens");
    }

    #[test]
    fn missing_graph_errors() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.graph(&GraphKey::new("gpt2-tiny", "fp", "decode", 1)).is_err());
    }
}
