//! PJRT engine: compile HLO text, execute with host tensors.
//!
//! Thread-safety: the xla crate's wrappers hold raw pointers without
//! Send/Sync markers, but the underlying PJRT C API is thread-safe for
//! compilation and execution (clients own an internal thread pool and all
//! entry points lock internally — the same executable is executed
//! concurrently by every serving framework built on PJRT). `Engine` and
//! `Executable` therefore wrap them in types we mark Send + Sync; the
//! worker pool shares executables via `Arc`.

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::tensor::{DType, Tensor};

/// The PJRT literal type callers pass around (`runtime::Literal` is the
/// backend-independent name; the no-`xla` stub provides its own).
pub type Literal = xla::Literal;

fn element_type(dt: DType) -> xla::ElementType {
    match dt {
        DType::F32 => xla::ElementType::F32,
        DType::I8 => xla::ElementType::S8,
        DType::U8 => xla::ElementType::U8,
        DType::I32 => xla::ElementType::S32,
    }
}

fn dtype_of(ty: xla::ElementType) -> Result<DType> {
    Ok(match ty {
        xla::ElementType::F32 => DType::F32,
        xla::ElementType::S8 => DType::I8,
        xla::ElementType::U8 => DType::U8,
        xla::ElementType::S32 => DType::I32,
        other => anyhow::bail!("unsupported output element type {other:?}"),
    })
}

/// Convert a host tensor into a PJRT literal.
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    literal_from_raw(t.dtype, &t.shape, t.bytes())
}

/// Build a literal directly from raw bytes — the zero-intermediate-copy
/// path the decode loop uses (PJRT copies once at creation; no staging
/// Tensor clone).
pub fn literal_from_raw(dtype: DType, shape: &[usize], bytes: &[u8]) -> Result<xla::Literal> {
    xla::Literal::create_from_shape_and_untyped_data(element_type(dtype), shape, bytes)
        .map_err(|e| anyhow!("literal creation failed: {e:?}"))
}

/// Convert a PJRT literal back into a host tensor.
pub fn literal_to_tensor(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape().map_err(|e| anyhow!("literal shape: {e:?}"))?;
    let dt = dtype_of(shape.ty())?;
    let dims: Vec<usize> = shape.dims().iter().map(|d| *d as usize).collect();
    let err = |e| anyhow!("literal to_vec: {e:?}");
    Ok(match dt {
        DType::F32 => Tensor::from_f32(dims, lit.to_vec::<f32>().map_err(err)?),
        DType::I8 => Tensor::from_i8(dims, lit.to_vec::<i8>().map_err(err)?),
        DType::U8 => Tensor::from_u8(dims, lit.to_vec::<u8>().map_err(err)?),
        DType::I32 => Tensor::from_i32(dims, lit.to_vec::<i32>().map_err(err)?),
    })
}

/// A compiled graph ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

// SAFETY: PJRT executables are internally synchronized; see module docs.
unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

impl Executable {
    /// Execute with host tensors; returns the flattened output tuple.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(tensor_to_literal)
            .collect::<Result<_>>()?;
        self.run_literals(&literals)
    }

    /// Execute with pre-built literals (lets callers cache weight literals
    /// off the hot path).
    pub fn run_literals(&self, literals: &[xla::Literal]) -> Result<Vec<Tensor>> {
        let refs: Vec<&xla::Literal> = literals.iter().collect();
        self.run_borrowed(&refs)
    }

    /// Execute with borrowed literals — the hot path: cached weight
    /// literals are borrowed, only the runtime inputs are fresh.
    pub fn run_borrowed(&self, literals: &[&xla::Literal]) -> Result<Vec<Tensor>> {
        let result = self
            .exe
            .execute::<&xla::Literal>(literals)
            .map_err(|e| anyhow!("pjrt execute: {e:?}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal_sync: {e:?}"))?;
        // graphs are lowered with return_tuple=True
        let parts = out.to_tuple().map_err(|e| anyhow!("to_tuple: {e:?}"))?;
        parts.iter().map(literal_to_tensor).collect()
    }
}

/// The PJRT client + compiler.
pub struct Engine {
    client: xla::PjRtClient,
}

// SAFETY: see module docs.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl Engine {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Engine { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile an HLO text artifact.
    pub fn compile_hlo_file(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing HLO text {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))
            .with_context(|| format!("artifact {}", path.display()))?;
        Ok(Executable { exe })
    }
}
