//! PJRT runtime: load AOT artifacts (HLO text), compile once, execute from
//! the serving hot path.
//!
//! The flow (see /opt/xla-example/load_hlo and DESIGN.md §6):
//!   manifest.json -> GraphSpec (input/output signatures)
//!   <model>_<variant>_<phase>_b<B>.hlo.txt -> HloModuleProto::from_text_file
//!   -> XlaComputation -> PjRtClient::cpu().compile -> Executable
//!   <model>.weights.bin -> quant::prepare -> weight input literals
//!
//! Python never runs here; the rust binary is self-contained once
//! `make artifacts` has produced the files.
//!
//! The PJRT backend is feature-gated: with `--features xla` the real
//! `engine` (PJRT via the `xla` crate) is compiled; by default the
//! API-identical `stub` backend is used instead, whose `Literal` is a
//! host buffer and whose compile/execute calls return errors — everything
//! else (quantizers, coordinator, benches) builds and runs offline.

#[cfg(feature = "xla")]
mod engine;
#[cfg(not(feature = "xla"))]
#[path = "stub.rs"]
mod engine;
mod manifest;
mod registry;
mod sim;

pub use engine::{
    literal_from_raw, literal_to_tensor, tensor_to_literal, Engine, Executable, Literal,
};
pub use manifest::{GraphKey, GraphSpec, Manifest, ModelCfg};
pub use registry::{ModelHandle, Registry};
pub use sim::{is_injected_crash, InjectedCrash, ShardFaults, SimCost, SimModel};

/// View a f32 slice as little-endian bytes (host is LE on all supported
/// targets; PJRT consumes the same layout).
pub fn f32_bytes(v: &[f32]) -> &[u8] {
    crate::tensor::pod_bytes(v)
}

/// View an i32 slice as little-endian bytes.
pub fn i32_bytes(v: &[i32]) -> &[u8] {
    crate::tensor::pod_bytes(v)
}
