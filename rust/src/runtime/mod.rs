//! PJRT runtime: load AOT artifacts (HLO text), compile once, execute from
//! the serving hot path.
//!
//! The flow (see /opt/xla-example/load_hlo and DESIGN.md §6):
//!   manifest.json -> GraphSpec (input/output signatures)
//!   <model>_<variant>_<phase>_b<B>.hlo.txt -> HloModuleProto::from_text_file
//!   -> XlaComputation -> PjRtClient::cpu().compile -> Executable
//!   <model>.weights.bin -> quant::prepare -> weight input literals
//!
//! Python never runs here; the rust binary is self-contained once
//! `make artifacts` has produced the files.

mod engine;
mod manifest;
mod registry;

pub use engine::{f32_bytes, i32_bytes, literal_from_raw, literal_to_tensor, tensor_to_literal, Engine, Executable};
pub use manifest::{GraphKey, GraphSpec, Manifest, ModelCfg};
pub use registry::{ModelHandle, Registry};
