//! Simulated execution backend for the serving engine.
//!
//! PJRT needs compiled artifacts and the `xla` feature, so the scheduler
//! layer (continuous batching, open-loop replay, the batching ablation)
//! would otherwise be untestable in the default offline build. `SimModel`
//! stands in for a compiled (prefill, decode) graph pair with the same
//! tensor contract the workers consume:
//!
//! ```text
//! prefill: tokens [B, CTX]            -> [logits [B, CTX, V],
//!                                         k [L, B, CTX, D],
//!                                         v [L, B, CTX, D]]
//! decode:  token [B], pos [B], caches -> [logits [B, V],
//!                                         k_new [L, B, D],
//!                                         v_new [L, B, D]]
//! ```
//!
//! Outputs are a pure deterministic hash of (token, position), so
//! generation is reproducible across runs, thread counts, and — crucially
//! for the scheduler tests — across *scheduling orders*: static and
//! continuous batching must produce token-identical responses, which
//! pins "the scheduler never corrupts a request's (token, pos) stream".
//!
//! Each call burns a calibrated slice of wall-clock CPU ([`SimCost`],
//! spin-waited for microsecond fidelity) so queueing, head-of-line
//! blocking, TTFT, and tail latency are real measured quantities, not
//! model outputs. The defaults approximate a small model on one GPU:
//! a fused decode step costs a fixed launch overhead plus a per-active-
//! slot increment, and prefill costs scale with ingested prompt tokens.

use std::cell::Cell;
use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::quant::Variant;
use crate::tensor::Tensor;
use crate::util::json::{self, Value};

use super::manifest::ModelCfg;

/// Wall-clock cost knobs (microseconds) for one simulated device.
#[derive(Debug, Clone, Copy)]
pub struct SimCost {
    /// prefill cost per ingested prompt token
    pub prefill_us_per_token: f64,
    /// fixed per-decode-step launch cost (paid once per fused step)
    pub decode_step_us: f64,
    /// incremental decode cost per active slot in the step
    pub decode_us_per_slot: f64,
}

/// The knobs a [`SimCost`] profile object may set (anything else in
/// the object is a typo and triggers a [`SimCost::from_profile`]
/// warning).
const PROFILE_KEYS: [&str; 3] =
    ["prefill_us_per_token", "decode_step_us", "decode_us_per_slot"];

impl Default for SimCost {
    fn default() -> Self {
        SimCost {
            prefill_us_per_token: 2.0,
            decode_step_us: 250.0,
            decode_us_per_slot: 25.0,
        }
    }
}

impl SimCost {
    /// Near-free cost model for fast scheduler unit tests.
    pub fn fast() -> Self {
        SimCost {
            prefill_us_per_token: 0.2,
            decode_step_us: 20.0,
            decode_us_per_slot: 2.0,
        }
    }

    /// Wall-clock microseconds one fused decode step costs with `active`
    /// live slots — exactly what [`SimModel::decode`] spin-waits.
    pub fn step_us(&self, active: usize) -> f64 {
        self.decode_step_us + self.decode_us_per_slot * active as f64
    }

    /// Effective decode cost per generated token when `batch` slots
    /// share each fused step: the step launch amortizes across the
    /// batch, the per-slot increment does not. This is the calibrated
    /// per-token rate the predictive admission estimator
    /// (`coordinator::cost::CostEstimator`) prices decode backlog with.
    pub fn decode_us_per_token(&self, batch: usize) -> f64 {
        self.decode_step_us / batch.max(1) as f64 + self.decode_us_per_slot
    }

    /// Expected probability that one self-speculative draft token,
    /// drawn from the `draft_bits`-wide SimQuant variant of the same
    /// weights, matches the full-width model's token at a position.
    /// The ladder is monotone in width — FineQuant-style grouping
    /// bounds the 4-bit quality gap tightly, 2-bit drafts diverge more
    /// often — and 8 bits is the serving width itself, so it always
    /// agrees. [`SimModel`] draws per-(token, pos) Bernoulli outcomes
    /// against this rate; `coordinator::cost::CostEstimator` prices
    /// speculative decode cycles with the same numbers so predictive
    /// admission stays honest.
    pub fn draft_accept_rate(draft_bits: u32) -> f64 {
        match draft_bits {
            8.. => 1.0,
            4..=7 => 0.95,
            2..=3 => 0.8,
            _ => 0.5,
        }
    }

    /// Expected tokens emitted per speculative draft/verify cycle: the
    /// accepted prefix (`sum_{i=1..k} a^i` for per-position acceptance
    /// `a`) plus one more token the verify pass always yields — the
    /// correction token when a draft missed, the bonus continuation of
    /// the last draft when all `k` landed. `k == 0` degenerates to
    /// plain decode (one token per fused step).
    pub fn spec_tokens_per_cycle(k: usize, draft_bits: u32) -> f64 {
        let a = Self::draft_accept_rate(draft_bits);
        let mut tokens = 1.0;
        let mut run = 1.0;
        for _ in 0..k {
            run *= a;
            tokens += run;
        }
        tokens
    }

    /// Read a cost profile from parsed JSON. Accepts two shapes:
    ///
    ///   * a profile object: `{"prefill_us_per_token": ..,
    ///     "decode_step_us": .., "decode_us_per_slot": ..}` (missing keys
    ///     keep their defaults), or
    ///   * the `BENCH_hotpath.json` row array written by `perf_hotpath`,
    ///     which is fitted via [`SimCost::fit_hotpath`].
    ///
    /// This is what makes the offline batching ablation quantitatively
    /// predictive: measure PJRT step times once (`cargo bench --bench
    /// perf_hotpath --features xla`), then replay scheduling experiments
    /// against the measured costs without the hardware.
    pub fn from_profile(v: &Value) -> Result<SimCost> {
        if v.as_arr().is_some() {
            return Self::fit_hotpath(v)
                .ok_or_else(|| anyhow!("hotpath rows lack a PJRT decode-step sample"));
        }
        if v.as_obj().is_none() {
            bail!("sim cost profile must be a JSON object or a hotpath row array");
        }
        for key in Self::unknown_profile_keys(v) {
            eprintln!(
                "warning: sim cost profile key {key:?} is not a SimCost knob \
                 (known: {PROFILE_KEYS:?}); it will be ignored and the knob it \
                 was probably meant to set keeps its default"
            );
        }
        let mut c = SimCost::default();
        let read = |key: &str, slot: &mut f64| -> Result<()> {
            if let Some(x) = v.get(key) {
                let x = x
                    .as_f64()
                    .ok_or_else(|| anyhow!("profile key {key} must be a number"))?;
                if !x.is_finite() || x < 0.0 {
                    bail!("profile key {key} must be finite and >= 0 (got {x})");
                }
                *slot = x;
            }
            Ok(())
        };
        read("prefill_us_per_token", &mut c.prefill_us_per_token)?;
        read("decode_step_us", &mut c.decode_step_us)?;
        read("decode_us_per_slot", &mut c.decode_us_per_slot)?;
        Ok(c)
    }

    /// Profile-object keys [`SimCost::from_profile`] does not
    /// recognize. A typo'd knob (say `decode_us_per_tok`) would
    /// otherwise be silently dropped and the real knob would quietly
    /// run with its default; `from_profile` warns on each of these.
    pub fn unknown_profile_keys(v: &Value) -> Vec<String> {
        let Some(obj) = v.as_obj() else { return Vec::new() };
        obj.iter()
            .map(|(key, _)| key.clone())
            .filter(|key| !PROFILE_KEYS.contains(&key.as_str()))
            .collect()
    }

    /// Load a cost profile from a JSON file (see [`SimCost::from_profile`]).
    pub fn load_profile(path: &Path) -> Result<SimCost> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("read sim cost profile {}: {e}", path.display()))?;
        Self::from_profile(&json::parse(&text)?)
            .map_err(|e| anyhow!("sim cost profile {}: {e}", path.display()))
    }

    /// Like [`SimCost::load_profile`], but a malformed profile degrades
    /// to the defaults with a stderr warning (naming the offending key
    /// via [`SimCost::from_profile`]'s diagnostics) instead of killing
    /// the run — an opt-in `LLEQ_SIM_PROFILE` typo should cost accuracy,
    /// not the bench.
    pub fn load_profile_or_default(path: &Path) -> SimCost {
        match Self::load_profile(path) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("warning: {e:#}; falling back to SimCost::default()");
                SimCost::default()
            }
        }
    }

    /// Fit a cost model from `perf_hotpath` rows (`[{"name", "mean_us",
    /// ...}, ...]`). The only measured decode sample is the fused b=8 PJRT
    /// step, one observation for a two-parameter model, so the split is a
    /// documented prior rather than a regression: fused decode is
    /// launch-dominated at small batch, so 70% of the step is charged as
    /// fixed cost and 30% is spread across the 8 slots. Prefill does the
    /// same per-token work as decode without the per-step launch, so
    /// prefill_us_per_token ≈ mean_us / batch. Returns `None` when no
    /// PJRT decode row is present (offline hotpath runs skip it).
    pub fn fit_hotpath(rows: &Value) -> Option<SimCost> {
        let rows = rows.as_arr()?;
        let decode_mean = rows.iter().find_map(|r| {
            let name = r.get("name")?.as_str()?;
            if name.starts_with("decode step b8") {
                r.get("mean_us")?.as_f64()
            } else {
                None
            }
        })?;
        let batch = 8.0;
        Some(SimCost {
            prefill_us_per_token: decode_mean / batch,
            decode_step_us: 0.7 * decode_mean,
            decode_us_per_slot: 0.3 * decode_mean / batch,
        })
    }
}

/// Deterministic fault schedule for one simulated shard, counted in
/// fused decode calls. Built from a seeded `coordinator::FaultPlan`;
/// executed here so the failure originates inside the "device", exactly
/// where a real crash would, and the scheduler layer above has to
/// *detect* it rather than being told.
#[derive(Debug, Clone, Copy, Default)]
pub struct ShardFaults {
    /// Crash permanently at this decode call (0-based): the call and
    /// every later prefill/decode return an [`InjectedCrash`] error.
    pub crash_at_step: Option<u64>,
    /// `(at_step, extra_steps)`: at this decode call, burn
    /// `extra_steps` additional fused-step costs of wall clock once — a
    /// transient stall (GC pause, preempted VM) the liveness tracker
    /// must ride out without declaring death.
    pub stall: Option<(u64, u64)>,
}

impl ShardFaults {
    pub fn is_empty(&self) -> bool {
        self.crash_at_step.is_none() && self.stall.is_none()
    }
}

/// Marker error for a scheduled [`ShardFaults`] crash. Injected faults
/// must stay distinguishable from real bugs: the worker loop swallows
/// this one silently (a crashed device says nothing) while any other
/// error is surfaced to the dispatcher.
#[derive(Debug, Clone, Copy)]
pub struct InjectedCrash {
    /// decode call at which the shard died
    pub step: u64,
}

impl std::fmt::Display for InjectedCrash {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "injected shard crash at decode step {}", self.step)
    }
}

impl std::error::Error for InjectedCrash {}

/// True when `e` is (or wraps) a scheduled [`InjectedCrash`].
pub fn is_injected_crash(e: &anyhow::Error) -> bool {
    e.chain().any(|c| c.is::<InjectedCrash>())
}

/// A simulated (prefill, decode) graph pair for one worker shard.
#[derive(Debug, Clone)]
pub struct SimModel {
    pub cfg: ModelCfg,
    pub variant: Variant,
    pub batch: usize,
    pub cost: SimCost,
    seed: u64,
    faults: ShardFaults,
    /// decode calls issued so far (interior: `decode` takes `&self`)
    decode_calls: Cell<u64>,
    crashed: Cell<bool>,
    /// KV-cache code width (bits) the simulated decode step reads at.
    /// Fused decode is dominated by streaming the KV pages, so the
    /// per-active-slot cost scales with `kv_bits / 8` — dropping 8 -> 4
    /// halves the per-slot term. Runtime-adjustable (interior): the
    /// dispatcher flips it mid-run for degraded-mode serving.
    kv_bits: Cell<u32>,
}

impl SimModel {
    pub fn new(cfg: ModelCfg, variant: Variant, batch: usize, cost: SimCost) -> Self {
        SimModel {
            cfg,
            variant,
            batch,
            cost,
            seed: 0xC0FF_EE00,
            faults: ShardFaults::default(),
            decode_calls: Cell::new(0),
            crashed: Cell::new(false),
            kv_bits: Cell::new(8),
        }
    }

    /// Switch the KV read width for subsequent decode steps (degraded-
    /// mode serving). Clamped to [1, 8]: 8 is the native page width, so
    /// wider makes no sense, and 0 would make decode free.
    pub fn set_kv_bits(&self, bits: u32) {
        self.kv_bits.set(bits.clamp(1, 8));
    }

    /// Current KV read width (bits).
    pub fn kv_bits(&self) -> u32 {
        self.kv_bits.get()
    }

    /// Attach a fault schedule (builder-style; default is fault-free).
    pub fn with_faults(mut self, faults: ShardFaults) -> Self {
        self.faults = faults;
        self
    }

    fn check_crashed(&self) -> Result<()> {
        if self.crashed.get() {
            return Err(anyhow::Error::new(InjectedCrash { step: self.decode_calls.get() }));
        }
        Ok(())
    }

    /// A gpt2-tiny-shaped config (vocab matches `corpus::VOCAB_SIZE`).
    pub fn tiny(variant: Variant, batch: usize, cost: SimCost) -> Self {
        let cfg = ModelCfg {
            name: "sim-tiny".to_string(),
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            ctx: 128,
            vocab: 32,
            zq_group: 8,
            n_params: 16 * 16 * 12,
        };
        Self::new(cfg, variant, batch, cost)
    }

    /// Simulated weight footprint: 4 bytes/param for the fp graphs, 1
    /// byte/param (8-bit codes) for every quantized variant.
    pub fn weight_storage_bytes(&self) -> usize {
        match self.variant {
            Variant::Fp => self.cfg.n_params * 4,
            _ => self.cfg.n_params,
        }
    }

    /// One logit row for (token, pos); `argmax` over it is the generated
    /// token, so the trajectory is a pure function of the prompt.
    fn fill_logits(&self, token: i32, pos: usize, out: &mut [f32]) {
        let h = mix(self.seed ^ ((token as u64) << 1) ^ ((pos as u64) << 24));
        for (j, o) in out.iter_mut().enumerate() {
            *o = unit(mix(h ^ ((j as u64) << 40)));
        }
    }

    /// One KV row for (layer, token, pos); bounded in [-1, 1) so the
    /// SimQuant page ranges stay sane and re-encodes stay rare.
    fn fill_kv(&self, layer: usize, token: i32, pos: usize, is_k: bool, out: &mut [f32]) {
        let tag: u64 = if is_k { 0x5eed } else { 0xfeed };
        let h = mix(
            self.seed
                ^ tag
                ^ ((layer as u64) << 2)
                ^ ((token as u64) << 12)
                ^ ((pos as u64) << 32),
        );
        for (c, o) in out.iter_mut().enumerate() {
            *o = unit(mix(h ^ ((c as u64) << 44)));
        }
    }

    /// Seeded per-(token, pos) acceptance draw for self-speculative
    /// decoding: does the `draft_bits`-wide draft of the same weights
    /// produce the full-width token at this position? A pure hash of
    /// (seed, token, pos, draft_bits) thresholded against
    /// [`SimCost::draft_accept_rate`], so the outcome is reproducible
    /// across runs, lanes, and scheduling orders — exactly like the
    /// trajectory itself.
    fn draft_agrees(&self, token: i32, pos: usize, draft_bits: u32) -> bool {
        let h = mix(
            self.seed
                ^ 0xD4AF_7000
                ^ ((token as u64) << 1)
                ^ ((pos as u64) << 24)
                ^ ((draft_bits as u64) << 56),
        );
        unit01(h) < SimCost::draft_accept_rate(draft_bits)
    }

    /// Draft logits for (token, pos): the full-width row wherever the
    /// acceptance model agrees, a deterministically perturbed row where
    /// the low-bit draft would mispredict. A mispredicting row demotes
    /// the full-width argmax below the [`unit`] range, so the draft
    /// token provably differs and the acceptance draw actually binds.
    fn fill_draft_logits(&self, token: i32, pos: usize, draft_bits: u32, out: &mut [f32]) {
        self.fill_logits(token, pos, out);
        if self.draft_agrees(token, pos, draft_bits) {
            return;
        }
        let mut top = 0usize;
        for (j, x) in out.iter().enumerate() {
            if *x > out[top] {
                top = j;
            }
        }
        let h = mix(
            self.seed
                ^ 0xD1F7_0000
                ^ ((token as u64) << 1)
                ^ ((pos as u64) << 24)
                ^ ((draft_bits as u64) << 48),
        );
        for (j, o) in out.iter_mut().enumerate() {
            *o = unit(mix(h ^ ((j as u64) << 40)));
        }
        out[top] = -1.5;
    }

    /// Run the simulated prefill graph over a `[B, CTX]` token matrix.
    /// Rows with `prompt_lens[slot] == 0` are padding (not charged).
    pub fn prefill(&self, tokens: &[i32], prompt_lens: &[usize]) -> Result<Vec<Tensor>> {
        let spans: Vec<(usize, usize)> = prompt_lens.iter().map(|&l| (0, l)).collect();
        self.prefill_range(tokens, &spans)
    }

    /// Chunked prefill: ingest only `spans[slot] = (start, len)` of each
    /// slot's prompt — the primitive behind bounded-stall prefill, where
    /// a long prompt is fed to the model a chunk at a time between decode
    /// steps. Costs are charged for the span tokens only, and outputs
    /// (logits + KV rows) are filled only at the span positions, so
    /// resuming at `start` after an earlier `(0, start)` call produces
    /// exactly the rows a whole-prompt call would have.
    pub fn prefill_range(
        &self,
        tokens: &[i32],
        spans: &[(usize, usize)],
    ) -> Result<Vec<Tensor>> {
        self.check_crashed()?;
        let (b, ctx, v) = (self.batch, self.cfg.ctx, self.cfg.vocab);
        let (l, d) = (self.cfg.n_layers, self.cfg.d_model);
        if tokens.len() != b * ctx || spans.len() != b {
            bail!("sim prefill: tokens {} != {}x{}", tokens.len(), b, ctx);
        }
        let mut logits = vec![0f32; b * ctx * v];
        let mut k = vec![0f32; l * b * ctx * d];
        let mut vv = vec![0f32; l * b * ctx * d];
        let mut total_tokens = 0usize;
        for (slot, &(start, len)) in spans.iter().enumerate() {
            let end = (start + len).min(ctx);
            total_tokens += end.saturating_sub(start);
            for t in start..end {
                let tok = tokens[slot * ctx + t];
                let lo = (slot * ctx + t) * v;
                self.fill_logits(tok, t, &mut logits[lo..lo + v]);
                for layer in 0..l {
                    let off = ((layer * b + slot) * ctx + t) * d;
                    self.fill_kv(layer, tok, t, true, &mut k[off..off + d]);
                    self.fill_kv(layer, tok, t, false, &mut vv[off..off + d]);
                }
            }
        }
        spin_us(self.cost.prefill_us_per_token * total_tokens as f64);
        Ok(vec![
            Tensor::from_f32(vec![b, ctx, v], logits),
            Tensor::from_f32(vec![l, b, ctx, d], k),
            Tensor::from_f32(vec![l, b, ctx, d], vv),
        ])
    }

    /// Run one simulated fused decode step. `active[slot]` marks the
    /// slots whose (token, pos) inputs are live; inactive rows are zero.
    pub fn decode(&self, token: &[i32], pos: &[i32], active: &[bool]) -> Result<Vec<Tensor>> {
        self.check_crashed()?;
        let call = self.decode_calls.get();
        self.decode_calls.set(call + 1);
        if let Some(at) = self.faults.crash_at_step {
            if call >= at {
                self.crashed.set(true);
                return Err(anyhow::Error::new(InjectedCrash { step: call }));
            }
        }
        let (b, v) = (self.batch, self.cfg.vocab);
        let (l, d) = (self.cfg.n_layers, self.cfg.d_model);
        if token.len() != b || pos.len() != b || active.len() != b {
            bail!("sim decode: expected {} slots, got {}", b, token.len());
        }
        let mut logits = vec![0f32; b * v];
        let mut k = vec![0f32; l * b * d];
        let mut vv = vec![0f32; l * b * d];
        let mut n_active = 0usize;
        for slot in 0..b {
            if !active[slot] {
                continue;
            }
            n_active += 1;
            let p = pos[slot] as usize;
            self.fill_logits(token[slot], p, &mut logits[slot * v..(slot + 1) * v]);
            for layer in 0..l {
                let off = (layer * b + slot) * d;
                self.fill_kv(layer, token[slot], p, true, &mut k[off..off + d]);
                self.fill_kv(layer, token[slot], p, false, &mut vv[off..off + d]);
            }
        }
        let kv_scale = self.kv_bits.get() as f64 / 8.0;
        spin_us(
            self.cost.decode_step_us + self.cost.decode_us_per_slot * kv_scale * n_active as f64,
        );
        if let Some((at, extra)) = self.faults.stall {
            if call == at {
                spin_us(extra as f64 * self.cost.step_us(n_active));
            }
        }
        Ok(vec![
            Tensor::from_f32(vec![b, v], logits),
            Tensor::from_f32(vec![l, b, d], k),
            Tensor::from_f32(vec![l, b, d], vv),
        ])
    }

    /// One fused *draft* decode step for self-speculative decoding:
    /// the same lane contract as [`SimModel::decode`], run through the
    /// `draft_bits`-wide SimQuant variant of the same weights. Logits
    /// follow the full-width trajectory wherever the seeded
    /// per-(token, pos) acceptance model agrees and diverge
    /// deterministically where the low-bit draft would mispredict; KV
    /// rows are exact — the sim models draft error at the argmax
    /// level, which is what the verify pass arbitrates. A draft step
    /// streams `draft_bits / 8` of the bytes everywhere — weights
    /// (the fixed launch term) and KV pages (the per-slot term, the
    /// same scale [`SimModel::set_kv_bits`] applies) — so the whole
    /// spin scales with the draft width; that discount is where
    /// speculation's throughput win comes from. Draft passes do not
    /// advance the fault clock: [`ShardFaults`] steps count full-width
    /// fused calls, and one draft+verify cycle is one scheduler step.
    pub fn decode_draft(
        &self,
        token: &[i32],
        pos: &[i32],
        active: &[bool],
        draft_bits: u32,
    ) -> Result<Vec<Tensor>> {
        self.check_crashed()?;
        let (b, v) = (self.batch, self.cfg.vocab);
        let (l, d) = (self.cfg.n_layers, self.cfg.d_model);
        if token.len() != b || pos.len() != b || active.len() != b {
            bail!("sim draft decode: expected {} slots, got {}", b, token.len());
        }
        let bits = draft_bits.clamp(1, 8);
        let mut logits = vec![0f32; b * v];
        let mut k = vec![0f32; l * b * d];
        let mut vv = vec![0f32; l * b * d];
        let mut n_active = 0usize;
        for slot in 0..b {
            if !active[slot] {
                continue;
            }
            n_active += 1;
            let p = pos[slot] as usize;
            self.fill_draft_logits(token[slot], p, bits, &mut logits[slot * v..(slot + 1) * v]);
            for layer in 0..l {
                let off = (layer * b + slot) * d;
                self.fill_kv(layer, token[slot], p, true, &mut k[off..off + d]);
                self.fill_kv(layer, token[slot], p, false, &mut vv[off..off + d]);
            }
        }
        let scale = bits as f64 / 8.0;
        spin_us(
            scale * (self.cost.decode_step_us + self.cost.decode_us_per_slot * n_active as f64),
        );
        Ok(vec![
            Tensor::from_f32(vec![b, v], logits),
            Tensor::from_f32(vec![l, b, d], k),
            Tensor::from_f32(vec![l, b, d], vv),
        ])
    }

    /// One fused full-width *verify* pass over `k` speculated
    /// positions per lane. `token`/`pos`/`live` are `[B * k]`
    /// slot-major (lane `s`, position `j` at index `s * k + j`); dead
    /// entries stay zero-filled. Returns `[B, k, V]` logits plus
    /// `[L, B, k, D]` KV rows — exactly what the full-width model
    /// produces for those inputs, so longest-prefix acceptance against
    /// these logits is exact and the client stream stays bit-identical
    /// to non-speculative decoding. Counts as one fused decode call on
    /// the fault clock (crash/stall semantics match
    /// [`SimModel::decode`]). Costs the same as a plain fused step —
    /// one launch plus the native per-slot cost per lane with any live
    /// position: verification is memory-bound on streaming the weights
    /// and each lane's KV pages once, and the extra positions ride the
    /// same pass as near-free compute.
    pub fn decode_verify(
        &self,
        token: &[i32],
        pos: &[i32],
        live: &[bool],
        k: usize,
    ) -> Result<Vec<Tensor>> {
        self.check_crashed()?;
        let call = self.decode_calls.get();
        self.decode_calls.set(call + 1);
        if let Some(at) = self.faults.crash_at_step {
            if call >= at {
                self.crashed.set(true);
                return Err(anyhow::Error::new(InjectedCrash { step: call }));
            }
        }
        let (b, v) = (self.batch, self.cfg.vocab);
        let (l, d) = (self.cfg.n_layers, self.cfg.d_model);
        if k == 0 || token.len() != b * k || pos.len() != b * k || live.len() != b * k {
            bail!("sim verify: expected {}x{} positions, got {}", b, k, token.len());
        }
        let mut logits = vec![0f32; b * k * v];
        let mut kk = vec![0f32; l * b * k * d];
        let mut vv = vec![0f32; l * b * k * d];
        let mut n_lanes = 0usize;
        for slot in 0..b {
            if !live[slot * k..(slot + 1) * k].iter().any(|x| *x) {
                continue;
            }
            n_lanes += 1;
            for j in 0..k {
                let i = slot * k + j;
                if !live[i] {
                    continue;
                }
                let p = pos[i] as usize;
                self.fill_logits(token[i], p, &mut logits[i * v..(i + 1) * v]);
                for layer in 0..l {
                    let off = ((layer * b + slot) * k + j) * d;
                    self.fill_kv(layer, token[i], p, true, &mut kk[off..off + d]);
                    self.fill_kv(layer, token[i], p, false, &mut vv[off..off + d]);
                }
            }
        }
        let kv_scale = self.kv_bits.get() as f64 / 8.0;
        spin_us(
            self.cost.decode_step_us + self.cost.decode_us_per_slot * kv_scale * n_lanes as f64,
        );
        if let Some((at, extra)) = self.faults.stall {
            if call == at {
                spin_us(extra as f64 * self.cost.step_us(n_lanes));
            }
        }
        Ok(vec![
            Tensor::from_f32(vec![b, k, v], logits),
            Tensor::from_f32(vec![l, b, k, d], kk),
            Tensor::from_f32(vec![l, b, k, d], vv),
        ])
    }
}

/// splitmix64 finalizer — a cheap, well-mixed stateless hash.
fn mix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Map a hash to f32 in [-1, 1).
fn unit(h: u64) -> f32 {
    ((h >> 11) as f64 / (1u64 << 53) as f64 * 2.0 - 1.0) as f32
}

/// Map a hash to f64 in [0, 1) — the acceptance-model coin flip.
fn unit01(h: u64) -> f64 {
    (h >> 11) as f64 / (1u64 << 53) as f64
}

/// Burn `us` microseconds of wall clock (spin, not sleep: OS sleep
/// granularity is far too coarse for per-step costs).
fn spin_us(us: f64) {
    if us <= 0.0 {
        return;
    }
    let dur = Duration::from_nanos((us * 1e3) as u64);
    let t0 = Instant::now();
    while t0.elapsed() < dur {
        std::hint::spin_loop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim() -> SimModel {
        SimModel::tiny(Variant::Fp, 4, SimCost::fast())
    }

    #[test]
    fn prefill_shapes_and_determinism() {
        let m = sim();
        let (b, ctx) = (m.batch, m.cfg.ctx);
        let mut tokens = vec![0i32; b * ctx];
        tokens[..3].copy_from_slice(&[1, 5, 9]);
        let mut lens = vec![0usize; b];
        lens[0] = 3;
        let a = m.prefill(&tokens, &lens).unwrap();
        let c = m.prefill(&tokens, &lens).unwrap();
        assert_eq!(a[0].shape, vec![b, ctx, m.cfg.vocab]);
        assert_eq!(a[1].shape, vec![m.cfg.n_layers, b, ctx, m.cfg.d_model]);
        assert_eq!(a[0].f32_view().unwrap(), c[0].f32_view().unwrap());
        assert_eq!(a[1].f32_view().unwrap(), c[1].f32_view().unwrap());
    }

    #[test]
    fn decode_depends_only_on_token_and_pos() {
        let m = sim();
        // slot 0 in one call must equal slot 2 in another for the same
        // (token, pos) — the property that makes scheduling orders
        // token-identical
        let a = m
            .decode(&[7, 0, 0, 0], &[4, 0, 0, 0], &[true, false, false, false])
            .unwrap();
        let c = m
            .decode(&[0, 0, 7, 0], &[0, 0, 4, 0], &[false, false, true, false])
            .unwrap();
        let v = m.cfg.vocab;
        let (av, cv) = (a[0].f32_view().unwrap(), c[0].f32_view().unwrap());
        assert_eq!(&av[..v], &cv[2 * v..3 * v]);
    }

    #[test]
    fn inactive_slots_stay_zero() {
        let m = sim();
        let out = m
            .decode(&[3, 0, 0, 0], &[1, 0, 0, 0], &[true, false, false, false])
            .unwrap();
        let v = m.cfg.vocab;
        assert!(out[0].f32_view().unwrap()[v..].iter().all(|x| *x == 0.0));
    }

    #[test]
    fn kv_rows_bounded() {
        let m = sim();
        let out = m
            .decode(&[3, 0, 0, 0], &[1, 0, 0, 0], &[true, false, false, false])
            .unwrap();
        assert!(out[1].f32_view().unwrap().iter().all(|x| x.abs() <= 1.0));
    }

    #[test]
    fn weight_bytes_track_variant() {
        let fp = SimModel::tiny(Variant::Fp, 4, SimCost::fast());
        let q = SimModel::tiny(Variant::Int8, 4, SimCost::fast());
        assert_eq!(fp.weight_storage_bytes(), 4 * q.weight_storage_bytes());
    }

    #[test]
    fn prefill_range_matches_whole_prompt() {
        // two chunked calls must reproduce the single-call rows exactly —
        // the property chunked prefill rests on
        let m = sim();
        let (b, ctx) = (m.batch, m.cfg.ctx);
        let mut tokens = vec![0i32; b * ctx];
        for t in 0..7 {
            tokens[t] = 1 + t as i32;
        }
        let mut lens = vec![0usize; b];
        lens[0] = 7;
        let whole = m.prefill(&tokens, &lens).unwrap();
        let mut spans = vec![(0usize, 0usize); b];
        spans[0] = (0, 3);
        let first = m.prefill_range(&tokens, &spans).unwrap();
        spans[0] = (3, 4);
        let second = m.prefill_range(&tokens, &spans).unwrap();
        for out in 0..3 {
            let w = whole[out].f32_view().unwrap();
            let a = first[out].f32_view().unwrap();
            let c = second[out].f32_view().unwrap();
            let merged: Vec<f32> = a.iter().zip(c).map(|(x, y)| x + y).collect();
            assert_eq!(&merged[..], w, "output {out} diverged across the chunk seam");
        }
    }

    #[test]
    fn cost_profile_from_json_object() {
        let v = json::parse(r#"{"prefill_us_per_token": 9.5, "decode_step_us": 300}"#).unwrap();
        let c = SimCost::from_profile(&v).unwrap();
        assert_eq!(c.prefill_us_per_token, 9.5);
        assert_eq!(c.decode_step_us, 300.0);
        // unspecified knobs keep defaults
        assert_eq!(c.decode_us_per_slot, SimCost::default().decode_us_per_slot);
        assert!(SimCost::from_profile(&json::parse("3").unwrap()).is_err());
        let neg = json::parse(r#"{"decode_step_us": -1}"#).unwrap();
        assert!(SimCost::from_profile(&neg).is_err());
    }

    #[test]
    fn cost_profile_fits_hotpath_rows() {
        let rows = json::parse(
            r#"[{"name": "token_quantize 512x512", "mean_us": 50.0},
                {"name": "decode step b8 gpt2-tiny/smooth (PJRT)", "mean_us": 800.0}]"#,
        )
        .unwrap();
        let c = SimCost::from_profile(&rows).unwrap();
        assert_eq!(c.prefill_us_per_token, 100.0);
        assert_eq!(c.decode_step_us, 560.0);
        assert_eq!(c.decode_us_per_slot, 30.0);
        // fixed + per-slot at b=8 reconstructs the measured fused step
        assert!((c.decode_step_us + 8.0 * c.decode_us_per_slot - 800.0).abs() < 1e-9);
        let offline = json::parse(r#"[{"name": "token_quantize", "mean_us": 1}]"#).unwrap();
        assert!(SimCost::fit_hotpath(&offline).is_none());
    }

    #[test]
    fn per_token_hooks_match_the_spun_model() {
        let c = SimCost::default();
        // a full b=8 fused step costs launch + 8 slot increments ...
        assert_eq!(c.step_us(8), 250.0 + 8.0 * 25.0);
        assert_eq!(c.step_us(0), 250.0);
        // ... and generates 8 tokens, so per-token cost is step/8 + slot
        assert!((c.decode_us_per_token(8) - (250.0 / 8.0 + 25.0)).abs() < 1e-12);
        assert_eq!(c.decode_us_per_token(8) * 8.0, c.step_us(8));
        // batch 0 clamps instead of dividing by zero
        assert!(c.decode_us_per_token(0).is_finite());
    }

    #[test]
    fn injected_crash_fires_at_the_scheduled_step_and_sticks() {
        let m = sim().with_faults(ShardFaults { crash_at_step: Some(2), stall: None });
        let (tok, pos, act) = ([3, 0, 0, 0], [1, 0, 0, 0], [true, false, false, false]);
        assert!(m.decode(&tok, &pos, &act).is_ok()); // call 0
        assert!(m.decode(&tok, &pos, &act).is_ok()); // call 1
        let err = m.decode(&tok, &pos, &act).unwrap_err(); // call 2: dies
        assert!(is_injected_crash(&err), "{err:#}");
        // the crash is permanent: decode and prefill both keep failing
        assert!(is_injected_crash(&m.decode(&tok, &pos, &act).unwrap_err()));
        let tokens = vec![0i32; m.batch * m.cfg.ctx];
        let lens = vec![0usize; m.batch];
        assert!(is_injected_crash(&m.prefill(&tokens, &lens).unwrap_err()));
        // a real contract violation is NOT an injected crash
        let healthy = sim();
        let err = healthy.decode(&[1], &[0], &[true]).unwrap_err();
        assert!(!is_injected_crash(&err));
    }

    #[test]
    fn stall_burns_extra_wall_clock_without_perturbing_outputs() {
        let clean = sim();
        let stalled =
            sim().with_faults(ShardFaults { crash_at_step: None, stall: Some((0, 100)) });
        let (tok, pos, act) = ([7, 0, 0, 0], [4, 0, 0, 0], [true, false, false, false]);
        let t0 = Instant::now();
        let a = stalled.decode(&tok, &pos, &act).unwrap();
        let el = t0.elapsed().as_secs_f64();
        // 100 extra fast-cost steps at 1 active slot = 100 * 22 us
        assert!(el >= 1.5e-3, "stall spun only {el}s");
        let b = clean.decode(&tok, &pos, &act).unwrap();
        assert_eq!(a[0].f32_view().unwrap(), b[0].f32_view().unwrap());
        // one-shot: the next call pays only the normal step cost
        let t1 = Instant::now();
        stalled.decode(&tok, &pos, &act).unwrap();
        assert!(t1.elapsed().as_secs_f64() < 1.5e-3);
    }

    #[test]
    fn kv_bits_scale_the_per_slot_decode_cost_only() {
        // all cost in the per-slot term so the kv width dominates timing
        let cost = SimCost {
            prefill_us_per_token: 0.0,
            decode_step_us: 0.0,
            decode_us_per_slot: 1000.0,
        };
        let m = SimModel::tiny(Variant::Fp, 4, cost);
        let (tok, pos, act) = ([7, 3, 9, 2], [4, 1, 2, 3], [true; 4]);
        assert_eq!(m.kv_bits(), 8, "native width is the default");
        let t0 = Instant::now();
        let full = m.decode(&tok, &pos, &act).unwrap();
        let full_el = t0.elapsed().as_secs_f64();
        assert!(full_el >= 3.5e-3, "8-bit spun only {full_el}s");
        m.set_kv_bits(4);
        let t1 = Instant::now();
        let half = m.decode(&tok, &pos, &act).unwrap();
        let half_el = t1.elapsed().as_secs_f64();
        assert!(half_el < 3.0e-3, "4-bit kv still spun {half_el}s");
        // degraded decode is cheaper, never different: the trajectory is
        // a pure (token, pos) hash regardless of kv width
        assert_eq!(full[0].f32_view().unwrap(), half[0].f32_view().unwrap());
        // clamped to a sane range
        m.set_kv_bits(0);
        assert_eq!(m.kv_bits(), 1);
        m.set_kv_bits(99);
        assert_eq!(m.kv_bits(), 8);
    }

    #[test]
    fn spin_is_roughly_calibrated() {
        let t0 = Instant::now();
        spin_us(200.0);
        let el = t0.elapsed().as_secs_f64();
        assert!(el >= 190e-6, "spun only {el}s");
    }

    #[test]
    fn unknown_profile_keys_warn_but_known_keys_pass() {
        let typo =
            json::parse(r#"{"decode_us_per_tok": 30, "decode_step_us": 300}"#).unwrap();
        assert_eq!(SimCost::unknown_profile_keys(&typo), vec!["decode_us_per_tok"]);
        // the typo'd knob still parses (warn, don't fail) with defaults
        let c = SimCost::from_profile(&typo).unwrap();
        assert_eq!(c.decode_step_us, 300.0);
        assert_eq!(c.decode_us_per_slot, SimCost::default().decode_us_per_slot);
        let clean = json::parse(r#"{"decode_us_per_slot": 30}"#).unwrap();
        assert!(SimCost::unknown_profile_keys(&clean).is_empty());
        // non-objects (hotpath row arrays) have no keys to vet
        assert!(SimCost::unknown_profile_keys(&json::parse("[]").unwrap()).is_empty());
    }

    #[test]
    fn acceptance_model_tracks_draft_width() {
        assert_eq!(SimCost::draft_accept_rate(8), 1.0);
        assert_eq!(SimCost::draft_accept_rate(4), 0.95);
        assert_eq!(SimCost::draft_accept_rate(2), 0.8);
        assert_eq!(SimCost::draft_accept_rate(1), 0.5);
        // k=0 degenerates to plain decode: one token per cycle
        assert_eq!(SimCost::spec_tokens_per_cycle(0, 4), 1.0);
        // a=1: every draft accepted plus the bonus verify token
        assert_eq!(SimCost::spec_tokens_per_cycle(3, 8), 4.0);
        // a=0.95, k=2: 1 + 0.95 + 0.9025
        let e = SimCost::spec_tokens_per_cycle(2, 4);
        assert!((e - 2.8525).abs() < 1e-12, "got {e}");
        // more drafts never hurt expected tokens per cycle
        for bits in [2u32, 4] {
            for k in 1..6usize {
                assert!(
                    SimCost::spec_tokens_per_cycle(k + 1, bits)
                        >= SimCost::spec_tokens_per_cycle(k, bits)
                );
            }
        }
    }

    #[test]
    fn draft_logits_match_full_width_exactly_when_the_model_agrees() {
        let m = sim();
        let v = m.cfg.vocab;
        let (mut full, mut draft) = (vec![0f32; v], vec![0f32; v]);
        let (mut agreed, mut diverged) = (0usize, 0usize);
        for token in 0..16i32 {
            for pos in 0..16usize {
                m.fill_logits(token, pos, &mut full);
                m.fill_draft_logits(token, pos, 4, &mut draft);
                if m.draft_agrees(token, pos, 4) {
                    agreed += 1;
                    assert_eq!(full, draft, "agreeing draft row must be bit-identical");
                } else {
                    diverged += 1;
                    assert_ne!(
                        argmax_idx(&full),
                        argmax_idx(&draft),
                        "mispredicted draft should flip the argmax (token {token} pos {pos})"
                    );
                }
            }
        }
        // the seeded coin actually lands on both sides at a = 0.95
        assert!(agreed > diverged, "agreed {agreed} <= diverged {diverged}");
        assert!(diverged > 0, "no mispredictions in 256 draws at a = 0.95");
        // native-width drafts never mispredict (a = 1.0)
        for token in 0..16i32 {
            for pos in 0..16usize {
                assert!(m.draft_agrees(token, pos, 8));
            }
        }
    }

    fn argmax_idx(row: &[f32]) -> usize {
        let mut best = 0usize;
        for (j, x) in row.iter().enumerate() {
            if *x > row[best] {
                best = j;
            }
        }
        best
    }

    #[test]
    fn verify_pass_reproduces_plain_decode_rows() {
        let m = sim();
        let (b, k, v, d) = (m.batch, 3usize, m.cfg.vocab, m.cfg.d_model);
        // lane 1 speculates tokens 5, 9, 2 at positions 10, 11, 12
        let mut token = vec![0i32; b * k];
        let mut pos = vec![0i32; b * k];
        let mut live = vec![false; b * k];
        token[k..2 * k].copy_from_slice(&[5, 9, 2]);
        pos[k..2 * k].copy_from_slice(&[10, 11, 12]);
        live[k..2 * k].fill(true);
        let out = m.decode_verify(&token, &pos, &live, k).unwrap();
        assert_eq!(out[0].shape, vec![b, k, v]);
        assert_eq!(out[1].shape, vec![m.cfg.n_layers, b, k, d]);
        let verify = out[0].f32_view().unwrap();
        let plain = m
            .decode(&[0, 9, 0, 0], &[0, 11, 0, 0], &[false, true, false, false])
            .unwrap();
        // verify row (lane 1, j = 1) == plain decode of (9, 11)
        let row = &verify[(k + 1) * v..(k + 2) * v];
        assert_eq!(row, &plain[0].f32_view().unwrap()[v..2 * v]);
        // dead positions stay zero (lane 0 is entirely dead)
        assert!(verify[..k * v].iter().all(|x| *x == 0.0));
    }

    #[test]
    fn draft_passes_do_not_advance_the_fault_clock() {
        let m = sim().with_faults(ShardFaults { crash_at_step: Some(1), stall: None });
        let (tok, pos, act) = ([3, 0, 0, 0], [1, 0, 0, 0], [true, false, false, false]);
        // any number of draft passes before the first counted call is fine
        for _ in 0..5 {
            m.decode_draft(&tok, &pos, &act, 4).unwrap();
        }
        let vtok = vec![3i32; m.batch * 2];
        let vpos = vec![1i32; m.batch * 2];
        let vlive = vec![true; m.batch * 2];
        assert!(m.decode_verify(&vtok, &vpos, &vlive, 2).is_ok()); // call 0
        let err = m.decode_verify(&vtok, &vpos, &vlive, 2).unwrap_err(); // call 1
        assert!(is_injected_crash(&err), "{err:#}");
        // the crash sticks for draft passes too
        assert!(is_injected_crash(&m.decode_draft(&tok, &pos, &act, 4).unwrap_err()));
    }

    #[test]
    fn draft_decode_is_cheaper_than_native_width() {
        let cost = SimCost {
            prefill_us_per_token: 0.0,
            decode_step_us: 0.0,
            decode_us_per_slot: 1000.0,
        };
        let m = SimModel::tiny(Variant::Fp, 4, cost);
        let (tok, pos, act) = ([7, 3, 9, 2], [4, 1, 2, 3], [true; 4]);
        let t0 = Instant::now();
        m.decode(&tok, &pos, &act).unwrap();
        let full_el = t0.elapsed().as_secs_f64();
        let t1 = Instant::now();
        let draft = m.decode_draft(&tok, &pos, &act, 2).unwrap();
        let draft_el = t1.elapsed().as_secs_f64();
        // 2-bit draft spins a quarter of the native per-slot cost
        assert!(full_el >= 3.5e-3, "8-bit spun only {full_el}s");
        assert!(draft_el < 2.0e-3, "2-bit draft still spun {draft_el}s");
        // draft KV rows are exact — rollback/accept never corrupts cache
        let plain = m.decode(&tok, &pos, &act).unwrap();
        assert_eq!(draft[1].f32_view().unwrap(), plain[1].f32_view().unwrap());
        assert_eq!(draft[2].f32_view().unwrap(), plain[2].f32_view().unwrap());
    }
}
