//! Default (no-`xla`) runtime backend: the same API surface as `engine`,
//! with `Literal` as a plain host buffer and compile/execute returning
//! errors. This keeps every caller — registry, workers, benches, the CLI —
//! building and testable offline; rebuild with `--features xla` (and the
//! `xla` crate available, see Cargo.toml) to execute real artifacts
//! through PJRT.

use std::path::Path;

use anyhow::{bail, Result};

use crate::tensor::{DType, Tensor};

/// Host-side stand-in for a PJRT literal: packed bytes + shape + dtype.
/// Creation copies once, like PJRT literal creation does.
pub struct Literal {
    dtype: DType,
    shape: Vec<usize>,
    data: Vec<u8>,
}

/// Convert a host tensor into a literal.
pub fn tensor_to_literal(t: &Tensor) -> Result<Literal> {
    literal_from_raw(t.dtype, &t.shape, t.bytes())
}

/// Build a literal directly from raw bytes — same single-copy semantics
/// as the PJRT-backed path.
pub fn literal_from_raw(dtype: DType, shape: &[usize], bytes: &[u8]) -> Result<Literal> {
    let want = shape.iter().product::<usize>() * dtype.itemsize();
    if bytes.len() != want {
        bail!(
            "literal bytes {} do not match shape {:?} ({} bytes)",
            bytes.len(),
            shape,
            want
        );
    }
    Ok(Literal { dtype, shape: shape.to_vec(), data: bytes.to_vec() })
}

/// Convert a literal back into a host tensor.
pub fn literal_to_tensor(lit: &Literal) -> Result<Tensor> {
    Tensor::from_bytes(lit.dtype, lit.shape.clone(), &lit.data)
}

fn unavailable<T>(what: &str) -> Result<T> {
    bail!("{what} requires the PJRT runtime: rebuild with `--features xla` (see Cargo.toml)")
}

/// A compiled graph ready to execute — never constructible in this
/// backend (compilation errors first), but the type and methods exist so
/// callers typecheck identically with and without the `xla` feature.
pub struct Executable {
    _private: (),
}

impl Executable {
    /// Execute with host tensors; returns the flattened output tuple.
    pub fn run(&self, _inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        unavailable("graph execution")
    }

    /// Execute with pre-built literals.
    pub fn run_literals(&self, _literals: &[Literal]) -> Result<Vec<Tensor>> {
        unavailable("graph execution")
    }

    /// Execute with borrowed literals.
    pub fn run_borrowed(&self, _literals: &[&Literal]) -> Result<Vec<Tensor>> {
        unavailable("graph execution")
    }
}

/// The (unavailable) PJRT client + compiler.
pub struct Engine {
    _private: (),
}

impl Engine {
    pub fn cpu() -> Result<Self> {
        unavailable("the PJRT CPU client")
    }

    pub fn platform(&self) -> String {
        "unavailable (built without the xla feature)".to_string()
    }

    /// Compile an HLO text artifact.
    pub fn compile_hlo_file(&self, _path: &Path) -> Result<Executable> {
        unavailable("HLO compilation")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let t = Tensor::from_f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let lit = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn literal_shape_mismatch_rejected() {
        assert!(literal_from_raw(DType::F32, &[2, 2], &[0u8; 15]).is_err());
    }

    #[test]
    fn engine_reports_missing_feature() {
        let err = Engine::cpu().err().unwrap();
        assert!(err.to_string().contains("xla"));
    }
}
