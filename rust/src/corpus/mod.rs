//! Synthetic corpus + tokenizer — bit-identical mirror of
//! `python/compile/corpus.py` (same PRNG, same lexicon, same Zipf walk),
//! so both languages agree on the training/validation split without
//! shipping data. `tests/cross_language.rs` pins the checksum.

mod rng;
mod text;

pub use rng::XorShift64Star;
pub use text::{detokenize, tokenize};

/// Token alphabet (vocab = 32): see python/compile/corpus.py.
pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 30;
pub const SPACE: i32 = 28;
pub const PERIOD: i32 = 29;
pub const VOCAB_SIZE: usize = 32;

const N_WORDS: usize = 512;
const MIN_WLEN: u64 = 2;
const MAX_WLEN: u64 = 8;
const SENT_MIN: u64 = 4;
const SENT_MAX: u64 = 12;
const LEXICON_SEED: u64 = 0xC0_FFEE;
const ZIPF_S: f64 = 1.1;

/// Deterministic lexicon: N_WORDS words of letter tokens.
pub fn build_lexicon() -> Vec<Vec<i32>> {
    let mut rng = XorShift64Star::new(LEXICON_SEED);
    (0..N_WORDS)
        .map(|_| {
            let wlen = MIN_WLEN + rng.next_below(MAX_WLEN - MIN_WLEN + 1);
            (0..wlen).map(|_| 2 + rng.next_below(26) as i32).collect()
        })
        .collect()
}

/// Zipf CDF over word ranks.
pub fn zipf_cdf(n: usize, s: f64) -> Vec<f64> {
    let w: Vec<f64> = (1..=n).map(|r| (r as f64).powf(-s)).collect();
    let total: f64 = w.iter().sum();
    let mut acc = 0.0;
    w.iter()
        .map(|x| {
            acc += x / total;
            acc
        })
        .collect()
}

/// Generate exactly `n_tokens` ids (BOS-prefixed). Mirrors Python.
pub fn generate_tokens(n_tokens: usize, seed: u64) -> Vec<i32> {
    let lex = build_lexicon();
    let cdf = zipf_cdf(N_WORDS, ZIPF_S);
    let mut rng = XorShift64Star::new(seed);
    let mut out = Vec::with_capacity(n_tokens + MAX_WLEN as usize);
    out.push(BOS);
    while out.len() < n_tokens {
        let sent_len = SENT_MIN + rng.next_below(SENT_MAX - SENT_MIN + 1);
        for wi in 0..sent_len {
            let u = rng.next_f64();
            // binary search — identical branch structure to Python
            let (mut lo, mut hi) = (0usize, N_WORDS - 1);
            while lo < hi {
                let mid = (lo + hi) / 2;
                if cdf[mid] < u {
                    lo = mid + 1;
                } else {
                    hi = mid;
                }
            }
            out.extend_from_slice(&lex[lo]);
            out.push(if wi + 1 < sent_len { SPACE } else { PERIOD });
            if out.len() >= n_tokens {
                break;
            }
        }
    }
    out.truncate(n_tokens);
    out
}

/// Shared split rule: one stream; first n_train tokens train, next valid.
pub fn train_valid_split(n_train: usize, n_valid: usize, seed: u64) -> (Vec<i32>, Vec<i32>) {
    let mut stream = generate_tokens(n_train + n_valid, seed);
    let valid = stream.split_off(n_train);
    (stream, valid)
}

/// FNV-1a over token low bytes — the cross-language identity check.
pub fn checksum(tokens: &[i32]) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for t in tokens {
        h ^= (*t as u64) & 0xFF;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bos_prefixed_and_exact_length() {
        let t = generate_tokens(1000, 1234);
        assert_eq!(t.len(), 1000);
        assert_eq!(t[0], BOS);
    }

    #[test]
    fn tokens_in_vocab() {
        for t in generate_tokens(5000, 99) {
            assert!((0..VOCAB_SIZE as i32).contains(&t));
        }
    }

    #[test]
    fn checksum_matches_python() {
        // pinned from python: corpus.checksum(corpus.generate_tokens(4096))
        let t = generate_tokens(4096, 1234);
        assert_eq!(checksum(&t), 0x14CC_B6D0_9EA9_D22B);
    }

    #[test]
    fn split_is_consistent() {
        let (tr, va) = train_valid_split(100, 50, 7);
        let full = generate_tokens(150, 7);
        assert_eq!(tr, full[..100].to_vec());
        assert_eq!(va, full[100..].to_vec());
    }

    #[test]
    fn zipf_cdf_monotone_to_one() {
        let cdf = zipf_cdf(64, 1.1);
        assert!(cdf.windows(2).all(|w| w[0] <= w[1]));
        assert!((cdf[63] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(generate_tokens(256, 1), generate_tokens(256, 2));
    }
}
