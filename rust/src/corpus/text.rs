//! Char tokenizer: maps between readable text and the 32-symbol alphabet.
//! Used by the serving examples so requests/responses are human-readable.

use super::{BOS, EOS, PAD, PERIOD, SPACE};

/// Encode text to token ids. Unknown chars map to SPACE.
pub fn tokenize(text: &str) -> Vec<i32> {
    text.chars()
        .map(|c| match c {
            'a'..='z' => 2 + (c as i32 - 'a' as i32),
            'A'..='Z' => 2 + (c.to_ascii_lowercase() as i32 - 'a' as i32),
            '.' => PERIOD,
            _ => SPACE,
        })
        .collect()
}

/// Decode token ids to text. Control tokens render as markers.
pub fn detokenize(tokens: &[i32]) -> String {
    tokens
        .iter()
        .map(|&t| match t {
            t if (2..28).contains(&t) => (b'a' + (t - 2) as u8) as char,
            SPACE => ' ',
            PERIOD => '.',
            BOS => '^',
            EOS => '$',
            PAD => '_',
            _ => '?',
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_lowercase() {
        let s = "hello world.";
        assert_eq!(detokenize(&tokenize(s)), s);
    }

    #[test]
    fn uppercase_folds() {
        assert_eq!(detokenize(&tokenize("AbC")), "abc");
    }

    #[test]
    fn unknown_to_space() {
        assert_eq!(detokenize(&tokenize("a!b")), "a b");
    }

    #[test]
    fn control_tokens_render() {
        assert_eq!(detokenize(&[BOS, 2, EOS, PAD]), "^a$_");
    }
}
