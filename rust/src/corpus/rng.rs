//! xorshift64* PRNG — bit-identical mirror of
//! `python/compile/corpus.py::XorShift64Star`.
//!
//! Used everywhere the Rust side needs deterministic randomness that must
//! (or may conveniently) agree with the Python side: corpus generation,
//! golden token sequences, synthetic workloads, property-test inputs.

#[derive(Debug, Clone)]
pub struct XorShift64Star {
    state: u64,
}

impl XorShift64Star {
    pub fn new(seed: u64) -> Self {
        let state = if seed == 0 { 0x9E37_79B9_7F4A_7C15 } else { seed };
        XorShift64Star { state }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Modulo draw — same (slightly biased) rule as the Python mirror.
    pub fn next_below(&mut self, n: u64) -> u64 {
        self.next_u64() % n
    }

    /// Uniform f64 in [0, 1): top 53 bits / 2^53, same as Python.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Standard normal via Box-Muller (Rust-only; used for synthetic
    /// workloads, not for anything that must match Python).
    pub fn next_normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = XorShift64Star::new(42);
        let mut b = XorShift64Star::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn zero_seed_remapped() {
        let mut r = XorShift64Star::new(0);
        assert_ne!(r.next_u64(), 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = XorShift64Star::new(7);
        for _ in 0..1000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_has_sane_moments() {
        let mut r = XorShift64Star::new(9);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }
}
