//! Shared plumbing for the `benches/` targets (harness = false): registry
//! loading, the paper-scale workload definitions, and metric
//! normalization for the figure benches.

use std::path::Path;
use std::sync::Arc;

use anyhow::Result;

use crate::collective::LinkModel;
use crate::memsim::{GpuSpec, PaperModel, PipelineCost};
use crate::quant::Variant;
use crate::runtime::Registry;

/// Artifacts dir — overridable with LLEQ_ARTIFACTS.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("LLEQ_ARTIFACTS")
        .map(Into::into)
        .unwrap_or_else(|_| Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

pub fn open_registry() -> Result<Arc<Registry>> {
    Ok(Arc::new(Registry::open(&artifacts_dir())?))
}

/// Trained models available in the registry (measured rows).
pub const TRAINED_MODELS: [&str; 3] = ["gpt2-tiny", "gpt2-small", "gpt2-med"];

/// Method columns of Tables 1-3 mapped to our variants.
pub fn table_methods() -> Vec<(&'static str, Variant)> {
    vec![
        ("FP16", Variant::Fp),
        ("SmoothQuant", Variant::Smooth),
        ("SimQuant", Variant::SimQuant),
        ("AWQ", Variant::Awq),
        ("GPTQ", Variant::Gptq),
        ("ZeroQuant", Variant::ZeroQuant),
    ]
}

/// The paper's Table 2 serving workload on simulated 8xA100 (batch 256 =
/// high-occupancy continuous batching, where bandwidth gains dominate the
/// fixed kernel/collective overheads).
pub fn paper_serving_cost(m: &PaperModel, ctx: usize) -> PipelineCost {
    PipelineCost::from_paper_model(m, 256, ctx, 8, GpuSpec::a100_80g(), LinkModel::nvlink())
}

/// Min-max normalize (higher = better); used by the radar figure.
pub fn normalize_higher_better(values: &[f64]) -> Vec<f64> {
    let (lo, hi) = values
        .iter()
        .fold((f64::INFINITY, f64::NEG_INFINITY), |(l, h), v| (l.min(*v), h.max(*v)));
    let span = (hi - lo).max(1e-12);
    values.iter().map(|v| (v - lo) / span).collect()
}

/// Normalize where lower raw values are better (invert then min-max).
pub fn normalize_lower_better(values: &[f64]) -> Vec<f64> {
    let inverted: Vec<f64> = values.iter().map(|v| -v).collect();
    normalize_higher_better(&inverted)
}

/// CSV emitter for figure series (so plots can be regenerated outside).
pub struct CsvOut {
    path: std::path::PathBuf,
    lines: Vec<String>,
}

impl CsvOut {
    pub fn new(name: &str, header: &str) -> Self {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("target/bench_series");
        let _ = std::fs::create_dir_all(&dir);
        CsvOut { path: dir.join(name), lines: vec![header.to_string()] }
    }

    pub fn row(&mut self, cells: &[String]) {
        self.lines.push(cells.join(","));
    }

    pub fn finish(self) {
        let _ = std::fs::write(&self.path, self.lines.join("\n"));
        println!("(series written to {})", self.path.display());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalization_bounds() {
        let n = normalize_higher_better(&[1.0, 3.0, 2.0]);
        assert_eq!(n[0], 0.0);
        assert_eq!(n[1], 1.0);
        let l = normalize_lower_better(&[1.0, 3.0]);
        assert_eq!(l[0], 1.0);
        assert_eq!(l[1], 0.0);
    }

    #[test]
    fn method_table_has_six_columns() {
        assert_eq!(table_methods().len(), 6);
    }
}
