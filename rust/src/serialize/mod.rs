//! ONNX-compatible quantization serialization (paper §3.5, Eqs. 10-11).

mod onnx;

pub use onnx::{
    dequantize_initializer, export_model, export_to_file, from_json, import_model,
    save as save_graph, to_json, OnnxGraph, OnnxNode, QuantTensor,
};
