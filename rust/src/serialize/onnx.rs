//! ONNX-compatible export: QuantizeLinear / DequantizeLinear graphs
//! (paper §3.5, Eqs. 10-11).
//!
//! Emits a JSON graph carrying the same node semantics and metadata an
//! ONNX QDQ export would: per-initializer int8/u8 payloads with (scale,
//! zero_point) attributes, DequantizeLinear nodes feeding MatMul nodes.
//! `import_model` round-trips it and reconstructs f32 weights via Eq. 11,
//! which the round-trip test checks inverts Eq. 10 exactly on codes.

use std::path::Path;

use anyhow::{anyhow, Result};

use crate::quant::prepare::{prepare_linear, Checkpoint};
use crate::quant::Variant;
use crate::runtime::ModelCfg;
use crate::util::json::{self, Value};

/// A quantized initializer (weight tensor) in the exported graph.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantTensor {
    pub name: String,
    pub shape: Vec<usize>,
    /// int8 codes (absent for fp weights)
    pub codes: Vec<i8>,
    /// per-channel or per-tensor scales
    pub scale: Vec<f32>,
    /// zero points (empty = symmetric)
    pub zero_point: Vec<f32>,
    /// channel axis for per-channel scales (-1 = per-tensor)
    pub axis: i32,
}

/// One node in the exported graph.
#[derive(Debug, Clone, PartialEq)]
pub struct OnnxNode {
    pub op: String,
    pub inputs: Vec<String>,
    pub outputs: Vec<String>,
}

/// The exported graph.
#[derive(Debug, Clone, PartialEq)]
pub struct OnnxGraph {
    pub model: String,
    pub variant: String,
    pub opset: usize,
    pub initializers: Vec<QuantTensor>,
    pub nodes: Vec<OnnxNode>,
}

/// Export every linear of (model, variant) as a QDQ graph.
pub fn export_model(cfg: &ModelCfg, ckpt: &Checkpoint, variant: Variant) -> Result<OnnxGraph> {
    let mut initializers = Vec::new();
    let mut nodes = Vec::new();
    let d = cfg.d_model;
    let f = cfg.d_ff();
    let linears: Vec<(String, usize, usize)> = (0..cfg.n_layers)
        .flat_map(|i| {
            vec![
                (format!("h{i}.qkv"), d, 3 * d),
                (format!("h{i}.attn_out"), d, d),
                (format!("h{i}.fc1"), d, f),
                (format!("h{i}.fc2"), f, d),
            ]
        })
        .collect();

    for (name, k, n) in linears {
        let prepared = prepare_linear(variant, &name, ckpt, cfg.zq_group, 0.5)?;
        let (codes, scale, zp, axis) = match variant {
            Variant::Fp | Variant::Awq | Variant::Gptq => {
                // weight-only baselines export their dequantized f32 —
                // re-quantize per-channel for the QDQ form
                let w = prepared["w"].f32_view()?;
                let (q, delta) =
                    crate::quant::symmetric_quantize_channel(w, k, n, 8)?;
                (q, delta, Vec::new(), 1)
            }
            Variant::AbsMax => (
                prepared["w_q"].as_i8()?,
                vec![prepared["w_delta"].as_f32()?[0]],
                Vec::new(),
                -1,
            ),
            Variant::ZeroPoint => (
                prepared["w_q"].as_i8()?,
                prepared["w_scale"].as_f32()?,
                prepared["w_zp"].as_f32()?,
                -1,
            ),
            Variant::Sym8 | Variant::Int8 | Variant::SimQuant => (
                prepared["w_q"].as_i8()?,
                prepared["w_delta"].as_f32()?,
                Vec::new(),
                1,
            ),
            Variant::Smooth | Variant::ZeroQuant => {
                // smoothing factors / group scales are runtime-internal;
                // export the *effective* weight re-encoded per-channel so
                // any ONNX runtime reconstructs W directly (Eq. 11)
                let w = crate::quant::prepare::effective_weight(
                    variant, &prepared, k, n, cfg.zq_group,
                )?;
                let (q, delta) =
                    crate::quant::symmetric_quantize_channel(&w, k, n, 8)?;
                (q, delta, Vec::new(), 1)
            }
        };
        initializers.push(QuantTensor {
            name: format!("{name}.weight_q"),
            shape: vec![k, n],
            codes,
            scale,
            zero_point: zp,
            axis,
        });
        nodes.push(OnnxNode {
            op: "DequantizeLinear".into(),
            inputs: vec![
                format!("{name}.weight_q"),
                format!("{name}.weight_scale"),
                format!("{name}.weight_zero_point"),
            ],
            outputs: vec![format!("{name}.weight_f")],
        });
        nodes.push(OnnxNode {
            op: "MatMul".into(),
            inputs: vec![format!("{name}.input"), format!("{name}.weight_f")],
            outputs: vec![format!("{name}.output")],
        });
    }
    Ok(OnnxGraph {
        model: cfg.name.clone(),
        variant: variant.name().to_string(),
        opset: 13,
        initializers,
        nodes,
    })
}

/// Eq. 11: reconstruct f32 weights from an initializer.
pub fn dequantize_initializer(t: &QuantTensor) -> Vec<f32> {
    let n_cols = *t.shape.last().unwrap_or(&1);
    t.codes
        .iter()
        .enumerate()
        .map(|(i, q)| {
            let (s, z) = if t.axis == 1 && t.scale.len() == n_cols {
                let col = i % n_cols;
                (t.scale[col], t.zero_point.get(col).copied().unwrap_or(0.0))
            } else {
                (t.scale[0], t.zero_point.first().copied().unwrap_or(0.0))
            };
            (*q as f32 - z) * s
        })
        .collect()
}

// ---------------------------------------------------------------------------
// JSON (de)serialization
// ---------------------------------------------------------------------------

pub fn to_json(g: &OnnxGraph) -> Value {
    let inits: Vec<Value> = g
        .initializers
        .iter()
        .map(|t| {
            Value::obj(vec![
                ("name", t.name.as_str().into()),
                ("shape", Value::Arr(t.shape.iter().map(|d| (*d).into()).collect())),
                (
                    "codes",
                    Value::Arr(t.codes.iter().map(|c| (*c as f64).into()).collect()),
                ),
                (
                    "scale",
                    Value::Arr(t.scale.iter().map(|s| (*s as f64).into()).collect()),
                ),
                (
                    "zero_point",
                    Value::Arr(t.zero_point.iter().map(|z| (*z as f64).into()).collect()),
                ),
                ("axis", (t.axis as f64).into()),
            ])
        })
        .collect();
    let nodes: Vec<Value> = g
        .nodes
        .iter()
        .map(|n| {
            Value::obj(vec![
                ("op", n.op.as_str().into()),
                ("inputs", Value::Arr(n.inputs.iter().map(|s| s.as_str().into()).collect())),
                (
                    "outputs",
                    Value::Arr(n.outputs.iter().map(|s| s.as_str().into()).collect()),
                ),
            ])
        })
        .collect();
    Value::obj(vec![
        ("ir_version", 8usize.into()),
        ("opset", g.opset.into()),
        ("producer", "llmeasyquant".into()),
        ("model", g.model.as_str().into()),
        ("variant", g.variant.as_str().into()),
        ("initializers", Value::Arr(inits)),
        ("nodes", Value::Arr(nodes)),
    ])
}

pub fn from_json(v: &Value) -> Result<OnnxGraph> {
    let model = v.get("model").and_then(Value::as_str).unwrap_or("").to_string();
    let variant = v.get("variant").and_then(Value::as_str).unwrap_or("").to_string();
    let opset = v.get("opset").and_then(Value::as_usize).unwrap_or(13);
    let mut initializers = Vec::new();
    for t in v
        .get("initializers")
        .and_then(Value::as_arr)
        .ok_or_else(|| anyhow!("missing initializers"))?
    {
        let nums = |key: &str| -> Vec<f64> {
            t.get(key)
                .and_then(Value::as_arr)
                .map(|a| a.iter().filter_map(Value::as_f64).collect())
                .unwrap_or_default()
        };
        initializers.push(QuantTensor {
            name: t.get("name").and_then(Value::as_str).unwrap_or("").to_string(),
            shape: nums("shape").iter().map(|d| *d as usize).collect(),
            codes: nums("codes").iter().map(|c| *c as i8).collect(),
            scale: nums("scale").iter().map(|s| *s as f32).collect(),
            zero_point: nums("zero_point").iter().map(|z| *z as f32).collect(),
            axis: t.get("axis").and_then(Value::as_f64).unwrap_or(-1.0) as i32,
        });
    }
    let mut nodes = Vec::new();
    for n in v.get("nodes").and_then(Value::as_arr).unwrap_or(&[]) {
        let strs = |key: &str| -> Vec<String> {
            n.get(key)
                .and_then(Value::as_arr)
                .map(|a| {
                    a.iter()
                        .filter_map(Value::as_str)
                        .map(str::to_string)
                        .collect()
                })
                .unwrap_or_default()
        };
        nodes.push(OnnxNode {
            op: n.get("op").and_then(Value::as_str).unwrap_or("").to_string(),
            inputs: strs("inputs"),
            outputs: strs("outputs"),
        });
    }
    Ok(OnnxGraph { model, variant, opset, initializers, nodes })
}

/// Write the graph to a file.
pub fn save(g: &OnnxGraph, path: &Path) -> Result<()> {
    std::fs::write(path, json::to_string(&to_json(g)))?;
    Ok(())
}

/// Read a graph back.
pub fn import_model(path: &Path) -> Result<OnnxGraph> {
    let text = std::fs::read_to_string(path)?;
    from_json(&json::parse(&text)?)
}

/// Convenience: export + save.
pub fn export_to_file(
    cfg: &ModelCfg,
    ckpt: &Checkpoint,
    variant: Variant,
    path: &Path,
) -> Result<OnnxGraph> {
    let g = export_model(cfg, ckpt, variant)?;
    save(&g, path)?;
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::XorShift64Star;
    use crate::tensor::Tensor;
    use std::collections::BTreeMap;

    fn tiny_cfg() -> ModelCfg {
        ModelCfg {
            name: "t".into(),
            d_model: 8,
            n_layers: 1,
            n_heads: 2,
            ctx: 16,
            vocab: 32,
            zq_group: 4,
            n_params: 0,
        }
    }

    fn tiny_ckpt(cfg: &ModelCfg) -> Checkpoint {
        let mut r = XorShift64Star::new(21);
        let mut m = BTreeMap::new();
        let d = cfg.d_model;
        let f = cfg.d_ff();
        for (name, k, n) in [
            ("h0.qkv", d, 3 * d),
            ("h0.attn_out", d, d),
            ("h0.fc1", d, f),
            ("h0.fc2", f, d),
        ] {
            let w: Vec<f32> = (0..k * n).map(|_| r.next_normal() as f32 * 0.1).collect();
            m.insert(format!("{name}_w"), Tensor::from_f32(vec![k, n], w));
            m.insert(
                format!("calib.{name}.absmax"),
                Tensor::from_f32(vec![k], vec![1.0; k]),
            );
            m.insert(
                format!("calib.{name}.meanabs"),
                Tensor::from_f32(vec![k], vec![0.5; k]),
            );
            m.insert(
                format!("calib.{name}.sqsum"),
                Tensor::from_f32(vec![k], vec![8.0; k]),
            );
            m.insert(format!("calib.{name}.count"), Tensor::from_i32(vec![1], vec![16]));
        }
        Checkpoint::new(m)
    }

    #[test]
    fn export_has_qdq_structure() {
        let cfg = tiny_cfg();
        let g = export_model(&cfg, &tiny_ckpt(&cfg), Variant::Sym8).unwrap();
        assert_eq!(g.initializers.len(), 4);
        assert_eq!(g.nodes.len(), 8);
        assert!(g.nodes.iter().any(|n| n.op == "DequantizeLinear"));
        assert!(g.nodes.iter().any(|n| n.op == "MatMul"));
    }

    #[test]
    fn eq11_inverts_eq10_on_codes() {
        let cfg = tiny_cfg();
        let ckpt = tiny_ckpt(&cfg);
        let g = export_model(&cfg, &ckpt, Variant::Sym8).unwrap();
        let t = &g.initializers[0];
        let w_hat = dequantize_initializer(t);
        let orig = ckpt.f32("h0.qkv_w").unwrap();
        let max_scale = t.scale.iter().cloned().fold(0f32, f32::max);
        for (a, b) in orig.iter().zip(&w_hat) {
            assert!((a - b).abs() <= max_scale * 0.5 + 1e-6);
        }
    }

    #[test]
    fn json_roundtrip_exact() {
        let cfg = tiny_cfg();
        let g = export_model(&cfg, &tiny_ckpt(&cfg), Variant::ZeroPoint).unwrap();
        let back = from_json(&to_json(&g)).unwrap();
        assert_eq!(g, back);
    }

    #[test]
    fn file_roundtrip() {
        let cfg = tiny_cfg();
        let dir = std::env::temp_dir().join("lleq_onnx_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("m.onnx.json");
        let g = export_to_file(&cfg, &tiny_ckpt(&cfg), Variant::Smooth, &p).unwrap();
        let back = import_model(&p).unwrap();
        assert_eq!(g, back);
    }

    #[test]
    fn every_variant_exports() {
        let cfg = tiny_cfg();
        let ckpt = tiny_ckpt(&cfg);
        for v in Variant::all() {
            let g = export_model(&cfg, &ckpt, *v).unwrap();
            assert_eq!(g.initializers.len(), 4, "{v:?}");
            // dequantized initializers stay close to the originals
            let w_hat = dequantize_initializer(&g.initializers[0]);
            let orig = ckpt.f32("h0.qkv_w").unwrap();
            let mse: f64 = orig
                .iter()
                .zip(&w_hat)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                / orig.len() as f64;
            assert!(mse < 1e-4, "{v:?} mse {mse}");
        }
    }
}
