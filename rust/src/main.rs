//! LLMEasyQuant CLI — the leader entrypoint.
//!
//! Subcommands:
//!   info            — list models/variants/graphs in the artifact registry
//!   serve           — run a synthetic serving workload, report throughput
//!   eval-ppl        — perplexity of (model, variant) on the held-out split
//!   breakdown       — Eq. 12 latency breakdown (A100-sim)
//!   bitwidth-search — Thm. 3 mixed-precision search over a checkpoint
//!   export-onnx     — ONNX-compatible QDQ export (Eqs. 10-11)
//!   cluster-sim     — lockstep multi-shard scale sync (Thm. 4 / Eqs. 7-8)

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Result};

use llmeasyquant::collective::{Collective, Topology, Transport};
use llmeasyquant::coordinator::{
    search_bitwidths, size_reduction, sync_wire_bits_for, workload, AdmissionPolicy,
    BatchPolicy, FaultPlan, FaultSpec, LayerInfo, Priority, ScaleSync, SchedulerMode,
    SearchPolicy, Server, ServerConfig,
};
use llmeasyquant::corpus;
use llmeasyquant::eval::{perplexity, weight_errors};
use llmeasyquant::memsim::{GpuSpec, PaperModel, PipelineCost};
use llmeasyquant::quant::Variant;
use llmeasyquant::runtime::{Registry, SimCost};
use llmeasyquant::serialize;
use llmeasyquant::util::args::Args;
use llmeasyquant::util::bench::Table;

fn main() -> Result<()> {
    let args = Args::parse_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "info" => info(&args),
        "serve" => serve(&args),
        "eval-ppl" => eval_ppl(&args),
        "breakdown" => breakdown(&args),
        "bitwidth-search" => bitwidth(&args),
        "export-onnx" => export_onnx(&args),
        "cluster-sim" => cluster_sim(&args),
        _ => {
            print_help();
            Ok(())
        }
    }
}

fn print_help() {
    println!(
        "llmeasyquant — scalable quantization for parallel & distributed LLM inference

USAGE: llmeasyquant <command> [--options]

COMMANDS:
  info             list artifact registry contents
  serve            --model gpt2-tiny --variant smooth --shards 2 --requests 16
                   --max-new 16 [--batch 8] [--mode static|continuous]
                   [--backend pjrt|sim]  (sim: calibrated spin-wait shards, no
                                          artifacts needed; required for the
                                          rejoin/standby/degrade options below —
                                          compiled PJRT shards neither respawn
                                          nor change KV width at runtime)
                   [--rate REQS_PER_S]   (rate > 0: open-loop Poisson replay)
                   [--prefill-chunk N]   (bound prefill to N tokens/step; 0 = whole)
                   [--slo-p99-ms MS --admission shed|priority|predict]
                                         (enforce a p99 latency target at admission;
                                          `predict` gates on completion time predicted
                                          from the in-flight backlog x calibrated
                                          per-token cost — PJRT needs BENCH_hotpath.json
                                          or LLEQ_HOTPATH_PROFILE)
                   [--priority-mix F]    (fraction of requests tagged interactive;
                                          the rest are batch priority: low queue
                                          tier, shed first. default 1.0)
                   [--shared-prefix F]   (fraction of requests prefixed with a
                                          shared synthetic system prompt; the
                                          paged KV prefix cache converts repeats
                                          into block hits that skip prefill.
                                          default 0.0)
                   [--kv-blocks N]       (KV block pool size per shard; default
                                          sizes the pool to batch x ctx)
                   [--no-prefix-cache]   (disable prefix-block retention; paged
                                          allocation and preemption stay on)
                   [--fault-plan SPEC]   (seeded fault injection + recovery; SPEC is
                                          comma-separated `crash:<shard>@<step>`,
                                          `stall:<shard>@<step>x<steps>`, `corrupt:<p>`,
                                          `recover:<shard>@<step>`, `seed:<n>`,
                                          e.g. crash:1@40,recover:1@120,seed:7.
                                          continuous mode only: dead shards are
                                          detected by missed step deadlines and
                                          their in-flight requests migrate with
                                          exactly-once token delivery. `recover:`
                                          respawns the shard at the plan step —
                                          it re-shards weights over the ring,
                                          re-syncs scales, then ramps back into
                                          routing behind probe traffic; sim
                                          backend only)
                   [--standby N]         (warm spare pool: at most one spare
                                          promotes per detected shard death,
                                          rejoining through the same probe
                                          ramp; sim backend only)
                   [--degrade-bits B]    (degraded-mode serving: while the fleet
                                          is shrunk or decode backlog stays hot,
                                          survivors drop KV pages from 8-bit to
                                          B-bit — faster decode, more effective
                                          capacity, fewer sheds — and restore
                                          native width once the fleet is whole
                                          and pressure clears; sim backend only)
                   [--spec-k K]          (self-speculative decoding: each lane
                                          drafts K tokens/cycle from a low-bit
                                          variant of the same weights, then one
                                          fused full-width pass verifies and
                                          accepts the longest matching prefix;
                                          rejected tokens roll the paged KV
                                          table back. 0 = off; sim backend only)
                   [--spec-bits B]       (draft bit-width for --spec-k, 2 or 4;
                                          default 4. lower bits draft faster
                                          but mispredict more)
                   [--disagg]            (disaggregated prefill/decode serving:
                                          the first half of the fleet admits and
                                          chunk-prefills, the rest decodes;
                                          finished prefills migrate their
                                          quantized KV pages over the simulated
                                          wire and the decode shard continues
                                          the stream bit-identically. shards
                                          re-role elastically when the
                                          estimator sees the prefill:decode
                                          backlog drift. continuous mode +
                                          --backend sim only)
                   [--prefill-heavy F]   (fraction of requests forced to
                                          max-length prompts with minimum
                                          decode — the prefill-bound trace the
                                          disagg split is built for. default 0)
  eval-ppl         --model gpt2-tiny --variant all [--windows 8]
  breakdown        --ctx 32768 --batch 448 [--world 8] [--transport nccl]
  bitwidth-search  --model gpt2-tiny [--lambda 1e-4] [--policy greedy|grid|entropy]
  export-onnx      --model gpt2-tiny --variant smooth --out model.onnx.json
  cluster-sim      --shards 8 --steps 50 [--transport nccl|tcp] [--regions 16]
  (--artifacts DIR overrides the artifact directory; default ./artifacts)"
    );
}

fn artifacts(args: &Args) -> PathBuf {
    PathBuf::from(args.get_or("artifacts", "artifacts"))
}

fn registry(args: &Args) -> Result<Arc<Registry>> {
    Ok(Arc::new(Registry::open(&artifacts(args))?))
}

fn parse_variant(name: &str) -> Result<Variant> {
    Variant::from_name(name).ok_or_else(|| anyhow::anyhow!("unknown variant {name}"))
}

// ---------------------------------------------------------------------------

fn info(args: &Args) -> Result<()> {
    let reg = registry(args)?;
    println!("models:");
    for (name, cfg) in &reg.manifest().models {
        println!(
            "  {name}: d={} L={} H={} ctx={} vocab={} params={}",
            cfg.d_model, cfg.n_layers, cfg.n_heads, cfg.ctx, cfg.vocab, cfg.n_params
        );
    }
    println!("graphs: {}", reg.manifest().graphs.len());
    println!(
        "variants: {:?}",
        Variant::all().iter().map(|v| v.name()).collect::<Vec<_>>()
    );
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    let model = args.get_or("model", "gpt2-tiny");
    let variant = parse_variant(&args.get_or("variant", "smooth"))?;
    let shards = args.get_usize("shards", 2);
    let n_requests = args.get_usize("requests", 16);
    let max_new = args.get_usize("max-new", 16);
    let batch = args.get_usize("batch", 8);
    let mode = match args.get_or("mode", "continuous").as_str() {
        "static" => SchedulerMode::Static,
        "continuous" => SchedulerMode::Continuous,
        m => bail!("unknown scheduler mode {m} (static|continuous)"),
    };
    // requests/second for open-loop Poisson replay; 0 = closed-loop
    let rate = args.get_f64("rate", 0.0);
    // prefill chunk in tokens per step boundary; 0 = whole-prompt
    let prefill_chunk = args.get_usize("prefill-chunk", 0);
    // p99 latency target; 0 = no SLO enforcement (AdmissionPolicy::Open)
    let slo_p99_ms = args.get_f64("slo-p99-ms", 0.0);
    let admission = if slo_p99_ms > 0.0 {
        match args.get_or("admission", "shed").as_str() {
            "shed" => AdmissionPolicy::SheddingP99 { target_ms: slo_p99_ms },
            "priority" => AdmissionPolicy::Priority { target_ms: slo_p99_ms },
            "predict" => AdmissionPolicy::Predictive { target_ms: slo_p99_ms },
            a => bail!("unknown admission policy {a} (shed|priority|predict)"),
        }
    } else {
        AdmissionPolicy::Open
    };
    // seeded fault-injection plan; empty = no faults, liveness disarmed
    let fault_plan = match args.get("fault-plan") {
        Some(spec) => Some(FaultPlan::parse(spec)?),
        None => None,
    };
    let backend = args.get_or("backend", "pjrt");
    if backend != "pjrt" && backend != "sim" {
        bail!("unknown backend {backend} (pjrt|sim)");
    }
    // warm spare pool + degraded-mode KV width (0 = native 8-bit only)
    let standby = args.get_usize("standby", 0);
    let degrade_bits = args.get_usize("degrade-bits", 0);
    // self-speculative decoding: draft depth + draft bit-width (0 = off)
    let spec_k = args.get_usize("spec-k", 0);
    let spec_bits = args.get_usize("spec-bits", 4);
    if spec_k > 0 && !(1..=8).contains(&spec_bits) {
        bail!("--spec-bits must be in 1..=8 (got {spec_bits})");
    }
    // disaggregated prefill/decode fleet split (sim + continuous only)
    let disagg = args.has_flag("disagg");
    if disagg && !matches!(mode, SchedulerMode::Continuous) {
        bail!("--disagg needs --mode continuous (static batches never hand off mid-stream)");
    }
    if disagg && shards < 2 {
        bail!("--disagg needs --shards >= 2 (one shard cannot split roles)");
    }
    if backend != "sim" {
        // compiled PJRT shards neither respawn nor change KV width at
        // runtime — reject the elastic options instead of silently
        // serving without them (and mispricing admission)
        if degrade_bits > 0 {
            bail!("--degrade-bits needs --backend sim (PJRT graphs compile at a fixed KV width)");
        }
        if spec_k > 0 {
            bail!(
                "--spec-k needs --backend sim (PJRT graphs compile at a fixed width; \
                 there is no low-bit draft variant to run)"
            );
        }
        if standby > 0 || fault_plan.as_ref().is_some_and(|p| p.has_recovery()) {
            bail!(
                "--standby / recover: clauses need --backend sim (compiled PJRT \
                 shards don't respawn; PJRT recovery is detection + migration only)"
            );
        }
        if disagg {
            bail!(
                "--disagg needs --backend sim (compiled PJRT shards neither re-role \
                 at runtime nor export quantized KV pages over the simulated wire)"
            );
        }
    }
    // fraction of requests tagged interactive priority (rest are batch)
    let priority_mix = args.get_f64("priority-mix", 1.0);
    if !(0.0..=1.0).contains(&priority_mix) {
        bail!("--priority-mix must be in [0, 1] (got {priority_mix})");
    }
    // fraction of requests sharing a synthetic system prompt (prefix cache)
    let shared_prefix = args.get_f64("shared-prefix", 0.0);
    if !(0.0..=1.0).contains(&shared_prefix) {
        bail!("--shared-prefix must be in [0, 1] (got {shared_prefix})");
    }
    // fraction of requests forced to a prefill-bound shape (long prompt,
    // minimum decode) — the trace the disagg split is built for
    let prefill_heavy = args.get_f64("prefill-heavy", 0.0);
    if !(0.0..=1.0).contains(&prefill_heavy) {
        bail!("--prefill-heavy must be in [0, 1] (got {prefill_heavy})");
    }
    // KV block pool override (0 = default batch x ctx sizing)
    let kv_blocks = args.get_usize("kv-blocks", 0);
    let prefix_cache = !args.has_flag("no-prefix-cache");
    // predict sheds batch-priority work only: an all-interactive mix
    // leaves nothing sheddable and the gate silently degrades to open —
    // surface that at the point of use instead
    if matches!(admission, AdmissionPolicy::Predictive { .. }) && priority_mix >= 1.0 {
        bail!(
            "--admission predict sheds batch-priority requests only, but --priority-mix \
             {priority_mix} tags every request interactive (nothing sheddable); pass \
             --priority-mix < 1.0 or use --admission shed"
        );
    }

    let mut cfg = ServerConfig::new(&model, variant);
    cfg.shards = shards;
    cfg.batch = batch;
    cfg.policy = BatchPolicy::default();
    cfg.mode = mode;
    cfg.prefill_chunk = prefill_chunk;
    cfg.admission = admission;
    cfg.standby = standby;
    cfg.degrade_bits = (degrade_bits > 0).then_some(degrade_bits as u32);
    cfg.kv_blocks = (kv_blocks > 0).then_some(kv_blocks);
    cfg.prefix_cache = prefix_cache;
    cfg.spec_k = spec_k;
    cfg.spec_draft_bits = spec_bits as u32;
    cfg.disagg = disagg;
    if let Some(plan) = fault_plan {
        cfg.fault = FaultSpec::with_plan(plan);
    }
    let fault_active = cfg.fault.active();
    let server = if backend == "sim" {
        println!("spinning up {shards} sim shards ({}) ...", variant.name());
        Server::start_sim(cfg, SimCost::default())?
    } else {
        let reg = registry(args)?;
        println!("compiling executables for {model}/{} ...", variant.name());
        Server::start(&reg, cfg)?
    };

    // synthetic workload: prompts drawn from the corpus generator
    let spec = workload::WorkloadSpec {
        n_requests,
        rate_per_s: if rate > 0.0 { rate } else { 100.0 },
        prompt_min: 24,
        prompt_max: 24,
        max_new_min: max_new,
        max_new_max: max_new,
        long_frac: 0.0,
        interactive_frac: priority_mix,
        shared_prefix_frac: shared_prefix,
        prefill_heavy_frac: prefill_heavy,
        seed: 9000,
    };
    let report = if rate > 0.0 {
        server.run_open_loop(workload::generate(&spec))?
    } else {
        server.run_open_loop(workload::firehose(&spec))?
    };

    let lat = report.latency_summary();
    println!(
        "served {} requests ({} scheduling, {} admission) | {:.1} tok/s | {} decode steps",
        report.responses.len(),
        mode.name(),
        admission.name(),
        report.tokens_per_s(),
        report.decode_steps,
    );
    if slo_p99_ms > 0.0 {
        println!(
            "slo: target p99 {slo_p99_ms} ms | shed {} ({:.1}%, {} interactive) | \
             deprioritized {}",
            report.shed(),
            report.shed_rate() * 100.0,
            report.shed_interactive,
            report.deprioritized,
        );
    }
    if fault_active {
        println!(
            "faults: dead shards {:?} (health {:?}) | detection {:?} deadlines | \
             migrated {} reqs ({} re-prefill tokens) | dup suppressed {} | lost {}",
            report.dead_shards,
            report.shard_health.iter().map(|h| h.name()).collect::<Vec<_>>(),
            report
                .detection_deadlines
                .iter()
                .map(|d| (d * 100.0).round() / 100.0)
                .collect::<Vec<_>>(),
            report.migrated(),
            report.reprefill_tokens,
            report.dup_tokens,
            report.lost_tokens,
        );
    }
    if !report.rejoined.is_empty()
        || report.standby_promotions > 0
        || report.degrade_enters > 0
    {
        println!(
            "recovery: rejoined {:?} (admit share {:?}) | standby promotions {} | \
             degrade enter/exit {}/{} | rebroadcast {:.2} MB quantized weights",
            report.rejoined,
            report
                .rejoin_admit_share
                .iter()
                .map(|s| (s * 100.0).round() / 100.0)
                .collect::<Vec<_>>(),
            report.standby_promotions,
            report.degrade_enters,
            report.degrade_exits,
            report.rebroadcast_bytes as f64 / 1e6,
        );
    }
    if spec_k > 0 {
        println!(
            "speculation: k={spec_k} draft {spec_bits}-bit | drafted {} | accepted {} \
             ({:.1}% acceptance)",
            report.drafted_tokens,
            report.accepted_tokens,
            report.acceptance_rate() * 100.0,
        );
    }
    if disagg || report.handoffs > 0 {
        println!(
            "disagg: handoffs {} | kv pages migrated {:.2} MB | re-roles {} | \
             busy split prefill {:.0}% / decode {:.0}% | estimator abs err {:.1} ms",
            report.handoffs,
            report.kv_migrate_bytes as f64 / 1e6,
            report.reroles,
            report.prefill_busy_share * 100.0,
            report.decode_busy_share * 100.0,
            report.estimator_abs_err * 1e3,
        );
    }
    if shared_prefix > 0.0
        || report.prefix_hit_tokens > 0
        || report.preemptions > 0
    {
        println!(
            "paged kv: prefix hit {} tokens | preemptions {} | resume re-prefill {} tokens",
            report.prefix_hit_tokens,
            report.preemptions,
            report.resume_reprefill_tokens,
        );
    }
    if priority_mix < 1.0 {
        println!(
            "priority: interactive {} served p99 {:.1} ms | batch {} served p99 {:.1} ms \
             | queue delay p99 {:.1} ms",
            report.served_for(Priority::Interactive),
            report.latency_percentile_for(Priority::Interactive, 0.99) * 1e3,
            report.served_for(Priority::Batch),
            report.latency_percentile_for(Priority::Batch, 0.99) * 1e3,
            report.queue_delay_percentile(0.99) * 1e3,
        );
    }
    println!(
        "latency mean {:.1} ms ci95 [{:.1}, {:.1}] p99 {:.1} ms | ttft mean {:.1} ms p99 {:.1} ms",
        lat.mean * 1e3,
        lat.ci95_lo * 1e3,
        lat.ci95_hi * 1e3,
        report.latency_percentile(0.99) * 1e3,
        report.ttft_summary().mean * 1e3,
        report.ttft_percentile(0.99) * 1e3,
    );
    println!(
        "weights: {:.2} MB under {} | shard tokens: {:?}",
        report.weight_storage_bytes as f64 / 1e6,
        variant.name(),
        report.shard_tokens
    );
    if let Some(sample) = report.responses.first() {
        println!(
            "sample completion (req {}): {:?}",
            sample.id,
            corpus::detokenize(&sample.tokens)
        );
    }
    Ok(())
}

fn eval_ppl(args: &Args) -> Result<()> {
    let model = args.get_or("model", "gpt2-tiny");
    let variant_arg = args.get_or("variant", "all");
    let windows = args.get_usize("windows", 8);
    let reg = registry(args)?;
    let variants: Vec<Variant> = if variant_arg == "all" {
        Variant::all().to_vec()
    } else {
        vec![parse_variant(&variant_arg)?]
    };
    let mut table = Table::new(&["variant", "ppl", "nll", "tokens"]);
    for v in variants {
        let r = perplexity(&reg, &model, v, windows)?;
        table.row(vec![
            v.name().into(),
            format!("{:.3}", r.ppl),
            format!("{:.4}", r.nll),
            r.tokens.to_string(),
        ]);
    }
    table.print();
    Ok(())
}

fn breakdown(args: &Args) -> Result<()> {
    let ctx = args.get_usize("ctx", 32768);
    let batch = args.get_usize("batch", 448);
    let world = args.get_usize("world", 8);
    let transport = Transport::from_name(&args.get_or("transport", "nccl"))
        .ok_or_else(|| anyhow::anyhow!("bad transport"))?;
    let mut cost = PipelineCost::from_paper_model(
        &PaperModel::gpt2_117m(),
        batch,
        ctx,
        world,
        GpuSpec::a100_80g(),
        transport.link(),
    );
    cost.w.instrumented = true;
    let mut table = Table::new(&["Method", "Load", "Quant", "GEMM", "Comm", "Sync", "Total"]);
    for v in [Variant::Fp, Variant::Int8, Variant::SimQuant, Variant::Smooth] {
        let b = cost.decode_layer(v);
        let ms = b.as_ms();
        table.row(vec![
            v.name().into(),
            format!("{:.1}", ms[0]),
            format!("{:.1}", ms[1]),
            format!("{:.1}", ms[2]),
            format!("{:.1}", ms[3]),
            format!("{:.1}", ms[4]),
            format!("{:.1}", b.total_s() * 1e3),
        ]);
    }
    println!(
        "A100-sim latency breakdown (ms/layer, ctx={ctx}, batch={batch}, world={world}, {}):",
        transport.name()
    );
    table.print();
    Ok(())
}

fn bitwidth(args: &Args) -> Result<()> {
    let model = args.get_or("model", "gpt2-tiny");
    let lambda = args.get_f64("lambda", 1e-4);
    let policy = match args.get_or("policy", "greedy").as_str() {
        "grid" => SearchPolicy::Grid,
        "entropy" => SearchPolicy::Entropy {
            mean_bits: args.get_f64("mean-bits", 4.0) as f32,
        },
        _ => SearchPolicy::Greedy,
    };
    let reg = registry(args)?;
    let cfg = reg.model_cfg(&model)?.clone();
    let ckpt = reg.checkpoint(&model)?;
    let mut layers = Vec::new();
    let mut params = Vec::new();
    for i in 0..cfg.n_layers {
        for lname in ["qkv", "attn_out", "fc1", "fc2"] {
            let full = format!("h{i}.{lname}");
            let w = ckpt.f32(&format!("{full}_w"))?;
            let sens = ckpt
                .f32(&format!("calib.{full}.sqsum"))
                .map(|s| s.iter().sum::<f32>() / s.len() as f32)
                .unwrap_or(1.0);
            params.push(w.len());
            layers.push(LayerInfo { name: full, w, sensitivity: sens });
        }
    }
    let (choices, iters) = search_bitwidths(&layers, lambda, policy);
    let mut table = Table::new(&["layer", "bits", "objective"]);
    for c in &choices {
        table.row(vec![c.name.clone(), c.bits.to_string(), format!("{:.3e}", c.err)]);
    }
    table.print();
    println!(
        "size reduction vs f32: {:.2}x (converged in {iters} sweeps)",
        size_reduction(&choices, &params)
    );
    Ok(())
}

fn export_onnx(args: &Args) -> Result<()> {
    let model = args.get_or("model", "gpt2-tiny");
    let variant = parse_variant(&args.get_or("variant", "smooth"))?;
    let out = PathBuf::from(args.get_or("out", "model.onnx.json"));
    let reg = registry(args)?;
    let cfg = reg.model_cfg(&model)?.clone();
    let ckpt = reg.checkpoint(&model)?;
    let g = serialize::export_model(&cfg, &ckpt, variant)?;
    serialize::save_graph(&g, &out)?;
    println!(
        "exported {} initializers, {} nodes to {}",
        g.initializers.len(),
        g.nodes.len(),
        out.display()
    );
    let errs = weight_errors(&cfg, &ckpt, variant)?;
    let worst = errs.iter().map(|e| e.mse).fold(0.0, f64::max);
    println!("worst-layer weight MSE under {}: {:.3e}", variant.name(), worst);
    Ok(())
}

fn cluster_sim(args: &Args) -> Result<()> {
    let shards = args.get_usize("shards", 8);
    let steps = args.get_usize("steps", 50);
    let regions = args.get_usize("regions", 16);
    let transport = Transport::from_name(&args.get_or("transport", "nccl"))
        .ok_or_else(|| anyhow::anyhow!("bad transport"))?;
    if shards < 1 {
        bail!("need at least one shard");
    }
    println!(
        "cluster-sim: {shards} shards, {steps} lockstep steps, {regions} scale regions, {}",
        transport.name()
    );
    let ring = Collective::ring(Topology::new(shards, transport));
    let mut handles = Vec::new();
    for (rank, mut comm) in ring.into_iter().enumerate() {
        handles.push(std::thread::spawn(move || {
            // edge/TCP tiers drop the sync wire to 4-bit
            let mut sync = ScaleSync::new(regions, 0.9, 1e-6, 8)
                .with_wire_bits(sync_wire_bits_for(transport));
            let mut rng = corpus::XorShift64Star::new(100 + rank as u64);
            for _ in 0..steps {
                for region in 0..regions {
                    // shard-specific activation distributions
                    let x: Vec<f32> = (0..256)
                        .map(|_| rng.next_normal() as f32 * (1.0 + rank as f32 * 0.2))
                        .collect();
                    sync.observe(region, &x);
                }
                if sync.due() {
                    sync.sync(&mut comm).expect("sync");
                }
            }
            // final sync so every shard agrees
            let states = sync.sync(&mut comm).expect("final sync");
            (comm.stats(), states, sync.syncs)
        }));
    }
    let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    // consistency check (Thm. 4)
    let first = &results[0].1;
    for (rank, (_, states, _)) in results.iter().enumerate() {
        for (a, b) in first.iter().zip(states) {
            assert_eq!(a.delta, b.delta, "shard {rank} diverged");
        }
    }
    let stats = results[0].0;
    println!(
        "consistent across shards ok | syncs/shard: {} | comm: {} ops, {:.1} KB sent, sim wire {:.3} ms, wall {:.3} ms",
        results[0].2,
        stats.ops,
        stats.bytes_sent as f64 / 1e3,
        stats.sim_time_s * 1e3,
        stats.wall_time_s * 1e3,
    );
    Ok(())
}
