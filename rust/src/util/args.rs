//! Tiny CLI argument parser (no clap in the offline crate set).
//!
//! Supports `--key value`, `--key=value`, `--flag`, and positionals.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an explicit token list (first token is NOT the binary).
    pub fn parse_from<I: IntoIterator<Item = String>>(tokens: I) -> Self {
        let mut out = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    out.options.insert(stripped.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    pub fn parse_env() -> Self {
        Self::parse_from(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from))
    }

    #[test]
    fn key_value_styles() {
        let a = parse("serve --model gpt2-tiny --shards=8 --verbose");
        assert_eq!(a.positional, vec!["serve"]);
        assert_eq!(a.get("model"), Some("gpt2-tiny"));
        assert_eq!(a.get_usize("shards", 1), 8);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn flag_before_option() {
        let a = parse("--dry-run --steps 5");
        assert!(a.has_flag("dry-run"));
        assert_eq!(a.get_usize("steps", 0), 5);
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.get_or("x", "d"), "d");
        assert_eq!(a.get_f64("y", 1.5), 1.5);
    }
}
