//! Micro-bench harness (no criterion in the offline crate set).
//!
//! Warmup + timed iterations with mean / p50 / p95 / stddev, plus a table
//! printer used by every `benches/` target to emit the paper's rows.

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub std_ns: f64,
}

impl BenchStats {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }
}

/// Run `f` for `warmup` unmeasured + `iters` measured iterations.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    stats_from(name, samples)
}

/// Time a single run (for expensive end-to-end passes).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

fn stats_from(name: &str, mut samples: Vec<f64>) -> BenchStats {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len().max(1) as f64;
    let mean = samples.iter().sum::<f64>() / n;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n;
    let idx = |q: f64| samples[(q * (samples.len() - 1) as f64) as usize];
    BenchStats {
        name: name.to_string(),
        iters: samples.len(),
        mean_ns: mean,
        p50_ns: if samples.is_empty() { 0.0 } else { idx(0.5) },
        p95_ns: if samples.is_empty() { 0.0 } else { idx(0.95) },
        std_ns: var.sqrt(),
    }
}

/// Fixed-width table printer for bench outputs.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    #[allow(clippy::inherent_to_string)]
    pub fn to_string(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = line(&self.headers);
        out.push('\n');
        out.push_str(&widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("  "));
        for row in &self.rows {
            out.push('\n');
            out.push_str(&line(row));
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.to_string());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_ordered_stats() {
        let s = bench("noop", 2, 50, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(s.iters, 50);
        assert!(s.p50_ns <= s.p95_ns);
        assert!(s.mean_ns > 0.0);
    }

    #[test]
    fn table_aligns() {
        let mut t = Table::new(&["method", "ms"]);
        t.row(vec!["fp16".into(), "24.1".into()]);
        t.row(vec!["smoothquant".into(), "10.8".into()]);
        let s = t.to_string();
        assert!(s.contains("smoothquant"));
        assert_eq!(s.lines().count(), 4);
    }

    #[test]
    #[should_panic]
    fn table_rejects_bad_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["x".into()]);
    }
}
