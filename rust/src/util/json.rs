//! Minimal JSON: parser + writer (no serde in the offline crate set).
//!
//! Covers the full JSON grammar needed by the artifact manifest, the
//! ONNX-compatible serializer and bench outputs: objects, arrays, strings
//! with escapes, numbers, bools, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn obj(entries: Vec<(&str, Value)>) -> Value {
        Value::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::Str(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::Str(s)
    }
}
impl From<f64> for Value {
    fn from(n: f64) -> Self {
        Value::Num(n)
    }
}
impl From<usize> for Value {
    fn from(n: usize) -> Self {
        Value::Num(n as f64)
    }
}
impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

pub fn parse(input: &str) -> Result<Value> {
    let mut p = Parser { b: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.b.len() {
        bail!("trailing characters at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected '{}' at byte {}", c as char, self.pos)
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        let is_num_byte =
            |c: u8| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-');
        while matches!(self.peek(), Some(c) if is_num_byte(c)) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.pos])?;
        Ok(Value::Num(s.parse::<f64>()?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                self.b
                                    .get(self.pos + 1..self.pos + 5)
                                    .ok_or_else(|| anyhow::anyhow!("bad \\u escape"))?,
                            )?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => bail!("bad escape {:?}", other.map(|c| c as char)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.pos..])?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(out));
                }
                _ => bail!("expected ',' or ']' at byte {}", self.pos),
            }
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            out.insert(key, self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(out));
                }
                _ => bail!("expected ',' or '}}' at byte {}", self.pos),
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------------

pub fn to_string(v: &Value) -> String {
    let mut s = String::new();
    write_value(v, &mut s, 0, false);
    s
}

pub fn to_string_pretty(v: &Value) -> String {
    let mut s = String::new();
    write_value(v, &mut s, 0, true);
    s
}

fn write_value(v: &Value, out: &mut String, indent: usize, pretty: bool) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                let _ = write!(out, "{}", *n as i64);
            } else {
                let _ = write!(out, "{}", n);
            }
        }
        Value::Str(s) => write_string(s, out),
        Value::Arr(a) => {
            out.push('[');
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                if pretty {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent + 1));
                }
                write_value(item, out, indent + 1, pretty);
            }
            if pretty && !a.is_empty() {
                out.push('\n');
                out.push_str(&" ".repeat(indent));
            }
            out.push(']');
        }
        Value::Obj(m) => {
            out.push('{');
            for (i, (k, item)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                if pretty {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent + 1));
                }
                write_string(k, out);
                out.push(':');
                if pretty {
                    out.push(' ');
                }
                write_value(item, out, indent + 1, pretty);
            }
            if pretty && !m.is_empty() {
                out.push('\n');
                out.push_str(&" ".repeat(indent));
            }
            out.push('}');
        }
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(parse(r#""a\nb""#).unwrap(), Value::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"graphs":{"m/v/p/b1":{"file":"f.hlo.txt","inputs":[{"dtype":"f32","name":"w","shape":[2,3]}]}},"n":42}"#;
        let v = parse(src).unwrap();
        let s = to_string(&v);
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn pretty_roundtrip() {
        let v = parse(r#"{"a":[1,2],"b":{"c":true}}"#).unwrap();
        assert_eq!(parse(&to_string_pretty(&v)).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap(), Value::Str("A".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{}x").is_err());
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(to_string(&Value::Num(128.0)), "128");
        assert_eq!(to_string(&Value::Num(0.5)), "0.5");
    }
}
