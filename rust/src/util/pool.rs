//! Dependency-free scoped-thread row-parallel driver for the quantization
//! hot path (`quant::kernels`), using the same plain `std::thread`
//! substrate as `collective::ops` and `coordinator::server`.
//!
//! The model: split a `[rows, width]` row-major buffer into contiguous
//! row ranges, hand each range (and the matching disjoint `&mut` output
//! block) to one scoped thread, and — for column reductions — combine
//! per-range partials *in range order* on the calling thread. Per-element
//! math is untouched and f32 min/max are associative, so results are
//! bit-identical to the single-threaded traversal for any thread count
//! (`tests/kernel_equivalence.rs` pins this).

use std::ops::Range;
use std::sync::OnceLock;

/// Worker threads to fan out to: the `LLEQ_THREADS` env override when set
/// (>= 1), otherwise the machine's available parallelism. Cached for the
/// process lifetime.
pub fn max_threads() -> usize {
    static CACHED: OnceLock<usize> = OnceLock::new();
    *CACHED.get_or_init(|| {
        std::env::var("LLEQ_THREADS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|n| *n >= 1)
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            })
    })
}

/// Split `rows` into at most `max_chunks` contiguous ranges of at least
/// `min_rows` rows each (sizes differ by at most one row). Returns a
/// single range when the work is too small to be worth fanning out, and
/// no ranges when `rows == 0`.
pub fn chunk_ranges(rows: usize, max_chunks: usize, min_rows: usize) -> Vec<Range<usize>> {
    if rows == 0 {
        return Vec::new();
    }
    let cap = (rows / min_rows.max(1)).max(1);
    let chunks = max_chunks.max(1).min(cap);
    let base = rows / chunks;
    let rem = rows % chunks;
    let mut out = Vec::with_capacity(chunks);
    let mut start = 0;
    for i in 0..chunks {
        let len = base + usize::from(i < rem);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Split a `rows * width` row-major buffer into one mutable block per
/// range (ranges must be contiguous, ascending, and cover a prefix of the
/// buffer — exactly what `chunk_ranges` produces).
pub fn split_rows<'a, T>(
    mut data: &'a mut [T],
    ranges: &[Range<usize>],
    width: usize,
) -> Vec<&'a mut [T]> {
    let mut out = Vec::with_capacity(ranges.len());
    for r in ranges {
        let (head, tail) = data.split_at_mut((r.end - r.start) * width);
        out.push(head);
        data = tail;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_ranges_cover_rows_exactly() {
        for rows in [1usize, 2, 7, 64, 513] {
            for chunks in [1usize, 2, 3, 8] {
                let rs = chunk_ranges(rows, chunks, 1);
                assert!(rs.len() <= chunks);
                assert_eq!(rs.first().unwrap().start, 0);
                assert_eq!(rs.last().unwrap().end, rows);
                for w in rs.windows(2) {
                    assert_eq!(w[0].end, w[1].start);
                }
            }
        }
    }

    #[test]
    fn chunk_ranges_respect_min_rows() {
        // 10 rows at min 4 per chunk -> at most 2 chunks
        let rs = chunk_ranges(10, 8, 4);
        assert!(rs.len() <= 2);
        // tiny work stays single-chunk
        assert_eq!(chunk_ranges(3, 8, 4).len(), 1);
        assert!(chunk_ranges(0, 8, 4).is_empty());
    }

    #[test]
    fn split_rows_partitions_disjointly() {
        let mut data = vec![0u32; 10 * 3];
        let ranges = chunk_ranges(10, 4, 1);
        let blocks = split_rows(&mut data, &ranges, 3);
        assert_eq!(blocks.len(), ranges.len());
        let total: usize = blocks.iter().map(|b| b.len()).sum();
        assert_eq!(total, 30);
        for (r, b) in ranges.iter().zip(&blocks) {
            assert_eq!(b.len(), (r.end - r.start) * 3);
        }
    }

    #[test]
    fn max_threads_is_at_least_one() {
        assert!(max_threads() >= 1);
    }
}
