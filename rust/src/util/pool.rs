//! Dependency-free persistent worker pool for the quantization hot path
//! (`quant::kernels`), replacing the per-call scoped-thread spawn that
//! cost ~10-20µs of fan-out overhead on every decode-step quantize.
//!
//! The model: `max_threads() - 1` long-lived workers park on a shared
//! condvar-guarded job queue; `run` enqueues every boxed task, then the
//! calling thread *helps drain the queue* until it is empty and finally
//! blocks until each of its tasks has signalled completion. The pull
//! model is work-conserving: no thread idles while runnable jobs exist,
//! one slow task never convoys jobs behind it, and concurrent callers
//! interleave (a caller may execute another caller's job; completions
//! route to the owning caller through each job's done channel). Because
//! `run` never returns before all its tasks finish, tasks may borrow
//! from the caller's stack exactly like `std::thread::scope` closures —
//! that blocking wait is what makes the lifetime erasure in `erase`
//! sound. A panicking task's payload is carried back to the owning
//! caller and re-raised with `resume_unwind`, so the original message
//! survives the pool hop.
//!
//! Row-range splitting (`chunk_ranges` / `split_rows`) is unchanged: hand
//! each contiguous row range (and the matching disjoint `&mut` output
//! block) to one task, and — for column reductions — combine per-range
//! partials *in range order* on the calling thread. Per-element math is
//! untouched and f32 min/max are associative, so results are bit-identical
//! to the single-threaded traversal for any thread count
//! (`tests/kernel_equivalence.rs` pins this).
//!
//! A task that itself calls `run` (e.g. a parallel prefill-ingest page
//! encoding a large region through a parallel kernel) executes its
//! subtasks inline: workers never wait on other workers, so the pool
//! cannot deadlock on nested fan-outs.

use std::any::Any;
use std::cell::Cell;
use std::collections::VecDeque;
use std::ops::Range;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Condvar, Mutex, OnceLock};

/// A unit of pool work: boxed so `run` can erase its borrow lifetime for
/// the trip through the shared queue.
pub type Task<'a> = Box<dyn FnOnce() + Send + 'a>;

/// A captured panic payload, carried back to the calling thread.
type Panic = Box<dyn Any + Send + 'static>;

struct Job {
    task: Task<'static>,
    done: Sender<Result<(), Panic>>,
}

struct Pool {
    queue: Mutex<VecDeque<Job>>,
    available: Condvar,
    /// live worker threads; grows via [`reserve`], never shrinks
    workers: AtomicUsize,
}

thread_local! {
    /// Set inside pool workers so nested `run` calls execute inline
    /// instead of waiting on sibling workers (deadlock avoidance).
    static IN_POOL_WORKER: Cell<bool> = const { Cell::new(false) };
}

fn pool() -> &'static Pool {
    static POOL: OnceLock<&'static Pool> = OnceLock::new();
    POOL.get_or_init(|| {
        let workers = max_threads().saturating_sub(1);
        let p: &'static Pool = Box::leak(Box::new(Pool {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            workers: AtomicUsize::new(workers),
        }));
        for i in 0..workers {
            std::thread::Builder::new()
                .name(format!("lleq-pool-{i}"))
                .spawn(move || worker_loop(p))
                .expect("spawn pool worker");
        }
        p
    })
}

/// Hard cap on pool growth: beyond this, extra parked threads only cost
/// memory — concurrent callers help-drain anyway.
const MAX_POOL_WORKERS: usize = 64;

/// Grow the pool to at least `min_workers` threads (capped, never
/// shrinks). The server sizes the pool from `shards x batch` at startup
/// so the per-shard kernel fan-outs (prefill page encodes, row-parallel
/// quantize) don't convoy behind one another at high shard counts.
/// An explicit `LLEQ_THREADS` override stays authoritative: reserve
/// never grows past the pool size that override implies, so
/// `LLEQ_THREADS=1` still means strictly serial kernels on every path.
pub fn reserve(min_workers: usize) {
    static GROW: Mutex<()> = Mutex::new(());
    let p = pool();
    let cap = if std::env::var("LLEQ_THREADS").is_ok() {
        max_threads().saturating_sub(1)
    } else {
        MAX_POOL_WORKERS
    };
    let want = min_workers.min(cap);
    let _g = GROW.lock().unwrap_or_else(|e| e.into_inner());
    let have = p.workers.load(Ordering::Relaxed);
    if want <= have {
        return;
    }
    for i in have..want {
        std::thread::Builder::new()
            .name(format!("lleq-pool-{i}"))
            .spawn(move || worker_loop(p))
            .expect("spawn pool worker");
    }
    p.workers.store(want, Ordering::Relaxed);
}

fn worker_loop(p: &'static Pool) {
    IN_POOL_WORKER.with(|f| f.set(true));
    loop {
        let job = {
            let mut q = p.queue.lock().unwrap_or_else(|e| e.into_inner());
            loop {
                match q.pop_front() {
                    Some(job) => break job,
                    None => q = p.available.wait(q).unwrap_or_else(|e| e.into_inner()),
                }
            }
        };
        let result = catch_unwind(AssertUnwindSafe(job.task));
        let _ = job.done.send(result);
    }
}

/// Erase a task's borrow lifetime for the trip through the shared queue.
///
/// SAFETY: sound only because `run` blocks until the task has signalled
/// completion (or executes it inline, caught), so everything the task
/// borrows outlives its execution.
unsafe fn erase(task: Task<'_>) -> Task<'static> {
    let raw: *mut (dyn FnOnce() + Send + '_) = Box::into_raw(task);
    Box::from_raw(raw as *mut (dyn FnOnce() + Send + 'static))
}

/// Execute every task to completion, fanning out across the persistent
/// workers. All tasks go onto the shared queue; the calling thread then
/// *helps drain it* — executing queued jobs (its own, or a concurrent
/// caller's) until the queue is empty — before blocking on the
/// completion barrier. Work-conserving: no thread idles while runnable
/// jobs exist, and no static assignment can convoy jobs behind a slow
/// one. Blocks until all of this call's tasks finish, then re-raises
/// the first task panic with its original payload. Tasks may borrow
/// from the caller's stack (scoped-thread semantics).
pub fn run(tasks: Vec<Task<'_>>) {
    if tasks.is_empty() {
        return;
    }
    let p = pool();
    let nested = IN_POOL_WORKER.with(|f| f.get());
    let workers = p.workers.load(Ordering::Relaxed);
    if tasks.len() == 1 || nested || workers == 0 {
        for t in tasks {
            t();
        }
        return;
    }
    let (done_tx, done_rx) = channel::<Result<(), Panic>>();
    let mut total = 0usize;
    {
        let mut q = p.queue.lock().unwrap_or_else(|e| e.into_inner());
        for t in tasks {
            q.push_back(Job {
                // SAFETY: the recv barrier below blocks until this job
                // signals completion (whoever executes it sends).
                task: unsafe { erase(t) },
                done: done_tx.clone(),
            });
            total += 1;
        }
    }
    // wake only as many workers as there are jobs (no thundering herd)
    for _ in 0..total.min(workers) {
        p.available.notify_one();
    }
    // help drain: panics are caught and routed to the owning caller's
    // done channel, so nothing unwinds out of `run` before the barrier
    // (the soundness invariant of `erase`)
    let mut first_panic: Option<Panic> = None;
    loop {
        let job = {
            let mut q = p.queue.lock().unwrap_or_else(|e| e.into_inner());
            q.pop_front()
        };
        match job {
            Some(job) => {
                let result = catch_unwind(AssertUnwindSafe(job.task));
                let _ = job.done.send(result);
            }
            None => break,
        }
    }
    for _ in 0..total {
        // `done_tx` is still alive in this scope, so recv cannot see a
        // closed channel before every enqueued job reports in — and the
        // wait is what keeps the erased borrows in `Job` sound.
        match done_rx.recv() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => {
                first_panic.get_or_insert(e);
            }
            // unreachable while `done_tx` lives; treat as a lost task
            Err(_) => {
                first_panic.get_or_insert(Box::new("pool worker channel closed"));
            }
        }
    }
    if let Some(e) = first_panic {
        resume_unwind(e);
    }
}

/// Worker threads to fan out to: the `LLEQ_THREADS` env override when set
/// (>= 1), otherwise the machine's available parallelism. Cached for the
/// process lifetime; the persistent pool sizes itself from this.
pub fn max_threads() -> usize {
    static CACHED: OnceLock<usize> = OnceLock::new();
    *CACHED.get_or_init(|| {
        std::env::var("LLEQ_THREADS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|n| *n >= 1)
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            })
    })
}

/// Split `rows` into at most `max_chunks` contiguous ranges of at least
/// `min_rows` rows each (sizes differ by at most one row). Returns a
/// single range when the work is too small to be worth fanning out, and
/// no ranges when `rows == 0`.
pub fn chunk_ranges(rows: usize, max_chunks: usize, min_rows: usize) -> Vec<Range<usize>> {
    if rows == 0 {
        return Vec::new();
    }
    let cap = (rows / min_rows.max(1)).max(1);
    let chunks = max_chunks.max(1).min(cap);
    let base = rows / chunks;
    let rem = rows % chunks;
    let mut out = Vec::with_capacity(chunks);
    let mut start = 0;
    for i in 0..chunks {
        let len = base + usize::from(i < rem);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Split a `rows * width` row-major buffer into one mutable block per
/// range (ranges must be contiguous, ascending, and cover a prefix of the
/// buffer — exactly what `chunk_ranges` produces).
pub fn split_rows<'a, T>(
    mut data: &'a mut [T],
    ranges: &[Range<usize>],
    width: usize,
) -> Vec<&'a mut [T]> {
    let mut out = Vec::with_capacity(ranges.len());
    for r in ranges {
        let (head, tail) = data.split_at_mut((r.end - r.start) * width);
        out.push(head);
        data = tail;
    }
    out
}

#[cfg(test)]
mod tests {
    use std::sync::atomic::{AtomicUsize, Ordering};

    use super::*;

    #[test]
    fn chunk_ranges_cover_rows_exactly() {
        for rows in [1usize, 2, 7, 64, 513] {
            for chunks in [1usize, 2, 3, 8] {
                let rs = chunk_ranges(rows, chunks, 1);
                assert!(rs.len() <= chunks);
                assert_eq!(rs.first().unwrap().start, 0);
                assert_eq!(rs.last().unwrap().end, rows);
                for w in rs.windows(2) {
                    assert_eq!(w[0].end, w[1].start);
                }
            }
        }
    }

    #[test]
    fn chunk_ranges_respect_min_rows() {
        // 10 rows at min 4 per chunk -> at most 2 chunks
        let rs = chunk_ranges(10, 8, 4);
        assert!(rs.len() <= 2);
        // tiny work stays single-chunk
        assert_eq!(chunk_ranges(3, 8, 4).len(), 1);
        assert!(chunk_ranges(0, 8, 4).is_empty());
    }

    #[test]
    fn split_rows_partitions_disjointly() {
        let mut data = vec![0u32; 10 * 3];
        let ranges = chunk_ranges(10, 4, 1);
        let blocks = split_rows(&mut data, &ranges, 3);
        assert_eq!(blocks.len(), ranges.len());
        let total: usize = blocks.iter().map(|b| b.len()).sum();
        assert_eq!(total, 30);
        for (r, b) in ranges.iter().zip(&blocks) {
            assert_eq!(b.len(), (r.end - r.start) * 3);
        }
    }

    #[test]
    fn max_threads_is_at_least_one() {
        assert!(max_threads() >= 1);
    }

    #[test]
    fn run_executes_every_task() {
        let hits = AtomicUsize::new(0);
        let tasks: Vec<Task<'_>> = (0..23)
            .map(|_| {
                Box::new(|| {
                    hits.fetch_add(1, Ordering::Relaxed);
                }) as Task<'_>
            })
            .collect();
        run(tasks);
        assert_eq!(hits.load(Ordering::Relaxed), 23);
    }

    #[test]
    fn run_supports_disjoint_mut_borrows() {
        let mut data = vec![0u32; 64 * 4];
        let ranges = chunk_ranges(64, 8, 1);
        let blocks = split_rows(&mut data, &ranges, 4);
        let tasks: Vec<Task<'_>> = ranges
            .iter()
            .zip(blocks)
            .map(|(r, b)| {
                let start = r.start as u32;
                Box::new(move || {
                    for (i, v) in b.iter_mut().enumerate() {
                        *v = start * 4 + i as u32;
                    }
                }) as Task<'_>
            })
            .collect();
        run(tasks);
        for (i, v) in data.iter().enumerate() {
            assert_eq!(*v, i as u32);
        }
    }

    #[test]
    fn nested_run_completes_inline() {
        let hits = AtomicUsize::new(0);
        let outer: Vec<Task<'_>> = (0..4)
            .map(|_| {
                let hits = &hits;
                Box::new(move || {
                    let inner: Vec<Task<'_>> = (0..4)
                        .map(|_| {
                            Box::new(|| {
                                hits.fetch_add(1, Ordering::Relaxed);
                            }) as Task<'_>
                        })
                        .collect();
                    run(inner);
                }) as Task<'_>
            })
            .collect();
        run(outer);
        assert_eq!(hits.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn panic_payload_survives_the_pool_hop() {
        let tasks: Vec<Task<'_>> = (0..3)
            .map(|i| {
                Box::new(move || {
                    if i == 2 {
                        panic!("boom-{i}");
                    }
                }) as Task<'_>
            })
            .collect();
        let err = catch_unwind(AssertUnwindSafe(|| run(tasks))).unwrap_err();
        // resume_unwind carries the original payload through the pool
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_default();
        assert!(msg.contains("boom-2"), "payload lost: {msg:?}");
    }

    #[test]
    fn reserve_grows_and_still_runs() {
        let before = max_threads().saturating_sub(1);
        reserve(before + 2);
        // idempotent + capped
        reserve(before + 2);
        reserve(usize::MAX);
        let hits = AtomicUsize::new(0);
        let tasks: Vec<Task<'_>> = (0..32)
            .map(|_| {
                Box::new(|| {
                    hits.fetch_add(1, Ordering::Relaxed);
                }) as Task<'_>
            })
            .collect();
        run(tasks);
        assert_eq!(hits.load(Ordering::Relaxed), 32);
    }

    #[test]
    fn pool_survives_a_panicked_task() {
        let bad: Vec<Task<'_>> = vec![Box::new(|| {}), Box::new(|| panic!("transient"))];
        assert!(catch_unwind(AssertUnwindSafe(|| run(bad))).is_err());
        // the workers caught the unwind and keep serving
        let hits = AtomicUsize::new(0);
        let tasks: Vec<Task<'_>> = (0..8)
            .map(|_| {
                Box::new(|| {
                    hits.fetch_add(1, Ordering::Relaxed);
                }) as Task<'_>
            })
            .collect();
        run(tasks);
        assert_eq!(hits.load(Ordering::Relaxed), 8);
    }
}
