//! Mini property-testing harness (no proptest crate offline).
//!
//! `check(seed, cases, gen, prop)` draws `cases` random inputs and asserts
//! the property on each; on failure it performs greedy shrinking via the
//! generator's `shrink` and reports the minimal counterexample. Used by the
//! coordinator invariants tests (DESIGN.md §Substitutions).

use crate::corpus::XorShift64Star;

/// A generator: draws a value from the RNG and optionally shrinks it.
pub trait Gen {
    type Value: Clone + std::fmt::Debug;
    fn draw(&self, rng: &mut XorShift64Star) -> Self::Value;
    /// Candidate smaller values (for shrinking). Default: none.
    fn shrink(&self, _v: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Run a property over `cases` random draws; panic with the (shrunk)
/// counterexample on failure.
pub fn check<G, P>(seed: u64, cases: usize, gen: &G, prop: P)
where
    G: Gen,
    P: Fn(&G::Value) -> bool,
{
    let mut rng = XorShift64Star::new(seed);
    for case in 0..cases {
        let v = gen.draw(&mut rng);
        if !prop(&v) {
            let minimal = shrink_loop(gen, v, &prop);
            panic!("property failed at case {case}; minimal counterexample: {minimal:?}");
        }
    }
}

fn shrink_loop<G, P>(gen: &G, mut v: G::Value, prop: &P) -> G::Value
where
    G: Gen,
    P: Fn(&G::Value) -> bool,
{
    'outer: for _ in 0..1000 {
        for cand in gen.shrink(&v) {
            if !prop(&cand) {
                v = cand;
                continue 'outer;
            }
        }
        break;
    }
    v
}

// ---------------------------------------------------------------------------
// Stock generators
// ---------------------------------------------------------------------------

/// usize in [lo, hi] — shrinks toward lo.
pub struct UsizeRange(pub usize, pub usize);

impl Gen for UsizeRange {
    type Value = usize;
    fn draw(&self, rng: &mut XorShift64Star) -> usize {
        self.0 + rng.next_below((self.1 - self.0 + 1) as u64) as usize
    }
    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *v > self.0 {
            out.push(self.0);
            out.push(self.0 + (v - self.0) / 2);
            out.push(v - 1);
        }
        out.dedup();
        out
    }
}

/// Vec<f32> of length in [min_len, max_len], values ~ scaled normal.
/// Shrinks by halving length and zeroing elements.
pub struct F32Vec {
    pub min_len: usize,
    pub max_len: usize,
    pub scale: f32,
}

impl Gen for F32Vec {
    type Value = Vec<f32>;
    fn draw(&self, rng: &mut XorShift64Star) -> Vec<f32> {
        let len = self.min_len
            + rng.next_below((self.max_len - self.min_len + 1) as u64) as usize;
        (0..len).map(|_| rng.next_normal() as f32 * self.scale).collect()
    }
    fn shrink(&self, v: &Vec<f32>) -> Vec<Vec<f32>> {
        let mut out = Vec::new();
        if v.len() > self.min_len {
            out.push(v[..self.min_len.max(v.len() / 2)].to_vec());
        }
        if v.iter().any(|x| *x != 0.0) {
            out.push(vec![0.0; v.len()]);
        }
        out
    }
}

/// Pair generator.
pub struct Pair<A, B>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for Pair<A, B> {
    type Value = (A::Value, B::Value);
    fn draw(&self, rng: &mut XorShift64Star) -> Self::Value {
        (self.0.draw(rng), self.1.draw(rng))
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> =
            self.0.shrink(&v.0).into_iter().map(|a| (a, v.1.clone())).collect();
        out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

/// Triple generator (shrinks one coordinate at a time, like `Pair`).
pub struct Triple<A, B, C>(pub A, pub B, pub C);

impl<A: Gen, B: Gen, C: Gen> Gen for Triple<A, B, C> {
    type Value = (A::Value, B::Value, C::Value);
    fn draw(&self, rng: &mut XorShift64Star) -> Self::Value {
        (self.0.draw(rng), self.1.draw(rng), self.2.draw(rng))
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = self
            .0
            .shrink(&v.0)
            .into_iter()
            .map(|a| (a, v.1.clone(), v.2.clone()))
            .collect();
        out.extend(
            self.1
                .shrink(&v.1)
                .into_iter()
                .map(|b| (v.0.clone(), b, v.2.clone())),
        );
        out.extend(
            self.2
                .shrink(&v.2)
                .into_iter()
                .map(|c| (v.0.clone(), v.1.clone(), c)),
        );
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check(1, 200, &UsizeRange(0, 100), |v| *v <= 100);
    }

    #[test]
    #[should_panic(expected = "minimal counterexample")]
    fn failing_property_shrinks() {
        check(2, 200, &UsizeRange(0, 100), |v| *v < 50);
    }

    #[test]
    fn f32vec_respects_bounds() {
        let gen = F32Vec { min_len: 2, max_len: 10, scale: 1.0 };
        check(3, 100, &gen, |v| v.len() >= 2 && v.len() <= 10);
    }

    #[test]
    fn pair_draws_both() {
        let gen = Pair(UsizeRange(1, 4), UsizeRange(5, 8));
        check(4, 100, &gen, |(a, b)| *a <= 4 && *b >= 5);
    }

    #[test]
    fn triple_draws_all_three() {
        let gen = Triple(UsizeRange(1, 4), UsizeRange(5, 8), UsizeRange(9, 12));
        check(5, 100, &gen, |(a, b, c)| *a <= 4 && *b >= 5 && *c >= 9);
    }
}
