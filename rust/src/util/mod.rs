//! Small self-contained utilities (the offline image has no serde_json /
//! clap / criterion, so the repo carries its own minimal substrates —
//! DESIGN.md §Substitutions).

pub mod args;
pub mod bench;
pub mod json;
pub mod pool;
pub mod proptest;
