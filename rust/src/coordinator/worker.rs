//! Worker shard: a step-driven execution core over one model backend.
//!
//! One worker models one GPU of the paper's cluster. It owns a paged
//! batched KV cache (fp32 or SimQuant codes depending on the variant)
//! over a shard-wide block pool, a [`PrefixCacheManager`] mapping
//! token-prefix chains to retained blocks, per-layer EMA scale trackers
//! (Alg. 1), and the Eq. 12 breakdown instrumentation.
//!
//! The core is two step primitives the scheduler composes:
//!
//!   [`Worker::join`] — admit requests into free lanes and start their
//!   prefill: whole-prompt by default, or the first `prefill_chunk`
//!   tokens when chunking is on. Admission first probes the prefix
//!   cache — a shared-prefix arrival maps the cached blocks and starts
//!   prefill at the first uncached token — then reserves the lane's
//!   block budget up front so decode appends never fail mid-flight. A
//!   slot whose prompt is fully ingested emits its first token + TTFT;
//!   otherwise it parks in `Phase::Prefilling { next_pos }` and resumes
//!   one chunk per step. When its prefill completes, the prompt's full
//!   blocks are published to the prefix cache for the next arrival.
//!
//!   [`Worker::step`] — one bounded prefill chunk for any mid-prefill
//!   slots, then one fused decode step across every *decoding* slot;
//!   finished slots retire inside the step, release their KV blocks back
//!   to the pool (prefix-retained blocks stay), and emit a `Done`
//!   response.
//!
//! Preemption is a table unmap, not a loss: when an interactive arrival
//! finds no free lane or no free blocks
//! ([`Worker::join_continuous`]), the youngest batch-priority slot is
//! unmapped and parked with its generated tokens intact
//! ([`Worker::resume_parked`] re-maps it when capacity frees, re-
//! prefilling `prompt ++ generated[..n-1]` — mostly prefix-cache hits —
//! and decoding onward from the last generated token). The victim loses
//! at most one step of progress and its stream stays loss/dup-free; the
//! interactive request admits within the same boundary.
//!
//! Static batching is the degenerate composition (join everything, step
//! until drained — [`Worker::process_batch`]); continuous batching
//! interleaves `join` between `step`s at every boundary, which is what
//! kills head-of-line blocking: a finished slot's capacity is reusable
//! on the very next step instead of when the whole batch drains.
//!
//! Chunked prefill bounds the *other* stall: without it, a joining
//! 2k-token prompt prefills whole between decode steps, freezing every
//! in-flight slot for the duration. With `prefill_chunk = c`, each step
//! pays at most `c` prefill tokens before decoding, so the inter-token
//! gap a joiner imposes on its batch neighbors is bounded regardless of
//! prompt length — at the price of a slightly later first token for the
//! joiner itself. Token streams are unaffected: chunked and whole-prompt
//! prefill ingest identical rows (pinned by the serving tests).
//!
//! Backends: [`Backend::Pjrt`] executes compiled AOT artifacts through
//! the runtime engine; [`Backend::Sim`] is the deterministic simulated
//! model (`runtime::SimModel`) the scheduler tests and the batching
//! ablation run offline.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::corpus::PAD;
use crate::metrics::{Breakdown, Stage};
use crate::quant::Variant;
use crate::runtime::{i32_bytes, literal_from_raw, Literal, ModelCfg, ModelHandle, SimModel};
use crate::tensor::{DType, Tensor};

use std::sync::Arc;

use super::batcher::Batch;
use super::kv_cache::{KvCache, LaneExport, PrefillPage, DEFAULT_BLOCK_SIZE};
use super::prefix_cache::PrefixCacheManager;
use super::request::{Priority, Request, Response, ServeEvent};
use super::scale_sync::ScaleSync;

/// Model execution backend for one worker shard.
pub enum Backend {
    /// compiled AOT artifacts through PJRT (requires `--features xla`)
    Pjrt(ModelHandle),
    /// deterministic simulated graphs with a wall-clock cost model
    Sim(SimModel),
}

impl Backend {
    pub fn cfg(&self) -> &ModelCfg {
        match self {
            Backend::Pjrt(h) => &h.cfg,
            Backend::Sim(m) => &m.cfg,
        }
    }

    pub fn variant(&self) -> Variant {
        match self {
            Backend::Pjrt(h) => h.variant,
            Backend::Sim(m) => m.variant,
        }
    }

    /// Compiled graph batch size (slot count).
    pub fn batch(&self) -> usize {
        match self {
            Backend::Pjrt(h) => h.batch,
            Backend::Sim(m) => m.batch,
        }
    }

    pub fn weight_storage_bytes(&self) -> usize {
        match self {
            Backend::Pjrt(h) => h.weight_storage_bytes(),
            Backend::Sim(m) => m.weight_storage_bytes(),
        }
    }

    /// Switch the KV read width for degraded-mode serving. Only the sim
    /// backend supports runtime width changes (PJRT graphs compile the
    /// width in); returns whether the request was applied.
    pub fn set_kv_bits(&self, bits: u32) -> bool {
        match self {
            Backend::Pjrt(_) => false,
            Backend::Sim(m) => {
                m.set_kv_bits(bits);
                true
            }
        }
    }
}

/// Where a slot's request is in its lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    /// prompt ingested up to `next_pos`; the rest prefills one chunk per
    /// step boundary
    Prefilling { next_pos: usize },
    /// prompt fully ingested; the slot decodes one token per step
    Decoding,
}

/// One in-flight request occupying a batch slot (or parked between
/// preemption and resume).
struct Slot {
    req: Request,
    /// current ingest-stream length: the original prompt at admission,
    /// `prompt ++ generated[..n-1]` after a resume
    prompt_len: usize,
    /// original admitted prompt length — the prefix-cache registration
    /// slice and the reported `Response::prompt_len`
    base_prompt_len: usize,
    phase: Phase,
    generated: Vec<i32>,
    ttft_s: f64,
    /// arrival -> slot admission: the queueing/park interval, reported
    /// separately from decode cadence
    queued_s: f64,
    first_token_at: Instant,
    /// admission order — preemption targets the youngest batch slot
    join_seq: u64,
}

/// Counters a worker thread hands back at shutdown.
#[derive(Debug)]
pub struct WorkerStats {
    pub breakdown: Breakdown,
    pub steps: u64,
    pub tokens_out: u64,
    pub joins: u64,
    pub retires: u64,
    pub peak_active: usize,
    /// prompt tokens whose prefill a prefix-cache hit skipped
    pub prefix_hit_tokens: u64,
    /// batch slots unmapped to admit an interactive arrival
    pub preemptions: u64,
    /// tokens re-prefilled (not served by the prefix cache) on resume
    pub resume_reprefill_tokens: u64,
    /// draft tokens proposed by low-bit speculative passes
    pub drafted_tokens: u64,
    /// draft tokens the full-width verify pass accepted
    pub accepted_tokens: u64,
    /// lanes exported at prefill completion for page migration
    pub handoffs: u64,
    /// wall seconds spent in fused prefill passes
    pub prefill_busy_s: f64,
    /// wall seconds spent in fused decode (and draft/verify) passes
    pub decode_busy_s: f64,
}

pub struct Worker {
    pub shard: usize,
    backend: Backend,
    kv: KvCache,
    slots: Vec<Option<Slot>>,
    /// preempted slots awaiting re-map, FIFO
    parked: VecDeque<Slot>,
    /// prefix cache over the KV block pool
    prefix: PrefixCacheManager,
    prefix_enabled: bool,
    next_join_seq: u64,
    /// max prompt tokens prefilled per step boundary (0 = whole prompt);
    /// pinned to 0 on the PJRT backend, whose compiled prefill graph
    /// ingests full prompts
    prefill_chunk: usize,
    pub scales: ScaleSync,
    pub breakdown: Breakdown,
    /// decode steps executed (for per-step metrics)
    pub steps: u64,
    pub tokens_out: u64,
    /// requests admitted into a slot
    pub joins: u64,
    /// requests retired from a slot
    pub retires: u64,
    /// max concurrently in-flight slots observed
    pub peak_active: usize,
    /// prompt tokens whose prefill a prefix-cache hit skipped
    pub prefix_hit_tokens: u64,
    /// batch slots unmapped to admit an interactive arrival
    pub preemptions: u64,
    /// tokens re-prefilled (not served by the prefix cache) on resume
    pub resume_reprefill_tokens: u64,
    /// self-speculative draft depth per decode cycle (0 = plain decode);
    /// pinned to 0 on the PJRT backend, whose compiled graphs have no
    /// low-bit draft variant to run
    spec_k: usize,
    /// draft width (bits) the speculative draft passes run at
    spec_draft_bits: u32,
    /// draft tokens proposed by low-bit speculative passes
    pub drafted_tokens: u64,
    /// draft tokens the full-width verify pass accepted
    pub accepted_tokens: u64,
    /// disaggregated prefill role: when set, a lane whose prefill
    /// completes is exported as a [`ServeEvent::Handoff`] (block table
    /// at packed width) instead of decoding here — the dispatcher
    /// migrates it to a decode-role shard
    handoff_on_prefill: bool,
    /// lanes exported at prefill completion for page migration
    pub handoffs: u64,
    /// wall seconds spent in fused prefill passes
    pub prefill_busy_s: f64,
    /// wall seconds spent in fused decode (and draft/verify) passes
    pub decode_busy_s: f64,
}

impl Worker {
    pub fn new(shard: usize, backend: Backend) -> Self {
        Self::new_chunked(shard, backend, 0)
    }

    /// Worker with a bounded prefill chunk: at most `prefill_chunk`
    /// prompt tokens are ingested per step boundary (0 = whole-prompt
    /// prefill, the pre-chunking behavior). The PJRT backend pins the
    /// chunk to 0 — its compiled prefill graph is whole-prompt.
    /// Fully provisions the block pool (every lane can hold a full
    /// context) with the prefix cache on.
    pub fn new_chunked(shard: usize, backend: Backend, prefill_chunk: usize) -> Self {
        Self::new_chunked_paged(shard, backend, prefill_chunk, None, true)
    }

    /// Worker over an explicit KV block pool. `kv_blocks` bounds the
    /// shard's physical blocks (`None` = fully provisioned: `batch *
    /// ceil(ctx / block_size)`, so lanes never compete); under-
    /// provisioned pools make admission a block-budget question —
    /// arrivals bounce or preempt when the pool runs dry.
    /// `prefix_cache` toggles shared-prefix block reuse.
    pub fn new_chunked_paged(
        shard: usize,
        backend: Backend,
        prefill_chunk: usize,
        kv_blocks: Option<usize>,
        prefix_cache: bool,
    ) -> Self {
        Self::new_spec(shard, backend, prefill_chunk, kv_blocks, prefix_cache, 0, 4)
    }

    /// The widest constructor: [`Worker::new_chunked_paged`] plus
    /// self-speculative decoding. When `spec_k > 0` every decode cycle
    /// drafts up to `spec_k` tokens per lane from the
    /// `spec_draft_bits`-wide variant of the same weights and verifies
    /// them in one fused full-width pass (see `step`); token streams
    /// stay bit-identical to plain decode because only verified tokens
    /// are emitted. Sim backend only — on PJRT the knob pins to 0,
    /// mirroring `prefill_chunk` (compiled graphs have no runtime
    /// draft variant).
    pub fn new_spec(
        shard: usize,
        backend: Backend,
        prefill_chunk: usize,
        kv_blocks: Option<usize>,
        prefix_cache: bool,
        spec_k: usize,
        spec_draft_bits: u32,
    ) -> Self {
        let c = backend.cfg().clone();
        let b = backend.batch();
        let bs = DEFAULT_BLOCK_SIZE.min(c.ctx).max(1);
        let n_blocks = kv_blocks.unwrap_or(b * ((c.ctx + bs - 1) / bs));
        let kv = if backend.variant() == Variant::SimQuant {
            KvCache::new_simquant_bits_paged(c.n_layers, b, c.ctx, c.d_model, 8, bs, n_blocks)
        } else {
            KvCache::new_f32_paged(c.n_layers, b, c.ctx, c.d_model, bs, n_blocks)
        };
        let (prefill_chunk, spec_k) = match &backend {
            Backend::Pjrt(_) => (0, 0),
            Backend::Sim(_) => (prefill_chunk, spec_k),
        };
        let mut slots = Vec::with_capacity(b);
        slots.resize_with(b, || None);
        Worker {
            shard,
            backend,
            kv,
            slots,
            parked: VecDeque::new(),
            prefix: PrefixCacheManager::new(bs),
            prefix_enabled: prefix_cache,
            next_join_seq: 0,
            prefill_chunk,
            scales: ScaleSync::new(c.n_layers, 0.9, 1e-6, 0),
            breakdown: Breakdown::new(),
            steps: 0,
            tokens_out: 0,
            joins: 0,
            retires: 0,
            peak_active: 0,
            prefix_hit_tokens: 0,
            preemptions: 0,
            resume_reprefill_tokens: 0,
            spec_k,
            spec_draft_bits: spec_draft_bits.clamp(1, 8),
            drafted_tokens: 0,
            accepted_tokens: 0,
            handoff_on_prefill: false,
            handoffs: 0,
            prefill_busy_s: 0.0,
            decode_busy_s: 0.0,
        }
    }

    /// Flip the disaggregated prefill role: when on, lanes export at
    /// prefill completion ([`ServeEvent::Handoff`]) instead of decoding
    /// here. Safe to toggle live — lanes already decoding finish where
    /// they are; only *future* prefill completions hand off (that's what
    /// keeps elastic re-roling cheap: no drain barrier).
    pub fn set_handoff(&mut self, on: bool) {
        self.handoff_on_prefill = on;
    }

    /// Whether prefill completions currently hand off.
    pub fn handoff_on_prefill(&self) -> bool {
        self.handoff_on_prefill
    }

    pub fn variant(&self) -> Variant {
        self.backend.variant()
    }

    /// Compiled slot capacity.
    pub fn capacity(&self) -> usize {
        self.backend.batch()
    }

    /// Prefill chunk in effect (0 = whole-prompt).
    pub fn prefill_chunk(&self) -> usize {
        self.prefill_chunk
    }

    /// Speculative draft depth in effect (0 = plain decode; pinned to 0
    /// on PJRT).
    pub fn spec_k(&self) -> usize {
        self.spec_k
    }

    /// Degraded-mode control: switch the backend's KV read width (no-op
    /// on PJRT, whose compiled graphs pin the width). Returns whether
    /// the width was applied.
    pub fn set_kv_bits(&self, bits: u32) -> bool {
        self.backend.set_kv_bits(bits)
    }

    /// Slots available for `join`.
    pub fn free_slots(&self) -> usize {
        self.kv.free_slots()
    }

    /// Requests currently in flight.
    pub fn active(&self) -> usize {
        self.capacity() - self.kv.free_slots()
    }

    /// Whether the worker still owes progress: in-flight slots or
    /// preempted requests awaiting resume.
    pub fn has_work(&self) -> bool {
        self.active() > 0 || !self.parked.is_empty()
    }

    /// Preempted requests awaiting a resume.
    pub fn parked_len(&self) -> usize {
        self.parked.len()
    }

    /// Whether any in-flight slot is batch-priority (a preemption
    /// candidate for an interactive arrival).
    pub fn has_preemptible_batch(&self) -> bool {
        self.slots
            .iter()
            .any(|s| matches!(s, Some(s) if s.req.priority == Priority::Batch))
    }

    /// The shard's KV cache (tests + observability).
    pub fn kv(&self) -> &KvCache {
        &self.kv
    }

    pub fn into_stats(self) -> WorkerStats {
        WorkerStats {
            breakdown: self.breakdown,
            steps: self.steps,
            tokens_out: self.tokens_out,
            joins: self.joins,
            retires: self.retires,
            peak_active: self.peak_active,
            prefix_hit_tokens: self.prefix_hit_tokens,
            preemptions: self.preemptions,
            resume_reprefill_tokens: self.resume_reprefill_tokens,
            drafted_tokens: self.drafted_tokens,
            accepted_tokens: self.accepted_tokens,
            handoffs: self.handoffs,
            prefill_busy_s: self.prefill_busy_s,
            decode_busy_s: self.decode_busy_s,
        }
    }

    /// Admit `reqs` into free slots at a step boundary and start their
    /// prefill (whole prompt when `prefill_chunk == 0`, else the first
    /// chunk). Joiners whose whole prompt fits the first ingest emit
    /// their first token + TTFT immediately; requests whose budget is a
    /// single token retire immediately. This is the strict (static-path)
    /// entry: it never preempts, and errors when lanes or blocks run
    /// out — continuous serving uses [`Worker::join_continuous`].
    pub fn join(&mut self, reqs: Vec<Request>) -> Result<Vec<ServeEvent>> {
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        let b = self.backend.batch();
        if reqs.len() > self.kv.free_slots() {
            bail!(
                "batch of {} exceeds free capacity {} (compiled batch size {b})",
                reqs.len(),
                self.kv.free_slots()
            );
        }
        for req in reqs {
            let id = req.id;
            if self.admit_one(req, false).is_err() {
                bail!("KV block pool exhausted admitting request {id}");
            }
        }
        self.advance_prefill()
    }

    /// Continuous-batching admission: admit what fits, returning what
    /// doesn't to the caller's queue. Interactive arrivals may preempt
    /// the youngest batch-priority slot when lanes or blocks run dry —
    /// the one-step interference bound paged allocation buys.
    pub fn join_continuous(
        &mut self,
        reqs: Vec<Request>,
    ) -> Result<(Vec<ServeEvent>, Vec<Request>)> {
        let mut bounced = Vec::new();
        let mut admitted = false;
        for req in reqs {
            match self.admit_one(req, true) {
                Ok(()) => admitted = true,
                Err(req) => bounced.push(req),
            }
        }
        let events = if admitted { self.advance_prefill()? } else { Vec::new() };
        Ok((events, bounced))
    }

    /// Admit one request: acquire a lane (preempting the youngest batch
    /// slot for an interactive arrival when allowed), probe the prefix
    /// cache so a shared-prefix prompt skips to its first uncached
    /// block, then reserve the lane's whole block budget up front —
    /// evicting idle cached prefixes, then preempting (when allowed) if
    /// the pool is still dry. Returns the request on bounce.
    fn admit_one(&mut self, req: Request, allow_preempt: bool) -> Result<(), Request> {
        let ctx = self.backend.cfg().ctx;
        let preempting = allow_preempt && req.priority == Priority::Interactive;
        let lane = loop {
            if let Some(lane) = self.kv.acquire_slot() {
                break lane;
            }
            if preempting && self.preempt_youngest_batch() {
                continue;
            }
            return Err(req);
        };
        let plen = req.prompt.len().min(ctx - 1);
        let cached = if self.prefix_enabled {
            self.prefix.attach(&req.prompt[..plen], lane, &mut self.kv)
        } else {
            0
        };
        // reserve the full residency now so decode appends cannot hit
        // an exhausted pool mid-flight
        let target = (plen + req.max_new_tokens).min(ctx);
        loop {
            if self.kv.try_reserve(lane, target) {
                break;
            }
            if self.prefix.evict_one(&mut self.kv) {
                continue;
            }
            if preempting && self.preempt_youngest_batch() {
                continue;
            }
            self.kv.release_slot(lane);
            return Err(req);
        }
        self.prefix_hit_tokens += cached as u64;
        // admission into a slot ends the queueing phase: everything
        // before this instant is park/batch-formation delay, not
        // serving cadence
        let queued_s = req.arrival.elapsed().as_secs_f64();
        let join_seq = self.next_join_seq;
        self.next_join_seq += 1;
        self.slots[lane] = Some(Slot {
            req,
            prompt_len: plen,
            base_prompt_len: plen,
            phase: Phase::Prefilling { next_pos: cached },
            generated: Vec::new(),
            ttft_s: 0.0,
            queued_s,
            first_token_at: Instant::now(),
            join_seq,
        });
        self.joins += 1;
        self.peak_active = self.peak_active.max(self.active());
        Ok(())
    }

    /// Unmap the youngest batch-priority slot: its block table releases
    /// back to the pool (prefix-retained blocks stay warm) and the
    /// request parks with its generated tokens intact for
    /// [`Worker::resume_parked`]. O(table) bookkeeping — no KV copies.
    fn preempt_youngest_batch(&mut self) -> bool {
        let victim = self
            .slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| s.as_ref().map(|s| (i, s.join_seq, s.req.priority)))
            .filter(|(_, _, p)| *p == Priority::Batch)
            .max_by_key(|(_, seq, _)| *seq)
            .map(|(i, _, _)| i);
        let Some(lane) = victim else {
            return false;
        };
        let slot = self.slots[lane].take().expect("victim slot is occupied");
        self.kv.release_slot(lane);
        self.parked.push_back(slot);
        self.preemptions += 1;
        true
    }

    /// Re-map preempted requests (FIFO) into free lanes: rebuild the
    /// ingest stream `prompt ++ generated[..n-1]` (the last generated
    /// token's KV row is produced by its own decode step), attach
    /// whatever the prefix cache still holds, and re-enter `Prefilling`
    /// at the first uncached position. The resumed slot then decodes
    /// onward from its last generated token — the stream continues
    /// loss/dup-free under its original seq numbering. Returns how many
    /// requests resumed; their prefill advances at the next step
    /// boundary. Never preempts (resume must not thrash a live slot).
    pub fn resume_parked(&mut self) -> usize {
        let ctx = self.backend.cfg().ctx;
        let mut resumed = 0;
        while !self.parked.is_empty() {
            let Some(lane) = self.kv.acquire_slot() else { break };
            let mut slot = self.parked.pop_front().expect("checked non-empty");
            // rebuild the ingest stream from the original prompt — a
            // slot preempted more than once must not replay twice
            slot.req.prompt.truncate(slot.base_prompt_len);
            let replay = slot.generated.len().saturating_sub(1);
            slot.req.prompt.extend_from_slice(&slot.generated[..replay]);
            slot.prompt_len = slot.req.prompt.len().min(ctx - 1);
            let cached = if self.prefix_enabled {
                self.prefix.attach(&slot.req.prompt[..slot.prompt_len], lane, &mut self.kv)
            } else {
                0
            };
            let target = (slot.base_prompt_len + slot.req.max_new_tokens).min(ctx);
            let reserved = loop {
                if self.kv.try_reserve(lane, target) {
                    break true;
                }
                if self.prefix.evict_one(&mut self.kv) {
                    continue;
                }
                break false;
            };
            if !reserved {
                self.kv.release_slot(lane);
                self.parked.push_front(slot);
                break;
            }
            self.resume_reprefill_tokens += (slot.prompt_len - cached) as u64;
            slot.phase = Phase::Prefilling { next_pos: cached };
            self.slots[lane] = Some(slot);
            resumed += 1;
        }
        if resumed > 0 {
            self.peak_active = self.peak_active.max(self.active());
        }
        resumed
    }

    /// Run one bounded prefill chunk over every mid-prefill slot: one
    /// fused prefill call over the chunk spans, KV rows ingested at their
    /// positions. Slots whose prompt completes emit first token + TTFT
    /// (admission order) and move to `Phase::Decoding`; the rest park
    /// until the next step boundary.
    fn advance_prefill(&mut self) -> Result<Vec<ServeEvent>> {
        let cfg = self.backend.cfg().clone();
        let b = self.backend.batch();
        let (ctx, v, l, d) = (cfg.ctx, cfg.vocab, cfg.n_layers, cfg.d_model);

        let mut tokens = vec![PAD; b * ctx];
        let mut spans = vec![(0usize, 0usize); b];
        let mut advancing: Vec<usize> = Vec::new();
        for slot in 0..b {
            let Some(s) = &self.slots[slot] else { continue };
            let Phase::Prefilling { next_pos } = s.phase else { continue };
            let remaining = s.prompt_len - next_pos;
            let len = if self.prefill_chunk == 0 {
                remaining
            } else {
                remaining.min(self.prefill_chunk)
            };
            tokens[slot * ctx..slot * ctx + s.prompt_len]
                .copy_from_slice(&s.req.prompt[..s.prompt_len]);
            spans[slot] = (next_pos, len);
            advancing.push(slot);
        }
        if advancing.is_empty() {
            return Ok(Vec::new());
        }
        let t_busy = Instant::now();

        // fused prefill over this round's chunk spans
        let outs = match &self.backend {
            Backend::Pjrt(handle) => {
                // whole-prompt only (prefill_chunk pinned to 0): the
                // compiled graph ingests the full token matrix
                let bd = &mut self.breakdown;
                let tok = bd.span(Stage::Load, || Tensor::from_i32(vec![b, ctx], tokens));
                bd.span(Stage::Gemm, || handle.prefill(&[tok]))?
            }
            Backend::Sim(m) => {
                let bd = &mut self.breakdown;
                bd.span(Stage::Gemm, || m.prefill_range(&tokens, &spans))?
            }
        };
        let logits = outs[0].f32_view()?; // [B, CTX, V]
        let k_cache = outs[1].f32_view()?; // [L, B, CTX, D]
        let v_cache = outs[2].f32_view()?;

        // ingest the chunk KV pages (disjoint (slot, layer) fan-out)
        {
            let bd = &mut self.breakdown;
            let kv = &mut self.kv;
            let mut pages = Vec::with_capacity(advancing.len() * l);
            for &slot in &advancing {
                let (start, len) = spans[slot];
                for layer in 0..l {
                    let off = ((layer * b + slot) * ctx + start) * d;
                    pages.push(PrefillPage {
                        slot,
                        layer,
                        k_rows: &k_cache[off..off + len * d],
                        v_rows: &v_cache[off..off + len * d],
                        t0: start,
                        t_len: len,
                    });
                }
            }
            bd.span(Stage::Quant, || kv.ingest_prefill_batch(&pages));
        }
        self.prefill_busy_s += t_busy.elapsed().as_secs_f64();

        // completed prefills emit their first token; unfinished slots
        // record their resume position
        enum After {
            Decode,
            Retire,
            Handoff,
        }
        let mut events = Vec::with_capacity(advancing.len());
        for &slot in &advancing {
            let (start, len) = spans[slot];
            let mut emitted = false;
            let after = {
                let s = self.slots[slot].as_mut().expect("advancing slot is occupied");
                if start + len < s.prompt_len {
                    s.phase = Phase::Prefilling { next_pos: start + len };
                    continue;
                }
                // prompt fully ingested: publish its full blocks so the
                // next shared-prefix arrival skips them
                if self.prefix_enabled {
                    self.prefix.register(
                        &s.req.prompt[..s.base_prompt_len],
                        slot,
                        &mut self.kv,
                    );
                }
                if !s.generated.is_empty() {
                    // resumed after preemption: its first token (and any
                    // later ones) were already served — re-enter decode
                    // from the last generated token, no re-emission (a
                    // prefill-role worker exports the lane instead)
                    s.phase = Phase::Decoding;
                    if self.handoff_on_prefill {
                        After::Handoff
                    } else {
                        continue;
                    }
                } else {
                    let plen = s.prompt_len;
                    let row =
                        &logits[(slot * ctx + plen - 1) * v..(slot * ctx + plen) * v];
                    let tok = argmax(row);
                    s.generated.push(tok);
                    s.ttft_s = s.req.arrival.elapsed().as_secs_f64();
                    s.first_token_at = Instant::now();
                    s.phase = Phase::Decoding;
                    events.push(ServeEvent::Token {
                        id: s.req.id,
                        token: tok,
                        seq: 0,
                        first: true,
                        at: s.first_token_at,
                    });
                    emitted = true;
                    if s.req.max_new_tokens <= 1 {
                        // budget satisfied by the prefill token: retire
                        // locally, nothing to migrate
                        After::Retire
                    } else if self.handoff_on_prefill {
                        After::Handoff
                    } else {
                        After::Decode
                    }
                }
            };
            if emitted {
                self.tokens_out += 1;
            }
            match after {
                After::Decode => {}
                After::Retire => events.push(ServeEvent::Done(self.retire(slot))),
                After::Handoff => events.push(self.hand_off(slot)),
            }
        }
        Ok(events)
    }

    /// One step boundary: a bounded prefill chunk for any mid-prefill
    /// slots, then one fused decode step across every decoding slot.
    /// Finished slots retire inside the step and free their KV pages for
    /// the next join.
    pub fn step(&mut self) -> Result<Vec<ServeEvent>> {
        let cfg = self.backend.cfg().clone();
        let b = self.backend.batch();
        let (ctx, v, l, d) = (cfg.ctx, cfg.vocab, cfg.n_layers, cfg.d_model);

        // snapshot the decoding set *before* the prefill chunk: a slot
        // whose prefill completes this step decodes from the next one,
        // matching the whole-prompt path (join emits the first token,
        // the following step produces the second)
        let mut active = vec![false; b];
        let mut token = vec![PAD; b];
        let mut pos = vec![0i32; b];
        let mut any = false;
        for slot in 0..b {
            if let Some(s) = &self.slots[slot] {
                if s.phase != Phase::Decoding {
                    continue;
                }
                active[slot] = true;
                token[slot] = *s.generated.last().expect("decoding slots hold >= 1 token");
                pos[slot] = self.kv.len(slot) as i32;
                any = true;
            }
        }

        // the bounded prefill chunk this boundary pays (no-op when no
        // slot is mid-prefill)
        let mut events = self.advance_prefill()?;
        if !any {
            return Ok(events);
        }
        if self.spec_k > 0 && matches!(self.backend, Backend::Sim(_)) {
            return self.step_speculative(events, &active, &token);
        }
        let t_busy = Instant::now();

        let outs = match &self.backend {
            Backend::Pjrt(handle) => {
                // build literals straight from the KV buffers (input
                // order: token, pos, k_cache, v_cache, [params])
                let bd = &mut self.breakdown;
                let kv = &self.kv;
                let lits = bd.span(Stage::Load, || -> Result<Vec<Literal>> {
                    let mut lits = vec![
                        literal_from_raw(DType::I32, &[b], i32_bytes(&token))?,
                        literal_from_raw(DType::I32, &[b], i32_bytes(&pos))?,
                    ];
                    lits.extend(kv.input_literals()?);
                    Ok(lits)
                })?;
                bd.span(Stage::Gemm, || handle.decode_literals(&lits))?
            }
            Backend::Sim(m) => {
                let bd = &mut self.breakdown;
                bd.span(Stage::Gemm, || m.decode(&token, &pos, &active))?
            }
        };
        self.steps += 1;
        let step_logits = outs[0].f32_view()?; // [B, V]
        let k_new = outs[1].f32_view()?; // [L, B, D]
        let v_new = outs[2].f32_view()?;

        // append the new KV rows + track activation ranges (Alg. 1);
        // mid-prefill slots were not decoded and get no rows
        {
            let bd = &mut self.breakdown;
            let kv = &mut self.kv;
            let scales = &mut self.scales;
            let act = &active;
            bd.span(Stage::Quant, || {
                for (slot, &live) in act.iter().enumerate() {
                    if !live {
                        continue;
                    }
                    for layer in 0..l {
                        let off = (layer * b + slot) * d;
                        kv.append_row(slot, layer, &k_new[off..off + d], &v_new[off..off + d]);
                        scales.observe(layer, &k_new[off..off + d]);
                    }
                    kv.bump(slot);
                }
            });
        }
        self.decode_busy_s += t_busy.elapsed().as_secs_f64();

        // emit this step's tokens; retire finished slots immediately
        for slot in 0..b {
            if !active[slot] {
                continue;
            }
            let done = {
                let s = self.slots[slot].as_mut().expect("active slot is occupied");
                let row = &step_logits[slot * v..(slot + 1) * v];
                let tok = argmax(row);
                s.generated.push(tok);
                events.push(ServeEvent::Token {
                    id: s.req.id,
                    token: tok,
                    seq: s.generated.len() - 1,
                    first: false,
                    at: Instant::now(),
                });
                s.generated.len() >= s.req.max_new_tokens || self.kv.len(slot) + 1 >= ctx
            };
            self.tokens_out += 1;
            if done {
                events.push(ServeEvent::Done(self.retire(slot)));
            }
        }
        Ok(events)
    }

    /// One self-speculative draft/verify/accept cycle over the decoding
    /// set (sim backend only; `active`/`token` are the pre-prefill
    /// decoding snapshot `step` built). Each lane autoregressively
    /// drafts up to `spec_k` tokens through the `spec_draft_bits`-wide
    /// variant of the same weights, appending their KV rows as it goes;
    /// then ONE fused full-width pass verifies every drafted position
    /// plus a continuation slot per lane. The longest draft prefix
    /// matching the full-width argmax is accepted and the verify row
    /// right after it supplies the next token (the correction when a
    /// draft missed, the bonus continuation when all landed) — so every
    /// emitted token is exactly the plain-decode token and streams stay
    /// bit-identical by construction. A rejected suffix rolls the
    /// lane's paged KV table back via [`KvCache::truncate`]: pure
    /// bookkeeping, no block movement, and the lane's admission-time
    /// block reservation is never exceeded, so rollback never needs to
    /// free anything. Only the verify pass advances the fault clock —
    /// one speculative cycle is one counted fused step.
    fn step_speculative(
        &mut self,
        mut events: Vec<ServeEvent>,
        active: &[bool],
        token: &[i32],
    ) -> Result<Vec<ServeEvent>> {
        let cfg = self.backend.cfg().clone();
        let b = self.backend.batch();
        let (ctx, v, l, d) = (cfg.ctx, cfg.vocab, cfg.n_layers, cfg.d_model);
        let draft_bits = self.spec_draft_bits;
        let t_busy = Instant::now();

        // per-lane draft depth: bounded by the speculation knob, the
        // remaining token budget, and the context ceiling (a cycle
        // emits up to k_eff + 1 tokens), so lanes retire at exactly the
        // plain-decode boundaries and drafting never outruns the block
        // reservation made at admission
        let mut k_eff = vec![0usize; b];
        let mut pos0 = vec![0usize; b];
        for slot in 0..b {
            if !active[slot] {
                continue;
            }
            let s = self.slots[slot].as_ref().expect("active slot is occupied");
            pos0[slot] = self.kv.len(slot);
            k_eff[slot] = self
                .spec_k
                .min(s.req.max_new_tokens.saturating_sub(s.generated.len() + 1))
                .min(ctx.saturating_sub(pos0[slot] + 2));
        }
        let k_max = (0..b).filter(|&s| active[s]).map(|s| k_eff[s]).max().unwrap_or(0);
        let kk = k_max + 1;

        // draft phase (k_max low-bit passes), then one fused verify
        let mut drafts: Vec<Vec<i32>> = vec![Vec::new(); b];
        let verify_outs = {
            let Backend::Sim(model) = &self.backend else {
                bail!("speculative decoding requires the sim backend");
            };
            let bd = &mut self.breakdown;
            let kv = &mut self.kv;
            let scales = &mut self.scales;
            let mut cur = token.to_vec();
            let mut drafted = 0u64;
            for i in 1..=k_max {
                let mut dact = vec![false; b];
                let mut dtok = vec![PAD; b];
                let mut dpos = vec![0i32; b];
                for slot in 0..b {
                    if active[slot] && k_eff[slot] >= i {
                        dact[slot] = true;
                        dtok[slot] = cur[slot];
                        dpos[slot] = kv.len(slot) as i32;
                        drafted += 1;
                    }
                }
                let outs = bd.span(Stage::Gemm, || {
                    model.decode_draft(&dtok, &dpos, &dact, draft_bits)
                })?;
                let d_logits = outs[0].f32_view()?; // [B, V]
                let k_new = outs[1].f32_view()?; // [L, B, D]
                let v_new = outs[2].f32_view()?;
                bd.span(Stage::Quant, || {
                    for slot in 0..b {
                        if !dact[slot] {
                            continue;
                        }
                        for layer in 0..l {
                            let off = (layer * b + slot) * d;
                            kv.append_row(
                                slot,
                                layer,
                                &k_new[off..off + d],
                                &v_new[off..off + d],
                            );
                            scales.observe(layer, &k_new[off..off + d]);
                        }
                        kv.bump(slot);
                    }
                });
                for slot in 0..b {
                    if dact[slot] {
                        let t = argmax(&d_logits[slot * v..(slot + 1) * v]);
                        drafts[slot].push(t);
                        cur[slot] = t;
                    }
                }
            }
            self.drafted_tokens += drafted;
            let mut vtok = vec![PAD; b * kk];
            let mut vpos = vec![0i32; b * kk];
            let mut vlive = vec![false; b * kk];
            for slot in 0..b {
                if !active[slot] {
                    continue;
                }
                for j in 0..=k_eff[slot] {
                    let i = slot * kk + j;
                    vtok[i] = if j == 0 { token[slot] } else { drafts[slot][j - 1] };
                    vpos[i] = (pos0[slot] + j) as i32;
                    vlive[i] = true;
                }
            }
            bd.span(Stage::Gemm, || model.decode_verify(&vtok, &vpos, &vlive, kk))?
        };
        self.steps += 1;
        let v_logits = verify_outs[0].f32_view()?; // [B, kk, V]
        let k_new = verify_outs[1].f32_view()?; // [L, B, kk, D]
        let v_new = verify_outs[2].f32_view()?;

        // accept the longest draft prefix matching the full-width
        // argmax; the verify row after it is the next emitted token
        let mut accept = vec![0usize; b];
        let mut next_tok = vec![PAD; b];
        for slot in 0..b {
            if !active[slot] {
                continue;
            }
            let mut j = 0usize;
            while j < k_eff[slot] {
                let row = &v_logits[(slot * kk + j) * v..(slot * kk + j + 1) * v];
                if drafts[slot][j] != argmax(row) {
                    break;
                }
                j += 1;
            }
            accept[slot] = j;
            let row = &v_logits[(slot * kk + j) * v..(slot * kk + j + 1) * v];
            next_tok[slot] = argmax(row);
            self.accepted_tokens += j as u64;
        }

        // KV fixup: a rejected suffix rolls the table back (no block
        // movement); a fully-accepted chain appends the verify pass's
        // bonus row so the cache ends one row behind the stream,
        // exactly like plain decode
        {
            let kv = &mut self.kv;
            let scales = &mut self.scales;
            let bd = &mut self.breakdown;
            bd.span(Stage::Quant, || {
                for slot in 0..b {
                    if !active[slot] {
                        continue;
                    }
                    let (j, ke) = (accept[slot], k_eff[slot]);
                    if j < ke {
                        kv.truncate(slot, pos0[slot] + j + 1);
                    } else {
                        for layer in 0..l {
                            let off = ((layer * b + slot) * kk + ke) * d;
                            kv.append_row(
                                slot,
                                layer,
                                &k_new[off..off + d],
                                &v_new[off..off + d],
                            );
                            scales.observe(layer, &k_new[off..off + d]);
                        }
                        kv.bump(slot);
                    }
                }
            });
        }
        self.decode_busy_s += t_busy.elapsed().as_secs_f64();

        // emit the accepted prefix + the verify token; retire finished
        // lanes exactly where plain decode would
        for slot in 0..b {
            if !active[slot] {
                continue;
            }
            let done = {
                let s = self.slots[slot].as_mut().expect("active slot is occupied");
                for t in 0..=accept[slot] {
                    let tok =
                        if t < accept[slot] { drafts[slot][t] } else { next_tok[slot] };
                    s.generated.push(tok);
                    events.push(ServeEvent::Token {
                        id: s.req.id,
                        token: tok,
                        seq: s.generated.len() - 1,
                        first: false,
                        at: Instant::now(),
                    });
                }
                s.generated.len() >= s.req.max_new_tokens || self.kv.len(slot) + 1 >= ctx
            };
            self.tokens_out += (accept[slot] + 1) as u64;
            if done {
                events.push(ServeEvent::Done(self.retire(slot)));
            }
        }
        Ok(events)
    }

    /// Run one batch to completion (static scheduling): join everything,
    /// step until drained. Returns a response per request in completion
    /// order.
    pub fn process_batch(&mut self, batch: Batch) -> Result<Vec<Response>> {
        let mut responses = Vec::with_capacity(batch.len());
        let mut events = self.join(batch.requests)?;
        loop {
            for e in events {
                if let ServeEvent::Done(r) = e {
                    responses.push(r);
                }
            }
            if self.active() == 0 {
                break;
            }
            events = self.step()?;
        }
        Ok(responses)
    }

    /// Free a finished slot and build its response.
    fn retire(&mut self, slot: usize) -> Response {
        let s = self.slots[slot].take().expect("retire of empty slot");
        self.kv.release_slot(slot);
        self.retires += 1;
        Response {
            id: s.req.id,
            tokens: s.generated,
            prompt_len: s.base_prompt_len,
            priority: s.req.priority,
            latency_s: s.req.arrival.elapsed().as_secs_f64(),
            ttft_s: s.ttft_s,
            queued_s: s.queued_s,
            first_token_at: s.first_token_at,
            shard: self.shard,
        }
    }

    /// Export a lane and release it, returning the
    /// [`ServeEvent::Handoff`] the dispatcher migrates to a decode
    /// shard. The block table is serialized at true packed width
    /// *before* the lane frees; the carried request is restored to its
    /// original prompt (a resumed slot's ingest stream may have been
    /// extended with generated tokens). The lane's capacity is reusable
    /// on the very next join — a prefill-role worker turns its lanes
    /// over per prompt, not per stream.
    fn hand_off(&mut self, slot: usize) -> ServeEvent {
        let pages = Arc::new(self.kv.export_lane(slot));
        let mut s = self.slots[slot].take().expect("handoff of empty slot");
        self.kv.release_slot(slot);
        self.handoffs += 1;
        s.req.prompt.truncate(s.base_prompt_len);
        ServeEvent::Handoff {
            shard: self.shard,
            req: s.req,
            generated: s.generated,
            ttft_s: s.ttft_s,
            queued_s: s.queued_s,
            first_token_at: Some(s.first_token_at),
            pages,
        }
    }

    /// Export the *youngest* decoding lane as a migration handoff (the
    /// rebalance path: a freshly revived shard asks the most-loaded
    /// survivor for work, and the youngest lane has the most stream
    /// left to gain from moving). Mid-prefill lanes never qualify —
    /// their block tables are incomplete. Returns `None` when nothing
    /// is decoding.
    pub fn export_one_lane(&mut self) -> Option<ServeEvent> {
        let lane = self
            .slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| s.as_ref().map(|s| (i, s.join_seq, s.phase)))
            .filter(|(_, _, phase)| *phase == Phase::Decoding)
            .max_by_key(|(_, seq, _)| *seq)
            .map(|(i, _, _)| i)?;
        Some(self.hand_off(lane))
    }

    /// Admit a migrated lane: acquire a slot, map the exported block
    /// table into the local pool (no re-prefill), extend the block
    /// reservation to the stream's full residency, and resume decoding
    /// from the last generated token. The continued stream is
    /// bit-identical to staying put because the imported pages preserve
    /// every (row, position) and the model trajectory is a pure
    /// function of them. Timing fields carry over from the source shard
    /// so TTFT/queueing reflect the request's real history. Returns the
    /// request on failure (no free lane, or the pool cannot hold the
    /// residency) — the dispatcher's cue to fall back to re-prefill
    /// injection, the no-pages path.
    #[allow(clippy::result_large_err)]
    pub fn import_handoff(
        &mut self,
        req: Request,
        generated: Vec<i32>,
        pages: &LaneExport,
        ttft_s: f64,
        queued_s: f64,
        first_token_at: Option<Instant>,
    ) -> Result<(), Request> {
        let ctx = self.backend.cfg().ctx;
        if generated.is_empty() || pages.is_empty() || pages.len() > ctx {
            return Err(req);
        }
        let Some(lane) = self.kv.acquire_slot() else {
            return Err(req);
        };
        if !self.kv.import_lane(lane, pages) {
            self.kv.release_slot(lane);
            return Err(req);
        }
        let plen = req.prompt.len().min(ctx - 1);
        // extend the reservation to the full residency now so decode
        // appends cannot hit an exhausted pool mid-flight (mirrors
        // admission), evicting idle cached prefixes if needed
        let target = (plen + req.max_new_tokens).min(ctx);
        loop {
            if self.kv.try_reserve(lane, target) {
                break;
            }
            if self.prefix.evict_one(&mut self.kv) {
                continue;
            }
            self.kv.release_slot(lane);
            return Err(req);
        }
        let join_seq = self.next_join_seq;
        self.next_join_seq += 1;
        self.slots[lane] = Some(Slot {
            req,
            prompt_len: plen,
            base_prompt_len: plen,
            phase: Phase::Decoding,
            generated,
            ttft_s,
            queued_s,
            first_token_at: first_token_at.unwrap_or_else(Instant::now),
            join_seq,
        });
        self.joins += 1;
        self.peak_active = self.peak_active.max(self.active());
        Ok(())
    }
}

fn argmax(row: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, v) in row.iter().enumerate() {
        if *v > row[best] {
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use std::time::Instant;

    use super::*;
    use crate::runtime::SimCost;

    fn sim_worker(variant: Variant, batch: usize) -> Worker {
        Worker::new(0, Backend::Sim(SimModel::tiny(variant, batch, SimCost::fast())))
    }

    fn req(id: u64, prompt_len: usize, max_new: usize) -> Request {
        Request::new(id, vec![2 + (id % 7) as i32; prompt_len], max_new)
    }

    #[test]
    fn argmax_picks_first_max() {
        assert_eq!(argmax(&[0.1, 0.9, 0.9, 0.2]), 1);
        assert_eq!(argmax(&[-5.0, -1.0]), 1);
    }

    #[test]
    fn join_then_steps_drain_batch() {
        let mut w = sim_worker(Variant::Fp, 4);
        let batch = Batch {
            requests: vec![req(1, 4, 3), req(2, 6, 5)],
            formed_at: Instant::now(),
        };
        let rs = w.process_batch(batch).unwrap();
        assert_eq!(rs.len(), 2);
        let by_id = |id: u64| rs.iter().find(|r| r.id == id).unwrap();
        assert_eq!(by_id(1).tokens.len(), 3);
        assert_eq!(by_id(2).tokens.len(), 5);
        assert_eq!(w.active(), 0);
        assert_eq!(w.free_slots(), 4);
        assert_eq!(w.joins, 2);
        assert_eq!(w.retires, 2);
        // request 1 finished first (fewer tokens) -> completion order
        assert_eq!(rs[0].id, 1);
    }

    #[test]
    fn midflight_join_retires_independently() {
        let mut w = sim_worker(Variant::SimQuant, 4);
        let evs = w.join(vec![req(1, 4, 6)]).unwrap();
        assert_eq!(evs.len(), 1, "first token only");
        let _ = w.step().unwrap();
        // join a second request two steps into the first one's decode
        let _ = w.step().unwrap();
        let evs = w.join(vec![req(2, 4, 2)]).unwrap();
        assert_eq!(evs.len(), 1);
        assert_eq!(w.active(), 2);
        // one more step finishes request 2 (budget 2) but not request 1
        let evs = w.step().unwrap();
        let done: Vec<u64> = evs
            .iter()
            .filter_map(|e| match e {
                ServeEvent::Done(r) => Some(r.id),
                _ => None,
            })
            .collect();
        assert_eq!(done, vec![2]);
        assert_eq!(w.active(), 1);
        assert_eq!(w.free_slots(), 3, "slot freed immediately at retirement");
        // drain request 1
        while w.active() > 0 {
            let _ = w.step().unwrap();
        }
        assert_eq!(w.retires, 2);
    }

    #[test]
    fn single_token_budget_retires_at_join() {
        let mut w = sim_worker(Variant::Fp, 2);
        let evs = w.join(vec![req(1, 4, 1)]).unwrap();
        assert_eq!(evs.len(), 2, "token + done");
        assert!(matches!(&evs[1], ServeEvent::Done(r) if r.tokens.len() == 1));
        assert_eq!(w.active(), 0);
        assert_eq!(w.steps, 0, "no decode steps for a 1-token budget");
    }

    #[test]
    fn join_rejects_overflow() {
        let mut w = sim_worker(Variant::Fp, 2);
        let err = w
            .join(vec![req(1, 4, 2), req(2, 4, 2), req(3, 4, 2)])
            .unwrap_err();
        assert!(err.to_string().contains("exceeds free capacity"), "{err}");
    }

    fn chunked_worker(variant: Variant, batch: usize, chunk: usize) -> Worker {
        Worker::new_chunked(
            0,
            Backend::Sim(SimModel::tiny(variant, batch, SimCost::fast())),
            chunk,
        )
    }

    #[test]
    fn chunked_join_defers_first_token_until_prompt_ingested() {
        let mut w = chunked_worker(Variant::Fp, 2, 4);
        // 10-token prompt at chunk 4 -> join ingests 4, two more steps
        // finish the prompt (4 + 4 + 2)
        let evs = w.join(vec![req(1, 10, 3)]).unwrap();
        assert!(evs.is_empty(), "first token before the prompt is ingested");
        assert_eq!(w.active(), 1, "mid-prefill slot occupies capacity");
        let evs = w.step().unwrap();
        assert!(evs.is_empty(), "still mid-prefill");
        let evs = w.step().unwrap();
        assert_eq!(evs.len(), 1, "prompt complete -> first token");
        assert!(matches!(&evs[0], ServeEvent::Token { first: true, .. }));
        assert_eq!(w.steps, 0, "no decode steps ran while prefilling alone");
        // drain the remaining budget
        while w.active() > 0 {
            let _ = w.step().unwrap();
        }
        assert_eq!(w.retires, 1);
    }

    #[test]
    fn chunked_process_batch_matches_whole_prompt() {
        // chunked and whole-prompt prefill must generate identical token
        // streams — the sim trajectory is a pure function of (token, pos)
        let run = |chunk: usize| {
            let mut w = chunked_worker(Variant::SimQuant, 4, chunk);
            let rs = w
                .process_batch(Batch {
                    requests: vec![req(1, 11, 5), req(2, 3, 4), req(3, 17, 3)],
                    formed_at: Instant::now(),
                })
                .unwrap();
            let mut rs: Vec<_> = rs.into_iter().map(|r| (r.id, r.tokens)).collect();
            rs.sort();
            rs
        };
        assert_eq!(run(0), run(4), "chunked prefill changed a token stream");
        assert_eq!(run(0), run(1), "single-token chunks changed a token stream");
    }

    #[test]
    fn inflight_slots_decode_between_chunks() {
        let mut w = chunked_worker(Variant::Fp, 4, 4);
        // request 1: short prompt, long budget -> decoding while 2 joins
        let evs = w.join(vec![req(1, 4, 12)]).unwrap();
        assert_eq!(evs.len(), 1, "whole 4-token prompt fits one chunk");
        // request 2: 16-token prompt = 4 chunks (1 at join + 3 steps)
        let evs = w.join(vec![req(2, 16, 2)]).unwrap();
        assert!(evs.is_empty());
        let mut r1_tokens_during_prefill = 0;
        loop {
            let evs = w.step().unwrap();
            let r2_first = evs
                .iter()
                .any(|e| matches!(e, ServeEvent::Token { id: 2, first: true, .. }));
            r1_tokens_during_prefill += evs
                .iter()
                .filter(|e| matches!(e, ServeEvent::Token { id: 1, .. }))
                .count();
            if r2_first {
                break;
            }
        }
        assert!(
            r1_tokens_during_prefill >= 3,
            "request 1 made only {r1_tokens_during_prefill} decode steps while 2 prefilled"
        );
    }

    #[test]
    fn prefill_chunk_knob_is_reported() {
        // sim backends honor the knob (PJRT pins it to 0 — whole-prompt
        // compiled graph); the accessor reports what is in effect
        let w = chunked_worker(Variant::Fp, 2, 8);
        assert_eq!(w.prefill_chunk(), 8);
        let w0 = sim_worker(Variant::Fp, 2);
        assert_eq!(w0.prefill_chunk(), 0);
    }

    #[test]
    fn token_seq_counts_per_stream_position() {
        // `seq` is the token's 0-based position in its request's stream
        // — the dedup key exactly-once failover delivery rebases on
        let mut w = sim_worker(Variant::Fp, 4);
        let mut seqs: Vec<(u64, usize)> = Vec::new();
        let mut evs = w.join(vec![req(1, 4, 4), req(2, 4, 2)]).unwrap();
        loop {
            for e in &evs {
                if let ServeEvent::Token { id, seq, .. } = e {
                    seqs.push((*id, *seq));
                }
            }
            if w.active() == 0 {
                break;
            }
            evs = w.step().unwrap();
        }
        let of = |id: u64| -> Vec<usize> {
            seqs.iter().filter(|(i, _)| *i == id).map(|(_, s)| *s).collect()
        };
        assert_eq!(of(1), vec![0, 1, 2, 3]);
        assert_eq!(of(2), vec![0, 1]);
    }

    fn paged_worker(
        variant: Variant,
        batch: usize,
        chunk: usize,
        kv_blocks: Option<usize>,
        prefix: bool,
    ) -> Worker {
        Worker::new_chunked_paged(
            0,
            Backend::Sim(SimModel::tiny(variant, batch, SimCost::fast())),
            chunk,
            kv_blocks,
            prefix,
        )
    }

    #[test]
    fn prefix_hit_skips_prefill_and_preserves_stream() {
        // ids 1 and 8 build identical prompts (2 + id % 7 == 3), so the
        // second arrival hits the chain the first one registered
        let mut w = sim_worker(Variant::Fp, 4);
        let first = w
            .process_batch(Batch { requests: vec![req(1, 24, 4)], formed_at: Instant::now() })
            .unwrap();
        assert_eq!(w.prefix_hit_tokens, 0, "cold arrival cannot hit");
        assert!(w.kv().retained_count() > 0, "full prompt blocks were published");
        let second = w
            .process_batch(Batch { requests: vec![req(8, 24, 4)], formed_at: Instant::now() })
            .unwrap();
        // one full 16-token block is cached; the 24-token prompt's tail
        // (and at least the last token) still prefills
        assert_eq!(w.prefix_hit_tokens, 16);
        assert_eq!(first[0].tokens, second[0].tokens, "prefix hit changed the stream");
    }

    #[test]
    fn preempt_resume_continues_stream_loss_dup_free() {
        let solo = {
            let mut w = sim_worker(Variant::Fp, 1);
            let rs = w
                .process_batch(Batch {
                    requests: vec![req(5, 20, 6)],
                    formed_at: Instant::now(),
                })
                .unwrap();
            rs[0].tokens.clone()
        };
        let mut w = sim_worker(Variant::Fp, 1);
        let mut events = w
            .join(vec![req(5, 20, 6).with_priority(Priority::Batch)])
            .unwrap();
        events.extend(w.step().unwrap());
        // lane and pool are held by the batch slot: the interactive
        // arrival preempts it and admits within the same boundary
        let (evs, bounced) = w.join_continuous(vec![req(9, 4, 2)]).unwrap();
        assert!(bounced.is_empty(), "interactive arrival must not bounce");
        assert_eq!(w.preemptions, 1);
        assert_eq!(w.parked_len(), 1);
        assert!(
            evs.iter()
                .any(|e| matches!(e, ServeEvent::Token { id: 9, first: true, .. })),
            "interactive first token within the join boundary"
        );
        events.extend(evs);
        while w.active() > 0 {
            events.extend(w.step().unwrap());
        }
        assert_eq!(w.resume_parked(), 1);
        assert!(w.resume_reprefill_tokens > 0, "resume re-prefills the uncached tail");
        while w.has_work() {
            events.extend(w.step().unwrap());
        }
        let stream: Vec<(usize, i32)> = events
            .iter()
            .filter_map(|e| match e {
                ServeEvent::Token { id: 5, seq, token, .. } => Some((*seq, *token)),
                _ => None,
            })
            .collect();
        let seqs: Vec<usize> = stream.iter().map(|(s, _)| *s).collect();
        assert_eq!(seqs, vec![0, 1, 2, 3, 4, 5], "loss/dup-free seq numbering");
        let tokens: Vec<i32> = stream.iter().map(|(_, t)| *t).collect();
        assert_eq!(tokens, solo, "preempt + resume changed the stream");
        let done: Vec<u64> = events
            .iter()
            .filter_map(|e| match e {
                ServeEvent::Done(r) => Some(r.id),
                _ => None,
            })
            .collect();
        assert_eq!(done, vec![9, 5], "both requests complete");
    }

    #[test]
    fn paged_pool_drains_clean() {
        // prefix cache off: every block returns to the free pool
        let mut w = paged_worker(Variant::SimQuant, 4, 4, None, false);
        let total = w.kv().total_blocks();
        assert_eq!(w.kv().free_block_count(), total);
        let rs = w
            .process_batch(Batch {
                requests: vec![req(1, 20, 4), req(2, 33, 5), req(3, 10, 3)],
                formed_at: Instant::now(),
            })
            .unwrap();
        assert_eq!(rs.len(), 3);
        assert_eq!(w.active(), 0);
        assert_eq!(w.kv().free_block_count(), total, "refcount leak: blocks not returned");
        assert_eq!(w.kv().retained_count(), 0);
        // prefix cache on: drained pool = free + retained prefix blocks
        let mut w = paged_worker(Variant::SimQuant, 4, 4, None, true);
        let _ = w
            .process_batch(Batch {
                requests: vec![req(1, 20, 4), req(2, 33, 5), req(3, 10, 3)],
                formed_at: Instant::now(),
            })
            .unwrap();
        assert_eq!(w.kv().free_block_count() + w.kv().retained_count(), total);
        // 20 -> 1 full block, 33 -> 2, 10 -> 0
        assert_eq!(w.kv().retained_count(), 3);
    }

    #[test]
    fn prefix_cache_and_small_pools_do_not_change_streams() {
        // ids 1/8/15 share the token fill (2 + id % 7 == 3): maximal
        // prefix sharing across all three prompts
        let run = |kv_blocks: Option<usize>, prefix: bool| {
            let mut w = paged_worker(Variant::Fp, 4, 4, kv_blocks, prefix);
            let rs = w
                .process_batch(Batch {
                    requests: vec![req(1, 24, 5), req(8, 24, 5), req(15, 9, 4)],
                    formed_at: Instant::now(),
                })
                .unwrap();
            let mut rs: Vec<_> = rs.into_iter().map(|r| (r.id, r.tokens)).collect();
            rs.sort();
            rs
        };
        let reference = run(None, false);
        assert_eq!(reference, run(None, true), "prefix cache changed a stream");
        // 2 + 2 + 1 = 5 blocks of residency squeezed into a 6-block pool
        assert_eq!(reference, run(Some(6), true), "tight pool changed a stream");
        assert_eq!(reference, run(Some(6), false));
    }

    fn spec_worker(variant: Variant, batch: usize, k: usize, bits: u32) -> Worker {
        Worker::new_spec(
            0,
            Backend::Sim(SimModel::tiny(variant, batch, SimCost::fast())),
            0,
            None,
            true,
            k,
            bits,
        )
    }

    #[test]
    fn speculative_decode_streams_match_plain() {
        // verification is exact, so every (k, bits) combination must
        // reproduce the plain-decode streams bit for bit
        let reqs = || vec![req(1, 4, 12), req(2, 6, 7), req(3, 9, 1), req(4, 3, 2)];
        let run = |mut w: Worker| {
            let rs = w
                .process_batch(Batch { requests: reqs(), formed_at: Instant::now() })
                .unwrap();
            let mut rs: Vec<_> = rs.into_iter().map(|r| (r.id, r.tokens)).collect();
            rs.sort();
            rs
        };
        let plain = run(sim_worker(Variant::Fp, 4));
        for k in [2usize, 4] {
            for bits in [2u32, 4] {
                let got = run(spec_worker(Variant::Fp, 4, k, bits));
                assert_eq!(got, plain, "spec k={k} bits={bits} changed a stream");
            }
        }
    }

    #[test]
    fn speculative_counters_steps_and_pool_accounting() {
        let batch = || Batch {
            requests: vec![req(1, 20, 12), req(2, 33, 9), req(3, 10, 6)],
            formed_at: Instant::now(),
        };
        let plain_steps = {
            let mut w = sim_worker(Variant::SimQuant, 4);
            let _ = w.process_batch(batch()).unwrap();
            w.steps
        };
        let mut w = spec_worker(Variant::SimQuant, 4, 4, 4);
        let total = w.kv().total_blocks();
        let rs = w.process_batch(batch()).unwrap();
        assert_eq!(rs.len(), 3);
        assert!(w.drafted_tokens > 0, "speculation proposed no drafts");
        assert!(w.accepted_tokens > 0, "verify accepted no drafts at a = 0.95");
        assert!(w.accepted_tokens <= w.drafted_tokens);
        // fewer fused full-width steps for the same streams — the win
        assert!(w.steps < plain_steps, "spec {} >= plain {}", w.steps, plain_steps);
        // rejected-suffix rollbacks leaked nothing: the pool balances
        assert_eq!(w.kv().free_block_count() + w.kv().retained_count(), total);
    }

    fn take_handoff(
        evs: Vec<ServeEvent>,
    ) -> (Request, Vec<i32>, Arc<LaneExport>, f64, f64, Option<Instant>) {
        evs.into_iter()
            .find_map(|e| match e {
                ServeEvent::Handoff {
                    req,
                    generated,
                    pages,
                    ttft_s,
                    queued_s,
                    first_token_at,
                    ..
                } => Some((req, generated, pages, ttft_s, queued_s, first_token_at)),
                _ => None,
            })
            .expect("handoff event")
    }

    #[test]
    fn prefill_handoff_then_import_is_bit_identical() {
        let baseline = {
            let mut w = sim_worker(Variant::SimQuant, 2);
            let rs = w
                .process_batch(Batch {
                    requests: vec![req(1, 12, 6)],
                    formed_at: Instant::now(),
                })
                .unwrap();
            rs[0].tokens.clone()
        };
        let mut src = sim_worker(Variant::SimQuant, 2);
        src.set_handoff(true);
        let evs = src.join(vec![req(1, 12, 6)]).unwrap();
        // the first token is emitted on the prefill shard, then the lane
        // exports and frees immediately
        let first_tok = evs
            .iter()
            .find_map(|e| match e {
                ServeEvent::Token { token, seq: 0, first: true, .. } => Some(*token),
                _ => None,
            })
            .expect("first token on the prefill shard");
        assert_eq!(src.handoffs, 1);
        assert_eq!(src.active(), 0, "lane must free at handoff");
        assert!(!src.has_work());
        let (hreq, generated, pages, ttft_s, queued_s, at) = take_handoff(evs);
        assert_eq!(generated, vec![first_tok]);
        assert_eq!(hreq.prompt.len(), 12, "original prompt travels");
        // import into a fresh decode worker and drain: the combined
        // stream must match the mixed baseline token for token
        let mut dst = sim_worker(Variant::SimQuant, 2);
        dst.import_handoff(hreq, generated.clone(), &pages, ttft_s, queued_s, at)
            .expect("import into a fresh pool");
        assert_eq!(dst.active(), 1);
        let mut stream = generated;
        let mut seqs = vec![0usize];
        while dst.active() > 0 {
            for e in dst.step().unwrap() {
                if let ServeEvent::Token { token, seq, .. } = e {
                    stream.push(token);
                    seqs.push(seq);
                }
            }
        }
        assert_eq!(stream, baseline, "handoff changed the stream");
        assert_eq!(seqs, (0..baseline.len()).collect::<Vec<_>>(), "seq numbering continues");
        // the imported lane's blocks return to the pool at retirement
        assert_eq!(
            dst.kv().free_block_count() + dst.kv().retained_count(),
            dst.kv().total_blocks()
        );
    }

    #[test]
    fn import_handoff_bounces_when_the_pool_cannot_hold_the_stream() {
        let mut src = sim_worker(Variant::SimQuant, 2);
        src.set_handoff(true);
        let evs = src.join(vec![req(1, 40, 8)]).unwrap();
        let (hreq, generated, pages, ttft_s, queued_s, at) = take_handoff(evs);
        // a 2-block destination pool cannot hold the 40-token lane
        let mut dst = paged_worker(Variant::SimQuant, 2, 0, Some(2), false);
        let back = dst
            .import_handoff(hreq, generated, &pages, ttft_s, queued_s, at)
            .expect_err("import must bounce, not panic");
        assert_eq!(back.id, 1, "request returns to the dispatcher");
        assert_eq!(dst.active(), 0);
        assert_eq!(
            dst.kv().free_block_count(),
            dst.kv().total_blocks(),
            "failed import leaked blocks"
        );
    }

    #[test]
    fn export_one_lane_picks_the_youngest_decoding_lane() {
        let mut w = sim_worker(Variant::Fp, 4);
        let _ = w.join(vec![req(1, 4, 8)]).unwrap();
        let _ = w.step().unwrap();
        let _ = w.join(vec![req(2, 4, 8)]).unwrap();
        let (hreq, generated, ..) = take_handoff(
            w.export_one_lane().map(|e| vec![e]).expect("a decoding lane exists"),
        );
        assert_eq!(hreq.id, 2, "youngest decoding lane exports");
        assert!(!generated.is_empty());
        assert_eq!(w.active(), 1, "the older lane stays");
        // nothing decoding -> nothing to export
        let mut idle = sim_worker(Variant::Fp, 2);
        assert!(idle.export_one_lane().is_none());
    }

    #[test]
    fn handoff_round_trip_keeps_speculative_streams_identical() {
        // import into a speculative decode worker: verified-exact
        // speculation over migrated pages must still match plain decode
        let baseline = {
            let mut w = sim_worker(Variant::Fp, 2);
            let rs = w
                .process_batch(Batch {
                    requests: vec![req(3, 10, 9)],
                    formed_at: Instant::now(),
                })
                .unwrap();
            rs[0].tokens.clone()
        };
        let mut src = sim_worker(Variant::Fp, 2);
        src.set_handoff(true);
        let evs = src.join(vec![req(3, 10, 9)]).unwrap();
        let (hreq, generated, pages, ttft_s, queued_s, at) = take_handoff(evs);
        let mut dst = spec_worker(Variant::Fp, 2, 4, 4);
        dst.import_handoff(hreq, generated.clone(), &pages, ttft_s, queued_s, at)
            .expect("import into the speculative worker");
        let mut stream = generated;
        while dst.active() > 0 {
            for e in dst.step().unwrap() {
                if let ServeEvent::Token { token, .. } = e {
                    stream.push(token);
                }
            }
        }
        assert_eq!(stream, baseline, "speculative decode over migrated pages diverged");
        assert!(dst.drafted_tokens > 0, "speculation ran on the imported lane");
    }

    #[test]
    fn trajectories_are_slot_independent() {
        // the same request must generate the same tokens whether it runs
        // alone or shares the batch — the scheduler-correctness anchor
        let solo = {
            let mut w = sim_worker(Variant::Fp, 4);
            let rs = w
                .process_batch(Batch {
                    requests: vec![req(7, 5, 6)],
                    formed_at: Instant::now(),
                })
                .unwrap();
            rs[0].tokens.clone()
        };
        let shared = {
            let mut w = sim_worker(Variant::Fp, 4);
            let rs = w
                .process_batch(Batch {
                    requests: vec![req(9, 3, 4), req(7, 5, 6), req(11, 2, 2)],
                    formed_at: Instant::now(),
                })
                .unwrap();
            rs.iter().find(|r| r.id == 7).unwrap().tokens.clone()
        };
        assert_eq!(solo, shared);
    }
}
