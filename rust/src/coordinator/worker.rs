//! Worker shard: executes prefill/decode batches against its ModelHandle.
//!
//! One worker models one GPU of the paper's cluster. It owns a batched KV
//! cache (fp32 or SimQuant codes depending on the variant), per-layer EMA
//! scale trackers (Alg. 1), and the Eq. 12 breakdown instrumentation.
//! Batches run to completion (static batching); the server overlaps
//! batches across workers.

use std::time::Instant;

use anyhow::{bail, Result};

use crate::corpus::PAD;
use crate::metrics::{Breakdown, Stage};
use crate::quant::Variant;
use crate::runtime::{i32_bytes, literal_from_raw, Literal, ModelHandle};
use crate::tensor::Tensor;

use super::batcher::Batch;
use super::kv_cache::{KvCache, PrefillPage};
use super::request::Response;
use super::scale_sync::ScaleSync;

pub struct Worker {
    pub shard: usize,
    handle: ModelHandle,
    pub scales: ScaleSync,
    pub breakdown: Breakdown,
    /// decode steps executed (for per-step metrics)
    pub steps: u64,
    pub tokens_out: u64,
}

impl Worker {
    pub fn new(shard: usize, handle: ModelHandle) -> Self {
        let n_regions = handle.cfg.n_layers;
        Worker {
            shard,
            handle,
            scales: ScaleSync::new(n_regions, 0.9, 1e-6, 0),
            breakdown: Breakdown::new(),
            steps: 0,
            tokens_out: 0,
        }
    }

    pub fn variant(&self) -> Variant {
        self.handle.variant
    }

    fn fresh_kv(&self) -> KvCache {
        let c = &self.handle.cfg;
        if self.handle.variant == Variant::SimQuant {
            KvCache::new_simquant(c.n_layers, self.handle.batch, c.ctx, c.d_model)
        } else {
            KvCache::new_f32(c.n_layers, self.handle.batch, c.ctx, c.d_model)
        }
    }

    /// Run one batch to completion; returns a response per request.
    pub fn process_batch(&mut self, batch: Batch) -> Result<Vec<Response>> {
        let cfg = self.handle.cfg.clone();
        let b = self.handle.batch;
        let (ctx, v, l, d) = (cfg.ctx, cfg.vocab, cfg.n_layers, cfg.d_model);
        if batch.len() > b {
            bail!("batch of {} exceeds compiled batch size {b}", batch.len());
        }
        let n_active = batch.len();
        let started = Instant::now();

        // ---- prefill ------------------------------------------------------
        let mut tokens = vec![PAD; b * ctx];
        let mut prompt_lens = vec![0usize; b];
        for (slot, req) in batch.requests.iter().enumerate() {
            let plen = req.prompt.len().min(ctx - 1);
            prompt_lens[slot] = plen;
            tokens[slot * ctx..slot * ctx + plen].copy_from_slice(&req.prompt[..plen]);
        }
        let tok_tensor = self.breakdown.span(Stage::Load, || {
            Tensor::from_i32(vec![b, ctx], tokens)
        });
        let outs = {
            let bd = &mut self.breakdown;
            let handle = &self.handle;
            bd.span(Stage::Gemm, || handle.prefill(&[tok_tensor]))?
        };
        // zero-copy views into the prefill outputs (no 4MB clones per batch)
        let logits = outs[0].f32_view()?; // [B, CTX, V]
        let k_cache = outs[1].f32_view()?; // [L, B, CTX, D]
        let v_cache = outs[2].f32_view()?;

        let mut kv = self.fresh_kv();
        self.breakdown.span(Stage::Quant, || {
            // the (slot, layer) pages are disjoint: fan the encodes out
            // across the worker pool instead of ingesting serially
            let mut pages = Vec::with_capacity(n_active * l);
            for slot in 0..n_active {
                let plen = prompt_lens[slot];
                for layer in 0..l {
                    let off = (layer * b + slot) * ctx * d;
                    pages.push(PrefillPage {
                        slot,
                        layer,
                        k_rows: &k_cache[off..off + plen * d],
                        v_rows: &v_cache[off..off + plen * d],
                        t_len: plen,
                    });
                }
            }
            kv.ingest_prefill_batch(&pages);
        });

        // first generated token per active slot + ttft
        let mut generated: Vec<Vec<i32>> = vec![Vec::new(); b];
        let mut done = vec![false; b];
        let mut ttft = vec![0f64; b];
        for slot in 0..n_active {
            let plen = prompt_lens[slot];
            let row = &logits[(slot * ctx + plen - 1) * v..(slot * ctx + plen) * v];
            generated[slot].push(argmax(row));
            ttft[slot] = batch.requests[slot].arrival.elapsed().as_secs_f64();
            self.tokens_out += 1;
            if batch.requests[slot].max_new_tokens <= 1 {
                done[slot] = true;
            }
        }
        for slot in n_active..b {
            done[slot] = true;
        }

        // ---- decode loop ---------------------------------------------------
        while !done.iter().all(|d| *d) {
            let mut token = vec![PAD; b];
            let mut pos = vec![0i32; b];
            for slot in 0..n_active {
                if !done[slot] {
                    token[slot] = *generated[slot].last().unwrap();
                    pos[slot] = kv.len(slot) as i32;
                }
            }
            // build literals straight from the KV buffers (input order:
            // token, pos, k_cache, v_cache, [params]) — no staging copies
            let runtime_lits = self.breakdown.span(Stage::Load, || -> Result<Vec<Literal>> {
                let mut lits = vec![
                    literal_from_raw(crate::tensor::DType::I32, &[b], i32_bytes(&token))?,
                    literal_from_raw(crate::tensor::DType::I32, &[b], i32_bytes(&pos))?,
                ];
                lits.extend(kv.input_literals()?);
                Ok(lits)
            })?;
            let outs = {
                let bd = &mut self.breakdown;
                let handle = &self.handle;
                bd.span(Stage::Gemm, || handle.decode_literals(&runtime_lits))?
            };
            self.steps += 1;
            // zero-copy views into the decode-step outputs
            let step_logits = outs[0].f32_view()?; // [B, V]
            let k_new = outs[1].f32_view()?; // [L, B, D]
            let v_new = outs[2].f32_view()?;

            self.breakdown.span(Stage::Quant, || {
                for slot in 0..n_active {
                    if done[slot] {
                        continue;
                    }
                    for layer in 0..l {
                        let off = (layer * b + slot) * d;
                        kv.append_row(slot, layer, &k_new[off..off + d], &v_new[off..off + d]);
                        // Alg. 1: track activation ranges per layer region
                        self.scales.observe(layer, &k_new[off..off + d]);
                    }
                    kv.bump(slot);
                }
            });

            for slot in 0..n_active {
                if done[slot] {
                    continue;
                }
                let row = &step_logits[slot * v..(slot + 1) * v];
                generated[slot].push(argmax(row));
                self.tokens_out += 1;
                let req = &batch.requests[slot];
                if generated[slot].len() >= req.max_new_tokens
                    || kv.len(slot) + 1 >= cfg.ctx
                {
                    done[slot] = true;
                }
            }
        }

        let _ = started;
        Ok((0..n_active)
            .map(|slot| {
                let req = &batch.requests[slot];
                Response {
                    id: req.id,
                    tokens: generated[slot].clone(),
                    prompt_len: prompt_lens[slot],
                    latency_s: req.arrival.elapsed().as_secs_f64(),
                    ttft_s: ttft[slot],
                    shard: self.shard,
                }
            })
            .collect())
    }
}

fn argmax(row: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, v) in row.iter().enumerate() {
        if *v > row[best] {
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::argmax;

    #[test]
    fn argmax_picks_first_max() {
        assert_eq!(argmax(&[0.1, 0.9, 0.9, 0.2]), 1);
        assert_eq!(argmax(&[-5.0, -1.0]), 1);
    }
}
