//! Prefix cache: maps token-prefix chains to retained KV blocks so a
//! shared-prefix arrival (system prompt, replayed chat history) skips
//! prefill straight to its first uncached block.
//!
//! Entries form hash chains at block granularity: block `i` of a prompt
//! is keyed by an FNV-1a hash folded over the parent block's hash and
//! the block's own tokens, so a chain lookup is one hash + map probe
//! per block and two prompts share exactly their common full-block
//! prefix. Each entry pins one physical block in the [`KvCache`]
//! ([`KvCache::retain_block`]): at lane refcount 0 the block stays
//! allocated, holding the encoded rows for the next hit. Entries store
//! their exact tokens, so a hash collision degrades to a miss instead
//! of serving another prompt's KV rows.
//!
//! Eviction is LRU over refcount-0 *leaf* entries (`children == 0` and
//! no lane mapping the block), ties broken by block index — child
//! chains always evict before their parents, so a surviving entry's
//! ancestors are always present and lookups never dangle. Only full
//! prompt blocks are ever registered; the lookup additionally caps the
//! cached length at `prompt_len - 1` so at least one token always
//! prefills (the first output token's logits come from the last prompt
//! position).

use std::collections::HashMap;

use super::kv_cache::KvCache;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv1a_fold(mut h: u64, bytes: &[u8]) -> u64 {
    for b in bytes {
        h ^= *b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Hash of one chain link: parent hash folded with the block's tokens.
fn chain_hash(parent: Option<u64>, tokens: &[i32]) -> u64 {
    let mut h = fnv1a_fold(FNV_OFFSET, &parent.unwrap_or(0).to_le_bytes());
    for t in tokens {
        h = fnv1a_fold(h, &t.to_le_bytes());
    }
    h
}

struct Entry {
    /// physical block in the KvCache pool holding these tokens' rows
    block: usize,
    /// chain parent (hash of the previous block), None for block 0
    parent: Option<u64>,
    /// live child entries (an entry with children never evicts)
    children: u32,
    /// exact tokens — collision guard
    tokens: Vec<i32>,
    /// logical LRU clock at last hit/registration
    last_use: u64,
}

/// Per-worker prefix cache over the shard's KV block pool.
pub struct PrefixCacheManager {
    block_size: usize,
    by_hash: HashMap<u64, Entry>,
    clock: u64,
}

impl PrefixCacheManager {
    pub fn new(block_size: usize) -> Self {
        PrefixCacheManager { block_size, by_hash: HashMap::new(), clock: 0 }
    }

    /// Cached entries (tests + observability).
    pub fn len(&self) -> usize {
        self.by_hash.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_hash.is_empty()
    }

    /// Longest cached full-block prefix of `prompt`, capped so at least
    /// one prompt token is left to prefill. Attaches the matched blocks
    /// to `slot` (which must be freshly acquired) and returns the
    /// cached token count (0 on a cold miss).
    pub fn attach(&mut self, prompt: &[i32], slot: usize, kv: &mut KvCache) -> usize {
        let bs = self.block_size;
        if prompt.len() < 2 {
            return 0;
        }
        let max_blocks = (prompt.len() - 1) / bs;
        let mut blocks = Vec::new();
        let mut parent = None;
        self.clock += 1;
        for i in 0..max_blocks {
            let tokens = &prompt[i * bs..(i + 1) * bs];
            let h = chain_hash(parent, tokens);
            match self.by_hash.get_mut(&h) {
                Some(e) if e.tokens == tokens => {
                    e.last_use = self.clock;
                    blocks.push(e.block);
                    parent = Some(h);
                }
                _ => break,
            }
        }
        if blocks.is_empty() {
            return 0;
        }
        let cached_len = blocks.len() * bs;
        kv.attach_cached_blocks(slot, &blocks, cached_len);
        cached_len
    }

    /// Register `slot`'s full prompt blocks after its prefill completed:
    /// each becomes (or refreshes) a chain entry whose physical block
    /// the KvCache retains past the lane's release. A block already
    /// chained (this lane hit it, or another lane registered the same
    /// prefix first) just refreshes its LRU stamp.
    pub fn register(&mut self, prompt: &[i32], slot: usize, kv: &mut KvCache) {
        let bs = self.block_size;
        let n = (prompt.len() / bs).min(kv.table(slot).len());
        let mut parent = None;
        self.clock += 1;
        for i in 0..n {
            let tokens = &prompt[i * bs..(i + 1) * bs];
            let h = chain_hash(parent, tokens);
            match self.by_hash.get_mut(&h) {
                Some(e) => {
                    debug_assert!(e.tokens == tokens, "prefix chain hash collision");
                    e.last_use = self.clock;
                }
                None => {
                    let block = kv.table(slot)[i];
                    kv.retain_block(block);
                    if let Some(p) = parent {
                        if let Some(pe) = self.by_hash.get_mut(&p) {
                            pe.children += 1;
                        }
                    }
                    self.by_hash.insert(
                        h,
                        Entry {
                            block,
                            parent,
                            children: 0,
                            tokens: tokens.to_vec(),
                            last_use: self.clock,
                        },
                    );
                }
            }
            parent = Some(h);
        }
    }

    /// Evict the least-recently-used idle leaf (no children, no lane
    /// mapping its block), returning its block to the free pool. Ties
    /// break on block index, so eviction is deterministic. Returns
    /// `false` when every entry is pinned (live lanes or interior
    /// chain links).
    pub fn evict_one(&mut self, kv: &mut KvCache) -> bool {
        let victim = self
            .by_hash
            .iter()
            .filter(|(_, e)| e.children == 0 && kv.ref_count(e.block) == 0)
            .min_by_key(|(_, e)| (e.last_use, e.block))
            .map(|(h, _)| *h);
        let Some(h) = victim else {
            return false;
        };
        let e = self.by_hash.remove(&h).expect("victim vanished");
        if let Some(p) = e.parent {
            if let Some(pe) = self.by_hash.get_mut(&p) {
                pe.children -= 1;
            }
        }
        kv.free_retained_block(e.block);
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::generate_tokens;

    fn cache(batch: usize, n_blocks: usize) -> KvCache {
        KvCache::new_f32_paged(1, batch, 16, 2, 4, n_blocks)
    }

    fn kv_rows(t: usize, seed: u64) -> Vec<f32> {
        use crate::corpus::XorShift64Star;
        let mut r = XorShift64Star::new(seed);
        (0..t * 2).map(|_| r.next_normal() as f32).collect()
    }

    /// Prefill a lane with `prompt.len()` rows and register its blocks.
    fn admit_and_register(
        pc: &mut PrefixCacheManager,
        kv: &mut KvCache,
        prompt: &[i32],
        seed: u64,
    ) -> usize {
        let slot = kv.acquire_slot().expect("lane");
        let cached = pc.attach(prompt, slot, kv);
        let t = prompt.len();
        let rows = kv_rows(t - cached, seed);
        kv.ingest_prefill_at(slot, 0, cached, &rows, &rows, t - cached);
        pc.register(prompt, slot, kv);
        slot
    }

    #[test]
    fn cold_miss_then_hit_skips_full_blocks() {
        let mut kv = cache(2, 8);
        let mut pc = PrefixCacheManager::new(4);
        let prompt = generate_tokens(10, 7); // 2 full blocks + tail
        let s = admit_and_register(&mut pc, &mut kv, &prompt, 1);
        assert_eq!(pc.len(), 2);
        kv.release_slot(s);
        assert_eq!(kv.retained_count(), 2);
        // same prompt again: both full blocks hit
        let s2 = kv.acquire_slot().unwrap();
        let cached = pc.attach(&prompt, s2, &mut kv);
        assert_eq!(cached, 8);
        assert_eq!(kv.len(s2), 8);
    }

    #[test]
    fn hit_caps_below_full_prompt() {
        // an exact-multiple prompt still leaves one token to prefill
        let mut kv = cache(2, 8);
        let mut pc = PrefixCacheManager::new(4);
        let prompt = generate_tokens(8, 9);
        let s = admit_and_register(&mut pc, &mut kv, &prompt, 2);
        kv.release_slot(s);
        let s2 = kv.acquire_slot().unwrap();
        let cached = pc.attach(&prompt, s2, &mut kv);
        assert_eq!(cached, 4, "cap at prompt_len - 1 leaves the last block cold");
    }

    #[test]
    fn divergent_prompt_shares_only_common_prefix() {
        let mut kv = cache(2, 8);
        let mut pc = PrefixCacheManager::new(4);
        let a = generate_tokens(10, 11);
        let mut b = a.clone();
        b[6] = b[6].wrapping_add(1); // diverge inside block 1
        let s = admit_and_register(&mut pc, &mut kv, &a, 3);
        kv.release_slot(s);
        let s2 = kv.acquire_slot().unwrap();
        let cached = pc.attach(&b, s2, &mut kv);
        assert_eq!(cached, 4, "only block 0 is shared");
    }

    #[test]
    fn attached_rows_match_the_registered_lanes_rows() {
        let mut kv = cache(2, 8);
        let mut pc = PrefixCacheManager::new(4);
        let prompt = generate_tokens(10, 13);
        let s = admit_and_register(&mut pc, &mut kv, &prompt, 4);
        let original = kv.decode_k(s, 0);
        kv.release_slot(s);
        let s2 = kv.acquire_slot().unwrap();
        let cached = pc.attach(&prompt, s2, &mut kv);
        assert_eq!(&kv.decode_k(s2, 0), &original[..cached * 2]);
    }

    #[test]
    fn evicts_lru_leaf_child_before_parent() {
        let mut kv = cache(2, 8);
        let mut pc = PrefixCacheManager::new(4);
        let prompt = generate_tokens(10, 17); // chain of 2 entries
        let s = admit_and_register(&mut pc, &mut kv, &prompt, 5);
        kv.release_slot(s);
        assert_eq!(pc.len(), 2);
        // first eviction must take the leaf (block 1 of the chain)
        assert!(pc.evict_one(&mut kv));
        assert_eq!(pc.len(), 1);
        assert_eq!(kv.retained_count(), 1);
        let s2 = kv.acquire_slot().unwrap();
        assert_eq!(pc.attach(&prompt, s2, &mut kv), 4, "parent still serves hits");
        kv.release_slot(s2);
        assert!(pc.evict_one(&mut kv));
        assert!(pc.is_empty());
        assert_eq!(kv.retained_count(), 0);
        assert_eq!(kv.free_block_count(), 8, "all blocks back in the pool");
        assert!(!pc.evict_one(&mut kv), "nothing left to evict");
    }

    #[test]
    fn live_blocks_never_evict() {
        let mut kv = cache(2, 8);
        let mut pc = PrefixCacheManager::new(4);
        let prompt = generate_tokens(6, 19); // 1 full block
        let _s = admit_and_register(&mut pc, &mut kv, &prompt, 6);
        // the registering lane still maps the block (refcount 1)
        assert!(!pc.evict_one(&mut kv));
        assert_eq!(pc.len(), 1);
    }

    #[test]
    fn lru_order_prefers_older_chains() {
        let mut kv = cache(3, 12);
        let mut pc = PrefixCacheManager::new(4);
        let a = generate_tokens(6, 23);
        let b = generate_tokens(6, 29);
        let sa = admit_and_register(&mut pc, &mut kv, &a, 7);
        kv.release_slot(sa);
        let sb = admit_and_register(&mut pc, &mut kv, &b, 8);
        kv.release_slot(sb);
        // touch a: b becomes the LRU victim
        let s = kv.acquire_slot().unwrap();
        assert_eq!(pc.attach(&a, s, &mut kv), 4);
        kv.release_slot(s);
        assert!(pc.evict_one(&mut kv));
        let s2 = kv.acquire_slot().unwrap();
        assert_eq!(pc.attach(&a, s2, &mut kv), 4, "a survives");
        kv.release_slot(s2);
        let s3 = kv.acquire_slot().unwrap();
        assert_eq!(pc.attach(&b, s3, &mut kv), 0, "b was evicted");
    }
}
