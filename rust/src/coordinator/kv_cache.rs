//! KV-cache manager: batch-slot cache buffers, fp32 or SimQuant-compressed.
//!
//! Layout matches the decode graphs' inputs: `[L, B, CTX, D]` caches plus,
//! for SimQuant, per-(layer, slot) channel params `[L, B, 1, D]`.
//!
//! SimQuant mode implements the paper's online KV quantization (§3.4):
//! each (layer, slot) page carries per-channel (vmin, step); appending a
//! row that falls outside the page's range triggers an in-place page
//! re-encode (dequantize codes, widen range, requantize) — the runtime
//! adaptation that keeps Thm. A.2's bound tight as the sequence grows.
//!
//! Hot-path contract: prefill ingestion encodes through
//! `quant::kernels::simquant_encode_into` straight into the cache's own
//! code/param pages (no staging vectors), page re-encodes run on reused
//! scratch buffers, and `input_literals` builds PJRT literals directly
//! from the cache buffers — one copy per decode step, total.

use anyhow::Result;

use crate::quant::kernels::{
    simquant_decode_into, simquant_encode_into, simquant_encode_with_params_into,
};
use crate::runtime::{f32_bytes, literal_from_raw, Literal};
use crate::tensor::{DType, Tensor};

/// Whether the cache stores f32 rows or SimQuant u8 codes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Mode {
    F32,
    SimQuant,
}

/// Batched KV cache for one worker shard.
pub struct KvCache {
    n_layers: usize,
    batch: usize,
    ctx: usize,
    d: usize,
    mode: Mode,
    /// f32 mode: [L, B, CTX, D] values; simquant mode: codes as f32-free u8
    k_f32: Vec<f32>,
    v_f32: Vec<f32>,
    k_q: Vec<u8>,
    v_q: Vec<u8>,
    /// per (layer, slot, channel) params, [L, B, D]
    k_min: Vec<f32>,
    k_step: Vec<f32>,
    v_min: Vec<f32>,
    v_step: Vec<f32>,
    /// per-slot filled length
    lens: Vec<usize>,
    /// reused page-reencode scratch (decoded page, widened lo/hi)
    scratch: Vec<f32>,
    lo_scratch: Vec<f32>,
    hi_scratch: Vec<f32>,
    /// page re-encode counter (observability)
    pub reencodes: u64,
}

impl KvCache {
    pub fn new_f32(n_layers: usize, batch: usize, ctx: usize, d: usize) -> Self {
        KvCache {
            n_layers,
            batch,
            ctx,
            d,
            mode: Mode::F32,
            k_f32: vec![0.0; n_layers * batch * ctx * d],
            v_f32: vec![0.0; n_layers * batch * ctx * d],
            k_q: Vec::new(),
            v_q: Vec::new(),
            k_min: Vec::new(),
            k_step: Vec::new(),
            v_min: Vec::new(),
            v_step: Vec::new(),
            lens: vec![0; batch],
            scratch: Vec::new(),
            lo_scratch: Vec::new(),
            hi_scratch: Vec::new(),
            reencodes: 0,
        }
    }

    pub fn new_simquant(n_layers: usize, batch: usize, ctx: usize, d: usize) -> Self {
        KvCache {
            n_layers,
            batch,
            ctx,
            d,
            mode: Mode::SimQuant,
            k_f32: Vec::new(),
            v_f32: Vec::new(),
            k_q: vec![0; n_layers * batch * ctx * d],
            v_q: vec![0; n_layers * batch * ctx * d],
            k_min: vec![0.0; n_layers * batch * d],
            k_step: vec![1e-8; n_layers * batch * d],
            v_min: vec![0.0; n_layers * batch * d],
            v_step: vec![1e-8; n_layers * batch * d],
            lens: vec![0; batch],
            scratch: Vec::new(),
            lo_scratch: Vec::new(),
            hi_scratch: Vec::new(),
            reencodes: 0,
        }
    }

    pub fn is_quantized(&self) -> bool {
        self.mode == Mode::SimQuant
    }

    pub fn len(&self, slot: usize) -> usize {
        self.lens[slot]
    }

    pub fn is_empty(&self) -> bool {
        self.lens.iter().all(|l| *l == 0)
    }

    /// Clear one slot for reuse by a new request.
    pub fn reset_slot(&mut self, slot: usize) {
        self.lens[slot] = 0;
        if self.mode == Mode::SimQuant {
            for layer in 0..self.n_layers {
                let p = (layer * self.batch + slot) * self.d;
                self.k_min[p..p + self.d].fill(0.0);
                self.k_step[p..p + self.d].fill(1e-8);
                self.v_min[p..p + self.d].fill(0.0);
                self.v_step[p..p + self.d].fill(1e-8);
            }
        }
    }

    /// Bytes the cache occupies (memory accounting for the tables).
    pub fn storage_bytes(&self) -> usize {
        match self.mode {
            Mode::F32 => (self.k_f32.len() + self.v_f32.len()) * 4,
            Mode::SimQuant => {
                self.k_q.len()
                    + self.v_q.len()
                    + (self.k_min.len() + self.k_step.len() + self.v_min.len()
                        + self.v_step.len())
                        * 4
            }
        }
    }

    #[inline]
    fn row_off(&self, layer: usize, slot: usize, t: usize) -> usize {
        ((layer * self.batch + slot) * self.ctx + t) * self.d
    }

    #[inline]
    fn param_off(&self, layer: usize, slot: usize) -> usize {
        (layer * self.batch + slot) * self.d
    }

    /// Ingest prefill caches for one slot: rows [T, D] per layer, stored
    /// (and for SimQuant: page-encoded, straight into the cache pages)
    /// at positions 0..t_len.
    pub fn ingest_prefill(
        &mut self,
        slot: usize,
        layer: usize,
        k_rows: &[f32],
        v_rows: &[f32],
        t_len: usize,
    ) {
        assert!(t_len <= self.ctx);
        assert_eq!(k_rows.len(), t_len * self.d);
        assert_eq!(v_rows.len(), t_len * self.d);
        let d = self.d;
        match self.mode {
            Mode::F32 => {
                let off = self.row_off(layer, slot, 0);
                self.k_f32[off..off + t_len * d].copy_from_slice(k_rows);
                self.v_f32[off..off + t_len * d].copy_from_slice(v_rows);
            }
            Mode::SimQuant => {
                let off = self.row_off(layer, slot, 0);
                let p = self.param_off(layer, slot);
                simquant_encode_into(
                    k_rows,
                    t_len,
                    d,
                    8,
                    &mut self.k_q[off..off + t_len * d],
                    &mut self.k_min[p..p + d],
                    &mut self.k_step[p..p + d],
                )
                .expect("simquant encode (bits=8, sized buffers) cannot fail");
                simquant_encode_into(
                    v_rows,
                    t_len,
                    d,
                    8,
                    &mut self.v_q[off..off + t_len * d],
                    &mut self.v_min[p..p + d],
                    &mut self.v_step[p..p + d],
                )
                .expect("simquant encode (bits=8, sized buffers) cannot fail");
            }
        }
        self.lens[slot] = self.lens[slot].max(t_len);
    }

    /// Append one decode-step row per cache; grows the slot by one.
    pub fn append_row(&mut self, slot: usize, layer: usize, k_row: &[f32], v_row: &[f32]) {
        let t = self.lens[slot];
        assert!(t < self.ctx, "slot {slot} KV overflow");
        match self.mode {
            Mode::F32 => {
                let off = self.row_off(layer, slot, t);
                self.k_f32[off..off + self.d].copy_from_slice(k_row);
                self.v_f32[off..off + self.d].copy_from_slice(v_row);
            }
            Mode::SimQuant => {
                self.append_quantized(slot, layer, t, k_row, true);
                self.append_quantized(slot, layer, t, v_row, false);
            }
        }
        // the caller bumps the length once after appending all layers
    }

    /// Mark the slot one token longer (after all layers appended).
    pub fn bump(&mut self, slot: usize) {
        self.lens[slot] += 1;
    }

    fn append_quantized(
        &mut self,
        slot: usize,
        layer: usize,
        t: usize,
        row: &[f32],
        is_k: bool,
    ) {
        let p = self.param_off(layer, slot);
        let d = self.d;
        // the zipped loops below would silently truncate a short row
        assert_eq!(row.len(), d, "KV row length != d");
        // check range; widen + re-encode the page if violated
        let mut needs_reencode = false;
        {
            let (vmin, vstep) = if is_k {
                (&self.k_min[p..p + d], &self.k_step[p..p + d])
            } else {
                (&self.v_min[p..p + d], &self.v_step[p..p + d])
            };
            for ((mn, st), v) in vmin.iter().zip(vstep).zip(row) {
                let hi = mn + st * 255.0;
                if *v < mn - 1e-9 || *v > hi + 1e-9 {
                    needs_reencode = true;
                    break;
                }
            }
        }
        if needs_reencode && t > 0 {
            self.reencode_page(slot, layer, t, row, is_k);
            self.reencodes += 1;
        } else if needs_reencode {
            // empty page: seed params from the row itself
            let (vmin, vstep) = if is_k {
                (&mut self.k_min[p..p + d], &mut self.k_step[p..p + d])
            } else {
                (&mut self.v_min[p..p + d], &mut self.v_step[p..p + d])
            };
            for ((mn, st), v) in vmin.iter_mut().zip(vstep.iter_mut()).zip(row) {
                let lo = v.min(0.0);
                let hi = v.max(0.0);
                *mn = lo;
                *st = (hi - lo).max(1e-8) / 255.0;
            }
        }
        // encode the row with current params (cache pages are 8-bit)
        let off = self.row_off(layer, slot, t);
        let (vmin, vstep, codes) = if is_k {
            (&self.k_min[p..p + d], &self.k_step[p..p + d], &mut self.k_q[off..off + d])
        } else {
            (&self.v_min[p..p + d], &self.v_step[p..p + d], &mut self.v_q[off..off + d])
        };
        simquant_encode_with_params_into(row, vmin, vstep, 255.0, codes);
    }

    /// Widen the page range to cover `row` and requantize existing codes.
    /// Runs entirely on the cache's reused scratch buffers.
    fn reencode_page(&mut self, slot: usize, layer: usize, t: usize, row: &[f32], is_k: bool) {
        let p = self.param_off(layer, slot);
        let d = self.d;
        let base = self.row_off(layer, slot, 0);
        // decode current page into the reused scratch
        let mut page = std::mem::take(&mut self.scratch);
        page.clear();
        page.resize(t * d, 0.0);
        {
            let (codes, vmin, vstep) = if is_k {
                (&self.k_q[base..base + t * d], &self.k_min[p..p + d], &self.k_step[p..p + d])
            } else {
                (&self.v_q[base..base + t * d], &self.v_min[p..p + d], &self.v_step[p..p + d])
            };
            simquant_decode_into(codes, vmin, vstep, t, d, &mut page);
        }
        // widened per-channel range over page + new row
        let mut lo = std::mem::take(&mut self.lo_scratch);
        let mut hi = std::mem::take(&mut self.hi_scratch);
        lo.clear();
        lo.resize(d, f32::INFINITY);
        hi.clear();
        hi.resize(d, f32::NEG_INFINITY);
        for prow in page.chunks_exact(d) {
            for ((l, h), v) in lo.iter_mut().zip(hi.iter_mut()).zip(prow) {
                *l = l.min(*v);
                *h = h.max(*v);
            }
        }
        for ((l, h), v) in lo.iter_mut().zip(hi.iter_mut()).zip(row) {
            *l = l.min(*v);
            *h = h.max(*v);
        }
        // write params + re-encoded codes
        {
            let (vmin, vstep) = if is_k {
                (&mut self.k_min[p..p + d], &mut self.k_step[p..p + d])
            } else {
                (&mut self.v_min[p..p + d], &mut self.v_step[p..p + d])
            };
            for ((mn, st), (l, h)) in
                vmin.iter_mut().zip(vstep.iter_mut()).zip(lo.iter().zip(&hi))
            {
                *mn = *l;
                *st = (h - l).max(1e-8) / 255.0;
            }
        }
        let (codes, vmin, vstep) = if is_k {
            (&mut self.k_q[base..base + t * d], &self.k_min[p..p + d], &self.k_step[p..p + d])
        } else {
            (&mut self.v_q[base..base + t * d], &self.v_min[p..p + d], &self.v_step[p..p + d])
        };
        simquant_encode_with_params_into(&page, vmin, vstep, 255.0, codes);
        self.scratch = page;
        self.lo_scratch = lo;
        self.hi_scratch = hi;
    }

    /// Dequantize one slot's K page into a reused buffer (cleared and
    /// refilled) — the scratch-friendly variant of [`KvCache::decode_k`].
    pub fn decode_k_into(&self, slot: usize, layer: usize, out: &mut Vec<f32>) {
        let t = self.lens[slot];
        let d = self.d;
        out.clear();
        out.resize(t * d, 0.0);
        match self.mode {
            Mode::F32 => {
                let off = self.row_off(layer, slot, 0);
                out.copy_from_slice(&self.k_f32[off..off + t * d]);
            }
            Mode::SimQuant => {
                let off = self.row_off(layer, slot, 0);
                let p = self.param_off(layer, slot);
                simquant_decode_into(
                    &self.k_q[off..off + t * d],
                    &self.k_min[p..p + d],
                    &self.k_step[p..p + d],
                    t,
                    d,
                    out,
                );
            }
        }
    }

    /// Dequantize one slot's K page (tests + debugging).
    pub fn decode_k(&self, slot: usize, layer: usize) -> Vec<f32> {
        let mut out = Vec::new();
        self.decode_k_into(slot, layer, &mut out);
        out
    }

    /// Build the decode-graph cache input tensors.
    /// f32 mode: [k_cache, v_cache]; simquant: [k_cache, v_cache, k_min,
    /// k_step, v_min, v_step] in graph input order.
    pub fn graph_inputs(&self) -> Vec<Tensor> {
        let (l, b, c, d) = (self.n_layers, self.batch, self.ctx, self.d);
        match self.mode {
            Mode::F32 => vec![
                Tensor::from_f32_slice(vec![l, b, c, d], &self.k_f32),
                Tensor::from_f32_slice(vec![l, b, c, d], &self.v_f32),
            ],
            Mode::SimQuant => {
                let expand =
                    |params: &[f32]| Tensor::from_f32_slice(vec![l, b, 1, d], params);
                vec![
                    Tensor::from_u8_slice(vec![l, b, c, d], &self.k_q),
                    Tensor::from_u8_slice(vec![l, b, c, d], &self.v_q),
                    expand(&self.k_min),
                    expand(&self.k_step),
                    expand(&self.v_min),
                    expand(&self.v_step),
                ]
            }
        }
    }

    pub fn dtype(&self) -> DType {
        match self.mode {
            Mode::F32 => DType::F32,
            Mode::SimQuant => DType::U8,
        }
    }

    /// Build the decode-graph cache inputs as PJRT literals directly from
    /// the cache's own buffers — one copy (into the literal) instead of
    /// the two `graph_inputs()` pays (staging Tensor + literal). This is
    /// the decode hot path (EXPERIMENTS.md §Perf).
    pub fn input_literals(&self) -> Result<Vec<Literal>> {
        let (l, b, c, d) = (self.n_layers, self.batch, self.ctx, self.d);
        let cache_shape = [l, b, c, d];
        let param_shape = [l, b, 1, d];
        Ok(match self.mode {
            Mode::F32 => vec![
                literal_from_raw(DType::F32, &cache_shape, f32_bytes(&self.k_f32))?,
                literal_from_raw(DType::F32, &cache_shape, f32_bytes(&self.v_f32))?,
            ],
            Mode::SimQuant => vec![
                literal_from_raw(DType::U8, &cache_shape, &self.k_q)?,
                literal_from_raw(DType::U8, &cache_shape, &self.v_q)?,
                literal_from_raw(DType::F32, &param_shape, f32_bytes(&self.k_min))?,
                literal_from_raw(DType::F32, &param_shape, f32_bytes(&self.k_step))?,
                literal_from_raw(DType::F32, &param_shape, f32_bytes(&self.v_min))?,
                literal_from_raw(DType::F32, &param_shape, f32_bytes(&self.v_step))?,
            ],
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::XorShift64Star;

    fn rows(t: usize, d: usize, seed: u64, scale: f32) -> Vec<f32> {
        let mut r = XorShift64Star::new(seed);
        (0..t * d).map(|_| r.next_normal() as f32 * scale).collect()
    }

    #[test]
    fn f32_roundtrip() {
        let mut kv = KvCache::new_f32(2, 1, 8, 4);
        let k = rows(3, 4, 1, 1.0);
        let v = rows(3, 4, 2, 1.0);
        for layer in 0..2 {
            kv.ingest_prefill(0, layer, &k, &v, 3);
        }
        assert_eq!(kv.len(0), 3);
        assert_eq!(kv.decode_k(0, 1), k);
    }

    #[test]
    fn simquant_roundtrip_bounded() {
        let mut kv = KvCache::new_simquant(1, 1, 16, 8);
        let k = rows(5, 8, 3, 2.0);
        let v = rows(5, 8, 4, 2.0);
        kv.ingest_prefill(0, 0, &k, &v, 5);
        let dk = kv.decode_k(0, 0);
        for (a, b) in k.iter().zip(&dk) {
            assert!((a - b).abs() < 0.05, "{a} vs {b}");
        }
    }

    #[test]
    fn prefill_encode_matches_reference_kernel() {
        // the in-place page encode must be bit-identical to the pinned
        // scalar reference (same codes, same params)
        let (t, d) = (6, 8);
        let k = rows(t, d, 9, 1.5);
        let mut kv = KvCache::new_simquant(1, 1, 16, d);
        kv.ingest_prefill(0, 0, &k, &k, t);
        let (rq, rmin, rstep) = crate::quant::reference::simquant_encode(&k, t, d, 8);
        let ins = kv.graph_inputs();
        assert_eq!(&ins[0].u8_view().unwrap()[..t * d], &rq[..]);
        assert_eq!(&ins[2].f32_view().unwrap()[..d], &rmin[..]);
        assert_eq!(&ins[3].f32_view().unwrap()[..d], &rstep[..]);
    }

    #[test]
    fn append_within_range_no_reencode() {
        let mut kv = KvCache::new_simquant(1, 1, 16, 4);
        // wide prefill range so appended rows stay inside
        let k = vec![-10.0, -10.0, -10.0, -10.0, 10.0, 10.0, 10.0, 10.0];
        kv.ingest_prefill(0, 0, &k, &k, 2);
        kv.append_row(0, 0, &[1.0, 2.0, -3.0, 0.5], &[0.0, 0.0, 0.0, 0.0]);
        kv.bump(0);
        assert_eq!(kv.reencodes, 0);
        assert_eq!(kv.len(0), 3);
    }

    #[test]
    fn out_of_range_append_triggers_reencode_and_stays_accurate() {
        let mut kv = KvCache::new_simquant(1, 1, 16, 4);
        let k = vec![0.1, 0.1, 0.1, 0.1, 0.2, 0.2, 0.2, 0.2];
        kv.ingest_prefill(0, 0, &k, &k, 2);
        let big = [5.0, -4.0, 3.0, 7.0];
        kv.append_row(0, 0, &big, &big);
        kv.bump(0);
        assert!(kv.reencodes > 0);
        let dk = kv.decode_k(0, 0);
        // old rows still reconstruct within the widened step bound
        for (a, b) in k.iter().zip(&dk[..8]) {
            assert!((a - b).abs() < 0.05, "{a} vs {b}");
        }
        for (a, b) in big.iter().zip(&dk[8..]) {
            assert!((a - b).abs() < 0.05, "{a} vs {b}");
        }
    }

    #[test]
    fn storage_is_quarter_of_f32() {
        let f = KvCache::new_f32(2, 4, 64, 32);
        let q = KvCache::new_simquant(2, 4, 64, 32);
        let ratio = q.storage_bytes() as f64 / f.storage_bytes() as f64;
        assert!(ratio < 0.3, "ratio {ratio}");
    }

    #[test]
    fn reset_slot_clears() {
        let mut kv = KvCache::new_simquant(1, 2, 8, 4);
        let k = rows(4, 4, 5, 1.0);
        kv.ingest_prefill(1, 0, &k, &k, 4);
        kv.reset_slot(1);
        assert_eq!(kv.len(1), 0);
    }

    #[test]
    fn graph_inputs_shapes() {
        let kv = KvCache::new_simquant(2, 3, 8, 4);
        let ins = kv.graph_inputs();
        assert_eq!(ins.len(), 6);
        assert_eq!(ins[0].shape, vec![2, 3, 8, 4]);
        assert_eq!(ins[2].shape, vec![2, 3, 1, 4]);
        let f = KvCache::new_f32(2, 3, 8, 4);
        assert_eq!(f.graph_inputs().len(), 2);
    }

    #[test]
    #[should_panic(expected = "KV overflow")]
    fn overflow_panics() {
        let mut kv = KvCache::new_f32(1, 1, 2, 2);
        kv.ingest_prefill(0, 0, &[0.0; 4], &[0.0; 4], 2);
        kv.append_row(0, 0, &[1.0, 1.0], &[1.0, 1.0]);
    }
}
