//! Paged KV-cache manager: fixed-size token blocks in a shard-wide pool,
//! per-request block tables, fp32 or SimQuant-compressed storage.
//!
//! **Paged layout** (the vLLM-style design): the cache owns a pool of
//! `n_blocks` physical blocks of `block_size` token rows each. A lane
//! (batch slot) maps logical token positions to physical blocks through
//! its block table: position `t` lives at row `t % block_size` of block
//! `table[t / block_size]`. One physical block spans *all* layers — the
//! storage region for (layer `l`, block `b`) starts at
//! `((l * n_blocks + b) * block_size) * d`. Blocks and lanes are handed
//! out lowest-first from ordered free pools (`BTreeSet`, O(log n)
//! insert/pop — no sort-per-release), keeping assignment deterministic.
//!
//! **Sharing and copy-on-write**: every block carries a refcount. A
//! forked lane ([`KvCache::fork_slot`]) or a prefix-cache attach
//! ([`KvCache::attach_cached_blocks`]) maps the same physical block into
//! several tables; any write through a table whose block is shared first
//! copies the block (all layers + params) and remaps — readers never
//! observe a neighbour's mutation. Blocks can additionally be *retained*
//! ([`KvCache::retain_block`]): at refcount 0 they stay allocated
//! (holding a reusable prefix) instead of returning to the free pool,
//! until the prefix cache evicts them ([`KvCache::free_retained_block`]).
//!
//! **SimQuant pages** implement the paper's online KV quantization
//! (§3.4) at block granularity: each (layer, block) carries per-channel
//! (vmin, step); appending a row that falls outside the block's range
//! triggers an in-place block re-encode (dequantize codes, widen range,
//! requantize). Sub-byte codes (4/2/1 bits) stay bit-packed —
//! `packed_len(D, bits)` bytes per row — so `storage_bytes` reports the
//! true packed width through the paged refactor. Chunked prefill resumes
//! mid-block: a chunk landing at `t0` inside a partially-filled block
//! encodes under that block's fitted params, widening at most once per
//! chunk ([`KvCache::ingest_prefill_at`]).
//!
//! **Graph contract**: the decode graphs still consume dense
//! `[L, B, CTX, *]` inputs with one param row per (layer, lane). `graph_
//! inputs`/`input_literals` gather the mapped blocks into that dense
//! form; when every block of a (layer, lane) shares bitwise-identical
//! params (always true for single-block residencies) the codes are
//! copied verbatim — bit-identical to the unpaged encode — otherwise the
//! rows re-encode under the per-channel union range of the blocks'
//! params. The gather is the per-step cost paging pays on the PJRT path;
//! the sim backend only builds it in tests.
//!
//! Hot-path contract: prefill ingestion encodes straight into the
//! cache's own block regions (no staging vectors) and fans disjoint
//! (layer, block) segments out across the worker pool via
//! [`KvCache::ingest_prefill_batch`]; block re-encodes run on reused
//! scratch buffers.

use std::collections::BTreeSet;

use anyhow::Result;

use crate::quant::kernels::{
    pack_u8_into, packed_len, simquant_decode_into, simquant_encode_into,
    simquant_encode_with_params_into, unpack_u8_into, validate_pack_bits,
    validate_simquant_bits,
};
use crate::runtime::{f32_bytes, literal_from_raw, Literal};
use crate::tensor::{DType, Tensor};
use crate::util::pool;

/// Default tokens per KV block. 16 keeps a whole short prompt in one
/// block (the verbatim-gather fast path) while leaving prefix-cache
/// sharing granular enough for chat-style system prompts.
pub const DEFAULT_BLOCK_SIZE: usize = 16;

/// Whether the cache stores f32 rows or SimQuant u8 codes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Mode {
    F32,
    SimQuant,
}

/// One (slot, layer) prefill page for [`KvCache::ingest_prefill_batch`]:
/// rows `[t_len, D]` per cache, destined for positions `t0..t0 + t_len`.
/// `t0 > 0` resumes a page mid-prompt (chunked prefill): positions
/// `0..t0` must already hold the earlier chunks' rows.
pub struct PrefillPage<'a> {
    pub slot: usize,
    pub layer: usize,
    pub k_rows: &'a [f32],
    pub v_rows: &'a [f32],
    /// first position the rows land at (0 for whole-prompt prefill)
    pub t0: usize,
    pub t_len: usize,
}

/// One lane's KV residency serialized at true packed width — the unit
/// of disaggregated prefill→decode handoff and page-based migration.
/// [`KvCache::export_lane`] copies the lane's mapped blocks (codes at
/// their bit-packed wire width plus the per-(layer, block) channel
/// params, or raw f32 rows for an uncompressed cache) in logical-block
/// order; [`KvCache::import_lane`] maps them into a fresh lane of a
/// *geometry-identical* cache on another shard, after which decode
/// continues bit-identically: the codes and params are copied verbatim,
/// so every future dequantize sees exactly the bytes the source shard
/// held. The export is a copy — source refcounts, retention, and COW
/// state are untouched, and the importer always writes into private
/// fresh blocks.
#[derive(Debug, Clone, PartialEq)]
pub struct LaneExport {
    /// tokens resident in the lane at export
    len: usize,
    quantized: bool,
    bits: u32,
    n_layers: usize,
    d: usize,
    block_size: usize,
    /// logical blocks exported (= ceil(len / block_size))
    n_lblocks: usize,
    /// f32 mode: [L, n_lblocks, block_size, D] rows (empty when quantized)
    k_f32: Vec<f32>,
    v_f32: Vec<f32>,
    /// simquant mode: [L, n_lblocks, block_size, row_bytes] packed codes
    k_q: Vec<u8>,
    v_q: Vec<u8>,
    /// simquant mode: [L, n_lblocks, D] per-channel params
    k_min: Vec<f32>,
    k_step: Vec<f32>,
    v_min: Vec<f32>,
    v_step: Vec<f32>,
}

impl LaneExport {
    /// Tokens resident in the exported lane.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Code bitwidth the pages travel at (8 for an f32 cache).
    pub fn code_bits(&self) -> u32 {
        if self.quantized {
            self.bits
        } else {
            8
        }
    }

    /// The byte segments that cross the wire: bit-packed code pages
    /// (`codes`) and f32 side data (`params` — channel params for a
    /// quantized lane, the raw rows for an f32 lane). The split is what
    /// [`crate::collective::ops::transfer_quant_pages`] checksums and
    /// charges to the link model.
    pub fn wire_segments(&self) -> (Vec<&[u8]>, Vec<&[f32]>) {
        if self.quantized {
            (
                vec![self.k_q.as_slice(), self.v_q.as_slice()],
                vec![
                    self.k_min.as_slice(),
                    self.k_step.as_slice(),
                    self.v_min.as_slice(),
                    self.v_step.as_slice(),
                ],
            )
        } else {
            (Vec::new(), vec![self.k_f32.as_slice(), self.v_f32.as_slice()])
        }
    }

    /// Total bytes the export occupies on the wire (packed codes + f32
    /// side data) — the quantized-width payload, not a dense gather.
    pub fn wire_bytes(&self) -> usize {
        let (codes, params) = self.wire_segments();
        codes.iter().map(|c| c.len()).sum::<usize>()
            + params.iter().map(|p| p.len() * 4).sum::<usize>()
    }
}

/// Paged, batched KV cache for one worker shard.
pub struct KvCache {
    n_layers: usize,
    batch: usize,
    ctx: usize,
    d: usize,
    mode: Mode,
    /// SimQuant code bitwidth (8, 4, 2, or 1); codes below 8 bits are
    /// stored bit-packed, `row_bytes` per row
    bits: u32,
    /// bytes one packed row of codes occupies (== d at 8 bits)
    row_bytes: usize,
    /// tokens per block
    block_size: usize,
    /// physical blocks in the pool
    n_blocks: usize,
    /// f32 mode: [L, n_blocks, block_size, D] rows; simquant mode empty
    k_f32: Vec<f32>,
    v_f32: Vec<f32>,
    /// simquant mode: [L, n_blocks, block_size, row_bytes] packed codes
    k_q: Vec<u8>,
    v_q: Vec<u8>,
    /// per (layer, block, channel) params, [L, n_blocks, D]
    k_min: Vec<f32>,
    k_step: Vec<f32>,
    v_min: Vec<f32>,
    v_step: Vec<f32>,
    /// per-lane filled length
    lens: Vec<usize>,
    /// per-lane block table: logical block index -> physical block
    tables: Vec<Vec<usize>>,
    /// ordered lane free pool (lowest-first handout, O(log n) release)
    free_lanes: BTreeSet<usize>,
    /// ordered block free pool (lowest-first handout, O(log n) release)
    free_blocks: BTreeSet<usize>,
    /// per-block table references (lanes mapping the block)
    ref_counts: Vec<u32>,
    /// per-block prefix-cache retention: at refcount 0 a retained block
    /// stays allocated (its prefix is reusable) until evicted
    retained: Vec<bool>,
    /// reused block-reencode scratch (decoded rows, widened lo/hi)
    scratch: Vec<f32>,
    lo_scratch: Vec<f32>,
    hi_scratch: Vec<f32>,
    /// reused unpacked-code staging for sub-byte blocks
    code_scratch: Vec<u8>,
    /// block re-encode counter (observability)
    pub reencodes: u64,
}

fn blocks_of(tokens: usize, block_size: usize) -> usize {
    (tokens + block_size - 1) / block_size
}

impl KvCache {
    pub fn new_f32(n_layers: usize, batch: usize, ctx: usize, d: usize) -> Self {
        let bs = DEFAULT_BLOCK_SIZE.min(ctx).max(1);
        Self::new_f32_paged(n_layers, batch, ctx, d, bs, batch * blocks_of(ctx, bs))
    }

    /// F32 cache with an explicit block geometry. `n_blocks` below
    /// `batch * ceil(ctx / block_size)` under-provisions the pool: lanes
    /// then compete for blocks ([`KvCache::try_reserve`]) and the
    /// serving layer preempts or bounces on exhaustion.
    pub fn new_f32_paged(
        n_layers: usize,
        batch: usize,
        ctx: usize,
        d: usize,
        block_size: usize,
        n_blocks: usize,
    ) -> Self {
        assert!(block_size >= 1 && block_size <= ctx, "block_size must be in 1..=ctx");
        KvCache {
            n_layers,
            batch,
            ctx,
            d,
            mode: Mode::F32,
            bits: 8,
            row_bytes: d,
            block_size,
            n_blocks,
            k_f32: vec![0.0; n_layers * n_blocks * block_size * d],
            v_f32: vec![0.0; n_layers * n_blocks * block_size * d],
            k_q: Vec::new(),
            v_q: Vec::new(),
            k_min: Vec::new(),
            k_step: Vec::new(),
            v_min: Vec::new(),
            v_step: Vec::new(),
            lens: vec![0; batch],
            tables: vec![Vec::new(); batch],
            free_lanes: (0..batch).collect(),
            free_blocks: (0..n_blocks).collect(),
            ref_counts: vec![0; n_blocks],
            retained: vec![false; n_blocks],
            scratch: Vec::new(),
            lo_scratch: Vec::new(),
            hi_scratch: Vec::new(),
            code_scratch: Vec::new(),
            reencodes: 0,
        }
    }

    pub fn new_simquant(n_layers: usize, batch: usize, ctx: usize, d: usize) -> Self {
        Self::new_simquant_bits(n_layers, batch, ctx, d, 8)
    }

    /// SimQuant cache storing `bits`-bit codes (8, 4, 2, or 1); sub-byte
    /// pages are bit-packed, `packed_len(d, bits)` bytes per row.
    pub fn new_simquant_bits(
        n_layers: usize,
        batch: usize,
        ctx: usize,
        d: usize,
        bits: u32,
    ) -> Self {
        let bs = DEFAULT_BLOCK_SIZE.min(ctx).max(1);
        Self::new_simquant_bits_paged(n_layers, batch, ctx, d, bits, bs, batch * blocks_of(ctx, bs))
    }

    /// SimQuant cache with an explicit block geometry (see
    /// [`KvCache::new_f32_paged`] for the pool semantics).
    #[allow(clippy::too_many_arguments)]
    pub fn new_simquant_bits_paged(
        n_layers: usize,
        batch: usize,
        ctx: usize,
        d: usize,
        bits: u32,
        block_size: usize,
        n_blocks: usize,
    ) -> Self {
        validate_simquant_bits(bits).expect("KvCache bits");
        validate_pack_bits(bits).expect("KvCache bits must pack (1, 2, 4, or 8)");
        assert!(block_size >= 1 && block_size <= ctx, "block_size must be in 1..=ctx");
        let row_bytes = packed_len(d, bits);
        KvCache {
            n_layers,
            batch,
            ctx,
            d,
            mode: Mode::SimQuant,
            bits,
            row_bytes,
            block_size,
            n_blocks,
            k_f32: Vec::new(),
            v_f32: Vec::new(),
            k_q: vec![0; n_layers * n_blocks * block_size * row_bytes],
            v_q: vec![0; n_layers * n_blocks * block_size * row_bytes],
            k_min: vec![0.0; n_layers * n_blocks * d],
            k_step: vec![1e-8; n_layers * n_blocks * d],
            v_min: vec![0.0; n_layers * n_blocks * d],
            v_step: vec![1e-8; n_layers * n_blocks * d],
            lens: vec![0; batch],
            tables: vec![Vec::new(); batch],
            free_lanes: (0..batch).collect(),
            free_blocks: (0..n_blocks).collect(),
            ref_counts: vec![0; n_blocks],
            retained: vec![false; n_blocks],
            scratch: Vec::new(),
            lo_scratch: Vec::new(),
            hi_scratch: Vec::new(),
            code_scratch: Vec::new(),
            reencodes: 0,
        }
    }

    pub fn is_quantized(&self) -> bool {
        self.mode == Mode::SimQuant
    }

    /// SimQuant code bitwidth (8 for the f32 cache, vacuously).
    pub fn code_bits(&self) -> u32 {
        self.bits
    }

    pub fn len(&self, slot: usize) -> usize {
        self.lens[slot]
    }

    pub fn is_empty(&self) -> bool {
        self.lens.iter().all(|l| *l == 0)
    }

    /// Tokens per physical block.
    pub fn block_size(&self) -> usize {
        self.block_size
    }

    /// Physical blocks in the pool.
    pub fn total_blocks(&self) -> usize {
        self.n_blocks
    }

    /// Blocks currently in the free pool (excludes retained blocks).
    pub fn free_block_count(&self) -> usize {
        self.free_blocks.len()
    }

    /// Blocks currently held by the prefix cache (retained flag set).
    pub fn retained_count(&self) -> usize {
        self.retained.iter().filter(|r| **r).count()
    }

    /// Blocks a residency of `tokens` (clamped to ctx) occupies.
    pub fn blocks_needed(&self, tokens: usize) -> usize {
        blocks_of(tokens.min(self.ctx), self.block_size)
    }

    /// Lane-table references on a block (prefix retention not counted).
    pub fn ref_count(&self, block: usize) -> u32 {
        self.ref_counts[block]
    }

    pub fn is_retained(&self, block: usize) -> bool {
        self.retained[block]
    }

    /// One lane's block table (logical block index -> physical block).
    pub fn table(&self, slot: usize) -> &[usize] {
        &self.tables[slot]
    }

    /// Highest representable code for the current bitwidth.
    fn levels(&self) -> f32 {
        ((1u32 << self.bits) - 1) as f32
    }

    /// Unmap one lane: every table entry drops a reference; blocks at
    /// refcount 0 are scrubbed and returned to the free pool unless the
    /// prefix cache retains them (the decode graphs consume full dense
    /// pages, so a retired request's rows must not leak into the next
    /// occupant's cache inputs).
    pub fn reset_slot(&mut self, slot: usize) {
        let table = std::mem::take(&mut self.tables[slot]);
        for b in table {
            self.ref_counts[b] -= 1;
            if self.ref_counts[b] == 0 && !self.retained[b] {
                self.scrub_block(b);
                let fresh = self.free_blocks.insert(b);
                debug_assert!(fresh, "double free of block {b}");
            }
        }
        self.lens[slot] = 0;
    }

    /// Number of lanes currently available to `acquire_slot`.
    pub fn free_slots(&self) -> usize {
        self.free_lanes.len()
    }

    /// Claim the lowest free lane for a new request (the caller attaches
    /// cached blocks and/or ingests prefill rows into it next). Returns
    /// `None` when the batch is full.
    pub fn acquire_slot(&mut self) -> Option<usize> {
        let slot = self.free_lanes.pop_first()?;
        debug_assert!(self.tables[slot].is_empty() && self.lens[slot] == 0);
        Some(slot)
    }

    /// Retire a lane: unmap its blocks and return it to the ordered free
    /// pool so the next admitted request reuses the lowest lane.
    pub fn release_slot(&mut self, slot: usize) {
        self.reset_slot(slot);
        let fresh = self.free_lanes.insert(slot);
        debug_assert!(fresh, "double release of slot {slot}");
    }

    /// Clone one lane's residency into a fresh lane, sharing every block
    /// copy-on-write (refcounts bumped; first write through either table
    /// copies the block). Returns `None` when no lane is free.
    pub fn fork_slot(&mut self, src: usize) -> Option<usize> {
        let lane = self.acquire_slot()?;
        let table = self.tables[src].clone();
        for &b in &table {
            self.ref_counts[b] += 1;
        }
        self.tables[lane] = table;
        self.lens[lane] = self.lens[src];
        Some(lane)
    }

    /// Map already-encoded shared blocks (a prefix-cache hit) into an
    /// empty lane: the lane starts `cached_len` tokens long and prefill
    /// resumes at the first uncached position. The blocks stay shared
    /// (refcounted); the lane's own writes land in fresh blocks past the
    /// cached prefix.
    pub fn attach_cached_blocks(&mut self, slot: usize, blocks: &[usize], cached_len: usize) {
        assert!(
            self.tables[slot].is_empty() && self.lens[slot] == 0,
            "attach into a dirty slot"
        );
        assert!(cached_len <= blocks.len() * self.block_size, "cached_len past blocks");
        for &b in blocks {
            self.ref_counts[b] += 1;
            self.tables[slot].push(b);
        }
        self.lens[slot] = cached_len;
    }

    /// Mark a block retained: at refcount 0 it stays allocated for the
    /// prefix cache instead of returning to the free pool.
    pub fn retain_block(&mut self, block: usize) {
        self.retained[block] = true;
    }

    /// Prefix-cache eviction: scrub a retained, unreferenced block and
    /// return it to the free pool.
    pub fn free_retained_block(&mut self, block: usize) {
        assert!(
            self.retained[block] && self.ref_counts[block] == 0,
            "evicting a live block {block}"
        );
        self.retained[block] = false;
        self.scrub_block(block);
        let fresh = self.free_blocks.insert(block);
        debug_assert!(fresh, "double free of block {block}");
    }

    /// Eagerly extend a lane's table to cover `target_tokens` (clamped
    /// to ctx). Returns `false` — leaving any blocks it did claim mapped,
    /// so a bouncing caller releases the lane to undo — when the free
    /// pool cannot cover the remainder. Reserving up front means decode
    /// appends never fail mid-flight.
    pub fn try_reserve(&mut self, slot: usize, target_tokens: usize) -> bool {
        let need = blocks_of(target_tokens.min(self.ctx), self.block_size);
        while self.tables[slot].len() < need {
            match self.alloc_block() {
                Some(b) => self.tables[slot].push(b),
                None => return false,
            }
        }
        true
    }

    /// Lowest free block, scrubbed-clean, refcount 1.
    fn alloc_block(&mut self) -> Option<usize> {
        let b = self.free_blocks.pop_first()?;
        debug_assert!(self.ref_counts[b] == 0 && !self.retained[b]);
        self.ref_counts[b] = 1;
        Some(b)
    }

    /// Grow the table so position `upto - 1` is mapped; panics when the
    /// pool is exhausted (serving paths reserve eagerly and preempt or
    /// bounce instead of reaching this).
    fn ensure_capacity(&mut self, slot: usize, upto: usize) {
        let need = blocks_of(upto, self.block_size);
        while self.tables[slot].len() < need {
            let b = self
                .alloc_block()
                .unwrap_or_else(|| panic!("KV block pool exhausted (slot {slot})"));
            self.tables[slot].push(b);
        }
    }

    /// Copy-on-write barrier: writing through `table[bi]` while the
    /// block is shared first copies it (all layers + params) into a
    /// fresh block and remaps this lane.
    fn ensure_private(&mut self, slot: usize, bi: usize) {
        let block = self.tables[slot][bi];
        if self.ref_counts[block] <= 1 && !self.retained[block] {
            return;
        }
        let fresh = self
            .alloc_block()
            .unwrap_or_else(|| panic!("KV block pool exhausted (copy-on-write)"));
        for layer in 0..self.n_layers {
            match self.mode {
                Mode::F32 => {
                    let n = self.block_size * self.d;
                    let src = self.block_row_off(layer, block, 0);
                    let dst = self.block_row_off(layer, fresh, 0);
                    self.k_f32.copy_within(src..src + n, dst);
                    self.v_f32.copy_within(src..src + n, dst);
                }
                Mode::SimQuant => {
                    let n = self.block_size * self.row_bytes;
                    let src = self.block_code_off(layer, block, 0);
                    let dst = self.block_code_off(layer, fresh, 0);
                    self.k_q.copy_within(src..src + n, dst);
                    self.v_q.copy_within(src..src + n, dst);
                    let ps = self.block_param_off(layer, block);
                    let pd = self.block_param_off(layer, fresh);
                    self.k_min.copy_within(ps..ps + self.d, pd);
                    self.k_step.copy_within(ps..ps + self.d, pd);
                    self.v_min.copy_within(ps..ps + self.d, pd);
                    self.v_step.copy_within(ps..ps + self.d, pd);
                }
            }
        }
        self.ref_counts[block] -= 1;
        self.tables[slot][bi] = fresh;
    }

    /// Zero one block's rows and reset its params across all layers.
    fn scrub_block(&mut self, block: usize) {
        for layer in 0..self.n_layers {
            match self.mode {
                Mode::F32 => {
                    let n = self.block_size * self.d;
                    let off = self.block_row_off(layer, block, 0);
                    self.k_f32[off..off + n].fill(0.0);
                    self.v_f32[off..off + n].fill(0.0);
                }
                Mode::SimQuant => {
                    let n = self.block_size * self.row_bytes;
                    let off = self.block_code_off(layer, block, 0);
                    self.k_q[off..off + n].fill(0);
                    self.v_q[off..off + n].fill(0);
                    let p = self.block_param_off(layer, block);
                    self.k_min[p..p + self.d].fill(0.0);
                    self.k_step[p..p + self.d].fill(1e-8);
                    self.v_min[p..p + self.d].fill(0.0);
                    self.v_step[p..p + self.d].fill(1e-8);
                }
            }
        }
    }

    /// Bytes the cache occupies (memory accounting for the tables).
    /// Sub-byte caches count their bit-packed code pages, so the
    /// reported ratio vs f32 is the real one; SimQuant adds the
    /// per-(layer, block) channel params.
    pub fn storage_bytes(&self) -> usize {
        match self.mode {
            Mode::F32 => (self.k_f32.len() + self.v_f32.len()) * 4,
            Mode::SimQuant => {
                self.k_q.len()
                    + self.v_q.len()
                    + (self.k_min.len() + self.k_step.len() + self.v_min.len()
                        + self.v_step.len())
                        * 4
            }
        }
    }

    #[inline]
    fn block_row_off(&self, layer: usize, block: usize, r: usize) -> usize {
        ((layer * self.n_blocks + block) * self.block_size + r) * self.d
    }

    /// Byte offset of row `r` in a block's (packed) code region.
    #[inline]
    fn block_code_off(&self, layer: usize, block: usize, r: usize) -> usize {
        ((layer * self.n_blocks + block) * self.block_size + r) * self.row_bytes
    }

    #[inline]
    fn block_param_off(&self, layer: usize, block: usize) -> usize {
        (layer * self.n_blocks + block) * self.d
    }

    /// Ingest prefill caches for one slot: rows [T, D] per layer, stored
    /// (and for SimQuant: block-encoded, straight into the block pool)
    /// at positions 0..t_len.
    pub fn ingest_prefill(
        &mut self,
        slot: usize,
        layer: usize,
        k_rows: &[f32],
        v_rows: &[f32],
        t_len: usize,
    ) {
        self.ingest_prefill_at(slot, layer, 0, k_rows, v_rows, t_len);
    }

    /// Resume-capable prefill ingest: store rows [T, D] at positions
    /// `t0..t0 + t_len`, split across the lane's blocks. A chunk landing
    /// mid-block (`t0 % block_size != 0`) resumes that block: its params
    /// were fitted to the earlier rows, and rows that escape the range
    /// widen it once per chunk (old rows decoded, range recomputed over
    /// the union, block re-encoded) — the same adaptation the decode
    /// append path performs per row. Fresh blocks fit their params to
    /// their own first segment.
    pub fn ingest_prefill_at(
        &mut self,
        slot: usize,
        layer: usize,
        t0: usize,
        k_rows: &[f32],
        v_rows: &[f32],
        t_len: usize,
    ) {
        assert!(t0 + t_len <= self.ctx, "prefill rows past ctx");
        assert_eq!(k_rows.len(), t_len * self.d);
        assert_eq!(v_rows.len(), t_len * self.d);
        if t_len == 0 {
            return;
        }
        self.ensure_capacity(slot, t0 + t_len);
        let (bs, d) = (self.block_size, self.d);
        for bi in (t0 / bs)..=((t0 + t_len - 1) / bs) {
            self.ensure_private(slot, bi);
            let block = self.tables[slot][bi];
            let seg_start = t0.max(bi * bs);
            let seg_end = (t0 + t_len).min((bi + 1) * bs);
            let (r0, n) = (seg_start - bi * bs, seg_end - seg_start);
            let src = (seg_start - t0) * d;
            match self.mode {
                Mode::F32 => {
                    let off = self.block_row_off(layer, block, r0);
                    self.k_f32[off..off + n * d].copy_from_slice(&k_rows[src..src + n * d]);
                    self.v_f32[off..off + n * d].copy_from_slice(&v_rows[src..src + n * d]);
                }
                Mode::SimQuant => {
                    let (bits, row_bytes) = (self.bits, self.row_bytes);
                    let off = self.block_code_off(layer, block, 0);
                    let p = self.block_param_off(layer, block);
                    let page = (r0 + n) * row_bytes;
                    let mut cscratch = std::mem::take(&mut self.code_scratch);
                    let mut fscratch = std::mem::take(&mut self.scratch);
                    resume_page_packed(
                        &k_rows[src..src + n * d],
                        r0,
                        n,
                        d,
                        bits,
                        row_bytes,
                        &mut self.k_q[off..off + page],
                        &mut self.k_min[p..p + d],
                        &mut self.k_step[p..p + d],
                        &mut fscratch,
                        &mut cscratch,
                    );
                    resume_page_packed(
                        &v_rows[src..src + n * d],
                        r0,
                        n,
                        d,
                        bits,
                        row_bytes,
                        &mut self.v_q[off..off + page],
                        &mut self.v_min[p..p + d],
                        &mut self.v_step[p..p + d],
                        &mut fscratch,
                        &mut cscratch,
                    );
                    self.code_scratch = cscratch;
                    self.scratch = fscratch;
                }
            }
        }
        self.lens[slot] = self.lens[slot].max(t0 + t_len);
    }

    /// Ingest a batch of disjoint (slot, layer) prefill pages in
    /// parallel: each page is split into its per-(layer, block) segments
    /// and the segment encodes fan out across the persistent worker pool
    /// (distinct lanes own disjoint blocks after the COW barrier, so the
    /// carved regions never alias). Panics if two pages target the same
    /// (slot, layer).
    pub fn ingest_prefill_batch(&mut self, pages: &[PrefillPage<'_>]) {
        for p in pages {
            assert!(p.slot < self.batch && p.layer < self.n_layers, "page out of range");
            assert!(p.t0 + p.t_len <= self.ctx, "prefill rows past ctx");
            assert_eq!(p.k_rows.len(), p.t_len * self.d);
            assert_eq!(p.v_rows.len(), p.t_len * self.d);
        }
        let mut keys: Vec<usize> =
            pages.iter().map(|p| p.layer * self.batch + p.slot).collect();
        keys.sort_unstable();
        for w in keys.windows(2) {
            assert!(w[0] < w[1], "duplicate (slot, layer) prefill page");
        }
        let (bs, d) = (self.block_size, self.d);
        // map + privatize up front so the segment expansion below sees
        // final, lane-owned physical blocks
        for p in pages {
            if p.t_len == 0 {
                continue;
            }
            self.ensure_capacity(p.slot, p.t0 + p.t_len);
            for bi in (p.t0 / bs)..=((p.t0 + p.t_len - 1) / bs) {
                self.ensure_private(p.slot, bi);
            }
        }
        // (pool index, page, src offset, block-local row, rows)
        let mut segs: Vec<(usize, usize, usize, usize, usize)> = Vec::new();
        for (i, p) in pages.iter().enumerate() {
            if p.t_len == 0 {
                continue;
            }
            for bi in (p.t0 / bs)..=((p.t0 + p.t_len - 1) / bs) {
                let block = self.tables[p.slot][bi];
                let seg_start = p.t0.max(bi * bs);
                let seg_end = (p.t0 + p.t_len).min((bi + 1) * bs);
                segs.push((
                    p.layer * self.n_blocks + block,
                    i,
                    (seg_start - p.t0) * d,
                    seg_start - bi * bs,
                    seg_end - seg_start,
                ));
            }
        }
        segs.sort_unstable_by_key(|s| s.0);
        let idxs: Vec<usize> = segs.iter().map(|s| s.0).collect();
        debug_assert!(idxs.windows(2).all(|w| w[0] < w[1]), "aliased block segments");
        match self.mode {
            Mode::F32 => {
                let page_len = bs * d;
                let kblocks = carve(&mut self.k_f32, &idxs, page_len);
                let vblocks = carve(&mut self.v_f32, &idxs, page_len);
                let mut tasks: Vec<pool::Task<'_>> = Vec::with_capacity(segs.len());
                for ((&(_, pi, src, r0, n), kb), vb) in
                    segs.iter().zip(kblocks).zip(vblocks)
                {
                    let p = &pages[pi];
                    let (k_rows, v_rows) = (p.k_rows, p.v_rows);
                    tasks.push(Box::new(move || {
                        kb[r0 * d..(r0 + n) * d].copy_from_slice(&k_rows[src..src + n * d]);
                        vb[r0 * d..(r0 + n) * d].copy_from_slice(&v_rows[src..src + n * d]);
                    }));
                }
                pool::run(tasks);
            }
            Mode::SimQuant => {
                let (bits, row_bytes) = (self.bits, self.row_bytes);
                let code_page = bs * row_bytes;
                let kq = carve(&mut self.k_q, &idxs, code_page);
                let vq = carve(&mut self.v_q, &idxs, code_page);
                let kmin = carve(&mut self.k_min, &idxs, d);
                let kstep = carve(&mut self.k_step, &idxs, d);
                let vmin = carve(&mut self.v_min, &idxs, d);
                let vstep = carve(&mut self.v_step, &idxs, d);
                let iter = segs
                    .iter()
                    .zip(kq.into_iter().zip(vq))
                    .zip(kmin.into_iter().zip(kstep))
                    .zip(vmin.into_iter().zip(vstep));
                let mut tasks: Vec<pool::Task<'_>> = Vec::with_capacity(segs.len());
                for (((&(_, pi, src, r0, n), (kqb, vqb)), (kmb, ksb)), (vmb, vsb)) in iter {
                    let p = &pages[pi];
                    let (k_rows, v_rows) = (p.k_rows, p.v_rows);
                    tasks.push(Box::new(move || {
                        // per-task staging (only allocated for sub-byte
                        // or resumed segments; the fresh 8-bit path
                        // encodes in place)
                        let mut cscratch = Vec::new();
                        let mut fscratch = Vec::new();
                        let page = (r0 + n) * row_bytes;
                        resume_page_packed(
                            &k_rows[src..src + n * d],
                            r0,
                            n,
                            d,
                            bits,
                            row_bytes,
                            &mut kqb[..page],
                            kmb,
                            ksb,
                            &mut fscratch,
                            &mut cscratch,
                        );
                        resume_page_packed(
                            &v_rows[src..src + n * d],
                            r0,
                            n,
                            d,
                            bits,
                            row_bytes,
                            &mut vqb[..page],
                            vmb,
                            vsb,
                            &mut fscratch,
                            &mut cscratch,
                        );
                    }));
                }
                pool::run(tasks);
            }
        }
        for p in pages {
            self.lens[p.slot] = self.lens[p.slot].max(p.t0 + p.t_len);
        }
    }

    /// Append one decode-step row per cache; grows the slot by one.
    pub fn append_row(&mut self, slot: usize, layer: usize, k_row: &[f32], v_row: &[f32]) {
        let t = self.lens[slot];
        assert!(t < self.ctx, "slot {slot} KV overflow");
        self.ensure_capacity(slot, t + 1);
        let bi = t / self.block_size;
        self.ensure_private(slot, bi);
        let block = self.tables[slot][bi];
        let r = t % self.block_size;
        match self.mode {
            Mode::F32 => {
                let off = self.block_row_off(layer, block, r);
                self.k_f32[off..off + self.d].copy_from_slice(k_row);
                self.v_f32[off..off + self.d].copy_from_slice(v_row);
            }
            Mode::SimQuant => {
                self.append_quantized(block, layer, r, k_row, true);
                self.append_quantized(block, layer, r, v_row, false);
            }
        }
        // the caller bumps the length once after appending all layers
    }

    /// Mark the slot one token longer (after all layers appended).
    pub fn bump(&mut self, slot: usize) {
        self.lens[slot] += 1;
    }

    /// Roll the slot back to `len` tokens — the speculative-decoding
    /// rejection path. Per-lane block tables make this pure
    /// bookkeeping: the table keeps its mappings and no blocks move or
    /// free (the lane's reservation was sized for its full budget at
    /// admit, so the freed tail is re-filled by the very next append).
    /// Rows past `len` become dead and are overwritten in place later;
    /// any widened SimQuant page params they left behind only loosen a
    /// bound, never corrupt live rows.
    pub fn truncate(&mut self, slot: usize, len: usize) {
        assert!(
            len <= self.lens[slot],
            "truncate can only shrink: slot {} has {} tokens, asked for {}",
            slot,
            self.lens[slot],
            len
        );
        self.lens[slot] = len;
    }

    fn append_quantized(
        &mut self,
        block: usize,
        layer: usize,
        r: usize,
        row: &[f32],
        is_k: bool,
    ) {
        let p = self.block_param_off(layer, block);
        let d = self.d;
        let levels = self.levels();
        // the zipped loops below would silently truncate a short row
        assert_eq!(row.len(), d, "KV row length != d");
        // check range against the block's params; widen + re-encode the
        // block if violated
        let mut needs_reencode = false;
        {
            let (vmin, vstep) = if is_k {
                (&self.k_min[p..p + d], &self.k_step[p..p + d])
            } else {
                (&self.v_min[p..p + d], &self.v_step[p..p + d])
            };
            for ((mn, st), v) in vmin.iter().zip(vstep).zip(row) {
                let hi = mn + st * levels;
                if *v < mn - 1e-9 || *v > hi + 1e-9 {
                    needs_reencode = true;
                    break;
                }
            }
        }
        if needs_reencode && r > 0 {
            self.reencode_block(block, layer, r, row, is_k);
            self.reencodes += 1;
        } else if needs_reencode {
            // fresh block: seed params from the row itself
            let (vmin, vstep) = if is_k {
                (&mut self.k_min[p..p + d], &mut self.k_step[p..p + d])
            } else {
                (&mut self.v_min[p..p + d], &mut self.v_step[p..p + d])
            };
            for ((mn, st), v) in vmin.iter_mut().zip(vstep.iter_mut()).zip(row) {
                let lo = v.min(0.0);
                let hi = v.max(0.0);
                *mn = lo;
                *st = (hi - lo).max(1e-8) / levels;
            }
        }
        // encode the row with current params
        let off = self.block_code_off(layer, block, r);
        let row_bytes = self.row_bytes;
        if self.bits == 8 {
            let (vmin, vstep, codes) = if is_k {
                (
                    &self.k_min[p..p + d],
                    &self.k_step[p..p + d],
                    &mut self.k_q[off..off + d],
                )
            } else {
                (
                    &self.v_min[p..p + d],
                    &self.v_step[p..p + d],
                    &mut self.v_q[off..off + d],
                )
            };
            simquant_encode_with_params_into(row, vmin, vstep, levels, codes);
        } else {
            // sub-byte: encode into the reused staging row, then pack
            let mut scratch = std::mem::take(&mut self.code_scratch);
            scratch.clear();
            scratch.resize(d, 0);
            {
                let (vmin, vstep) = if is_k {
                    (&self.k_min[p..p + d], &self.k_step[p..p + d])
                } else {
                    (&self.v_min[p..p + d], &self.v_step[p..p + d])
                };
                simquant_encode_with_params_into(row, vmin, vstep, levels, &mut scratch);
            }
            let codes = if is_k {
                &mut self.k_q[off..off + row_bytes]
            } else {
                &mut self.v_q[off..off + row_bytes]
            };
            pack_u8_into(&scratch, self.bits, codes).expect("sized packed row");
            self.code_scratch = scratch;
        }
    }

    /// Widen one block's range to cover `row` and requantize its first
    /// `r` rows. Runs entirely on the cache's reused scratch buffers.
    /// The re-encode scope is the block, not the residency — the paged
    /// win over the old whole-page widening.
    fn reencode_block(&mut self, block: usize, layer: usize, r: usize, row: &[f32], is_k: bool) {
        let p = self.block_param_off(layer, block);
        let d = self.d;
        let levels = self.levels();
        let (bits, row_bytes) = (self.bits, self.row_bytes);
        let base = self.block_code_off(layer, block, 0);
        // decode current rows into the reused scratch (unpacking
        // sub-byte rows through the reused code staging first)
        let mut page = std::mem::take(&mut self.scratch);
        page.clear();
        page.resize(r * d, 0.0);
        let mut ucodes = std::mem::take(&mut self.code_scratch);
        {
            let (codes, vmin, vstep) = if is_k {
                (
                    &self.k_q[base..base + r * row_bytes],
                    &self.k_min[p..p + d],
                    &self.k_step[p..p + d],
                )
            } else {
                (
                    &self.v_q[base..base + r * row_bytes],
                    &self.v_min[p..p + d],
                    &self.v_step[p..p + d],
                )
            };
            if bits == 8 {
                simquant_decode_into(codes, vmin, vstep, r, d, &mut page);
            } else {
                ucodes.clear();
                ucodes.resize(r * d, 0);
                unpack_rows(codes, r, d, bits, row_bytes, &mut ucodes);
                simquant_decode_into(&ucodes, vmin, vstep, r, d, &mut page);
            }
        }
        // widened per-channel range over the block's rows + new row
        let mut lo = std::mem::take(&mut self.lo_scratch);
        let mut hi = std::mem::take(&mut self.hi_scratch);
        lo.clear();
        lo.resize(d, f32::INFINITY);
        hi.clear();
        hi.resize(d, f32::NEG_INFINITY);
        for prow in page.chunks_exact(d) {
            for ((l, h), v) in lo.iter_mut().zip(hi.iter_mut()).zip(prow) {
                *l = l.min(*v);
                *h = h.max(*v);
            }
        }
        for ((l, h), v) in lo.iter_mut().zip(hi.iter_mut()).zip(row) {
            *l = l.min(*v);
            *h = h.max(*v);
        }
        // write params + re-encoded codes
        {
            let (vmin, vstep) = if is_k {
                (&mut self.k_min[p..p + d], &mut self.k_step[p..p + d])
            } else {
                (&mut self.v_min[p..p + d], &mut self.v_step[p..p + d])
            };
            for ((mn, st), (l, h)) in
                vmin.iter_mut().zip(vstep.iter_mut()).zip(lo.iter().zip(&hi))
            {
                *mn = *l;
                *st = (h - l).max(1e-8) / levels;
            }
        }
        let (codes, vmin, vstep) = if is_k {
            (
                &mut self.k_q[base..base + r * row_bytes],
                &self.k_min[p..p + d],
                &self.k_step[p..p + d],
            )
        } else {
            (
                &mut self.v_q[base..base + r * row_bytes],
                &self.v_min[p..p + d],
                &self.v_step[p..p + d],
            )
        };
        if bits == 8 {
            simquant_encode_with_params_into(&page, vmin, vstep, levels, codes);
        } else {
            ucodes.clear();
            ucodes.resize(r * d, 0);
            simquant_encode_with_params_into(&page, vmin, vstep, levels, &mut ucodes);
            pack_rows(&ucodes, r, d, bits, row_bytes, codes);
        }
        self.scratch = page;
        self.lo_scratch = lo;
        self.hi_scratch = hi;
        self.code_scratch = ucodes;
    }

    /// Dequantize one slot's K rows into a reused buffer (cleared and
    /// refilled), gathering through the block table — the
    /// scratch-friendly variant of [`KvCache::decode_k`]. Sub-byte
    /// blocks unpack through the cache's reused code staging (hence
    /// `&mut self`); no per-call allocation on any path.
    pub fn decode_k_into(&mut self, slot: usize, layer: usize, out: &mut Vec<f32>) {
        let t = self.lens[slot];
        let d = self.d;
        out.clear();
        out.resize(t * d, 0.0);
        if t == 0 {
            return;
        }
        let bs = self.block_size;
        let mut ucodes = std::mem::take(&mut self.code_scratch);
        for bi in 0..=(t - 1) / bs {
            let block = self.tables[slot][bi];
            let n = (t - bi * bs).min(bs);
            let dst = bi * bs * d;
            match self.mode {
                Mode::F32 => {
                    let off = self.block_row_off(layer, block, 0);
                    out[dst..dst + n * d].copy_from_slice(&self.k_f32[off..off + n * d]);
                }
                Mode::SimQuant => {
                    let off = self.block_code_off(layer, block, 0);
                    let p = self.block_param_off(layer, block);
                    if self.bits == 8 {
                        simquant_decode_into(
                            &self.k_q[off..off + n * d],
                            &self.k_min[p..p + d],
                            &self.k_step[p..p + d],
                            n,
                            d,
                            &mut out[dst..dst + n * d],
                        );
                    } else {
                        let rb = self.row_bytes;
                        ucodes.clear();
                        ucodes.resize(n * d, 0);
                        unpack_rows(
                            &self.k_q[off..off + n * rb],
                            n,
                            d,
                            self.bits,
                            rb,
                            &mut ucodes,
                        );
                        simquant_decode_into(
                            &ucodes,
                            &self.k_min[p..p + d],
                            &self.k_step[p..p + d],
                            n,
                            d,
                            &mut out[dst..dst + n * d],
                        );
                    }
                }
            }
        }
        self.code_scratch = ucodes;
    }

    /// Dequantize one slot's K rows (tests + debugging).
    pub fn decode_k(&mut self, slot: usize, layer: usize) -> Vec<f32> {
        let mut out = Vec::new();
        self.decode_k_into(slot, layer, &mut out);
        out
    }

    /// Gather the paged f32 pool into dense `[L, B, CTX, D]` caches.
    fn dense_f32(&self) -> (Vec<f32>, Vec<f32>) {
        let (l, b, c, d, bs) = (self.n_layers, self.batch, self.ctx, self.d, self.block_size);
        let mut k = vec![0.0f32; l * b * c * d];
        let mut v = vec![0.0f32; l * b * c * d];
        for slot in 0..b {
            let t = self.lens[slot];
            if t == 0 {
                continue;
            }
            for layer in 0..l {
                for bi in 0..=(t - 1) / bs {
                    let block = self.tables[slot][bi];
                    let n = (t - bi * bs).min(bs);
                    let src = self.block_row_off(layer, block, 0);
                    let dst = ((layer * b + slot) * c + bi * bs) * d;
                    k[dst..dst + n * d].copy_from_slice(&self.k_f32[src..src + n * d]);
                    v[dst..dst + n * d].copy_from_slice(&self.v_f32[src..src + n * d]);
                }
            }
        }
        (k, v)
    }

    /// Re-encode one (layer, slot)'s rows under the union of its blocks'
    /// param ranges, writing dense codes + the union params. Only runs
    /// when the blocks' params diverge (the dense graph consumes one
    /// param row per lane).
    #[allow(clippy::too_many_arguments)]
    fn union_reencode(
        &self,
        slot: usize,
        layer: usize,
        t: usize,
        is_k: bool,
        fbuf: &mut Vec<f32>,
        ubuf: &mut Vec<u8>,
        codes_out: &mut [u8],
        min_out: &mut [f32],
        step_out: &mut [f32],
    ) {
        let (d, bs, rb, bits) = (self.d, self.block_size, self.row_bytes, self.bits);
        let levels = self.levels();
        let (q, pmin, pstep) = if is_k {
            (&self.k_q, &self.k_min, &self.k_step)
        } else {
            (&self.v_q, &self.v_min, &self.v_step)
        };
        let nb = (t - 1) / bs + 1;
        // union per-channel range from the block params (step_out holds
        // the running hi until the final conversion)
        min_out.fill(f32::INFINITY);
        step_out.fill(f32::NEG_INFINITY);
        for bi in 0..nb {
            let p = self.block_param_off(layer, self.tables[slot][bi]);
            for ch in 0..d {
                let lo = pmin[p + ch];
                let hi = lo + pstep[p + ch] * levels;
                min_out[ch] = min_out[ch].min(lo);
                step_out[ch] = step_out[ch].max(hi);
            }
        }
        for ch in 0..d {
            step_out[ch] = (step_out[ch] - min_out[ch]).max(1e-8) / levels;
        }
        // decode each block's rows under its own params
        fbuf.clear();
        fbuf.resize(t * d, 0.0);
        for bi in 0..nb {
            let block = self.tables[slot][bi];
            let n = (t - bi * bs).min(bs);
            let src = self.block_code_off(layer, block, 0);
            let p = self.block_param_off(layer, block);
            let dst = bi * bs * d;
            if bits == 8 {
                simquant_decode_into(
                    &q[src..src + n * d],
                    &pmin[p..p + d],
                    &pstep[p..p + d],
                    n,
                    d,
                    &mut fbuf[dst..dst + n * d],
                );
            } else {
                ubuf.clear();
                ubuf.resize(n * d, 0);
                unpack_rows(&q[src..src + n * rb], n, d, bits, rb, ubuf);
                simquant_decode_into(
                    ubuf,
                    &pmin[p..p + d],
                    &pstep[p..p + d],
                    n,
                    d,
                    &mut fbuf[dst..dst + n * d],
                );
            }
        }
        // re-encode the gathered rows under the union params
        if bits == 8 {
            simquant_encode_with_params_into(
                &fbuf[..t * d],
                min_out,
                step_out,
                levels,
                &mut codes_out[..t * d],
            );
        } else {
            ubuf.clear();
            ubuf.resize(t * d, 0);
            simquant_encode_with_params_into(&fbuf[..t * d], min_out, step_out, levels, ubuf);
            pack_rows(ubuf, t, d, bits, rb, &mut codes_out[..t * rb]);
        }
    }

    /// Gather the paged SimQuant pool into dense `[L, B, CTX,
    /// row_bytes]` codes + `[L, B, D]` params. Uniform-params lanes
    /// (every mapped block bitwise-identical, always true single-block)
    /// copy codes verbatim; diverging lanes re-encode under the union
    /// range.
    #[allow(clippy::type_complexity)]
    fn dense_simquant(&self) -> (Vec<u8>, Vec<u8>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let (l, b, c, d, rb, bs) =
            (self.n_layers, self.batch, self.ctx, self.d, self.row_bytes, self.block_size);
        let mut kq = vec![0u8; l * b * c * rb];
        let mut vq = vec![0u8; l * b * c * rb];
        let mut kmin = vec![0.0f32; l * b * d];
        let mut kstep = vec![1e-8f32; l * b * d];
        let mut vmin = vec![0.0f32; l * b * d];
        let mut vstep = vec![1e-8f32; l * b * d];
        let mut fbuf: Vec<f32> = Vec::new();
        let mut ubuf: Vec<u8> = Vec::new();
        for slot in 0..b {
            let t = self.lens[slot];
            if t == 0 {
                continue;
            }
            let nb = (t - 1) / bs + 1;
            for layer in 0..l {
                let cbase = ((layer * b + slot) * c) * rb;
                let pdst = (layer * b + slot) * d;
                let p0 = self.block_param_off(layer, self.tables[slot][0]);
                let uniform = (1..nb).all(|bi| {
                    let p = self.block_param_off(layer, self.tables[slot][bi]);
                    self.k_min[p..p + d] == self.k_min[p0..p0 + d]
                        && self.k_step[p..p + d] == self.k_step[p0..p0 + d]
                        && self.v_min[p..p + d] == self.v_min[p0..p0 + d]
                        && self.v_step[p..p + d] == self.v_step[p0..p0 + d]
                });
                if uniform {
                    for bi in 0..nb {
                        let block = self.tables[slot][bi];
                        let n = (t - bi * bs).min(bs);
                        let src = self.block_code_off(layer, block, 0);
                        let dst = cbase + bi * bs * rb;
                        kq[dst..dst + n * rb].copy_from_slice(&self.k_q[src..src + n * rb]);
                        vq[dst..dst + n * rb].copy_from_slice(&self.v_q[src..src + n * rb]);
                    }
                    kmin[pdst..pdst + d].copy_from_slice(&self.k_min[p0..p0 + d]);
                    kstep[pdst..pdst + d].copy_from_slice(&self.k_step[p0..p0 + d]);
                    vmin[pdst..pdst + d].copy_from_slice(&self.v_min[p0..p0 + d]);
                    vstep[pdst..pdst + d].copy_from_slice(&self.v_step[p0..p0 + d]);
                } else {
                    self.union_reencode(
                        slot,
                        layer,
                        t,
                        true,
                        &mut fbuf,
                        &mut ubuf,
                        &mut kq[cbase..cbase + c * rb],
                        &mut kmin[pdst..pdst + d],
                        &mut kstep[pdst..pdst + d],
                    );
                    self.union_reencode(
                        slot,
                        layer,
                        t,
                        false,
                        &mut fbuf,
                        &mut ubuf,
                        &mut vq[cbase..cbase + c * rb],
                        &mut vmin[pdst..pdst + d],
                        &mut vstep[pdst..pdst + d],
                    );
                }
            }
        }
        (kq, vq, kmin, kstep, vmin, vstep)
    }

    /// Build the decode-graph cache input tensors by gathering the block
    /// tables into the dense layout the graphs consume.
    /// f32 mode: [k_cache, v_cache]; simquant: [k_cache, v_cache, k_min,
    /// k_step, v_min, v_step] in graph input order. Sub-byte caches ship
    /// their packed code rows (`[L, B, CTX, packed_row_bytes]`).
    pub fn graph_inputs(&self) -> Vec<Tensor> {
        let (l, b, c, d) = (self.n_layers, self.batch, self.ctx, self.d);
        match self.mode {
            Mode::F32 => {
                let (k, v) = self.dense_f32();
                vec![
                    Tensor::from_f32_slice(vec![l, b, c, d], &k),
                    Tensor::from_f32_slice(vec![l, b, c, d], &v),
                ]
            }
            Mode::SimQuant => {
                let (kq, vq, kmin, kstep, vmin, vstep) = self.dense_simquant();
                let expand = |params: &[f32]| Tensor::from_f32_slice(vec![l, b, 1, d], params);
                vec![
                    Tensor::from_u8_slice(vec![l, b, c, self.row_bytes], &kq),
                    Tensor::from_u8_slice(vec![l, b, c, self.row_bytes], &vq),
                    expand(&kmin),
                    expand(&kstep),
                    expand(&vmin),
                    expand(&vstep),
                ]
            }
        }
    }

    pub fn dtype(&self) -> DType {
        match self.mode {
            Mode::F32 => DType::F32,
            Mode::SimQuant => DType::U8,
        }
    }

    /// Build the decode-graph cache inputs as PJRT literals from the
    /// gathered dense pages. The gather (one pass over the mapped
    /// blocks) is the per-step cost the paged cache pays on the PJRT
    /// decode path, in exchange for prefix sharing and O(block)
    /// preemption.
    pub fn input_literals(&self) -> Result<Vec<Literal>> {
        let (l, b, c, d) = (self.n_layers, self.batch, self.ctx, self.d);
        let cache_shape = [l, b, c, d];
        let code_shape = [l, b, c, self.row_bytes];
        let param_shape = [l, b, 1, d];
        Ok(match self.mode {
            Mode::F32 => {
                let (k, v) = self.dense_f32();
                vec![
                    literal_from_raw(DType::F32, &cache_shape, f32_bytes(&k))?,
                    literal_from_raw(DType::F32, &cache_shape, f32_bytes(&v))?,
                ]
            }
            Mode::SimQuant => {
                let (kq, vq, kmin, kstep, vmin, vstep) = self.dense_simquant();
                vec![
                    literal_from_raw(DType::U8, &code_shape, &kq)?,
                    literal_from_raw(DType::U8, &code_shape, &vq)?,
                    literal_from_raw(DType::F32, &param_shape, f32_bytes(&kmin))?,
                    literal_from_raw(DType::F32, &param_shape, f32_bytes(&kstep))?,
                    literal_from_raw(DType::F32, &param_shape, f32_bytes(&vmin))?,
                    literal_from_raw(DType::F32, &param_shape, f32_bytes(&vstep))?,
                ]
            }
        })
    }

    /// Serialize one lane's resident blocks for migration. Each mapped
    /// logical block is copied whole (all layers, codes at packed width
    /// + params), in logical order — dead rows past `len` inside the
    /// last block travel too, which keeps the block byte-identical to
    /// the source (they are dead on arrival as well: `len` caps every
    /// read). Blocks reserved beyond the residency (decode budget) are
    /// not exported; the importer re-reserves from its own pool. The
    /// source lane is untouched: refcounts, retention, and length all
    /// stay, so the caller decides separately whether to release it.
    pub fn export_lane(&self, slot: usize) -> LaneExport {
        let t = self.lens[slot];
        let nb = if t == 0 { 0 } else { (t - 1) / self.block_size + 1 };
        let (l, bs, d, rb) = (self.n_layers, self.block_size, self.d, self.row_bytes);
        let mut ex = LaneExport {
            len: t,
            quantized: self.mode == Mode::SimQuant,
            bits: self.bits,
            n_layers: l,
            d,
            block_size: bs,
            n_lblocks: nb,
            k_f32: Vec::new(),
            v_f32: Vec::new(),
            k_q: Vec::new(),
            v_q: Vec::new(),
            k_min: Vec::new(),
            k_step: Vec::new(),
            v_min: Vec::new(),
            v_step: Vec::new(),
        };
        match self.mode {
            Mode::F32 => {
                ex.k_f32.reserve(l * nb * bs * d);
                ex.v_f32.reserve(l * nb * bs * d);
                for layer in 0..l {
                    for bi in 0..nb {
                        let block = self.tables[slot][bi];
                        let off = self.block_row_off(layer, block, 0);
                        ex.k_f32.extend_from_slice(&self.k_f32[off..off + bs * d]);
                        ex.v_f32.extend_from_slice(&self.v_f32[off..off + bs * d]);
                    }
                }
            }
            Mode::SimQuant => {
                ex.k_q.reserve(l * nb * bs * rb);
                ex.v_q.reserve(l * nb * bs * rb);
                ex.k_min.reserve(l * nb * d);
                ex.k_step.reserve(l * nb * d);
                ex.v_min.reserve(l * nb * d);
                ex.v_step.reserve(l * nb * d);
                for layer in 0..l {
                    for bi in 0..nb {
                        let block = self.tables[slot][bi];
                        let off = self.block_code_off(layer, block, 0);
                        ex.k_q.extend_from_slice(&self.k_q[off..off + bs * rb]);
                        ex.v_q.extend_from_slice(&self.v_q[off..off + bs * rb]);
                        let p = self.block_param_off(layer, block);
                        ex.k_min.extend_from_slice(&self.k_min[p..p + d]);
                        ex.k_step.extend_from_slice(&self.k_step[p..p + d]);
                        ex.v_min.extend_from_slice(&self.v_min[p..p + d]);
                        ex.v_step.extend_from_slice(&self.v_step[p..p + d]);
                    }
                }
            }
        }
        ex
    }

    /// Map a serialized lane into an empty, acquired lane of this cache
    /// (the receiving shard). Reserves the residency's blocks from the
    /// local pool and writes the exported codes + params verbatim at
    /// block granularity — no dequantize, no re-encode, so the imported
    /// lane decodes bit-identically to the source. Returns `false` when
    /// the free pool cannot cover the residency; any blocks already
    /// claimed stay mapped (the caller releases the lane to undo,
    /// mirroring [`KvCache::try_reserve`]). The export's geometry
    /// (layers, head dim, block size, bitwidth, mode) must match —
    /// shards in one fleet are built identically, so a mismatch is a
    /// construction bug, not a runtime condition.
    pub fn import_lane(&mut self, slot: usize, ex: &LaneExport) -> bool {
        assert_eq!(ex.quantized, self.mode == Mode::SimQuant, "import across cache modes");
        assert_eq!(ex.bits, self.bits, "import across code bitwidths");
        assert_eq!(ex.n_layers, self.n_layers, "import across layer counts");
        assert_eq!(ex.d, self.d, "import across head dims");
        assert_eq!(ex.block_size, self.block_size, "import across block sizes");
        assert!(ex.len <= self.ctx, "imported lane past ctx");
        assert!(
            self.tables[slot].is_empty() && self.lens[slot] == 0,
            "import into a dirty slot"
        );
        if !self.try_reserve(slot, ex.len) {
            return false;
        }
        let (bs, d, rb, nb) = (self.block_size, self.d, self.row_bytes, ex.n_lblocks);
        for layer in 0..self.n_layers {
            for bi in 0..nb {
                let block = self.tables[slot][bi];
                let src = (layer * nb + bi) * bs;
                match self.mode {
                    Mode::F32 => {
                        let off = self.block_row_off(layer, block, 0);
                        self.k_f32[off..off + bs * d]
                            .copy_from_slice(&ex.k_f32[src * d..(src + bs) * d]);
                        self.v_f32[off..off + bs * d]
                            .copy_from_slice(&ex.v_f32[src * d..(src + bs) * d]);
                    }
                    Mode::SimQuant => {
                        let off = self.block_code_off(layer, block, 0);
                        self.k_q[off..off + bs * rb]
                            .copy_from_slice(&ex.k_q[src * rb..(src + bs) * rb]);
                        self.v_q[off..off + bs * rb]
                            .copy_from_slice(&ex.v_q[src * rb..(src + bs) * rb]);
                        let p = self.block_param_off(layer, block);
                        let ps = (layer * nb + bi) * d;
                        self.k_min[p..p + d].copy_from_slice(&ex.k_min[ps..ps + d]);
                        self.k_step[p..p + d].copy_from_slice(&ex.k_step[ps..ps + d]);
                        self.v_min[p..p + d].copy_from_slice(&ex.v_min[ps..ps + d]);
                        self.v_step[p..p + d].copy_from_slice(&ex.v_step[ps..ps + d]);
                    }
                }
            }
        }
        self.lens[slot] = ex.len;
        true
    }
}

/// Encode a `[t_len, D]` page: params per channel, codes written row by
/// row (bit-packed below 8 bits, `row_bytes` per row). `scratch` stages
/// the unpacked codes for sub-byte pages and is untouched at 8 bits.
#[allow(clippy::too_many_arguments)]
fn encode_page_packed(
    rows: &[f32],
    t_len: usize,
    d: usize,
    bits: u32,
    row_bytes: usize,
    codes: &mut [u8],
    vmin: &mut [f32],
    step: &mut [f32],
    scratch: &mut Vec<u8>,
) {
    if bits == 8 {
        simquant_encode_into(rows, t_len, d, 8, codes, vmin, step)
            .expect("simquant encode (bits=8, sized buffers) cannot fail");
        return;
    }
    scratch.clear();
    scratch.resize(t_len * d, 0);
    simquant_encode_into(rows, t_len, d, bits, scratch, vmin, step)
        .expect("simquant encode (sized buffers) cannot fail");
    pack_rows(scratch, t_len, d, bits, row_bytes, codes);
}

/// Encode rows `[t_len, D]` into page positions `t0..t0 + t_len`.
///
/// `t0 == 0` is a fresh page encode (params fitted to the rows). For
/// `t0 > 0` — resuming a chunked prefill mid-block — the page's first
/// `t0` rows were encoded by earlier chunks under the current `(vmin,
/// step)`: when every new row fits that range, the new rows are encoded
/// with the existing params; otherwise the old rows are decoded, the
/// per-channel range recomputed over old + new, and the whole page
/// re-encoded — the decode append path's widening, amortized to at most
/// once per chunk. `codes` must cover rows `0..t0 + t_len`.
#[allow(clippy::too_many_arguments)]
fn resume_page_packed(
    rows: &[f32],
    t0: usize,
    t_len: usize,
    d: usize,
    bits: u32,
    row_bytes: usize,
    codes: &mut [u8],
    vmin: &mut [f32],
    step: &mut [f32],
    fscratch: &mut Vec<f32>,
    cscratch: &mut Vec<u8>,
) {
    if t0 == 0 {
        encode_page_packed(rows, t_len, d, bits, row_bytes, codes, vmin, step, cscratch);
        return;
    }
    let levels = ((1u32 << bits) - 1) as f32;
    let in_range = rows.chunks_exact(d).take(t_len).all(|row| {
        row.iter().zip(vmin.iter().zip(step.iter())).all(|(v, (mn, st))| {
            let hi = mn + st * levels;
            *v >= mn - 1e-9 && *v <= hi + 1e-9
        })
    });
    if in_range {
        for (r, row) in rows.chunks_exact(d).take(t_len).enumerate() {
            let off = (t0 + r) * row_bytes;
            if bits == 8 {
                simquant_encode_with_params_into(
                    row,
                    vmin,
                    step,
                    levels,
                    &mut codes[off..off + d],
                );
            } else {
                cscratch.clear();
                cscratch.resize(d, 0);
                simquant_encode_with_params_into(row, vmin, step, levels, cscratch);
                pack_u8_into(cscratch, bits, &mut codes[off..off + row_bytes])
                    .expect("sized packed row");
            }
        }
        return;
    }
    // widen: decode the earlier chunks' rows, append the new ones, and
    // re-encode the union as one fresh page
    fscratch.clear();
    fscratch.resize((t0 + t_len) * d, 0.0);
    if bits == 8 {
        simquant_decode_into(&codes[..t0 * d], vmin, step, t0, d, &mut fscratch[..t0 * d]);
    } else {
        cscratch.clear();
        cscratch.resize(t0 * d, 0);
        unpack_rows(&codes[..t0 * row_bytes], t0, d, bits, row_bytes, cscratch);
        simquant_decode_into(cscratch, vmin, step, t0, d, &mut fscratch[..t0 * d]);
    }
    fscratch[t0 * d..].copy_from_slice(&rows[..t_len * d]);
    encode_page_packed(fscratch, t0 + t_len, d, bits, row_bytes, codes, vmin, step, cscratch);
}

/// Pack `t` unpacked code rows ([t, d] u8) into `row_bytes`-wide packed
/// rows — the single site for the page row layout (see also
/// [`unpack_rows`]).
fn pack_rows(ucodes: &[u8], t: usize, d: usize, bits: u32, row_bytes: usize, codes: &mut [u8]) {
    for (r, urow) in ucodes.chunks_exact(d).take(t).enumerate() {
        pack_u8_into(urow, bits, &mut codes[r * row_bytes..(r + 1) * row_bytes])
            .expect("sized packed row");
    }
}

/// Inverse of [`pack_rows`]: unpack `t` packed rows into [t, d] u8 codes.
fn unpack_rows(codes: &[u8], t: usize, d: usize, bits: u32, row_bytes: usize, ucodes: &mut [u8]) {
    for r in 0..t {
        unpack_u8_into(
            &codes[r * row_bytes..(r + 1) * row_bytes],
            bits,
            &mut ucodes[r * d..(r + 1) * d],
        )
        .expect("sized packed row");
    }
}

/// Split `buf` into one `page`-sized mutable block per index in `idxs`
/// (strictly ascending); the blocks are disjoint, so they can fan out
/// across pool tasks.
fn carve<'a, T>(mut buf: &'a mut [T], idxs: &[usize], page: usize) -> Vec<&'a mut [T]> {
    let mut out = Vec::with_capacity(idxs.len());
    let mut pos = 0usize;
    for &i in idxs {
        let start = i * page;
        debug_assert!(start >= pos, "indices must be sorted");
        let (_, rest) = buf.split_at_mut(start - pos);
        let (block, rest) = rest.split_at_mut(page);
        out.push(block);
        buf = rest;
        pos = start + page;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::XorShift64Star;

    fn rows(t: usize, d: usize, seed: u64, scale: f32) -> Vec<f32> {
        let mut r = XorShift64Star::new(seed);
        (0..t * d).map(|_| r.next_normal() as f32 * scale).collect()
    }

    #[test]
    fn f32_roundtrip() {
        let mut kv = KvCache::new_f32(2, 1, 8, 4);
        let k = rows(3, 4, 1, 1.0);
        let v = rows(3, 4, 2, 1.0);
        for layer in 0..2 {
            kv.ingest_prefill(0, layer, &k, &v, 3);
        }
        assert_eq!(kv.len(0), 3);
        assert_eq!(kv.decode_k(0, 1), k);
    }

    #[test]
    fn simquant_roundtrip_bounded() {
        let mut kv = KvCache::new_simquant(1, 1, 16, 8);
        let k = rows(5, 8, 3, 2.0);
        let v = rows(5, 8, 4, 2.0);
        kv.ingest_prefill(0, 0, &k, &v, 5);
        let dk = kv.decode_k(0, 0);
        for (a, b) in k.iter().zip(&dk) {
            assert!((a - b).abs() < 0.05, "{a} vs {b}");
        }
    }

    #[test]
    fn prefill_encode_matches_reference_kernel() {
        // the in-place page encode must be bit-identical to the pinned
        // scalar reference (same codes, same params)
        let (t, d) = (6, 8);
        let k = rows(t, d, 9, 1.5);
        let mut kv = KvCache::new_simquant(1, 1, 16, d);
        kv.ingest_prefill(0, 0, &k, &k, t);
        let (rq, rmin, rstep) = crate::quant::reference::simquant_encode(&k, t, d, 8);
        let ins = kv.graph_inputs();
        assert_eq!(&ins[0].u8_view().unwrap()[..t * d], &rq[..]);
        assert_eq!(&ins[2].f32_view().unwrap()[..d], &rmin[..]);
        assert_eq!(&ins[3].f32_view().unwrap()[..d], &rstep[..]);
    }

    #[test]
    fn packed_page_roundtrip_matches_unpacked_codes() {
        // 4-bit page: decode must reproduce exactly what the unpacked
        // 4-bit reference codes decode to (packing is lossless on codes)
        let (t, d) = (5, 7); // ragged: row_bytes = 4, last nibble padding
        let k = rows(t, d, 21, 1.0);
        let mut kv = KvCache::new_simquant_bits(1, 1, 8, d, 4);
        kv.ingest_prefill(0, 0, &k, &k, t);
        let (rq, rmin, rstep) = crate::quant::reference::simquant_encode(&k, t, d, 4);
        let expect: Vec<f32> = rq
            .iter()
            .enumerate()
            .map(|(j, q)| *q as f32 * rstep[j % d] + rmin[j % d])
            .collect();
        assert_eq!(kv.decode_k(0, 0), expect);
    }

    #[test]
    fn packed_append_and_reencode_stay_bounded() {
        let mut kv = KvCache::new_simquant_bits(1, 1, 16, 4, 4);
        let k = vec![0.1, 0.1, 0.1, 0.1, 0.2, 0.2, 0.2, 0.2];
        kv.ingest_prefill(0, 0, &k, &k, 2);
        let big = [5.0, -4.0, 3.0, 7.0];
        kv.append_row(0, 0, &big, &big);
        kv.bump(0);
        assert!(kv.reencodes > 0);
        let dk = kv.decode_k(0, 0);
        // 4-bit steps are coarse after widening to ~11.0: step ~ 0.74
        for (a, b) in big.iter().zip(&dk[8..]) {
            assert!((a - b).abs() < 0.5, "{a} vs {b}");
        }
    }

    #[test]
    fn packed_storage_is_half_of_8bit_and_8x_under_f32() {
        let f = KvCache::new_f32(2, 4, 64, 32);
        let q8 = KvCache::new_simquant(2, 4, 64, 32);
        let q4 = KvCache::new_simquant_bits(2, 4, 64, 32, 4);
        let q2 = KvCache::new_simquant_bits(2, 4, 64, 32, 2);
        let codes8 = q8.storage_bytes();
        let codes4 = q4.storage_bytes();
        let codes2 = q2.storage_bytes();
        assert!(codes4 < codes8 && codes2 < codes4);
        let ratio4 = codes4 as f64 / f.storage_bytes() as f64;
        assert!(ratio4 < 0.16, "4-bit ratio {ratio4}");
        let ratio2 = codes2 as f64 / f.storage_bytes() as f64;
        assert!(ratio2 < 0.10, "2-bit ratio {ratio2}");
    }

    #[test]
    fn batch_ingest_matches_serial_ingest() {
        let (l, b, ctx, d) = (3usize, 2usize, 8usize, 16usize);
        for bits in [8u32, 4] {
            let mut serial = KvCache::new_simquant_bits(l, b, ctx, d, bits);
            let mut batch = KvCache::new_simquant_bits(l, b, ctx, d, bits);
            let data: Vec<(usize, usize, Vec<f32>, Vec<f32>, usize)> = (0..l)
                .flat_map(|layer| {
                    (0..b).map(move |slot| {
                        let t = 3 + slot;
                        let seed = (layer * 10 + slot) as u64;
                        (slot, layer, rows(t, d, seed, 1.0), rows(t, d, seed + 99, 1.0), t)
                    })
                })
                .collect();
            for (slot, layer, k, v, t) in &data {
                serial.ingest_prefill(*slot, *layer, k, v, *t);
            }
            let pages: Vec<PrefillPage<'_>> = data
                .iter()
                .map(|(slot, layer, k, v, t)| PrefillPage {
                    slot: *slot,
                    layer: *layer,
                    k_rows: k,
                    v_rows: v,
                    t0: 0,
                    t_len: *t,
                })
                .collect();
            batch.ingest_prefill_batch(&pages);
            for slot in 0..b {
                assert_eq!(serial.len(slot), batch.len(slot));
                for layer in 0..l {
                    assert_eq!(
                        serial.decode_k(slot, layer),
                        batch.decode_k(slot, layer),
                        "bits={bits} slot={slot} layer={layer}"
                    );
                }
            }
        }
    }

    #[test]
    fn f32_batch_ingest_matches_serial() {
        let (l, b, ctx, d) = (2usize, 2usize, 8usize, 4usize);
        let mut serial = KvCache::new_f32(l, b, ctx, d);
        let mut batch = KvCache::new_f32(l, b, ctx, d);
        let k = rows(5, d, 1, 1.0);
        let v = rows(5, d, 2, 1.0);
        let mut pages = Vec::new();
        for layer in 0..l {
            serial.ingest_prefill(1, layer, &k, &v, 5);
            pages.push(PrefillPage { slot: 1, layer, k_rows: &k, v_rows: &v, t0: 0, t_len: 5 });
        }
        batch.ingest_prefill_batch(&pages);
        for layer in 0..l {
            assert_eq!(serial.decode_k(1, layer), batch.decode_k(1, layer));
        }
    }

    #[test]
    #[should_panic(expected = "duplicate")]
    fn batch_ingest_rejects_duplicate_pages() {
        let mut kv = KvCache::new_f32(1, 1, 4, 2);
        let k = vec![0.0; 4];
        let pages = vec![
            PrefillPage { slot: 0, layer: 0, k_rows: &k, v_rows: &k, t0: 0, t_len: 2 },
            PrefillPage { slot: 0, layer: 0, k_rows: &k, v_rows: &k, t0: 0, t_len: 2 },
        ];
        kv.ingest_prefill_batch(&pages);
    }

    #[test]
    fn f32_chunked_ingest_matches_whole() {
        let (t, d) = (6usize, 4usize);
        let k = rows(t, d, 31, 1.0);
        let v = rows(t, d, 32, 1.0);
        let mut whole = KvCache::new_f32(1, 1, 8, d);
        whole.ingest_prefill(0, 0, &k, &v, t);
        let mut chunked = KvCache::new_f32(1, 1, 8, d);
        chunked.ingest_prefill_at(0, 0, 0, &k[..2 * d], &v[..2 * d], 2);
        chunked.ingest_prefill_at(0, 0, 2, &k[2 * d..], &v[2 * d..], 4);
        assert_eq!(chunked.len(0), t);
        assert_eq!(whole.decode_k(0, 0), chunked.decode_k(0, 0));
    }

    #[test]
    fn simquant_resume_within_range_keeps_params() {
        for bits in [8u32, 4] {
            let d = 8usize;
            let mut kv = KvCache::new_simquant_bits(1, 1, 16, d, bits);
            // first chunk spans [-4, 4] on every channel, so the smaller
            // resume rows are guaranteed in range
            let mut first = vec![0.5f32; 3 * d];
            first[..d].fill(-4.0);
            first[d..2 * d].fill(4.0);
            let second: Vec<f32> = rows(2, d, 42, 0.5)
                .into_iter()
                .map(|x| x.clamp(-2.0, 2.0))
                .collect();
            kv.ingest_prefill_at(0, 0, 0, &first, &first, 3);
            let params_before = kv.graph_inputs()[2].f32_view().unwrap().to_vec();
            kv.ingest_prefill_at(0, 0, 3, &second, &second, 2);
            let params_after = kv.graph_inputs()[2].f32_view().unwrap().to_vec();
            assert_eq!(params_before, params_after, "bits={bits}: in-range resume re-fit");
            assert_eq!(kv.len(0), 5);
            // reconstruction bounded by half a step over the [-4, 4] range
            let tol = 0.5 * 8.0 / (((1u32 << bits) - 1) as f32) + 1e-3;
            let dk = kv.decode_k(0, 0);
            for (a, b) in second.iter().zip(&dk[3 * d..]) {
                assert!((a - b).abs() <= tol, "bits={bits}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn simquant_resume_widens_out_of_range_chunk() {
        let d = 4usize;
        let mut kv = KvCache::new_simquant(1, 1, 16, d);
        let first = vec![0.1, 0.1, 0.1, 0.1, 0.2, 0.2, 0.2, 0.2];
        kv.ingest_prefill_at(0, 0, 0, &first, &first, 2);
        // second chunk far outside the first chunk's range
        let second = vec![5.0, -4.0, 3.0, 7.0];
        kv.ingest_prefill_at(0, 0, 2, &second, &second, 1);
        let dk = kv.decode_k(0, 0);
        // old rows survive the widening within the widened step bound
        for (a, b) in first.iter().zip(&dk[..2 * d]) {
            assert!((a - b).abs() < 0.05, "{a} vs {b}");
        }
        for (a, b) in second.iter().zip(&dk[2 * d..]) {
            assert!((a - b).abs() < 0.05, "{a} vs {b}");
        }
    }

    #[test]
    fn batch_resume_matches_serial_resume() {
        let (l, b, ctx, d) = (2usize, 2usize, 16usize, 8usize);
        for bits in [8u32, 4] {
            let mut serial = KvCache::new_simquant_bits(l, b, ctx, d, bits);
            let mut batch = KvCache::new_simquant_bits(l, b, ctx, d, bits);
            let chunk1: Vec<(usize, usize, Vec<f32>, Vec<f32>)> = (0..l)
                .flat_map(|layer| {
                    (0..b).map(move |slot| {
                        let seed = (layer * 10 + slot) as u64;
                        (slot, layer, rows(3, d, seed, 1.0), rows(3, d, seed + 50, 1.0))
                    })
                })
                .collect();
            let chunk2: Vec<(usize, usize, Vec<f32>, Vec<f32>)> = (0..l)
                .flat_map(|layer| {
                    (0..b).map(move |slot| {
                        let seed = 777 + (layer * 10 + slot) as u64;
                        // mix of in-range and widening chunks
                        let scale = if slot == 0 { 0.5 } else { 3.0 };
                        (slot, layer, rows(2, d, seed, scale), rows(2, d, seed + 50, scale))
                    })
                })
                .collect();
            for cache in [&mut serial, &mut batch] {
                let pages: Vec<PrefillPage<'_>> = chunk1
                    .iter()
                    .map(|(slot, layer, k, v)| PrefillPage {
                        slot: *slot,
                        layer: *layer,
                        k_rows: k,
                        v_rows: v,
                        t0: 0,
                        t_len: 3,
                    })
                    .collect();
                cache.ingest_prefill_batch(&pages);
            }
            for (slot, layer, k, v) in &chunk2 {
                serial.ingest_prefill_at(*slot, *layer, 3, k, v, 2);
            }
            let pages: Vec<PrefillPage<'_>> = chunk2
                .iter()
                .map(|(slot, layer, k, v)| PrefillPage {
                    slot: *slot,
                    layer: *layer,
                    k_rows: k,
                    v_rows: v,
                    t0: 3,
                    t_len: 2,
                })
                .collect();
            batch.ingest_prefill_batch(&pages);
            for slot in 0..b {
                assert_eq!(serial.len(slot), batch.len(slot));
                assert_eq!(serial.len(slot), 5);
                for layer in 0..l {
                    assert_eq!(
                        serial.decode_k(slot, layer),
                        batch.decode_k(slot, layer),
                        "bits={bits} slot={slot} layer={layer}"
                    );
                }
            }
        }
    }

    #[test]
    fn append_within_range_no_reencode() {
        let mut kv = KvCache::new_simquant(1, 1, 16, 4);
        // wide prefill range so appended rows stay inside
        let k = vec![-10.0, -10.0, -10.0, -10.0, 10.0, 10.0, 10.0, 10.0];
        kv.ingest_prefill(0, 0, &k, &k, 2);
        kv.append_row(0, 0, &[1.0, 2.0, -3.0, 0.5], &[0.0, 0.0, 0.0, 0.0]);
        kv.bump(0);
        assert_eq!(kv.reencodes, 0);
        assert_eq!(kv.len(0), 3);
    }

    #[test]
    fn out_of_range_append_triggers_reencode_and_stays_accurate() {
        let mut kv = KvCache::new_simquant(1, 1, 16, 4);
        let k = vec![0.1, 0.1, 0.1, 0.1, 0.2, 0.2, 0.2, 0.2];
        kv.ingest_prefill(0, 0, &k, &k, 2);
        let big = [5.0, -4.0, 3.0, 7.0];
        kv.append_row(0, 0, &big, &big);
        kv.bump(0);
        assert!(kv.reencodes > 0);
        let dk = kv.decode_k(0, 0);
        // old rows still reconstruct within the widened step bound
        for (a, b) in k.iter().zip(&dk[..8]) {
            assert!((a - b).abs() < 0.05, "{a} vs {b}");
        }
        for (a, b) in big.iter().zip(&dk[8..]) {
            assert!((a - b).abs() < 0.05, "{a} vs {b}");
        }
    }

    #[test]
    fn storage_is_quarter_of_f32() {
        let f = KvCache::new_f32(2, 4, 64, 32);
        let q = KvCache::new_simquant(2, 4, 64, 32);
        let ratio = q.storage_bytes() as f64 / f.storage_bytes() as f64;
        assert!(ratio < 0.3, "ratio {ratio}");
    }

    #[test]
    fn reset_slot_clears() {
        let mut kv = KvCache::new_simquant(1, 2, 8, 4);
        let k = rows(4, 4, 5, 1.0);
        kv.ingest_prefill(1, 0, &k, &k, 4);
        kv.reset_slot(1);
        assert_eq!(kv.len(1), 0);
    }

    #[test]
    fn slot_free_list_acquire_release_reuse() {
        let mut kv = KvCache::new_simquant(1, 3, 8, 4);
        assert_eq!(kv.free_slots(), 3);
        // lowest-first, deterministic
        assert_eq!(kv.acquire_slot(), Some(0));
        assert_eq!(kv.acquire_slot(), Some(1));
        assert_eq!(kv.acquire_slot(), Some(2));
        assert_eq!(kv.acquire_slot(), None);
        let k = rows(2, 4, 7, 1.0);
        kv.ingest_prefill(1, 0, &k, &k, 2);
        kv.release_slot(1);
        assert_eq!(kv.free_slots(), 1);
        assert_eq!(kv.len(1), 0);
        // released slot is handed out again
        assert_eq!(kv.acquire_slot(), Some(1));
    }

    #[test]
    fn release_slot_scrubs_pages() {
        let mut kv = KvCache::new_f32(1, 2, 4, 2);
        let k = vec![1.0, 2.0, 3.0, 4.0];
        kv.ingest_prefill(0, 0, &k, &k, 2);
        assert_eq!(kv.acquire_slot(), Some(0));
        kv.release_slot(0);
        // the next occupant must not see the retired request's rows
        let ins = kv.graph_inputs();
        assert!(ins[0].f32_view().unwrap().iter().all(|x| *x == 0.0));
    }

    #[test]
    fn graph_inputs_shapes() {
        let kv = KvCache::new_simquant(2, 3, 8, 4);
        let ins = kv.graph_inputs();
        assert_eq!(ins.len(), 6);
        assert_eq!(ins[0].shape, vec![2, 3, 8, 4]);
        assert_eq!(ins[2].shape, vec![2, 3, 1, 4]);
        // sub-byte caches ship packed rows
        let kv4 = KvCache::new_simquant_bits(2, 3, 8, 4, 4);
        assert_eq!(kv4.graph_inputs()[0].shape, vec![2, 3, 8, 2]);
        let f = KvCache::new_f32(2, 3, 8, 4);
        assert_eq!(f.graph_inputs().len(), 2);
    }

    #[test]
    #[should_panic(expected = "KV overflow")]
    fn overflow_panics() {
        let mut kv = KvCache::new_f32(1, 1, 2, 2);
        kv.ingest_prefill(0, 0, &[0.0; 4], &[0.0; 4], 2);
        kv.append_row(0, 0, &[1.0, 1.0], &[1.0, 1.0]);
    }

    // ---- paged-allocator tests ----

    #[test]
    fn block_pool_hands_out_lowest_first() {
        let mut kv = KvCache::new_f32_paged(1, 2, 8, 2, 2, 3);
        assert_eq!(kv.block_size(), 2);
        assert_eq!(kv.total_blocks(), 3);
        assert_eq!(kv.free_block_count(), 3);
        let s = kv.acquire_slot().unwrap();
        assert!(kv.try_reserve(s, 4));
        assert_eq!(kv.table(s), &[0, 1]);
        kv.release_slot(s);
        assert_eq!(kv.free_block_count(), 3);
        // released blocks are handed out again, lowest-first
        let s2 = kv.acquire_slot().unwrap();
        assert!(kv.try_reserve(s2, 2));
        assert_eq!(kv.table(s2), &[0]);
    }

    #[test]
    fn try_reserve_fails_on_exhausted_pool_and_release_restores() {
        let mut kv = KvCache::new_f32_paged(1, 2, 8, 2, 2, 3);
        let a = kv.acquire_slot().unwrap();
        let b = kv.acquire_slot().unwrap();
        assert!(kv.try_reserve(a, 4)); // 2 blocks
        assert!(!kv.try_reserve(b, 4)); // needs 2, only 1 free
        // the partial claim stays mapped; bouncing releases the lane
        assert_eq!(kv.free_block_count(), 0);
        kv.release_slot(b);
        assert_eq!(kv.free_block_count(), 1);
        kv.release_slot(a);
        assert_eq!(kv.free_block_count(), 3);
        assert_eq!(kv.free_slots(), 2);
    }

    #[test]
    fn truncate_rolls_back_appends_without_freeing_blocks() {
        // 1 layer, 2 slots, ctx 8, d 2, block 2, 4 blocks
        let mut kv = KvCache::new_f32_paged(1, 2, 8, 2, 2, 4);
        let s = kv.acquire_slot().unwrap();
        assert!(kv.try_reserve(s, 6)); // 3 blocks, the lane's full budget
        assert_eq!(kv.free_block_count(), 1);
        let committed = rows(2, 2, 21, 1.0);
        for t in 0..2 {
            kv.append_row(s, 0, &committed[t * 2..(t + 1) * 2], &committed[t * 2..(t + 1) * 2]);
            kv.bump(s);
        }
        let table = kv.table(s).to_vec();
        // three speculative rows land in the reserved blocks ...
        let draft = rows(3, 2, 22, 1.0);
        for t in 0..3 {
            kv.append_row(s, 0, &draft[t * 2..(t + 1) * 2], &draft[t * 2..(t + 1) * 2]);
            kv.bump(s);
        }
        assert_eq!(kv.len(s), 5);
        // ... and a full rejection rolls them back: pure table bookkeeping
        kv.truncate(s, 2);
        assert_eq!(kv.len(s), 2);
        assert_eq!(kv.table(s), &table[..], "rollback must not remap blocks");
        assert_eq!(kv.free_block_count(), 1, "rollback must not free blocks");
        assert_eq!(kv.decode_k(s, 0), committed, "committed rows survive rollback");
        // the next append overwrites the dead rows in place
        let fresh = rows(1, 2, 23, 1.0);
        kv.append_row(s, 0, &fresh, &fresh);
        kv.bump(s);
        assert_eq!(&kv.decode_k(s, 0)[4..], &fresh[..]);
        // drain: the pool balances, so nothing leaked
        kv.release_slot(s);
        assert_eq!(kv.free_block_count(), 4);
        assert_eq!(kv.free_slots(), 2);
        // shrink-only contract
        let r = std::panic::catch_unwind(|| {
            let mut kv2 = KvCache::new_f32_paged(1, 1, 4, 2, 2, 2);
            let s = kv2.acquire_slot().unwrap();
            kv2.truncate(s, 1);
        });
        assert!(r.is_err(), "growing via truncate must panic");
    }

    #[test]
    fn cow_fork_shares_then_copies_on_write() {
        let mut kv = KvCache::new_f32_paged(1, 2, 8, 2, 4, 4);
        let s = kv.acquire_slot().unwrap();
        let k = rows(4, 2, 11, 1.0);
        let v = rows(4, 2, 12, 1.0);
        kv.ingest_prefill(s, 0, &k, &v, 4);
        assert_eq!(kv.free_block_count(), 3);
        let f = kv.fork_slot(s).unwrap();
        // fork shares the block (no copy yet)
        assert_eq!(kv.free_block_count(), 3);
        assert_eq!(kv.table(s), kv.table(f));
        assert_eq!(kv.ref_count(kv.table(s)[0]), 2);
        assert_eq!(kv.decode_k(s, 0), kv.decode_k(f, 0));
        // writing through the fork copies the block and leaves the
        // original untouched
        let k2 = rows(2, 2, 13, 2.0);
        kv.ingest_prefill_at(f, 0, 0, &k2, &k2, 2);
        assert_ne!(kv.table(s)[0], kv.table(f)[0]);
        assert_eq!(kv.ref_count(kv.table(s)[0]), 1);
        assert_eq!(kv.ref_count(kv.table(f)[0]), 1);
        assert_eq!(kv.decode_k(s, 0), k);
        assert_eq!(&kv.decode_k(f, 0)[..4], &k2[..]);
        // drain: every block returns to the pool
        kv.release_slot(s);
        kv.release_slot(f);
        assert_eq!(kv.free_block_count(), 4);
        assert_eq!(kv.free_slots(), 2);
    }

    #[test]
    fn paged_matches_single_block_cache_across_block_sizes() {
        // same rows through a 4-token-block pool and a one-block-per-
        // slot pool: decode and gathered graph inputs are identical
        let (t, d, ctx) = (7usize, 4usize, 8usize);
        let k = rows(t, d, 41, 1.0);
        let v = rows(t, d, 42, 1.0);
        let mut small = KvCache::new_f32_paged(2, 1, ctx, d, 4, 4);
        let mut whole = KvCache::new_f32_paged(2, 1, ctx, d, ctx, 2);
        for kv in [&mut small, &mut whole] {
            for layer in 0..2 {
                kv.ingest_prefill(0, layer, &k, &v, t);
            }
        }
        for layer in 0..2 {
            assert_eq!(small.decode_k(0, layer), whole.decode_k(0, layer));
        }
        let (a, b) = (small.graph_inputs(), whole.graph_inputs());
        assert_eq!(a[0].f32_view().unwrap(), b[0].f32_view().unwrap());
        assert_eq!(a[1].f32_view().unwrap(), b[1].f32_view().unwrap());
    }

    #[test]
    fn mid_block_chunked_resume_matches_whole() {
        // chunk boundary at t=3 inside the first 4-token block: the
        // resume lands mid-block and must splice, not restart
        let (t, d, ctx, bs) = (6usize, 4usize, 8usize, 4usize);
        let k = rows(t, d, 51, 1.0);
        let v = rows(t, d, 52, 1.0);
        let mut whole = KvCache::new_f32_paged(1, 1, ctx, d, bs, 2);
        whole.ingest_prefill(0, 0, &k, &v, t);
        let mut chunked = KvCache::new_f32_paged(1, 1, ctx, d, bs, 2);
        chunked.ingest_prefill_at(0, 0, 0, &k[..3 * d], &v[..3 * d], 3);
        chunked.ingest_prefill_at(0, 0, 3, &k[3 * d..], &v[3 * d..], 3);
        assert_eq!(chunked.len(0), t);
        assert_eq!(whole.decode_k(0, 0), chunked.decode_k(0, 0));
    }

    #[test]
    fn simquant_mid_block_resume_in_range_matches_whole() {
        // first chunk carries the per-channel extremes, so the mid-block
        // resume encodes under identical block params → exact equality
        for bits in [8u32, 4] {
            let (d, ctx, bs) = (4usize, 8usize, 4usize);
            let mut data = vec![0.5f32; 6 * d];
            data[..d].fill(-4.0);
            data[d..2 * d].fill(4.0);
            for x in &mut data[3 * d..] {
                *x = 1.25;
            }
            let mut whole = KvCache::new_simquant_bits_paged(1, 1, ctx, d, bits, bs, 2);
            whole.ingest_prefill(0, 0, &data, &data, 6);
            let mut chunked = KvCache::new_simquant_bits_paged(1, 1, ctx, d, bits, bs, 2);
            chunked.ingest_prefill_at(0, 0, 0, &data[..3 * d], &data[..3 * d], 3);
            chunked.ingest_prefill_at(0, 0, 3, &data[3 * d..], &data[3 * d..], 3);
            assert_eq!(
                whole.decode_k(0, 0),
                chunked.decode_k(0, 0),
                "bits={bits}"
            );
        }
    }

    #[test]
    fn retained_blocks_survive_release_and_evict() {
        let mut kv = KvCache::new_f32_paged(1, 1, 8, 2, 4, 2);
        let s = kv.acquire_slot().unwrap();
        let k = rows(4, 2, 61, 1.0);
        kv.ingest_prefill(s, 0, &k, &k, 4);
        let block = kv.table(s)[0];
        kv.retain_block(block);
        kv.release_slot(s);
        // refcount 0 but retained: stays out of the free pool
        assert_eq!(kv.ref_count(block), 0);
        assert_eq!(kv.free_block_count(), 1);
        assert_eq!(kv.retained_count(), 1);
        // a prefix hit re-maps the retained block with its rows intact
        let s2 = kv.acquire_slot().unwrap();
        kv.attach_cached_blocks(s2, &[block], 4);
        assert_eq!(kv.ref_count(block), 1);
        assert_eq!(kv.decode_k(s2, 0), k);
        kv.release_slot(s2);
        // eviction scrubs and returns it
        kv.free_retained_block(block);
        assert!(!kv.is_retained(block));
        assert_eq!(kv.retained_count(), 0);
        assert_eq!(kv.free_block_count(), 2);
    }

    #[test]
    fn graph_inputs_union_covers_mixed_block_params() {
        // two blocks with very different ranges: the dense gather must
        // re-encode under the per-channel union so one param row covers
        // both blocks' rows
        let (d, ctx, bs) = (4usize, 8usize, 4usize);
        let mut kv = KvCache::new_simquant_bits_paged(1, 1, ctx, d, 8, bs, 2);
        let narrow = rows(4, d, 71, 0.5);
        let wide = rows(4, d, 72, 4.0);
        kv.ingest_prefill_at(0, 0, 0, &narrow, &narrow, 4);
        kv.ingest_prefill_at(0, 0, 4, &wide, &wide, 4);
        let ins = kv.graph_inputs();
        let codes = ins[0].u8_view().unwrap();
        let vmin = ins[2].f32_view().unwrap();
        let vstep = ins[3].f32_view().unwrap();
        let expect: Vec<f32> = narrow.iter().chain(&wide).copied().collect();
        for (i, e) in expect.iter().enumerate() {
            let ch = i % d;
            let got = codes[i] as f32 * vstep[ch] + vmin[ch];
            // union step over a ~[-16, 16] range plus the first
            // quantization's error
            assert!((got - e).abs() < 0.2, "row {i}: {got} vs {e}");
        }
    }

    #[test]
    fn export_import_roundtrip_is_bit_identical() {
        // a lane shipped as packed pages must decode on the importer to
        // exactly the bytes the source decodes to — per layer, per bits
        for bits in [8u32, 4, 2] {
            let (l, d, ctx, bs) = (2usize, 8usize, 16usize, 4usize);
            let mut src = KvCache::new_simquant_bits_paged(l, 2, ctx, d, bits, bs, 8);
            let s = src.acquire_slot().unwrap();
            let k = rows(6, d, 91, 1.5);
            let v = rows(6, d, 92, 1.5);
            for layer in 0..l {
                src.ingest_prefill(s, layer, &k, &v, 6);
            }
            let ex = src.export_lane(s);
            assert_eq!(ex.len(), 6);
            assert_eq!(ex.code_bits(), bits);
            let mut dst = KvCache::new_simquant_bits_paged(l, 2, ctx, d, bits, bs, 8);
            let t = dst.acquire_slot().unwrap();
            assert!(dst.import_lane(t, &ex));
            assert_eq!(dst.len(t), 6);
            for layer in 0..l {
                assert_eq!(src.decode_k(s, layer), dst.decode_k(t, layer), "bits={bits}");
            }
            // the continuation stays identical too: the same appended row
            // encodes to the same codes under the copied params
            let row = rows(1, d, 93, 1.0);
            for layer in 0..l {
                src.append_row(s, layer, &row, &row);
                dst.append_row(t, layer, &row, &row);
            }
            src.bump(s);
            dst.bump(t);
            for layer in 0..l {
                assert_eq!(src.decode_k(s, layer), dst.decode_k(t, layer), "bits={bits}");
            }
            // source lane untouched by the export (copy semantics)
            assert_eq!(src.len(s), 7);
        }
    }

    #[test]
    fn f32_export_import_roundtrip() {
        let mut src = KvCache::new_f32_paged(2, 1, 8, 4, 4, 4);
        let s = src.acquire_slot().unwrap();
        let k = rows(5, 4, 95, 1.0);
        for layer in 0..2 {
            src.ingest_prefill(s, layer, &k, &k, 5);
        }
        let ex = src.export_lane(s);
        assert!(!ex.is_empty());
        let (codes, params) = ex.wire_segments();
        assert!(codes.is_empty(), "f32 lanes travel as raw rows");
        assert_eq!(params.len(), 2);
        let mut dst = KvCache::new_f32_paged(2, 1, 8, 4, 4, 4);
        let t = dst.acquire_slot().unwrap();
        assert!(dst.import_lane(t, &ex));
        for layer in 0..2 {
            assert_eq!(src.decode_k(s, layer), dst.decode_k(t, layer));
        }
    }

    #[test]
    fn export_wire_bytes_shrink_with_bitwidth() {
        let mk = |bits| {
            let mut kv = KvCache::new_simquant_bits_paged(2, 1, 16, 8, bits, 4, 8);
            let s = kv.acquire_slot().unwrap();
            let k = rows(8, 8, 97, 1.0);
            for layer in 0..2 {
                kv.ingest_prefill(s, layer, &k, &k, 8);
            }
            kv.export_lane(s).wire_bytes()
        };
        let (b8, b4, b2) = (mk(8), mk(4), mk(2));
        assert!(b4 < b8 && b2 < b4, "packed widths must ship packed: {b8} {b4} {b2}");
    }

    #[test]
    fn export_import_balances_refcounts_with_shared_prefix() {
        // exporting a lane that maps a shared retained block must not
        // disturb the source's COW state, and the importer's blocks are
        // private — both pools balance after release
        let (d, ctx, bs) = (2usize, 8usize, 4usize);
        let mut src = KvCache::new_f32_paged(1, 2, ctx, d, bs, 4);
        let a = src.acquire_slot().unwrap();
        let k = rows(6, d, 98, 1.0);
        src.ingest_prefill(a, 0, &k, &k, 6);
        let shared = src.table(a)[0];
        src.retain_block(shared);
        let ex = src.export_lane(a);
        assert_eq!(src.ref_count(shared), 1, "export must not touch refcounts");
        let mut dst = KvCache::new_f32_paged(1, 2, ctx, d, bs, 4);
        let t = dst.acquire_slot().unwrap();
        assert!(dst.import_lane(t, &ex));
        assert_eq!(dst.decode_k(t, 0), k);
        src.release_slot(a);
        dst.release_slot(t);
        assert_eq!(
            src.free_block_count() + src.retained_count(),
            src.total_blocks(),
            "source pool must balance (retained prefix stays)"
        );
        assert_eq!(dst.free_block_count(), dst.total_blocks());
    }

    #[test]
    fn import_fails_cleanly_on_exhausted_pool() {
        let mut src = KvCache::new_f32_paged(1, 1, 16, 2, 4, 4);
        let s = src.acquire_slot().unwrap();
        let k = rows(12, 2, 99, 1.0);
        src.ingest_prefill(s, 0, &k, &k, 12);
        let ex = src.export_lane(s);
        // destination pool has 2 blocks; the lane needs 3
        let mut dst = KvCache::new_f32_paged(1, 1, 16, 2, 4, 2);
        let t = dst.acquire_slot().unwrap();
        assert!(!dst.import_lane(t, &ex));
        // claimed blocks stay mapped; releasing the lane restores them
        dst.release_slot(t);
        assert_eq!(dst.free_block_count(), 2);
        assert_eq!(dst.free_slots(), 1);
    }

    #[test]
    fn attach_skips_reprefill_positions() {
        // attaching a cached block starts the lane mid-prompt: only the
        // tail needs prefill, and the decode matches a cold lane
        let (d, ctx, bs) = (2usize, 8usize, 4usize);
        let k = rows(6, d, 81, 1.0);
        let mut cold = KvCache::new_f32_paged(1, 2, ctx, d, bs, 4);
        let a = cold.acquire_slot().unwrap();
        cold.ingest_prefill(a, 0, &k, &k, 6);
        let shared = cold.table(a)[0];
        cold.retain_block(shared);
        cold.release_slot(a);
        let b = cold.acquire_slot().unwrap();
        cold.attach_cached_blocks(b, &[shared], 4);
        assert_eq!(cold.len(b), 4);
        cold.ingest_prefill_at(b, 0, 4, &k[4 * d..], &k[4 * d..], 2);
        assert_eq!(cold.decode_k(b, 0), k);
    }
}
