//! KV-cache manager: batch-slot cache buffers, fp32 or SimQuant-compressed.
//!
//! Layout matches the decode graphs' inputs: `[L, B, CTX, D]` caches plus,
//! for SimQuant, per-(layer, slot) channel params `[L, B, 1, D]`.
//!
//! SimQuant mode implements the paper's online KV quantization (§3.4):
//! each (layer, slot) page carries per-channel (vmin, step); appending a
//! row that falls outside the page's range triggers an in-place page
//! re-encode (dequantize codes, widen range, requantize) — the runtime
//! adaptation that keeps Thm. A.2's bound tight as the sequence grows.
//!
//! Pages can store sub-byte codes bit-packed
//! ([`KvCache::new_simquant_bits`] with 4 or 2 bits): each row occupies
//! `packed_len(D, bits)` bytes, so `storage_bytes` reports the true
//! 8x/16x ratio vs f32 instead of one byte per code. At 8 bits the page
//! layout is byte-for-byte the unpacked one. Sub-byte graph inputs ship
//! the packed rows (shape `[L, B, CTX, packed_row_bytes]`); the lowered
//! graphs consuming that wire format are future work — the serving
//! decode path runs at 8 bits.
//!
//! Hot-path contract: prefill ingestion encodes through
//! `quant::kernels::simquant_encode_into` straight into the cache's own
//! code/param pages (no staging vectors) — and fans disjoint (slot,
//! layer) pages out across the worker pool via
//! [`KvCache::ingest_prefill_batch`]; page re-encodes run on reused
//! scratch buffers, and `input_literals` builds PJRT literals directly
//! from the cache buffers — one copy per decode step, total.
//!
//! Chunked prefill resumes ingestion mid-prompt
//! ([`KvCache::ingest_prefill_at`] / `PrefillPage.t0`): later chunks
//! encode under the params fitted to the earlier ones, widening the page
//! range at most once per chunk when a row escapes it.

use anyhow::Result;

use crate::quant::kernels::{
    pack_u8_into, packed_len, simquant_decode_into, simquant_encode_into,
    simquant_encode_with_params_into, unpack_u8_into, validate_pack_bits,
    validate_simquant_bits,
};
use crate::runtime::{f32_bytes, literal_from_raw, Literal};
use crate::tensor::{DType, Tensor};
use crate::util::pool;

/// Whether the cache stores f32 rows or SimQuant u8 codes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Mode {
    F32,
    SimQuant,
}

/// One (slot, layer) prefill page for [`KvCache::ingest_prefill_batch`]:
/// rows `[t_len, D]` per cache, destined for positions `t0..t0 + t_len`.
/// `t0 > 0` resumes a page mid-prompt (chunked prefill): positions
/// `0..t0` must already hold the earlier chunks' rows.
pub struct PrefillPage<'a> {
    pub slot: usize,
    pub layer: usize,
    pub k_rows: &'a [f32],
    pub v_rows: &'a [f32],
    /// first position the rows land at (0 for whole-prompt prefill)
    pub t0: usize,
    pub t_len: usize,
}

/// Batched KV cache for one worker shard.
pub struct KvCache {
    n_layers: usize,
    batch: usize,
    ctx: usize,
    d: usize,
    mode: Mode,
    /// SimQuant code bitwidth (8, 4, 2, or 1); codes below 8 bits are
    /// stored bit-packed, `row_bytes` per row
    bits: u32,
    /// bytes one packed row of codes occupies (== d at 8 bits)
    row_bytes: usize,
    /// f32 mode: [L, B, CTX, D] values; simquant mode: codes as f32-free u8
    k_f32: Vec<f32>,
    v_f32: Vec<f32>,
    k_q: Vec<u8>,
    v_q: Vec<u8>,
    /// per (layer, slot, channel) params, [L, B, D]
    k_min: Vec<f32>,
    k_step: Vec<f32>,
    v_min: Vec<f32>,
    v_step: Vec<f32>,
    /// per-slot filled length
    lens: Vec<usize>,
    /// slot free-list for the continuous-batching engine (descending, so
    /// `pop` hands out the lowest free slot — deterministic assignment)
    free: Vec<usize>,
    /// reused page-reencode scratch (decoded page, widened lo/hi)
    scratch: Vec<f32>,
    lo_scratch: Vec<f32>,
    hi_scratch: Vec<f32>,
    /// reused unpacked-code staging for sub-byte pages
    code_scratch: Vec<u8>,
    /// page re-encode counter (observability)
    pub reencodes: u64,
}

impl KvCache {
    pub fn new_f32(n_layers: usize, batch: usize, ctx: usize, d: usize) -> Self {
        KvCache {
            n_layers,
            batch,
            ctx,
            d,
            mode: Mode::F32,
            bits: 8,
            row_bytes: d,
            k_f32: vec![0.0; n_layers * batch * ctx * d],
            v_f32: vec![0.0; n_layers * batch * ctx * d],
            k_q: Vec::new(),
            v_q: Vec::new(),
            k_min: Vec::new(),
            k_step: Vec::new(),
            v_min: Vec::new(),
            v_step: Vec::new(),
            lens: vec![0; batch],
            free: (0..batch).rev().collect(),
            scratch: Vec::new(),
            lo_scratch: Vec::new(),
            hi_scratch: Vec::new(),
            code_scratch: Vec::new(),
            reencodes: 0,
        }
    }

    pub fn new_simquant(n_layers: usize, batch: usize, ctx: usize, d: usize) -> Self {
        Self::new_simquant_bits(n_layers, batch, ctx, d, 8)
    }

    /// SimQuant cache storing `bits`-bit codes (8, 4, 2, or 1); sub-byte
    /// pages are bit-packed, `packed_len(d, bits)` bytes per row.
    pub fn new_simquant_bits(
        n_layers: usize,
        batch: usize,
        ctx: usize,
        d: usize,
        bits: u32,
    ) -> Self {
        validate_simquant_bits(bits).expect("KvCache bits");
        validate_pack_bits(bits).expect("KvCache bits must pack (1, 2, 4, or 8)");
        let row_bytes = packed_len(d, bits);
        KvCache {
            n_layers,
            batch,
            ctx,
            d,
            mode: Mode::SimQuant,
            bits,
            row_bytes,
            k_f32: Vec::new(),
            v_f32: Vec::new(),
            k_q: vec![0; n_layers * batch * ctx * row_bytes],
            v_q: vec![0; n_layers * batch * ctx * row_bytes],
            k_min: vec![0.0; n_layers * batch * d],
            k_step: vec![1e-8; n_layers * batch * d],
            v_min: vec![0.0; n_layers * batch * d],
            v_step: vec![1e-8; n_layers * batch * d],
            lens: vec![0; batch],
            free: (0..batch).rev().collect(),
            scratch: Vec::new(),
            lo_scratch: Vec::new(),
            hi_scratch: Vec::new(),
            code_scratch: Vec::new(),
            reencodes: 0,
        }
    }

    pub fn is_quantized(&self) -> bool {
        self.mode == Mode::SimQuant
    }

    /// SimQuant code bitwidth (8 for the f32 cache, vacuously).
    pub fn code_bits(&self) -> u32 {
        self.bits
    }

    pub fn len(&self, slot: usize) -> usize {
        self.lens[slot]
    }

    pub fn is_empty(&self) -> bool {
        self.lens.iter().all(|l| *l == 0)
    }

    /// Highest representable code for the current bitwidth.
    fn levels(&self) -> f32 {
        ((1u32 << self.bits) - 1) as f32
    }

    /// Clear one slot for reuse by a new request: length, SimQuant page
    /// params, and the pages themselves (the decode graphs consume full
    /// `[CTX]` pages, so a retired request's rows must not leak into the
    /// next occupant's cache inputs).
    pub fn reset_slot(&mut self, slot: usize) {
        self.lens[slot] = 0;
        for layer in 0..self.n_layers {
            match self.mode {
                Mode::F32 => {
                    let off = self.row_off(layer, slot, 0);
                    let page = self.ctx * self.d;
                    self.k_f32[off..off + page].fill(0.0);
                    self.v_f32[off..off + page].fill(0.0);
                }
                Mode::SimQuant => {
                    let off = self.code_off(layer, slot, 0);
                    let page = self.ctx * self.row_bytes;
                    self.k_q[off..off + page].fill(0);
                    self.v_q[off..off + page].fill(0);
                    let p = (layer * self.batch + slot) * self.d;
                    self.k_min[p..p + self.d].fill(0.0);
                    self.k_step[p..p + self.d].fill(1e-8);
                    self.v_min[p..p + self.d].fill(0.0);
                    self.v_step[p..p + self.d].fill(1e-8);
                }
            }
        }
    }

    /// Number of slots currently available to `acquire_slot`.
    pub fn free_slots(&self) -> usize {
        self.free.len()
    }

    /// Claim the lowest free slot for a new request (the caller ingests
    /// prefill rows into it next). Returns `None` when the batch is full.
    pub fn acquire_slot(&mut self) -> Option<usize> {
        self.free.pop()
    }

    /// Retire a slot: clear it and return it to the free list so the
    /// next admitted request can reuse its pages immediately.
    pub fn release_slot(&mut self, slot: usize) {
        debug_assert!(!self.free.contains(&slot), "double release of slot {slot}");
        self.reset_slot(slot);
        self.free.push(slot);
        // keep descending order so `pop` stays lowest-first
        self.free.sort_unstable_by(|a, b| b.cmp(a));
    }

    /// Bytes the cache occupies (memory accounting for the tables).
    /// Sub-byte caches count their bit-packed code pages, so the reported
    /// ratio vs f32 is the real one.
    pub fn storage_bytes(&self) -> usize {
        match self.mode {
            Mode::F32 => (self.k_f32.len() + self.v_f32.len()) * 4,
            Mode::SimQuant => {
                self.k_q.len()
                    + self.v_q.len()
                    + (self.k_min.len() + self.k_step.len() + self.v_min.len()
                        + self.v_step.len())
                        * 4
            }
        }
    }

    #[inline]
    fn row_off(&self, layer: usize, slot: usize, t: usize) -> usize {
        ((layer * self.batch + slot) * self.ctx + t) * self.d
    }

    /// Byte offset of row `t` in the (packed) code pages.
    #[inline]
    fn code_off(&self, layer: usize, slot: usize, t: usize) -> usize {
        ((layer * self.batch + slot) * self.ctx + t) * self.row_bytes
    }

    #[inline]
    fn param_off(&self, layer: usize, slot: usize) -> usize {
        (layer * self.batch + slot) * self.d
    }

    /// Ingest prefill caches for one slot: rows [T, D] per layer, stored
    /// (and for SimQuant: page-encoded, straight into the cache pages)
    /// at positions 0..t_len.
    pub fn ingest_prefill(
        &mut self,
        slot: usize,
        layer: usize,
        k_rows: &[f32],
        v_rows: &[f32],
        t_len: usize,
    ) {
        self.ingest_prefill_at(slot, layer, 0, k_rows, v_rows, t_len);
    }

    /// Resume-capable prefill ingest: store rows [T, D] at positions
    /// `t0..t0 + t_len`. For `t0 > 0` (a later chunk of a chunked
    /// prefill) the SimQuant page's params were fitted to the earlier
    /// chunks; rows that escape that range widen it once per chunk (old
    /// rows decoded, range recomputed over the union, page re-encoded) —
    /// the same adaptation the decode append path performs per row.
    pub fn ingest_prefill_at(
        &mut self,
        slot: usize,
        layer: usize,
        t0: usize,
        k_rows: &[f32],
        v_rows: &[f32],
        t_len: usize,
    ) {
        assert!(t0 + t_len <= self.ctx, "prefill rows past ctx");
        assert_eq!(k_rows.len(), t_len * self.d);
        assert_eq!(v_rows.len(), t_len * self.d);
        let d = self.d;
        match self.mode {
            Mode::F32 => {
                let off = self.row_off(layer, slot, t0);
                self.k_f32[off..off + t_len * d].copy_from_slice(k_rows);
                self.v_f32[off..off + t_len * d].copy_from_slice(v_rows);
            }
            Mode::SimQuant => {
                let off = self.code_off(layer, slot, 0);
                let p = self.param_off(layer, slot);
                let (bits, row_bytes) = (self.bits, self.row_bytes);
                let page = (t0 + t_len) * row_bytes;
                let mut cscratch = std::mem::take(&mut self.code_scratch);
                let mut fscratch = std::mem::take(&mut self.scratch);
                resume_page_packed(
                    k_rows,
                    t0,
                    t_len,
                    d,
                    bits,
                    row_bytes,
                    &mut self.k_q[off..off + page],
                    &mut self.k_min[p..p + d],
                    &mut self.k_step[p..p + d],
                    &mut fscratch,
                    &mut cscratch,
                );
                resume_page_packed(
                    v_rows,
                    t0,
                    t_len,
                    d,
                    bits,
                    row_bytes,
                    &mut self.v_q[off..off + page],
                    &mut self.v_min[p..p + d],
                    &mut self.v_step[p..p + d],
                    &mut fscratch,
                    &mut cscratch,
                );
                self.code_scratch = cscratch;
                self.scratch = fscratch;
            }
        }
        self.lens[slot] = self.lens[slot].max(t0 + t_len);
    }

    /// Ingest a batch of disjoint (slot, layer) prefill pages in
    /// parallel: the cache's own buffers are split into per-page blocks
    /// and the page encodes fan out across the persistent worker pool.
    /// Panics if two pages target the same (slot, layer).
    pub fn ingest_prefill_batch(&mut self, pages: &[PrefillPage<'_>]) {
        for p in pages {
            assert!(p.slot < self.batch && p.layer < self.n_layers, "page out of range");
            assert!(p.t0 + p.t_len <= self.ctx, "prefill rows past ctx");
            assert_eq!(p.k_rows.len(), p.t_len * self.d);
            assert_eq!(p.v_rows.len(), p.t_len * self.d);
        }
        let mut order: Vec<usize> = (0..pages.len()).collect();
        order.sort_by_key(|&i| (pages[i].layer, pages[i].slot));
        let idxs: Vec<usize> = order
            .iter()
            .map(|&i| pages[i].layer * self.batch + pages[i].slot)
            .collect();
        for w in idxs.windows(2) {
            assert!(w[0] < w[1], "duplicate (slot, layer) prefill page");
        }
        let d = self.d;
        match self.mode {
            Mode::F32 => {
                let page_len = self.ctx * d;
                let kblocks = carve(&mut self.k_f32, &idxs, page_len);
                let vblocks = carve(&mut self.v_f32, &idxs, page_len);
                let mut tasks: Vec<pool::Task<'_>> = Vec::with_capacity(order.len());
                for (&pi, (kb, vb)) in order.iter().zip(kblocks.into_iter().zip(vblocks)) {
                    let p = &pages[pi];
                    let (start, n) = (p.t0 * d, p.t_len * d);
                    let (k_rows, v_rows) = (p.k_rows, p.v_rows);
                    tasks.push(Box::new(move || {
                        kb[start..start + n].copy_from_slice(k_rows);
                        vb[start..start + n].copy_from_slice(v_rows);
                    }));
                }
                pool::run(tasks);
            }
            Mode::SimQuant => {
                let (bits, row_bytes) = (self.bits, self.row_bytes);
                let code_page = self.ctx * row_bytes;
                let kq = carve(&mut self.k_q, &idxs, code_page);
                let vq = carve(&mut self.v_q, &idxs, code_page);
                let kmin = carve(&mut self.k_min, &idxs, d);
                let kstep = carve(&mut self.k_step, &idxs, d);
                let vmin = carve(&mut self.v_min, &idxs, d);
                let vstep = carve(&mut self.v_step, &idxs, d);
                let iter = order
                    .iter()
                    .zip(kq.into_iter().zip(vq))
                    .zip(kmin.into_iter().zip(kstep))
                    .zip(vmin.into_iter().zip(vstep));
                let mut tasks: Vec<pool::Task<'_>> = Vec::with_capacity(order.len());
                for (((&pi, (kqb, vqb)), (kmb, ksb)), (vmb, vsb)) in iter {
                    let p = &pages[pi];
                    let (k_rows, v_rows, t0, t_len) = (p.k_rows, p.v_rows, p.t0, p.t_len);
                    tasks.push(Box::new(move || {
                        // per-task staging (only allocated for sub-byte
                        // or resumed pages; the fresh 8-bit path encodes
                        // in place)
                        let mut cscratch = Vec::new();
                        let mut fscratch = Vec::new();
                        let page = (t0 + t_len) * row_bytes;
                        resume_page_packed(
                            k_rows,
                            t0,
                            t_len,
                            d,
                            bits,
                            row_bytes,
                            &mut kqb[..page],
                            kmb,
                            ksb,
                            &mut fscratch,
                            &mut cscratch,
                        );
                        resume_page_packed(
                            v_rows,
                            t0,
                            t_len,
                            d,
                            bits,
                            row_bytes,
                            &mut vqb[..page],
                            vmb,
                            vsb,
                            &mut fscratch,
                            &mut cscratch,
                        );
                    }));
                }
                pool::run(tasks);
            }
        }
        for p in pages {
            self.lens[p.slot] = self.lens[p.slot].max(p.t0 + p.t_len);
        }
    }

    /// Append one decode-step row per cache; grows the slot by one.
    pub fn append_row(&mut self, slot: usize, layer: usize, k_row: &[f32], v_row: &[f32]) {
        let t = self.lens[slot];
        assert!(t < self.ctx, "slot {slot} KV overflow");
        match self.mode {
            Mode::F32 => {
                let off = self.row_off(layer, slot, t);
                self.k_f32[off..off + self.d].copy_from_slice(k_row);
                self.v_f32[off..off + self.d].copy_from_slice(v_row);
            }
            Mode::SimQuant => {
                self.append_quantized(slot, layer, t, k_row, true);
                self.append_quantized(slot, layer, t, v_row, false);
            }
        }
        // the caller bumps the length once after appending all layers
    }

    /// Mark the slot one token longer (after all layers appended).
    pub fn bump(&mut self, slot: usize) {
        self.lens[slot] += 1;
    }

    fn append_quantized(
        &mut self,
        slot: usize,
        layer: usize,
        t: usize,
        row: &[f32],
        is_k: bool,
    ) {
        let p = self.param_off(layer, slot);
        let d = self.d;
        let levels = self.levels();
        // the zipped loops below would silently truncate a short row
        assert_eq!(row.len(), d, "KV row length != d");
        // check range; widen + re-encode the page if violated
        let mut needs_reencode = false;
        {
            let (vmin, vstep) = if is_k {
                (&self.k_min[p..p + d], &self.k_step[p..p + d])
            } else {
                (&self.v_min[p..p + d], &self.v_step[p..p + d])
            };
            for ((mn, st), v) in vmin.iter().zip(vstep).zip(row) {
                let hi = mn + st * levels;
                if *v < mn - 1e-9 || *v > hi + 1e-9 {
                    needs_reencode = true;
                    break;
                }
            }
        }
        if needs_reencode && t > 0 {
            self.reencode_page(slot, layer, t, row, is_k);
            self.reencodes += 1;
        } else if needs_reencode {
            // empty page: seed params from the row itself
            let (vmin, vstep) = if is_k {
                (&mut self.k_min[p..p + d], &mut self.k_step[p..p + d])
            } else {
                (&mut self.v_min[p..p + d], &mut self.v_step[p..p + d])
            };
            for ((mn, st), v) in vmin.iter_mut().zip(vstep.iter_mut()).zip(row) {
                let lo = v.min(0.0);
                let hi = v.max(0.0);
                *mn = lo;
                *st = (hi - lo).max(1e-8) / levels;
            }
        }
        // encode the row with current params
        let off = self.code_off(layer, slot, t);
        let row_bytes = self.row_bytes;
        if self.bits == 8 {
            let (vmin, vstep, codes) = if is_k {
                (
                    &self.k_min[p..p + d],
                    &self.k_step[p..p + d],
                    &mut self.k_q[off..off + d],
                )
            } else {
                (
                    &self.v_min[p..p + d],
                    &self.v_step[p..p + d],
                    &mut self.v_q[off..off + d],
                )
            };
            simquant_encode_with_params_into(row, vmin, vstep, levels, codes);
        } else {
            // sub-byte: encode into the reused staging row, then pack
            let mut scratch = std::mem::take(&mut self.code_scratch);
            scratch.clear();
            scratch.resize(d, 0);
            {
                let (vmin, vstep) = if is_k {
                    (&self.k_min[p..p + d], &self.k_step[p..p + d])
                } else {
                    (&self.v_min[p..p + d], &self.v_step[p..p + d])
                };
                simquant_encode_with_params_into(row, vmin, vstep, levels, &mut scratch);
            }
            let codes = if is_k {
                &mut self.k_q[off..off + row_bytes]
            } else {
                &mut self.v_q[off..off + row_bytes]
            };
            pack_u8_into(&scratch, self.bits, codes).expect("sized packed row");
            self.code_scratch = scratch;
        }
    }

    /// Widen the page range to cover `row` and requantize existing codes.
    /// Runs entirely on the cache's reused scratch buffers.
    fn reencode_page(&mut self, slot: usize, layer: usize, t: usize, row: &[f32], is_k: bool) {
        let p = self.param_off(layer, slot);
        let d = self.d;
        let levels = self.levels();
        let (bits, row_bytes) = (self.bits, self.row_bytes);
        let base = self.code_off(layer, slot, 0);
        // decode current page into the reused scratch (unpacking sub-byte
        // rows through the reused code staging first)
        let mut page = std::mem::take(&mut self.scratch);
        page.clear();
        page.resize(t * d, 0.0);
        let mut ucodes = std::mem::take(&mut self.code_scratch);
        {
            let (codes, vmin, vstep) = if is_k {
                (
                    &self.k_q[base..base + t * row_bytes],
                    &self.k_min[p..p + d],
                    &self.k_step[p..p + d],
                )
            } else {
                (
                    &self.v_q[base..base + t * row_bytes],
                    &self.v_min[p..p + d],
                    &self.v_step[p..p + d],
                )
            };
            if bits == 8 {
                simquant_decode_into(codes, vmin, vstep, t, d, &mut page);
            } else {
                ucodes.clear();
                ucodes.resize(t * d, 0);
                unpack_rows(codes, t, d, bits, row_bytes, &mut ucodes);
                simquant_decode_into(&ucodes, vmin, vstep, t, d, &mut page);
            }
        }
        // widened per-channel range over page + new row
        let mut lo = std::mem::take(&mut self.lo_scratch);
        let mut hi = std::mem::take(&mut self.hi_scratch);
        lo.clear();
        lo.resize(d, f32::INFINITY);
        hi.clear();
        hi.resize(d, f32::NEG_INFINITY);
        for prow in page.chunks_exact(d) {
            for ((l, h), v) in lo.iter_mut().zip(hi.iter_mut()).zip(prow) {
                *l = l.min(*v);
                *h = h.max(*v);
            }
        }
        for ((l, h), v) in lo.iter_mut().zip(hi.iter_mut()).zip(row) {
            *l = l.min(*v);
            *h = h.max(*v);
        }
        // write params + re-encoded codes
        {
            let (vmin, vstep) = if is_k {
                (&mut self.k_min[p..p + d], &mut self.k_step[p..p + d])
            } else {
                (&mut self.v_min[p..p + d], &mut self.v_step[p..p + d])
            };
            for ((mn, st), (l, h)) in
                vmin.iter_mut().zip(vstep.iter_mut()).zip(lo.iter().zip(&hi))
            {
                *mn = *l;
                *st = (h - l).max(1e-8) / levels;
            }
        }
        let (codes, vmin, vstep) = if is_k {
            (
                &mut self.k_q[base..base + t * row_bytes],
                &self.k_min[p..p + d],
                &self.k_step[p..p + d],
            )
        } else {
            (
                &mut self.v_q[base..base + t * row_bytes],
                &self.v_min[p..p + d],
                &self.v_step[p..p + d],
            )
        };
        if bits == 8 {
            simquant_encode_with_params_into(&page, vmin, vstep, levels, codes);
        } else {
            ucodes.clear();
            ucodes.resize(t * d, 0);
            simquant_encode_with_params_into(&page, vmin, vstep, levels, &mut ucodes);
            pack_rows(&ucodes, t, d, bits, row_bytes, codes);
        }
        self.scratch = page;
        self.lo_scratch = lo;
        self.hi_scratch = hi;
        self.code_scratch = ucodes;
    }

    /// Dequantize one slot's K page into a reused buffer (cleared and
    /// refilled) — the scratch-friendly variant of [`KvCache::decode_k`].
    /// Sub-byte pages unpack through the cache's reused code staging
    /// (hence `&mut self`); no per-call allocation on any path.
    pub fn decode_k_into(&mut self, slot: usize, layer: usize, out: &mut Vec<f32>) {
        let t = self.lens[slot];
        let d = self.d;
        out.clear();
        out.resize(t * d, 0.0);
        match self.mode {
            Mode::F32 => {
                let off = self.row_off(layer, slot, 0);
                out.copy_from_slice(&self.k_f32[off..off + t * d]);
            }
            Mode::SimQuant => {
                let off = self.code_off(layer, slot, 0);
                let p = self.param_off(layer, slot);
                if self.bits == 8 {
                    simquant_decode_into(
                        &self.k_q[off..off + t * d],
                        &self.k_min[p..p + d],
                        &self.k_step[p..p + d],
                        t,
                        d,
                        out,
                    );
                } else {
                    let rb = self.row_bytes;
                    let mut ucodes = std::mem::take(&mut self.code_scratch);
                    ucodes.clear();
                    ucodes.resize(t * d, 0);
                    unpack_rows(&self.k_q[off..off + t * rb], t, d, self.bits, rb, &mut ucodes);
                    simquant_decode_into(
                        &ucodes,
                        &self.k_min[p..p + d],
                        &self.k_step[p..p + d],
                        t,
                        d,
                        out,
                    );
                    self.code_scratch = ucodes;
                }
            }
        }
    }

    /// Dequantize one slot's K page (tests + debugging).
    pub fn decode_k(&mut self, slot: usize, layer: usize) -> Vec<f32> {
        let mut out = Vec::new();
        self.decode_k_into(slot, layer, &mut out);
        out
    }

    /// Build the decode-graph cache input tensors.
    /// f32 mode: [k_cache, v_cache]; simquant: [k_cache, v_cache, k_min,
    /// k_step, v_min, v_step] in graph input order. Sub-byte caches ship
    /// their packed code rows (`[L, B, CTX, packed_row_bytes]`).
    pub fn graph_inputs(&self) -> Vec<Tensor> {
        let (l, b, c, d) = (self.n_layers, self.batch, self.ctx, self.d);
        match self.mode {
            Mode::F32 => vec![
                Tensor::from_f32_slice(vec![l, b, c, d], &self.k_f32),
                Tensor::from_f32_slice(vec![l, b, c, d], &self.v_f32),
            ],
            Mode::SimQuant => {
                let expand =
                    |params: &[f32]| Tensor::from_f32_slice(vec![l, b, 1, d], params);
                vec![
                    Tensor::from_u8_slice(vec![l, b, c, self.row_bytes], &self.k_q),
                    Tensor::from_u8_slice(vec![l, b, c, self.row_bytes], &self.v_q),
                    expand(&self.k_min),
                    expand(&self.k_step),
                    expand(&self.v_min),
                    expand(&self.v_step),
                ]
            }
        }
    }

    pub fn dtype(&self) -> DType {
        match self.mode {
            Mode::F32 => DType::F32,
            Mode::SimQuant => DType::U8,
        }
    }

    /// Build the decode-graph cache inputs as PJRT literals directly from
    /// the cache's own buffers — one copy (into the literal) instead of
    /// the two `graph_inputs()` pays (staging Tensor + literal). This is
    /// the decode hot path (EXPERIMENTS.md §Perf).
    pub fn input_literals(&self) -> Result<Vec<Literal>> {
        let (l, b, c, d) = (self.n_layers, self.batch, self.ctx, self.d);
        let cache_shape = [l, b, c, d];
        let code_shape = [l, b, c, self.row_bytes];
        let param_shape = [l, b, 1, d];
        Ok(match self.mode {
            Mode::F32 => vec![
                literal_from_raw(DType::F32, &cache_shape, f32_bytes(&self.k_f32))?,
                literal_from_raw(DType::F32, &cache_shape, f32_bytes(&self.v_f32))?,
            ],
            Mode::SimQuant => vec![
                literal_from_raw(DType::U8, &code_shape, &self.k_q)?,
                literal_from_raw(DType::U8, &code_shape, &self.v_q)?,
                literal_from_raw(DType::F32, &param_shape, f32_bytes(&self.k_min))?,
                literal_from_raw(DType::F32, &param_shape, f32_bytes(&self.k_step))?,
                literal_from_raw(DType::F32, &param_shape, f32_bytes(&self.v_min))?,
                literal_from_raw(DType::F32, &param_shape, f32_bytes(&self.v_step))?,
            ],
        })
    }
}

/// Encode a `[t_len, D]` page: params per channel, codes written row by
/// row (bit-packed below 8 bits, `row_bytes` per row). `scratch` stages
/// the unpacked codes for sub-byte pages and is untouched at 8 bits.
#[allow(clippy::too_many_arguments)]
fn encode_page_packed(
    rows: &[f32],
    t_len: usize,
    d: usize,
    bits: u32,
    row_bytes: usize,
    codes: &mut [u8],
    vmin: &mut [f32],
    step: &mut [f32],
    scratch: &mut Vec<u8>,
) {
    if bits == 8 {
        simquant_encode_into(rows, t_len, d, 8, codes, vmin, step)
            .expect("simquant encode (bits=8, sized buffers) cannot fail");
        return;
    }
    scratch.clear();
    scratch.resize(t_len * d, 0);
    simquant_encode_into(rows, t_len, d, bits, scratch, vmin, step)
        .expect("simquant encode (sized buffers) cannot fail");
    pack_rows(scratch, t_len, d, bits, row_bytes, codes);
}

/// Encode rows `[t_len, D]` into page positions `t0..t0 + t_len`.
///
/// `t0 == 0` is a fresh page encode (params fitted to the rows). For
/// `t0 > 0` — resuming a chunked prefill — the page's first `t0` rows
/// were encoded by earlier chunks under the current `(vmin, step)`:
/// when every new row fits that range, the new rows are encoded with the
/// existing params; otherwise the old rows are decoded, the per-channel
/// range recomputed over old + new, and the whole page re-encoded — the
/// decode append path's widening, amortized to at most once per chunk.
/// `codes` must cover rows `0..t0 + t_len`.
#[allow(clippy::too_many_arguments)]
fn resume_page_packed(
    rows: &[f32],
    t0: usize,
    t_len: usize,
    d: usize,
    bits: u32,
    row_bytes: usize,
    codes: &mut [u8],
    vmin: &mut [f32],
    step: &mut [f32],
    fscratch: &mut Vec<f32>,
    cscratch: &mut Vec<u8>,
) {
    if t0 == 0 {
        encode_page_packed(rows, t_len, d, bits, row_bytes, codes, vmin, step, cscratch);
        return;
    }
    let levels = ((1u32 << bits) - 1) as f32;
    let in_range = rows.chunks_exact(d).take(t_len).all(|row| {
        row.iter().zip(vmin.iter().zip(step.iter())).all(|(v, (mn, st))| {
            let hi = mn + st * levels;
            *v >= mn - 1e-9 && *v <= hi + 1e-9
        })
    });
    if in_range {
        for (r, row) in rows.chunks_exact(d).take(t_len).enumerate() {
            let off = (t0 + r) * row_bytes;
            if bits == 8 {
                simquant_encode_with_params_into(
                    row,
                    vmin,
                    step,
                    levels,
                    &mut codes[off..off + d],
                );
            } else {
                cscratch.clear();
                cscratch.resize(d, 0);
                simquant_encode_with_params_into(row, vmin, step, levels, cscratch);
                pack_u8_into(cscratch, bits, &mut codes[off..off + row_bytes])
                    .expect("sized packed row");
            }
        }
        return;
    }
    // widen: decode the earlier chunks' rows, append the new ones, and
    // re-encode the union as one fresh page
    fscratch.clear();
    fscratch.resize((t0 + t_len) * d, 0.0);
    if bits == 8 {
        simquant_decode_into(&codes[..t0 * d], vmin, step, t0, d, &mut fscratch[..t0 * d]);
    } else {
        cscratch.clear();
        cscratch.resize(t0 * d, 0);
        unpack_rows(&codes[..t0 * row_bytes], t0, d, bits, row_bytes, cscratch);
        simquant_decode_into(cscratch, vmin, step, t0, d, &mut fscratch[..t0 * d]);
    }
    fscratch[t0 * d..].copy_from_slice(&rows[..t_len * d]);
    encode_page_packed(fscratch, t0 + t_len, d, bits, row_bytes, codes, vmin, step, cscratch);
}

/// Pack `t` unpacked code rows ([t, d] u8) into `row_bytes`-wide packed
/// rows — the single site for the page row layout (see also
/// [`unpack_rows`]).
fn pack_rows(ucodes: &[u8], t: usize, d: usize, bits: u32, row_bytes: usize, codes: &mut [u8]) {
    for (r, urow) in ucodes.chunks_exact(d).take(t).enumerate() {
        pack_u8_into(urow, bits, &mut codes[r * row_bytes..(r + 1) * row_bytes])
            .expect("sized packed row");
    }
}

/// Inverse of [`pack_rows`]: unpack `t` packed rows into [t, d] u8 codes.
fn unpack_rows(codes: &[u8], t: usize, d: usize, bits: u32, row_bytes: usize, ucodes: &mut [u8]) {
    for r in 0..t {
        unpack_u8_into(
            &codes[r * row_bytes..(r + 1) * row_bytes],
            bits,
            &mut ucodes[r * d..(r + 1) * d],
        )
        .expect("sized packed row");
    }
}

/// Split `buf` into one `page`-sized mutable block per index in `idxs`
/// (strictly ascending); the blocks are disjoint, so they can fan out
/// across pool tasks.
fn carve<'a, T>(mut buf: &'a mut [T], idxs: &[usize], page: usize) -> Vec<&'a mut [T]> {
    let mut out = Vec::with_capacity(idxs.len());
    let mut pos = 0usize;
    for &i in idxs {
        let start = i * page;
        debug_assert!(start >= pos, "indices must be sorted");
        let (_, rest) = buf.split_at_mut(start - pos);
        let (block, rest) = rest.split_at_mut(page);
        out.push(block);
        buf = rest;
        pos = start + page;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::XorShift64Star;

    fn rows(t: usize, d: usize, seed: u64, scale: f32) -> Vec<f32> {
        let mut r = XorShift64Star::new(seed);
        (0..t * d).map(|_| r.next_normal() as f32 * scale).collect()
    }

    #[test]
    fn f32_roundtrip() {
        let mut kv = KvCache::new_f32(2, 1, 8, 4);
        let k = rows(3, 4, 1, 1.0);
        let v = rows(3, 4, 2, 1.0);
        for layer in 0..2 {
            kv.ingest_prefill(0, layer, &k, &v, 3);
        }
        assert_eq!(kv.len(0), 3);
        assert_eq!(kv.decode_k(0, 1), k);
    }

    #[test]
    fn simquant_roundtrip_bounded() {
        let mut kv = KvCache::new_simquant(1, 1, 16, 8);
        let k = rows(5, 8, 3, 2.0);
        let v = rows(5, 8, 4, 2.0);
        kv.ingest_prefill(0, 0, &k, &v, 5);
        let dk = kv.decode_k(0, 0);
        for (a, b) in k.iter().zip(&dk) {
            assert!((a - b).abs() < 0.05, "{a} vs {b}");
        }
    }

    #[test]
    fn prefill_encode_matches_reference_kernel() {
        // the in-place page encode must be bit-identical to the pinned
        // scalar reference (same codes, same params)
        let (t, d) = (6, 8);
        let k = rows(t, d, 9, 1.5);
        let mut kv = KvCache::new_simquant(1, 1, 16, d);
        kv.ingest_prefill(0, 0, &k, &k, t);
        let (rq, rmin, rstep) = crate::quant::reference::simquant_encode(&k, t, d, 8);
        let ins = kv.graph_inputs();
        assert_eq!(&ins[0].u8_view().unwrap()[..t * d], &rq[..]);
        assert_eq!(&ins[2].f32_view().unwrap()[..d], &rmin[..]);
        assert_eq!(&ins[3].f32_view().unwrap()[..d], &rstep[..]);
    }

    #[test]
    fn packed_page_roundtrip_matches_unpacked_codes() {
        // 4-bit page: decode must reproduce exactly what the unpacked
        // 4-bit reference codes decode to (packing is lossless on codes)
        let (t, d) = (5, 7); // ragged: row_bytes = 4, last nibble padding
        let k = rows(t, d, 21, 1.0);
        let mut kv = KvCache::new_simquant_bits(1, 1, 8, d, 4);
        kv.ingest_prefill(0, 0, &k, &k, t);
        let (rq, rmin, rstep) = crate::quant::reference::simquant_encode(&k, t, d, 4);
        let expect: Vec<f32> = rq
            .iter()
            .enumerate()
            .map(|(j, q)| *q as f32 * rstep[j % d] + rmin[j % d])
            .collect();
        assert_eq!(kv.decode_k(0, 0), expect);
    }

    #[test]
    fn packed_append_and_reencode_stay_bounded() {
        let mut kv = KvCache::new_simquant_bits(1, 1, 16, 4, 4);
        let k = vec![0.1, 0.1, 0.1, 0.1, 0.2, 0.2, 0.2, 0.2];
        kv.ingest_prefill(0, 0, &k, &k, 2);
        let big = [5.0, -4.0, 3.0, 7.0];
        kv.append_row(0, 0, &big, &big);
        kv.bump(0);
        assert!(kv.reencodes > 0);
        let dk = kv.decode_k(0, 0);
        // 4-bit steps are coarse after widening to ~11.0: step ~ 0.74
        for (a, b) in big.iter().zip(&dk[8..]) {
            assert!((a - b).abs() < 0.5, "{a} vs {b}");
        }
    }

    #[test]
    fn packed_storage_is_half_of_8bit_and_8x_under_f32() {
        let f = KvCache::new_f32(2, 4, 64, 32);
        let q8 = KvCache::new_simquant(2, 4, 64, 32);
        let q4 = KvCache::new_simquant_bits(2, 4, 64, 32, 4);
        let q2 = KvCache::new_simquant_bits(2, 4, 64, 32, 2);
        let codes8 = q8.storage_bytes();
        let codes4 = q4.storage_bytes();
        let codes2 = q2.storage_bytes();
        assert!(codes4 < codes8 && codes2 < codes4);
        let ratio4 = codes4 as f64 / f.storage_bytes() as f64;
        assert!(ratio4 < 0.16, "4-bit ratio {ratio4}");
        let ratio2 = codes2 as f64 / f.storage_bytes() as f64;
        assert!(ratio2 < 0.10, "2-bit ratio {ratio2}");
    }

    #[test]
    fn batch_ingest_matches_serial_ingest() {
        let (l, b, ctx, d) = (3usize, 2usize, 8usize, 16usize);
        for bits in [8u32, 4] {
            let mut serial = KvCache::new_simquant_bits(l, b, ctx, d, bits);
            let mut batch = KvCache::new_simquant_bits(l, b, ctx, d, bits);
            let data: Vec<(usize, usize, Vec<f32>, Vec<f32>, usize)> = (0..l)
                .flat_map(|layer| {
                    (0..b).map(move |slot| {
                        let t = 3 + slot;
                        let seed = (layer * 10 + slot) as u64;
                        (slot, layer, rows(t, d, seed, 1.0), rows(t, d, seed + 99, 1.0), t)
                    })
                })
                .collect();
            for (slot, layer, k, v, t) in &data {
                serial.ingest_prefill(*slot, *layer, k, v, *t);
            }
            let pages: Vec<PrefillPage<'_>> = data
                .iter()
                .map(|(slot, layer, k, v, t)| PrefillPage {
                    slot: *slot,
                    layer: *layer,
                    k_rows: k,
                    v_rows: v,
                    t0: 0,
                    t_len: *t,
                })
                .collect();
            batch.ingest_prefill_batch(&pages);
            for slot in 0..b {
                assert_eq!(serial.len(slot), batch.len(slot));
                for layer in 0..l {
                    assert_eq!(
                        serial.decode_k(slot, layer),
                        batch.decode_k(slot, layer),
                        "bits={bits} slot={slot} layer={layer}"
                    );
                }
            }
        }
    }

    #[test]
    fn f32_batch_ingest_matches_serial() {
        let (l, b, ctx, d) = (2usize, 2usize, 8usize, 4usize);
        let mut serial = KvCache::new_f32(l, b, ctx, d);
        let mut batch = KvCache::new_f32(l, b, ctx, d);
        let k = rows(5, d, 1, 1.0);
        let v = rows(5, d, 2, 1.0);
        let mut pages = Vec::new();
        for layer in 0..l {
            serial.ingest_prefill(1, layer, &k, &v, 5);
            pages.push(PrefillPage { slot: 1, layer, k_rows: &k, v_rows: &v, t0: 0, t_len: 5 });
        }
        batch.ingest_prefill_batch(&pages);
        for layer in 0..l {
            assert_eq!(serial.decode_k(1, layer), batch.decode_k(1, layer));
        }
    }

    #[test]
    #[should_panic(expected = "duplicate")]
    fn batch_ingest_rejects_duplicate_pages() {
        let mut kv = KvCache::new_f32(1, 1, 4, 2);
        let k = vec![0.0; 4];
        let pages = vec![
            PrefillPage { slot: 0, layer: 0, k_rows: &k, v_rows: &k, t0: 0, t_len: 2 },
            PrefillPage { slot: 0, layer: 0, k_rows: &k, v_rows: &k, t0: 0, t_len: 2 },
        ];
        kv.ingest_prefill_batch(&pages);
    }

    #[test]
    fn f32_chunked_ingest_matches_whole() {
        let (t, d) = (6usize, 4usize);
        let k = rows(t, d, 31, 1.0);
        let v = rows(t, d, 32, 1.0);
        let mut whole = KvCache::new_f32(1, 1, 8, d);
        whole.ingest_prefill(0, 0, &k, &v, t);
        let mut chunked = KvCache::new_f32(1, 1, 8, d);
        chunked.ingest_prefill_at(0, 0, 0, &k[..2 * d], &v[..2 * d], 2);
        chunked.ingest_prefill_at(0, 0, 2, &k[2 * d..], &v[2 * d..], 4);
        assert_eq!(chunked.len(0), t);
        assert_eq!(whole.decode_k(0, 0), chunked.decode_k(0, 0));
    }

    #[test]
    fn simquant_resume_within_range_keeps_params() {
        for bits in [8u32, 4] {
            let d = 8usize;
            let mut kv = KvCache::new_simquant_bits(1, 1, 16, d, bits);
            // first chunk spans [-4, 4] on every channel, so the smaller
            // resume rows are guaranteed in range
            let mut first = vec![0.5f32; 3 * d];
            first[..d].fill(-4.0);
            first[d..2 * d].fill(4.0);
            let second: Vec<f32> = rows(2, d, 42, 0.5)
                .into_iter()
                .map(|x| x.clamp(-2.0, 2.0))
                .collect();
            kv.ingest_prefill_at(0, 0, 0, &first, &first, 3);
            let params_before = kv.graph_inputs()[2].f32_view().unwrap().to_vec();
            kv.ingest_prefill_at(0, 0, 3, &second, &second, 2);
            let params_after = kv.graph_inputs()[2].f32_view().unwrap().to_vec();
            assert_eq!(params_before, params_after, "bits={bits}: in-range resume re-fit");
            assert_eq!(kv.len(0), 5);
            // reconstruction bounded by half a step over the [-4, 4] range
            let tol = 0.5 * 8.0 / (((1u32 << bits) - 1) as f32) + 1e-3;
            let dk = kv.decode_k(0, 0);
            for (a, b) in second.iter().zip(&dk[3 * d..]) {
                assert!((a - b).abs() <= tol, "bits={bits}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn simquant_resume_widens_out_of_range_chunk() {
        let d = 4usize;
        let mut kv = KvCache::new_simquant(1, 1, 16, d);
        let first = vec![0.1, 0.1, 0.1, 0.1, 0.2, 0.2, 0.2, 0.2];
        kv.ingest_prefill_at(0, 0, 0, &first, &first, 2);
        // second chunk far outside the first chunk's range
        let second = vec![5.0, -4.0, 3.0, 7.0];
        kv.ingest_prefill_at(0, 0, 2, &second, &second, 1);
        let dk = kv.decode_k(0, 0);
        // old rows survive the widening within the widened step bound
        for (a, b) in first.iter().zip(&dk[..2 * d]) {
            assert!((a - b).abs() < 0.05, "{a} vs {b}");
        }
        for (a, b) in second.iter().zip(&dk[2 * d..]) {
            assert!((a - b).abs() < 0.05, "{a} vs {b}");
        }
    }

    #[test]
    fn batch_resume_matches_serial_resume() {
        let (l, b, ctx, d) = (2usize, 2usize, 16usize, 8usize);
        for bits in [8u32, 4] {
            let mut serial = KvCache::new_simquant_bits(l, b, ctx, d, bits);
            let mut batch = KvCache::new_simquant_bits(l, b, ctx, d, bits);
            let chunk1: Vec<(usize, usize, Vec<f32>, Vec<f32>)> = (0..l)
                .flat_map(|layer| {
                    (0..b).map(move |slot| {
                        let seed = (layer * 10 + slot) as u64;
                        (slot, layer, rows(3, d, seed, 1.0), rows(3, d, seed + 50, 1.0))
                    })
                })
                .collect();
            let chunk2: Vec<(usize, usize, Vec<f32>, Vec<f32>)> = (0..l)
                .flat_map(|layer| {
                    (0..b).map(move |slot| {
                        let seed = 777 + (layer * 10 + slot) as u64;
                        // mix of in-range and widening chunks
                        let scale = if slot == 0 { 0.5 } else { 3.0 };
                        (slot, layer, rows(2, d, seed, scale), rows(2, d, seed + 50, scale))
                    })
                })
                .collect();
            for cache in [&mut serial, &mut batch] {
                let pages: Vec<PrefillPage<'_>> = chunk1
                    .iter()
                    .map(|(slot, layer, k, v)| PrefillPage {
                        slot: *slot,
                        layer: *layer,
                        k_rows: k,
                        v_rows: v,
                        t0: 0,
                        t_len: 3,
                    })
                    .collect();
                cache.ingest_prefill_batch(&pages);
            }
            for (slot, layer, k, v) in &chunk2 {
                serial.ingest_prefill_at(*slot, *layer, 3, k, v, 2);
            }
            let pages: Vec<PrefillPage<'_>> = chunk2
                .iter()
                .map(|(slot, layer, k, v)| PrefillPage {
                    slot: *slot,
                    layer: *layer,
                    k_rows: k,
                    v_rows: v,
                    t0: 3,
                    t_len: 2,
                })
                .collect();
            batch.ingest_prefill_batch(&pages);
            for slot in 0..b {
                assert_eq!(serial.len(slot), batch.len(slot));
                assert_eq!(serial.len(slot), 5);
                for layer in 0..l {
                    assert_eq!(
                        serial.decode_k(slot, layer),
                        batch.decode_k(slot, layer),
                        "bits={bits} slot={slot} layer={layer}"
                    );
                }
            }
        }
    }

    #[test]
    fn append_within_range_no_reencode() {
        let mut kv = KvCache::new_simquant(1, 1, 16, 4);
        // wide prefill range so appended rows stay inside
        let k = vec![-10.0, -10.0, -10.0, -10.0, 10.0, 10.0, 10.0, 10.0];
        kv.ingest_prefill(0, 0, &k, &k, 2);
        kv.append_row(0, 0, &[1.0, 2.0, -3.0, 0.5], &[0.0, 0.0, 0.0, 0.0]);
        kv.bump(0);
        assert_eq!(kv.reencodes, 0);
        assert_eq!(kv.len(0), 3);
    }

    #[test]
    fn out_of_range_append_triggers_reencode_and_stays_accurate() {
        let mut kv = KvCache::new_simquant(1, 1, 16, 4);
        let k = vec![0.1, 0.1, 0.1, 0.1, 0.2, 0.2, 0.2, 0.2];
        kv.ingest_prefill(0, 0, &k, &k, 2);
        let big = [5.0, -4.0, 3.0, 7.0];
        kv.append_row(0, 0, &big, &big);
        kv.bump(0);
        assert!(kv.reencodes > 0);
        let dk = kv.decode_k(0, 0);
        // old rows still reconstruct within the widened step bound
        for (a, b) in k.iter().zip(&dk[..8]) {
            assert!((a - b).abs() < 0.05, "{a} vs {b}");
        }
        for (a, b) in big.iter().zip(&dk[8..]) {
            assert!((a - b).abs() < 0.05, "{a} vs {b}");
        }
    }

    #[test]
    fn storage_is_quarter_of_f32() {
        let f = KvCache::new_f32(2, 4, 64, 32);
        let q = KvCache::new_simquant(2, 4, 64, 32);
        let ratio = q.storage_bytes() as f64 / f.storage_bytes() as f64;
        assert!(ratio < 0.3, "ratio {ratio}");
    }

    #[test]
    fn reset_slot_clears() {
        let mut kv = KvCache::new_simquant(1, 2, 8, 4);
        let k = rows(4, 4, 5, 1.0);
        kv.ingest_prefill(1, 0, &k, &k, 4);
        kv.reset_slot(1);
        assert_eq!(kv.len(1), 0);
    }

    #[test]
    fn slot_free_list_acquire_release_reuse() {
        let mut kv = KvCache::new_simquant(1, 3, 8, 4);
        assert_eq!(kv.free_slots(), 3);
        // lowest-first, deterministic
        assert_eq!(kv.acquire_slot(), Some(0));
        assert_eq!(kv.acquire_slot(), Some(1));
        assert_eq!(kv.acquire_slot(), Some(2));
        assert_eq!(kv.acquire_slot(), None);
        let k = rows(2, 4, 7, 1.0);
        kv.ingest_prefill(1, 0, &k, &k, 2);
        kv.release_slot(1);
        assert_eq!(kv.free_slots(), 1);
        assert_eq!(kv.len(1), 0);
        // released slot is handed out again
        assert_eq!(kv.acquire_slot(), Some(1));
    }

    #[test]
    fn release_slot_scrubs_pages() {
        let mut kv = KvCache::new_f32(1, 2, 4, 2);
        let k = vec![1.0, 2.0, 3.0, 4.0];
        kv.ingest_prefill(0, 0, &k, &k, 2);
        assert_eq!(kv.acquire_slot(), Some(0));
        kv.release_slot(0);
        // the next occupant must not see the retired request's rows
        let ins = kv.graph_inputs();
        assert!(ins[0].f32_view().unwrap().iter().all(|x| *x == 0.0));
    }

    #[test]
    fn graph_inputs_shapes() {
        let kv = KvCache::new_simquant(2, 3, 8, 4);
        let ins = kv.graph_inputs();
        assert_eq!(ins.len(), 6);
        assert_eq!(ins[0].shape, vec![2, 3, 8, 4]);
        assert_eq!(ins[2].shape, vec![2, 3, 1, 4]);
        // sub-byte caches ship packed rows
        let kv4 = KvCache::new_simquant_bits(2, 3, 8, 4, 4);
        assert_eq!(kv4.graph_inputs()[0].shape, vec![2, 3, 8, 2]);
        let f = KvCache::new_f32(2, 3, 8, 4);
        assert_eq!(f.graph_inputs().len(), 2);
    }

    #[test]
    #[should_panic(expected = "KV overflow")]
    fn overflow_panics() {
        let mut kv = KvCache::new_f32(1, 1, 2, 2);
        kv.ingest_prefill(0, 0, &[0.0; 4], &[0.0; 4], 2);
        kv.append_row(0, 0, &[1.0, 1.0], &[1.0, 1.0]);
    }
}
