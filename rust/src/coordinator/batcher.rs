//! Admission queue for both scheduler modes.
//!
//! In **static** mode this is the classic dynamic batcher: collect
//! requests up to a max batch size or a deadline, whichever comes first,
//! then hand the batch to a worker that runs it to completion. In
//! **continuous** mode the queue is per-worker and drained at every step
//! boundary (`take_up_to`, capped by the shard's free slots) — requests
//! never wait for a batch to "form", only for capacity.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use super::request::Request;

/// How the serving engine schedules admitted requests onto workers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SchedulerMode {
    /// seed behavior: deadline-formed batches run to completion
    /// (head-of-line blocking; the ablation baseline)
    #[default]
    Static,
    /// step-driven workers: requests join in-flight batches at step
    /// boundaries, finished slots retire and free capacity immediately
    Continuous,
}

impl SchedulerMode {
    pub fn name(self) -> &'static str {
        match self {
            SchedulerMode::Static => "static",
            SchedulerMode::Continuous => "continuous",
        }
    }
}

/// Batching policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// max requests per batch (compiled graph batch size)
    pub max_batch: usize,
    /// max time the oldest request may wait before the batch is released
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5) }
    }
}

/// A released batch.
#[derive(Debug)]
pub struct Batch {
    pub requests: Vec<Request>,
    pub formed_at: Instant,
}

impl Batch {
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }
}

/// FIFO queue + policy.
#[derive(Debug)]
pub struct Batcher {
    policy: BatchPolicy,
    queue: VecDeque<Request>,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Self {
        assert!(policy.max_batch >= 1);
        Batcher { policy, queue: VecDeque::new() }
    }

    pub fn policy(&self) -> BatchPolicy {
        self.policy
    }

    pub fn push(&mut self, req: Request) {
        self.queue.push_back(req);
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Whether a batch should be released `now`.
    pub fn ready(&self, now: Instant) -> bool {
        if self.queue.len() >= self.policy.max_batch {
            return true;
        }
        match self.queue.front() {
            Some(r) => now.duration_since(r.arrival) >= self.policy.max_wait,
            None => false,
        }
    }

    /// When the oldest queued request's deadline expires (static-mode
    /// release even if the batch is not full). `None` when empty.
    pub fn next_deadline(&self) -> Option<Instant> {
        self.queue.front().map(|r| r.arrival + self.policy.max_wait)
    }

    /// Continuous-mode admission: immediately pop up to `n` requests
    /// (the shard's free slot count) in FIFO order — no deadline, no
    /// batch formation.
    pub fn take_up_to(&mut self, n: usize) -> Vec<Request> {
        let k = self.queue.len().min(n);
        self.queue.drain(..k).collect()
    }

    /// Release the next batch if the policy allows.
    pub fn take(&mut self, now: Instant) -> Option<Batch> {
        if !self.ready(now) {
            return None;
        }
        let n = self.queue.len().min(self.policy.max_batch);
        let requests: Vec<Request> = self.queue.drain(..n).collect();
        Some(Batch { requests, formed_at: now })
    }

    /// Drain everything regardless of deadline (shutdown path).
    pub fn flush(&mut self) -> Vec<Batch> {
        let mut out = Vec::new();
        while !self.queue.is_empty() {
            let n = self.queue.len().min(self.policy.max_batch);
            out.push(Batch {
                requests: self.queue.drain(..n).collect(),
                formed_at: Instant::now(),
            });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, UsizeRange};

    fn req(id: u64) -> Request {
        Request::new(id, vec![1, 2], 4)
    }

    #[test]
    fn releases_on_full_batch() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 2, max_wait: Duration::from_secs(10) });
        b.push(req(1));
        assert!(b.take(Instant::now()).is_none());
        b.push(req(2));
        let batch = b.take(Instant::now()).unwrap();
        assert_eq!(batch.len(), 2);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn releases_on_deadline() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) });
        b.push(req(1));
        assert!(b.take(Instant::now()).is_none());
        std::thread::sleep(Duration::from_millis(3));
        let batch = b.take(Instant::now()).unwrap();
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn oversized_queue_splits() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 3, max_wait: Duration::ZERO });
        for i in 0..7 {
            b.push(req(i));
        }
        let sizes: Vec<usize> = b.flush().iter().map(Batch::len).collect();
        assert_eq!(sizes, vec![3, 3, 1]);
    }

    #[test]
    fn fifo_order_preserved() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 4, max_wait: Duration::ZERO });
        for i in 0..4 {
            b.push(req(i));
        }
        let batch = b.take(Instant::now()).unwrap();
        let ids: Vec<u64> = batch.requests.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn take_up_to_pops_fifo_without_deadline() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 8, max_wait: Duration::from_secs(10) });
        for i in 0..5 {
            b.push(req(i));
        }
        // deadline far away, but continuous admission drains immediately
        let got = b.take_up_to(3);
        assert_eq!(got.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(b.pending(), 2);
        assert_eq!(b.take_up_to(9).len(), 2);
        assert!(b.take_up_to(4).is_empty());
    }

    #[test]
    fn next_deadline_tracks_oldest() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(7) });
        assert!(b.next_deadline().is_none());
        let r = req(1);
        let expect = r.arrival + Duration::from_millis(7);
        b.push(r);
        b.push(req(2));
        assert_eq!(b.next_deadline(), Some(expect));
    }

    #[test]
    fn scheduler_mode_names() {
        assert_eq!(SchedulerMode::default(), SchedulerMode::Static);
        assert_eq!(SchedulerMode::Static.name(), "static");
        assert_eq!(SchedulerMode::Continuous.name(), "continuous");
    }

    #[test]
    fn prop_batches_never_exceed_max_and_lose_nothing() {
        check(11, 100, &UsizeRange(1, 50), |n| {
            let mut b =
                Batcher::new(BatchPolicy { max_batch: 4, max_wait: Duration::ZERO });
            for i in 0..*n {
                b.push(req(i as u64));
            }
            let batches = b.flush();
            let total: usize = batches.iter().map(Batch::len).sum();
            total == *n && batches.iter().all(|x| x.len() <= 4 && !x.is_empty())
        });
    }
}
