//! Admission queue for both scheduler modes.
//!
//! In **static** mode this is the classic dynamic batcher: collect
//! requests up to a max batch size or a deadline, whichever comes first,
//! then hand the batch to a worker that runs it to completion. In
//! **continuous** mode the queue is per-worker and drained at every step
//! boundary (`take_up_to`, capped by the shard's free slots) — requests
//! never wait for a batch to "form", only for capacity.
//!
//! The queue is two-tier: [`Batcher::push`] enqueues at normal priority,
//! [`Batcher::push_low`] behind it. Low-priority requests are only
//! released once the normal queue is drained — the [`AdmissionPolicy`]'s
//! `Priority` mode parks load arriving during an SLO breach there
//! instead of shedding it.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use super::request::{Priority, Request};

/// What the serving engine does with new load while a shard is (or is
/// predicted to be) breaching its latency target. Decided at the
/// dispatcher's join boundary — see `coordinator::server`.
///
/// The trailing policies (`SheddingP99`, `Priority`) read a rolling
/// per-shard window of *completed* latencies: the gate trips below the
/// target (detection-lag margin), idle shards always admit (recovery
/// probe), and windows with no recent completions age out so a
/// full-shed interval cannot freeze the verdict. `Predictive` gates on
/// the *future* instead: the candidate's completion time predicted from
/// the shard's in-flight token backlog and the calibrated per-token
/// cost (`coordinator::cost::CostEstimator`), so the shed decision
/// lands during an arrival ramp rather than a window after it.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum AdmissionPolicy {
    /// admit everything (the pre-SLO behavior; one burst can blow p99
    /// indefinitely)
    #[default]
    Open,
    /// shed new requests routed to a shard whose rolling-window p99
    /// end-to-end latency exceeds `target_ms`; shed requests get exactly
    /// one terminal `ServeEvent::Shed` and are never served
    SheddingP99 { target_ms: f64 },
    /// admit everything, but requests arriving during a breach join the
    /// low-priority queue and only reach a slot when no normal-priority
    /// request is waiting
    Priority { target_ms: f64 },
    /// shed batch-priority requests whose *predicted* completion time
    /// (backlog x calibrated per-token cost + chunked-prefill
    /// serialization) would breach `target_ms` — the gate trips at half
    /// the target to absorb the estimate's full-batch optimism, see
    /// `coordinator::server`. Interactive requests are never shed: they
    /// ride the normal tier ahead of all parked batch work, which
    /// absorbs the shed instead
    Predictive { target_ms: f64 },
}

impl AdmissionPolicy {
    pub fn name(self) -> &'static str {
        match self {
            AdmissionPolicy::Open => "open",
            AdmissionPolicy::SheddingP99 { .. } => "shed-p99",
            AdmissionPolicy::Priority { .. } => "priority",
            AdmissionPolicy::Predictive { .. } => "predict",
        }
    }

    /// Latency target in ms, if the policy has one.
    pub fn target_ms(self) -> Option<f64> {
        match self {
            AdmissionPolicy::Open => None,
            AdmissionPolicy::SheddingP99 { target_ms }
            | AdmissionPolicy::Priority { target_ms }
            | AdmissionPolicy::Predictive { target_ms } => Some(target_ms),
        }
    }
}

/// How the serving engine schedules admitted requests onto workers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SchedulerMode {
    /// seed behavior: deadline-formed batches run to completion
    /// (head-of-line blocking; the ablation baseline)
    #[default]
    Static,
    /// step-driven workers: requests join in-flight batches at step
    /// boundaries, finished slots retire and free capacity immediately
    Continuous,
}

impl SchedulerMode {
    pub fn name(self) -> &'static str {
        match self {
            SchedulerMode::Static => "static",
            SchedulerMode::Continuous => "continuous",
        }
    }
}

/// Batching policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// max requests per batch (compiled graph batch size)
    pub max_batch: usize,
    /// max time the oldest request may wait before the batch is released
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5) }
    }
}

/// A released batch.
#[derive(Debug)]
pub struct Batch {
    pub requests: Vec<Request>,
    pub formed_at: Instant,
}

impl Batch {
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }
}

/// Two-tier FIFO queue + policy: `queue` (normal) drains ahead of `low`
/// (deprioritized by the admission policy).
#[derive(Debug)]
pub struct Batcher {
    policy: BatchPolicy,
    queue: VecDeque<Request>,
    low: VecDeque<Request>,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Self {
        assert!(policy.max_batch >= 1);
        Batcher { policy, queue: VecDeque::new(), low: VecDeque::new() }
    }

    pub fn policy(&self) -> BatchPolicy {
        self.policy
    }

    pub fn push(&mut self, req: Request) {
        self.queue.push_back(req);
    }

    /// Enqueue behind every normal-priority request (SLO-breach
    /// deprioritization): released only when the normal queue is empty.
    pub fn push_low(&mut self, req: Request) {
        self.low.push_back(req);
    }

    /// Return a request to the *front* of the normal tier. The paged
    /// dispatcher uses this for block-budget bounces: a request taken at
    /// a step boundary that found no KV blocks goes back first-in-line
    /// (its arrival order is preserved) instead of re-queuing behind
    /// newer load.
    pub fn push_front(&mut self, req: Request) {
        self.queue.push_front(req);
    }

    /// [`Batcher::push_front`] for the low tier.
    pub fn push_low_front(&mut self, req: Request) {
        self.low.push_front(req);
    }

    /// Whether the next request `take_up_to` would release is
    /// interactive-priority. The paged dispatcher peeks this when a
    /// shard's lanes are full: an interactive head-of-line may still
    /// admit within one step by preempting a batch residency, so it is
    /// worth taking even at zero free slots.
    pub fn front_interactive(&self) -> bool {
        self.queue
            .front()
            .or_else(|| self.low.front())
            .is_some_and(|r| r.priority == Priority::Interactive)
    }

    pub fn pending(&self) -> usize {
        self.queue.len() + self.low.len()
    }

    /// Low-priority requests currently parked.
    pub fn pending_low(&self) -> usize {
        self.low.len()
    }

    /// Oldest request across both tiers — `ready` and `next_deadline`
    /// must agree on it, or the dispatcher busy-spins between a due
    /// deadline and a refused release.
    fn oldest_front(&self) -> Option<&Request> {
        match (self.queue.front(), self.low.front()) {
            (Some(a), Some(b)) => Some(if a.arrival <= b.arrival { a } else { b }),
            (a, b) => a.or(b),
        }
    }

    /// Whether a batch should be released `now`.
    pub fn ready(&self, now: Instant) -> bool {
        if self.pending() >= self.policy.max_batch {
            return true;
        }
        match self.oldest_front() {
            Some(r) => now.duration_since(r.arrival) >= self.policy.max_wait,
            None => false,
        }
    }

    /// When the oldest queued request's deadline expires (static-mode
    /// release even if the batch is not full). `None` when empty.
    pub fn next_deadline(&self) -> Option<Instant> {
        self.oldest_front().map(|r| r.arrival + self.policy.max_wait)
    }

    /// Pop up to `n` requests, normal tier first, FIFO within each tier.
    fn pop_tiered(&mut self, n: usize) -> Vec<Request> {
        let k = self.queue.len().min(n);
        let mut out: Vec<Request> = self.queue.drain(..k).collect();
        let k = self.low.len().min(n - out.len());
        out.extend(self.low.drain(..k));
        out
    }

    /// Continuous-mode admission: immediately pop up to `n` requests
    /// (the shard's free slot count) — no deadline, no batch formation.
    pub fn take_up_to(&mut self, n: usize) -> Vec<Request> {
        self.pop_tiered(n)
    }

    /// Release the next batch if the policy allows.
    pub fn take(&mut self, now: Instant) -> Option<Batch> {
        if !self.ready(now) {
            return None;
        }
        let requests = self.pop_tiered(self.policy.max_batch);
        Some(Batch { requests, formed_at: now })
    }

    /// Drain everything regardless of deadline (shutdown path).
    pub fn flush(&mut self) -> Vec<Batch> {
        let mut out = Vec::new();
        while self.pending() > 0 {
            out.push(Batch {
                requests: self.pop_tiered(self.policy.max_batch),
                formed_at: Instant::now(),
            });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, UsizeRange};

    fn req(id: u64) -> Request {
        Request::new(id, vec![1, 2], 4)
    }

    #[test]
    fn releases_on_full_batch() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 2, max_wait: Duration::from_secs(10) });
        b.push(req(1));
        assert!(b.take(Instant::now()).is_none());
        b.push(req(2));
        let batch = b.take(Instant::now()).unwrap();
        assert_eq!(batch.len(), 2);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn releases_on_deadline() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) });
        b.push(req(1));
        assert!(b.take(Instant::now()).is_none());
        std::thread::sleep(Duration::from_millis(3));
        let batch = b.take(Instant::now()).unwrap();
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn oversized_queue_splits() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 3, max_wait: Duration::ZERO });
        for i in 0..7 {
            b.push(req(i));
        }
        let sizes: Vec<usize> = b.flush().iter().map(Batch::len).collect();
        assert_eq!(sizes, vec![3, 3, 1]);
    }

    #[test]
    fn fifo_order_preserved() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 4, max_wait: Duration::ZERO });
        for i in 0..4 {
            b.push(req(i));
        }
        let batch = b.take(Instant::now()).unwrap();
        let ids: Vec<u64> = batch.requests.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn take_up_to_pops_fifo_without_deadline() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 8, max_wait: Duration::from_secs(10) });
        for i in 0..5 {
            b.push(req(i));
        }
        // deadline far away, but continuous admission drains immediately
        let got = b.take_up_to(3);
        assert_eq!(got.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(b.pending(), 2);
        assert_eq!(b.take_up_to(9).len(), 2);
        assert!(b.take_up_to(4).is_empty());
    }

    #[test]
    fn next_deadline_tracks_oldest() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(7) });
        assert!(b.next_deadline().is_none());
        let r = req(1);
        let expect = r.arrival + Duration::from_millis(7);
        b.push(r);
        b.push(req(2));
        assert_eq!(b.next_deadline(), Some(expect));
    }

    #[test]
    fn scheduler_mode_names() {
        assert_eq!(SchedulerMode::default(), SchedulerMode::Static);
        assert_eq!(SchedulerMode::Static.name(), "static");
        assert_eq!(SchedulerMode::Continuous.name(), "continuous");
    }

    #[test]
    fn admission_policy_names_and_targets() {
        assert_eq!(AdmissionPolicy::default(), AdmissionPolicy::Open);
        assert_eq!(AdmissionPolicy::Open.name(), "open");
        assert_eq!(AdmissionPolicy::Open.target_ms(), None);
        let shed = AdmissionPolicy::SheddingP99 { target_ms: 25.0 };
        assert_eq!(shed.name(), "shed-p99");
        assert_eq!(shed.target_ms(), Some(25.0));
        let prio = AdmissionPolicy::Priority { target_ms: 10.0 };
        assert_eq!(prio.name(), "priority");
        assert_eq!(prio.target_ms(), Some(10.0));
        let pred = AdmissionPolicy::Predictive { target_ms: 40.0 };
        assert_eq!(pred.name(), "predict");
        assert_eq!(pred.target_ms(), Some(40.0));
    }

    #[test]
    fn low_priority_drains_after_normal() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 8, max_wait: Duration::ZERO });
        b.push_low(req(10));
        b.push(req(1));
        b.push_low(req(11));
        b.push(req(2));
        assert_eq!(b.pending(), 4);
        assert_eq!(b.pending_low(), 2);
        // normal tier first even though a low request arrived earlier
        let got = b.take_up_to(3);
        assert_eq!(got.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2, 10]);
        assert_eq!(b.take_up_to(9).iter().map(|r| r.id).collect::<Vec<_>>(), vec![11]);
    }

    #[test]
    fn low_priority_alone_still_releases_on_deadline() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 8, max_wait: Duration::ZERO });
        let r = req(1);
        let expect = r.arrival + Duration::ZERO;
        b.push_low(r);
        // parked low request must not starve forever: the deadline and
        // readiness checks see it
        assert_eq!(b.next_deadline(), Some(expect));
        let batch = b.take(Instant::now()).unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn flush_covers_both_tiers() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 3, max_wait: Duration::ZERO });
        for i in 0..4 {
            b.push(req(i));
        }
        for i in 4..6 {
            b.push_low(req(i));
        }
        let batches = b.flush();
        let total: usize = batches.iter().map(Batch::len).sum();
        assert_eq!(total, 6);
        let first: Vec<u64> = batches[0].requests.iter().map(|r| r.id).collect();
        assert_eq!(first, vec![0, 1, 2]);
    }

    #[test]
    fn push_front_returns_a_bounce_first_in_line() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 8, max_wait: Duration::ZERO });
        b.push(req(1));
        b.push(req(2));
        let mut got = b.take_up_to(2);
        assert_eq!(got.len(), 2);
        // request 1 found no KV blocks: back to the front, not the back
        b.push(req(3));
        b.push_front(got.remove(0));
        assert_eq!(
            b.take_up_to(9).iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![1, 3],
            "bounced request keeps its arrival-order position"
        );
        // low-tier bounce stays in the low tier, ahead of newer low load
        b.push_low(req(20));
        b.push_low_front(req(10));
        b.push(req(4));
        assert_eq!(b.take_up_to(9).iter().map(|r| r.id).collect::<Vec<_>>(), vec![4, 10, 20]);
    }

    #[test]
    fn front_interactive_peeks_the_next_release() {
        use super::super::request::Priority;
        let mut b = Batcher::new(BatchPolicy::default());
        assert!(!b.front_interactive(), "empty queue has no interactive head");
        b.push(req(1).with_priority(Priority::Batch));
        b.push(req(2));
        assert!(!b.front_interactive(), "batch request is head-of-line");
        let _ = b.take_up_to(1);
        assert!(b.front_interactive());
        let _ = b.take_up_to(1);
        // low tier is peeked once normal drains
        b.push_low(req(3));
        assert!(b.front_interactive());
        b.push(req(4).with_priority(Priority::Batch));
        assert!(!b.front_interactive(), "normal tier releases first");
    }

    #[test]
    fn prop_batches_never_exceed_max_and_lose_nothing() {
        check(11, 100, &UsizeRange(1, 50), |n| {
            let mut b =
                Batcher::new(BatchPolicy { max_batch: 4, max_wait: Duration::ZERO });
            for i in 0..*n {
                b.push(req(i as u64));
            }
            let batches = b.flush();
            let total: usize = batches.iter().map(Batch::len).sum();
            total == *n && batches.iter().all(|x| x.len() <= 4 && !x.is_empty())
        });
    }
}
