//! Seeded fault injection: the deterministic failure schedule the
//! recovery machinery is tested against.
//!
//! A [`FaultPlan`] names *what goes wrong and when* — shard crashes at a
//! decode step, transient stalls of K steps, link chunk corruption with
//! probability p, scheduled recoveries — under a single seed, so a
//! failing recovery run replays bit-identically. The plan itself does
//! nothing: it compiles into per-shard [`ShardFaults`] executed inside
//! the sim backend (the "device" dies; the scheduler has to notice) and
//! per-rank [`LinkFaults`] drawn by the ring transport.
//!
//! A `recover:<shard>@<step>` clause schedules a *replacement device*
//! for the shard: at recovery step `at_step` (counted in calibrated
//! fused-decode step times on the dispatcher's clock) the device is
//! available, and the shard rejoins as soon as it is both available and
//! Dead. Each rejoin starts a fresh *incarnation* of the shard;
//! [`FaultPlan::shard_faults_incarnation`] hands incarnation `k` the
//! k-th scheduled crash (steps counted on that incarnation's own decode
//! clock), which is how a flapping shard — crash, recover, crash again
//! — is scripted deterministically.
//!
//! [`FaultSpec`] carries the server-side handling knobs next to the
//! plan: the per-shard step deadline, the miss budget `M` driving the
//! Healthy → Suspect → Dead lifecycle ([`ShardHealth`]), and the rejoin
//! ramp length (clean deadlines a recovered shard must string together
//! on probe traffic before regaining its full routing share). Liveness
//! tracking is armed only when a plan is present — on a healthy
//! deployment (and on slow CI runners) there is no wall-clock deadline
//! that could false-kill a busy shard.
//!
//! # Plan grammar
//!
//! [`FaultPlan::parse`] accepts the comma-separated spec the CLI's
//! `serve --fault-plan` flag takes. Each clause is one of:
//!
//! | clause | meaning |
//! |---|---|
//! | `crash:<shard>@<step>` | shard dies permanently at fused-decode step `<step>` (0-based, on that incarnation's own clock) |
//! | `stall:<shard>@<step>x<steps>` | shard burns `<steps>` extra step costs of wall clock at `<step>`, then resumes |
//! | `recover:<shard>@<step>` | a replacement device for `<shard>` becomes available at dispatcher recovery step `<step>` |
//! | `corrupt:<p>` | each collective wire chunk is corrupted with probability `p` in `[0, 1]` |
//! | `seed:<n>` | RNG seed for the corruption draws (defaults to 0) |
//!
//! Example: `crash:1@40,recover:1@120,seed:7` kills shard 1 at its 40th
//! fused decode step and schedules a replacement at recovery step 120.
//! Repeated `crash:`/`recover:` clauses for the same shard script a
//! flapping device: the k-th crash clause applies to the shard's k-th
//! incarnation.

use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::collective::LinkFaults;
use crate::runtime::ShardFaults;

/// Permanent crash of one shard at a 0-based fused-decode step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CrashFault {
    pub shard: usize,
    pub at_step: u64,
}

/// Transient stall: at `at_step`, the shard burns `steps` extra
/// fused-step costs of wall clock, then resumes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StallFault {
    pub shard: usize,
    pub at_step: u64,
    pub steps: u64,
}

/// Scheduled recovery: a replacement device for `shard` becomes
/// available at dispatcher recovery step `at_step` (units of the
/// calibrated fused-decode step time). The shard rejoins at the later
/// of availability and death detection — a replacement cannot rejoin a
/// shard that is still alive.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RecoverFault {
    pub shard: usize,
    pub at_step: u64,
}

/// A seeded, reproducible failure schedule for one serving run.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FaultPlan {
    pub crashes: Vec<CrashFault>,
    pub stalls: Vec<StallFault>,
    pub recovers: Vec<RecoverFault>,
    /// per-chunk wire corruption probability in [0, 1]
    pub corrupt_p: f64,
    pub seed: u64,
}

impl FaultPlan {
    pub fn new(seed: u64) -> Self {
        FaultPlan { seed, ..Default::default() }
    }

    /// Schedule a permanent crash of `shard` at decode step `at_step`.
    pub fn crash(mut self, shard: usize, at_step: u64) -> Self {
        self.crashes.push(CrashFault { shard, at_step });
        self
    }

    /// Schedule a `steps`-step transient stall on `shard` at `at_step`.
    pub fn stall(mut self, shard: usize, at_step: u64, steps: u64) -> Self {
        self.stalls.push(StallFault { shard, at_step, steps });
        self
    }

    /// Set the per-chunk wire corruption probability.
    pub fn corrupt(mut self, p: f64) -> Self {
        self.corrupt_p = p;
        self
    }

    /// Schedule a replacement device for `shard` at recovery step
    /// `at_step`.
    pub fn recover(mut self, shard: usize, at_step: u64) -> Self {
        self.recovers.push(RecoverFault { shard, at_step });
        self
    }

    /// Compile the schedule one sim shard executes. Multiple crash
    /// clauses for a shard collapse to the earliest (a device dies
    /// once); stalls keep the first clause.
    pub fn shard_faults(&self, shard: usize) -> ShardFaults {
        self.shard_faults_incarnation(shard, 0)
    }

    /// Compile the schedule for incarnation `incarnation` of a shard
    /// (0 = the original device, 1 = the first replacement, ...).
    /// Incarnation `k` receives the shard's k-th scheduled crash (by
    /// ascending step), with the step counted on that incarnation's own
    /// decode clock — so `crash:1@40,recover:1@120,crash:1@60` crashes
    /// the replacement at *its* step 60. Stalls apply to the original
    /// incarnation only.
    pub fn shard_faults_incarnation(&self, shard: usize, incarnation: usize) -> ShardFaults {
        let mut crash_steps: Vec<u64> = self
            .crashes
            .iter()
            .filter(|c| c.shard == shard)
            .map(|c| c.at_step)
            .collect();
        crash_steps.sort_unstable();
        ShardFaults {
            crash_at_step: crash_steps.get(incarnation).copied(),
            stall: if incarnation == 0 {
                self.stalls
                    .iter()
                    .find(|s| s.shard == shard)
                    .map(|s| (s.at_step, s.steps))
            } else {
                None
            },
        }
    }

    /// Recovery steps scheduled for a shard, ascending. One rejoin is
    /// granted per clause: a shard that dies again after consuming its
    /// last clause stays dead.
    pub fn recover_steps(&self, shard: usize) -> Vec<u64> {
        let mut steps: Vec<u64> = self
            .recovers
            .iter()
            .filter(|r| r.shard == shard)
            .map(|r| r.at_step)
            .collect();
        steps.sort_unstable();
        steps
    }

    /// Whether any recovery is scheduled (arms the rejoin machinery).
    pub fn has_recovery(&self) -> bool {
        !self.recovers.is_empty()
    }

    /// Per-rank corruption schedule for the ring transport, derived
    /// from the plan seed so ranks draw independent but reproducible
    /// streams.
    pub fn link_faults(&self, rank: usize) -> LinkFaults {
        LinkFaults::new(
            self.corrupt_p,
            self.seed ^ (rank as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        )
    }

    /// Parse a plan from the `--fault-plan` CLI spec: comma-separated
    /// clauses `crash:<shard>@<step>`, `stall:<shard>@<step>x<steps>`,
    /// `recover:<shard>@<step>`, `corrupt:<p>`, `seed:<n>`. Example:
    ///
    /// ```text
    /// crash:1@40,recover:1@120,stall:2@10x5,corrupt:0.01,seed:7
    /// ```
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        fn num<T: std::str::FromStr>(what: &str, clause: &str, s: &str) -> Result<T> {
            s.trim()
                .parse::<T>()
                .map_err(|_| anyhow!("fault clause `{clause}`: bad {what} `{}`", s.trim()))
        }
        let mut plan = FaultPlan::default();
        for clause in spec.split(',').map(str::trim).filter(|c| !c.is_empty()) {
            let (kind, rest) = clause
                .split_once(':')
                .ok_or_else(|| anyhow!("fault clause `{clause}` needs a `kind:` prefix"))?;
            match kind {
                "crash" => {
                    let (shard, step) = rest.split_once('@').ok_or_else(|| {
                        anyhow!("crash clause `{clause}` needs `shard@step`")
                    })?;
                    plan.crashes.push(CrashFault {
                        shard: num("shard", clause, shard)?,
                        at_step: num("step", clause, step)?,
                    });
                }
                "stall" => {
                    let (shard, at) = rest.split_once('@').ok_or_else(|| {
                        anyhow!("stall clause `{clause}` needs `shard@step x steps`")
                    })?;
                    let (step, steps) = at.split_once('x').ok_or_else(|| {
                        anyhow!("stall clause `{clause}` needs `@<step>x<steps>`")
                    })?;
                    plan.stalls.push(StallFault {
                        shard: num("shard", clause, shard)?,
                        at_step: num("step", clause, step)?,
                        steps: num("steps", clause, steps)?,
                    });
                }
                "recover" => {
                    let (shard, step) = rest.split_once('@').ok_or_else(|| {
                        anyhow!("recover clause `{clause}` needs `shard@step`")
                    })?;
                    plan.recovers.push(RecoverFault {
                        shard: num("shard", clause, shard)?,
                        at_step: num("step", clause, step)?,
                    });
                }
                "corrupt" => {
                    let p: f64 = num("probability", clause, rest)?;
                    if !(0.0..=1.0).contains(&p) {
                        bail!("fault clause `{clause}`: probability must be in [0, 1]");
                    }
                    plan.corrupt_p = p;
                }
                "seed" => plan.seed = num("seed", clause, rest)?,
                other => bail!(
                    "unknown fault clause kind `{other}` (expected crash | stall | \
                     recover | corrupt | seed)"
                ),
            }
        }
        Ok(plan)
    }
}

/// Server-side fault handling: the (optional) injection plan plus the
/// detection knobs. With `plan: None` (the default) no fault is
/// injected and liveness tracking stays disarmed.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSpec {
    pub plan: Option<FaultPlan>,
    /// a shard with runnable work that stays silent past this deadline
    /// accrues one miss
    pub step_deadline: Duration,
    /// consecutive misses before Suspect becomes Dead (the `M` in the
    /// detection-latency gate: detection must land within `M + 1`
    /// deadlines)
    pub max_misses: u32,
    /// rejoin ramp: clean step deadlines a recovered shard must string
    /// together on probe traffic (at most one in-flight request) before
    /// it regains its full routing share
    pub ramp_deadlines: u32,
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec {
            plan: None,
            step_deadline: Duration::from_millis(250),
            max_misses: 3,
            ramp_deadlines: 3,
        }
    }
}

impl FaultSpec {
    pub fn with_plan(plan: FaultPlan) -> Self {
        FaultSpec { plan: Some(plan), ..Default::default() }
    }

    /// Liveness tracking runs only when a plan is configured.
    pub fn active(&self) -> bool {
        self.plan.is_some()
    }

    /// Total silence (with runnable work) after which a shard is Dead.
    pub fn death_deadline(&self) -> Duration {
        self.step_deadline * self.max_misses.max(1)
    }
}

/// Shard lifecycle as seen by the dispatcher's liveness tracker.
///
/// `Healthy` shards met their last step deadline. A shard with
/// runnable work that misses one deadline is `Suspect` (still routed
/// to — stalls recover); missing `max_misses` consecutive deadlines is
/// `Dead`: its sender is dropped, its in-flight requests migrate, and
/// it leaves the routing set. A `Dead` shard re-enters as `Healthy`
/// only through the rejoin path (a scheduled `recover:` clause or a
/// promoted warm standby), behind the router's probe ramp; every
/// transition is idempotent — re-declaring a dead shard dead, or
/// re-recovering an alive one, is a no-op.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ShardHealth {
    #[default]
    Healthy,
    Suspect,
    Dead,
}

impl ShardHealth {
    pub fn name(self) -> &'static str {
        match self {
            ShardHealth::Healthy => "healthy",
            ShardHealth::Suspect => "suspect",
            ShardHealth::Dead => "dead",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_spec() {
        let p = FaultPlan::parse(
            "crash:1@40, recover:1@120, stall:2@10x5, corrupt:0.01, seed:7",
        )
        .unwrap();
        assert_eq!(p.crashes, vec![CrashFault { shard: 1, at_step: 40 }]);
        assert_eq!(p.recovers, vec![RecoverFault { shard: 1, at_step: 120 }]);
        assert_eq!(p.stalls, vec![StallFault { shard: 2, at_step: 10, steps: 5 }]);
        assert_eq!(p.corrupt_p, 0.01);
        assert_eq!(p.seed, 7);
        assert!(p.has_recovery());
        assert_eq!(FaultPlan::parse("").unwrap(), FaultPlan::default());
        assert!(!FaultPlan::default().has_recovery());
    }

    #[test]
    fn parse_rejects_malformed_clauses() {
        for bad in [
            "crash:1",          // missing @step
            "crash:x@4",        // bad shard
            "stall:2@10",       // missing xsteps
            "recover:1",        // missing @step
            "recover:x@4",      // bad shard
            "corrupt:1.5",      // out of range
            "corrupt:x",        // not a number
            "explode:1@2",      // unknown kind
            "seed",             // no colon
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "`{bad}` should not parse");
        }
    }

    #[test]
    fn compiles_per_shard_schedules() {
        let p = FaultPlan::new(3).crash(1, 40).crash(1, 20).stall(0, 5, 3);
        assert_eq!(p.shard_faults(1).crash_at_step, Some(20), "earliest crash wins");
        assert_eq!(p.shard_faults(0).stall, Some((5, 3)));
        assert!(p.shard_faults(2).is_empty());
    }

    #[test]
    fn incarnations_take_crashes_in_step_order() {
        // flap script: original dies at 40, the replacement at its own
        // step 60, a second replacement never crashes
        let p = FaultPlan::new(5).crash(1, 60).crash(1, 40).stall(1, 5, 2);
        assert_eq!(p.shard_faults_incarnation(1, 0).crash_at_step, Some(40));
        assert_eq!(p.shard_faults_incarnation(1, 0).stall, Some((5, 2)));
        let second = p.shard_faults_incarnation(1, 1);
        assert_eq!(second.crash_at_step, Some(60));
        assert_eq!(second.stall, None, "stalls apply to the original incarnation only");
        assert!(p.shard_faults_incarnation(1, 2).is_empty());
        assert!(p.shard_faults_incarnation(0, 0).is_empty());
    }

    #[test]
    fn recover_steps_sort_ascending_per_shard() {
        let p = FaultPlan::new(1).recover(2, 90).recover(1, 120).recover(2, 30);
        assert_eq!(p.recover_steps(2), vec![30, 90]);
        assert_eq!(p.recover_steps(1), vec![120]);
        assert!(p.recover_steps(0).is_empty());
        assert!(p.has_recovery());
    }

    #[test]
    fn link_faults_are_seeded_per_rank() {
        let p = FaultPlan::new(9).corrupt(0.5);
        let mut a = p.link_faults(0);
        let mut b = p.link_faults(0);
        let draws_a: Vec<bool> = (0..64).map(|_| a.corrupt_next()).collect();
        let draws_b: Vec<bool> = (0..64).map(|_| b.corrupt_next()).collect();
        assert_eq!(draws_a, draws_b, "same rank + seed must replay identically");
        assert!(draws_a.iter().any(|c| *c) && !draws_a.iter().all(|c| *c));
        let mut c = p.link_faults(1);
        let draws_c: Vec<bool> = (0..64).map(|_| c.corrupt_next()).collect();
        assert_ne!(draws_a, draws_c, "ranks draw independent streams");
    }

    #[test]
    fn spec_defaults_are_disarmed() {
        let s = FaultSpec::default();
        assert!(!s.active());
        assert_eq!(s.death_deadline(), Duration::from_millis(750));
        assert!(FaultSpec::with_plan(FaultPlan::new(1).crash(0, 1)).active());
        assert_eq!(ShardHealth::default(), ShardHealth::Healthy);
        assert_eq!(ShardHealth::Dead.name(), "dead");
    }
}
