//! Synthetic serving workload generator: Poisson arrivals, Zipf-ish
//! prompt/output length mix — the open-loop traffic the batching ablation
//! and serve benches drive (substitute for production traces, DESIGN.md §3).

use crate::corpus::{self, XorShift64Star};

use super::request::{Priority, Request};

/// Workload shape parameters.
#[derive(Debug, Clone, Copy)]
pub struct WorkloadSpec {
    pub n_requests: usize,
    /// mean arrival rate (requests/second) for the Poisson process
    pub rate_per_s: f64,
    pub prompt_min: usize,
    pub prompt_max: usize,
    pub max_new_min: usize,
    pub max_new_max: usize,
    /// fraction of requests drawn as full `prompt_max`-length prompts —
    /// the heavy tail that makes prefill stalls visible (0.0 keeps the
    /// uniform mix)
    pub long_frac: f64,
    /// fraction of requests tagged `Priority::Interactive`; the rest are
    /// `Priority::Batch` (CLI `--priority-mix`). 1.0 keeps the
    /// pre-priority all-interactive workload
    pub interactive_frac: f64,
    /// fraction of requests prefixed with a synthetic system prompt
    /// drawn from [`system_prompt_bank`] (CLI `--shared-prefix`) — the
    /// shared-prefix chat traffic the prefix cache converts into block
    /// hits. 0.0 consumes no randomness, so pinned seeds reproduce
    pub shared_prefix_frac: f64,
    /// fraction of requests reshaped into the prefill-heavy extreme —
    /// full `prompt_max` prompt, minimum `max_new_min` decode (CLI
    /// `--prefill-heavy`): the summarization-style traffic that starves
    /// a mixed fleet's decode path and motivates disaggregation. 0.0
    /// consumes no randomness, so pinned seeds reproduce
    pub prefill_heavy_frac: f64,
    pub seed: u64,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        WorkloadSpec {
            n_requests: 32,
            rate_per_s: 100.0,
            prompt_min: 8,
            prompt_max: 48,
            max_new_min: 4,
            max_new_max: 24,
            long_frac: 0.0,
            interactive_frac: 1.0,
            shared_prefix_frac: 0.0,
            prefill_heavy_frac: 0.0,
            seed: 42,
        }
    }
}

/// Length of each synthetic system prompt in the bank. With the BOS the
/// router prepends, a 63-token system prompt fills exactly four 16-token
/// KV blocks — every block of the shared prefix is cacheable.
pub const SYSTEM_PROMPT_TOKENS: usize = 63;

/// The synthetic system-prompt bank: four fixed token sequences standing
/// in for the handful of system prompts most chat traffic shares. Fixed
/// seeds (independent of `WorkloadSpec::seed`) keep the bank identical
/// across workloads, so prefix-cache hit rates are comparable between
/// runs.
pub fn system_prompt_bank() -> Vec<Vec<i32>> {
    (0..4u64)
        .map(|i| corpus::generate_tokens(SYSTEM_PROMPT_TOKENS, 0xB10C + i))
        .collect()
}

/// One generated arrival: the request plus its offset from workload start.
#[derive(Debug, Clone)]
pub struct Arrival {
    pub at_s: f64,
    pub request: Request,
}

/// Generate the arrival sequence (deterministic under the seed).
pub fn generate(spec: &WorkloadSpec) -> Vec<Arrival> {
    let mut rng = XorShift64Star::new(spec.seed);
    let bank = system_prompt_bank();
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(spec.n_requests);
    for i in 0..spec.n_requests {
        // exponential inter-arrival
        let u = rng.next_f64().max(1e-12);
        t += -u.ln() / spec.rate_per_s;
        // long_frac == 0.0 must consume no randomness so existing seeds
        // reproduce their pinned workloads bit-for-bit
        let is_long = spec.long_frac > 0.0 && rng.next_f64() < spec.long_frac;
        let plen = if is_long {
            spec.prompt_max
        } else {
            spec.prompt_min
                + rng.next_below((spec.prompt_max - spec.prompt_min + 1) as u64) as usize
        };
        let max_new = spec.max_new_min
            + rng.next_below((spec.max_new_max - spec.max_new_min + 1) as u64) as usize;
        // interactive_frac >= 1.0 must consume no randomness so existing
        // seeds reproduce their pinned workloads bit-for-bit
        let mut priority = Priority::Interactive;
        if spec.interactive_frac < 1.0 && rng.next_f64() >= spec.interactive_frac {
            priority = Priority::Batch;
        }
        // shared_prefix_frac == 0.0 must consume no randomness so existing
        // seeds reproduce their pinned workloads bit-for-bit. A shared
        // request prepends one bank prompt to its unique tail, so its
        // total length exceeds `prompt_max` by SYSTEM_PROMPT_TOKENS —
        // that's the shape of chat traffic: fixed system prompt + turn.
        let shared =
            spec.shared_prefix_frac > 0.0 && rng.next_f64() < spec.shared_prefix_frac;
        // prefill_heavy_frac == 0.0 must consume no randomness so existing
        // seeds reproduce their pinned workloads bit-for-bit. A heavy
        // request overrides the already-drawn lengths (the draws above
        // still happen, keeping the stream aligned for its neighbors):
        // maximal prompt, minimal decode — the shape that starves a
        // mixed fleet's decode path.
        let heavy =
            spec.prefill_heavy_frac > 0.0 && rng.next_f64() < spec.prefill_heavy_frac;
        let (plen, max_new) =
            if heavy { (spec.prompt_max, spec.max_new_min) } else { (plen, max_new) };
        let mut prompt = if shared {
            bank[rng.next_below(bank.len() as u64) as usize].clone()
        } else {
            Vec::new()
        };
        prompt.extend(corpus::generate_tokens(
            plen,
            spec.seed.wrapping_add(1000 + i as u64),
        ));
        out.push(Arrival {
            at_s: t,
            request: Request::new(i as u64 + 1, prompt, max_new).with_priority(priority),
        });
    }
    out
}

/// Drop the timing and return just the requests (offline workloads).
pub fn requests(spec: &WorkloadSpec) -> Vec<Request> {
    generate(spec).into_iter().map(|a| a.request).collect()
}

/// Closed-loop firehose: the same request mix with every arrival at t=0,
/// so the server is saturated from the first step (capacity measurement,
/// no arrival-process queueing).
pub fn firehose(spec: &WorkloadSpec) -> Vec<Arrival> {
    let mut arr = generate(spec);
    for a in &mut arr {
        a.at_s = 0.0;
    }
    arr
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_under_seed() {
        let a = generate(&WorkloadSpec::default());
        let b = generate(&WorkloadSpec::default());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at_s, y.at_s);
            assert_eq!(x.request.prompt, y.request.prompt);
        }
    }

    #[test]
    fn arrivals_monotone_and_rate_plausible() {
        let spec = WorkloadSpec { n_requests: 500, rate_per_s: 50.0, ..Default::default() };
        let arr = generate(&spec);
        assert!(arr.windows(2).all(|w| w[0].at_s <= w[1].at_s));
        let span = arr.last().unwrap().at_s;
        let expected = 500.0 / 50.0;
        assert!((span / expected - 1.0).abs() < 0.35, "span {span} vs {expected}");
    }

    #[test]
    fn lengths_within_bounds() {
        let spec = WorkloadSpec { n_requests: 100, ..Default::default() };
        for a in generate(&spec) {
            assert!((spec.prompt_min..=spec.prompt_max).contains(&a.request.prompt.len()));
            assert!(
                (spec.max_new_min..=spec.max_new_max).contains(&a.request.max_new_tokens)
            );
        }
    }

    #[test]
    fn firehose_same_mix_zero_offsets() {
        let spec = WorkloadSpec { n_requests: 20, ..Default::default() };
        let open = generate(&spec);
        let fire = firehose(&spec);
        assert!(fire.iter().all(|a| a.at_s == 0.0));
        for (o, f) in open.iter().zip(&fire) {
            assert_eq!(o.request.prompt, f.request.prompt);
            assert_eq!(o.request.max_new_tokens, f.request.max_new_tokens);
        }
    }

    #[test]
    fn long_frac_zero_consumes_no_extra_randomness() {
        let base = generate(&WorkloadSpec::default());
        let explicit = generate(&WorkloadSpec { long_frac: 0.0, ..Default::default() });
        for (a, b) in base.iter().zip(&explicit) {
            assert_eq!(a.request.prompt, b.request.prompt);
            assert_eq!(a.at_s, b.at_s);
        }
    }

    #[test]
    fn long_frac_mixes_in_full_length_prompts() {
        let spec = WorkloadSpec { n_requests: 200, long_frac: 0.3, ..Default::default() };
        let arr = generate(&spec);
        let long = arr.iter().filter(|a| a.request.prompt.len() == spec.prompt_max).count();
        // ~60 expected; a uniform mix alone would give ~5
        assert!((30..=100).contains(&long), "long prompts: {long}");
        assert!(arr.iter().all(|a| a.request.prompt.len() >= spec.prompt_min));
    }

    #[test]
    fn all_interactive_consumes_no_extra_randomness() {
        let base = generate(&WorkloadSpec::default());
        let explicit = generate(&WorkloadSpec { interactive_frac: 1.0, ..Default::default() });
        for (a, b) in base.iter().zip(&explicit) {
            assert_eq!(a.request.prompt, b.request.prompt);
            assert_eq!(a.at_s, b.at_s);
            assert_eq!(a.request.priority, Priority::Interactive);
        }
    }

    #[test]
    fn priority_mix_tags_batch_requests() {
        let spec =
            WorkloadSpec { n_requests: 200, interactive_frac: 0.5, ..Default::default() };
        let arr = generate(&spec);
        let batch =
            arr.iter().filter(|a| a.request.priority == Priority::Batch).count();
        // ~100 expected; wide band for the deterministic PRNG draw
        assert!((60..=140).contains(&batch), "batch-priority requests: {batch}");
        // mix is reproducible under the seed
        let again = generate(&spec);
        for (a, b) in arr.iter().zip(&again) {
            assert_eq!(a.request.priority, b.request.priority);
        }
    }

    #[test]
    fn zero_interactive_frac_tags_everything_batch() {
        let spec =
            WorkloadSpec { n_requests: 50, interactive_frac: 0.0, ..Default::default() };
        assert!(generate(&spec)
            .iter()
            .all(|a| a.request.priority == Priority::Batch));
    }

    #[test]
    fn shared_prefix_zero_consumes_no_extra_randomness() {
        let base = generate(&WorkloadSpec::default());
        let explicit =
            generate(&WorkloadSpec { shared_prefix_frac: 0.0, ..Default::default() });
        for (a, b) in base.iter().zip(&explicit) {
            assert_eq!(a.request.prompt, b.request.prompt);
            assert_eq!(a.at_s, b.at_s);
        }
    }

    #[test]
    fn shared_prefix_prepends_bank_prompts_reproducibly() {
        let spec = WorkloadSpec {
            n_requests: 200,
            shared_prefix_frac: 0.5,
            ..Default::default()
        };
        let arr = generate(&spec);
        let bank = system_prompt_bank();
        let shared: Vec<_> = arr
            .iter()
            .filter(|a| {
                bank.iter().any(|sys| a.request.prompt.starts_with(sys))
            })
            .collect();
        // ~100 expected; wide band for the deterministic PRNG draw
        assert!((60..=140).contains(&shared.len()), "shared: {}", shared.len());
        // shared prompts carry the full 63-token system prefix plus a
        // unique per-request tail within the configured bounds
        for a in &shared {
            let tail = a.request.prompt.len() - SYSTEM_PROMPT_TOKENS;
            assert!((spec.prompt_min..=spec.prompt_max).contains(&tail));
        }
        assert!(
            shared.windows(2).any(|w| w[0].request.prompt != w[1].request.prompt),
            "tails must differ between shared requests"
        );
        // mix is reproducible under the seed
        let again = generate(&spec);
        for (a, b) in arr.iter().zip(&again) {
            assert_eq!(a.request.prompt, b.request.prompt);
        }
    }

    #[test]
    fn prefill_heavy_zero_consumes_no_extra_randomness() {
        let base = generate(&WorkloadSpec::default());
        let explicit =
            generate(&WorkloadSpec { prefill_heavy_frac: 0.0, ..Default::default() });
        for (a, b) in base.iter().zip(&explicit) {
            assert_eq!(a.request.prompt, b.request.prompt);
            assert_eq!(a.at_s, b.at_s);
            assert_eq!(a.request.max_new_tokens, b.request.max_new_tokens);
        }
    }

    #[test]
    fn prefill_heavy_skews_to_long_prompts_short_decodes() {
        let spec = WorkloadSpec {
            n_requests: 200,
            prefill_heavy_frac: 1.0,
            ..Default::default()
        };
        for a in generate(&spec) {
            assert_eq!(a.request.prompt.len(), spec.prompt_max);
            assert_eq!(a.request.max_new_tokens, spec.max_new_min);
        }
        // a partial mix keeps both shapes and reproduces under the seed
        let half = WorkloadSpec {
            n_requests: 200,
            prefill_heavy_frac: 0.5,
            ..Default::default()
        };
        let arr = generate(&half);
        let heavy = arr
            .iter()
            .filter(|a| {
                a.request.prompt.len() == half.prompt_max
                    && a.request.max_new_tokens == half.max_new_min
            })
            .count();
        // ~100 expected; wide band for the deterministic PRNG draw
        assert!((60..=140).contains(&heavy), "heavy requests: {heavy}");
        let again = generate(&half);
        for (a, b) in arr.iter().zip(&again) {
            assert_eq!(a.request.prompt, b.request.prompt);
            assert_eq!(a.request.max_new_tokens, b.request.max_new_tokens);
        }
        // heavy composes with shared prefixes: the bank prompt rides in
        // front of the full-length tail
        let mixed = WorkloadSpec {
            n_requests: 50,
            prefill_heavy_frac: 1.0,
            shared_prefix_frac: 1.0,
            ..Default::default()
        };
        for a in generate(&mixed) {
            assert_eq!(
                a.request.prompt.len(),
                SYSTEM_PROMPT_TOKENS + mixed.prompt_max
            );
            assert_eq!(a.request.max_new_tokens, mixed.max_new_min);
        }
    }

    #[test]
    fn system_prompt_bank_is_fixed_and_block_aligned() {
        let a = system_prompt_bank();
        let b = system_prompt_bank();
        assert_eq!(a, b, "bank must be seed-independent and stable");
        assert_eq!(a.len(), 4);
        for p in &a {
            assert_eq!(p.len(), SYSTEM_PROMPT_TOKENS);
        }
        // the four prompts are distinct, so cache chains don't collide
        for i in 0..a.len() {
            for j in i + 1..a.len() {
                assert_ne!(a[i], a[j]);
            }
        }
    }

    #[test]
    fn distinct_prompts() {
        let rs = requests(&WorkloadSpec { n_requests: 10, ..Default::default() });
        assert!(rs.windows(2).any(|w| w[0].prompt != w[1].prompt));
    }
}
