//! Calibrated per-token cost model for *predictive* admission.
//!
//! The trailing SLO gate (`metrics::RollingWindow` over completed
//! latencies) only learns about an overload after slow completions land;
//! during an arrival ramp it sheds after the breach instead of before
//! it. The [`CostEstimator`] closes that loop: fitted from the same
//! calibrated knobs the sim backend burns ([`SimCost`]) — or from the
//! measured `BENCH_hotpath.json` PJRT profile — it converts a shard's
//! in-flight token backlog into a *predicted completion time* for a
//! candidate request:
//!
//! ```text
//! t_pred = (backlog_prefill + prompt_len)  * prefill_s_per_token
//!        + (backlog_decode  + decode_len)  * decode_s_per_token
//!        + chunk_serialization(prompt_len, prefill_chunk)
//! ```
//!
//! where `decode_s_per_token` amortizes the fused step launch across the
//! compiled batch (a step generates up to `batch` tokens for one launch),
//! and the serialization term charges one interleaved decode-step launch
//! per extra prefill chunk — the price chunked prefill pays for bounding
//! its neighbors' stalls. The dispatcher gates on `t_pred` *at arrival*,
//! so the shed decision lands during the ramp, not a window later.

use std::path::Path;

use anyhow::{Context, Result};

use crate::runtime::SimCost;

/// Per-token completion-time model for one worker shard.
#[derive(Debug, Clone, Copy)]
pub struct CostEstimator {
    /// seconds to ingest one prompt token
    prefill_s_per_token: f64,
    /// effective seconds per generated token with the fused-step launch
    /// amortized across the compiled batch
    decode_s_per_token: f64,
    /// fixed fused-step launch cost (seconds) — what an extra prefill
    /// chunk boundary serializes behind
    step_s: f64,
    /// compiled graph batch size the decode amortization assumes
    batch: usize,
}

impl CostEstimator {
    /// Fit from the sim backend's calibrated cost knobs (the same model
    /// `SimModel` spin-waits, so sim-backend predictions are tautologically
    /// calibrated — the interesting fit is `from_hotpath_profile`).
    pub fn from_sim_cost(cost: &SimCost, batch: usize) -> Self {
        let b = batch.max(1);
        CostEstimator {
            prefill_s_per_token: cost.prefill_us_per_token * 1e-6,
            decode_s_per_token: cost.decode_us_per_token(b) * 1e-6,
            step_s: cost.decode_step_us * 1e-6,
            batch: b,
        }
    }

    /// Fit from a `BENCH_hotpath.json` profile (either the row array
    /// `perf_hotpath` writes — fitted via `SimCost::fit_hotpath` — or an
    /// explicit cost-knob object). This is the PJRT path: measure step
    /// times once, then gate real serving on the measured costs.
    pub fn from_hotpath_profile(path: &Path, batch: usize) -> Result<Self> {
        let cost = SimCost::load_profile(path)
            .with_context(|| format!("fit cost estimator from {}", path.display()))?;
        Ok(Self::from_sim_cost(&cost, batch))
    }

    /// Compiled batch size the decode amortization assumes.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Fixed fused-step launch cost (seconds). Doubles as the
    /// dispatcher's decode-step clock: fault-plan steps (`crash@N`,
    /// `recover@N`) are counted in fused decode calls, so `N * step_s()`
    /// converts a plan step into elapsed serving time.
    pub fn step_s(&self) -> f64 {
        self.step_s
    }

    /// The estimator for degraded-mode serving at `kv_bits`-wide KV
    /// pages. Fused decode is memory-bound on streaming the KV cache, so
    /// the per-slot share of the decode rate scales with `kv_bits / 8`
    /// (mirroring `SimModel::set_kv_bits`); the step launch and prefill
    /// rates are width-independent. The dispatcher swaps this in when
    /// the fleet degrades so admission prices the *actual* (higher)
    /// capacity and sheds less.
    pub fn degraded(&self, kv_bits: u32) -> Self {
        let scale = kv_bits.clamp(1, 8) as f64 / 8.0;
        let launch_share = self.step_s / self.batch as f64;
        let slot_share = (self.decode_s_per_token - launch_share).max(0.0);
        CostEstimator {
            decode_s_per_token: launch_share + slot_share * scale,
            ..*self
        }
    }

    /// The estimator for self-speculative serving: each decode cycle
    /// runs `k` draft steps through the `draft_bits`-wide variant of
    /// the same weights — each priced at `draft_bits / 8` of a plain
    /// per-token step, since a draft streams that fraction of the
    /// bytes (weights and KV pages alike) — plus one full-price fused
    /// verify pass, and emits [`SimCost::spec_tokens_per_cycle`]
    /// tokens in expectation. The effective per-token decode rate is
    /// the cycle cost over that yield, so predictive admission keeps
    /// pricing real throughput when speculation is on. `k == 0` is the
    /// identity.
    pub fn speculative(&self, k: usize, draft_bits: u32) -> Self {
        if k == 0 {
            return *self;
        }
        let scale = draft_bits.clamp(1, 8) as f64 / 8.0;
        let cycle_s = (1.0 + k as f64 * scale) * self.decode_s_per_token;
        CostEstimator {
            decode_s_per_token: cycle_s / SimCost::spec_tokens_per_cycle(k, draft_bits),
            ..*self
        }
    }

    /// Serialization cost (seconds) chunked prefill adds for a prompt:
    /// each chunk boundary after the first waits behind one fused decode
    /// step before the next chunk is paid. `prefill_chunk == 0` is
    /// whole-prompt (one stall, no extra boundaries).
    pub fn chunk_serialization_s(&self, prompt_len: usize, prefill_chunk: usize) -> f64 {
        if prefill_chunk == 0 || prompt_len == 0 {
            return 0.0;
        }
        let chunks = prompt_len.div_ceil(prefill_chunk);
        (chunks.saturating_sub(1)) as f64 * self.step_s
    }

    /// Predicted completion time (seconds) for a candidate with
    /// `prompt_len` prompt tokens and `decode_len` budgeted output
    /// tokens joining a shard whose in-flight backlog (excluding the
    /// candidate) is `(backlog_prefill, backlog_decode)` tokens.
    pub fn predict_s(
        &self,
        backlog: (usize, usize),
        prompt_len: usize,
        decode_len: usize,
        prefill_chunk: usize,
    ) -> f64 {
        let (bp, bd) = backlog;
        (bp + prompt_len) as f64 * self.prefill_s_per_token
            + (bd + decode_len) as f64 * self.decode_s_per_token
            + self.chunk_serialization_s(prompt_len, prefill_chunk)
    }

    /// [`CostEstimator::predict_s`] in milliseconds — the unit the
    /// admission targets are configured in.
    pub fn predict_ms(
        &self,
        backlog: (usize, usize),
        prompt_len: usize,
        decode_len: usize,
        prefill_chunk: usize,
    ) -> f64 {
        self.predict_s(backlog, prompt_len, decode_len, prefill_chunk) * 1e3
    }

    /// KV blocks a request's full residency occupies in a paged cache:
    /// `ceil((prompt + decode budget) / block_size)`. The block-budget
    /// admission question the paged KV cache replaces the hard
    /// slot-count cap with.
    pub fn blocks_for(prompt_len: usize, decode_len: usize, block_size: usize) -> usize {
        if block_size == 0 {
            return 0;
        }
        (prompt_len + decode_len).div_ceil(block_size)
    }

    /// Seconds of decode progress needed to free `deficit_blocks` KV
    /// blocks: a retiring residency returns its blocks only after its
    /// remaining tokens decode, so the drain rate is the shard's decode
    /// rate over the deficit's token mass. The predictive gate adds this
    /// on top of `predict_s` when a candidate's block demand exceeds the
    /// shard's free pool — block pressure becomes latency the gate can
    /// price instead of an invisible admission stall.
    pub fn block_drain_s(&self, deficit_blocks: usize, block_size: usize) -> f64 {
        (deficit_blocks * block_size) as f64 * self.decode_s_per_token
    }

    /// The estimator with every time knob scaled by an online
    /// calibration factor ([`EstimatorCalibration::correction`]). A
    /// single multiplicative residual models "the whole fit was
    /// proportionally off" (contention the static fit can't see), so
    /// prefill, decode, and the step clock stretch together and every
    /// derived margin — chunk serialization, block drain — stays
    /// consistent with the corrected rates. Non-positive or non-finite
    /// factors are ignored (identity).
    pub fn calibrated(&self, correction: f64) -> Self {
        if !correction.is_finite() || correction <= 0.0 {
            return *self;
        }
        CostEstimator {
            prefill_s_per_token: self.prefill_s_per_token * correction,
            decode_s_per_token: self.decode_s_per_token * correction,
            step_s: self.step_s * correction,
            batch: self.batch,
        }
    }
}

/// Online predicted-vs-actual calibration for the [`CostEstimator`].
///
/// The estimator's knobs come from a static fit (sim cost knobs or a
/// pinned hotpath profile), but the serving fleet drifts away from any
/// static fit: degraded widths, speculative yield, and disaggregated
/// handoff all bend real completion times. Every completed request is
/// one labeled sample — the dispatcher records `t_pred` at admission
/// and observes `t_act` at completion — and this regresses the
/// multiplicative residual online as an EMA, so recent traffic
/// dominates. The corrected estimate `predict * correction()` feeds the
/// predictive admission margin, and the prefill:decode re-roling band
/// reads the same calibrated model — the estimator-feedback loop the
/// predictive-admission PR left open.
#[derive(Debug, Clone, Copy, Default)]
pub struct EstimatorCalibration {
    /// EMA of `actual / predicted`
    ratio: f64,
    /// EMA of `|actual - predicted| / predicted`
    abs_err: f64,
    samples: u64,
}

impl EstimatorCalibration {
    /// EMA smoothing: one sample moves the running estimates by 10%.
    const ALPHA: f64 = 0.1;
    /// Clamp band for the correction so a few wild residuals cannot
    /// price the fleet into shedding everything (or admitting blind).
    const CORRECTION_BAND: (f64, f64) = (0.25, 4.0);

    /// Fold in one completed request: `predicted_s` is what the gate
    /// priced at admission, `actual_s` the measured completion time.
    /// Degenerate samples (non-positive or non-finite on either side)
    /// are dropped — a zero prediction carries no calibration signal.
    pub fn observe(&mut self, predicted_s: f64, actual_s: f64) {
        let usable = predicted_s.is_finite()
            && actual_s.is_finite()
            && predicted_s > 0.0
            && actual_s > 0.0;
        if !usable {
            return;
        }
        let ratio = actual_s / predicted_s;
        let err = (actual_s - predicted_s).abs() / predicted_s;
        if self.samples == 0 {
            self.ratio = ratio;
            self.abs_err = err;
        } else {
            self.ratio += Self::ALPHA * (ratio - self.ratio);
            self.abs_err += Self::ALPHA * (err - self.abs_err);
        }
        self.samples += 1;
    }

    /// Multiplicative correction for predictions: `1.0` until the first
    /// sample lands, then the EMA of `actual / predicted` clamped to
    /// the safety band.
    pub fn correction(&self) -> f64 {
        if self.samples == 0 {
            return 1.0;
        }
        let (lo, hi) = Self::CORRECTION_BAND;
        self.ratio.clamp(lo, hi)
    }

    /// Mean absolute relative prediction error (EMA) — the
    /// estimator-quality signal the disaggregation bench reports as
    /// `estimator_err`.
    pub fn mean_abs_err(&self) -> f64 {
        self.abs_err
    }

    /// Completed-request samples folded in so far.
    pub fn samples(&self) -> u64 {
        self.samples
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn est() -> CostEstimator {
        // prefill 2 us/tok, step 250 us, slot 25 us, batch 8
        CostEstimator::from_sim_cost(&SimCost::default(), 8)
    }

    #[test]
    fn decode_rate_amortizes_the_step_launch() {
        let e = est();
        // 250/8 + 25 = 56.25 us/token
        assert!((e.decode_s_per_token - 56.25e-6).abs() < 1e-12);
        assert!((e.prefill_s_per_token - 2e-6).abs() < 1e-15);
        assert_eq!(e.batch(), 8);
    }

    #[test]
    fn empty_backlog_costs_only_the_candidate() {
        let e = est();
        let t = e.predict_s((0, 0), 16, 8, 0);
        assert!((t - (16.0 * 2e-6 + 8.0 * 56.25e-6)).abs() < 1e-12, "{t}");
    }

    #[test]
    fn prediction_grows_with_backlog() {
        let e = est();
        let idle = e.predict_ms((0, 0), 8, 4, 0);
        let busy = e.predict_ms((800, 400), 8, 4, 0);
        assert!(busy > idle);
        // backlog contribution is linear in tokens
        let busier = e.predict_ms((1600, 800), 8, 4, 0);
        assert!((busier - idle) > 1.99 * (busy - idle) - 1e-9);
    }

    #[test]
    fn chunk_serialization_charges_extra_boundaries_only() {
        let e = est();
        assert_eq!(e.chunk_serialization_s(120, 0), 0.0, "whole-prompt");
        assert_eq!(e.chunk_serialization_s(0, 16), 0.0, "empty prompt");
        // 120 tokens at chunk 16 -> 8 chunks -> 7 extra boundaries
        assert!((e.chunk_serialization_s(120, 16) - 7.0 * 250e-6).abs() < 1e-12);
        // one chunk covers the whole prompt -> no serialization
        assert_eq!(e.chunk_serialization_s(10, 16), 0.0);
        // and the prediction includes it
        let whole = e.predict_s((0, 0), 120, 4, 0);
        let chunked = e.predict_s((0, 0), 120, 4, 16);
        assert!((chunked - whole - 7.0 * 250e-6).abs() < 1e-12);
    }

    #[test]
    fn degraded_estimator_scales_the_slot_share_only() {
        let e = est();
        let d = e.degraded(4);
        // launch share 250/8 = 31.25 us stays; slot share 25 -> 12.5 us
        assert!((d.decode_s_per_token - (31.25e-6 + 12.5e-6)).abs() < 1e-12);
        assert_eq!(d.prefill_s_per_token, e.prefill_s_per_token);
        assert_eq!(d.step_s(), e.step_s());
        assert_eq!(d.batch(), e.batch());
        // degraded capacity is strictly higher: same backlog, lower t_pred
        assert!(d.predict_s((0, 400), 8, 16, 0) < e.predict_s((0, 400), 8, 16, 0));
        // native width is the identity
        let same = e.degraded(8);
        assert_eq!(same.decode_s_per_token, e.decode_s_per_token);
        // clamped below, and the step clock is the sim launch cost
        assert!(e.degraded(0).decode_s_per_token > 31.25e-6);
        assert!((e.step_s() - 250e-6).abs() < 1e-15);
    }

    #[test]
    fn speculative_estimator_prices_cycle_cost_over_expected_yield() {
        let e = est();
        // k=4 draft-4-bit: cycle = (1 + 4 * 0.5) * 56.25 us = 168.75 us,
        // yield = 1 + 0.95 + 0.95^2 + 0.95^3 + 0.95^4 = 4.52438125
        let s = e.speculative(4, 4);
        let want = 3.0 * 56.25e-6 / SimCost::spec_tokens_per_cycle(4, 4);
        assert!((s.decode_s_per_token - want).abs() < 1e-15);
        // the modeled speedup clears the bench gate's 1.2x bar
        assert!(e.decode_s_per_token / s.decode_s_per_token > 1.2);
        // prefill, launch clock, and batch are untouched
        assert_eq!(s.prefill_s_per_token, e.prefill_s_per_token);
        assert_eq!(s.step_s(), e.step_s());
        assert_eq!(s.batch(), e.batch());
        // k=0 is the identity
        assert_eq!(e.speculative(0, 4).decode_s_per_token, e.decode_s_per_token);
        // native-width drafts accept everything but cost a full step each:
        // yield k+1 over cost k+1 — the identity again, not a free lunch
        let native = e.speculative(4, 8);
        assert!((native.decode_s_per_token - e.decode_s_per_token).abs() < 1e-15);
        // a cheaper, chattier draft (2-bit) still beats plain decode
        assert!(e.speculative(4, 2).decode_s_per_token < e.decode_s_per_token);
        // and speculative composes with degraded-width serving
        let both = e.degraded(4).speculative(4, 4);
        assert!(both.decode_s_per_token < e.degraded(4).decode_s_per_token);
    }

    #[test]
    fn zero_batch_is_clamped() {
        let e = CostEstimator::from_sim_cost(&SimCost::default(), 0);
        assert_eq!(e.batch(), 1);
        assert!(e.predict_s((0, 0), 1, 1, 0).is_finite());
    }

    #[test]
    fn blocks_for_rounds_residency_up_to_whole_blocks() {
        assert_eq!(CostEstimator::blocks_for(16, 0, 16), 1);
        assert_eq!(CostEstimator::blocks_for(17, 0, 16), 2);
        assert_eq!(CostEstimator::blocks_for(10, 6, 16), 1, "prompt + decode share a block");
        assert_eq!(CostEstimator::blocks_for(10, 7, 16), 2);
        assert_eq!(CostEstimator::blocks_for(0, 0, 16), 0);
        assert_eq!(CostEstimator::blocks_for(100, 100, 0), 0, "paging disabled");
    }

    #[test]
    fn block_drain_prices_deficit_at_the_decode_rate() {
        let e = est();
        // 3 blocks of 16 tokens at 56.25 us/token
        assert!((e.block_drain_s(3, 16) - 48.0 * 56.25e-6).abs() < 1e-12);
        assert_eq!(e.block_drain_s(0, 16), 0.0);
        // degraded width drains faster — deficit latency shrinks with it
        assert!(e.degraded(4).block_drain_s(3, 16) < e.block_drain_s(3, 16));
    }

    #[test]
    fn calibration_starts_neutral() {
        let c = EstimatorCalibration::default();
        assert_eq!(c.correction(), 1.0);
        assert_eq!(c.mean_abs_err(), 0.0);
        assert_eq!(c.samples(), 0);
    }

    #[test]
    fn calibration_tracks_a_constant_bias() {
        let mut c = EstimatorCalibration::default();
        for _ in 0..200 {
            c.observe(0.010, 0.015); // the fit is 1.5x optimistic
        }
        assert!((c.correction() - 1.5).abs() < 1e-9, "{}", c.correction());
        assert!((c.mean_abs_err() - 0.5).abs() < 1e-9, "{}", c.mean_abs_err());
        assert_eq!(c.samples(), 200);
    }

    #[test]
    fn calibration_is_recency_weighted() {
        let mut c = EstimatorCalibration::default();
        for _ in 0..50 {
            c.observe(0.01, 0.02); // old regime: 2x under-priced
        }
        for _ in 0..50 {
            c.observe(0.01, 0.01); // fleet drifts back to the fit
        }
        // 0.9^50 of the old bias is all that survives
        assert!(c.correction() < 1.05, "{}", c.correction());
        assert!(c.correction() >= 1.0);
    }

    #[test]
    fn calibration_clamps_wild_residuals() {
        let mut over = EstimatorCalibration::default();
        over.observe(0.001, 10.0);
        assert_eq!(over.correction(), 4.0);
        let mut under = EstimatorCalibration::default();
        under.observe(10.0, 0.001);
        assert_eq!(under.correction(), 0.25);
    }

    #[test]
    fn calibration_ignores_degenerate_samples() {
        let mut c = EstimatorCalibration::default();
        c.observe(0.0, 1.0);
        c.observe(1.0, 0.0);
        c.observe(f64::NAN, 1.0);
        c.observe(1.0, f64::INFINITY);
        c.observe(-1.0, 1.0);
        assert_eq!(c.samples(), 0);
        assert_eq!(c.correction(), 1.0);
    }

    #[test]
    fn calibrated_estimator_scales_every_time_knob_together() {
        let e = est();
        let c = e.calibrated(1.5);
        let t = e.predict_s((100, 50), 16, 8, 16);
        assert!((c.predict_s((100, 50), 16, 8, 16) - 1.5 * t).abs() < 1e-12);
        assert!((c.step_s() - 1.5 * e.step_s()).abs() < 1e-15);
        assert!((c.block_drain_s(3, 16) - 1.5 * e.block_drain_s(3, 16)).abs() < 1e-12);
        assert_eq!(c.batch(), e.batch());
        // degenerate corrections are the identity
        assert_eq!(e.calibrated(0.0).step_s(), e.step_s());
        assert_eq!(e.calibrated(f64::NAN).step_s(), e.step_s());
        assert_eq!(e.calibrated(1.0).step_s(), e.step_s());
    }
}
