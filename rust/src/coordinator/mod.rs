//! The serving coordinator — LLMEasyQuant's Distributed Controller Layer.
//!
//! A step-driven serving engine (paper §2.1, §3; scheduling discipline
//! modeled on production continuous-batching servers) that *enforces*
//! latency SLOs rather than just measuring them:
//!
//!   router     — admission rewrite (BOS/truncate) + least-loaded shard
//!                choice, where load is in-flight *tokens*, not request
//!                count; shed requests refund their charge (`release`)
//!   batcher    — two-tier admission queue for both [`SchedulerMode`]s
//!                (static deadline-formed batches, or per-shard
//!                step-boundary draining) and the [`AdmissionPolicy`]
//!                the dispatcher's SLO gate applies at the join boundary
//!   kv_cache   — per-slot KV pages (fp32 or SimQuant codes with online
//!                re-encode, §3.4) plus a slot free-list; prefill ingest
//!                can resume mid-prompt (`ingest_prefill_at`) for
//!                chunked prefill
//!   worker     — the step core: `join` (admit into free slots, start
//!                prefill) and `step` (one bounded prefill chunk for
//!                mid-prefill slots, then one fused decode across
//!                decoding slots; finished slots retire mid-flight).
//!                Backends: PJRT artifacts or the offline deterministic
//!                `runtime::SimModel`
//!   server     — event-driven dispatcher: open-loop `Arrival` replay or
//!                closed-loop firehose, routing via `RouteDecision`,
//!                per-token `ServeEvent` streaming, and the SLO gate
//!                (rolling per-shard latency windows feeding the
//!                admission policy)
//!   scale_sync — Alg. 1 EMA trackers + Eqs. 7-8 collective sync
//!   bitwidth   — Thm. 3 greedy per-layer mixed-precision search
//!   workload   — Poisson arrival generator (open loop) + firehose
//!
//! The two serving-time pressure valves (the paper's runtime-adaptation
//! story, applied to scheduling):
//!
//! **Chunked prefill** (`ServerConfig::prefill_chunk`): a joining prompt
//! is ingested at most `chunk` tokens per step boundary, interleaved
//! with decode steps, so the decode stall a long prompt imposes on
//! in-flight slots is bounded by the chunk — not the prompt length.
//! Token streams are unchanged (chunk seams reproduce the whole-prompt
//! rows exactly); only timing moves: joiners trade a later first token
//! for their neighbors' bounded inter-token gaps.
//!
//! **SLO-aware admission** (`ServerConfig::admission`): every completion
//! feeds a rolling per-shard latency window; when a shard's window p99
//! breaches the configured target, `SheddingP99` refuses new load routed
//! there (one terminal `ServeEvent::Shed` per request, router charge
//! refunded) and `Priority` parks it in the low-priority queue tier
//! behind all normal traffic. `Open` preserves the measure-only
//! behavior.
//!
//! Static mode survives as the ablation baseline: run-to-completion
//! batches, exactly the pre-refactor behavior. Continuous mode retires
//! finished slots immediately, so one long request no longer
//! head-of-line-blocks the other slots of its batch.
//!
//! Python never appears here: workers execute AOT artifacts through PJRT
//! (or the simulated backend offline).

mod batcher;
mod bitwidth;
mod kv_cache;
mod request;
mod router;
mod scale_sync;
mod server;
mod worker;
pub mod workload;

pub use batcher::{AdmissionPolicy, Batch, BatchPolicy, Batcher, SchedulerMode};
pub use bitwidth::{
    quant_mse, search_bitwidths, size_reduction, BitwidthChoice, LayerInfo, SearchPolicy,
    BIT_CHOICES,
};
pub use kv_cache::{KvCache, PrefillPage};
pub use request::{Request, RequestId, Response, ServeEvent};
pub use router::{request_cost, RouteDecision, Router};
pub use scale_sync::{ScaleSync, SYNC_WIRE_BITS};
pub use server::{Server, ServerConfig, ServerReport};
pub use worker::{Backend, Worker, WorkerStats};
