//! The serving coordinator — LLMEasyQuant's Distributed Controller Layer.
//!
//! Since the continuous-batching refactor this layer is a step-driven
//! serving engine (paper §2.1, §3; scheduling discipline modeled on
//! production continuous-batching servers):
//!
//!   router     — admission (BOS/truncate) + least-loaded shard choice,
//!                where load is in-flight *tokens*, not request count
//!   batcher    — admission queue for both [`SchedulerMode`]s: static
//!                deadline-formed batches, or per-shard step-boundary
//!                draining (continuous)
//!   kv_cache   — per-slot KV pages (fp32 or SimQuant codes with online
//!                re-encode, §3.4) plus a slot free-list: retired slots
//!                are scrubbed and reusable on the next step
//!   worker     — the step core: `join` (fused prefill of joiners into
//!                free slots, first token + TTFT) and `step` (one fused
//!                decode across in-flight slots; finished slots retire
//!                mid-flight). Backends: PJRT artifacts or the offline
//!                deterministic `runtime::SimModel`
//!   server     — event-driven dispatcher: open-loop `Arrival` replay or
//!                closed-loop firehose, routing via `RouteDecision`,
//!                per-token `ServeEvent` streaming back to the collector
//!   scale_sync — Alg. 1 EMA trackers + Eqs. 7-8 collective sync
//!   bitwidth   — Thm. 3 greedy per-layer mixed-precision search
//!   workload   — Poisson arrival generator (open loop) + firehose
//!
//! Static mode survives as the ablation baseline: run-to-completion
//! batches, exactly the pre-refactor behavior. Continuous mode retires
//! finished slots immediately, so one long request no longer
//! head-of-line-blocks the other slots of its batch.
//!
//! Python never appears here: workers execute AOT artifacts through PJRT
//! (or the simulated backend offline).

mod batcher;
mod bitwidth;
mod kv_cache;
mod request;
mod router;
mod scale_sync;
mod server;
mod worker;
pub mod workload;

pub use batcher::{Batch, BatchPolicy, Batcher, SchedulerMode};
pub use bitwidth::{
    quant_mse, search_bitwidths, size_reduction, BitwidthChoice, LayerInfo, SearchPolicy,
    BIT_CHOICES,
};
pub use kv_cache::{KvCache, PrefillPage};
pub use request::{Request, RequestId, Response, ServeEvent};
pub use router::{request_cost, RouteDecision, Router};
pub use scale_sync::{ScaleSync, SYNC_WIRE_BITS};
pub use server::{Server, ServerConfig, ServerReport};
pub use worker::{Backend, Worker, WorkerStats};
