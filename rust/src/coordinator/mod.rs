//! The serving coordinator — LLMEasyQuant's Distributed Controller Layer.
//!
//! A step-driven serving engine (paper §2.1, §3; scheduling discipline
//! modeled on production continuous-batching servers) that *enforces*
//! latency SLOs rather than just measuring them:
//!
//!   router     — admission rewrite (BOS/truncate) + least-loaded shard
//!                choice, where load is in-flight *tokens*, not request
//!                count, split per shard into (prefill, decode) backlog
//!                for the predictive gate; shed requests refund their
//!                charge (`release`) exactly once
//!   cost       — [`CostEstimator`]: the calibrated per-token completion
//!                -time model predictive admission prices backlog with,
//!                fitted from `SimCost` (sim) or `BENCH_hotpath.json`
//!                (PJRT)
//!   batcher    — two-tier admission queue for both [`SchedulerMode`]s
//!                (static deadline-formed batches, or per-shard
//!                step-boundary draining) and the [`AdmissionPolicy`]
//!                the dispatcher's SLO gate applies at the join boundary
//!   kv_cache   — paged KV storage (fp32 or SimQuant codes with online
//!                re-encode, §3.4): a shard-wide pool of fixed-size
//!                blocks (`DEFAULT_BLOCK_SIZE` tokens each, lowest-index
//!                -first allocation from a `BTreeSet` free pool) mapped
//!                into per-lane *block tables*. Forks share blocks
//!                copy-on-write under refcounts; prefix-cache hits
//!                attach retained blocks instead of re-prefilling; and
//!                releasing a table is O(blocks) pointer returns — which
//!                is what makes preemption cheap. Prefill ingest can
//!                resume mid-prompt *and* mid-block (`ingest_prefill_at`)
//!                for chunked prefill, and sub-byte SimQuant pages keep
//!                their true packed width in `storage_bytes`
//!   prefix_cache — [`PrefixCacheManager`]: hashes token-prefix chains
//!                (block-aligned, parent-linked) to retained KV blocks;
//!                a shared-prefix arrival skips prefill to its first
//!                uncached block. Idle chains (refcount 0) evict LRU
//!                leaf-first when the pool runs dry
//!   worker     — the step core: `join` (admit into free lanes, reserve
//!                blocks, start prefill) and `step` (one bounded prefill
//!                chunk for mid-prefill slots, then one fused decode
//!                across decoding slots; finished slots retire
//!                mid-flight). An interactive arrival finding no free
//!                blocks *preempts* the youngest batch slot: its table
//!                unmaps (blocks return to the pool), the slot parks,
//!                and it resumes later by re-prefilling through the
//!                prefix cache — interference bounded to one step.
//!                Backends: PJRT artifacts or the offline deterministic
//!                `runtime::SimModel`
//!   server     — event-driven dispatcher: open-loop `Arrival` replay or
//!                closed-loop firehose, routing via `RouteDecision`,
//!                per-token `ServeEvent` streaming, the SLO gate
//!                (rolling per-shard latency windows feeding the
//!                admission policy), and the fault-recovery machinery
//!                (liveness tracking, kill, migrate)
//!   faults     — seeded [`FaultPlan`] (shard crash @ step, transient
//!                stall, link chunk corruption) + the [`FaultSpec`]
//!                detection knobs and the [`ShardHealth`] lifecycle
//!   scale_sync — Alg. 1 EMA trackers + Eqs. 7-8 collective sync
//!   bitwidth   — Thm. 3 greedy per-layer mixed-precision search
//!   workload   — Poisson arrival generator (open loop) + firehose
//!
//! The two serving-time pressure valves (the paper's runtime-adaptation
//! story, applied to scheduling):
//!
//! **Chunked prefill** (`ServerConfig::prefill_chunk`): a joining prompt
//! is ingested at most `chunk` tokens per step boundary, interleaved
//! with decode steps, so the decode stall a long prompt imposes on
//! in-flight slots is bounded by the chunk — not the prompt length.
//! Token streams are unchanged (chunk seams reproduce the whole-prompt
//! rows exactly); only timing moves: joiners trade a later first token
//! for their neighbors' bounded inter-token gaps.
//!
//! **SLO-aware admission** (`ServerConfig::admission`): the trailing
//! policies feed every completion into a rolling per-shard latency
//! window — when its p99 breaches the configured target, `SheddingP99`
//! refuses new load routed there (one terminal `ServeEvent::Shed` per
//! request, router charge refunded) and `Priority` parks it in the
//! low-priority queue tier. Window samples age out past a staleness
//! horizon, so a sustained full-shed interval (zero fresh completions)
//! re-evaluates instead of freezing its last verdict. `Open` preserves
//! the measure-only behavior.
//!
//! **Predictive admission** (`AdmissionPolicy::Predictive`): the
//! trailing window only trips *after* slow completions land; during an
//! arrival ramp that is a window too late. The predictive gate instead
//! prices each candidate at arrival:
//!
//! ```text
//! t_pred = (backlog_prefill + prompt) * prefill_s/token
//!        + (backlog_decode + decode_budget) * decode_s/token
//!        + chunk_serialization(prompt, prefill_chunk)
//! ```
//!
//! with per-token rates calibrated from the sim cost model or the
//! measured PJRT hotpath profile (`cost::CostEstimator`), and sheds a
//! batch-priority candidate whose predicted completion would breach the
//! target — before the window ever sees a slow completion.
//!
//! **Client priority** ([`Priority`]): every request carries an
//! `Interactive` | `Batch` hint. Batch work rides the low queue tier
//! (interactive traffic preempts it at every drain) and sheds first;
//! interactive requests are never shed while batch work remains
//! sheddable. Queueing delay is reported separately from decode cadence
//! (`Response::queued_s` vs emission-stamped inter-token gaps).
//!
//! Static mode survives as the ablation baseline: run-to-completion
//! batches, exactly the pre-refactor behavior. Continuous mode retires
//! finished slots immediately, so one long request no longer
//! head-of-line-blocks the other slots of its batch.
//!
//! **Fault tolerance** (continuous mode, armed by a seeded
//! [`FaultPlan`] on `ServerConfig::fault`): every worker event doubles
//! as its shard's liveness beat. The lifecycle is Healthy → Suspect →
//! Dead ([`ShardHealth`]): a shard with runnable work that misses one
//! `step_deadline` is Suspect (still routed to — injected stalls
//! recover), and `max_misses` consecutive silent deadlines make it
//! Dead. On death the shard leaves the routing set, and each in-flight
//! request migrates with exactly-once delivery — the router charge
//! refunds idempotently, the admitted prompt plus every
//! already-delivered token re-prefills as a prefix on the least-loaded
//! survivor (the deterministic trajectory continues token-identically),
//! and the new stream's worker-local positions are rebased by the
//! handoff offset so each global position is delivered once: buffered
//! pre-crash duplicates are suppressed, gaps are an anomaly gated to
//! zero. Lost capacity flows into admission by construction — the dead
//! shard's load lands on the survivors' backlog, which the predictive
//! gate prices, shedding batch traffic instead of breaching the SLO.
//! On the wire, ring collectives carry per-chunk checksums with
//! bounded retry-then-eject (`collective`), so link corruption either
//! heals or removes the rank rather than corrupting scales.
//!
//! **Elastic recovery** (the full arc is kill → degrade → rejoin →
//! restore; death is permanent only when no replacement is
//! provisioned):
//!
//!   degrade — a shrunken fleet (or sustained decode backlog above a
//!             high watermark) drops every survivor's KV reads from
//!             8-bit to `ServerConfig::degrade_bits`; fused decode is
//!             memory-bound on KV pages, so the narrower reads raise
//!             effective capacity, and the predictive gate reprices
//!             with [`CostEstimator::degraded`] so it sheds less than
//!             a fixed-width fleet under the same kill. The ladder is
//!             hysteretic: enter on a death or on the high watermark
//!             held for consecutive deadline ticks, exit only at full
//!             fleet strength with backlog under the low watermark —
//!             one pressure episode moves the width once, not per
//!             oscillation.
//!   rejoin  — a `recover:<shard>@<step>` clause ([`RecoverFault`]) or
//!             a warm spare (`ServerConfig::standby`, at most one per
//!             detected death) brings a Dead shard back: the dispatcher
//!             spawns the next incarnation's worker, accounts the
//!             quantized (one byte per parameter) weight re-broadcast
//!             that re-shards its partition over the survivor ring,
//!             and re-enters it behind a probe ramp.
//!   restore — a probing shard holds at most one stream at a time (an
//!             idle prober takes routing priority, so the probe always
//!             lands) until it stays Healthy for
//!             `FaultSpec::ramp_deadlines` clean deadlines; then
//!             `Router::promote` restores its full least-loaded share.
//!             Health transitions are typed and idempotent
//!             ([`Transition`]): double-kill, double-recover, and
//!             promote-after-death are no-ops, so a flapping shard
//!             replays the ladder per incarnation without double
//!             counting. Streams stay exactly-once across
//!             kill → rejoin because migration already rebased them
//!             and a rejoined incarnation starts with fresh streams.
//!
//! Python never appears here: workers execute AOT artifacts through PJRT
//! (or the simulated backend offline).

mod batcher;
mod bitwidth;
mod cost;
mod faults;
mod kv_cache;
mod prefix_cache;
mod request;
mod router;
mod scale_sync;
mod server;
mod worker;
pub mod workload;

pub use batcher::{AdmissionPolicy, Batch, BatchPolicy, Batcher, SchedulerMode};
pub use cost::{CostEstimator, EstimatorCalibration};
pub use bitwidth::{
    quant_mse, search_bitwidths, size_reduction, BitwidthChoice, LayerInfo, SearchPolicy,
    BIT_CHOICES,
};
pub use faults::{CrashFault, FaultPlan, FaultSpec, RecoverFault, ShardHealth, StallFault};
pub use kv_cache::{KvCache, LaneExport, PrefillPage, DEFAULT_BLOCK_SIZE};
pub use prefix_cache::PrefixCacheManager;
pub use request::{Priority, Request, RequestId, Response, ServeEvent};
pub use router::{request_cost, RouteDecision, Router, ShardRole, Transition};
pub use scale_sync::{sync_wire_bits_for, ScaleSync, SYNC_WIRE_BITS};
pub use server::{Server, ServerConfig, ServerReport};
pub use worker::{Backend, Worker, WorkerStats};
