//! The serving coordinator — LLMEasyQuant's Distributed Controller Layer.
//!
//! Pieces (paper §2.1, §3):
//!   router     — request admission + shard assignment (least-loaded)
//!   batcher    — dynamic batching with a max-size / deadline policy
//!   kv_cache   — per-slot KV pages, fp32 or SimQuant u8 codes with online
//!                page re-encode (the "runtime adaptation" of §3.4)
//!   scale_sync — Alg. 1 EMA trackers + Eqs. 7-8 collective synchronization
//!   bitwidth   — Thm. 3 greedy per-layer mixed-precision search
//!   worker     — one shard: owns a ModelHandle, runs prefill/decode
//!   server     — ties it together: router -> batcher -> workers -> responses
//!
//! Python never appears here: workers execute AOT artifacts through PJRT.

mod batcher;
mod bitwidth;
mod kv_cache;
mod request;
mod router;
mod scale_sync;
mod server;
mod worker;
pub mod workload;

pub use batcher::{Batch, BatchPolicy, Batcher};
pub use bitwidth::{
    quant_mse, search_bitwidths, size_reduction, BitwidthChoice, LayerInfo, SearchPolicy,
    BIT_CHOICES,
};
pub use kv_cache::{KvCache, PrefillPage};
pub use request::{Request, RequestId, Response};
pub use router::Router;
pub use scale_sync::{ScaleSync, SYNC_WIRE_BITS};
pub use server::{Server, ServerConfig, ServerReport};
pub use worker::Worker;
