//! Request / response / event types for the serving path.

use std::sync::Arc;
use std::time::Instant;

use super::kv_cache::LaneExport;

pub type RequestId = u64;

/// Client priority hint, carried first-class on every request.
///
/// `Interactive` traffic is latency-sensitive: it rides the normal
/// queue tier and the admission gate never sheds it while batch work
/// remains sheddable. `Batch` traffic is throughput work: it parks in
/// the low queue tier (drained only when no interactive request waits)
/// and is shed *first* when the predictive gate sees a breach coming.
/// Under the paged KV cache, priority also decides *preemption*: an
/// interactive arrival finding no free lane or KV blocks unmaps the
/// youngest batch slot's block table (the victim parks with its
/// generated tokens and resumes via prefix-cached re-prefill, its
/// stream continuing loss/dup-free under the same `seq` numbering).
/// This replaces the PR 4 behavior where the low tier was derived
/// purely from breach timing — with one legacy exception: under
/// `AdmissionPolicy::Priority`, a tripped window still demotes *every*
/// breach-time arrival (interactive included) to the low tier; that
/// demotion is that policy's entire mechanism.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Priority {
    /// latency-sensitive; never shed while batch work is sheddable
    #[default]
    Interactive,
    /// throughput work; parks behind interactive traffic, sheds first
    Batch,
}

impl Priority {
    pub fn name(self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Batch => "batch",
        }
    }
}

/// One generation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    /// prompt token ids (BOS-prefixed by the router if absent)
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// client priority hint (admission tier + shed order)
    pub priority: Priority,
    /// when the request entered the system; the open-loop dispatcher
    /// re-stamps this at injection time so TTFT/latency measure real
    /// queueing from arrival, not workload-generation time
    pub arrival: Instant,
}

impl Request {
    pub fn new(id: RequestId, prompt: Vec<i32>, max_new_tokens: usize) -> Self {
        Request {
            id,
            prompt,
            max_new_tokens,
            priority: Priority::Interactive,
            arrival: Instant::now(),
        }
    }

    /// Builder-style priority override (`Request::new` defaults to
    /// `Interactive`, the pre-priority behavior).
    pub fn with_priority(mut self, priority: Priority) -> Self {
        self.priority = priority;
        self
    }
}

/// Completed generation.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: RequestId,
    pub tokens: Vec<i32>,
    /// prompt length actually used (after truncation)
    pub prompt_len: usize,
    /// priority the request was served under
    pub priority: Priority,
    /// end-to-end latency from arrival
    pub latency_s: f64,
    /// time to first token
    pub ttft_s: f64,
    /// time spent queued before the request was admitted into a worker
    /// slot — the park/batch-formation interval, reported separately so
    /// inter-token latency reflects decode cadence only
    pub queued_s: f64,
    /// absolute instant the first token was emitted (jitter-free TTFT
    /// ordering for the scheduler invariant tests)
    pub first_token_at: Instant,
    /// shard that served the request
    pub shard: usize,
}

/// One streamed serving event. Workers emit a `Token` per generated
/// token as it happens (decode-step granularity) and a final `Done`
/// carrying the complete response; per-sender channel order guarantees
/// every `Token` of a request precedes its `Done`. Tokens carry their
/// *emission* instant (`at`): inter-token gaps are measured between
/// emission stamps, not dispatcher receive times, so a dispatcher busy
/// parking or shedding arrivals cannot inflate the decode-cadence
/// signal. Tokens also carry `seq`, their 0-based position in the
/// *emitting worker's* stream — after a failover re-prefills the
/// delivered prefix on a new shard, the dispatcher rebases `seq` by the
/// handoff offset and dedupes by global position, which is what makes
/// delivery exactly-once across a migration. Preemption needs no such
/// rebase: a preempted request resumes on the *same* worker with its
/// generated tokens intact, so `seq` simply continues where it stopped
/// — already-served positions are never re-emitted. `Shed` is the other
/// terminal event: the dispatcher's admission gate refused the request
/// — a shed request emits exactly one `Shed` and never a `Token` or
/// `Done`.
#[derive(Debug, Clone)]
pub enum ServeEvent {
    Token {
        id: RequestId,
        token: i32,
        /// 0-based position in the emitting worker's output stream
        seq: usize,
        /// true for the prefill-produced first token
        first: bool,
        /// instant the worker emitted the token
        at: Instant,
    },
    Done(Response),
    Shed {
        id: RequestId,
        /// shard whose gate (latency window or predicted backlog)
        /// triggered the shed
        shard: usize,
    },
    /// A prefill-role worker finished a request's prefill and released
    /// the lane: the dispatcher must migrate the exported KV pages to a
    /// decode-role shard, which continues the stream bit-identically.
    /// The `Token` for `seq` 0 (the prefill-produced first token, last
    /// element of `generated`) has already been emitted by the source
    /// worker; the importing worker resumes at `seq == generated.len()`.
    /// `pages` is `Arc`-shared so the event channel never copies the
    /// block payload — only the simulated wire does.
    Handoff {
        /// source (prefill) shard
        shard: usize,
        /// the original request (prompt as admitted, priority intact)
        req: Request,
        /// tokens generated so far (the prefill first token, plus any
        /// decode progress if a mixed-role worker handed off late)
        generated: Vec<i32>,
        /// TTFT measured on the source shard (first token already out)
        ttft_s: f64,
        /// queueing time measured on the source shard
        queued_s: f64,
        /// emission instant of the first token on the source shard
        first_token_at: Option<Instant>,
        /// the lane's KV block table at true packed width
        pages: Arc<LaneExport>,
    },
    /// A decode-role worker could not admit an `ImportPages` migration
    /// (no free lane, or its block pool cannot hold the residency): the
    /// request bounces back to the dispatcher, which falls back to
    /// re-prefill injection on a live shard — the no-pages path. The
    /// dispatcher rebuilds the continuation from its own delivered
    /// prefix, so the bounce carries only the original request.
    ImportBounced { req: Request },
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_records_arrival() {
        let r = Request::new(1, vec![1, 2, 3], 16);
        assert!(r.arrival.elapsed().as_secs() < 1);
        assert_eq!(r.max_new_tokens, 16);
        assert_eq!(r.priority, Priority::Interactive, "default priority");
    }

    #[test]
    fn priority_builder_and_names() {
        let r = Request::new(2, vec![1], 4).with_priority(Priority::Batch);
        assert_eq!(r.priority, Priority::Batch);
        assert_eq!(Priority::default(), Priority::Interactive);
        assert_eq!(Priority::Interactive.name(), "interactive");
        assert_eq!(Priority::Batch.name(), "batch");
    }

    #[test]
    fn serve_event_carries_first_flag_seq_and_stamp() {
        let before = Instant::now();
        let e = ServeEvent::Token { id: 4, token: 9, seq: 0, first: true, at: Instant::now() };
        match e {
            ServeEvent::Token { id, token, seq, first, at } => {
                assert_eq!((id, token, seq, first), (4, 9, 0, true));
                assert!(at >= before);
            }
            _ => panic!("wrong arm"),
        }
    }

    #[test]
    fn shed_event_names_the_breaching_shard() {
        let e = ServeEvent::Shed { id: 7, shard: 2 };
        assert!(matches!(e, ServeEvent::Shed { id: 7, shard: 2 }));
    }
}
