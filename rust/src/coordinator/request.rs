//! Request / response types for the serving path.

use std::time::Instant;

pub type RequestId = u64;

/// One generation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    /// prompt token ids (BOS-prefixed by the router if absent)
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub arrival: Instant,
}

impl Request {
    pub fn new(id: RequestId, prompt: Vec<i32>, max_new_tokens: usize) -> Self {
        Request { id, prompt, max_new_tokens, arrival: Instant::now() }
    }
}

/// Completed generation.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: RequestId,
    pub tokens: Vec<i32>,
    /// prompt length actually used (after truncation)
    pub prompt_len: usize,
    /// end-to-end latency from arrival
    pub latency_s: f64,
    /// time to first token
    pub ttft_s: f64,
    /// shard that served the request
    pub shard: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_records_arrival() {
        let r = Request::new(1, vec![1, 2, 3], 16);
        assert!(r.arrival.elapsed().as_secs() < 1);
        assert_eq!(r.max_new_tokens, 16);
    }
}
