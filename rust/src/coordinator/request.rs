//! Request / response / event types for the serving path.

use std::time::Instant;

pub type RequestId = u64;

/// One generation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    /// prompt token ids (BOS-prefixed by the router if absent)
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// when the request entered the system; the open-loop dispatcher
    /// re-stamps this at injection time so TTFT/latency measure real
    /// queueing from arrival, not workload-generation time
    pub arrival: Instant,
}

impl Request {
    pub fn new(id: RequestId, prompt: Vec<i32>, max_new_tokens: usize) -> Self {
        Request { id, prompt, max_new_tokens, arrival: Instant::now() }
    }
}

/// Completed generation.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: RequestId,
    pub tokens: Vec<i32>,
    /// prompt length actually used (after truncation)
    pub prompt_len: usize,
    /// end-to-end latency from arrival
    pub latency_s: f64,
    /// time to first token
    pub ttft_s: f64,
    /// absolute instant the first token was emitted (jitter-free TTFT
    /// ordering for the scheduler invariant tests)
    pub first_token_at: Instant,
    /// shard that served the request
    pub shard: usize,
}

/// One streamed serving event. Workers emit a `Token` per generated
/// token as it happens (decode-step granularity) and a final `Done`
/// carrying the complete response; per-sender channel order guarantees
/// every `Token` of a request precedes its `Done`. `Shed` is the other
/// terminal event: the dispatcher's admission gate refused the request
/// (SLO breach under `AdmissionPolicy::SheddingP99`) — a shed request
/// emits exactly one `Shed` and never a `Token` or `Done`.
#[derive(Debug, Clone)]
pub enum ServeEvent {
    Token {
        id: RequestId,
        token: i32,
        /// true for the prefill-produced first token
        first: bool,
    },
    Done(Response),
    Shed {
        id: RequestId,
        /// shard whose latency window triggered the shed
        shard: usize,
    },
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_records_arrival() {
        let r = Request::new(1, vec![1, 2, 3], 16);
        assert!(r.arrival.elapsed().as_secs() < 1);
        assert_eq!(r.max_new_tokens, 16);
    }

    #[test]
    fn serve_event_carries_first_flag() {
        let e = ServeEvent::Token { id: 4, token: 9, first: true };
        match e {
            ServeEvent::Token { id, token, first } => {
                assert_eq!((id, token, first), (4, 9, true));
            }
            _ => panic!("wrong arm"),
        }
    }

    #[test]
    fn shed_event_names_the_breaching_shard() {
        let e = ServeEvent::Shed { id: 7, shard: 2 };
        assert!(matches!(e, ServeEvent::Shed { id: 7, shard: 2 }));
    }
}
