//! Per-layer mixed-precision bitwidth search (paper §2.1, Thm. 3).
//!
//! Minimizes  sum_l err_l(b_l) + lambda * sum_l cost(b_l)  over
//! b_l in {2, 3, 4, 8}, where err_l is the Hessian-proxy-weighted
//! quantization MSE of layer l's weight at b_l bits and cost(b) = b/8 of
//! the layer's parameter bytes (the model-size axis of the paper's
//! "3.2x size reduction with acceptable loss" claim).
//!
//! Three policies (paper: "grid search, entropy heuristics, or learned
//! policy" — the third is substituted by the greedy coordinate descent
//! whose convergence Thm. 3 proves):
//!   Greedy  — coordinate descent to a local optimum (Thm. 3)
//!   Grid    — per-layer independent exhaustive choice (the objective is
//!             separable across layers, so this is the global optimum)
//!   Entropy — rank layers by weight entropy; high-entropy layers get
//!             more bits under a mean-bit budget

use crate::metrics::Histogram;
use crate::quant::{qrange, round_ties_even};

pub const BIT_CHOICES: [u32; 4] = [2, 3, 4, 8];

/// One layer's input to the search.
pub struct LayerInfo {
    pub name: String,
    /// flattened weight
    pub w: Vec<f32>,
    /// importance proxy (e.g. mean diag Hessian from calibration); 1.0 = flat
    pub sensitivity: f32,
}

/// Search output per layer.
#[derive(Debug, Clone, PartialEq)]
pub struct BitwidthChoice {
    pub name: String,
    pub bits: u32,
    pub err: f64,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SearchPolicy {
    Greedy,
    Grid,
    Entropy { mean_bits: f32 },
}

/// Quantization MSE of `w` at `bits` (per-tensor symmetric absmax).
pub fn quant_mse(w: &[f32], bits: u32) -> f64 {
    let (qmin, qmax) = qrange(bits);
    let amax = w.iter().fold(0f32, |a, v| a.max(v.abs())).max(1e-8);
    let delta = amax / qmax as f32;
    let mut mse = 0f64;
    for v in w {
        let q = round_ties_even(v / delta).clamp(qmin as f32, qmax as f32);
        let e = (v - q * delta) as f64;
        mse += e * e;
    }
    mse / w.len().max(1) as f64
}

fn layer_obj(l: &LayerInfo, bits: u32, lambda: f64) -> f64 {
    quant_mse(&l.w, bits) * l.sensitivity as f64 + lambda * (bits as f64 / 8.0)
}

/// Run the search. Returns per-layer choices and the iteration count the
/// greedy descent needed (1 for the separable-exact policies).
pub fn search_bitwidths(
    layers: &[LayerInfo],
    lambda: f64,
    policy: SearchPolicy,
) -> (Vec<BitwidthChoice>, usize) {
    match policy {
        SearchPolicy::Grid => {
            // objective separable across layers -> exact per-layer argmin
            let out = layers
                .iter()
                .map(|l| {
                    let best = BIT_CHOICES
                        .iter()
                        .map(|&b| (b, layer_obj(l, b, lambda)))
                        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                        .unwrap();
                    BitwidthChoice { name: l.name.clone(), bits: best.0, err: best.1 }
                })
                .collect();
            (out, 1)
        }
        SearchPolicy::Greedy => {
            // Thm. 3 coordinate descent: start at 8 bits, sweep layers,
            // accept single-layer moves that lower the objective, stop at a
            // fixed point (monotone + bounded -> converges)
            let mut bits: Vec<u32> = vec![8; layers.len()];
            let mut iters = 0usize;
            loop {
                iters += 1;
                let mut improved = false;
                for (i, l) in layers.iter().enumerate() {
                    let cur = layer_obj(l, bits[i], lambda);
                    let best = BIT_CHOICES
                        .iter()
                        .map(|&b| (b, layer_obj(l, b, lambda)))
                        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                        .unwrap();
                    if best.1 + 1e-15 < cur {
                        bits[i] = best.0;
                        improved = true;
                    }
                }
                if !improved || iters > 64 {
                    break;
                }
            }
            let out = layers
                .iter()
                .zip(&bits)
                .map(|(l, &b)| BitwidthChoice {
                    name: l.name.clone(),
                    bits: b,
                    err: layer_obj(l, b, lambda),
                })
                .collect();
            (out, iters)
        }
        SearchPolicy::Entropy { mean_bits } => {
            // rank layers by weight-histogram entropy; spend the bit budget
            // on the highest-entropy (hardest to quantize) layers
            let mut ranked: Vec<(usize, f64)> = layers
                .iter()
                .enumerate()
                .map(|(i, l)| (i, Histogram::from_data(&l.w, 64).entropy()))
                .collect();
            ranked.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
            let budget = (mean_bits as f64 * layers.len() as f64).round() as i64;
            let mut bits = vec![BIT_CHOICES[0]; layers.len()];
            let mut spent: i64 = bits.iter().map(|b| *b as i64).sum();
            // greedily upgrade the highest-entropy layers to the next tier
            'outer: for tier in 1..BIT_CHOICES.len() {
                for (i, _) in &ranked {
                    let next = BIT_CHOICES[tier];
                    let cur = bits[*i];
                    if cur < next {
                        let delta = (next - cur) as i64;
                        if spent + delta > budget {
                            continue;
                        }
                        bits[*i] = next;
                        spent += delta;
                        if spent >= budget {
                            break 'outer;
                        }
                    }
                }
            }
            let out = layers
                .iter()
                .zip(&bits)
                .map(|(l, &b)| BitwidthChoice {
                    name: l.name.clone(),
                    bits: b,
                    err: layer_obj(l, b, lambda),
                })
                .collect();
            (out, 1)
        }
    }
}

/// Model-size reduction factor vs f32 for a bit assignment.
pub fn size_reduction(choices: &[BitwidthChoice], layer_params: &[usize]) -> f64 {
    let f32_bytes: f64 = layer_params.iter().map(|p| *p as f64 * 4.0).sum();
    let q_bytes: f64 = choices
        .iter()
        .zip(layer_params)
        .map(|(c, p)| *p as f64 * c.bits as f64 / 8.0)
        .sum();
    f32_bytes / q_bytes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::XorShift64Star;

    fn layers(n: usize, seed: u64) -> Vec<LayerInfo> {
        let mut r = XorShift64Star::new(seed);
        (0..n)
            .map(|i| {
                // alternate easy (tight) and hard (heavy-tailed) layers
                let scale = if i % 2 == 0 { 0.01 } else { 1.0 };
                LayerInfo {
                    name: format!("h{i}"),
                    w: (0..256).map(|_| r.next_normal() as f32 * scale).collect(),
                    sensitivity: 1.0,
                }
            })
            .collect()
    }

    #[test]
    fn mse_decreases_with_bits() {
        let l = layers(1, 1);
        let mut last = f64::INFINITY;
        for b in BIT_CHOICES {
            let m = quant_mse(&l[0].w, b);
            assert!(m < last, "bits {b}: {m} !< {last}");
            last = m;
        }
    }

    #[test]
    fn greedy_matches_grid_on_separable_objective() {
        let ls = layers(6, 2);
        let (greedy, iters) = search_bitwidths(&ls, 1e-6, SearchPolicy::Greedy);
        let (grid, _) = search_bitwidths(&ls, 1e-6, SearchPolicy::Grid);
        assert_eq!(greedy, grid);
        assert!(iters <= 3, "greedy converged in {iters} sweeps");
    }

    #[test]
    fn lambda_trades_accuracy_for_size() {
        let ls = layers(6, 3);
        let params = vec![256usize; 6];
        let (cheap, _) = search_bitwidths(&ls, 1e-2, SearchPolicy::Grid);
        let (accurate, _) = search_bitwidths(&ls, 1e-9, SearchPolicy::Grid);
        let mean = |cs: &[BitwidthChoice]| {
            cs.iter().map(|c| c.bits as f64).sum::<f64>() / cs.len() as f64
        };
        assert!(mean(&cheap) < mean(&accurate));
        assert!(size_reduction(&cheap, &params) > size_reduction(&accurate, &params));
    }

    #[test]
    fn high_lambda_reaches_paper_size_reduction() {
        // the paper claims up to 3.2x size reduction; an aggressive lambda
        // should push mean bits near 8/3.2 = 2.5
        let ls = layers(8, 4);
        let params = vec![256usize; 8];
        let (c, _) = search_bitwidths(&ls, 0.1, SearchPolicy::Grid);
        assert!(size_reduction(&c, &params) >= 3.0);
    }

    #[test]
    fn entropy_policy_respects_budget() {
        let ls = layers(8, 5);
        let (c, _) = search_bitwidths(&ls, 0.0, SearchPolicy::Entropy { mean_bits: 4.0 });
        let mean: f64 = c.iter().map(|x| x.bits as f64).sum::<f64>() / c.len() as f64;
        assert!(mean <= 4.01, "mean {mean}");
        // hard (high-entropy) layers got at least as many bits as easy ones
        let hard: u32 = c.iter().skip(1).step_by(2).map(|x| x.bits).min().unwrap();
        let easy: u32 = c.iter().step_by(2).map(|x| x.bits).max().unwrap();
        assert!(hard >= easy, "hard {hard} easy {easy}");
    }

    #[test]
    fn sensitivity_shifts_bits() {
        let mut ls = layers(2, 6);
        ls[0].sensitivity = 100.0;
        ls[1].sensitivity = 0.01;
        let (c, _) = search_bitwidths(&ls, 1e-4, SearchPolicy::Grid);
        assert!(c[0].bits >= c[1].bits);
    }
}
