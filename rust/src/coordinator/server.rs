//! The serving front-end: router -> batcher -> worker pool -> responses.
//!
//! Workers run on std::thread shards (one per simulated GPU). The server
//! API is synchronous-batch oriented: feed a workload of requests, get a
//! report with every response plus merged metrics — the shape every bench
//! and example drives.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::metrics::{mean_ci95, Breakdown, Stage, Summary};
use crate::quant::Variant;
use crate::runtime::Registry;

use super::batcher::{Batch, BatchPolicy, Batcher};
use super::request::{Request, Response};
use super::router::Router;
use super::worker::Worker;

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub model: String,
    pub variant: Variant,
    /// worker shards (simulated GPUs)
    pub shards: usize,
    /// compiled graph batch size (1 or 8 in the shipped artifacts)
    pub batch: usize,
    pub policy: BatchPolicy,
}

impl ServerConfig {
    pub fn new(model: &str, variant: Variant) -> Self {
        ServerConfig {
            model: model.to_string(),
            variant,
            shards: 1,
            batch: 8,
            policy: BatchPolicy::default(),
        }
    }
}

/// Workload results + metrics.
#[derive(Debug)]
pub struct ServerReport {
    pub responses: Vec<Response>,
    pub wall_s: f64,
    pub tokens_out: u64,
    pub decode_steps: u64,
    pub breakdown: Breakdown,
    pub weight_storage_bytes: usize,
    pub shard_tokens: Vec<u64>,
}

impl ServerReport {
    pub fn tokens_per_s(&self) -> f64 {
        self.tokens_out as f64 / self.wall_s.max(1e-9)
    }

    pub fn latency_summary(&self) -> Summary {
        let ls: Vec<f64> = self.responses.iter().map(|r| r.latency_s).collect();
        mean_ci95(&ls)
    }

    pub fn ttft_summary(&self) -> Summary {
        let ts: Vec<f64> = self.responses.iter().map(|r| r.ttft_s).collect();
        mean_ci95(&ts)
    }
}

/// Multi-shard server.
pub struct Server {
    cfg: ServerConfig,
    router: Router,
    batcher: Batcher,
    senders: Vec<Sender<Batch>>,
    results: Receiver<(usize, Result<Vec<Response>>)>,
    handles: Vec<JoinHandle<(Breakdown, u64, u64)>>,
    weight_storage_bytes: usize,
}

impl Server {
    /// Spin up the worker pool (compiles executables on first use).
    pub fn start(registry: &Arc<Registry>, cfg: ServerConfig) -> Result<Self> {
        let model_cfg = registry.model_cfg(&cfg.model)?;
        let router = Router::new(cfg.shards, model_cfg.ctx - 8);
        let batcher = Batcher::new(cfg.policy);

        let (res_tx, res_rx) = channel();
        let mut senders = Vec::new();
        let mut handles = Vec::new();
        let mut weight_storage_bytes = 0;
        for shard in 0..cfg.shards {
            let handle = registry.model_handle(&cfg.model, cfg.variant, cfg.batch)?;
            weight_storage_bytes = handle.weight_storage_bytes();
            let (tx, rx): (Sender<Batch>, Receiver<Batch>) = channel();
            senders.push(tx);
            let res_tx = res_tx.clone();
            handles.push(std::thread::spawn(move || {
                let mut worker = Worker::new(shard, handle);
                while let Ok(batch) = rx.recv() {
                    let out = worker.process_batch(batch);
                    if res_tx.send((shard, out)).is_err() {
                        break;
                    }
                }
                (worker.breakdown, worker.steps, worker.tokens_out)
            }));
        }
        Ok(Server {
            cfg,
            router,
            batcher,
            senders,
            results: res_rx,
            handles,
            weight_storage_bytes,
        })
    }

    /// Run a full workload to completion and shut the pool down.
    pub fn run_workload(mut self, requests: Vec<Request>) -> Result<ServerReport> {
        let t0 = Instant::now();
        let total = requests.len();
        // shard batches round-robin over workers via the router's
        // least-loaded choice at batch granularity
        let mut shard_rr = 0usize;
        for req in requests {
            let (req, _) = self.router.admit(req);
            self.batcher.push(req);
            // release full batches eagerly
            while let Some(batch) = self.batcher.take(Instant::now()) {
                self.dispatch(batch, &mut shard_rr)?;
            }
        }
        // deadline-flush the tail
        std::thread::sleep(self.batcher.policy().max_wait + Duration::from_millis(1));
        for batch in self.batcher.flush() {
            self.dispatch(batch, &mut shard_rr)?;
        }

        // collect
        let mut responses = Vec::with_capacity(total);
        let mut shard_tokens = vec![0u64; self.cfg.shards];
        while responses.len() < total {
            let (shard, out) = self
                .results
                .recv_timeout(Duration::from_secs(600))
                .map_err(|_| anyhow!("worker pool stalled"))?;
            let rs = out?;
            for r in &rs {
                self.router.complete(r.id);
                shard_tokens[shard] += r.tokens.len() as u64;
            }
            responses.extend(rs);
        }

        // shut down workers, merge metrics
        drop(self.senders);
        let mut breakdown = Breakdown::new();
        let mut steps = 0u64;
        let mut tokens = 0u64;
        for h in self.handles {
            let (b, s, t) = h.join().map_err(|_| anyhow!("worker panicked"))?;
            breakdown.merge(&b);
            steps += s;
            tokens += t;
        }
        // comm/sync stages are exercised by the cluster-sim path; on the
        // serve path they only appear if scale sync ran
        breakdown.add(Stage::Sync, 0.0);
        Ok(ServerReport {
            responses,
            wall_s: t0.elapsed().as_secs_f64(),
            tokens_out: tokens,
            decode_steps: steps,
            breakdown,
            weight_storage_bytes: self.weight_storage_bytes,
            shard_tokens,
        })
    }

    fn dispatch(&mut self, batch: Batch, shard_rr: &mut usize) -> Result<()> {
        let shard = *shard_rr % self.senders.len();
        *shard_rr += 1;
        self.senders[shard]
            .send(batch)
            .map_err(|_| anyhow!("worker {shard} is gone"))
    }
}
