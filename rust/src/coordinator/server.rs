//! The serving front-end: an event-driven dispatcher over step-driven
//! worker shards.
//!
//! Each worker runs on its own std::thread (one per simulated GPU) and
//! owns a step-driven [`Worker`]. The server's event loop:
//!
//!   * replays `Arrival.at_s` offsets (open loop, [`Server::run_open_loop`])
//!     or injects everything at t=0 (closed-loop firehose,
//!     [`Server::run_workload`]),
//!   * routes each admitted request through the [`Router`]'s actual
//!     `RouteDecision` — least loaded by in-flight *tokens*,
//!   * injects it into that shard's in-flight batch at the next step
//!     boundary (continuous mode) or forms deadline batches and
//!     round-robins them (static mode, the ablation baseline),
//!   * consumes the workers' streamed per-token [`ServeEvent`]s, so
//!     TTFT / p50 / p99 are measured under real queueing.
//!
//! Scheduler selection is [`SchedulerMode`] on the config; `Static`
//! preserves the pre-refactor run-to-completion behavior exactly.
//!
//! The dispatcher is also where SLOs are *enforced*, not just measured
//! ([`SloGate`]): the trailing policies feed every completion into a
//! rolling per-shard latency window (aged by [`STALE_AFTER_TARGETS`] so
//! a full-shed interval cannot freeze the verdict), while
//! [`AdmissionPolicy::Predictive`] prices each candidate's completion
//! time from the routed shard's in-flight token backlog and the
//! calibrated [`CostEstimator`] — shedding *before* the window would
//! ever see a slow completion. Shed requests get exactly one terminal
//! [`ServeEvent::Shed`] with their router charge refunded; batch-
//! priority load rides the low queue tier, which interactive traffic
//! preempts.
//!
//! **Fault tolerance** (armed by `ServerConfig::fault` carrying a
//! seeded `FaultPlan`; continuous mode only): the dispatcher tracks
//! every in-flight request in a [`Flight`] table and treats each
//! worker event as that shard's liveness beat. A shard with runnable
//! work that stays silent past `step_deadline` turns `Suspect`; past
//! `max_misses` consecutive deadlines it is `Dead` — its sender drops,
//! the router removes it from the routing set permanently, and every
//! in-flight request it held migrates: the router charge is refunded
//! idempotently, the admitted prompt plus all already-delivered tokens
//! re-prefill as a prefix on the least-loaded survivor, and the new
//! stream is rebased by the handoff offset so the dispatcher delivers
//! each token position exactly once (duplicates from a resurrected or
//! buffered stream are suppressed, gaps are impossible by
//! construction — both are counted in the report). Capacity loss flows
//! into admission automatically: survivors absorb the dead shard's
//! backlog, so the predictive gate prices the thinner fleet and sheds
//! batch work instead of breaching the SLO. An injected sim crash is
//! silent (`runtime::is_injected_crash`) — detection must come from
//! the missing beats, exactly as with a real dead rank; any *other*
//! worker error is surfaced: recorded in `ServerReport::worker_errors`
//! and handled as a kill when fault handling is armed, or propagated
//! as before when it is not.
//!
//! **Elastic recovery** extends the arc past detection into
//! kill -> degrade -> rejoin -> restore. A `recover:<shard>@<step>`
//! clause in the fault plan (or a `ServerConfig::standby` warm spare,
//! consumed at most one per detected death) brings a replacement online
//! once the shard is Dead: the dispatcher spawns a fresh sim worker for
//! the next incarnation of the shard's fault schedule, accounts the
//! quantized (8-bit) weight re-broadcast that re-shards its partition
//! over the survivor ring, and re-enters it behind a probe ramp — the
//! router routes a probing shard at most one stream at a time until it
//! stays healthy for `FaultSpec::ramp_deadlines` clean step deadlines,
//! then `Router::promote` restores its full share. Streams stay
//! exactly-once across kill -> rejoin: migration rebased them when the
//! shard died, and the rejoined incarnation is a fresh worker with
//! fresh streams, so the position dedup needs no new cases. Meanwhile
//! **degraded mode** (`ServerConfig::degrade_bits`) converts a shrunken
//! fleet into capacity instead of shed load: survivors drop their KV
//! read width (`SimModel::set_kv_bits` — fused decode is memory-bound
//! on KV pages, so 8 -> 4 roughly halves the per-slot step cost), the
//! predictive gate reprices with `CostEstimator::degraded`, and a
//! hysteretic ladder (enter on a death or on sustained backlog above
//! the high watermark; exit only at full fleet strength with backlog
//! below the low watermark) restores native width without oscillating
//! within one pressure episode. PJRT shards neither respawn nor change
//! width at runtime (compiled graphs pin both) — elastic recovery is a
//! sim-backend facility, like fault injection itself.
//!
//! **Disaggregated prefill/decode serving** (`ServerConfig::disagg`,
//! continuous mode only) splits the fleet by [`ShardRole`]: prefill-
//! role shards admit arrivals and run chunked prefill only — when a
//! lane's prefill completes (first token emitted), the worker exports
//! its KV block table ([`ServeEvent::Handoff`]) and the dispatcher
//! migrates the pages to a decode-role shard over a point-to-point
//! quantized transfer ([`collective::transfer_quant_pages`]): blocks
//! ship at their true packed width, checksummed and retried like every
//! quantized collective payload, bytes counted in the dispatcher's
//! wire [`CommStats`]. The importing worker maps the pages straight
//! into its pool and continues the stream bit-identically — the token
//! trajectory is a pure function of the KV prefix, so no re-prefill
//! and no `seq` rebase is needed (the importer resumes at `seq ==
//! generated.len()`, continuing the same global positions). When the
//! transfer ejects (persistent corruption) or the target cannot hold
//! the residency ([`ServeEvent::ImportBounced`]), the stream falls
//! back to the kill-path's re-prefill injection — the no-pages path.
//! Roles are *elastic*: an [`EstimatorCalibration`] regresses
//! predicted-vs-actual completion error online from completions (the
//! correction also feeds the predictive admission margin), and
//! `recovery_tick` re-roles one shard per pressure episode when the
//! predicted prefill:decode backlog ratio drifts past the
//! [`ROLE_HI`]/[`ROLE_LO`] hysteresis band — mirroring the degrade
//! ladder's watermark/tick discipline. Rejoining and standby-promoted
//! shards in a disaggregated fleet are seeded over the same page wire:
//! the most-loaded survivor hands off its youngest decoding lane and
//! the idle-prober routing priority lands the pages on the fresh
//! shard, so recovery costs a page transfer instead of a re-prefill.

use std::cmp::Ordering;
use std::collections::{HashMap, HashSet, VecDeque};
use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::collective::{transfer_quant_pages, CommStats, LinkFaults, LinkModel};
use crate::metrics::{mean_ci95, percentile, Breakdown, RollingWindow, Stage, Summary};
use crate::quant::Variant;
use crate::runtime::{is_injected_crash, Registry, SimCost, SimModel};
use crate::util::pool;

use super::batcher::{AdmissionPolicy, Batch, BatchPolicy, Batcher, SchedulerMode};
use super::cost::{CostEstimator, EstimatorCalibration};
use super::faults::{FaultSpec, ShardHealth};
use super::kv_cache::{LaneExport, DEFAULT_BLOCK_SIZE};
use super::request::{Priority, Request, RequestId, Response, ServeEvent};
use super::router::{Router, ShardRole};
use super::worker::{Backend, Worker, WorkerStats};
use super::workload::Arrival;

/// Completions the SLO gate remembers per shard; small enough to track
/// current pressure (a breach ages out once the shard recovers), large
/// enough for a usable tail estimate.
const SLO_WINDOW: usize = 64;

/// Both gates trip at this fraction of the configured target, for dual
/// reasons. Trailing windows are a *lagging* signal — completion
/// latencies, not the queue — so by the time served p99 reads at
/// `target/2` the backlog already in flight is worth roughly the other
/// half; tripping early absorbs that detection lag. The predictive
/// estimate is an *optimistic* signal — it prices decode at the
/// full-batch amortized rate and ignores preemption by later
/// interactive arrivals, which under-predicts by up to ~2x in the
/// prefill-heavy overload regime — so tripping at half the target
/// absorbs the calibration optimism. Both margins hold served p99
/// inside the target itself (pinned by the batching ablation's SLO and
/// predictive sweeps).
const SLO_TRIP_FRACTION: f64 = 0.5;

/// Trailing-window staleness horizon, in multiples of the latency
/// target: a window sample older than `STALE_AFTER_TARGETS x target`
/// (floored at [`STALE_FLOOR_MS`]) is expired before the gate reads the
/// window. The window only records *served* completions, so under a
/// sustained full-shed interval it would otherwise hold its breach-time
/// samples forever and the gate's verdict would freeze; aging lets a
/// shard with zero recent completions re-evaluate (an empty window
/// never breaches), complementing the idle-shard probe.
const STALE_AFTER_TARGETS: f64 = 8.0;

/// Floor (ms) for the staleness horizon, so aggressive test targets do
/// not expire the window faster than completions can possibly land.
const STALE_FLOOR_MS: f64 = 250.0;

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub model: String,
    pub variant: Variant,
    /// worker shards (simulated GPUs)
    pub shards: usize,
    /// compiled graph batch size (1 or 8 in the shipped artifacts)
    pub batch: usize,
    pub policy: BatchPolicy,
    /// scheduling discipline; `Static` is the seed behavior
    pub mode: SchedulerMode,
    /// max prompt tokens prefilled per step boundary (0 = whole-prompt;
    /// sim backend only — the compiled PJRT prefill graph is whole-prompt)
    pub prefill_chunk: usize,
    /// what to do with new load while a shard breaches its SLO
    pub admission: AdmissionPolicy,
    /// fault injection plan + liveness/detection knobs; the default is
    /// disarmed (no plan, no wall-clock deadlines). Continuous mode
    /// only — static batches run to completion and cannot migrate.
    pub fault: FaultSpec,
    /// warm-spare pool: replacements held ready beside the serving
    /// fleet. At most one spare promotes per detected death, rejoining
    /// the dead shard's rank immediately instead of waiting for a
    /// scheduled `recover:` clause. Sim backend only.
    pub standby: usize,
    /// degraded-mode KV width: `Some(bits)` arms the runtime bitwidth
    /// ladder — under capacity pressure (a dead shard, or sustained
    /// decode backlog) survivors drop their KV reads from 8-bit to
    /// `bits`, raising effective throughput so admission sheds less;
    /// the ladder restores native width hysteretically once the fleet
    /// is whole and pressure clears. `None` (default) = fixed-width
    /// serving, bit-identical to the pre-ladder behavior.
    pub degrade_bits: Option<u32>,
    /// physical KV blocks per shard pool (`None` = fully provisioned:
    /// every lane can hold a full context). Under-provisioned pools
    /// make admission a block-budget question: arrivals bounce back to
    /// the queue, interactive arrivals preempt batch residencies, and
    /// the predictive gate prices the block-pressure drain time.
    pub kv_blocks: Option<usize>,
    /// shared-prefix block reuse across requests (on by default):
    /// arrivals whose prompt prefix matches a retained chain skip
    /// straight to the first uncached block.
    pub prefix_cache: bool,
    /// self-speculative draft depth per decode cycle (0 = plain
    /// decode). Each decoding lane drafts up to `spec_k` tokens from
    /// the `spec_draft_bits`-wide variant of the same weights and one
    /// fused full-width pass verifies them; only verified tokens are
    /// emitted, so streams stay bit-identical to plain serving. Sim
    /// backend only — `Server::start` bails when set, mirroring
    /// `degrade_bits`.
    pub spec_k: usize,
    /// draft width (bits) speculative draft passes run at; the
    /// bitwidth-ladder knob that makes the draft model free
    pub spec_draft_bits: u32,
    /// disaggregated prefill/decode serving: split the fleet into
    /// [`ShardRole::Prefill`] shards (first `ceil(shards/2)`; admit and
    /// chunk-prefill only, handing finished lanes off over the
    /// quantized page wire) and [`ShardRole::Decode`] shards (import
    /// pages, run the decode loop), with estimator-driven elastic
    /// re-roling under sustained role imbalance. Continuous mode and
    /// `shards > 1` only; a single shard stays `Mixed`. `false`
    /// (default) = the mixed baseline, bit-identical to pre-disagg
    /// serving.
    pub disagg: bool,
}

impl ServerConfig {
    pub fn new(model: &str, variant: Variant) -> Self {
        ServerConfig {
            model: model.to_string(),
            variant,
            shards: 1,
            batch: 8,
            policy: BatchPolicy::default(),
            mode: SchedulerMode::Static,
            prefill_chunk: 0,
            admission: AdmissionPolicy::Open,
            fault: FaultSpec::default(),
            standby: 0,
            degrade_bits: None,
            kv_blocks: None,
            prefix_cache: true,
            spec_k: 0,
            spec_draft_bits: 4,
            disagg: false,
        }
    }
}

/// Messages from the dispatcher to a worker shard.
enum ToWorker {
    /// continuous mode: enqueue; the worker admits it at the next step
    /// boundary (capacity permitting). `true` = low queue tier (batch
    /// client priority, or breach-time arrival under
    /// `AdmissionPolicy::Priority`)
    Inject(Request, bool),
    /// static mode: run this formed batch to completion
    Batch(Vec<Request>),
    /// degrade ladder: switch the backend's KV read width (no-op on
    /// PJRT backends, whose compiled graphs pin the width)
    SetKvBits(u32),
    /// disaggregation: continue a handed-off stream from imported KV
    /// pages (no re-prefill). Fields mirror [`ServeEvent::Handoff`];
    /// a worker that cannot hold the residency bounces the request
    /// back as [`ServeEvent::ImportBounced`].
    ImportPages {
        req: Request,
        generated: Vec<i32>,
        pages: Arc<LaneExport>,
        ttft_s: f64,
        queued_s: f64,
        first_token_at: Option<Instant>,
    },
    /// elastic re-roling: arm (`true`, prefill role) or disarm the
    /// worker's hand-off-on-prefill-completion switch. Safe to flip
    /// live — lanes already decoding finish where they are.
    SetRole(bool),
    /// rejoin/standby seeding: export the youngest decoding lane as a
    /// [`ServeEvent::Handoff`]; a worker with nothing decoding ignores
    /// the request.
    ExportLane,
}

/// What the admission gate decided for one routed request.
#[derive(Clone, Copy)]
enum Gate {
    Admit,
    Low,
    Shed,
}

/// Rolling latency windows + the admission policy that reads them.
/// Lives in the dispatcher: completions stream through it anyway, so the
/// gate sees every latency sample with no extra synchronization.
///
/// Continuous mode keeps one window per shard (the router's `decision.
/// shard` is where the request will actually serve). Static mode
/// dispatches formed batches round-robin — the router's shard choice is
/// bookkeeping only — so the gate collapses to a single global window
/// there; per-shard windows would read (and starve) the wrong shard.
///
/// The `Predictive` policy ignores the windows entirely: it prices the
/// candidate against the routed shard's in-flight token backlog with
/// the calibrated [`CostEstimator`], so its signal can neither trail
/// nor go stale (no backlog, no breach).
struct SloGate {
    policy: AdmissionPolicy,
    windows: Vec<RollingWindow>,
    estimator: Option<CostEstimator>,
    /// native-width estimator the degrade ladder reprices from (the
    /// active `estimator` may be a `degraded()` variant of this)
    base_estimator: Option<CostEstimator>,
    /// server's prefill chunk (serialization term of the prediction)
    prefill_chunk: usize,
    /// KV block size the shards allocate at (0 disables the block-
    /// pressure term)
    block_size: usize,
    /// physical blocks in one shard's pool — demand past this drains at
    /// the decode rate before the candidate can hold its residency
    pool_blocks: usize,
    /// trailing policies only: samples older than this are expired
    /// before every read (the stale-window fix)
    stale_after: Option<Duration>,
    /// online predicted-vs-actual completion regression: every tracked
    /// completion feeds it one (raw prediction, observed latency)
    /// sample; its correction multiplies into the predictive margin and
    /// drives the re-role ratio, and its mean error is reported
    cal: EstimatorCalibration,
}

impl SloGate {
    fn new(
        policy: AdmissionPolicy,
        shards: usize,
        global: bool,
        estimator: Option<CostEstimator>,
        prefill_chunk: usize,
        block_size: usize,
        pool_blocks: usize,
    ) -> Self {
        let n = if global { 1 } else { shards };
        let stale_after = match policy {
            AdmissionPolicy::SheddingP99 { target_ms }
            | AdmissionPolicy::Priority { target_ms } => Some(Duration::from_secs_f64(
                (target_ms * STALE_AFTER_TARGETS).max(STALE_FLOOR_MS) / 1e3,
            )),
            _ => None,
        };
        SloGate {
            policy,
            windows: (0..n).map(|_| RollingWindow::new(SLO_WINDOW)).collect(),
            estimator,
            base_estimator: estimator,
            prefill_chunk,
            block_size,
            pool_blocks,
            stale_after,
            cal: EstimatorCalibration::default(),
        }
    }

    /// Price one routed candidate's completion for *calibration*: the
    /// raw (uncorrected) prediction the estimator makes from the
    /// shard's backlog, regardless of admission policy — calibration
    /// must regress the model's own error, never its corrected output.
    /// `None` when no estimator is fitted (e.g. the PJRT path without a
    /// profile under a trailing policy).
    fn predict_raw(&self, backlog: (usize, usize), req: &Request, block_demand: usize) -> Option<f64> {
        let est = self.estimator.as_ref()?;
        let mut ms =
            est.predict_ms(backlog, req.prompt.len(), req.max_new_tokens, self.prefill_chunk);
        if self.block_size > 0 {
            let deficit = block_demand.saturating_sub(self.pool_blocks);
            ms += est.block_drain_s(deficit, self.block_size) * 1e3;
        }
        Some(ms)
    }

    /// Degrade-ladder repricing: swap the predictive estimator for its
    /// `kv_bits`-scaled variant so admission prices the fleet's *actual*
    /// per-token rate — degraded survivors decode faster, so the gate
    /// sheds less instead of pricing phantom backlog at native speed.
    /// `kv_bits == 8` restores the native-width estimator exactly.
    fn reprice(&mut self, kv_bits: u32) {
        self.estimator = self.base_estimator.map(|e| e.degraded(kv_bits));
    }

    fn idx(&self, shard: usize) -> usize {
        if self.windows.len() == 1 {
            0
        } else {
            shard
        }
    }

    /// Feed one completion's end-to-end latency into its shard's window.
    fn observe(&mut self, shard: usize, latency_s: f64) {
        let i = self.idx(shard);
        self.windows[i].push(latency_s * 1e3);
    }

    /// Gate a request routed to `shard`.
    ///
    /// Trailing policies: an empty window never breaches, so cold
    /// shards admit; `established` is false when the shard holds no
    /// other in-flight work — an idle shard always admits (a probe):
    /// without it, shedding starves the window of fresh completions and
    /// a breached gate could never observe the recovery. Stale samples
    /// are expired before the read so a full-shed interval cannot
    /// freeze the verdict.
    ///
    /// Predictive: `backlog` is the shard's in-flight (prefill, decode)
    /// token backlog *excluding* the candidate; the gate sheds a
    /// batch-priority candidate whose predicted completion would breach
    /// the target. Interactive candidates are never shed — they ride
    /// the normal tier ahead of parked batch work, which absorbs the
    /// shed instead. `block_demand` is the shard's in-flight KV-block
    /// demand *including* the candidate's freshly-routed charge: the
    /// slice past the shard's pool can only materialize as residencies
    /// drain, so the gate adds that drain time (priced at the decode
    /// rate) — block pressure becomes predicted latency instead of an
    /// invisible admission stall.
    ///
    /// The queue tier comes from the request's first-class priority:
    /// batch-priority work parks in the low tier even with a healthy
    /// gate. One legacy exception: `AdmissionPolicy::Priority` demotes
    /// *every* breach-time arrival (interactive included) to the low
    /// tier — that demotion is the policy's entire mechanism.
    fn decide(
        &mut self,
        shard: usize,
        established: bool,
        req: &Request,
        backlog: (usize, usize),
        block_demand: usize,
    ) -> Gate {
        let i = self.idx(shard);
        if let Some(age) = self.stale_after {
            self.windows[i].expire_older_than(age);
        }
        let tier = match req.priority {
            Priority::Interactive => Gate::Admit,
            Priority::Batch => Gate::Low,
        };
        let breached = |w: &RollingWindow, target_ms: f64| {
            established && w.percentile(0.99) > SLO_TRIP_FRACTION * target_ms
        };
        match self.policy {
            AdmissionPolicy::Open => tier,
            AdmissionPolicy::SheddingP99 { target_ms } => {
                if breached(&self.windows[i], target_ms) {
                    Gate::Shed
                } else {
                    tier
                }
            }
            AdmissionPolicy::Priority { target_ms } => {
                if breached(&self.windows[i], target_ms) {
                    Gate::Low
                } else {
                    tier
                }
            }
            AdmissionPolicy::Predictive { target_ms } => {
                // run_arrivals refuses to start predictive without an
                // estimator; if that invariant ever slips, degrade to
                // the open tier instead of panicking mid-serve
                let Some(est) = self.estimator.as_ref() else {
                    return tier;
                };
                // fold the observed prediction error back into the
                // margin (identity until completions arrive)
                let est = est.calibrated(self.cal.correction());
                let mut predicted_ms = est.predict_ms(
                    backlog,
                    req.prompt.len(),
                    req.max_new_tokens,
                    self.prefill_chunk,
                );
                if self.block_size > 0 {
                    let deficit = block_demand.saturating_sub(self.pool_blocks);
                    predicted_ms += est.block_drain_s(deficit, self.block_size) * 1e3;
                }
                if req.priority == Priority::Batch && predicted_ms > SLO_TRIP_FRACTION * target_ms {
                    Gate::Shed
                } else {
                    tier
                }
            }
        }
    }
}

/// Workload results + metrics.
#[derive(Debug)]
pub struct ServerReport {
    pub responses: Vec<Response>,
    pub wall_s: f64,
    pub tokens_out: u64,
    /// per-token events observed by the dispatcher (== tokens_out when
    /// no request was lost in flight)
    pub tokens_streamed: u64,
    pub decode_steps: u64,
    pub breakdown: Breakdown,
    /// total weight bytes across all shards (each shard holds a replica)
    pub weight_storage_bytes: usize,
    pub shard_weight_bytes: Vec<usize>,
    pub shard_tokens: Vec<u64>,
    /// requests admitted into slots / retired from slots
    pub joins: u64,
    pub retires: u64,
    /// max concurrently in-flight slots per worker incarnation (one
    /// entry per shard, plus one per rejoin-spawned replacement)
    pub peak_active: Vec<usize>,
    /// requests the admission gate refused (one terminal `Shed` each;
    /// disjoint from `responses`)
    pub shed_ids: Vec<RequestId>,
    /// shed requests that carried `Priority::Interactive` — the
    /// predictive gate must keep this at zero while batch work remains
    /// sheddable
    pub shed_interactive: u64,
    /// requests parked in the low-priority tier at admission (batch
    /// priority, or breach-time load under `AdmissionPolicy::Priority`)
    pub deprioritized: u64,
    /// observed gaps between consecutive token *emission* stamps of the
    /// same request (seconds) — decode cadence only; queueing/park time
    /// is reported per response as `Response::queued_s`
    pub inter_token_gap_s: Vec<f64>,
    /// router sessions still holding a token charge at shutdown — a
    /// shed/complete accounting leak if nonzero (every request must be
    /// released exactly once)
    pub router_in_flight: usize,
    /// in-flight tokens still charged to shards at shutdown (0 when the
    /// refund/complete path is exact)
    pub router_inflight_tokens: usize,
    /// requests migrated off a dead shard, in migration order (a request
    /// surviving two kills appears twice)
    pub migrated_ids: Vec<RequestId>,
    /// prompt-prefix tokens re-ingested on survivor shards (admitted
    /// prompt + already-delivered tokens, summed over migrations) — the
    /// recovery cost the ablation reports
    pub reprefill_tokens: u64,
    /// duplicate token positions *suppressed* by the dispatcher's
    /// position dedup (buffered pre-crash stream overlapping the
    /// re-prefilled one); the client-visible stream stays exactly-once
    pub dup_tokens: u64,
    /// position gaps observed (a token arrived past the next expected
    /// position) — must be zero; nonzero means delivery broke
    pub lost_tokens: u64,
    /// shards declared Dead, in detection order
    pub dead_shards: Vec<usize>,
    /// final lifecycle state per shard
    pub shard_health: Vec<ShardHealth>,
    /// per-kill detection latency in units of the step deadline
    /// (liveness kills land in [max_misses, max_misses + 1])
    pub detection_deadlines: Vec<f64>,
    /// worker errors contained by fault handling instead of tearing the
    /// serve down (empty when disarmed — those still propagate)
    pub worker_errors: Vec<String>,
    /// shards brought back online, in rejoin order (a flapping shard
    /// that recovers twice appears twice)
    pub rejoined: Vec<usize>,
    /// warm spares consumed (at most one per detected death, bounded by
    /// `ServerConfig::standby`)
    pub standby_promotions: u64,
    /// degrade-ladder entries (8-bit -> `degrade_bits` KV reads); one
    /// pressure episode must produce exactly one
    pub degrade_enters: u64,
    /// degrade-ladder exits (native width restored)
    pub degrade_exits: u64,
    /// quantized weight bytes re-broadcast to rejoining shards (8-bit
    /// codes: one byte per parameter of the shard's replica)
    pub rebroadcast_bytes: u64,
    /// per promoted rejoin, the shard's routing share relative to a
    /// fair 1/alive split, measured over admissions from its promotion
    /// to drain (1.0 = exactly fair; no admissions after promotion
    /// reports 1.0)
    pub rejoin_admit_share: Vec<f64>,
    /// prompt tokens whose prefill a prefix-cache hit skipped, summed
    /// over all worker incarnations
    pub prefix_hit_tokens: u64,
    /// batch-priority residencies unmapped (table unmap + park) to
    /// admit an interactive arrival within one step
    pub preemptions: u64,
    /// tokens re-prefilled on preemption resume (the slice the prefix
    /// cache no longer held) — the bounded cost of cheap preemption
    pub resume_reprefill_tokens: u64,
    /// draft tokens proposed by low-bit speculative passes (0 when
    /// `ServerConfig::spec_k == 0`)
    pub drafted_tokens: u64,
    /// draft tokens the full-width verify passes accepted
    pub accepted_tokens: u64,
    /// finished-prefill lanes exported for migration (prefill-role
    /// handoffs plus rejoin-seeding exports), summed over all worker
    /// incarnations
    pub handoffs: u64,
    /// KV page bytes shipped over the quantized point-to-point
    /// migration wire (true packed width plus f32 per-block params);
    /// disagg serving must keep this > 0 while re-prefill stays the
    /// rare fallback
    pub kv_migrate_bytes: u64,
    /// elastic re-role moves (at most one per pressure episode)
    pub reroles: u64,
    /// fraction of fleet busy time spent in fused prefill passes
    /// (prefill + decode shares sum to 1 when the fleet did any work)
    pub prefill_busy_share: f64,
    /// fraction of fleet busy time spent in fused decode (and
    /// draft/verify) passes
    pub decode_busy_share: f64,
    /// online estimator calibration: mean |predicted - actual| /
    /// actual over tracked completions (0 with no samples)
    pub estimator_abs_err: f64,
}

impl ServerReport {
    pub fn tokens_per_s(&self) -> f64 {
        self.tokens_out as f64 / self.wall_s.max(1e-9)
    }

    /// Fraction of speculative drafts the full-width verify accepted
    /// (0 when speculation was off — no drafts were proposed).
    pub fn acceptance_rate(&self) -> f64 {
        if self.drafted_tokens == 0 {
            return 0.0;
        }
        self.accepted_tokens as f64 / self.drafted_tokens as f64
    }

    /// Requests shed by the admission gate.
    pub fn shed(&self) -> usize {
        self.shed_ids.len()
    }

    /// Requests migrated off a dead shard.
    pub fn migrated(&self) -> usize {
        self.migrated_ids.len()
    }

    /// Shed fraction of the offered load.
    pub fn shed_rate(&self) -> f64 {
        let total = self.responses.len() + self.shed_ids.len();
        if total == 0 {
            return 0.0;
        }
        self.shed_ids.len() as f64 / total as f64
    }

    /// Inter-token (decode-stall) latency percentile (q in [0, 1]),
    /// measured between worker emission stamps — park intervals and
    /// dispatcher-side queueing never inflate it.
    pub fn itl_percentile(&self, q: f64) -> f64 {
        percentile(&self.inter_token_gap_s, q)
    }

    /// Queueing-delay percentile (q in [0, 1]) over served requests:
    /// arrival -> slot admission, the park/batch-formation interval
    /// reported separately from decode cadence.
    pub fn queue_delay_percentile(&self, q: f64) -> f64 {
        let qs: Vec<f64> = self.responses.iter().map(|r| r.queued_s).collect();
        percentile(&qs, q)
    }

    /// Served requests carrying `priority`.
    pub fn served_for(&self, priority: Priority) -> usize {
        self.responses.iter().filter(|r| r.priority == priority).count()
    }

    /// End-to-end latency percentile over one priority class only.
    pub fn latency_percentile_for(&self, priority: Priority, q: f64) -> f64 {
        let ls: Vec<f64> = self
            .responses
            .iter()
            .filter(|r| r.priority == priority)
            .map(|r| r.latency_s)
            .collect();
        percentile(&ls, q)
    }

    pub fn latency_summary(&self) -> Summary {
        let ls: Vec<f64> = self.responses.iter().map(|r| r.latency_s).collect();
        mean_ci95(&ls)
    }

    pub fn ttft_summary(&self) -> Summary {
        let ts: Vec<f64> = self.responses.iter().map(|r| r.ttft_s).collect();
        mean_ci95(&ts)
    }

    /// End-to-end latency percentile (q in [0, 1]).
    pub fn latency_percentile(&self, q: f64) -> f64 {
        let ls: Vec<f64> = self.responses.iter().map(|r| r.latency_s).collect();
        percentile(&ls, q)
    }

    /// Time-to-first-token percentile (q in [0, 1]).
    pub fn ttft_percentile(&self, q: f64) -> f64 {
        let ts: Vec<f64> = self.responses.iter().map(|r| r.ttft_s).collect();
        percentile(&ts, q)
    }
}

/// Dispatcher-side state of one in-flight request: everything needed to
/// rebuild it on a survivor shard (admitted prompt, budget, priority)
/// plus the delivered token stream that makes handoff exactly-once.
struct Track {
    /// admitted prompt (post-router rewrite) — the re-prefill prefix
    prompt: Vec<i32>,
    /// admitted prompt length, preserved across migration for the
    /// response
    prompt_len: usize,
    /// original token budget
    max_new: usize,
    priority: Priority,
    /// low queue tier at admission; migrations keep the tier
    low: bool,
    /// injection-time arrival stamp (latency/TTFT baseline)
    arrival: Instant,
    /// shard currently serving the request
    shard: usize,
    /// delivered count at the last (re)assignment: a worker-local `seq`
    /// maps to global position `offset + seq`
    offset: usize,
    /// tokens delivered to the client so far, in position order
    delivered: Vec<i32>,
    ttft_s: f64,
    first_token_at: Option<Instant>,
    last_token_at: Option<Instant>,
    migrations: u32,
    /// the estimator's raw completion prediction at admission (ms; 0
    /// when no estimator was fitted) — regressed against the observed
    /// latency when the request completes
    predicted_ms: f64,
    /// terminal event consumed (Done, synthesized Done, or Shed); late
    /// duplicates from a resurrected stream are dropped against this
    done: bool,
}

impl Track {
    fn new(req: &Request, shard: usize, low: bool, predicted_ms: f64) -> Self {
        Track {
            prompt: req.prompt.clone(),
            prompt_len: req.prompt.len(),
            max_new: req.max_new_tokens,
            priority: req.priority,
            low,
            arrival: req.arrival,
            shard,
            offset: 0,
            delivered: Vec::new(),
            ttft_s: 0.0,
            first_token_at: None,
            last_token_at: None,
            migrations: 0,
            predicted_ms,
            done: false,
        }
    }

    /// Synthesize the response for a stream whose every token was
    /// already delivered when its shard died (the worker's own `Done`
    /// is either buffered — later deduped — or was never produced).
    fn response(&self, id: RequestId, shard: usize) -> Response {
        Response {
            id,
            tokens: self.delivered.clone(),
            prompt_len: self.prompt_len,
            priority: self.priority,
            latency_s: self.arrival.elapsed().as_secs_f64(),
            ttft_s: self.ttft_s,
            queued_s: 0.0,
            first_token_at: self.first_token_at.unwrap_or(self.arrival),
            shard,
        }
    }
}

/// Fault-recovery accounting accumulated by the dispatcher.
#[derive(Default)]
struct Recovery {
    dead_shards: Vec<usize>,
    detection_deadlines: Vec<f64>,
    migrated_ids: Vec<RequestId>,
    reprefill_tokens: u64,
    dup_tokens: u64,
    lost_tokens: u64,
    worker_errors: Vec<String>,
}

/// The dispatcher's in-flight table plus terminal accounting: token
/// delivery (position-deduped), completions, sheds, per-shard liveness
/// clocks, and the kill/migrate machinery. Router and senders are
/// passed in per call — they live on [`Server`] and mutate together
/// with this table during a kill.
struct Flight {
    tracks: HashMap<RequestId, Track>,
    responses: Vec<Response>,
    shed_ids: Vec<RequestId>,
    /// every shed id exactly once, even if a worker ever forwarded a
    /// duplicate terminal event (exactly-once shed accounting)
    shed_seen: HashSet<RequestId>,
    shed_interactive: u64,
    /// observed gaps between consecutive *delivered* token emission
    /// stamps of the same request
    gaps: Vec<f64>,
    tokens_streamed: u64,
    /// per-shard liveness clock: last event received (or last idle
    /// observation) — a busy shard silent past the death deadline dies
    last_event_at: Vec<Instant>,
    health: Vec<ShardHealth>,
    recovery: Recovery,
    /// backend context length; a migrated prefix at or past `ctx` can't
    /// extend, so its stream is synthesized complete instead
    ctx: usize,
}

impl Flight {
    fn new(shards: usize, ctx: usize) -> Self {
        Flight {
            tracks: HashMap::new(),
            responses: Vec::new(),
            shed_ids: Vec::new(),
            shed_seen: HashSet::new(),
            shed_interactive: 0,
            gaps: Vec::new(),
            tokens_streamed: 0,
            last_event_at: vec![Instant::now(); shards],
            health: vec![ShardHealth::Healthy; shards],
            recovery: Recovery::default(),
            ctx,
        }
    }

    fn undone(&self) -> usize {
        self.responses.len() + self.shed_ids.len()
    }

    fn busy(&self, shard: usize) -> bool {
        self.tracks.values().any(|t| !t.done && t.shard == shard)
    }

    /// Record a dispatched request. Resets the shard's liveness clock
    /// when this is its first runnable work — an idle shard's clock is
    /// stale by design and must not count against it.
    fn insert(&mut self, req: &Request, shard: usize, low: bool, predicted_ms: f64) {
        if !self.busy(shard) {
            self.last_event_at[shard] = Instant::now();
        }
        self.tracks.insert(req.id, Track::new(req, shard, low, predicted_ms));
    }

    /// Deliver one token at global position `offset + seq`, exactly
    /// once: the next expected position appends and streams, an earlier
    /// position is a suppressed duplicate (re-prefilled prefix racing
    /// the dead shard's buffered tail), a later one is a gap — which
    /// the migration protocol makes impossible, so it is counted as an
    /// anomaly and gated to zero.
    fn deliver(&mut self, id: RequestId, token: i32, seq: usize, at: Instant) {
        let Some(t) = self.tracks.get_mut(&id) else { return };
        if t.done {
            return;
        }
        let pos = t.offset + seq;
        match pos.cmp(&t.delivered.len()) {
            Ordering::Equal => {
                if pos == 0 {
                    t.ttft_s = at.duration_since(t.arrival).as_secs_f64();
                    t.first_token_at = Some(at);
                } else if let Some(prev) = t.last_token_at {
                    self.gaps.push(at.duration_since(prev).as_secs_f64());
                }
                t.last_token_at = Some(at);
                t.delivered.push(token);
                self.tokens_streamed += 1;
            }
            Ordering::Less => self.recovery.dup_tokens += 1,
            Ordering::Greater => self.recovery.lost_tokens += 1,
        }
    }

    /// Consume a worker `Done`. Returns the completed response's
    /// latency (for the SLO gate), or None for an untracked or
    /// duplicate terminal. A migrated request's response is rebuilt
    /// from the track: full delivered stream, original prompt length,
    /// client-observed TTFT.
    fn complete(&mut self, r: Response) -> Option<f64> {
        let Some(t) = self.tracks.get_mut(&r.id) else {
            // untracked Done — keep the response rather than lose a
            // request, but nothing to rebuild from
            let lat = r.latency_s;
            self.responses.push(r);
            return Some(lat);
        };
        if t.done {
            return None;
        }
        t.done = true;
        let resp = if t.migrations == 0 {
            r
        } else {
            Response {
                id: r.id,
                tokens: t.delivered.clone(),
                prompt_len: t.prompt_len,
                priority: t.priority,
                latency_s: r.latency_s,
                ttft_s: t.ttft_s,
                queued_s: r.queued_s,
                first_token_at: t.first_token_at.unwrap_or(r.first_token_at),
                shard: r.shard,
            }
        };
        let lat = resp.latency_s;
        self.responses.push(resp);
        Some(lat)
    }

    /// Terminal shed: exactly one record per id, marking any track done
    /// so late worker events for it are dropped.
    fn shed(&mut self, id: RequestId, priority: Priority) {
        if let Some(t) = self.tracks.get_mut(&id) {
            t.done = true;
        }
        if self.shed_seen.insert(id) {
            self.shed_interactive += (priority == Priority::Interactive) as u64;
            self.shed_ids.push(id);
        }
    }

    /// Liveness sweep: kill every routable shard with runnable work
    /// that stayed silent past the death deadline; one missed deadline
    /// is only `Suspect` (stalls recover). Idle shards get their clock
    /// reset — silence without work is not a miss.
    fn check_liveness(
        &mut self,
        router: &mut Router,
        senders: &mut [Option<Sender<ToWorker>>],
        spec: &FaultSpec,
    ) {
        for shard in 0..senders.len() {
            if self.health[shard] == ShardHealth::Dead || senders[shard].is_none() {
                continue;
            }
            if !self.busy(shard) {
                self.health[shard] = ShardHealth::Healthy;
                self.last_event_at[shard] = Instant::now();
                continue;
            }
            let elapsed = self.last_event_at[shard].elapsed();
            if elapsed >= spec.death_deadline() {
                self.kill_shard(router, senders, spec, shard);
            } else if elapsed >= spec.step_deadline {
                self.health[shard] = ShardHealth::Suspect;
            } else {
                self.health[shard] = ShardHealth::Healthy;
            }
        }
    }

    /// Declare `first` dead and migrate everything it held. Worklist-
    /// driven: a migration target whose sender turns out dead (send
    /// fails) is marked dead in the router immediately — so rerouting
    /// can't pick it again — queued for its own kill pass, and the
    /// request retries against the remaining survivors. With no
    /// survivor left the request sheds terminally (capacity is gone;
    /// the charge was already refunded).
    fn kill_shard(
        &mut self,
        router: &mut Router,
        senders: &mut [Option<Sender<ToWorker>>],
        spec: &FaultSpec,
        first: usize,
    ) {
        let mut queue = vec![first];
        while let Some(dead) = queue.pop() {
            let newly = senders[dead].is_some() || router.is_alive(dead);
            router.mark_dead(dead);
            senders[dead] = None;
            if newly {
                self.health[dead] = ShardHealth::Dead;
                self.recovery.dead_shards.push(dead);
                let units = self.last_event_at[dead].elapsed().as_secs_f64()
                    / spec.step_deadline.as_secs_f64().max(1e-9);
                self.recovery.detection_deadlines.push(units);
            }
            let mut ids: Vec<RequestId> = self
                .tracks
                .iter()
                .filter(|(_, t)| !t.done && t.shard == dead)
                .map(|(id, _)| *id)
                .collect();
            ids.sort_unstable();
            for id in ids {
                queue.extend(self.reroute_reprefill(router, senders, id));
            }
        }
    }

    /// Re-inject one in-flight request as a re-prefill (admitted prompt
    /// plus the delivered prefix) on a live shard — the shared no-pages
    /// path behind dead-shard migration, corrupt page transfers, and
    /// decode-side import bounces. Refunds the request's current charge
    /// idempotently, synthesizes the response when the stream is
    /// already complete (or cannot extend within the context window),
    /// rebases the delivery offset on success, and counts the
    /// migration. Returns any shards discovered dead while probing
    /// targets (their sends failed) for the caller to run its own kill
    /// pass over.
    fn reroute_reprefill(
        &mut self,
        router: &mut Router,
        senders: &mut [Option<Sender<ToWorker>>],
        id: RequestId,
    ) -> Vec<usize> {
        let mut newly_dead = Vec::new();
        // idempotent refund of the current charge; a successful
        // reroute re-charges the survivor
        router.release(id);
        let Some(t) = self.tracks.get_mut(&id) else { return newly_dead };
        if t.done {
            return newly_dead;
        }
        let remaining = t.max_new.saturating_sub(t.delivered.len());
        let priority = t.priority;
        let low = t.low;
        let shard_now = t.shard;
        let mut prompt = t.prompt.clone();
        prompt.extend_from_slice(&t.delivered);
        if remaining == 0 || prompt.len() >= self.ctx {
            // stream already fully delivered (its Done is either
            // buffered — later deduped — or died unemitted), or the
            // prefix can't extend within the context window, matching
            // where the original would have KV-capped
            t.done = true;
            let resp = t.response(id, shard_now);
            self.responses.push(resp);
            return newly_dead;
        }
        let arrival = t.arrival;
        let mut req = Request::new(id, prompt, remaining);
        req.priority = priority;
        req.arrival = arrival;
        let mut routed = None;
        while let Some(d) = router.route_migrated(&req) {
            let live = senders[d.shard]
                .as_ref()
                .is_some_and(|tx| tx.send(ToWorker::Inject(req.clone(), low)).is_ok());
            if live {
                routed = Some(d.shard);
                break;
            }
            // target died undetected: refund, eject it from routing
            // now, report it for its own kill pass, retry
            router.release(id);
            router.mark_dead(d.shard);
            newly_dead.push(d.shard);
        }
        match routed {
            Some(target) => {
                if !self.busy(target) {
                    self.last_event_at[target] = Instant::now();
                }
                if let Some(t) = self.tracks.get_mut(&id) {
                    t.offset = t.delivered.len();
                    t.shard = target;
                    t.migrations += 1;
                }
                self.recovery.migrated_ids.push(id);
                self.recovery.reprefill_tokens += req.prompt.len() as u64;
            }
            None => self.shed(id, priority),
        }
        newly_dead
    }
}

/// Degrade-ladder watermarks, in decode-backlog tokens per fleet slot.
/// Above HI for [`DEGRADE_TICKS`] consecutive step-deadline ticks the
/// ladder degrades; below LO (at full fleet strength) for the same
/// count it restores. The band between them is the hysteresis that
/// keeps one pressure episode from oscillating the width.
const DEGRADE_HI_PER_SLOT: f64 = 8.0;
const DEGRADE_LO_PER_SLOT: f64 = 2.0;
/// Consecutive pressure ticks a watermark must hold before the ladder
/// moves (a death bypasses this and degrades immediately — capacity
/// loss is a fact, not a noisy signal).
const DEGRADE_TICKS: u32 = 3;

/// Re-role watermarks on the predicted prefill:decode backlog ratio,
/// normalized per role-capable alive shard (disaggregated fleets
/// only). Above [`ROLE_HI`] for [`ROLE_TICKS`] consecutive
/// step-deadline ticks, prefill work is drowning its shards: one
/// decode-role shard re-roles to prefill. Below [`ROLE_LO`] for the
/// same count, decode is the bottleneck: one prefill-role shard
/// re-roles to decode. The band between the marks is the hysteresis,
/// and — mirroring the degrade ladder — at most one shard moves per
/// pressure episode (the flag resets when the ratio re-enters the
/// band), so one imbalance burst cannot oscillate the fleet.
const ROLE_HI: f64 = 2.0;
const ROLE_LO: f64 = 0.5;
/// Consecutive off-band ticks before a re-role move.
const ROLE_TICKS: u32 = 3;

/// Sim-only replacement-worker factory: `(shard, incarnation)` -> a
/// fresh worker running that incarnation's slice of the fault plan
/// (`FaultPlan::shard_faults_incarnation`), so a flapping shard's next
/// scheduled crash arms on the replacement's own decode clock.
type RespawnFn = Box<dyn Fn(usize, usize) -> Worker + Send>;

/// Per-run elastic-recovery state: rejoin schedule, warm-spare pool,
/// probe-ramp clocks, and the degrade ladder.
struct Elastic {
    /// next incarnation per shard (the initial worker is incarnation 0)
    incarnations: Vec<usize>,
    /// pending scheduled replacements: `(shard, ready-at offset from
    /// serve start)` — a replacement rejoins at the later of its
    /// availability and the shard's death detection
    recoveries: Vec<(usize, Duration)>,
    standby_left: usize,
    /// prefix of `recovery.dead_shards` already offered a warm spare
    deaths_seen: usize,
    /// probe-ramp clock per shard: start of the current clean window
    probe_since: Vec<Option<Instant>>,
    /// admitted-counter snapshot at each promotion (fair-share basis)
    promote_snaps: Vec<(usize, Vec<u64>)>,
    rejoined: Vec<usize>,
    standby_promotions: u64,
    rebroadcast_bytes: u64,
    degraded: bool,
    hi_ticks: u32,
    lo_ticks: u32,
    degrade_enters: u64,
    degrade_exits: u64,
    last_pressure_tick: Instant,
    /// re-role hysteresis (disagg only): off-band tick counters, the
    /// one-move-per-episode latch, and the move count
    role_hi_ticks: u32,
    role_lo_ticks: u32,
    role_moved: bool,
    reroles: u64,
    last_role_tick: Instant,
}

impl Elastic {
    fn new(cfg: &ServerConfig, step_s: f64) -> Self {
        let mut recoveries: Vec<(usize, Duration)> = Vec::new();
        if let Some(plan) = &cfg.fault.plan {
            for r in &plan.recovers {
                if r.shard < cfg.shards {
                    recoveries
                        .push((r.shard, Duration::from_secs_f64(r.at_step as f64 * step_s)));
                }
            }
        }
        recoveries.sort_by_key(|&(_, at)| at);
        Elastic {
            incarnations: vec![1; cfg.shards],
            recoveries,
            standby_left: cfg.standby,
            deaths_seen: 0,
            probe_since: vec![None; cfg.shards],
            promote_snaps: Vec::new(),
            rejoined: Vec::new(),
            standby_promotions: 0,
            rebroadcast_bytes: 0,
            degraded: false,
            hi_ticks: 0,
            lo_ticks: 0,
            degrade_enters: 0,
            degrade_exits: 0,
            last_pressure_tick: Instant::now(),
            role_hi_ticks: 0,
            role_lo_ticks: 0,
            role_moved: false,
            reroles: 0,
            last_role_tick: Instant::now(),
        }
    }
}

/// Multi-shard server.
pub struct Server {
    cfg: ServerConfig,
    router: Router,
    batcher: Batcher,
    senders: Vec<Option<Sender<ToWorker>>>,
    events: Receiver<(usize, Result<ServeEvent>)>,
    /// dispatcher-held clone of the workers' event sender, kept only
    /// while rejoin is possible (a respawned worker needs a fresh
    /// clone); dropped otherwise so a fully-exited pool still reads as
    /// disconnected
    ev_tx: Option<Sender<(usize, Result<ServeEvent>)>>,
    handles: Vec<JoinHandle<WorkerStats>>,
    shard_weight_bytes: Vec<usize>,
    /// backend context length (migration headroom bound)
    ctx: usize,
    /// calibrated per-token cost model for the predictive gate:
    /// `start_sim` fits it from the sim cost knobs, the PJRT path loads
    /// the measured `BENCH_hotpath.json` profile
    estimator: Option<CostEstimator>,
    /// sim-only factory for rejoin/standby replacement workers (None on
    /// the PJRT path: compiled shards don't respawn)
    respawn: Option<RespawnFn>,
}

impl Server {
    /// Spin up a PJRT-backed worker pool (compiles executables on first
    /// use; requires `--features xla` + artifacts). Predictive admission
    /// additionally needs a measured cost profile: `LLEQ_HOTPATH_PROFILE`
    /// if set, else `BENCH_hotpath.json` in the working directory or at
    /// the repo root (where `cargo bench --bench perf_hotpath --features
    /// xla` writes it). The profile is resolved *before* any executable
    /// compiles, so a missing file fails fast instead of after minutes
    /// of compilation.
    pub fn start(registry: &Arc<Registry>, cfg: ServerConfig) -> Result<Self> {
        if cfg.spec_k > 0 {
            bail!(
                "speculative decoding requires the sim backend: PJRT graphs \
                 compile at a fixed width and have no low-bit draft variant to \
                 run (mirroring degrade_bits, use Server::start_sim)"
            );
        }
        let estimator = match cfg.admission {
            AdmissionPolicy::Predictive { .. } => Some(Self::hotpath_estimator(cfg.batch)?),
            _ => None,
        };
        let mut backends = Vec::with_capacity(cfg.shards);
        for _ in 0..cfg.shards {
            let handle = registry.model_handle(&cfg.model, cfg.variant, cfg.batch)?;
            backends.push(Backend::Pjrt(handle));
        }
        let mut server = Self::start_with(cfg, backends)?;
        server.estimator = estimator;
        Ok(server)
    }

    /// Resolve the measured hotpath profile for the PJRT predictive
    /// gate: the env override wins; otherwise probe the working
    /// directory and the repo root (`perf_hotpath` writes to the root,
    /// one level above the crate, so a `cargo run` from `rust/` still
    /// finds it).
    fn hotpath_estimator(batch: usize) -> Result<CostEstimator> {
        let path = match std::env::var("LLEQ_HOTPATH_PROFILE") {
            Ok(p) => PathBuf::from(p),
            Err(_) => {
                let cwd = PathBuf::from("BENCH_hotpath.json");
                let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
                    .join("..")
                    .join("BENCH_hotpath.json");
                if cwd.exists() {
                    cwd
                } else {
                    root
                }
            }
        };
        CostEstimator::from_hotpath_profile(&path, batch).map_err(|e| {
            anyhow!(
                "predictive admission on the PJRT backend needs a measured cost \
                 profile: {e}; run `cargo bench --bench perf_hotpath --features xla` \
                 (writes BENCH_hotpath.json at the repo root) or point \
                 LLEQ_HOTPATH_PROFILE at a profile JSON"
            )
        })
    }

    /// Spin up simulated worker shards (offline: scheduler tests and the
    /// batching ablation). `cfg.model` is ignored; the sim graphs are
    /// gpt2-tiny-shaped with the given wall-clock cost model, and the
    /// predictive gate's estimator is fitted from the same cost knobs.
    /// A configured `cfg.fault` plan compiles into per-shard
    /// [`crate::runtime::ShardFaults`] executed inside each sim backend
    /// — the "device" crashes or stalls; the dispatcher has to notice
    /// from the outside. (The PJRT path ignores the plan: real devices
    /// supply their own faults.)
    pub fn start_sim(cfg: ServerConfig, cost: SimCost) -> Result<Self> {
        let batch = cfg.batch;
        let backends = (0..cfg.shards)
            .map(|shard| {
                let mut m = SimModel::tiny(cfg.variant, cfg.batch, cost);
                if let Some(plan) = &cfg.fault.plan {
                    m = m.with_faults(plan.shard_faults(shard));
                }
                Backend::Sim(m)
            })
            .collect();
        let respawn_cfg = cfg.clone();
        let mut server = Self::start_with(cfg, backends)?;
        // speculative serving changes the effective decode rate; price
        // admission at the expected draft/verify cycle yield so the
        // predictive gate stays honest (identity when spec_k == 0)
        server.estimator = Some(
            CostEstimator::from_sim_cost(&cost, batch)
                .speculative(respawn_cfg.spec_k, respawn_cfg.spec_draft_bits),
        );
        // replacement workers for rejoin/standby: incarnation k of a
        // shard runs the k-th slice of its fault schedule on a fresh
        // device clock (its ScaleSync starts fresh, exactly like every
        // shard's did at t=0 — the serve path runs per-shard trackers
        // with sync disarmed; when periodic sync is armed, a rejoiner
        // adopts a survivor's merged snapshot via
        // `ScaleSync::adopt_states` instead of waiting out a period)
        server.respawn = Some(Box::new(move |shard, incarnation| {
            let mut m = SimModel::tiny(respawn_cfg.variant, respawn_cfg.batch, cost);
            if let Some(plan) = &respawn_cfg.fault.plan {
                m = m.with_faults(plan.shard_faults_incarnation(shard, incarnation));
            }
            Worker::new_spec(
                shard,
                Backend::Sim(m),
                respawn_cfg.prefill_chunk,
                respawn_cfg.kv_blocks,
                respawn_cfg.prefix_cache,
                respawn_cfg.spec_k,
                respawn_cfg.spec_draft_bits,
            )
        }));
        Ok(server)
    }

    fn start_with(cfg: ServerConfig, backends: Vec<Backend>) -> Result<Self> {
        if backends.len() != cfg.shards || cfg.shards == 0 {
            bail!("need one backend per shard (got {})", backends.len());
        }
        let ctx = backends[0].cfg().ctx;
        let mut router = Router::new(cfg.shards, ctx - 8);
        // admission is a block-budget question now: charge routing in
        // the same block unit the shard allocators hand out
        router.set_block_budget(DEFAULT_BLOCK_SIZE.min(ctx).max(1));
        let batcher = Batcher::new(cfg.policy);
        // pool-aware batch shaping: size the shared kernel pool from the
        // total slot count so per-shard fan-outs don't convoy
        pool::reserve(cfg.shards * cfg.batch);

        let (ev_tx, ev_rx) = channel();
        let mut senders = Vec::with_capacity(cfg.shards);
        let mut handles = Vec::with_capacity(cfg.shards);
        let mut shard_weight_bytes = Vec::with_capacity(cfg.shards);
        for (shard, backend) in backends.into_iter().enumerate() {
            shard_weight_bytes.push(backend.weight_storage_bytes());
            let (tx, rx): (Sender<ToWorker>, Receiver<ToWorker>) = channel();
            senders.push(Some(tx));
            let ev_tx = ev_tx.clone();
            let worker = Worker::new_spec(
                shard,
                backend,
                cfg.prefill_chunk,
                cfg.kv_blocks,
                cfg.prefix_cache,
                cfg.spec_k,
                cfg.spec_draft_bits,
            );
            handles.push(std::thread::spawn(move || worker_loop(worker, rx, ev_tx)));
        }
        Ok(Server {
            cfg,
            router,
            batcher,
            senders,
            events: ev_rx,
            ev_tx: Some(ev_tx),
            handles,
            shard_weight_bytes,
            ctx,
            estimator: None,
            respawn: None,
        })
    }

    /// Closed-loop firehose: every request arrives at t=0. Runs the
    /// workload to completion and shuts the pool down.
    pub fn run_workload(self, requests: Vec<Request>) -> Result<ServerReport> {
        let arrivals = requests
            .into_iter()
            .map(|request| Arrival { at_s: 0.0, request })
            .collect();
        self.run_arrivals(arrivals)
    }

    /// Open-loop replay: each request is injected at its `Arrival.at_s`
    /// offset from workload start, independent of service progress — the
    /// arrival pressure under which TTFT/p99 are meaningful.
    pub fn run_open_loop(self, arrivals: Vec<Arrival>) -> Result<ServerReport> {
        self.run_arrivals(arrivals)
    }

    fn run_arrivals(mut self, mut arrivals: Vec<Arrival>) -> Result<ServerReport> {
        if matches!(self.cfg.admission, AdmissionPolicy::Predictive { .. })
            && self.estimator.is_none()
        {
            bail!(
                "predictive admission needs a calibrated cost estimator \
                 (Server::start_sim fits one from SimCost; the PJRT path loads \
                 BENCH_hotpath.json / LLEQ_HOTPATH_PROFILE)"
            );
        }
        if self.cfg.fault.plan.is_some() && self.cfg.mode != SchedulerMode::Continuous {
            bail!(
                "fault plans require SchedulerMode::Continuous — a static batch \
                 runs to completion inside its worker and cannot migrate"
            );
        }
        if self.cfg.disagg && self.cfg.mode != SchedulerMode::Continuous {
            bail!(
                "disaggregated serving requires SchedulerMode::Continuous — \
                 handoff migrates lanes between step boundaries, which a \
                 run-to-completion static batch never reaches"
            );
        }
        // disaggregated split: first ceil(n/2) shards take the prefill
        // role, the rest decode; a single shard stays Mixed (there is
        // nothing to hand off to)
        let disagg = self.cfg.disagg && self.cfg.shards > 1;
        if disagg {
            let prefill_n = self.cfg.shards.div_ceil(2);
            for shard in 0..self.cfg.shards {
                let role =
                    if shard < prefill_n { ShardRole::Prefill } else { ShardRole::Decode };
                self.router.set_role(shard, role);
                if let Some(tx) = self.senders[shard].as_ref() {
                    let _ = tx.send(ToWorker::SetRole(role == ShardRole::Prefill));
                }
            }
        }
        // liveness deadlines are wall-clock; arm them only when a plan
        // is configured so a loaded CI runner can't false-kill a shard
        let liveness = self.cfg.fault.active() && self.cfg.mode == SchedulerMode::Continuous;
        // elastic recovery: the dispatcher's decode-step clock converts
        // plan steps (`recover:<shard>@<step>`) into serve-time offsets
        let step_s = self.estimator.as_ref().map(|e| e.step_s()).unwrap_or(0.0);
        let mut elastic = Elastic::new(&self.cfg, step_s);
        let elastic_armed = self.cfg.mode == SchedulerMode::Continuous
            && (liveness || self.cfg.degrade_bits.is_some() || self.cfg.standby > 0 || disagg);
        // rejoin needs a fresh event-sender clone for the replacement
        // worker; keep ours only when one can actually spawn, so a
        // fully-exited pool still reads as disconnected otherwise
        let rejoin_possible = liveness
            && self.respawn.is_some()
            && (!elastic.recoveries.is_empty() || self.cfg.standby > 0);
        if !rejoin_possible {
            self.ev_tx = None;
            elastic.recoveries.clear();
            elastic.standby_left = 0;
        }
        arrivals.sort_by(|a, b| a.at_s.total_cmp(&b.at_s));
        let total = arrivals.len();
        let mut pending: VecDeque<Arrival> = arrivals.into();
        let t0 = Instant::now();

        let mut flight = Flight::new(self.cfg.shards, self.ctx);
        let mut shard_tokens = vec![0u64; self.cfg.shards];
        let mut shard_rr = 0usize;
        let block_size = DEFAULT_BLOCK_SIZE.min(self.ctx).max(1);
        let pool_blocks =
            self.cfg.kv_blocks.unwrap_or(self.cfg.batch * self.ctx.div_ceil(block_size));
        let mut gate = SloGate::new(
            self.cfg.admission,
            self.cfg.shards,
            self.cfg.mode == SchedulerMode::Static,
            self.estimator.take(),
            self.cfg.prefill_chunk,
            block_size,
            pool_blocks,
        );
        let mut deprioritized = 0u64;

        // disaggregated page-migration wire: an NVLink-class point-to-
        // point link for handed-off KV blocks, with the fault plan's
        // corruption probability (when armed) drawn at a rank of its
        // own past the ring transport's
        let wire = LinkModel::nvlink();
        let mut wire_comm = CommStats::default();
        let mut wire_faults: Option<LinkFaults> = self
            .cfg
            .fault
            .plan
            .as_ref()
            .filter(|p| p.corrupt_p > 0.0)
            .map(|p| p.link_faults(self.cfg.shards));
        let mut kv_migrate_bytes = 0u64;

        while flight.undone() < total {
            // 1) inject every due arrival, gating each on its routed
            // shard's SLO window
            let now_s = t0.elapsed().as_secs_f64();
            while pending.front().is_some_and(|a| a.at_s <= now_s) {
                let Some(mut a) = pending.pop_front() else { break };
                // the request enters the system *now*; TTFT/latency
                // measure queueing from this instant
                a.request.arrival = Instant::now();
                // a dead fleet can't serve: terminal shed, no charge
                if liveness && self.router.alive_count() == 0 {
                    flight.shed(a.request.id, a.request.priority);
                    continue;
                }
                let (req, decision) = self.router.admit(a.request);
                // one mode match feeds the gate both of its signals:
                // `established` (other in-flight work beyond this
                // request's own charge — the idle-probe condition) and
                // the token backlog the predictive gate prices,
                // excluding the candidate's own freshly-routed charge.
                // Static serves round-robin from one global queue, so
                // its probe is system-wide (matching the gate's global
                // window) and its backlog is the per-shard share of the
                // global total.
                let (established, backlog, block_demand) = match self.cfg.mode {
                    SchedulerMode::Continuous => {
                        let (p, d) = self.router.backlog(decision.shard);
                        (
                            self.router.load()[decision.shard] > decision.cost,
                            (
                                p.saturating_sub(req.prompt.len()),
                                d.saturating_sub(req.max_new_tokens),
                            ),
                            // includes the candidate's freshly-routed
                            // block charge — demand past the pool is
                            // what must drain first
                            self.router.block_backlog(decision.shard),
                        )
                    }
                    SchedulerMode::Static => {
                        let (p, d) = self.router.backlog_total();
                        (
                            self.router.load().iter().sum::<usize>() > decision.cost,
                            (
                                p.saturating_sub(req.prompt.len()) / self.cfg.shards,
                                d.saturating_sub(req.max_new_tokens) / self.cfg.shards,
                            ),
                            // static batches run to completion on one
                            // shard; block pressure resolves inside the
                            // worker, so the gate's block term is inert
                            0,
                        )
                    }
                };
                let verdict =
                    gate.decide(decision.shard, established, &req, backlog, block_demand);
                if let Gate::Shed = verdict {
                    // terminal: refund the router charge, record exactly
                    // one Shed event, never dispatch
                    self.router.release(req.id);
                    flight.shed(req.id, req.priority);
                    continue;
                }
                let low = matches!(verdict, Gate::Low);
                deprioritized += low as u64;
                // raw completion prediction, regressed against the
                // observed latency at Done (online calibration)
                let predicted_ms = gate.predict_raw(backlog, &req, block_demand).unwrap_or(0.0);
                match self.cfg.mode {
                    SchedulerMode::Continuous => {
                        // tracked *before* the send so a failed send can
                        // migrate this request along with the rest of
                        // the shard's in-flight work
                        flight.insert(&req, decision.shard, low, predicted_ms);
                        let sent = self.senders[decision.shard]
                            .as_ref()
                            .is_some_and(|tx| tx.send(ToWorker::Inject(req, low)).is_ok());
                        if !sent {
                            if liveness {
                                // hard evidence of death: the worker
                                // hung up before the deadline noticed
                                flight.kill_shard(
                                    &mut self.router,
                                    &mut self.senders,
                                    &self.cfg.fault,
                                    decision.shard,
                                );
                            } else {
                                bail!("worker {} is gone", decision.shard);
                            }
                        }
                    }
                    SchedulerMode::Static => {
                        flight.insert(&req, decision.shard, low, predicted_ms);
                        if low {
                            self.batcher.push_low(req);
                        } else {
                            self.batcher.push(req);
                        }
                    }
                }
            }
            // 2) static mode: release every batch the policy allows; once
            // the arrival stream is exhausted, flush the tail immediately
            // instead of sleeping out the deadline (and skip entirely
            // when the queue is empty)
            if self.cfg.mode == SchedulerMode::Static {
                while let Some(batch) = self.batcher.take(Instant::now()) {
                    self.dispatch_static(batch, &mut shard_rr)?;
                }
                if pending.is_empty() && self.batcher.pending() > 0 {
                    for batch in self.batcher.flush() {
                        self.dispatch_static(batch, &mut shard_rr)?;
                    }
                }
            }
            // 3) nothing left to inject: close the injection side so
            // idle workers can exit as soon as they drain. With fault
            // handling armed the senders stay open — a kill after the
            // last arrival still needs live mailboxes to migrate into —
            // and so does the degrade ladder: a width move after the
            // last arrival still needs a mailbox to send SetKvBits into.
            if !liveness && !elastic_armed && pending.is_empty() && self.batcher.pending() == 0 {
                for s in &mut self.senders {
                    *s = None;
                }
            }
            // 4) wait for the next event, the next arrival, or (static)
            // the next batch deadline — whichever is first; armed
            // liveness caps the wait at the step deadline so a silent
            // shard is noticed on schedule
            let mut timeout = Duration::from_secs(600);
            if let Some(a) = pending.front() {
                let dt = Duration::from_secs_f64((a.at_s - t0.elapsed().as_secs_f64()).max(0.0));
                timeout = timeout.min(dt);
            }
            if let Some(deadline) = self.batcher.next_deadline() {
                timeout = timeout.min(deadline.saturating_duration_since(Instant::now()));
            }
            if liveness {
                timeout = timeout.min(self.cfg.fault.step_deadline);
            } else if elastic_armed {
                // degrade ticks piggyback on the event stream (pressure
                // implies in-flight work implies events every step);
                // this cap only bounds stall detection while the
                // senders are held open for SetKvBits
                timeout = timeout.min(Duration::from_secs(1));
            }
            match self.events.recv_timeout(timeout) {
                Ok((shard, Ok(ev))) => {
                    // any event is that shard's liveness beat
                    if let Some(beat) = flight.last_event_at.get_mut(shard) {
                        *beat = Instant::now();
                    }
                    match ev {
                        ServeEvent::Token { id, token, seq, at, .. } => {
                            flight.deliver(id, token, seq, at);
                        }
                        ServeEvent::Done(r) => {
                            self.router.complete(r.id);
                            let rid = r.id;
                            let n_tokens = r.tokens.len() as u64;
                            // None = duplicate Done from a stream that
                            // already terminated (migration race); the
                            // first terminal won, drop this one
                            if let Some(latency_s) = flight.complete(r) {
                                shard_tokens[shard] += n_tokens;
                                gate.observe(shard, latency_s);
                                // feed the online estimator regression
                                // its predicted-vs-actual sample
                                let predicted_ms = flight
                                    .tracks
                                    .get(&rid)
                                    .map(|t| t.predicted_ms)
                                    .unwrap_or(0.0);
                                if predicted_ms > 0.0 {
                                    gate.cal.observe(predicted_ms / 1e3, latency_s);
                                }
                            }
                        }
                        // workers never shed; defensive accounting if
                        // one ever forwards a gate decision: refund the
                        // router charge (idempotent), count the terminal
                        // event exactly once, and attribute it to the
                        // request's priority class
                        ServeEvent::Shed { id, .. } => {
                            self.router.release(id);
                            let priority = flight
                                .tracks
                                .get(&id)
                                .map(|t| t.priority)
                                .unwrap_or(Priority::Batch);
                            flight.shed(id, priority);
                        }
                        // a prefill-role (or rebalance-donor) worker
                        // released a finished lane: ship its KV pages
                        // to a decode-capable shard over the quantized
                        // wire; any failure — no live target, a wire
                        // eject, a dead mailbox — falls back to the
                        // re-prefill path
                        ServeEvent::Handoff {
                            shard: src,
                            req,
                            generated,
                            ttft_s,
                            queued_s,
                            first_token_at,
                            pages,
                        } => {
                            let id = req.id;
                            // the source lane is gone; refund its
                            // charge before re-routing (idempotent)
                            self.router.release(id);
                            // price the continuation like a migrated
                            // stream: delivered prefix folded into the
                            // prompt, remaining budget as decode
                            let plan = flight.tracks.get(&id).filter(|t| !t.done).map(|t| {
                                let mut p = t.prompt.clone();
                                p.extend_from_slice(&t.delivered);
                                let rem = t.max_new.saturating_sub(t.delivered.len());
                                (p, rem, t.priority, t.arrival)
                            });
                            let mut fallback = false;
                            if let Some((pprompt, remaining, priority, arrival)) = plan {
                                let target = if remaining == 0 {
                                    // fully delivered: the fallback
                                    // synthesizes the response
                                    None
                                } else {
                                    let mut pricing = Request::new(id, pprompt, remaining);
                                    pricing.priority = priority;
                                    pricing.arrival = arrival;
                                    self.router.route_handoff(&pricing)
                                };
                                match target {
                                    Some(d) => {
                                        let transferred = {
                                            let (codes, params) = pages.wire_segments();
                                            transfer_quant_pages(
                                                &wire,
                                                src,
                                                wire_faults.as_mut(),
                                                &mut wire_comm,
                                                pages.code_bits(),
                                                &codes,
                                                &params,
                                            )
                                        };
                                        match transferred {
                                            Ok(bytes) => {
                                                kv_migrate_bytes += bytes;
                                                let msg = ToWorker::ImportPages {
                                                    req,
                                                    generated,
                                                    pages,
                                                    ttft_s,
                                                    queued_s,
                                                    first_token_at,
                                                };
                                                let sent = self.senders[d.shard]
                                                    .as_ref()
                                                    .is_some_and(|tx| tx.send(msg).is_ok());
                                                if sent {
                                                    if !flight.busy(d.shard) {
                                                        flight.last_event_at[d.shard] =
                                                            Instant::now();
                                                    }
                                                    // no offset rebase: the
                                                    // importer continues the
                                                    // same seq stream
                                                    if let Some(t) =
                                                        flight.tracks.get_mut(&id)
                                                    {
                                                        t.shard = d.shard;
                                                    }
                                                } else {
                                                    self.router.release(id);
                                                    fallback = true;
                                                }
                                            }
                                            Err(_) => {
                                                // the wire ejected after
                                                // retries: pages never landed
                                                self.router.release(id);
                                                fallback = true;
                                            }
                                        }
                                    }
                                    None => fallback = true,
                                }
                            }
                            if fallback {
                                for s in flight.reroute_reprefill(
                                    &mut self.router,
                                    &mut self.senders,
                                    id,
                                ) {
                                    flight.kill_shard(
                                        &mut self.router,
                                        &mut self.senders,
                                        &self.cfg.fault,
                                        s,
                                    );
                                }
                            }
                        }
                        // the decode target could not hold the migrated
                        // residency: fall back to re-prefill on a live
                        // shard (the no-pages path)
                        ServeEvent::ImportBounced { req } => {
                            for s in flight.reroute_reprefill(
                                &mut self.router,
                                &mut self.senders,
                                req.id,
                            ) {
                                flight.kill_shard(
                                    &mut self.router,
                                    &mut self.senders,
                                    &self.cfg.fault,
                                    s,
                                );
                            }
                        }
                    }
                }
                Ok((shard, Err(e))) => {
                    if liveness {
                        // a surfaced worker error is contained: record
                        // it, declare the shard dead, migrate its work
                        flight.recovery.worker_errors.push(format!("shard {shard}: {e:#}"));
                        flight.kill_shard(
                            &mut self.router,
                            &mut self.senders,
                            &self.cfg.fault,
                            shard,
                        );
                    } else {
                        return Err(e);
                    }
                }
                Err(RecvTimeoutError::Timeout) => {
                    // armed liveness turns silence into detection (the
                    // sweep below); disarmed, a silent drained pool is
                    // a stall
                    if !liveness && pending.is_empty() && self.batcher.pending() == 0 {
                        bail!(
                            "worker pool stalled ({}/{} served)",
                            flight.responses.len(),
                            total
                        );
                    }
                }
                Err(RecvTimeoutError::Disconnected) => {
                    bail!("workers exited with {}/{} served", flight.responses.len(), total)
                }
            }
            if liveness {
                flight.check_liveness(&mut self.router, &mut self.senders, &self.cfg.fault);
            }
            if elastic_armed {
                self.recovery_tick(&mut flight, &mut elastic, &mut gate, t0);
            }
        }

        // every Token of a completed request precedes its Done in its
        // sender's FIFO, so the stragglers are already buffered; run
        // them through the same position dedup (a migrated stream's
        // buffered tail must not double-count)
        while let Ok((_, ev)) = self.events.try_recv() {
            if let Ok(ServeEvent::Token { id, token, seq, at, .. }) = ev {
                flight.deliver(id, token, seq, at);
            }
        }

        // shut down workers, merge metrics
        for s in &mut self.senders {
            *s = None;
        }
        let mut breakdown = Breakdown::new();
        let (mut steps, mut tokens, mut joins, mut retires) = (0u64, 0u64, 0u64, 0u64);
        let (mut prefix_hits, mut preemptions, mut resume_reprefill) = (0u64, 0u64, 0u64);
        let (mut drafted, mut accepted) = (0u64, 0u64);
        let mut handoffs = 0u64;
        let (mut prefill_busy, mut decode_busy) = (0.0f64, 0.0f64);
        let mut peak_active = Vec::with_capacity(self.handles.len());
        for h in self.handles {
            let st = h.join().map_err(|_| anyhow!("worker panicked"))?;
            breakdown.merge(&st.breakdown);
            steps += st.steps;
            tokens += st.tokens_out;
            joins += st.joins;
            retires += st.retires;
            prefix_hits += st.prefix_hit_tokens;
            preemptions += st.preemptions;
            resume_reprefill += st.resume_reprefill_tokens;
            drafted += st.drafted_tokens;
            accepted += st.accepted_tokens;
            handoffs += st.handoffs;
            prefill_busy += st.prefill_busy_s;
            decode_busy += st.decode_busy_s;
            peak_active.push(st.peak_active);
        }
        let busy = prefill_busy + decode_busy;
        let (prefill_busy_share, decode_busy_share) =
            if busy > 0.0 { (prefill_busy / busy, decode_busy / busy) } else { (0.0, 0.0) };
        // comm/sync stages are exercised by the cluster-sim path; on the
        // serve path they only appear if scale sync ran
        breakdown.add(Stage::Sync, 0.0);
        let weight_storage_bytes = self.shard_weight_bytes.iter().sum();
        // fair-share audit for each promoted rejoin: its admissions
        // since promotion vs a 1/alive split of the fleet's
        let final_admitted = self.router.admitted().to_vec();
        let alive = self.router.alive_count().max(1);
        let rejoin_admit_share: Vec<f64> = elastic
            .promote_snaps
            .iter()
            .map(|(shard, snap)| {
                let mine = final_admitted[*shard].saturating_sub(snap[*shard]);
                let fleet: u64 = final_admitted
                    .iter()
                    .zip(snap)
                    .map(|(a, s)| a.saturating_sub(*s))
                    .sum();
                if fleet == 0 {
                    1.0
                } else {
                    mine as f64 * alive as f64 / fleet as f64
                }
            })
            .collect();
        Ok(ServerReport {
            responses: flight.responses,
            wall_s: t0.elapsed().as_secs_f64(),
            tokens_out: tokens,
            tokens_streamed: flight.tokens_streamed,
            decode_steps: steps,
            breakdown,
            weight_storage_bytes,
            shard_weight_bytes: self.shard_weight_bytes,
            shard_tokens,
            joins,
            retires,
            peak_active,
            shed_ids: flight.shed_ids,
            shed_interactive: flight.shed_interactive,
            deprioritized,
            inter_token_gap_s: flight.gaps,
            router_in_flight: self.router.in_flight(),
            router_inflight_tokens: self.router.load().iter().sum(),
            migrated_ids: flight.recovery.migrated_ids,
            reprefill_tokens: flight.recovery.reprefill_tokens,
            dup_tokens: flight.recovery.dup_tokens,
            lost_tokens: flight.recovery.lost_tokens,
            dead_shards: flight.recovery.dead_shards,
            shard_health: flight.health,
            detection_deadlines: flight.recovery.detection_deadlines,
            worker_errors: flight.recovery.worker_errors,
            rejoined: elastic.rejoined,
            standby_promotions: elastic.standby_promotions,
            degrade_enters: elastic.degrade_enters,
            degrade_exits: elastic.degrade_exits,
            rebroadcast_bytes: elastic.rebroadcast_bytes,
            rejoin_admit_share,
            prefix_hit_tokens: prefix_hits,
            preemptions,
            resume_reprefill_tokens: resume_reprefill,
            drafted_tokens: drafted,
            accepted_tokens: accepted,
            handoffs,
            kv_migrate_bytes,
            reroles: elastic.reroles,
            prefill_busy_share,
            decode_busy_share,
            estimator_abs_err: gate.cal.mean_abs_err(),
        })
    }

    /// Bring a replacement online for a Dead `shard`: spawn the next
    /// incarnation's worker (sim only), account the quantized weight
    /// re-broadcast that re-shards its partition over the survivor
    /// ring, reopen the shard's mailbox, and re-enter routing behind
    /// the probe ramp. Idempotent: a shard that is not Dead (double
    /// `recover:`, a spare already promoted) is a no-op returning
    /// false, as is any rejoin without a respawn factory (PJRT).
    fn rejoin(&mut self, flight: &mut Flight, el: &mut Elastic, shard: usize) -> bool {
        if flight.health[shard] != ShardHealth::Dead {
            return false;
        }
        let (Some(factory), Some(ev_tx)) = (self.respawn.as_ref(), self.ev_tx.clone()) else {
            return false;
        };
        let worker = factory(shard, el.incarnations[shard]);
        el.incarnations[shard] += 1;
        // a rejoiner enters at the fleet's current width
        if el.degraded {
            if let Some(bits) = self.cfg.degrade_bits {
                worker.set_kv_bits(bits);
            }
        }
        // weight re-shard over the survivor ring rides the quantized
        // wire (`collective::broadcast_quant`): 8-bit codes, one byte
        // per parameter of the shard's replica
        let params = match self.cfg.variant {
            Variant::Fp => self.shard_weight_bytes[shard] / 4,
            _ => self.shard_weight_bytes[shard],
        };
        el.rebroadcast_bytes += params as u64;
        let (tx, rx) = channel();
        self.senders[shard] = Some(tx);
        self.handles.push(std::thread::spawn(move || worker_loop(worker, rx, ev_tx)));
        flight.health[shard] = ShardHealth::Healthy;
        flight.last_event_at[shard] = Instant::now();
        self.router.revive(shard);
        el.probe_since[shard] = Some(Instant::now());
        el.rejoined.push(shard);
        // disaggregated fleets seed a decode-capable rejoiner over the
        // page wire: the most-loaded decode-capable survivor exports
        // its youngest decoding lane, and `route_handoff`'s idle-prober
        // priority lands the pages right here — recovery costs one page
        // transfer instead of a re-prefill. Mixed fleets keep the
        // arrival-driven probe ramp (pinned pre-disagg behavior).
        if self.cfg.disagg
            && self.cfg.shards > 1
            && self.router.role_of(shard).runs_decode()
        {
            let donor = (0..self.cfg.shards)
                .filter(|&s| {
                    s != shard
                        && self.router.is_alive(s)
                        && self.router.role_of(s).runs_decode()
                        && self.senders[s].is_some()
                        && self.router.load()[s] > 0
                })
                .max_by_key(|&s| self.router.load()[s]);
            if let Some(d) = donor {
                if let Some(tx) = self.senders[d].as_ref() {
                    let _ = tx.send(ToWorker::ExportLane);
                }
            }
        }
        true
    }

    /// One elastic pass, run at every event-loop turn while armed:
    /// consume warm spares for newly detected deaths, fire scheduled
    /// `recover:` replacements that are both ready and needed, move the
    /// degrade ladder, and promote probing shards that survived their
    /// ramp window.
    fn recovery_tick(
        &mut self,
        flight: &mut Flight,
        el: &mut Elastic,
        gate: &mut SloGate,
        t0: Instant,
    ) {
        // warm standby: at most one spare per detected death, promoted
        // immediately (no schedule to wait out)
        while el.deaths_seen < flight.recovery.dead_shards.len() {
            let dead = flight.recovery.dead_shards[el.deaths_seen];
            el.deaths_seen += 1;
            if el.standby_left > 0 && self.rejoin(flight, el, dead) {
                el.standby_left -= 1;
                el.standby_promotions += 1;
            }
        }
        // scheduled replacements fire at the later of availability and
        // death detection; a `recover:` for an alive shard stays
        // pending — a no-op unless/until the shard dies again, which is
        // exactly the flapping semantics
        let mut i = 0;
        while i < el.recoveries.len() {
            let (shard, at) = el.recoveries[i];
            if t0.elapsed() >= at
                && flight.health[shard] == ShardHealth::Dead
                && self.rejoin(flight, el, shard)
            {
                el.recoveries.remove(i);
            } else {
                i += 1;
            }
        }
        // degrade ladder: a death degrades immediately (capacity loss
        // is a fact); backlog pressure needs DEGRADE_TICKS consecutive
        // step-deadline ticks over the high watermark. Restore needs
        // the fleet whole again AND the same tick count under the low
        // watermark — the band between the marks is the hysteresis.
        if let Some(bits) = self.cfg.degrade_bits {
            let alive = self.router.alive_count().max(1);
            let fleet_shrunk = alive < self.cfg.shards;
            let tick = el.last_pressure_tick.elapsed() >= self.cfg.fault.step_deadline;
            if tick {
                el.last_pressure_tick = Instant::now();
            }
            let pressure = || {
                let (_, bd) = self.router.backlog_total();
                bd as f64 / (alive * self.cfg.batch) as f64
            };
            if !el.degraded {
                let mut enter = fleet_shrunk;
                if !enter && tick {
                    if pressure() >= DEGRADE_HI_PER_SLOT {
                        el.hi_ticks += 1;
                    } else {
                        el.hi_ticks = 0;
                    }
                    enter = el.hi_ticks >= DEGRADE_TICKS;
                }
                if enter {
                    el.degraded = true;
                    el.degrade_enters += 1;
                    el.hi_ticks = 0;
                    el.lo_ticks = 0;
                    self.set_fleet_kv_bits(bits);
                    gate.reprice(bits);
                }
            } else if tick {
                if !fleet_shrunk && pressure() <= DEGRADE_LO_PER_SLOT {
                    el.lo_ticks += 1;
                } else {
                    el.lo_ticks = 0;
                }
                if el.lo_ticks >= DEGRADE_TICKS {
                    el.degraded = false;
                    el.degrade_exits += 1;
                    el.lo_ticks = 0;
                    self.set_fleet_kv_bits(8);
                    gate.reprice(8);
                }
            }
        }
        // elastic re-roling (disaggregated fleets): compare the
        // predicted drain of the fleet's prefill backlog per admitting
        // shard against its decode backlog per decode-capable shard;
        // sustained drift past the ROLE_HI/ROLE_LO band re-roles the
        // least-loaded shard of the over-provisioned role — at most
        // one move per pressure episode, mirroring the degrade ladder
        if self.cfg.disagg && self.cfg.shards > 1 {
            let tick = el.last_role_tick.elapsed() >= self.cfg.fault.step_deadline;
            if tick {
                el.last_role_tick = Instant::now();
                let (p_tok, d_tok) = self.router.backlog_total();
                if p_tok + d_tok == 0 {
                    // idle fleet: no signal, and any episode is over
                    el.role_hi_ticks = 0;
                    el.role_lo_ticks = 0;
                    el.role_moved = false;
                } else {
                    let alive_with = |ok: fn(ShardRole) -> bool| {
                        (0..self.cfg.shards)
                            .filter(|&s| self.router.is_alive(s) && ok(self.router.role_of(s)))
                            .count()
                    };
                    let n_pre = alive_with(ShardRole::admits_arrivals);
                    let n_dec = alive_with(ShardRole::runs_decode);
                    // predicted drain times when an estimator is fitted
                    // (the sim path always has one), raw token backlogs
                    // otherwise — the ratio is what matters
                    let (p_cost, d_cost) = match gate.estimator.as_ref() {
                        Some(est) => (
                            est.predict_ms((p_tok, 0), 0, 0, self.cfg.prefill_chunk),
                            est.predict_ms((0, d_tok), 0, 0, self.cfg.prefill_chunk),
                        ),
                        None => (p_tok as f64, d_tok as f64),
                    };
                    let ratio = (p_cost / n_pre.max(1) as f64)
                        / (d_cost / n_dec.max(1) as f64).max(1e-9);
                    if ratio >= ROLE_HI {
                        el.role_hi_ticks += 1;
                        el.role_lo_ticks = 0;
                    } else if ratio <= ROLE_LO {
                        el.role_lo_ticks += 1;
                        el.role_hi_ticks = 0;
                    } else {
                        // back inside the band: the episode is over
                        el.role_hi_ticks = 0;
                        el.role_lo_ticks = 0;
                        el.role_moved = false;
                    }
                    // keep at least one shard of each capability alive
                    let (from_ok, to_role): (fn(ShardRole) -> bool, ShardRole) =
                        if el.role_hi_ticks >= ROLE_TICKS && n_dec > 1 {
                            // prefill is drowning: convert a decode shard
                            (|r| r == ShardRole::Decode, ShardRole::Prefill)
                        } else if el.role_lo_ticks >= ROLE_TICKS && n_pre > 1 {
                            // decode is drowning: convert a prefill shard
                            (|r| r == ShardRole::Prefill, ShardRole::Decode)
                        } else {
                            (|_| false, ShardRole::Mixed)
                        };
                    if !el.role_moved {
                        let mover = (0..self.cfg.shards)
                            .filter(|&s| {
                                self.router.is_alive(s)
                                    && from_ok(self.router.role_of(s))
                                    && self.senders[s].is_some()
                            })
                            .min_by_key(|&s| (self.router.load()[s], s));
                        if let Some(s) = mover {
                            self.router.set_role(s, to_role);
                            if let Some(tx) = self.senders[s].as_ref() {
                                let _ =
                                    tx.send(ToWorker::SetRole(to_role == ShardRole::Prefill));
                            }
                            el.reroles += 1;
                            el.role_moved = true;
                            el.role_hi_ticks = 0;
                            el.role_lo_ticks = 0;
                        }
                    }
                }
            }
        }
        // probe ramp: a probing shard healthy for `ramp_deadlines`
        // clean step deadlines gets its full share back; Suspect
        // restarts the clean window, death clears the probe entirely
        let ramp = self.cfg.fault.step_deadline * self.cfg.fault.ramp_deadlines;
        for shard in 0..self.cfg.shards {
            if !self.router.is_probing(shard) {
                continue;
            }
            match flight.health[shard] {
                ShardHealth::Suspect => el.probe_since[shard] = Some(Instant::now()),
                ShardHealth::Healthy => {
                    if el.probe_since[shard].is_some_and(|s| s.elapsed() >= ramp) {
                        self.router.promote(shard);
                        el.probe_since[shard] = None;
                        el.promote_snaps.push((shard, self.router.admitted().to_vec()));
                    }
                }
                ShardHealth::Dead => el.probe_since[shard] = None,
            }
        }
    }

    /// Broadcast a KV-width switch to every live shard (degrade ladder).
    fn set_fleet_kv_bits(&self, bits: u32) {
        for tx in self.senders.iter().flatten() {
            let _ = tx.send(ToWorker::SetKvBits(bits));
        }
    }

    /// Static-mode dispatch: round-robin formed batches over the shards
    /// (seed behavior, kept as the ablation baseline; fault handling is
    /// continuous-only, so every sender is normally live here).
    fn dispatch_static(&mut self, batch: Batch, shard_rr: &mut usize) -> Result<()> {
        let n = self.senders.len();
        for _ in 0..n {
            let shard = *shard_rr % n;
            *shard_rr += 1;
            if let Some(tx) = self.senders[shard].as_ref() {
                return tx
                    .send(ToWorker::Batch(batch.requests))
                    .map_err(|_| anyhow!("worker {shard} is gone"));
            }
        }
        bail!("no live worker to dispatch a static batch")
    }
}

/// One worker shard's thread: a step-driven scheduling loop. Continuous
/// injections queue in a per-shard admission queue and join the in-flight
/// batch at the next step boundary; static batches run to completion.
/// Exits when the dispatcher hangs up and all local work is drained.
fn worker_loop(
    mut worker: Worker,
    rx: Receiver<ToWorker>,
    tx: Sender<(usize, Result<ServeEvent>)>,
) -> WorkerStats {
    let shard = worker.shard;
    // per-shard admission queue (continuous mode): drained at step
    // boundaries via `take_up_to`, capped by free slots — no deadline
    let mut queue = Batcher::new(BatchPolicy {
        max_batch: worker.capacity(),
        max_wait: Duration::ZERO,
    });
    let mut open = true;
    'serve: loop {
        // drain the mailbox without blocking
        while open {
            match rx.try_recv() {
                Ok(ToWorker::Inject(r, false)) => queue.push(r),
                Ok(ToWorker::Inject(r, true)) => queue.push_low(r),
                Ok(ToWorker::SetKvBits(bits)) => {
                    worker.set_kv_bits(bits);
                }
                Ok(ToWorker::SetRole(prefill)) => worker.set_handoff(prefill),
                Ok(ToWorker::ImportPages { req, generated, pages, ttft_s, queued_s, first_token_at }) => {
                    if let Err(req) =
                        worker.import_handoff(req, generated, &pages, ttft_s, queued_s, first_token_at)
                    {
                        if tx.send((shard, Ok(ServeEvent::ImportBounced { req }))).is_err() {
                            break 'serve;
                        }
                    }
                }
                Ok(ToWorker::ExportLane) => {
                    if let Some(ev) = worker.export_one_lane() {
                        if tx.send((shard, Ok(ev))).is_err() {
                            break 'serve;
                        }
                    }
                }
                Ok(ToWorker::Batch(reqs)) => {
                    if !run_static(&mut worker, reqs, &tx) {
                        break 'serve;
                    }
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => open = false,
            }
        }
        if queue.pending() == 0 && !worker.has_work() {
            if !open {
                break;
            }
            // idle: park until the dispatcher sends work or hangs up
            match rx.recv() {
                Ok(ToWorker::Inject(r, false)) => queue.push(r),
                Ok(ToWorker::Inject(r, true)) => queue.push_low(r),
                Ok(ToWorker::SetKvBits(bits)) => {
                    worker.set_kv_bits(bits);
                }
                Ok(ToWorker::SetRole(prefill)) => worker.set_handoff(prefill),
                Ok(ToWorker::ImportPages { req, generated, pages, ttft_s, queued_s, first_token_at }) => {
                    if let Err(req) =
                        worker.import_handoff(req, generated, &pages, ttft_s, queued_s, first_token_at)
                    {
                        if tx.send((shard, Ok(ServeEvent::ImportBounced { req }))).is_err() {
                            break;
                        }
                    }
                }
                // an idle worker has nothing decoding; a busy one may
                Ok(ToWorker::ExportLane) => {
                    if let Some(ev) = worker.export_one_lane() {
                        if tx.send((shard, Ok(ev))).is_err() {
                            break;
                        }
                    }
                }
                Ok(ToWorker::Batch(reqs)) => {
                    if !run_static(&mut worker, reqs, &tx) {
                        break;
                    }
                }
                Err(_) => break,
            }
            continue;
        }
        // step boundary: admit joiners into free slots — or, with lanes
        // full, take an interactive head-of-line that can admit by
        // preempting a batch residency (the one-step interference bound
        // paged allocation buys) — then one fused decode step across
        // the in-flight batch
        let free = worker.free_slots();
        let joiners = if free > 0 && queue.pending() > 0 {
            queue.take_up_to(free)
        } else if free == 0 && queue.front_interactive() && worker.has_preemptible_batch() {
            queue.take_up_to(1)
        } else {
            Vec::new()
        };
        if !joiners.is_empty() {
            let taken = joiners.len();
            let (events, bounced) = match worker.join_continuous(joiners) {
                Ok(x) => x,
                Err(e) => {
                    let _ = emit(Err(e), &tx, shard);
                    break;
                }
            };
            if bounced.len() == taken && !worker.has_work() {
                // an empty shard that still can't hold the request will
                // never be able to: the pool is smaller than one
                // residency — a config error, not transient pressure
                let _ = emit(
                    Err(anyhow!(
                        "request exceeds shard {shard}'s KV block pool — raise kv_blocks"
                    )),
                    &tx,
                    shard,
                );
                break;
            }
            // block-budget bounces return first-in-line in their tier,
            // arrival order preserved
            for r in bounced.into_iter().rev() {
                if r.priority == Priority::Batch {
                    queue.push_low_front(r);
                } else {
                    queue.push_front(r);
                }
            }
            if !emit(Ok(events), &tx, shard) {
                break;
            }
        }
        // re-map preempted requests into whatever capacity remains;
        // their re-prefill advances inside the next step
        worker.resume_parked();
        if worker.active() > 0 && !emit(worker.step(), &tx, shard) {
            break;
        }
    }
    worker.into_stats()
}

/// Run one static batch to completion, streaming its events.
fn run_static(
    worker: &mut Worker,
    reqs: Vec<Request>,
    tx: &Sender<(usize, Result<ServeEvent>)>,
) -> bool {
    let shard = worker.shard;
    if !emit(worker.join(reqs), tx, shard) {
        return false;
    }
    while worker.active() > 0 {
        if !emit(worker.step(), tx, shard) {
            return false;
        }
    }
    true
}

/// Forward a step's events (or its error) to the dispatcher; false when
/// the worker should stop (fatal error or dispatcher hung up). An
/// *injected* crash is deliberately silent — a dead device announces
/// nothing, so the dispatcher must detect it from the missed step
/// deadlines, which is exactly what the fault drill exercises.
fn emit(
    result: Result<Vec<ServeEvent>>,
    tx: &Sender<(usize, Result<ServeEvent>)>,
    shard: usize,
) -> bool {
    match result {
        Ok(events) => {
            for ev in events {
                if tx.send((shard, Ok(ev))).is_err() {
                    return false;
                }
            }
            true
        }
        Err(e) => {
            if !is_injected_crash(&e) {
                let _ = tx.send((shard, Err(e)));
            }
            false
        }
    }
}
