//! Distributed scale synchronization (paper §3.3, Thm. 4).
//!
//! Every worker shard tracks activation scales with `EmaScaleTracker`s
//! (Alg. 1). Periodically the shards run an all-reduce(max) over their
//! deltas and an all-gather over zero points through the `collective`
//! ring, then adopt the merged state — after a sync, all shards quantize
//! with identical parameters, which Thm. 4's consistency argument
//! requires.

use crate::collective::{Collective, OpError};
use crate::quant::{EmaScaleTracker, EmaState};

/// Per-shard synchronizer: a tracker per tracked region (e.g. one per
/// layer input) plus the rank's collective endpoint.
pub struct ScaleSync {
    trackers: Vec<EmaScaleTracker>,
    /// sync every `period` observations (0 = never)
    period: u64,
    observations: u64,
    pub syncs: u64,
}

impl ScaleSync {
    pub fn new(n_regions: usize, alpha: f32, eps: f32, period: u64) -> Self {
        ScaleSync {
            trackers: (0..n_regions).map(|_| EmaScaleTracker::new(alpha, eps)).collect(),
            period,
            observations: 0,
            syncs: 0,
        }
    }

    pub fn n_regions(&self) -> usize {
        self.trackers.len()
    }

    /// Observe activations for a region; returns the local state.
    pub fn observe(&mut self, region: usize, x: &[f32]) -> EmaState {
        self.observations += 1;
        self.trackers[region].observe(x)
    }

    pub fn state(&self, region: usize) -> EmaState {
        self.trackers[region].state()
    }

    /// Whether the sync period has elapsed.
    pub fn due(&self) -> bool {
        self.period > 0 && self.observations > 0 && self.observations % self.period == 0
    }

    /// Eqs. 7-8: merge scales across shards.
    ///
    /// deltas merge with max (conservative: no shard's range is clipped);
    /// zero points average. Returns the merged states all shards adopted.
    pub fn sync(&mut self, comm: &mut Collective) -> Result<Vec<EmaState>, OpError> {
        let local_deltas: Vec<f32> = self.trackers.iter().map(|t| t.state().delta).collect();
        let local_zps: Vec<f32> =
            self.trackers.iter().map(|t| t.state().zero_point).collect();
        let merged_deltas = comm.all_reduce_max(local_deltas)?;
        let zp_sum = comm.all_reduce_sum(local_zps)?;
        let world = comm.world() as f32;
        let mut out = Vec::with_capacity(self.trackers.len());
        for (i, t) in self.trackers.iter_mut().enumerate() {
            let st = EmaState {
                delta: merged_deltas[i],
                zero_point: (zp_sum[i] / world).round(),
            };
            t.adopt(st);
            out.push(st);
        }
        self.syncs += 1;
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::{Collective, Topology, Transport};

    fn run_shards<F, T>(n: usize, f: F) -> Vec<T>
    where
        F: Fn(usize, Collective) -> T + Send + Sync + Clone + 'static,
        T: Send + 'static,
    {
        let ring = Collective::ring(Topology::new(n, Transport::NvlinkRdma));
        let mut handles = Vec::new();
        for (rank, c) in ring.into_iter().enumerate() {
            let f = f.clone();
            handles.push(std::thread::spawn(move || f(rank, c)));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn post_sync_states_identical_across_shards() {
        // Thm. 4: after sync every shard holds identical parameters
        let states = run_shards(4, |rank, mut comm| {
            let mut s = ScaleSync::new(3, 0.9, 1e-6, 0);
            // each shard sees different data
            for region in 0..3 {
                let x: Vec<f32> =
                    (0..64).map(|i| (i as f32 + rank as f32 * 10.0) * 0.01).collect();
                s.observe(region, &x);
            }
            s.sync(&mut comm).unwrap()
        });
        for other in &states[1..] {
            for (a, b) in states[0].iter().zip(other) {
                assert_eq!(a.delta, b.delta);
                assert_eq!(a.zero_point, b.zero_point);
            }
        }
    }

    #[test]
    fn merged_delta_is_max_over_shards() {
        let states = run_shards(3, |rank, mut comm| {
            let mut s = ScaleSync::new(1, 0.9, 1e-6, 0);
            s.observe(0, &[(rank as f32 + 1.0) * 2.0]);
            s.sync(&mut comm).unwrap()
        });
        // max absmax across shards = 6.0
        for st in states {
            assert!((st[0].delta - 6.0).abs() < 1e-5, "{:?}", st);
        }
    }

    #[test]
    fn due_respects_period() {
        let mut s = ScaleSync::new(1, 0.9, 1e-6, 4);
        for i in 1..=8 {
            s.observe(0, &[1.0]);
            assert_eq!(s.due(), i % 4 == 0, "at {i}");
        }
        let never = ScaleSync::new(1, 0.9, 1e-6, 0);
        assert!(!never.due());
    }
}
