//! Distributed scale synchronization (paper §3.3, Thm. 4).
//!
//! Every worker shard tracks activation scales with `EmaScaleTracker`s
//! (Alg. 1). Periodically the shards run an all-reduce(max) over their
//! deltas and an all-reduce(sum) over zero points through the
//! `collective` ring, then adopt the merged state — after a sync, all
//! shards quantize with identical parameters, which Thm. 4's consistency
//! argument requires.
//!
//! The sync traffic itself rides the quantized wire
//! (`all_gather_quant` for deltas, `all_reduce_sum_q` for zero points,
//! both at the synchronizer's wire bitwidth — [`SYNC_WIRE_BITS`] by
//! default, [`sync_wire_bits_for`] per transport tier): every shard
//! decodes the same low-bit bytes, so the merged state is still
//! bit-identical across shards, at ~4x fewer wire bytes (8-bit) or ~8x
//! (the 4-bit edge/TCP tier, trading wire bytes for a coarser — still
//! conservative — merge).
//!
//! Deltas ship in the **log2 domain**: max commutes with the monotone
//! log, so the merge semantics are unchanged, and the wire error becomes
//! a *uniform relative* error (2^(half step) − 1; under ~5% even when
//! tracked deltas span 2^±20) instead of an absolute error that
//! collapses any delta below max/254 to zero. The merge stays
//! **conservative** — no shard's range is clipped: each decoded
//! contribution is padded by its sender's wire half-step (computable
//! from the decoded amax, since the max-magnitude element decodes
//! exactly), so the merged delta is always ≥ every shard's true max, at
//! the cost of at most ~one wire step of overshoot. Adopted deltas are
//! still floored at the tracker eps as a backstop — the padding, floor,
//! and decode are identical on every shard, preserving Thm. 4 identity.
//!
//! Zero points are safe on the same wire: the tracker maintains
//! `|mean| <= delta` (an EMA of batch means against an EMA of batch
//! absmaxes), so `|zp| = |round(mean * 127 / delta)| <= 127`. The zp
//! chunk's token scale is therefore <= ~1 and the quantized-sum error
//! is under half a grid step per shard — after the `.round()`, the
//! merged zero point lands within one step of the exact average
//! (pinned by `zero_point_sync_error_bounded_to_one_grid_step`).

use crate::collective::{Collective, OpError, Transport};
use crate::quant::{EmaScaleTracker, EmaState};

/// Default wire bitwidth of the scale-sync collectives (paper §3.3: NCCL
/// payloads ship low-bit). 8 keeps the log-domain delta error at the low
/// percent level across any magnitude spread while cutting sync bytes
/// ~4x vs f32.
pub const SYNC_WIRE_BITS: u32 = 8;

/// Sync wire bitwidth for a transport tier: datacenter fabrics ship the
/// default 8-bit sync; the TCP fallback (paper's edge / CPU-GPU hybrid
/// tier, also where degraded links land) drops to 4 — the log-domain
/// delta error grows from the percent level to the ~10% level and zero
/// points coarsen, but sync bytes halve again on the slowest links. The
/// merge stays conservative at any width (the half-step pad scales with
/// the wire's qmax).
pub fn sync_wire_bits_for(transport: Transport) -> u32 {
    match transport {
        Transport::NvlinkRdma | Transport::Infiniband => SYNC_WIRE_BITS,
        Transport::Tcp => 4,
    }
}

/// Per-shard synchronizer: a tracker per tracked region (e.g. one per
/// layer input) plus the rank's collective endpoint.
pub struct ScaleSync {
    trackers: Vec<EmaScaleTracker>,
    /// tracker eps floor; also floors adopted deltas after a quantized
    /// sync (identical on every shard, so Thm. 4 identity survives)
    eps: f32,
    /// sync every `period` observations (0 = never)
    period: u64,
    /// wire bitwidth of the sync collectives (2, 4, or 8)
    wire_bits: u32,
    observations: u64,
    pub syncs: u64,
}

impl ScaleSync {
    pub fn new(n_regions: usize, alpha: f32, eps: f32, period: u64) -> Self {
        ScaleSync {
            trackers: (0..n_regions).map(|_| EmaScaleTracker::new(alpha, eps)).collect(),
            eps,
            period,
            wire_bits: SYNC_WIRE_BITS,
            observations: 0,
            syncs: 0,
        }
    }

    /// Override the sync wire bitwidth — must be 2, 4, or 8 (anything
    /// else is rejected by the collective at sync time, as
    /// `OpError::InvalidBits`). Every shard must pick the same width
    /// (SPMD contract); [`sync_wire_bits_for`] maps transport tiers.
    pub fn with_wire_bits(mut self, bits: u32) -> Self {
        self.wire_bits = bits;
        self
    }

    pub fn wire_bits(&self) -> u32 {
        self.wire_bits
    }

    pub fn n_regions(&self) -> usize {
        self.trackers.len()
    }

    /// Observe activations for a region; returns the local state.
    pub fn observe(&mut self, region: usize, x: &[f32]) -> EmaState {
        self.observations += 1;
        self.trackers[region].observe(x)
    }

    pub fn state(&self, region: usize) -> EmaState {
        self.trackers[region].state()
    }

    /// Rejoin re-sync: adopt a snapshot of per-region states wholesale —
    /// the fleet-side half of shard recovery. A rejoining shard has no
    /// observation history, so instead of waiting a full sync period (and
    /// quantizing with stale defaults meanwhile), it clones a healthy
    /// survivor's post-sync states; Thm. 4 identity holds immediately
    /// because every survivor already holds the same merged states.
    /// Extra regions in the snapshot are ignored; missing ones keep the
    /// tracker's current state. Returns how many regions were adopted.
    pub fn adopt_states(&mut self, states: &[EmaState]) -> usize {
        let n = self.trackers.len().min(states.len());
        for (t, st) in self.trackers.iter_mut().zip(states) {
            t.adopt(EmaState { delta: st.delta.max(self.eps), ..*st });
        }
        n
    }

    /// Snapshot every region's current state (what a rejoining shard
    /// clones via [`ScaleSync::adopt_states`]).
    pub fn states(&self) -> Vec<EmaState> {
        self.trackers.iter().map(|t| t.state()).collect()
    }

    /// Whether the sync period has elapsed.
    pub fn due(&self) -> bool {
        self.period > 0 && self.observations > 0 && self.observations % self.period == 0
    }

    /// Eqs. 7-8: merge scales across shards over the quantized wire.
    ///
    /// deltas merge with a conservative max, shipped as log2(delta) so
    /// the wire error is a uniform ~percent-level *relative* error for
    /// every region regardless of magnitude spread; zero points
    /// average. Every shard decodes the same quantized bytes and runs
    /// the same merge, so all shards adopt bit-identical merged states
    /// (Thm. 4). Returns those states.
    pub fn sync(&mut self, comm: &mut Collective) -> Result<Vec<EmaState>, OpError> {
        // max commutes with the monotone log2, so merging logs merges
        // deltas; trackers floor delta at eps > 0, keeping log2 finite
        let local_log_deltas: Vec<f32> = self
            .trackers
            .iter()
            .map(|t| t.state().delta.max(self.eps).log2())
            .collect();
        let local_zps: Vec<f32> =
            self.trackers.iter().map(|t| t.state().zero_point).collect();
        let parts = comm.all_gather_quant(&local_log_deltas, self.wire_bits)?;
        let zp_sum = comm.all_reduce_sum_q(&local_zps, self.wire_bits)?;
        let world = comm.world() as f32;
        // Conservative max-merge: a decoded log can sit up to half its
        // sender's wire step (amax / (2*qmax)) below the true value.
        // That step is bounded by the decoded amax (the max-magnitude
        // element decodes exactly, modulo f32 rounding — hence the 1e-5
        // headroom), so padding each contribution by its half-step bound
        // guarantees merged >= every shard's true max ("no shard's range
        // is clipped"), overshooting by at most ~one wire step.
        let qmax = ((1u32 << (self.wire_bits - 1)) - 1) as f32;
        let mut merged_logs = vec![f32::NEG_INFINITY; self.trackers.len()];
        for v in &parts {
            let amax = v.iter().fold(0f32, |a, x| a.max(x.abs())) * 1.00001;
            let half_step = amax / (2.0 * qmax);
            for (m, x) in merged_logs.iter_mut().zip(v) {
                *m = m.max(x + half_step);
            }
        }
        let mut out = Vec::with_capacity(self.trackers.len());
        for (i, t) in self.trackers.iter_mut().enumerate() {
            let st = EmaState {
                // eps floor as a backstop (identical on every shard)
                delta: merged_logs[i].exp2().max(self.eps),
                zero_point: (zp_sum[i] / world).round(),
            };
            t.adopt(st);
            out.push(st);
        }
        self.syncs += 1;
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::{Collective, Topology, Transport};

    fn run_shards<F, T>(n: usize, f: F) -> Vec<T>
    where
        F: Fn(usize, Collective) -> T + Send + Sync + Clone + 'static,
        T: Send + 'static,
    {
        let ring = Collective::ring(Topology::new(n, Transport::NvlinkRdma));
        let mut handles = Vec::new();
        for (rank, c) in ring.into_iter().enumerate() {
            let f = f.clone();
            handles.push(std::thread::spawn(move || f(rank, c)));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn post_sync_states_identical_across_shards() {
        // Thm. 4: after sync every shard holds identical parameters
        let states = run_shards(4, |rank, mut comm| {
            let mut s = ScaleSync::new(3, 0.9, 1e-6, 0);
            // each shard sees different data
            for region in 0..3 {
                let x: Vec<f32> =
                    (0..64).map(|i| (i as f32 + rank as f32 * 10.0) * 0.01).collect();
                s.observe(region, &x);
            }
            s.sync(&mut comm).unwrap()
        });
        for other in &states[1..] {
            for (a, b) in states[0].iter().zip(other) {
                assert_eq!(a.delta, b.delta);
                assert_eq!(a.zero_point, b.zero_point);
            }
        }
    }

    #[test]
    fn merged_delta_is_max_over_shards() {
        let states = run_shards(3, |rank, mut comm| {
            let mut s = ScaleSync::new(1, 0.9, 1e-6, 0);
            s.observe(0, &[(rank as f32 + 1.0) * 2.0]);
            s.sync(&mut comm).unwrap()
        });
        // max absmax across shards = 6.0; the merge is conservative:
        // never below the true max, at most ~one wire step above
        for st in states {
            assert!(st[0].delta >= 6.0 * (1.0 - 1e-6), "clipped: {:?}", st);
            assert!(st[0].delta <= 6.0 * 1.05, "overshot: {:?}", st);
        }
    }

    #[test]
    fn zero_point_sync_error_bounded_to_one_grid_step() {
        // |mean| <= delta keeps |zp| <= 127, so the zp wire chunk scale
        // is <= ~1 and the quantized sum can shift the merged grid by at
        // most one step vs the exact average. Shards observe identical
        // data, so the exact merged zp equals each local one.
        let states = run_shards(4, |_rank, mut comm| {
            let mut s = ScaleSync::new(3, 0.9, 1e-6, 0);
            s.observe(0, &[0.9, 1.0, 0.95]); // mean near delta -> |zp| near 127
            s.observe(1, &[-0.5, 0.5]); // zero-centered -> zp near 0
            s.observe(2, &[0.001, 0.002, 3.0]); // mixed offset
            let local: Vec<_> = (0..3).map(|r| s.state(r)).collect();
            (local, s.sync(&mut comm).unwrap())
        });
        for (local, merged) in &states {
            for (l, m) in local.iter().zip(merged) {
                assert!(m.zero_point.abs() <= 127.0, "zp {}", m.zero_point);
                assert!(
                    (m.zero_point - l.zero_point).abs() <= 1.0,
                    "zp drifted: {} -> {}",
                    l.zero_point,
                    m.zero_point
                );
            }
        }
    }

    #[test]
    fn tiny_delta_regions_survive_quantized_sync() {
        // region 0 tracks tiny activations, region 1 huge ones — a
        // 5x10^7 magnitude spread in one sync vector. The log2-domain
        // wire keeps the error *relative* (≤ ~5% at this spread), so the
        // tiny region's delta survives instead of collapsing to 0 (a
        // linear 8-bit wire would quantize it to code 0).
        let eps = 1e-6f32;
        let states = run_shards(3, move |_rank, mut comm| {
            let mut s = ScaleSync::new(2, 0.9, eps, 0);
            s.observe(0, &[1e-5, -2e-5]);
            s.observe(1, &[900.0, -1000.0]);
            s.sync(&mut comm).unwrap()
        });
        for st in &states {
            assert!(
                (st[0].delta - 2e-5).abs() <= 2e-5 * 0.06,
                "tiny delta drifted: {}",
                st[0].delta
            );
            assert!(
                (st[1].delta - 1000.0).abs() <= 1000.0 * 0.06,
                "large delta drifted: {}",
                st[1].delta
            );
            // and the merge stayed conservative (no range clipping)
            assert!(st[0].delta >= 2e-5 * (1.0 - 1e-6));
            assert!(st[1].delta >= 1000.0 * (1.0 - 1e-6));
        }
        for other in &states[1..] {
            for (a, b) in states[0].iter().zip(other) {
                assert_eq!(a.delta, b.delta);
            }
        }
    }

    #[test]
    fn transport_tiers_map_to_wire_bits() {
        assert_eq!(sync_wire_bits_for(Transport::NvlinkRdma), SYNC_WIRE_BITS);
        assert_eq!(sync_wire_bits_for(Transport::Infiniband), SYNC_WIRE_BITS);
        assert_eq!(sync_wire_bits_for(Transport::Tcp), 4);
        let s = ScaleSync::new(1, 0.9, 1e-6, 0).with_wire_bits(sync_wire_bits_for(Transport::Tcp));
        assert_eq!(s.wire_bits(), 4);
    }

    #[test]
    fn four_bit_wire_sync_stays_identical_and_conservative() {
        // the edge/TCP tier: coarser wire, same guarantees — bit-identical
        // adopted states and no shard's range clipped
        let states = run_shards(3, |rank, mut comm| {
            let mut s = ScaleSync::new(1, 0.9, 1e-6, 0).with_wire_bits(4);
            s.observe(0, &[(rank as f32 + 1.0) * 2.0]);
            s.sync(&mut comm).unwrap()
        });
        for st in &states {
            assert!(st[0].delta >= 6.0 * (1.0 - 1e-6), "clipped: {:?}", st);
            assert!(st[0].delta <= 6.0 * 1.5, "overshot past one 4-bit step: {:?}", st);
        }
        for other in &states[1..] {
            assert_eq!(states[0][0].delta, other[0].delta);
            assert_eq!(states[0][0].zero_point, other[0].zero_point);
        }
    }

    #[test]
    fn adopted_snapshot_matches_the_survivors() {
        // recovery path: survivors sync, a fresh shard adopts a snapshot
        // of one survivor's states and must quantize identically
        let merged = run_shards(3, |rank, mut comm| {
            let mut s = ScaleSync::new(2, 0.9, 1e-6, 0);
            s.observe(0, &[(rank as f32 + 1.0) * 2.0]);
            s.observe(1, &[0.5]);
            s.sync(&mut comm).unwrap()
        });
        let mut fresh = ScaleSync::new(2, 0.9, 1e-6, 0);
        assert_eq!(fresh.adopt_states(&merged[0]), 2);
        for (region, st) in merged[0].iter().enumerate() {
            assert_eq!(fresh.state(region).delta, st.delta);
            assert_eq!(fresh.state(region).zero_point, st.zero_point);
        }
        // shape mismatches are tolerated, not fatal
        let mut narrow = ScaleSync::new(1, 0.9, 1e-6, 0);
        assert_eq!(narrow.adopt_states(&merged[0]), 1);
        let before = fresh.state(1);
        assert_eq!(fresh.adopt_states(&merged[0][..1]), 1);
        assert_eq!(fresh.state(1).delta, before.delta, "missing region untouched");
        // the eps floor still backstops a degenerate snapshot
        let mut floored = ScaleSync::new(1, 0.9, 1e-3, 0);
        floored.adopt_states(&[EmaState { delta: 0.0, zero_point: 0.0 }]);
        assert!(floored.state(0).delta >= 1e-3);
    }

    #[test]
    fn due_respects_period() {
        let mut s = ScaleSync::new(1, 0.9, 1e-6, 4);
        for i in 1..=8 {
            s.observe(0, &[1.0]);
            assert_eq!(s.due(), i % 4 == 0, "at {i}");
        }
        let never = ScaleSync::new(1, 0.9, 1e-6, 0);
        assert!(!never.due());
    }
}
