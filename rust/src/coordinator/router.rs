//! Request router: admission control + least-loaded shard assignment.
//!
//! Load is tracked in in-flight *tokens* (admitted prompt length plus the
//! decode budget `max_new_tokens`), not request count: a shard chewing on
//! one 100-token generation is busier than one holding three 4-token
//! requests, and the continuous-batching dispatcher routes on exactly
//! this signal (`RouteDecision`).

use std::collections::BTreeMap;

use crate::corpus::BOS;

use super::request::{Request, RequestId};

/// Routing decision for one request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RouteDecision {
    pub shard: usize,
    /// token cost charged to the shard (released on `complete`)
    pub cost: usize,
}

/// Token cost of an admitted request: prompt tokens to prefill plus the
/// decode budget. Computed after BOS-prefixing/truncation.
pub fn request_cost(req: &Request) -> usize {
    req.prompt.len() + req.max_new_tokens
}

/// One request's in-flight charge: where it routed and how many prompt
/// (prefill) vs budgeted output (decode) tokens it holds. The split is
/// what the predictive admission gate prices: prefill and decode tokens
/// cost different calibrated rates (`coordinator::cost`).
#[derive(Debug, Clone, Copy)]
struct Charge {
    shard: usize,
    prefill: usize,
    decode: usize,
}

/// The router tracks in-flight token load per shard and a session table.
#[derive(Debug)]
pub struct Router {
    n_shards: usize,
    max_prompt: usize,
    /// in-flight token estimate per shard (prefill + decode)
    load: Vec<usize>,
    /// in-flight prompt tokens per shard (not yet known to be ingested —
    /// an upper bound on remaining prefill work)
    prefill_load: Vec<usize>,
    /// in-flight decode-budget tokens per shard
    decode_load: Vec<usize>,
    /// request -> charge; sessions stay on their shard for KV affinity
    sessions: BTreeMap<RequestId, Charge>,
    /// shards still in the routing set; a dead shard never rejoins.
    /// Killing a shard concentrates subsequent load (and therefore
    /// `backlog`) on the survivors, which is exactly how capacity loss
    /// reaches the predictive admission gate: the same target now
    /// prices against 1/(n-1) more backlog per shard and sheds batch
    /// traffic instead of breaching the SLO.
    alive: Vec<bool>,
    next_id: RequestId,
}

impl Router {
    pub fn new(n_shards: usize, max_prompt: usize) -> Self {
        assert!(n_shards >= 1);
        Router {
            n_shards,
            max_prompt,
            load: vec![0; n_shards],
            prefill_load: vec![0; n_shards],
            decode_load: vec![0; n_shards],
            sessions: BTreeMap::new(),
            alive: vec![true; n_shards],
            next_id: 1,
        }
    }

    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    pub fn fresh_id(&mut self) -> RequestId {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    /// Admit a request: BOS-prefix, truncate the prompt to fit, assign
    /// the live shard with the fewest in-flight tokens (ties -> lowest
    /// rank, keeps assignment deterministic for the property tests).
    /// With every shard dead (degenerate — the dispatcher sheds before
    /// routing in that state) shard 0 absorbs the charge.
    pub fn admit(&mut self, mut req: Request) -> (Request, RouteDecision) {
        if req.prompt.first() != Some(&BOS) {
            req.prompt.insert(0, BOS);
        }
        if req.prompt.len() > self.max_prompt {
            req.prompt.truncate(self.max_prompt);
        }
        let cost = request_cost(&req);
        let shard = self.least_loaded_alive().unwrap_or(0);
        self.charge(shard, &req);
        (req, RouteDecision { shard, cost })
    }

    /// Route a failover request to a healthy shard *without* the
    /// admission rewrite: the prompt was already BOS-prefixed/truncated
    /// at original admission and has since been extended with the
    /// delivered tokens (so it may legitimately exceed `max_prompt`;
    /// the worker caps ingestion at ctx - 1 and the trajectory is a
    /// pure function of the prefix, so the continuation is
    /// token-identical). Returns `None` when no live shard remains.
    pub fn route_migrated(&mut self, req: &Request) -> Option<RouteDecision> {
        let shard = self.least_loaded_alive()?;
        self.charge(shard, req);
        Some(RouteDecision { shard, cost: request_cost(req) })
    }

    fn least_loaded_alive(&self) -> Option<usize> {
        self.load
            .iter()
            .enumerate()
            .filter(|(i, _)| self.alive[*i])
            .min_by_key(|(i, l)| (**l, *i))
            .map(|(i, _)| i)
    }

    fn charge(&mut self, shard: usize, req: &Request) {
        self.load[shard] += request_cost(req);
        self.prefill_load[shard] += req.prompt.len();
        self.decode_load[shard] += req.max_new_tokens;
        self.sessions.insert(
            req.id,
            Charge { shard, prefill: req.prompt.len(), decode: req.max_new_tokens },
        );
    }

    /// Permanently remove a shard from the routing set. Its outstanding
    /// sessions are the dispatcher's to release (refund) and re-route;
    /// the shard itself never rejoins.
    pub fn mark_dead(&mut self, shard: usize) {
        if let Some(a) = self.alive.get_mut(shard) {
            *a = false;
        }
    }

    pub fn is_alive(&self, shard: usize) -> bool {
        self.alive.get(shard).copied().unwrap_or(false)
    }

    pub fn alive_count(&self) -> usize {
        self.alive.iter().filter(|a| **a).count()
    }

    /// Mark a request complete, releasing its token charge.
    pub fn complete(&mut self, id: RequestId) {
        if let Some(c) = self.sessions.remove(&id) {
            self.load[c.shard] = self.load[c.shard].saturating_sub(c.prefill + c.decode);
            self.prefill_load[c.shard] = self.prefill_load[c.shard].saturating_sub(c.prefill);
            self.decode_load[c.shard] = self.decode_load[c.shard].saturating_sub(c.decode);
        }
    }

    /// Undo an admission that will never be served (the SLO gate shed
    /// the request after routing): drops the session entry and refunds
    /// the shard's token charge, so shed load does not poison the
    /// least-loaded signal.
    pub fn release(&mut self, id: RequestId) {
        self.complete(id);
    }

    pub fn shard_of(&self, id: RequestId) -> Option<usize> {
        self.sessions.get(&id).map(|c| c.shard)
    }

    /// Per-shard in-flight token load.
    pub fn load(&self) -> &[usize] {
        &self.load
    }

    /// One shard's in-flight token backlog, split into (prefill, decode)
    /// tokens — the quantity the predictive admission gate prices with
    /// the calibrated per-token costs.
    pub fn backlog(&self, shard: usize) -> (usize, usize) {
        (self.prefill_load[shard], self.decode_load[shard])
    }

    /// Total in-flight (prefill, decode) backlog across all shards
    /// (static mode dispatches round-robin from one global queue, so its
    /// gate prices the system-wide backlog).
    pub fn backlog_total(&self) -> (usize, usize) {
        (self.prefill_load.iter().sum(), self.decode_load.iter().sum())
    }

    pub fn in_flight(&self) -> usize {
        self.sessions.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, UsizeRange};

    fn req(id: RequestId, len: usize) -> Request {
        Request::new(id, vec![5; len], 4)
    }

    #[test]
    fn bos_prefix_added_once() {
        let mut r = Router::new(2, 16);
        let (q, _) = r.admit(req(1, 3));
        assert_eq!(q.prompt[0], BOS);
        assert_eq!(q.prompt.len(), 4);
        let mut with_bos = req(2, 3);
        with_bos.prompt[0] = BOS;
        let (q2, _) = r.admit(with_bos);
        assert_eq!(q2.prompt.len(), 3);
    }

    #[test]
    fn truncates_to_max_prompt() {
        let mut r = Router::new(1, 8);
        let (q, _) = r.admit(req(1, 100));
        assert_eq!(q.prompt.len(), 8);
    }

    #[test]
    fn least_loaded_assignment() {
        let mut r = Router::new(3, 16);
        let (_, d1) = r.admit(req(1, 2));
        let (_, d2) = r.admit(req(2, 2));
        let (_, d3) = r.admit(req(3, 2));
        assert_eq!((d1.shard, d2.shard, d3.shard), (0, 1, 2));
        r.complete(2);
        let (_, d4) = r.admit(req(4, 2));
        assert_eq!(d4.shard, 1, "freed shard gets the next request");
    }

    #[test]
    fn routes_by_tokens_not_request_count() {
        let mut r = Router::new(2, 64);
        // one heavy request to shard 0 ...
        let (_, d1) = r.admit(Request::new(1, vec![5; 40], 16));
        assert_eq!(d1.shard, 0);
        // ... then two light ones both land on shard 1: 2 light requests
        // are still cheaper than 1 heavy one
        let (_, d2) = r.admit(Request::new(2, vec![5; 4], 2));
        let (_, d3) = r.admit(Request::new(3, vec![5; 4], 2));
        assert_eq!((d2.shard, d3.shard), (1, 1));
    }

    #[test]
    fn decision_cost_matches_admitted_prompt() {
        let mut r = Router::new(1, 8);
        // 100-token prompt truncated to 8, + 4 new tokens
        let (q, d) = r.admit(req(1, 100));
        assert_eq!(d.cost, request_cost(&q));
        assert_eq!(d.cost, 8 + 4);
        assert_eq!(r.load(), &[12]);
        r.complete(1);
        assert_eq!(r.load(), &[0]);
    }

    #[test]
    fn release_refunds_the_shed_charge() {
        let mut r = Router::new(2, 16);
        let (_, d) = r.admit(req(1, 4));
        assert!(r.load()[d.shard] > 0);
        r.release(1);
        assert_eq!(r.load(), &[0, 0]);
        assert_eq!(r.in_flight(), 0);
        // the next admission sees the refunded shard as free again
        let (_, d2) = r.admit(req(2, 4));
        assert_eq!(d2.shard, 0);
    }

    #[test]
    fn backlog_splits_prefill_and_decode_tokens() {
        let mut r = Router::new(2, 64);
        // shard 0: prompt 4 (+BOS = 5), decode 4
        let (_, d1) = r.admit(req(1, 4));
        assert_eq!(d1.shard, 0);
        assert_eq!(r.backlog(0), (5, 4));
        assert_eq!(r.backlog(1), (0, 0));
        let (_, d2) = r.admit(req(2, 10));
        assert_eq!(d2.shard, 1);
        assert_eq!(r.backlog(1), (11, 4));
        assert_eq!(r.backlog_total(), (16, 8));
        // load stays the sum of the split
        assert_eq!(r.load()[0], 5 + 4);
        r.complete(1);
        assert_eq!(r.backlog(0), (0, 0));
        assert_eq!(r.backlog_total(), (11, 4));
        r.release(2);
        assert_eq!(r.backlog_total(), (0, 0));
    }

    #[test]
    fn complete_is_idempotent() {
        let mut r = Router::new(2, 16);
        let (_, _) = r.admit(req(1, 2));
        r.complete(1);
        r.complete(1);
        assert_eq!(r.in_flight(), 0);
        assert_eq!(r.load(), &[0, 0]);
    }

    #[test]
    fn dead_shards_leave_the_routing_set() {
        let mut r = Router::new(3, 16);
        assert_eq!(r.alive_count(), 3);
        r.mark_dead(1);
        assert!(!r.is_alive(1) && r.is_alive(0));
        assert_eq!(r.alive_count(), 2);
        // four admissions split over the two survivors, never shard 1
        for i in 1..=4 {
            let (_, d) = r.admit(req(i, 2));
            assert_ne!(d.shard, 1, "routed to a dead shard");
        }
        assert_eq!(r.load()[1], 0);
    }

    #[test]
    fn route_migrated_skips_the_admission_rewrite() {
        let mut r = Router::new(2, 8);
        r.mark_dead(0);
        // a failover prompt longer than max_prompt (original admitted
        // prompt + delivered tokens) must survive untouched
        let m = Request::new(9, vec![5; 20], 3);
        let d = r.route_migrated(&m).unwrap();
        assert_eq!(d.shard, 1);
        assert_eq!(d.cost, 20 + 3, "no truncation, no BOS insert");
        assert_eq!(r.backlog(1), (20, 3));
        assert_eq!(r.shard_of(9), Some(1));
        r.complete(9);
        assert_eq!(r.backlog_total(), (0, 0));
        // no live shard -> no route
        r.mark_dead(1);
        assert!(r.route_migrated(&Request::new(10, vec![5; 4], 1)).is_none());
        assert_eq!(r.alive_count(), 0);
    }

    #[test]
    fn prop_load_balance_within_one_request() {
        // property: after admitting K equal-cost requests with no
        // completions, shard loads differ by at most one request's cost
        check(7, 100, &UsizeRange(1, 64), |k| {
            let mut r = Router::new(4, 16);
            let mut cost = 0;
            for i in 0..*k {
                let (_, d) = r.admit(Request::new(i as RequestId, vec![3, 4], 2));
                cost = d.cost;
            }
            let mx = *r.load().iter().max().unwrap();
            let mn = *r.load().iter().min().unwrap();
            mx - mn <= cost
        });
    }

    #[test]
    fn prop_load_conserved() {
        // property: total token load equals (admitted - completed) x cost
        check(8, 100, &UsizeRange(1, 40), |k| {
            let mut r = Router::new(3, 16);
            let mut cost = 0;
            for i in 0..*k {
                let (_, d) = r.admit(Request::new(i as RequestId, vec![3], 1));
                cost = d.cost;
            }
            for i in 0..(*k / 2) {
                r.complete(i as RequestId);
            }
            r.load().iter().sum::<usize>() == (*k - *k / 2) * cost
        });
    }
}
