//! Request router: admission control + least-loaded shard assignment.
//!
//! Load is tracked in in-flight *tokens* (admitted prompt length plus the
//! decode budget `max_new_tokens`), not request count: a shard chewing on
//! one 100-token generation is busier than one holding three 4-token
//! requests, and the continuous-batching dispatcher routes on exactly
//! this signal (`RouteDecision`).
//!
//! With a paged KV cache the router additionally tracks in-flight **KV
//! blocks** per shard ([`Router::set_block_budget`] /
//! [`Router::block_backlog`]): each charge prices
//! `ceil((prompt + decode budget) / block_size)` blocks, the same unit
//! the shard's allocator hands out, so the predictive admission gate can
//! compare a candidate's block demand against the shard pool instead of
//! relying on a hard slot-count cap.

use std::collections::BTreeMap;

use crate::corpus::BOS;

use super::cost::CostEstimator;
use super::request::{Request, RequestId};

/// Routing decision for one request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RouteDecision {
    pub shard: usize,
    /// token cost charged to the shard (released on `complete`)
    pub cost: usize,
}

/// Serving role of one shard in a (possibly disaggregated) fleet.
///
/// `Mixed` is the classic configuration — every shard admits arrivals,
/// prefills, and decodes. Under disaggregation the fleet splits:
/// `Prefill` shards admit new arrivals and run chunked prefill only;
/// when a lane finishes prefill its KV block table migrates (as packed
/// quantized pages) to a `Decode` shard, which continues the stream.
/// Roles are a *routing* property: the router keeps prefill-role shards
/// out of the handoff target set and decode-role shards out of the
/// admission set, while liveness/probing rules apply unchanged.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ShardRole {
    /// Admits new arrivals, runs chunked prefill, hands finished lanes
    /// off to a decode-capable shard.
    Prefill,
    /// Receives migrated KV pages and runs the decode loop only.
    Decode,
    /// Admits, prefills, and decodes — the mixed baseline.
    #[default]
    Mixed,
}

impl ShardRole {
    pub fn name(self) -> &'static str {
        match self {
            ShardRole::Prefill => "prefill",
            ShardRole::Decode => "decode",
            ShardRole::Mixed => "mixed",
        }
    }

    /// Whether this role accepts new client arrivals (prefill work).
    pub fn admits_arrivals(self) -> bool {
        !matches!(self, ShardRole::Decode)
    }

    /// Whether this role runs the decode loop (i.e. is a valid handoff
    /// or migration target for a prefilled lane).
    pub fn runs_decode(self) -> bool {
        !matches!(self, ShardRole::Prefill)
    }
}

/// Outcome of a routing-set health transition ([`Router::mark_dead`],
/// [`Router::revive`], [`Router::promote`]): `Noop` means the
/// transition had already been applied — killing a dead shard twice,
/// or double-applying a `recover:` clause, must not corrupt the
/// routing set, so re-entrant calls are typed no-ops instead of
/// silent state churn.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Transition {
    Applied,
    Noop,
}

/// Token cost of an admitted request: prompt tokens to prefill plus the
/// decode budget. Computed after BOS-prefixing/truncation.
pub fn request_cost(req: &Request) -> usize {
    req.prompt.len() + req.max_new_tokens
}

/// One request's in-flight charge: where it routed and how many prompt
/// (prefill) vs budgeted output (decode) tokens it holds. The split is
/// what the predictive admission gate prices: prefill and decode tokens
/// cost different calibrated rates (`coordinator::cost`).
#[derive(Debug, Clone, Copy)]
struct Charge {
    shard: usize,
    prefill: usize,
    decode: usize,
    /// KV blocks the full residency occupies (0 when block accounting
    /// is disabled)
    blocks: usize,
}

/// The router tracks in-flight token load per shard and a session table.
#[derive(Debug)]
pub struct Router {
    n_shards: usize,
    max_prompt: usize,
    /// in-flight token estimate per shard (prefill + decode)
    load: Vec<usize>,
    /// in-flight prompt tokens per shard (not yet known to be ingested —
    /// an upper bound on remaining prefill work)
    prefill_load: Vec<usize>,
    /// in-flight decode-budget tokens per shard
    decode_load: Vec<usize>,
    /// KV block size the shards allocate at (0 = block accounting off)
    block_size: usize,
    /// in-flight KV blocks per shard at full residency
    block_load: Vec<usize>,
    /// request -> charge; sessions stay on their shard for KV affinity
    sessions: BTreeMap<RequestId, Charge>,
    /// shards currently in the routing set. Killing a shard
    /// concentrates subsequent load (and therefore `backlog`) on the
    /// survivors, which is exactly how capacity loss reaches the
    /// predictive admission gate: the same target now prices against
    /// 1/(n-1) more backlog per shard and sheds batch traffic instead
    /// of breaching the SLO. A dead shard re-enters only via `revive`
    /// (rejoin / standby promotion), and then behind the probe ramp.
    alive: Vec<bool>,
    /// rejoin ramp: a revived shard is `probing` until promoted — it is
    /// only eligible for a new request while it has *zero* in-flight
    /// tokens (one probe stream at a time), so a flapping shard can
    /// never hold more than one migratable request
    probing: Vec<bool>,
    /// requests charged to each shard since construction (admissions +
    /// migrations) — the fair-share signal the rejoin drill measures
    admitted: Vec<u64>,
    /// serving role per shard (all `Mixed` unless the server
    /// disaggregates or re-roles)
    roles: Vec<ShardRole>,
    next_id: RequestId,
}

impl Router {
    pub fn new(n_shards: usize, max_prompt: usize) -> Self {
        assert!(n_shards >= 1);
        Router {
            n_shards,
            max_prompt,
            load: vec![0; n_shards],
            prefill_load: vec![0; n_shards],
            decode_load: vec![0; n_shards],
            block_size: 0,
            block_load: vec![0; n_shards],
            sessions: BTreeMap::new(),
            alive: vec![true; n_shards],
            probing: vec![false; n_shards],
            admitted: vec![0; n_shards],
            roles: vec![ShardRole::Mixed; n_shards],
            next_id: 1,
        }
    }

    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    pub fn fresh_id(&mut self) -> RequestId {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    /// Admit a request: BOS-prefix, truncate the prompt to fit, assign
    /// the live shard with the fewest in-flight tokens (ties -> lowest
    /// rank, keeps assignment deterministic for the property tests).
    /// With every shard dead (degenerate — the dispatcher sheds before
    /// routing in that state) shard 0 absorbs the charge.
    pub fn admit(&mut self, mut req: Request) -> (Request, RouteDecision) {
        if req.prompt.first() != Some(&BOS) {
            req.prompt.insert(0, BOS);
        }
        if req.prompt.len() > self.max_prompt {
            req.prompt.truncate(self.max_prompt);
        }
        let cost = request_cost(&req);
        let shard = self.least_loaded_alive().unwrap_or(0);
        self.charge(shard, &req);
        (req, RouteDecision { shard, cost })
    }

    /// Route a failover request to a healthy shard *without* the
    /// admission rewrite: the prompt was already BOS-prefixed/truncated
    /// at original admission and has since been extended with the
    /// delivered tokens (so it may legitimately exceed `max_prompt`;
    /// the worker caps ingestion at ctx - 1 and the trajectory is a
    /// pure function of the prefix, so the continuation is
    /// token-identical). Returns `None` when no live shard remains.
    pub fn route_migrated(&mut self, req: &Request) -> Option<RouteDecision> {
        let shard = self.least_loaded_alive()?;
        self.charge(shard, req);
        Some(RouteDecision { shard, cost: request_cost(req) })
    }

    /// Route a finished-prefill lane to a decode-capable shard (no
    /// admission rewrite, like [`Router::route_migrated`]). Prefers
    /// `Decode`/`Mixed` shards; if none is alive (degenerate — e.g.
    /// every decode shard died mid-handoff), falls back to any live
    /// shard so the stream continues rather than stalling.
    pub fn route_handoff(&mut self, req: &Request) -> Option<RouteDecision> {
        let shard = self
            .least_loaded_where(|i| self.roles[i].runs_decode())
            .or_else(|| self.least_loaded_where(|_| true))?;
        self.charge(shard, req);
        Some(RouteDecision { shard, cost: request_cost(req) })
    }

    /// The next shard a new arrival should land on: least-loaded among
    /// live shards whose role admits arrivals (`Prefill`/`Mixed`); if
    /// the admission set is empty (every admitting shard died), any
    /// live shard absorbs the request rather than stalling admission.
    fn least_loaded_alive(&self) -> Option<usize> {
        self.least_loaded_where(|i| self.roles[i].admits_arrivals())
            .or_else(|| self.least_loaded_where(|_| true))
    }

    /// Least-loaded live shard among those passing `ok`. An *idle*
    /// probing (just-rejoined) shard takes priority — the probe stream
    /// is what validates it, and it can hold only one at a time, so
    /// this cannot starve the full-share shards. Otherwise full-share
    /// live shards compete on in-flight tokens (ties -> lowest rank; a
    /// busy prober is not a candidate). If every passing live shard is
    /// a busy prober (degenerate), fall back to least-loaded among them
    /// rather than stalling.
    fn least_loaded_where(&self, ok: impl Fn(usize) -> bool) -> Option<usize> {
        let probe = (0..self.n_shards)
            .find(|&i| self.alive[i] && ok(i) && self.probing[i] && self.load[i] == 0);
        if probe.is_some() {
            return probe;
        }
        let eligible = self
            .load
            .iter()
            .enumerate()
            .filter(|(i, _)| self.alive[*i] && ok(*i) && !self.probing[*i])
            .min_by_key(|(i, l)| (**l, *i))
            .map(|(i, _)| i);
        eligible.or_else(|| {
            self.load
                .iter()
                .enumerate()
                .filter(|(i, _)| self.alive[*i] && ok(*i))
                .min_by_key(|(i, l)| (**l, *i))
                .map(|(i, _)| i)
        })
    }

    fn charge(&mut self, shard: usize, req: &Request) {
        let blocks =
            CostEstimator::blocks_for(req.prompt.len(), req.max_new_tokens, self.block_size);
        self.load[shard] += request_cost(req);
        self.prefill_load[shard] += req.prompt.len();
        self.decode_load[shard] += req.max_new_tokens;
        self.block_load[shard] += blocks;
        self.admitted[shard] += 1;
        self.sessions.insert(
            req.id,
            Charge { shard, prefill: req.prompt.len(), decode: req.max_new_tokens, blocks },
        );
    }

    /// Remove a shard from the routing set. Its outstanding sessions
    /// are the dispatcher's to release (refund) and re-route. Killing
    /// an already-dead shard is a typed no-op (re-entrant liveness
    /// ticks and double kill paths must not churn the routing state).
    pub fn mark_dead(&mut self, shard: usize) -> Transition {
        match self.alive.get_mut(shard) {
            Some(a) if *a => {
                *a = false;
                self.probing[shard] = false;
                Transition::Applied
            }
            _ => Transition::Noop,
        }
    }

    /// Re-enter a recovered (or standby-promoted) shard into the
    /// routing set behind the probe ramp: until [`Router::promote`],
    /// it is eligible only while idle. Reviving a shard that is
    /// already alive — a double `recover:` clause — is a typed no-op.
    pub fn revive(&mut self, shard: usize) -> Transition {
        match self.alive.get_mut(shard) {
            Some(a) if !*a => {
                *a = true;
                self.probing[shard] = true;
                Transition::Applied
            }
            _ => Transition::Noop,
        }
    }

    /// Complete the rejoin ramp: the shard regains its full routing
    /// share. No-op unless the shard is alive and still probing.
    pub fn promote(&mut self, shard: usize) -> Transition {
        match self.probing.get_mut(shard) {
            Some(p) if *p && self.alive[shard] => {
                *p = false;
                Transition::Applied
            }
            _ => Transition::Noop,
        }
    }

    /// Assign a shard's serving role. Re-assigning the current role is
    /// a typed no-op so re-entrant re-role ticks do not churn state.
    /// The shard's in-flight charges are untouched: lanes it already
    /// holds drain under the old behavior while new routing follows the
    /// new role (mirroring the probe-ramp philosophy).
    pub fn set_role(&mut self, shard: usize, role: ShardRole) -> Transition {
        match self.roles.get_mut(shard) {
            Some(r) if *r != role => {
                *r = role;
                Transition::Applied
            }
            _ => Transition::Noop,
        }
    }

    /// A shard's current serving role (`Mixed` for out-of-range).
    pub fn role_of(&self, shard: usize) -> ShardRole {
        self.roles.get(shard).copied().unwrap_or(ShardRole::Mixed)
    }

    /// Per-shard serving roles.
    pub fn roles(&self) -> &[ShardRole] {
        &self.roles
    }

    pub fn is_alive(&self, shard: usize) -> bool {
        self.alive.get(shard).copied().unwrap_or(false)
    }

    /// Whether a shard is alive but still in its probe ramp.
    pub fn is_probing(&self, shard: usize) -> bool {
        self.probing.get(shard).copied().unwrap_or(false)
    }

    pub fn alive_count(&self) -> usize {
        self.alive.iter().filter(|a| **a).count()
    }

    /// Requests charged per shard since construction (admissions plus
    /// migrations) — monotone counters for routing-share measurements.
    pub fn admitted(&self) -> &[u64] {
        &self.admitted
    }

    /// Mark a request complete, releasing its token charge.
    pub fn complete(&mut self, id: RequestId) {
        if let Some(c) = self.sessions.remove(&id) {
            self.load[c.shard] = self.load[c.shard].saturating_sub(c.prefill + c.decode);
            self.prefill_load[c.shard] = self.prefill_load[c.shard].saturating_sub(c.prefill);
            self.decode_load[c.shard] = self.decode_load[c.shard].saturating_sub(c.decode);
            self.block_load[c.shard] = self.block_load[c.shard].saturating_sub(c.blocks);
        }
    }

    /// Undo an admission that will never be served (the SLO gate shed
    /// the request after routing): drops the session entry and refunds
    /// the shard's token charge, so shed load does not poison the
    /// least-loaded signal.
    pub fn release(&mut self, id: RequestId) {
        self.complete(id);
    }

    pub fn shard_of(&self, id: RequestId) -> Option<usize> {
        self.sessions.get(&id).map(|c| c.shard)
    }

    /// Per-shard in-flight token load.
    pub fn load(&self) -> &[usize] {
        &self.load
    }

    /// Enable KV-block accounting: subsequent charges also price
    /// `ceil((prompt + decode budget) / block_size)` blocks per request.
    /// `block_size == 0` disables it (the pre-paged behavior). Call
    /// before admitting — existing charges are not re-priced.
    pub fn set_block_budget(&mut self, block_size: usize) {
        self.block_size = block_size;
    }

    /// One shard's in-flight token backlog, split into (prefill, decode)
    /// tokens — the quantity the predictive admission gate prices with
    /// the calibrated per-token costs.
    pub fn backlog(&self, shard: usize) -> (usize, usize) {
        (self.prefill_load[shard], self.decode_load[shard])
    }

    /// One shard's in-flight KV-block demand at full residency — what
    /// the predictive gate compares against the shard's block pool to
    /// price block-pressure drain time. Zero when block accounting is
    /// disabled.
    pub fn block_backlog(&self, shard: usize) -> usize {
        self.block_load[shard]
    }

    /// Total in-flight (prefill, decode) backlog across all shards
    /// (static mode dispatches round-robin from one global queue, so its
    /// gate prices the system-wide backlog).
    pub fn backlog_total(&self) -> (usize, usize) {
        (self.prefill_load.iter().sum(), self.decode_load.iter().sum())
    }

    pub fn in_flight(&self) -> usize {
        self.sessions.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, UsizeRange};

    fn req(id: RequestId, len: usize) -> Request {
        Request::new(id, vec![5; len], 4)
    }

    #[test]
    fn bos_prefix_added_once() {
        let mut r = Router::new(2, 16);
        let (q, _) = r.admit(req(1, 3));
        assert_eq!(q.prompt[0], BOS);
        assert_eq!(q.prompt.len(), 4);
        let mut with_bos = req(2, 3);
        with_bos.prompt[0] = BOS;
        let (q2, _) = r.admit(with_bos);
        assert_eq!(q2.prompt.len(), 3);
    }

    #[test]
    fn truncates_to_max_prompt() {
        let mut r = Router::new(1, 8);
        let (q, _) = r.admit(req(1, 100));
        assert_eq!(q.prompt.len(), 8);
    }

    #[test]
    fn least_loaded_assignment() {
        let mut r = Router::new(3, 16);
        let (_, d1) = r.admit(req(1, 2));
        let (_, d2) = r.admit(req(2, 2));
        let (_, d3) = r.admit(req(3, 2));
        assert_eq!((d1.shard, d2.shard, d3.shard), (0, 1, 2));
        r.complete(2);
        let (_, d4) = r.admit(req(4, 2));
        assert_eq!(d4.shard, 1, "freed shard gets the next request");
    }

    #[test]
    fn routes_by_tokens_not_request_count() {
        let mut r = Router::new(2, 64);
        // one heavy request to shard 0 ...
        let (_, d1) = r.admit(Request::new(1, vec![5; 40], 16));
        assert_eq!(d1.shard, 0);
        // ... then two light ones both land on shard 1: 2 light requests
        // are still cheaper than 1 heavy one
        let (_, d2) = r.admit(Request::new(2, vec![5; 4], 2));
        let (_, d3) = r.admit(Request::new(3, vec![5; 4], 2));
        assert_eq!((d2.shard, d3.shard), (1, 1));
    }

    #[test]
    fn decision_cost_matches_admitted_prompt() {
        let mut r = Router::new(1, 8);
        // 100-token prompt truncated to 8, + 4 new tokens
        let (q, d) = r.admit(req(1, 100));
        assert_eq!(d.cost, request_cost(&q));
        assert_eq!(d.cost, 8 + 4);
        assert_eq!(r.load(), &[12]);
        r.complete(1);
        assert_eq!(r.load(), &[0]);
    }

    #[test]
    fn release_refunds_the_shed_charge() {
        let mut r = Router::new(2, 16);
        let (_, d) = r.admit(req(1, 4));
        assert!(r.load()[d.shard] > 0);
        r.release(1);
        assert_eq!(r.load(), &[0, 0]);
        assert_eq!(r.in_flight(), 0);
        // the next admission sees the refunded shard as free again
        let (_, d2) = r.admit(req(2, 4));
        assert_eq!(d2.shard, 0);
    }

    #[test]
    fn backlog_splits_prefill_and_decode_tokens() {
        let mut r = Router::new(2, 64);
        // shard 0: prompt 4 (+BOS = 5), decode 4
        let (_, d1) = r.admit(req(1, 4));
        assert_eq!(d1.shard, 0);
        assert_eq!(r.backlog(0), (5, 4));
        assert_eq!(r.backlog(1), (0, 0));
        let (_, d2) = r.admit(req(2, 10));
        assert_eq!(d2.shard, 1);
        assert_eq!(r.backlog(1), (11, 4));
        assert_eq!(r.backlog_total(), (16, 8));
        // load stays the sum of the split
        assert_eq!(r.load()[0], 5 + 4);
        r.complete(1);
        assert_eq!(r.backlog(0), (0, 0));
        assert_eq!(r.backlog_total(), (11, 4));
        r.release(2);
        assert_eq!(r.backlog_total(), (0, 0));
    }

    #[test]
    fn complete_is_idempotent() {
        let mut r = Router::new(2, 16);
        let (_, _) = r.admit(req(1, 2));
        r.complete(1);
        r.complete(1);
        assert_eq!(r.in_flight(), 0);
        assert_eq!(r.load(), &[0, 0]);
    }

    #[test]
    fn dead_shards_leave_the_routing_set() {
        let mut r = Router::new(3, 16);
        assert_eq!(r.alive_count(), 3);
        r.mark_dead(1);
        assert!(!r.is_alive(1) && r.is_alive(0));
        assert_eq!(r.alive_count(), 2);
        // four admissions split over the two survivors, never shard 1
        for i in 1..=4 {
            let (_, d) = r.admit(req(i, 2));
            assert_ne!(d.shard, 1, "routed to a dead shard");
        }
        assert_eq!(r.load()[1], 0);
    }

    #[test]
    fn health_transitions_are_typed_and_idempotent() {
        let mut r = Router::new(2, 16);
        assert_eq!(r.mark_dead(1), Transition::Applied);
        assert_eq!(r.mark_dead(1), Transition::Noop, "double kill");
        assert_eq!(r.mark_dead(99), Transition::Noop, "out-of-range shard");
        assert_eq!(r.revive(1), Transition::Applied);
        assert_eq!(r.revive(1), Transition::Noop, "double recover");
        assert_eq!(r.revive(0), Transition::Noop, "reviving an alive shard");
        assert_eq!(r.revive(99), Transition::Noop);
        assert_eq!(r.promote(1), Transition::Applied);
        assert_eq!(r.promote(1), Transition::Noop, "double promote");
        assert_eq!(r.promote(0), Transition::Noop, "promoting a full-share shard");
        assert!(r.is_alive(1) && !r.is_probing(1));
    }

    #[test]
    fn probing_shard_gets_one_probe_stream_at_a_time() {
        let mut r = Router::new(2, 16);
        r.mark_dead(1);
        r.revive(1);
        assert!(r.is_probing(1));
        // idle prober is the least-loaded candidate -> takes the probe
        let (_, d1) = r.admit(req(1, 2));
        assert_eq!(d1.shard, 1, "idle prober should absorb the probe request");
        // while the probe is in flight, everything else lands on shard 0
        for i in 2..=5 {
            let (_, d) = r.admit(req(i, 2));
            assert_eq!(d.shard, 0, "busy prober must not take a second stream");
        }
        // probe completes -> prober is idle-eligible again
        r.complete(1);
        let (_, d6) = r.admit(req(6, 2));
        assert_eq!(d6.shard, 1);
        // promotion restores full least-loaded competition
        r.promote(1);
        assert!(!r.is_probing(1));
        for i in 7..=10 {
            let _ = r.admit(req(i, 2));
        }
        assert!(r.load()[1] > 0 && r.load()[0] > 0);
    }

    #[test]
    fn death_during_ramp_clears_the_probe_state() {
        let mut r = Router::new(2, 16);
        r.mark_dead(1);
        r.revive(1);
        assert!(r.is_probing(1));
        // the prober flaps before promotion: probing must not leak into
        // the next incarnation's bookkeeping
        r.mark_dead(1);
        assert!(!r.is_probing(1));
        assert_eq!(r.promote(1), Transition::Noop, "dead shard cannot promote");
        r.revive(1);
        assert!(r.is_probing(1), "each revival restarts its own ramp");
    }

    #[test]
    fn admitted_counters_track_charges_per_shard() {
        let mut r = Router::new(2, 16);
        for i in 1..=4 {
            let _ = r.admit(req(i, 2));
        }
        assert_eq!(r.admitted(), &[2, 2]);
        r.mark_dead(0);
        let m = Request::new(9, vec![5; 4], 2);
        r.route_migrated(&m).unwrap();
        assert_eq!(r.admitted(), &[2, 3], "migrations count as charges");
    }

    #[test]
    fn route_migrated_skips_the_admission_rewrite() {
        let mut r = Router::new(2, 8);
        r.mark_dead(0);
        // a failover prompt longer than max_prompt (original admitted
        // prompt + delivered tokens) must survive untouched
        let m = Request::new(9, vec![5; 20], 3);
        let d = r.route_migrated(&m).unwrap();
        assert_eq!(d.shard, 1);
        assert_eq!(d.cost, 20 + 3, "no truncation, no BOS insert");
        assert_eq!(r.backlog(1), (20, 3));
        assert_eq!(r.shard_of(9), Some(1));
        r.complete(9);
        assert_eq!(r.backlog_total(), (0, 0));
        // no live shard -> no route
        r.mark_dead(1);
        assert!(r.route_migrated(&Request::new(10, vec![5; 4], 1)).is_none());
        assert_eq!(r.alive_count(), 0);
    }

    #[test]
    fn block_backlog_charges_and_refunds_whole_blocks() {
        let mut r = Router::new(2, 64);
        assert_eq!(r.block_backlog(0), 0, "accounting off by default");
        r.set_block_budget(16);
        // prompt 9 (+BOS = 10) + decode 4 = 14 tokens -> 1 block
        let (_, d1) = r.admit(req(1, 9));
        assert_eq!(d1.shard, 0);
        assert_eq!(r.block_backlog(0), 1);
        // prompt 29 (+BOS = 30) + decode 4 = 34 tokens -> 3 blocks
        let (_, d2) = r.admit(req(2, 29));
        assert_eq!(d2.shard, 1);
        assert_eq!(r.block_backlog(1), 3);
        r.complete(1);
        assert_eq!(r.block_backlog(0), 0, "completion refunds the block charge");
        r.release(2);
        assert_eq!(r.block_backlog(1), 0, "shed release refunds too");
        r.complete(2);
        assert_eq!(r.block_backlog(1), 0, "idempotent");
    }

    #[test]
    fn block_charges_survive_migration_and_budget_off() {
        let mut r = Router::new(2, 16);
        r.set_block_budget(8);
        r.mark_dead(0);
        let m = Request::new(9, vec![5; 20], 3);
        r.route_migrated(&m).unwrap();
        assert_eq!(r.block_backlog(1), 23usize.div_ceil(8));
        r.complete(9);
        assert_eq!(r.block_backlog(1), 0);
        // turning the budget off mid-stream leaves old charges refundable
        r.revive(0);
        r.promote(0);
        let (_, d) = r.admit(req(1, 7));
        assert!(r.block_backlog(d.shard) > 0);
        r.set_block_budget(0);
        let (_, d2) = r.admit(req(2, 7));
        assert_eq!(r.block_backlog(d2.shard), 0, "new charges price zero blocks");
        r.complete(1);
        assert_eq!(r.block_backlog(d.shard), 0, "old charge still refunds its blocks");
    }

    #[test]
    fn roles_default_mixed_with_typed_transitions() {
        let mut r = Router::new(2, 16);
        assert_eq!(r.role_of(0), ShardRole::Mixed);
        assert_eq!(r.roles(), &[ShardRole::Mixed, ShardRole::Mixed]);
        assert_eq!(r.set_role(0, ShardRole::Prefill), Transition::Applied);
        assert_eq!(r.set_role(0, ShardRole::Prefill), Transition::Noop, "same role");
        assert_eq!(r.set_role(99, ShardRole::Decode), Transition::Noop, "out of range");
        assert_eq!(r.role_of(0), ShardRole::Prefill);
        assert_eq!(r.role_of(99), ShardRole::Mixed);
    }

    #[test]
    fn decode_role_shards_leave_the_admission_set() {
        let mut r = Router::new(2, 16);
        r.set_role(0, ShardRole::Prefill);
        r.set_role(1, ShardRole::Decode);
        for i in 1..=4 {
            let (_, d) = r.admit(req(i, 2));
            assert_eq!(d.shard, 0, "arrivals must land on the prefill shard");
        }
        // handoffs go the other way: decode shard only
        let h = Request::new(9, vec![5; 6], 3);
        let d = r.route_handoff(&h).unwrap();
        assert_eq!(d.shard, 1, "handoff must land on the decode shard");
        assert_eq!(d.cost, 6 + 3, "no admission rewrite on handoff");
        assert_eq!(r.shard_of(9), Some(1));
        r.complete(9);
    }

    #[test]
    fn role_routing_falls_back_rather_than_stalling() {
        // all shards prefill-role: handoff still routes (to the least
        // loaded live shard) instead of returning None
        let mut r = Router::new(2, 16);
        r.set_role(0, ShardRole::Prefill);
        r.set_role(1, ShardRole::Prefill);
        let h = Request::new(9, vec![5; 4], 2);
        assert!(r.route_handoff(&h).is_some());
        r.complete(9);
        // all shards decode-role: arrivals still admit somewhere
        r.set_role(0, ShardRole::Decode);
        r.set_role(1, ShardRole::Decode);
        let (_, d) = r.admit(req(1, 2));
        assert!(d.shard < 2);
        // no live shard at all -> handoff has nowhere to go
        r.mark_dead(0);
        r.mark_dead(1);
        assert!(r.route_handoff(&Request::new(10, vec![5; 4], 1)).is_none());
    }

    #[test]
    fn handoff_prefers_live_decode_shards_over_dead_ones() {
        let mut r = Router::new(3, 16);
        r.set_role(0, ShardRole::Prefill);
        r.set_role(1, ShardRole::Decode);
        r.set_role(2, ShardRole::Decode);
        r.mark_dead(1);
        let h = Request::new(9, vec![5; 4], 2);
        let d = r.route_handoff(&h).unwrap();
        assert_eq!(d.shard, 2, "dead decode shard must not take handoffs");
    }

    #[test]
    fn prop_load_balance_within_one_request() {
        // property: after admitting K equal-cost requests with no
        // completions, shard loads differ by at most one request's cost
        check(7, 100, &UsizeRange(1, 64), |k| {
            let mut r = Router::new(4, 16);
            let mut cost = 0;
            for i in 0..*k {
                let (_, d) = r.admit(Request::new(i as RequestId, vec![3, 4], 2));
                cost = d.cost;
            }
            let mx = *r.load().iter().max().unwrap();
            let mn = *r.load().iter().min().unwrap();
            mx - mn <= cost
        });
    }

    #[test]
    fn prop_load_conserved() {
        // property: total token load equals (admitted - completed) x cost
        check(8, 100, &UsizeRange(1, 40), |k| {
            let mut r = Router::new(3, 16);
            let mut cost = 0;
            for i in 0..*k {
                let (_, d) = r.admit(Request::new(i as RequestId, vec![3], 1));
                cost = d.cost;
            }
            for i in 0..(*k / 2) {
                r.complete(i as RequestId);
            }
            r.load().iter().sum::<usize>() == (*k - *k / 2) * cost
        });
    }
}
