//! Memory-hierarchy + GEMM cost model for paper-scale hardware.
//!
//! The paper measures on 8xA100 with CUDA NVTX instrumentation; this repo
//! runs on CPU. `memsim` is the calibrated analytic substitute (DESIGN.md
//! §3): it prices each decode/prefill pipeline stage of Eq. 12
//! (T_total = T_load + T_quant + T_gemm + T_comm + T_sync)
//! from first principles — HBM bytes over measured bandwidth, GEMM flops
//! over tensor-core rates (int8 at 2x fp16), quantization as a VPU
//! elementwise pass, collectives through `collective::LinkModel` — with
//! efficiency knobs representing achievable fractions of peak. The paper's
//! qualitative claims (SmoothQuant halves load+GEMM time; SimQuant wins on
//! long KV; INT8 trades comm for compute) fall out of the model rather
//! than being hard-coded.

mod gpu;
mod pipeline;

pub use gpu::{GpuSpec, PaperModel};
pub use pipeline::{LayerBreakdown, PipelineCost, Workload};
