//! Eq. 12 pipeline costing: T_load + T_quant + T_gemm + T_comm + T_sync.

use crate::collective::LinkModel;
use crate::quant::Variant;

use super::gpu::{GpuSpec, PaperModel};

/// Bytes per stored code at `bits` bits when bit-packed — the asymptotic
/// byte/code rate of `quant::kernels::packed_len`, so the cost model
/// prices sub-byte tensors at their true packed width instead of one
/// byte per code.
fn packed_bytes_per_code(bits: u32) -> f64 {
    f64::from(bits) / 8.0
}

/// One simulated deployment: model shape x batch x context x world.
#[derive(Debug, Clone, Copy)]
pub struct Workload {
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub vocab: usize,
    /// MLP matrices per layer (2 = GPT-2 MLP, 3 = SwiGLU)
    pub mlp_mats: usize,
    /// decode batch size (requests in flight)
    pub batch: usize,
    /// context length (KV entries attended per token)
    pub ctx: usize,
    /// tensor-parallel world size
    pub world: usize,
    /// stored weight-code width for the quantized variants (bit-packed
    /// below 8; 8 = classic int8 codes)
    pub weight_bits: u32,
    /// stored KV-code width for SimQuant pages (bit-packed below 8)
    pub kv_bits: u32,
    pub gpu: GpuSpec,
    pub link: LinkModel,
    /// fused quantize+GEMM kernels (§A.8); false = separate kernels that
    /// round-trip activation codes through HBM
    pub fused: bool,
    /// per-stage cudaEventRecord instrumentation, as in the paper's §4.7
    /// profiling run — forces stream flushes that dominate T_sync. On for
    /// the Table 5 reproduction, off for throughput tables.
    pub instrumented: bool,
}

/// Per-layer stage times in seconds (Eq. 12 decomposition).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LayerBreakdown {
    pub load_s: f64,
    pub quant_s: f64,
    pub gemm_s: f64,
    pub comm_s: f64,
    pub sync_s: f64,
}

impl LayerBreakdown {
    pub fn total_s(&self) -> f64 {
        self.load_s + self.quant_s + self.gemm_s + self.comm_s + self.sync_s
    }

    pub fn as_ms(&self) -> [f64; 5] {
        [
            self.load_s * 1e3,
            self.quant_s * 1e3,
            self.gemm_s * 1e3,
            self.comm_s * 1e3,
            self.sync_s * 1e3,
        ]
    }
}

/// Stream-flush cost of one cudaEventRecord-style barrier in the
/// instrumented profiling configuration (the one calibrated constant —
/// DESIGN.md §Substitutions).
const EVENT_SYNC_S: f64 = 2.05e-3;

pub struct PipelineCost {
    pub w: Workload,
}

impl PipelineCost {
    pub fn new(w: Workload) -> Self {
        PipelineCost { w }
    }

    pub fn from_paper_model(
        m: &PaperModel,
        batch: usize,
        ctx: usize,
        world: usize,
        gpu: GpuSpec,
        link: LinkModel,
    ) -> Self {
        PipelineCost::new(Workload {
            d_model: m.d_model,
            n_layers: m.n_layers,
            n_heads: m.n_heads,
            d_ff: m.d_ff,
            vocab: m.vocab,
            mlp_mats: m.mlp_mats,
            batch,
            ctx,
            world,
            weight_bits: 8,
            kv_bits: 8,
            gpu,
            link,
            fused: true,
            instrumented: false,
        })
    }

    fn params_per_layer(&self) -> f64 {
        let d = self.w.d_model as f64;
        let f = self.w.d_ff as f64;
        d * 3.0 * d + d * d + self.w.mlp_mats as f64 * d * f
    }

    /// Weight bytes resident per layer per shard.
    fn weight_bytes(&self, v: Variant) -> f64 {
        let elem = match v {
            Variant::Fp => 2.0, // FP16 baseline
            // bit-packed codes at their true width (+ scales, below)
            _ => packed_bytes_per_code(self.w.weight_bits),
        };
        let scales = match v {
            Variant::Fp => 0.0,
            // per-column f32 scales; zeroquant: one per (group=128, col)
            Variant::ZeroQuant => self.params_per_layer() / 128.0 * 4.0 / self.w.d_model as f64,
            _ => (self.w.mlp_mats + 2) as f64 * self.w.d_ff as f64 * 4.0,
        };
        (self.params_per_layer() * elem + scales) / self.w.world as f64
    }

    /// Bytes per element for the KV cache under a variant. W8A8 runtimes
    /// keep KV in int8 (the paper's SmoothQuant/INT8 rows compress
    /// "activation and weight bandwidth"); SimQuant's per-channel page
    /// params amortize better than per-token scales, so its effective
    /// footprint is lowest.
    fn kv_elem_bytes(&self, v: Variant) -> f64 {
        match v {
            // bit-packed codes + per-page params
            Variant::SimQuant => packed_bytes_per_code(self.w.kv_bits),
            _ if v.quantizes_activations() => 1.0 + 4.0 / 64.0, // per-64-token scale rows
            _ => 2.0,                                           // fp16 KV
        }
    }

    /// KV-cache bytes touched per decode step per layer per shard.
    fn kv_bytes(&self, v: Variant) -> f64 {
        2.0 * self.w.ctx as f64
            * self.w.d_model as f64
            * self.kv_elem_bytes(v)
            * self.w.batch as f64
            / self.w.world as f64
    }

    /// Eq. 12 stage times for one decode step on one layer.
    pub fn decode_layer(&self, v: Variant) -> LayerBreakdown {
        let g = &self.w.gpu;
        let (b, d, f) = (self.w.batch as f64, self.w.d_model as f64, self.w.d_ff as f64);
        let world = self.w.world as f64;
        let quantized_compute = v.quantizes_activations();

        // ---- T_load: HBM -> SRAM traffic ---------------------------------
        let act_elem = if quantized_compute { 1.0 } else { 2.0 };
        let mut bytes = self.weight_bytes(v) + self.kv_bytes(v);
        // activations in/out of the linears
        bytes += b * (6.0 * d + 2.0 * f) * act_elem / world;
        if quantized_compute && !self.w.fused {
            // unfused: activation codes round-trip through HBM (§A.8)
            bytes += 2.0 * b * (3.0 * d + f) / world;
        }
        let load_s = bytes / (g.hbm_bps * g.bw_eff) + 2.0 * g.launch_s;

        // ---- T_quant: online quantization kernels ------------------------
        let quant_s = if !quantized_compute {
            if v == Variant::Fp {
                0.0
            } else {
                // W8A16: in-SRAM dequant folded into the GEMM prologue
                g.launch_s
            }
        } else {
            // token-quantize the inputs of the linears (~6 flops/elem:
            // absmax reduce + divide + round + clip)
            let mut elems = b * (3.0 * d + f) / world;
            if v == Variant::SimQuant {
                // KV page encode of the new row + channel param update
                // (tile dequant ahead of attention is in-register, folded
                // into the attention kernel)
                elems += b * 2.0 * d / world;
            }
            let kernels = if self.w.fused { 1.0 } else { 4.0 };
            elems * 6.0 / g.vpu_flops + kernels * g.launch_s
        };

        // ---- T_gemm: tensor-core matmuls ---------------------------------
        let linear_flops = 2.0 * b * self.params_per_layer() / world;
        let attn_flops = 2.0 * b * self.w.ctx as f64 * d * 2.0 / world;
        let rate = if quantized_compute {
            g.int8_ops * g.gemm_eff
        } else {
            g.fp16_flops * g.gemm_eff
        };
        // W8A8 variants keep KV in int8, so QK^T/AV run on the int8 path
        // (dp4a / IMMA); W8A16 variants attend at fp16
        let attn_rate = if quantized_compute {
            g.int8_ops * g.gemm_eff
        } else {
            g.fp16_flops * g.gemm_eff
        };
        let gemm_s = linear_flops / rate + attn_flops / attn_rate + 6.0 * g.launch_s;

        // ---- T_comm: tensor-parallel collectives (Eqs. 7-8) --------------
        let comm_s = if self.w.world <= 1 {
            0.0
        } else {
            let act_bytes = (b * d * act_elem) as usize;
            let mut t = 2.0 * self.w.link.ring_allgather_time(act_bytes, self.w.world);
            if v != Variant::Fp {
                // per-token scales piggyback on the activation gather;
                // per-layer (delta, z) metadata costs one extra
                // latency-dominated gather (Eqs. 7-8) — why quantized rows
                // show *higher* T_comm in Table 5
                let meta_bytes = ((b + d) * 4.0_f64) as usize;
                t += self.w.link.ring_allgather_time(meta_bytes, self.w.world);
                if quantized_compute {
                    t += self.w.link.alpha_s * world;
                }
            }
            t
        };

        // ---- T_sync: stream barriers --------------------------------------
        let extra_kernels = match v {
            Variant::Fp => 0.0,
            _ if quantized_compute => {
                if self.w.fused {
                    2.0
                } else {
                    5.0
                }
            }
            _ => 1.0,
        };
        let mut sync_s = g.launch_s * (1.0 + extra_kernels) * world.log2().max(1.0)
            + self.w.link.alpha_s * world; // batch barrier
        if self.w.instrumented {
            // cudaEventRecord flush per instrumented stage (paper §4.7)
            sync_s += EVENT_SYNC_S * (1.0 + 0.15 * extra_kernels);
        }

        LayerBreakdown { load_s, quant_s, gemm_s, comm_s, sync_s }
    }

    /// Whole-model decode step time (all layers + LM head).
    pub fn decode_step_s(&self, v: Variant) -> f64 {
        let per_layer = self.decode_layer(v).total_s();
        let g = &self.w.gpu;
        let head_flops =
            2.0 * self.w.batch as f64 * self.w.d_model as f64 * self.w.vocab as f64
                / self.w.world as f64;
        let head_bytes = self.w.vocab as f64 * self.w.d_model as f64 * 2.0 / self.w.world as f64;
        let rate = g.fp16_flops * g.gemm_eff;
        per_layer * self.w.n_layers as f64
            + (head_flops / rate).max(head_bytes / (g.hbm_bps * g.bw_eff))
    }

    /// Steady-state decode throughput, tokens/second (whole batch).
    pub fn decode_tokens_per_s(&self, v: Variant) -> f64 {
        self.w.batch as f64 / self.decode_step_s(v)
    }

    /// Device memory footprint (weights + KV at full context), bytes/shard.
    pub fn memory_bytes(&self, v: Variant) -> f64 {
        let weights = self.weight_bytes(v) * self.w.n_layers as f64
            + self.w.vocab as f64 * self.w.d_model as f64 * 2.0 / self.w.world as f64;
        let kv = self.kv_bytes(v) * self.w.n_layers as f64;
        weights + kv
    }

    /// Total memory across the world, GB.
    pub fn memory_gb_total(&self, v: Variant) -> f64 {
        self.memory_bytes(v) * self.w.world as f64 / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::LinkModel;

    fn gpt2(batch: usize, ctx: usize, world: usize) -> PipelineCost {
        PipelineCost::from_paper_model(
            &PaperModel::gpt2_117m(),
            batch,
            ctx,
            world,
            GpuSpec::a100_80g(),
            LinkModel::nvlink(),
        )
    }

    #[test]
    fn fp16_has_zero_quant_time() {
        let b = gpt2(64, 32768, 8).decode_layer(Variant::Fp);
        assert_eq!(b.quant_s, 0.0);
        assert!(b.load_s > 0.0 && b.gemm_s > 0.0);
    }

    #[test]
    fn table5_shape_holds() {
        // the qualitative relations of Table 5 at 32K ctx on 8 shards
        let mut c = gpt2(448, 32768, 8);
        c.w.instrumented = true;
        let fp = c.decode_layer(Variant::Fp);
        let int8 = c.decode_layer(Variant::Int8);
        let smooth = c.decode_layer(Variant::Smooth);
        let sim = c.decode_layer(Variant::SimQuant);
        // load roughly halves (fp16 -> int8 weights/KV/activations)
        assert!(int8.load_s < fp.load_s * 0.65, "{} vs {}", int8.load_s, fp.load_s);
        assert!(sim.load_s < int8.load_s, "simquant's page params beat per-token scales");
        // gemm: int8 tensor cores ~halve linear compute
        assert!(int8.gemm_s < fp.gemm_s * 0.75);
        // comm: quantized pays more (scale gathers)
        assert!(int8.comm_s > fp.comm_s);
        // quant overhead exists but stays small vs gemm
        assert!(int8.quant_s > 0.0 && int8.quant_s < fp.gemm_s * 0.5);
        // overall ordering: smooth & sim beat fp
        assert!(smooth.total_s() < fp.total_s());
        assert!(sim.total_s() < fp.total_s());
    }

    #[test]
    fn fused_beats_unfused() {
        let mut c = gpt2(448, 32768, 8);
        c.w.fused = false;
        let unfused = c.decode_layer(Variant::Int8);
        c.w.fused = true;
        let fused = c.decode_layer(Variant::Int8);
        assert!(fused.load_s < unfused.load_s);
        assert!(fused.total_s() < unfused.total_s());
    }

    #[test]
    fn throughput_improves_with_quantization() {
        let c = PipelineCost::from_paper_model(
            &PaperModel::llama_7b(),
            64,
            8192,
            8,
            GpuSpec::a100_80g(),
            LinkModel::nvlink(),
        );
        let fp = c.decode_tokens_per_s(Variant::Fp);
        let sm = c.decode_tokens_per_s(Variant::Smooth);
        assert!(sm > fp * 1.2, "smooth {sm:.0} vs fp {fp:.0}");
    }

    #[test]
    fn memory_shrinks_with_int8_and_simquant_kv() {
        let c = gpt2(64, 32768, 8);
        let fp = c.memory_gb_total(Variant::Fp);
        let int8 = c.memory_gb_total(Variant::Int8);
        let sim = c.memory_gb_total(Variant::SimQuant);
        assert!(int8 < fp);
        assert!(sim < int8);
    }

    #[test]
    fn world_scaling_near_linear() {
        let mk = |world| {
            PipelineCost::from_paper_model(
                &PaperModel::llama_7b(),
                128,
                4096,
                world,
                GpuSpec::a100_80g(),
                LinkModel::nvlink(),
            )
            .decode_tokens_per_s(Variant::Smooth)
        };
        let speedup = mk(8) / mk(1);
        assert!(speedup > 4.0 && speedup <= 8.5, "speedup {speedup}");
    }

    #[test]
    fn context_length_scales_load() {
        let short = gpt2(64, 2048, 8).decode_layer(Variant::Fp);
        let long = gpt2(64, 32768, 8).decode_layer(Variant::Fp);
        assert!(long.load_s > short.load_s * 4.0);
    }

    #[test]
    fn packed_bits_shrink_storage_accounting() {
        // the storage ratio must reflect the true packed width: 4-bit
        // weights+KV roughly halve the 8-bit quantized footprint
        let mut c8 = gpt2(64, 32768, 8);
        c8.w.kv_bits = 8;
        let mut c4 = gpt2(64, 32768, 8);
        c4.w.weight_bits = 4;
        c4.w.kv_bits = 4;
        let m8 = c8.memory_gb_total(Variant::SimQuant);
        let m4 = c4.memory_gb_total(Variant::SimQuant);
        assert!(m4 < m8 * 0.65, "4-bit {m4} vs 8-bit {m8}");
        let mut c2 = gpt2(64, 32768, 8);
        c2.w.weight_bits = 2;
        c2.w.kv_bits = 2;
        let m2 = c2.memory_gb_total(Variant::SimQuant);
        assert!(m2 < m4, "2-bit {m2} vs 4-bit {m4}");
        // fp baseline untouched by the bit knobs
        assert_eq!(c8.memory_gb_total(Variant::Fp), c2.memory_gb_total(Variant::Fp));
    }

    #[test]
    fn simquant_advantage_grows_with_context() {
        // Fig. 8 claim: SimQuant shines at 32K+ contexts
        let ratio = |ctx: usize| {
            let c = gpt2(64, ctx, 8);
            c.decode_step_s(Variant::Int8) / c.decode_step_s(Variant::SimQuant)
        };
        assert!(ratio(32768) > ratio(2048));
    }
}
