//! Hardware specs and paper-scale model shapes.

/// GPU characteristics (dense rates; no structured sparsity).
#[derive(Debug, Clone, Copy)]
pub struct GpuSpec {
    pub name: &'static str,
    /// HBM bandwidth, bytes/s
    pub hbm_bps: f64,
    /// fp16 tensor-core throughput, flops/s
    pub fp16_flops: f64,
    /// int8 tensor-core throughput, ops/s
    pub int8_ops: f64,
    /// vector (CUDA-core / VPU) f32 throughput for elementwise work, flops/s
    pub vpu_flops: f64,
    /// kernel launch + stream sync overhead per kernel, seconds
    pub launch_s: f64,
    /// device memory capacity, bytes
    pub mem_bytes: f64,
    /// achievable fraction of peak bandwidth for streaming loads
    pub bw_eff: f64,
    /// achievable fraction of peak tensor throughput for decode GEMMs
    pub gemm_eff: f64,
}

impl GpuSpec {
    /// NVIDIA A100 SXM 80GB.
    pub fn a100_80g() -> Self {
        GpuSpec {
            name: "A100-80G",
            hbm_bps: 2.039e12,
            fp16_flops: 312e12,
            int8_ops: 624e12,
            vpu_flops: 19.5e12,
            launch_s: 4e-6,
            mem_bytes: 80e9,
            bw_eff: 0.82,
            gemm_eff: 0.45,
        }
    }

    /// Edge RTX 4090 (paper's edge platform).
    pub fn rtx4090() -> Self {
        GpuSpec {
            name: "RTX-4090",
            hbm_bps: 1.008e12,
            fp16_flops: 165e12,
            int8_ops: 330e12,
            vpu_flops: 82.6e12,
            launch_s: 5e-6,
            mem_bytes: 24e9,
            bw_eff: 0.78,
            gemm_eff: 0.40,
        }
    }
}

/// Transformer shapes for the models in the paper's tables. Our trained
/// tiny models use the same arithmetic through `Workload::from_dims`.
#[derive(Debug, Clone, Copy)]
pub struct PaperModel {
    pub name: &'static str,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub vocab: usize,
    /// MLP matrices per layer: 2 for GPT-2 (fc1/fc2), 3 for gated
    /// SwiGLU families (LLaMA / Mistral / Qwen)
    pub mlp_mats: usize,
}

impl PaperModel {
    pub fn all() -> Vec<PaperModel> {
        vec![
            Self::gpt2_117m(),
            Self::gpt2_345m(),
            Self::llama_7b(),
            Self::llama_13b(),
            Self::mistral_7b(),
            Self::qwen3_14b(),
        ]
    }

    pub fn gpt2_117m() -> Self {
        PaperModel {
            name: "GPT-2 (117M)",
            d_model: 768,
            n_layers: 12,
            n_heads: 12,
            d_ff: 3072,
            vocab: 50257,
            mlp_mats: 2,
        }
    }

    pub fn gpt2_345m() -> Self {
        PaperModel {
            name: "GPT-2 (345M)",
            d_model: 1024,
            n_layers: 24,
            n_heads: 16,
            d_ff: 4096,
            vocab: 50257,
            mlp_mats: 2,
        }
    }

    pub fn llama_7b() -> Self {
        PaperModel {
            name: "LLaMA-7B",
            d_model: 4096,
            n_layers: 32,
            n_heads: 32,
            d_ff: 11008,
            vocab: 32000,
            mlp_mats: 3,
        }
    }

    pub fn llama_13b() -> Self {
        PaperModel {
            name: "LLaMA-13B",
            d_model: 5120,
            n_layers: 40,
            n_heads: 40,
            d_ff: 13824,
            vocab: 32000,
            mlp_mats: 3,
        }
    }

    pub fn mistral_7b() -> Self {
        PaperModel {
            name: "Mistral-7B",
            d_model: 4096,
            n_layers: 32,
            n_heads: 32,
            d_ff: 14336,
            vocab: 32000,
            mlp_mats: 3,
        }
    }

    pub fn qwen3_14b() -> Self {
        PaperModel {
            name: "Qwen3-14B",
            d_model: 5120,
            n_layers: 40,
            n_heads: 40,
            d_ff: 17408,
            vocab: 151936,
            mlp_mats: 3,
        }
    }

    /// Weight parameters per transformer layer (qkv + out + 2 mlp mats).
    pub fn params_per_layer(&self) -> f64 {
        let d = self.d_model as f64;
        let f = self.d_ff as f64;
        d * 3.0 * d + d * d + self.mlp_mats as f64 * d * f
    }

    /// Total parameters (layers + embeddings).
    pub fn total_params(&self) -> f64 {
        self.params_per_layer() * self.n_layers as f64
            + (self.vocab as f64) * self.d_model as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_plausible() {
        // published sizes within ~15% (our layer formula ignores norms/bias)
        let cases = [
            (PaperModel::gpt2_117m(), 117e6),
            (PaperModel::gpt2_345m(), 345e6),
            (PaperModel::llama_7b(), 6.7e9),
            (PaperModel::llama_13b(), 13e9),
        ];
        for (m, expect) in cases {
            let got = m.total_params();
            let ratio = got / expect;
            assert!((0.8..1.25).contains(&ratio), "{}: {got:.3e} vs {expect:.3e}", m.name);
        }
    }

    #[test]
    fn int8_doubles_fp16() {
        let g = GpuSpec::a100_80g();
        assert!((g.int8_ops / g.fp16_flops - 2.0).abs() < 1e-9);
    }
}
