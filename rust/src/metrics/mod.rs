//! Instrumentation: Eq. 12 latency breakdown spans, histograms,
//! throughput counters, rolling SLO windows, and the statistical
//! machinery of §A.4 (paired t-tests, confidence intervals).

mod breakdown;
mod histogram;
mod stats;
mod throughput;
mod window;

pub use breakdown::{Breakdown, Stage};
pub use histogram::Histogram;
pub use stats::{mean_ci95, paired_t_test, percentile, Summary, TTest};
pub use throughput::ThroughputCounter;
pub use window::RollingWindow;
