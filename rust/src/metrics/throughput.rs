//! Token/request throughput + latency percentile tracking for the server.

use std::time::Instant;

/// Running throughput + latency statistics.
#[derive(Debug)]
pub struct ThroughputCounter {
    started: Instant,
    tokens: u64,
    requests: u64,
    latencies_s: Vec<f64>,
}

impl Default for ThroughputCounter {
    fn default() -> Self {
        Self::new()
    }
}

impl ThroughputCounter {
    pub fn new() -> Self {
        ThroughputCounter {
            started: Instant::now(),
            tokens: 0,
            requests: 0,
            latencies_s: Vec::new(),
        }
    }

    pub fn record_tokens(&mut self, n: u64) {
        self.tokens += n;
    }

    pub fn record_request(&mut self, latency_s: f64) {
        self.requests += 1;
        self.latencies_s.push(latency_s);
    }

    pub fn tokens(&self) -> u64 {
        self.tokens
    }

    pub fn requests(&self) -> u64 {
        self.requests
    }

    pub fn elapsed_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    pub fn tokens_per_s(&self) -> f64 {
        self.tokens as f64 / self.elapsed_s().max(1e-9)
    }

    pub fn latency_percentile_s(&self, q: f64) -> f64 {
        super::percentile(&self.latencies_s, q)
    }

    pub fn mean_latency_s(&self) -> f64 {
        if self.latencies_s.is_empty() {
            return 0.0;
        }
        self.latencies_s.iter().sum::<f64>() / self.latencies_s.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_accumulate() {
        let mut c = ThroughputCounter::new();
        c.record_tokens(10);
        c.record_tokens(5);
        c.record_request(0.1);
        c.record_request(0.3);
        assert_eq!(c.tokens(), 15);
        assert_eq!(c.requests(), 2);
        assert!((c.mean_latency_s() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn percentiles_ordered() {
        let mut c = ThroughputCounter::new();
        for i in 1..=100 {
            c.record_request(i as f64);
        }
        assert!(c.latency_percentile_s(0.5) <= c.latency_percentile_s(0.95));
        assert_eq!(c.latency_percentile_s(1.0), 100.0);
    }

    #[test]
    fn empty_safe() {
        let c = ThroughputCounter::new();
        assert_eq!(c.latency_percentile_s(0.5), 0.0);
        assert_eq!(c.mean_latency_s(), 0.0);
    }
}
