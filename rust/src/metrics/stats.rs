//! §A.4 statistics: summaries, 95% CIs, paired t-tests.
//!
//! The t CDF is evaluated through the regularized incomplete beta function
//! (continued fraction) — no external stats crate offline.

/// Mean / std / 95% CI of a sample.
#[derive(Debug, Clone, Copy)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub ci95_lo: f64,
    pub ci95_hi: f64,
}

/// Sample summary with a normal-approximation 95% CI (n >= ~20) or
/// t-quantile for small n.
pub fn mean_ci95(xs: &[f64]) -> Summary {
    let n = xs.len();
    if n == 0 {
        return Summary { n: 0, mean: 0.0, std: 0.0, ci95_lo: 0.0, ci95_hi: 0.0 };
    }
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = if n > 1 {
        xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
    } else {
        0.0
    };
    let std = var.sqrt();
    let tq = t_quantile_975(n.saturating_sub(1).max(1));
    let half = tq * std / (n as f64).sqrt();
    Summary { n, mean, std, ci95_lo: mean - half, ci95_hi: mean + half }
}

/// Sample percentile (nearest-rank on the sorted copy, q in [0, 1]).
/// The single implementation behind every serving-latency p50/p99 the
/// reports and benches quote.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = (q.clamp(0.0, 1.0) * (v.len() - 1) as f64).round() as usize;
    v[idx.min(v.len() - 1)]
}

/// Paired t-test result.
#[derive(Debug, Clone, Copy)]
pub struct TTest {
    pub t: f64,
    pub df: usize,
    pub p_two_sided: f64,
    pub mean_diff: f64,
}

/// Paired t-test over matched samples `a[i]` vs `b[i]`.
pub fn paired_t_test(a: &[f64], b: &[f64]) -> TTest {
    assert_eq!(a.len(), b.len(), "paired test needs matched samples");
    let n = a.len();
    assert!(n >= 2, "need at least 2 pairs");
    let diffs: Vec<f64> = a.iter().zip(b).map(|(x, y)| x - y).collect();
    let mean = diffs.iter().sum::<f64>() / n as f64;
    let var = diffs.iter().map(|d| (d - mean) * (d - mean)).sum::<f64>() / (n - 1) as f64;
    let se = (var / n as f64).sqrt();
    let t = if se > 0.0 { mean / se } else { f64::INFINITY * mean.signum() };
    let df = n - 1;
    let p = if t.is_finite() { 2.0 * (1.0 - t_cdf(t.abs(), df as f64)) } else { 0.0 };
    TTest { t, df, p_two_sided: p.clamp(0.0, 1.0), mean_diff: mean }
}

/// Student-t CDF via the regularized incomplete beta function.
fn t_cdf(t: f64, df: f64) -> f64 {
    let x = df / (df + t * t);
    let p = 0.5 * betainc(0.5 * df, 0.5, x);
    if t > 0.0 {
        1.0 - p
    } else {
        p
    }
}

/// Regularized incomplete beta I_x(a, b) by Lentz continued fraction.
fn betainc(a: f64, b: f64, x: f64) -> f64 {
    if x <= 0.0 {
        return 0.0;
    }
    if x >= 1.0 {
        return 1.0;
    }
    let ln_front = ln_gamma(a + b) - ln_gamma(a) - ln_gamma(b)
        + a * x.ln()
        + b * (1.0 - x).ln();
    let front = ln_front.exp();
    // use the symmetry that converges fast
    if x < (a + 1.0) / (a + b + 2.0) {
        front * beta_cf(a, b, x) / a
    } else {
        1.0 - ln_gamma_based_compl(a, b, x)
    }
}

fn ln_gamma_based_compl(a: f64, b: f64, x: f64) -> f64 {
    let ln_front = ln_gamma(a + b) - ln_gamma(a) - ln_gamma(b)
        + a * x.ln()
        + b * (1.0 - x).ln();
    ln_front.exp() * beta_cf(b, a, 1.0 - x) / b
}

fn beta_cf(a: f64, b: f64, x: f64) -> f64 {
    const MAX_ITER: usize = 200;
    const EPS: f64 = 1e-14;
    let qab = a + b;
    let qap = a + 1.0;
    let qam = a - 1.0;
    let mut c = 1.0;
    let mut d = 1.0 - qab * x / qap;
    if d.abs() < 1e-30 {
        d = 1e-30;
    }
    d = 1.0 / d;
    let mut h = d;
    for m in 1..=MAX_ITER {
        let m = m as f64;
        let m2 = 2.0 * m;
        let aa = m * (b - m) * x / ((qam + m2) * (a + m2));
        d = 1.0 + aa * d;
        if d.abs() < 1e-30 {
            d = 1e-30;
        }
        c = 1.0 + aa / c;
        if c.abs() < 1e-30 {
            c = 1e-30;
        }
        d = 1.0 / d;
        h *= d * c;
        let aa = -(a + m) * (qab + m) * x / ((a + m2) * (qap + m2));
        d = 1.0 + aa * d;
        if d.abs() < 1e-30 {
            d = 1e-30;
        }
        c = 1.0 + aa / c;
        if c.abs() < 1e-30 {
            c = 1e-30;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < EPS {
            break;
        }
    }
    h
}

/// Lanczos log-gamma.
fn ln_gamma(x: f64) -> f64 {
    const G: [f64; 7] = [
        76.18009172947146,
        -86.50532032941677,
        24.01409824083091,
        -1.231739572450155,
        0.1208650973866179e-2,
        -0.5395239384953e-5,
        2.5066282746310005,
    ];
    let mut ser = 1.000000000190015;
    let mut denom = x;
    for (i, g) in G[..6].iter().enumerate() {
        denom = x + 1.0 + i as f64;
        ser += g / denom;
    }
    let _ = denom;
    let tmp = x + 5.5;
    let tmp = tmp - (x + 0.5) * tmp.ln();
    -tmp + (G[6] * ser / x).ln()
}

/// 97.5% t quantile (two-sided 95%), small lookup + normal tail.
fn t_quantile_975(df: usize) -> f64 {
    const TABLE: [f64; 30] = [
        12.706, 4.303, 3.182, 2.776, 2.571, 2.447, 2.365, 2.306, 2.262, 2.228, 2.201, 2.179,
        2.160, 2.145, 2.131, 2.120, 2.110, 2.101, 2.093, 2.086, 2.080, 2.074, 2.069, 2.064,
        2.060, 2.056, 2.052, 2.048, 2.045, 2.042,
    ];
    if df == 0 {
        return f64::INFINITY;
    }
    if df <= 30 {
        TABLE[df - 1]
    } else {
        1.96 + 2.4 / df as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::XorShift64Star;

    #[test]
    fn ln_gamma_known_values() {
        assert!((ln_gamma(1.0)).abs() < 1e-10);
        assert!((ln_gamma(5.0) - 24f64.ln()).abs() < 1e-9); // gamma(5)=4!
        assert!((ln_gamma(0.5) - std::f64::consts::PI.sqrt().ln()).abs() < 1e-9);
    }

    #[test]
    fn t_cdf_symmetry_and_limits() {
        assert!((t_cdf(0.0, 10.0) - 0.5).abs() < 1e-9);
        assert!(t_cdf(10.0, 10.0) > 0.999);
        assert!(t_cdf(-10.0, 10.0) < 0.001);
        // t(df=inf-ish) at 1.96 ~ 0.975
        assert!((t_cdf(1.96, 1000.0) - 0.975).abs() < 0.002);
    }

    #[test]
    fn ci_contains_true_mean_usually() {
        let mut rng = XorShift64Star::new(3);
        let mut hits = 0;
        for _ in 0..100 {
            let xs: Vec<f64> = (0..30).map(|_| 5.0 + rng.next_normal()).collect();
            let s = mean_ci95(&xs);
            if s.ci95_lo <= 5.0 && 5.0 <= s.ci95_hi {
                hits += 1;
            }
        }
        assert!(hits >= 85, "CI coverage {hits}/100");
    }

    #[test]
    fn paired_t_detects_real_difference() {
        let mut rng = XorShift64Star::new(4);
        let a: Vec<f64> = (0..40).map(|_| 10.0 + rng.next_normal() * 0.5).collect();
        let b: Vec<f64> = a.iter().map(|x| x - 1.0 + rng.next_normal() * 0.1).collect();
        let t = paired_t_test(&a, &b);
        assert!(t.p_two_sided < 0.01, "p={}", t.p_two_sided);
        assert!(t.mean_diff > 0.5);
    }

    #[test]
    fn paired_t_accepts_null() {
        let mut rng = XorShift64Star::new(5);
        let a: Vec<f64> = (0..40).map(|_| rng.next_normal()).collect();
        let b: Vec<f64> = (0..40).map(|_| rng.next_normal()).collect();
        let t = paired_t_test(&a, &b);
        assert!(t.p_two_sided > 0.01, "p={}", t.p_two_sided);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 100.0);
        assert_eq!(percentile(&xs, 0.5), 51.0);
        assert!(percentile(&xs, 0.99) >= 98.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
        // unsorted input is handled
        assert_eq!(percentile(&[3.0, 1.0, 2.0], 1.0), 3.0);
    }

    #[test]
    fn empty_and_single_are_safe() {
        let s = mean_ci95(&[]);
        assert_eq!(s.n, 0);
        let s1 = mean_ci95(&[3.0]);
        assert_eq!(s1.mean, 3.0);
    }
}
