//! Rolling observation window — the SLO tracker's memory.
//!
//! A bounded FIFO of recent samples (per-shard completion latencies on
//! the serving path); the admission gate reads percentiles off it to
//! decide whether a shard is currently breaching its latency target.
//! Bounded so the signal tracks *current* pressure: old completions age
//! out instead of diluting a breach (or a recovery) forever.
//!
//! Samples are timestamped at insertion. Count-based eviction alone has
//! a blind spot: the window only ever records *served* completions, so
//! under a sustained full-shed interval nothing new arrives, the buffer
//! holds its breach-time samples indefinitely, and a trailing gate
//! reading it freezes its last verdict. [`RollingWindow::expire_older_than`]
//! closes that hole — callers drop samples past a staleness horizon
//! before reading, so a shard with zero recent completions re-evaluates
//! (an empty window never breaches) instead of shedding forever.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use super::stats::percentile;

/// Fixed-capacity rolling window of timestamped f64 samples.
#[derive(Debug, Clone)]
pub struct RollingWindow {
    cap: usize,
    buf: VecDeque<(Instant, f64)>,
}

impl RollingWindow {
    pub fn new(cap: usize) -> Self {
        assert!(cap >= 1, "window capacity must be >= 1");
        RollingWindow { cap, buf: VecDeque::with_capacity(cap) }
    }

    /// Append a sample stamped `now`, evicting the oldest once full.
    pub fn push(&mut self, v: f64) {
        self.push_at(Instant::now(), v);
    }

    /// Append a sample with an explicit timestamp (tests; replay).
    /// Samples are assumed to arrive in time order — eviction and
    /// expiry both pop from the front.
    pub fn push_at(&mut self, at: Instant, v: f64) {
        if self.buf.len() == self.cap {
            self.buf.pop_front();
        }
        self.buf.push_back((at, v));
    }

    /// Drop samples older than `age`. Returns how many were expired.
    /// A gate calling this before every read cannot freeze on a stale
    /// verdict: once the last breach-time sample passes the horizon the
    /// window reads empty (never a breach) and admission resumes.
    pub fn expire_older_than(&mut self, age: Duration) -> usize {
        let Some(cutoff) = Instant::now().checked_sub(age) else {
            return 0;
        };
        let mut expired = 0;
        while self.buf.front().is_some_and(|(t, _)| *t < cutoff) {
            self.buf.pop_front();
            expired += 1;
        }
        expired
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Percentile (q in [0, 1]) over the window; 0.0 when empty — an
    /// empty window never reads as a breach, so cold shards admit.
    pub fn percentile(&self, q: f64) -> f64 {
        let xs: Vec<f64> = self.buf.iter().map(|(_, v)| *v).collect();
        percentile(&xs, q)
    }

    pub fn mean(&self) -> f64 {
        if self.buf.is_empty() {
            return 0.0;
        }
        self.buf.iter().map(|(_, v)| *v).sum::<f64>() / self.buf.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_window_reads_zero() {
        let w = RollingWindow::new(4);
        assert!(w.is_empty());
        assert_eq!(w.percentile(0.99), 0.0);
        assert_eq!(w.mean(), 0.0);
    }

    #[test]
    fn pushes_and_percentiles() {
        let mut w = RollingWindow::new(8);
        for v in [1.0, 2.0, 3.0, 4.0] {
            w.push(v);
        }
        assert_eq!(w.len(), 4);
        assert_eq!(w.percentile(0.0), 1.0);
        assert_eq!(w.percentile(1.0), 4.0);
        assert_eq!(w.mean(), 2.5);
    }

    #[test]
    fn evicts_oldest_at_capacity() {
        let mut w = RollingWindow::new(3);
        for v in [10.0, 20.0, 30.0, 40.0] {
            w.push(v);
        }
        assert_eq!(w.len(), 3);
        // 10.0 aged out: the window now spans [20, 40]
        assert_eq!(w.percentile(0.0), 20.0);
        assert_eq!(w.percentile(1.0), 40.0);
    }

    #[test]
    fn recovery_is_visible_once_breach_ages_out() {
        let mut w = RollingWindow::new(4);
        w.push(100.0); // one slow completion
        for _ in 0..4 {
            w.push(1.0);
        }
        // the breach sample has been evicted; p99 reflects current load
        assert_eq!(w.percentile(0.99), 1.0);
    }

    #[test]
    fn stale_samples_expire_by_age() {
        let mut w = RollingWindow::new(8);
        let now = Instant::now();
        // breach-time samples from 10 s ago, one fresh sample
        for _ in 0..3 {
            w.push_at(now - Duration::from_secs(10), 500.0);
        }
        w.push_at(now, 1.0);
        assert_eq!(w.len(), 4);
        assert!(w.percentile(0.99) > 100.0, "stale breach still dominates");
        let expired = w.expire_older_than(Duration::from_secs(5));
        assert_eq!(expired, 3);
        assert_eq!(w.len(), 1);
        assert_eq!(w.percentile(0.99), 1.0, "fresh sample survives");
        // expiring everything leaves an empty (never-breaching) window
        let expired = w.expire_older_than(Duration::ZERO);
        assert_eq!(expired, 1);
        assert!(w.is_empty());
        assert_eq!(w.percentile(0.99), 0.0);
    }

    #[test]
    fn expire_on_fresh_window_is_a_noop() {
        let mut w = RollingWindow::new(4);
        w.push(2.0);
        w.push(3.0);
        assert_eq!(w.expire_older_than(Duration::from_secs(60)), 0);
        assert_eq!(w.len(), 2);
    }
}
