//! Rolling observation window — the SLO tracker's memory.
//!
//! A bounded FIFO of recent samples (per-shard completion latencies on
//! the serving path); the admission gate reads percentiles off it to
//! decide whether a shard is currently breaching its latency target.
//! Bounded so the signal tracks *current* pressure: old completions age
//! out instead of diluting a breach (or a recovery) forever.

use std::collections::VecDeque;

use super::stats::percentile;

/// Fixed-capacity rolling window of f64 samples.
#[derive(Debug, Clone)]
pub struct RollingWindow {
    cap: usize,
    buf: VecDeque<f64>,
}

impl RollingWindow {
    pub fn new(cap: usize) -> Self {
        assert!(cap >= 1, "window capacity must be >= 1");
        RollingWindow { cap, buf: VecDeque::with_capacity(cap) }
    }

    /// Append a sample, evicting the oldest once full.
    pub fn push(&mut self, v: f64) {
        if self.buf.len() == self.cap {
            self.buf.pop_front();
        }
        self.buf.push_back(v);
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Percentile (q in [0, 1]) over the window; 0.0 when empty — an
    /// empty window never reads as a breach, so cold shards admit.
    pub fn percentile(&self, q: f64) -> f64 {
        let xs: Vec<f64> = self.buf.iter().copied().collect();
        percentile(&xs, q)
    }

    pub fn mean(&self) -> f64 {
        if self.buf.is_empty() {
            return 0.0;
        }
        self.buf.iter().sum::<f64>() / self.buf.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_window_reads_zero() {
        let w = RollingWindow::new(4);
        assert!(w.is_empty());
        assert_eq!(w.percentile(0.99), 0.0);
        assert_eq!(w.mean(), 0.0);
    }

    #[test]
    fn pushes_and_percentiles() {
        let mut w = RollingWindow::new(8);
        for v in [1.0, 2.0, 3.0, 4.0] {
            w.push(v);
        }
        assert_eq!(w.len(), 4);
        assert_eq!(w.percentile(0.0), 1.0);
        assert_eq!(w.percentile(1.0), 4.0);
        assert_eq!(w.mean(), 2.5);
    }

    #[test]
    fn evicts_oldest_at_capacity() {
        let mut w = RollingWindow::new(3);
        for v in [10.0, 20.0, 30.0, 40.0] {
            w.push(v);
        }
        assert_eq!(w.len(), 3);
        // 10.0 aged out: the window now spans [20, 40]
        assert_eq!(w.percentile(0.0), 20.0);
        assert_eq!(w.percentile(1.0), 40.0);
    }

    #[test]
    fn recovery_is_visible_once_breach_ages_out() {
        let mut w = RollingWindow::new(4);
        w.push(100.0); // one slow completion
        for _ in 0..4 {
            w.push(1.0);
        }
        // the breach sample has been evicted; p99 reflects current load
        assert_eq!(w.percentile(0.99), 1.0);
    }
}
