//! Eq. 12 stage accounting — the CPU analogue of the paper's NVTX ranges.

use std::time::Instant;

/// The five components of Eq. 12.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Stage {
    Load,
    Quant,
    Gemm,
    Comm,
    Sync,
}

impl Stage {
    pub const ALL: [Stage; 5] = [Stage::Load, Stage::Quant, Stage::Gemm, Stage::Comm, Stage::Sync];

    pub fn name(self) -> &'static str {
        match self {
            Stage::Load => "load",
            Stage::Quant => "quant",
            Stage::Gemm => "gemm",
            Stage::Comm => "comm",
            Stage::Sync => "sync",
        }
    }

    fn idx(self) -> usize {
        match self {
            Stage::Load => 0,
            Stage::Quant => 1,
            Stage::Gemm => 2,
            Stage::Comm => 3,
            Stage::Sync => 4,
        }
    }
}

/// Accumulated per-stage time + counts.
#[derive(Debug, Clone, Default)]
pub struct Breakdown {
    seconds: [f64; 5],
    counts: [u64; 5],
}

impl Breakdown {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under a stage.
    pub fn span<T>(&mut self, stage: Stage, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add(stage, t0.elapsed().as_secs_f64());
        out
    }

    pub fn add(&mut self, stage: Stage, seconds: f64) {
        self.seconds[stage.idx()] += seconds;
        self.counts[stage.idx()] += 1;
    }

    pub fn seconds(&self, stage: Stage) -> f64 {
        self.seconds[stage.idx()]
    }

    pub fn count(&self, stage: Stage) -> u64 {
        self.counts[stage.idx()]
    }

    pub fn total_s(&self) -> f64 {
        self.seconds.iter().sum()
    }

    /// Proportional contribution of each stage (Fig. 3 series).
    pub fn proportions(&self) -> [f64; 5] {
        let total = self.total_s().max(1e-12);
        let mut out = [0f64; 5];
        for (o, s) in out.iter_mut().zip(self.seconds) {
            *o = s / total;
        }
        out
    }

    pub fn merge(&mut self, other: &Breakdown) {
        for i in 0..5 {
            self.seconds[i] += other.seconds[i];
            self.counts[i] += other.counts[i];
        }
    }

    /// ms per stage, scaled by 1/div (e.g. per layer, per step).
    pub fn as_ms_per(&self, div: f64) -> [f64; 5] {
        let mut out = [0f64; 5];
        for (o, s) in out.iter_mut().zip(self.seconds) {
            *o = s * 1e3 / div.max(1e-12);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_accumulates() {
        let mut b = Breakdown::new();
        let v = b.span(Stage::Gemm, || {
            std::thread::sleep(std::time::Duration::from_millis(2));
            42
        });
        assert_eq!(v, 42);
        assert!(b.seconds(Stage::Gemm) >= 0.002);
        assert_eq!(b.count(Stage::Gemm), 1);
        assert_eq!(b.count(Stage::Load), 0);
    }

    #[test]
    fn proportions_sum_to_one() {
        let mut b = Breakdown::new();
        b.add(Stage::Load, 1.0);
        b.add(Stage::Gemm, 3.0);
        let p = b.proportions();
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!((p[Stage::Gemm.idx()] - 0.75).abs() < 1e-9);
    }

    #[test]
    fn merge_adds() {
        let mut a = Breakdown::new();
        a.add(Stage::Comm, 1.0);
        let mut b = Breakdown::new();
        b.add(Stage::Comm, 2.0);
        a.merge(&b);
        assert_eq!(a.seconds(Stage::Comm), 3.0);
        assert_eq!(a.count(Stage::Comm), 2);
    }
}
