//! Fixed-range histograms — weight-distribution figures (Fig. 1) and
//! latency distributions.

/// Equal-width histogram over [lo, hi].
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    bins: Vec<u64>,
    count: u64,
    underflow: u64,
    overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, n_bins: usize) -> Self {
        assert!(hi > lo && n_bins > 0);
        Histogram { lo, hi, bins: vec![0; n_bins], count: 0, underflow: 0, overflow: 0 }
    }

    /// Histogram spanning the data's own min/max.
    pub fn from_data(data: &[f32], n_bins: usize) -> Self {
        let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
        for v in data {
            lo = lo.min(*v as f64);
            hi = hi.max(*v as f64);
        }
        if !lo.is_finite() || lo == hi {
            lo = -1.0;
            hi = 1.0;
        }
        let mut h = Histogram::new(lo, hi + (hi - lo) * 1e-9, n_bins);
        for v in data {
            h.record(*v as f64);
        }
        h
    }

    pub fn record(&mut self, v: f64) {
        self.count += 1;
        if v < self.lo {
            self.underflow += 1;
        } else if v >= self.hi {
            self.overflow += 1;
        } else {
            let n = self.bins.len();
            let idx = ((v - self.lo) / (self.hi - self.lo) * n as f64) as usize;
            self.bins[idx.min(n - 1)] += 1;
        }
    }

    pub fn counts(&self) -> &[u64] {
        &self.bins
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Bin centers for plotting.
    pub fn centers(&self) -> Vec<f64> {
        let w = (self.hi - self.lo) / self.bins.len() as f64;
        (0..self.bins.len()).map(|i| self.lo + w * (i as f64 + 0.5)).collect()
    }

    /// Normalized densities (sum to 1 over in-range mass).
    pub fn densities(&self) -> Vec<f64> {
        let in_range: u64 = self.bins.iter().sum();
        let denom = in_range.max(1) as f64;
        self.bins.iter().map(|c| *c as f64 / denom).collect()
    }

    /// Fraction of mass at the two outermost bins — the paper's
    /// "saturation near representational boundaries" diagnostic (Fig. 1).
    pub fn boundary_mass(&self) -> f64 {
        let in_range: u64 = self.bins.iter().sum();
        if in_range == 0 {
            return 0.0;
        }
        (self.bins[0] + self.bins[self.bins.len() - 1]) as f64 / in_range as f64
    }

    /// Shannon entropy over bins (nats) — distribution-shape feature.
    pub fn entropy(&self) -> f64 {
        self.densities()
            .iter()
            .filter(|p| **p > 0.0)
            .map(|p| -p * p.ln())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_into_bins() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.record(i as f64 + 0.5);
        }
        assert!(h.counts().iter().all(|c| *c == 1));
        assert_eq!(h.count(), 10);
    }

    #[test]
    fn overflow_tracked() {
        let mut h = Histogram::new(0.0, 1.0, 4);
        h.record(-5.0);
        h.record(5.0);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
        assert_eq!(h.counts().iter().sum::<u64>(), 0);
    }

    #[test]
    fn from_data_spans_range() {
        let data = vec![-2.0f32, 0.0, 2.0];
        let h = Histogram::from_data(&data, 4);
        assert_eq!(h.count(), 3);
        assert_eq!(h.underflow, 0);
        assert_eq!(h.overflow, 0);
    }

    #[test]
    fn boundary_mass_detects_saturation() {
        // clipped (saturated) data piles at the edges
        let clipped: Vec<f32> = (0..100)
            .map(|i| ((i as f32 - 50.0) * 10.0).clamp(-1.0, 1.0))
            .collect();
        let h = Histogram::from_data(&clipped, 16);
        assert!(h.boundary_mass() > 0.8);
        let uniform: Vec<f32> = (0..100).map(|i| i as f32 / 100.0).collect();
        let h2 = Histogram::from_data(&uniform, 16);
        assert!(h2.boundary_mass() < 0.2);
    }

    #[test]
    fn entropy_orders_shapes() {
        let uniform: Vec<f32> = (0..1000).map(|i| (i % 100) as f32).collect();
        let peaked = vec![0f32; 1000];
        let hu = Histogram::from_data(&uniform, 32);
        let hp = Histogram::from_data(&peaked, 32);
        assert!(hu.entropy() > hp.entropy());
    }
}
