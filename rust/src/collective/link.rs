//! Alpha-beta link model: transfer time = alpha + bytes / beta — plus
//! the seeded per-delivery corruption schedule ([`LinkFaults`]) the
//! checksummed quantized wire is exercised against.

/// Per-hop link characteristics.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkModel {
    /// per-message latency (seconds)
    pub alpha_s: f64,
    /// bandwidth (bytes / second)
    pub beta_bps: f64,
}

impl LinkModel {
    /// NVLink 3 + RDMA ring hop (A100 SXM: ~600 GB/s bidirectional,
    /// sub-10us launch+propagation latency).
    pub fn nvlink() -> Self {
        LinkModel { alpha_s: 5e-6, beta_bps: 600e9 }
    }

    /// InfiniBand HDR hop (~25 GB/s per direction, ~2us + software stack).
    pub fn infiniband() -> Self {
        LinkModel { alpha_s: 8e-6, beta_bps: 25e9 }
    }

    /// TCP fallback (paper: edge server / CPU-GPU hybrid): ~10 GbE with
    /// kernel networking latency.
    pub fn tcp() -> Self {
        LinkModel { alpha_s: 60e-6, beta_bps: 1.25e9 }
    }

    /// Time for one hop carrying `bytes`.
    pub fn hop_time(&self, bytes: usize) -> f64 {
        self.alpha_s + bytes as f64 / self.beta_bps
    }

    /// Bandwidth-delay product (bytes): how much data fits "in flight"
    /// on this link. The natural wire-chunk size — chunks much smaller
    /// than the BDP waste the pipe on per-message latency, much larger
    /// ones stop overlapping encode with flight (`ops::adaptive_chunk`
    /// derives the quantized-wire chunk from this).
    pub fn bdp_bytes(&self) -> f64 {
        self.alpha_s * self.beta_bps
    }

    /// Ring all-gather of `bytes` total payload across `n` ranks:
    /// (n-1) steps, each moving bytes/n per hop.
    pub fn ring_allgather_time(&self, bytes: usize, n: usize) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        (n - 1) as f64 * self.hop_time(bytes / n)
    }

    /// Ring all-reduce: reduce-scatter + all-gather = 2(n-1) steps of
    /// bytes/n.
    pub fn ring_allreduce_time(&self, bytes: usize, n: usize) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        2.0 * (n - 1) as f64 * self.hop_time(bytes / n)
    }

    /// Ring all-gather whose per-rank contribution is split into `chunks`
    /// pipelined messages (the quantized-wire path): each of the (n-1)
    /// steps pays one launch latency and streams its chunk train
    /// back-to-back over the established channel; the extra `(c-1)`
    /// fill term is the pipeline depth (first chunk in flight while the
    /// rest are still being produced).
    pub fn ring_allgather_chunked_time(&self, bytes: usize, n: usize, chunks: usize) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        let c = chunks.max(1) as f64;
        let per_rank = bytes as f64 / n as f64;
        (n - 1) as f64 * (self.alpha_s + per_rank / self.beta_bps)
            + (c - 1.0) * per_rank / c / self.beta_bps
    }

    /// Chunked ring all-reduce: reduce-scatter + all-gather, each step
    /// carrying `chunks` messages of the per-rank contribution.
    pub fn ring_allreduce_chunked_time(&self, bytes: usize, n: usize, chunks: usize) -> f64 {
        2.0 * self.ring_allgather_chunked_time(bytes, n, chunks)
    }

    /// Binomial-tree broadcast: ceil(log2 n) hops of the full payload.
    pub fn broadcast_time(&self, bytes: usize, n: usize) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        (n as f64).log2().ceil() * self.hop_time(bytes)
    }
}

/// Seeded corruption schedule for one rank's incoming ring link: an
/// independent splitmix64 stream drawn once per chunk *delivery
/// attempt* (a retransmission draws again), so a faulty-link run
/// replays bit-identically under the same seed. Built from a
/// `FaultPlan` in the coordinator (`FaultPlan::link_faults(rank)`
/// folds the rank into the plan seed); the ring transport consumes it
/// at the receive endpoint, where the per-chunk checksum — not this
/// schedule — is what detects the bad delivery.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LinkFaults {
    /// corruption probability per delivery attempt, as a fixed 2^-53
    /// threshold against the top 53 bits of each draw
    threshold: u64,
    state: u64,
}

impl LinkFaults {
    pub fn new(p: f64, seed: u64) -> Self {
        let threshold = (p.clamp(0.0, 1.0) * (1u64 << 53) as f64) as u64;
        LinkFaults { threshold, state: seed }
    }

    fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Draw the next delivery attempt: true = this chunk arrives
    /// corrupted on the wire.
    pub fn corrupt_next(&mut self) -> bool {
        (self.next_u64() >> 11) < self.threshold
    }

    /// Byte index to flip in a corrupted `len`-byte delivery.
    pub fn victim_byte(&mut self, len: usize) -> usize {
        (self.next_u64() % len.max(1) as u64) as usize
    }
}

/// Accumulated accounting for one rank's collective traffic.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CommStats {
    pub ops: u64,
    pub bytes_sent: u64,
    /// chunk deliveries that failed their checksum and were re-pulled
    /// from the sender's refcounted original (injected link faults)
    pub retransmits: u64,
    /// simulated wire time (seconds) under the link model
    pub sim_time_s: f64,
    /// wall-clock spent inside collective calls (seconds)
    pub wall_time_s: f64,
}

impl CommStats {
    pub fn merge(&mut self, other: &CommStats) {
        self.ops += other.ops;
        self.bytes_sent += other.bytes_sent;
        self.retransmits += other.retransmits;
        self.sim_time_s += other.sim_time_s;
        self.wall_time_s += other.wall_time_s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hop_time_scales_with_bytes() {
        let l = LinkModel::nvlink();
        assert!(l.hop_time(1 << 30) > l.hop_time(1 << 20));
        assert!((l.hop_time(0) - 5e-6).abs() < 1e-12);
    }

    #[test]
    fn tcp_slower_than_nvlink() {
        let b = 1 << 24;
        assert!(LinkModel::tcp().hop_time(b) > LinkModel::nvlink().hop_time(b) * 100.0);
    }

    #[test]
    fn bdp_orders_the_transport_tiers() {
        // nvlink ~3 MB, infiniband ~200 KB, tcp ~75 KB in flight
        let (nv, ib, tcp) = (
            LinkModel::nvlink().bdp_bytes(),
            LinkModel::infiniband().bdp_bytes(),
            LinkModel::tcp().bdp_bytes(),
        );
        assert!(nv > ib && ib > tcp, "nv {nv} ib {ib} tcp {tcp}");
        assert!((nv - 3e6).abs() < 1e3);
        assert!((ib - 200e3).abs() < 1e2);
        assert!((tcp - 75e3).abs() < 1e2);
    }

    #[test]
    fn ring_allgather_time_formula() {
        let l = LinkModel { alpha_s: 1e-6, beta_bps: 1e9 };
        // 8 ranks, 8 MB total: 7 steps of 1 MB
        let t = l.ring_allgather_time(8 << 20, 8);
        let expect = 7.0 * (1e-6 + (1 << 20) as f64 / 1e9);
        assert!((t - expect).abs() / expect < 1e-9);
    }

    #[test]
    fn single_rank_is_free() {
        let l = LinkModel::nvlink();
        assert_eq!(l.ring_allgather_time(1024, 1), 0.0);
        assert_eq!(l.ring_allreduce_time(1024, 1), 0.0);
        assert_eq!(l.broadcast_time(1024, 1), 0.0);
    }

    #[test]
    fn chunked_time_reduces_to_plain_at_one_chunk() {
        let l = LinkModel::nvlink();
        let (b, n) = (1 << 20, 4);
        let plain = l.ring_allgather_time(b, n);
        let one = l.ring_allgather_chunked_time(b, n, 1);
        assert!((plain - one).abs() < 1e-15);
        // more chunks -> same wire bytes, plus the pipeline-fill cost
        assert!(l.ring_allgather_chunked_time(b, n, 16) > plain);
        assert_eq!(l.ring_allgather_chunked_time(b, 1, 16), 0.0);
    }

    #[test]
    fn allreduce_twice_allgather() {
        let l = LinkModel::nvlink();
        let (ar, ag) = (l.ring_allreduce_time(1 << 20, 4), l.ring_allgather_time(1 << 20, 4));
        assert!((ar - 2.0 * ag).abs() < 1e-12);
    }

    #[test]
    fn link_faults_extremes_and_replay() {
        let mut never = LinkFaults::new(0.0, 42);
        assert!((0..256).all(|_| !never.corrupt_next()), "p=0 must never corrupt");
        let mut always = LinkFaults::new(1.0, 42);
        assert!((0..256).all(|_| always.corrupt_next()), "p=1 must always corrupt");
        let (mut a, mut b) = (LinkFaults::new(0.3, 7), LinkFaults::new(0.3, 7));
        let da: Vec<bool> = (0..512).map(|_| a.corrupt_next()).collect();
        let db: Vec<bool> = (0..512).map(|_| b.corrupt_next()).collect();
        assert_eq!(da, db, "same seed replays identically");
        let hits = da.iter().filter(|c| **c).count();
        assert!((100..220).contains(&hits), "p=0.3 over 512 draws, got {hits}");
        assert!(LinkFaults::new(0.5, 1).victim_byte(16) < 16);
        assert_eq!(LinkFaults::new(0.5, 1).victim_byte(0), 0, "empty buffer is safe");
    }
}
