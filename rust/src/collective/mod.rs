//! NCCL-style collectives over simulated interconnects (paper §3.3).
//!
//! Real message passing — each rank is a thread endpoint exchanging data
//! over std::sync::mpsc ring channels — combined with an analytic link
//! model that accounts the *simulated* wire time of each operation
//! (alpha-beta model per transport). The coordinator's scale synchronizer
//! runs on these primitives (Eqs. 7-8); the latency-breakdown experiments
//! read the simulated T_comm.
//!
//! Transports mirror the paper's deployment modes: NVLink/RDMA ring for
//! single-node multi-GPU, TCP fallback for edge / multi-node.

mod link;
mod ops;
mod topology;

pub use link::{CommStats, LinkModel};
pub use ops::{Collective, OpError};
pub use topology::{Topology, Transport};
