//! NCCL-style collectives over simulated interconnects (paper §3.3).
//!
//! Real message passing — each rank is a thread endpoint exchanging data
//! over std::sync::mpsc ring channels — combined with an analytic link
//! model that accounts the *simulated* wire time of each operation
//! (alpha-beta model per transport). The coordinator's scale synchronizer
//! runs on these primitives (Eqs. 7-8); the latency-breakdown experiments
//! read the simulated T_comm.
//!
//! Transports mirror the paper's deployment modes: NVLink/RDMA ring for
//! single-node multi-GPU, TCP fallback for edge / multi-node.
//!
//! The `_q` ops quantize at the ring endpoints (per-chunk token scales,
//! bit-packed sub-byte codes) so the wire itself is low-bit; byte and
//! sim-time accounting reflect the quantized payload sizes.

mod link;
mod ops;
mod topology;

pub use link::{CommStats, LinkFaults, LinkModel};
pub use ops::{
    adaptive_chunk, transfer_quant_pages, Collective, OpError, CHUNK_RETRY_LIMIT,
    MAX_QUANT_CHUNK, QUANT_CHUNK,
};
pub use topology::{Topology, Transport};

/// Spawn a `world`-rank ring, all-gather `len` synthetic f32 per rank
/// over the given wire (`bits == 32` = raw f32, otherwise the quantized
/// wire), and return rank 0's accumulated stats. Wire-byte and sim-time
/// accounting depend only on the shape, not the values — this is the
/// shared harness behind the wire-ratio bench, example, and acceptance
/// test.
pub fn wire_allgather_stats(
    world: usize,
    len: usize,
    bits: u32,
    transport: Transport,
) -> CommStats {
    let ring = Collective::ring(Topology::new(world, transport));
    let handles: Vec<_> = ring
        .into_iter()
        .map(|mut c| {
            std::thread::spawn(move || {
                let local: Vec<f32> =
                    (0..len).map(|i| ((i + c.rank()) as f32 * 0.37).sin()).collect();
                if bits == 32 {
                    c.all_gather(local).unwrap();
                } else {
                    c.all_gather_quant(&local, bits).unwrap();
                }
                c.stats()
            })
        })
        .collect();
    let stats: Vec<CommStats> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    stats[0]
}

/// One row of the wire-format comparison table.
pub struct WireFormatRow {
    /// 32 = raw f32, otherwise the quantized code width
    pub bits: u32,
    /// display label ("f32", "q8 packed", ...)
    pub label: String,
    pub bytes_per_rank: u64,
    pub ratio_vs_f32: f64,
    pub sim_time_s: f64,
}

/// Sweep the wire formats (f32 / q8 / packed q4 / packed q2) for one
/// all-gather shape and return a comparison row per format — the shared
/// data source behind the wire-ratio bench and example.
pub fn wire_format_rows(world: usize, len: usize, transport: Transport) -> Vec<WireFormatRow> {
    let mut rows = Vec::new();
    let mut f32_bytes = 0u64;
    for bits in [32u32, 8, 4, 2] {
        let stats = wire_allgather_stats(world, len, bits, transport);
        if bits == 32 {
            f32_bytes = stats.bytes_sent;
        }
        let label = if bits == 32 {
            "f32".to_string()
        } else {
            format!("q{bits} packed")
        };
        // a 1-rank ring sends nothing; report ratio 1.0 instead of 0/0
        let ratio_vs_f32 = if f32_bytes == 0 {
            1.0
        } else {
            stats.bytes_sent as f64 / f32_bytes as f64
        };
        rows.push(WireFormatRow {
            bits,
            label,
            bytes_per_rank: stats.bytes_sent,
            ratio_vs_f32,
            sim_time_s: stats.sim_time_s,
        });
    }
    rows
}
