//! Ring collectives: real message passing between rank threads.
//!
//! Each rank owns a `Collective` endpoint. Operations are SPMD: every rank
//! must call the same op in the same order (an op-sequence counter guards
//! against divergence — Thm. 4 consistency depends on it). Payloads travel
//! over mpsc channels to the next rank in the ring; simulated wire time is
//! accounted against the topology's link model.

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

use thiserror::Error;

use super::{CommStats, LinkModel, Topology};

const RECV_TIMEOUT: Duration = Duration::from_secs(30);

#[derive(Debug, Error)]
pub enum OpError {
    #[error("rank {rank}: op sequence mismatch: got {got}, expected {expected} — ranks diverged")]
    SequenceMismatch { rank: usize, got: u64, expected: u64 },
    #[error("rank {rank}: recv timeout/disconnect in {op}")]
    Recv { rank: usize, op: &'static str },
}

struct Packet {
    seq: u64,
    chunk_id: usize,
    data: Vec<f32>,
}

/// One rank's endpoint in the ring.
pub struct Collective {
    rank: usize,
    world: usize,
    link: LinkModel,
    to_next: Sender<Packet>,
    from_prev: Receiver<Packet>,
    seq: u64,
    stats: CommStats,
}

impl Collective {
    /// Build a ring of `world` endpoints (move each into its rank thread).
    pub fn ring(topo: Topology) -> Vec<Collective> {
        let n = topo.world;
        let mut senders = Vec::with_capacity(n);
        let mut receivers = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = channel();
            senders.push(tx);
            receivers.push(rx);
        }
        // rank i sends to rank (i+1) % n, receives from (i-1+n) % n.
        // receivers[j] belongs to the rank that *receives from* channel j's
        // sender; channel j carries i -> i+1, so receiver j goes to rank j+1.
        let mut out: Vec<Collective> = Vec::with_capacity(n);
        let mut rx_iter: Vec<Option<Receiver<Packet>>> =
            receivers.into_iter().map(Some).collect();
        for rank in 0..n {
            let to_next = senders[(rank + 1) % n].clone();
            let from_prev = rx_iter[rank].take().unwrap();
            out.push(Collective {
                rank,
                world: n,
                link: topo.link(),
                to_next,
                from_prev,
                seq: 0,
                stats: CommStats::default(),
            });
        }
        out
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn world(&self) -> usize {
        self.world
    }

    pub fn stats(&self) -> CommStats {
        self.stats
    }

    fn send(&mut self, chunk_id: usize, data: Vec<f32>) {
        self.stats.bytes_sent += (data.len() * 4) as u64;
        let _ = self.to_next.send(Packet { seq: self.seq, chunk_id, data });
    }

    fn recv(&mut self, op: &'static str) -> Result<(usize, Vec<f32>), OpError> {
        match self.from_prev.recv_timeout(RECV_TIMEOUT) {
            Ok(p) => {
                if p.seq != self.seq {
                    return Err(OpError::SequenceMismatch {
                        rank: self.rank,
                        got: p.seq,
                        expected: self.seq,
                    });
                }
                Ok((p.chunk_id, p.data))
            }
            Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => {
                Err(OpError::Recv { rank: self.rank, op })
            }
        }
    }

    /// Ring all-gather (Eq. 7): every rank contributes `local`, returns all
    /// contributions indexed by rank. (world-1) steps, each forwarding the
    /// chunk received in the previous step.
    pub fn all_gather(&mut self, local: Vec<f32>) -> Result<Vec<Vec<f32>>, OpError> {
        let t0 = Instant::now();
        self.seq += 1;
        let n = self.world;
        let mut slots: Vec<Option<Vec<f32>>> = vec![None; n];
        let total_bytes = local.len() * 4 * n;
        slots[self.rank] = Some(local.clone());
        let mut carry = (self.rank, local);
        for _ in 0..n.saturating_sub(1) {
            self.send(carry.0, carry.1);
            let (cid, data) = self.recv("all_gather")?;
            slots[cid] = Some(data.clone());
            carry = (cid, data);
        }
        self.stats.ops += 1;
        self.stats.sim_time_s += self.link.ring_allgather_time(total_bytes, n);
        self.stats.wall_time_s += t0.elapsed().as_secs_f64();
        Ok(slots.into_iter().map(|s| s.expect("ring hole")).collect())
    }

    /// All-reduce (sum): all-gather + local reduction (metadata-sized
    /// payloads make the bandwidth-optimal variant unnecessary; the wire
    /// time is still accounted with the 2(n-1)-step ring formula).
    pub fn all_reduce_sum(&mut self, local: Vec<f32>) -> Result<Vec<f32>, OpError> {
        let len = local.len();
        let bytes = len * 4 * self.world;
        let parts = self.all_gather(local)?;
        // replace the all-gather accounting with all-reduce accounting
        self.stats.sim_time_s -= self.link.ring_allgather_time(bytes, self.world);
        self.stats.sim_time_s += self.link.ring_allreduce_time(bytes, self.world);
        let mut out = vec![0f32; len];
        for p in parts {
            for (o, v) in out.iter_mut().zip(p) {
                *o += v;
            }
        }
        Ok(out)
    }

    /// Element-wise max reduction — the scale synchronizer's conservative
    /// merge rule for per-shard deltas.
    pub fn all_reduce_max(&mut self, local: Vec<f32>) -> Result<Vec<f32>, OpError> {
        let len = local.len();
        let bytes = len * 4 * self.world;
        let parts = self.all_gather(local)?;
        self.stats.sim_time_s -= self.link.ring_allgather_time(bytes, self.world);
        self.stats.sim_time_s += self.link.ring_allreduce_time(bytes, self.world);
        let mut out = vec![f32::NEG_INFINITY; len];
        for p in parts {
            for (o, v) in out.iter_mut().zip(p) {
                *o = o.max(v);
            }
        }
        Ok(out)
    }

    /// Broadcast from `root` (Eq. 8): ring forward of the root's payload.
    pub fn broadcast(&mut self, root: usize, local: Vec<f32>) -> Result<Vec<f32>, OpError> {
        let parts = self.all_gather(local)?;
        let bytes = parts[root].len() * 4;
        self.stats.sim_time_s -= self
            .link
            .ring_allgather_time(bytes * self.world, self.world);
        self.stats.sim_time_s += self.link.broadcast_time(bytes, self.world);
        Ok(parts[root].clone())
    }

    /// Barrier: zero-payload all-gather.
    pub fn barrier(&mut self) -> Result<(), OpError> {
        self.all_gather(Vec::new())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::Transport;

    fn run_world<F, T>(n: usize, f: F) -> Vec<T>
    where
        F: Fn(Collective) -> T + Send + Sync + Clone + 'static,
        T: Send + 'static,
    {
        let ring = Collective::ring(Topology::new(n, Transport::NvlinkRdma));
        let mut handles = Vec::new();
        for c in ring {
            let f = f.clone();
            handles.push(std::thread::spawn(move || f(c)));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn all_gather_collects_every_rank() {
        let results = run_world(4, |mut c| {
            let local = vec![c.rank() as f32; 3];
            c.all_gather(local).unwrap()
        });
        for r in results {
            for (rank, part) in r.iter().enumerate() {
                assert_eq!(part, &vec![rank as f32; 3]);
            }
        }
    }

    #[test]
    fn all_reduce_sum_matches() {
        let results = run_world(8, |mut c| {
            c.all_reduce_sum(vec![1.0, c.rank() as f32]).unwrap()
        });
        for r in results {
            assert_eq!(r[0], 8.0);
            assert_eq!(r[1], (0..8).sum::<i32>() as f32);
        }
    }

    #[test]
    fn all_reduce_max_matches() {
        let results = run_world(5, |mut c| c.all_reduce_max(vec![c.rank() as f32]).unwrap());
        for r in results {
            assert_eq!(r[0], 4.0);
        }
    }

    #[test]
    fn broadcast_delivers_root_payload() {
        let results = run_world(4, |mut c| {
            let local = vec![(10 * c.rank()) as f32];
            c.broadcast(2, local).unwrap()
        });
        for r in results {
            assert_eq!(r, vec![20.0]);
        }
    }

    #[test]
    fn stats_account_sim_time() {
        let results = run_world(4, |mut c| {
            c.all_gather(vec![0.0; 1024]).unwrap();
            c.stats()
        });
        for s in results {
            assert_eq!(s.ops, 1);
            assert!(s.sim_time_s > 0.0);
            assert!(s.bytes_sent >= 3 * 1024 * 4);
        }
    }

    #[test]
    fn world_of_one_is_trivial() {
        let results = run_world(1, |mut c| c.all_gather(vec![7.0]).unwrap());
        assert_eq!(results[0], vec![vec![7.0]]);
    }
}
