//! Ring collectives: real message passing between rank threads.
//!
//! Each rank owns a `Collective` endpoint. Operations are SPMD: every rank
//! must call the same op in the same order (an op-sequence counter guards
//! against divergence — Thm. 4 consistency depends on it). Payloads travel
//! over mpsc channels to the next rank in the ring; simulated wire time is
//! accounted against the topology's link model.
//!
//! # Quantized wire
//!
//! The `_q` variants make the wire itself low-bit (the paper's claim that
//! quantization must reach the communication layer): the send endpoint
//! splits its contribution into chunks, token-quantizes each chunk (one
//! f32 scale per chunk, `quant::kernels::token_quantize_packed_into`),
//! and ships bit-packed codes; every receive endpoint decodes. Encoding
//! chunk *k+1* happens after chunk *k* is already on the wire, so encode
//! overlaps flight. All ranks — the contributor included — adopt the
//! *dequantized* values, so the merged result is identical on every rank.
//! `CommStats::bytes_sent` counts the quantized bytes actually shipped
//! (codes + scales): 8-bit cuts wire bytes ~4x vs f32, packed 4/2-bit
//! ~8/16x.
//!
//! # Wire integrity
//!
//! Every quantized chunk carries an FNV-1a checksum over its packed
//! codes and scales, computed at encode and verified at *every* decode
//! — always on, not just under fault injection. A rank armed with
//! [`LinkFaults`] draws corruption per delivery attempt; a detected
//! chunk (checksum mismatch — the delivered view really is corrupted,
//! a byte is flipped) counts one `CommStats::retransmits` and is
//! re-pulled from the sender's refcounted original. After
//! [`CHUNK_RETRY_LIMIT`] consecutive bad deliveries the receiving rank
//! *ejects* ([`OpError::Corrupt`]): it abandons the op, its channel
//! endpoints drop, and the neighbors' next receive fails fast
//! (disconnect, not timeout) — the surviving ranks rebuild a smaller
//! ring and redo the op, which is the policy the eject test pins.

use std::fmt;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::quant::kernels;

use super::{CommStats, LinkFaults, LinkModel, Topology};

const RECV_TIMEOUT: Duration = Duration::from_secs(30);

/// Floor (and granularity) of the quantized wire chunk, in elements.
/// Each chunk carries one token scale and goes on the wire the moment
/// it is encoded, pipelining encode with the previous chunk's flight
/// down the ring. The chunk size actually used by an endpoint is
/// derived from its link's bandwidth-delay product ([`adaptive_chunk`])
/// and is always a multiple of this floor. Public so tests and benches
/// derive error bounds and byte counts from the real values.
pub const QUANT_CHUNK: usize = 4096;

/// Ceiling of the adaptive wire chunk (elements): past this, a chunk no
/// longer overlaps encode with flight and the per-chunk scale stops
/// tracking local dynamic range.
pub const MAX_QUANT_CHUNK: usize = 1 << 18;

/// Elements per quantized wire chunk for a link, derived from its
/// bandwidth-delay product: the chunk's wire bytes (~`bits/8` per
/// element) should roughly fill the link's in-flight window, so fast
/// fat links (NVLink, ~3 MB BDP) stream big chunks while the TCP tier
/// (~75 KB BDP) keeps chunks small enough that per-chunk latency still
/// hides behind flight. Lower wire bitwidths pack more elements into
/// the same in-flight bytes, so the element chunk grows as bits shrink.
/// Clamped to `[QUANT_CHUNK, MAX_QUANT_CHUNK]` and quantized to a
/// multiple of [`QUANT_CHUNK`]; every rank derives the same value from
/// the shared topology link (SPMD contract).
pub fn adaptive_chunk(link: &LinkModel, bits: u32) -> usize {
    let elems = (link.bdp_bytes() * 8.0 / bits.max(1) as f64) as usize;
    let floored = (elems / QUANT_CHUNK) * QUANT_CHUNK;
    floored.clamp(QUANT_CHUNK, MAX_QUANT_CHUNK)
}

/// Consecutive checksum failures on one chunk delivery before the
/// receiving rank gives up on the link and ejects from the ring.
pub const CHUNK_RETRY_LIMIT: u32 = 3;

#[derive(Debug)]
pub enum OpError {
    /// Ranks issued different op sequences — the SPMD contract broke.
    SequenceMismatch { rank: usize, got: u64, expected: u64 },
    /// Receive timed out or the ring disconnected.
    Recv { rank: usize, op: &'static str },
    /// A packet carried the wrong payload kind or malformed chunk bounds.
    Payload { rank: usize, op: &'static str },
    /// Quantized op requested with a bitwidth the packed wire format
    /// cannot carry (must be 2, 4, or 8).
    InvalidBits { rank: usize, bits: u32 },
    /// One chunk failed its checksum `attempts` consecutive deliveries:
    /// the link is declared bad and this rank ejects — callers rebuild
    /// the ring over the surviving ranks.
    Corrupt { rank: usize, op: &'static str, attempts: u32 },
}

impl fmt::Display for OpError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            OpError::SequenceMismatch { rank, got, expected } => write!(
                f,
                "rank {rank}: op sequence mismatch: got {got}, expected {expected} — \
                 ranks diverged"
            ),
            OpError::Recv { rank, op } => {
                write!(f, "rank {rank}: recv timeout/disconnect in {op}")
            }
            OpError::Payload { rank, op } => {
                write!(f, "rank {rank}: malformed or mismatched payload in {op}")
            }
            OpError::InvalidBits { rank, bits } => write!(
                f,
                "rank {rank}: quantized collective bits={bits} unsupported \
                 (wire format packs 2, 4, or 8 bits)"
            ),
            OpError::Corrupt { rank, op, attempts } => write!(
                f,
                "rank {rank}: chunk failed its checksum {attempts} consecutive \
                 deliveries in {op} — link declared bad, rank ejecting from the ring"
            ),
        }
    }
}

impl std::error::Error for OpError {}

/// Wire payload of one ring packet: raw f32, or bit-packed signed codes
/// with their per-chunk token scales. The quantized buffers are behind
/// `Arc` so forwarding a chunk down the ring clones a refcount, not the
/// bytes.
#[derive(Debug, Clone)]
enum Payload {
    F32(Vec<f32>),
    Quant { bits: u32, n: usize, codes: Arc<Vec<u8>>, scales: Arc<Vec<f32>>, checksum: u64 },
}

impl Payload {
    /// Bytes this payload occupies on the (simulated) wire.
    fn wire_bytes(&self) -> usize {
        match self {
            Payload::F32(d) => d.len() * 4,
            Payload::Quant { codes, scales, .. } => codes.len() + scales.len() * 4,
        }
    }
}

/// FNV-1a over a chunk's packed codes then its scales' little-endian
/// bytes — computed once at encode, carried in the packet, verified at
/// every decode. A single flipped byte always changes the digest
/// (xor-then-multiply-by-odd-prime is a bijection on u64).
fn chunk_checksum(codes: &[u8], scales: &[f32]) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    for &b in codes {
        h = (h ^ b as u64).wrapping_mul(PRIME);
    }
    for s in scales {
        for b in s.to_le_bytes() {
            h = (h ^ b as u64).wrapping_mul(PRIME);
        }
    }
    h
}

/// Wire shape of one rank's quantized contribution at a given chunk
/// size: (chunk count, bytes = packed codes + one f32 scale per chunk).
/// The single source for the gather and the reduce sim-time accounting.
fn quant_wire_shape(len: usize, bits: u32, chunk: usize) -> (usize, usize) {
    let n_chunks = len.div_ceil(chunk.max(1));
    (n_chunks, kernels::packed_len(len, bits) + n_chunks * 4)
}

struct Packet {
    seq: u64,
    /// rank whose contribution this packet carries
    origin: usize,
    /// chunk index within the origin's contribution (quantized path)
    part: usize,
    payload: Payload,
}

/// One rank's endpoint in the ring.
pub struct Collective {
    rank: usize,
    world: usize,
    link: LinkModel,
    to_next: Sender<Packet>,
    from_prev: Receiver<Packet>,
    seq: u64,
    stats: CommStats,
    /// seeded corruption schedule for this rank's incoming link
    faults: Option<LinkFaults>,
}

impl Collective {
    /// Build a ring of `world` endpoints (move each into its rank thread).
    pub fn ring(topo: Topology) -> Vec<Collective> {
        let n = topo.world;
        let mut senders = Vec::with_capacity(n);
        let mut receivers = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = channel();
            senders.push(tx);
            receivers.push(rx);
        }
        // rank i sends to rank (i+1) % n, receives from (i-1+n) % n.
        // receivers[j] belongs to the rank that *receives from* channel j's
        // sender; channel j carries i -> i+1, so receiver j goes to rank j+1.
        let mut out: Vec<Collective> = Vec::with_capacity(n);
        let mut rx_iter: Vec<Option<Receiver<Packet>>> =
            receivers.into_iter().map(Some).collect();
        for rank in 0..n {
            let to_next = senders[(rank + 1) % n].clone();
            let from_prev = rx_iter[rank].take().unwrap();
            out.push(Collective {
                rank,
                world: n,
                link: topo.link(),
                to_next,
                from_prev,
                seq: 0,
                stats: CommStats::default(),
                faults: None,
            });
        }
        out
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn world(&self) -> usize {
        self.world
    }

    pub fn stats(&self) -> CommStats {
        self.stats
    }

    /// Arm this endpoint's incoming link with a seeded corruption
    /// schedule — every received quantized chunk then draws once per
    /// delivery attempt.
    pub fn with_link_faults(mut self, faults: LinkFaults) -> Self {
        self.faults = Some(faults);
        self
    }

    fn send_packet(&mut self, origin: usize, part: usize, payload: Payload) {
        self.stats.bytes_sent += payload.wire_bytes() as u64;
        let _ = self.to_next.send(Packet { seq: self.seq, origin, part, payload });
    }

    fn recv_packet(&mut self, op: &'static str) -> Result<Packet, OpError> {
        match self.from_prev.recv_timeout(RECV_TIMEOUT) {
            Ok(p) => {
                if p.seq != self.seq {
                    return Err(OpError::SequenceMismatch {
                        rank: self.rank,
                        got: p.seq,
                        expected: self.seq,
                    });
                }
                Ok(p)
            }
            Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => {
                Err(OpError::Recv { rank: self.rank, op })
            }
        }
    }

    /// Ring all-gather (Eq. 7): every rank contributes `local`, returns all
    /// contributions indexed by rank. (world-1) steps, each forwarding the
    /// chunk received in the previous step.
    pub fn all_gather(&mut self, local: Vec<f32>) -> Result<Vec<Vec<f32>>, OpError> {
        let t0 = Instant::now();
        self.seq += 1;
        let n = self.world;
        let mut slots: Vec<Option<Vec<f32>>> = vec![None; n];
        let total_bytes = local.len() * 4 * n;
        slots[self.rank] = Some(local.clone());
        let mut carry = (self.rank, local);
        for _ in 0..n.saturating_sub(1) {
            self.send_packet(carry.0, 0, Payload::F32(carry.1));
            let p = self.recv_packet("all_gather")?;
            let data = match p.payload {
                Payload::F32(d) => d,
                Payload::Quant { .. } => {
                    return Err(OpError::Payload { rank: self.rank, op: "all_gather" })
                }
            };
            if p.origin >= n {
                return Err(OpError::Payload { rank: self.rank, op: "all_gather" });
            }
            slots[p.origin] = Some(data.clone());
            carry = (p.origin, data);
        }
        self.stats.ops += 1;
        self.stats.sim_time_s += self.link.ring_allgather_time(total_bytes, n);
        self.stats.wall_time_s += t0.elapsed().as_secs_f64();
        let rank = self.rank;
        slots
            .into_iter()
            .map(|s| s.ok_or(OpError::Payload { rank, op: "all_gather" }))
            .collect()
    }

    /// All-reduce (sum): all-gather + local reduction (metadata-sized
    /// payloads make the bandwidth-optimal variant unnecessary; the wire
    /// time is still accounted with the 2(n-1)-step ring formula).
    pub fn all_reduce_sum(&mut self, local: Vec<f32>) -> Result<Vec<f32>, OpError> {
        let len = local.len();
        let bytes = len * 4 * self.world;
        let parts = self.all_gather(local)?;
        // replace the all-gather accounting with all-reduce accounting
        self.stats.sim_time_s -= self.link.ring_allgather_time(bytes, self.world);
        self.stats.sim_time_s += self.link.ring_allreduce_time(bytes, self.world);
        let mut out = vec![0f32; len];
        for p in parts {
            for (o, v) in out.iter_mut().zip(p) {
                *o += v;
            }
        }
        Ok(out)
    }

    /// Element-wise max reduction — the scale synchronizer's conservative
    /// merge rule for per-shard deltas.
    pub fn all_reduce_max(&mut self, local: Vec<f32>) -> Result<Vec<f32>, OpError> {
        let len = local.len();
        let bytes = len * 4 * self.world;
        let parts = self.all_gather(local)?;
        self.stats.sim_time_s -= self.link.ring_allgather_time(bytes, self.world);
        self.stats.sim_time_s += self.link.ring_allreduce_time(bytes, self.world);
        let mut out = vec![f32::NEG_INFINITY; len];
        for p in parts {
            for (o, v) in out.iter_mut().zip(p) {
                *o = o.max(v);
            }
        }
        Ok(out)
    }

    /// Broadcast from `root` (Eq. 8): ring forward of the root's payload.
    pub fn broadcast(&mut self, root: usize, local: Vec<f32>) -> Result<Vec<f32>, OpError> {
        let parts = self.all_gather(local)?;
        let bytes = parts[root].len() * 4;
        self.stats.sim_time_s -= self
            .link
            .ring_allgather_time(bytes * self.world, self.world);
        self.stats.sim_time_s += self.link.broadcast_time(bytes, self.world);
        Ok(parts[root].clone())
    }

    /// Barrier: zero-payload all-gather.
    pub fn barrier(&mut self) -> Result<(), OpError> {
        self.all_gather(Vec::new())?;
        Ok(())
    }

    // -----------------------------------------------------------------------
    // Quantized-wire variants
    // -----------------------------------------------------------------------

    /// Ring all-gather over a quantized wire: contributions are encoded
    /// at the send endpoint (per-chunk token scales, bit-packed codes),
    /// shipped low-bit, and decoded at every receive endpoint. The
    /// contributor adopts its own dequantized chunks too, so all ranks
    /// return bit-identical vectors. Contributions must have the same
    /// length on every rank (SPMD contract). `bits` must be 2, 4, or 8.
    pub fn all_gather_quant(
        &mut self,
        local: &[f32],
        bits: u32,
    ) -> Result<Vec<Vec<f32>>, OpError> {
        let t0 = Instant::now();
        self.seq += 1;
        if kernels::validate_bits(bits).is_err() || kernels::validate_pack_bits(bits).is_err() {
            return Err(OpError::InvalidBits { rank: self.rank, bits });
        }
        let n = self.world;
        let rank = self.rank;
        let len = local.len();
        let chunk = adaptive_chunk(&self.link, bits);
        let (n_chunks, contrib_bytes) = quant_wire_shape(len, bits, chunk);
        let mut out: Vec<Vec<f32>> = (0..n).map(|_| vec![0f32; len]).collect();
        if len == 0 {
            self.stats.ops += 1;
            self.stats.wall_time_s += t0.elapsed().as_secs_f64();
            return Ok(out);
        }

        // step 0: encode chunk k, adopt its dequantized values locally
        // (borrowed, no clone), then put it on the wire — chunk k is in
        // flight while chunk k+1 is still being encoded
        for (ci, piece) in local.chunks(chunk).enumerate() {
            let mut codes = vec![0u8; kernels::packed_len(piece.len(), bits)];
            let mut scales = vec![0f32; 1];
            kernels::token_quantize_packed_into(
                piece,
                1,
                piece.len(),
                bits,
                &mut codes,
                &mut scales,
            )
            .map_err(|_| OpError::Payload { rank, op: "all_gather_quant" })?;
            let start = ci * chunk;
            kernels::token_dequantize_packed_into(
                &codes,
                &scales,
                1,
                piece.len(),
                bits,
                &mut out[rank][start..start + piece.len()],
            )
            .map_err(|_| OpError::Payload { rank, op: "all_gather_quant" })?;
            if n > 1 {
                let checksum = chunk_checksum(&codes, &scales);
                let payload = Payload::Quant {
                    bits,
                    n: piece.len(),
                    codes: Arc::new(codes),
                    scales: Arc::new(scales),
                    checksum,
                };
                self.send_packet(rank, ci, payload);
            }
        }
        // steps 1..n-1: forward each received chunk before decoding it,
        // so the next hop is never stalled behind our decode
        for step in 1..n {
            let forward = step + 1 < n;
            for _ in 0..n_chunks {
                let p = self.recv_packet("all_gather_quant")?;
                let clen = match &p.payload {
                    Payload::Quant { n: clen, .. } => *clen,
                    Payload::F32(_) => {
                        return Err(OpError::Payload { rank, op: "all_gather_quant" })
                    }
                };
                let start = p.part * chunk;
                if p.origin >= n || start + clen > len {
                    return Err(OpError::Payload { rank, op: "all_gather_quant" });
                }
                let payload = self.deliver_checked(p.payload, "all_gather_quant")?;
                if forward {
                    self.send_packet(p.origin, p.part, payload.clone());
                }
                Self::decode_chunk(
                    &payload,
                    &mut out[p.origin][start..start + clen],
                    rank,
                    "all_gather_quant",
                )?;
            }
        }
        self.stats.ops += 1;
        self.stats.sim_time_s +=
            self.link.ring_allgather_chunked_time(contrib_bytes * n, n, n_chunks);
        self.stats.wall_time_s += t0.elapsed().as_secs_f64();
        Ok(out)
    }

    /// [`Collective::all_gather_quant`] on the INT8 wire — the 4x
    /// wire-byte cut over f32 with no packing step.
    pub fn all_gather_q8(&mut self, local: &[f32]) -> Result<Vec<Vec<f32>>, OpError> {
        self.all_gather_quant(local, 8)
    }

    /// All-reduce (sum) over the quantized wire: gather dequantized
    /// contributions, reduce locally. Identical on every rank because
    /// each rank sums the same dequantized values.
    pub fn all_reduce_sum_q(&mut self, local: &[f32], bits: u32) -> Result<Vec<f32>, OpError> {
        self.all_reduce_q(local, bits, 0.0, |a, b| a + b)
    }

    /// Element-wise max reduction over the quantized wire — the scale
    /// synchronizer's merge rule, shipped low-bit.
    pub fn all_reduce_max_q(&mut self, local: &[f32], bits: u32) -> Result<Vec<f32>, OpError> {
        self.all_reduce_q(local, bits, f32::NEG_INFINITY, f32::max)
    }

    /// Broadcast from `root` over the quantized wire — the weight-shard
    /// distribution path (a rejoining shard pulls its weight partition
    /// from the fleet low-bit instead of as raw f32). Every rank adopts
    /// the root's *dequantized* chunks, so all ranks — the root included
    /// — hold bit-identical values. Sim time is accounted with the
    /// binomial-tree broadcast formula over the quantized contribution
    /// bytes; `CommStats::bytes_sent` counts the packed bytes actually
    /// shipped.
    pub fn broadcast_quant(
        &mut self,
        root: usize,
        local: &[f32],
        bits: u32,
    ) -> Result<Vec<f32>, OpError> {
        if root >= self.world {
            return Err(OpError::Payload { rank: self.rank, op: "broadcast_quant" });
        }
        let len = local.len();
        let chunk = adaptive_chunk(&self.link, bits);
        let (n_chunks, contrib_bytes) = quant_wire_shape(len, bits, chunk);
        let parts = self.all_gather_quant(local, bits)?;
        if len > 0 {
            self.stats.sim_time_s -= self
                .link
                .ring_allgather_chunked_time(contrib_bytes * self.world, self.world, n_chunks);
            self.stats.sim_time_s += self.link.broadcast_time(contrib_bytes, self.world);
        }
        Ok(parts[root].clone())
    }

    /// Shared body of the quantized reductions: gather over the
    /// quantized wire, swap the all-gather sim-time entry for the
    /// all-reduce ring formula (same wire shape, via
    /// [`quant_wire_shape`]), fold locally.
    fn all_reduce_q(
        &mut self,
        local: &[f32],
        bits: u32,
        init: f32,
        fold: fn(f32, f32) -> f32,
    ) -> Result<Vec<f32>, OpError> {
        let len = local.len();
        let chunk = adaptive_chunk(&self.link, bits);
        let (n_chunks, contrib_bytes) = quant_wire_shape(len, bits, chunk);
        let total = contrib_bytes * self.world;
        let parts = self.all_gather_quant(local, bits)?;
        if len > 0 {
            self.stats.sim_time_s -=
                self.link.ring_allgather_chunked_time(total, self.world, n_chunks);
            self.stats.sim_time_s +=
                self.link.ring_allreduce_chunked_time(total, self.world, n_chunks);
        }
        let mut out = vec![init; len];
        for p in parts {
            for (o, v) in out.iter_mut().zip(p) {
                *o = fold(*o, v);
            }
        }
        Ok(out)
    }

    /// Verify one received chunk against its carried checksum, replaying
    /// the delivery under the armed [`LinkFaults`] schedule: a corrupted
    /// attempt flips one byte of the delivered view, the mismatch counts
    /// one `CommStats::retransmits`, and the chunk is re-pulled from the
    /// sender's refcounted original — up to [`CHUNK_RETRY_LIMIT`]
    /// attempts, after which this rank ejects with [`OpError::Corrupt`].
    fn deliver_checked(&mut self, payload: Payload, op: &'static str) -> Result<Payload, OpError> {
        let (codes, scales, checksum) = match &payload {
            Payload::Quant { codes, scales, checksum, .. } => {
                (Arc::clone(codes), Arc::clone(scales), *checksum)
            }
            Payload::F32(_) => return Ok(payload),
        };
        let mut attempts = 0u32;
        loop {
            attempts += 1;
            let corrupted = self.faults.as_mut().is_some_and(|f| f.corrupt_next());
            let delivered_ok = if corrupted {
                let mut view = (*codes).clone();
                let victim = self.faults.as_mut().map_or(0, |f| f.victim_byte(view.len()));
                if let Some(b) = view.get_mut(victim) {
                    *b ^= 0x40;
                }
                chunk_checksum(&view, &scales) == checksum
            } else {
                chunk_checksum(&codes, &scales) == checksum
            };
            if delivered_ok && !corrupted {
                return Ok(payload);
            }
            self.stats.retransmits += 1;
            if attempts >= CHUNK_RETRY_LIMIT {
                return Err(OpError::Corrupt { rank: self.rank, op, attempts });
            }
        }
    }

    fn decode_chunk(
        payload: &Payload,
        out: &mut [f32],
        rank: usize,
        op: &'static str,
    ) -> Result<(), OpError> {
        match payload {
            Payload::Quant { bits, n, codes, scales, checksum } => {
                if chunk_checksum(codes, scales) != *checksum {
                    return Err(OpError::Payload { rank, op });
                }
                kernels::token_dequantize_packed_into(codes, scales, 1, *n, *bits, out)
                    .map_err(|_| OpError::Payload { rank, op })
            }
            Payload::F32(_) => Err(OpError::Payload { rank, op }),
        }
    }
}

/// Point-to-point transfer of a migrated KV lane's pages over one
/// modeled link — the disaggregated prefill→decode handoff wire (and
/// the rejoin/standby page-migration path). The payload arrives as the
/// lane's byte segments: bit-packed code pages (`codes`) plus f32 side
/// data (`params` — per-block channel params for a quantized lane, raw
/// rows for an f32 one). Each segment is chunked at the link's
/// BDP-derived granularity ([`adaptive_chunk`], scaled to packed
/// bytes); every chunk carries the same FNV checksum the ring payloads
/// do and replays its delivery under the armed [`LinkFaults`]
/// schedule: a corrupted attempt counts one `CommStats::retransmits`
/// and re-sends, up to [`CHUNK_RETRY_LIMIT`] attempts, after which the
/// transfer fails with [`OpError::Corrupt`] (callers fall back to
/// re-prefill — the no-pages path). Accounting lands in `stats`: one
/// op, the packed wire bytes, and `alpha + bytes/beta` sim time per
/// chunk (plus one hop per retransmit). Returns the wire bytes
/// shipped.
pub fn transfer_quant_pages(
    link: &LinkModel,
    src: usize,
    mut faults: Option<&mut LinkFaults>,
    stats: &mut CommStats,
    bits: u32,
    codes: &[&[u8]],
    params: &[&[f32]],
) -> Result<u64, OpError> {
    let t0 = Instant::now();
    let chunk_elems = adaptive_chunk(link, bits);
    let chunk_bytes = ((chunk_elems * bits.max(1) as usize) / 8).max(1);
    let mut total: u64 = 0;
    {
        let mut deliver = |chunk_codes: &[u8], chunk_params: &[f32]| -> Result<(), OpError> {
            let bytes = chunk_codes.len() + chunk_params.len() * 4;
            total += bytes as u64;
            stats.bytes_sent += bytes as u64;
            stats.sim_time_s += link.hop_time(bytes);
            let expect = chunk_checksum(chunk_codes, chunk_params);
            let mut attempts = 0u32;
            loop {
                attempts += 1;
                let corrupted = faults.as_mut().is_some_and(|f| f.corrupt_next());
                let delivered_ok = if corrupted {
                    let mut view = chunk_codes.to_vec();
                    let victim =
                        faults.as_mut().map_or(0, |f| f.victim_byte(view.len()));
                    if let Some(b) = view.get_mut(victim) {
                        *b ^= 0x40;
                    }
                    chunk_checksum(&view, chunk_params) == expect
                } else {
                    true
                };
                if delivered_ok && !corrupted {
                    return Ok(());
                }
                stats.retransmits += 1;
                stats.sim_time_s += link.hop_time(bytes);
                if attempts >= CHUNK_RETRY_LIMIT {
                    return Err(OpError::Corrupt {
                        rank: src,
                        op: "transfer_quant_pages",
                        attempts,
                    });
                }
            }
        };
        for seg in codes {
            for chunk in seg.chunks(chunk_bytes) {
                deliver(chunk, &[])?;
            }
        }
        let param_chunk = (chunk_bytes / 4).max(1);
        for seg in params {
            for chunk in seg.chunks(param_chunk) {
                deliver(&[], chunk)?;
            }
        }
    }
    stats.ops += 1;
    stats.wall_time_s += t0.elapsed().as_secs_f64();
    Ok(total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::{LinkFaults, Transport};

    fn run_world<F, T>(n: usize, f: F) -> Vec<T>
    where
        F: Fn(Collective) -> T + Send + Sync + Clone + 'static,
        T: Send + 'static,
    {
        let ring = Collective::ring(Topology::new(n, Transport::NvlinkRdma));
        let mut handles = Vec::new();
        for c in ring {
            let f = f.clone();
            handles.push(std::thread::spawn(move || f(c)));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn all_gather_collects_every_rank() {
        let results = run_world(4, |mut c| {
            let local = vec![c.rank() as f32; 3];
            c.all_gather(local).unwrap()
        });
        for r in results {
            for (rank, part) in r.iter().enumerate() {
                assert_eq!(part, &vec![rank as f32; 3]);
            }
        }
    }

    #[test]
    fn all_reduce_sum_matches() {
        let results = run_world(8, |mut c| {
            c.all_reduce_sum(vec![1.0, c.rank() as f32]).unwrap()
        });
        for r in results {
            assert_eq!(r[0], 8.0);
            assert_eq!(r[1], (0..8).sum::<i32>() as f32);
        }
    }

    #[test]
    fn all_reduce_max_matches() {
        let results = run_world(5, |mut c| c.all_reduce_max(vec![c.rank() as f32]).unwrap());
        for r in results {
            assert_eq!(r[0], 4.0);
        }
    }

    #[test]
    fn broadcast_delivers_root_payload() {
        let results = run_world(4, |mut c| {
            let local = vec![(10 * c.rank()) as f32];
            c.broadcast(2, local).unwrap()
        });
        for r in results {
            assert_eq!(r, vec![20.0]);
        }
    }

    #[test]
    fn stats_account_sim_time() {
        let results = run_world(4, |mut c| {
            c.all_gather(vec![0.0; 1024]).unwrap();
            c.stats()
        });
        for s in results {
            assert_eq!(s.ops, 1);
            assert!(s.sim_time_s > 0.0);
            assert!(s.bytes_sent >= 3 * 1024 * 4);
        }
    }

    #[test]
    fn world_of_one_is_trivial() {
        let results = run_world(1, |mut c| c.all_gather(vec![7.0]).unwrap());
        assert_eq!(results[0], vec![vec![7.0]]);
    }

    #[test]
    fn quant_all_gather_smoke() {
        let results = run_world(4, |mut c| {
            let local = vec![c.rank() as f32 + 0.5; 5];
            (c.all_gather_q8(&local).unwrap(), c.stats())
        });
        for (parts, stats) in &results {
            assert_eq!(parts.len(), 4);
            for (rank, part) in parts.iter().enumerate() {
                for v in part {
                    assert!((v - (rank as f32 + 0.5)).abs() < 0.02, "rank {rank}: {v}");
                }
            }
            assert_eq!(stats.ops, 1);
            assert!(stats.sim_time_s > 0.0);
        }
        // all ranks hold bit-identical merged vectors
        for (parts, _) in &results[1..] {
            assert_eq!(parts, &results[0].0);
        }
    }

    #[test]
    fn quant_all_gather_world_of_one_and_empty() {
        let results = run_world(1, |mut c| c.all_gather_q8(&[3.0, -3.0]).unwrap());
        assert_eq!(results[0].len(), 1);
        assert!((results[0][0][0] - 3.0).abs() < 0.05);
        let results = run_world(2, |mut c| c.all_gather_q8(&[]).unwrap());
        assert_eq!(results[0], vec![Vec::<f32>::new(); 2]);
    }

    #[test]
    fn quant_rejects_unpackable_bits() {
        let results = run_world(1, |mut c| c.all_gather_quant(&[1.0], 3).is_err());
        assert!(results[0]);
    }

    #[test]
    fn adaptive_chunk_tracks_the_links_bdp() {
        let nv = adaptive_chunk(&LinkModel::nvlink(), 8);
        let ib = adaptive_chunk(&LinkModel::infiniband(), 8);
        let tcp = adaptive_chunk(&LinkModel::tcp(), 8);
        assert!(nv >= ib && ib > tcp, "nv {nv} ib {ib} tcp {tcp}");
        for c in [nv, ib, tcp] {
            assert_eq!(c % QUANT_CHUNK, 0, "chunk {c} not a multiple of the floor");
            assert!((QUANT_CHUNK..=MAX_QUANT_CHUNK).contains(&c));
        }
        // lower wire bits pack more elements into the same in-flight bytes
        assert!(adaptive_chunk(&LinkModel::tcp(), 4) > tcp);
        // nvlink's ~3 MB BDP saturates the ceiling
        assert_eq!(nv, MAX_QUANT_CHUNK);
        // a degenerate link still yields a sane floor chunk
        let slow = LinkModel { alpha_s: 1e-6, beta_bps: 1e6 };
        assert_eq!(adaptive_chunk(&slow, 8), QUANT_CHUNK);
    }

    #[test]
    fn quant_broadcast_delivers_root_payload_on_every_rank() {
        let results = run_world(4, |mut c| {
            let local: Vec<f32> =
                (0..100).map(|i| (10 * c.rank()) as f32 + i as f32 * 0.01).collect();
            (c.broadcast_quant(2, &local, 8).unwrap(), c.stats())
        });
        for (r, stats) in &results {
            for (i, v) in r.iter().enumerate() {
                let expect = 20.0 + i as f32 * 0.01;
                assert!((v - expect).abs() < 0.15, "elem {i}: {v} vs {expect}");
            }
            assert!(stats.sim_time_s > 0.0);
            // the wire shipped packed 8-bit bytes, not f32
            assert!(
                stats.bytes_sent < (100 * 4 * 3) as u64,
                "broadcast shipped f32-sized payloads: {} bytes",
                stats.bytes_sent
            );
        }
        // all ranks adopt bit-identical dequantized values
        for (r, _) in &results[1..] {
            assert_eq!(r, &results[0].0);
        }
        // out-of-range root is a typed payload error, not a panic
        let bad = run_world(2, |mut c| c.broadcast_quant(7, &[1.0], 8).is_err());
        assert!(bad[0] && bad[1]);
    }

    #[test]
    fn quant_broadcast_costs_less_wire_time_than_f32() {
        // one rank: no wire traffic, but the accounting formulas still
        // apply — the quantized broadcast models ~4x fewer bytes
        let results = run_world(4, |mut c| {
            let local = vec![c.rank() as f32; 64 * 1024];
            if c.rank() == 0 {
                let t_f32 = {
                    let mut probe = c.stats().sim_time_s;
                    c.broadcast(0, local.clone()).unwrap();
                    probe = c.stats().sim_time_s - probe;
                    probe
                };
                let t_q = {
                    let mut probe = c.stats().sim_time_s;
                    c.broadcast_quant(0, &local, 8).unwrap();
                    probe = c.stats().sim_time_s - probe;
                    probe
                };
                (t_f32, t_q)
            } else {
                c.broadcast(0, local.clone()).unwrap();
                c.broadcast_quant(0, &local, 8).unwrap();
                (0.0, 0.0)
            }
        });
        let (t_f32, t_q) = results[0];
        assert!(t_q > 0.0 && t_f32 > 0.0);
        assert!(t_q < t_f32 / 2.0, "quantized broadcast wire time {t_q} vs f32 {t_f32}");
    }

    #[test]
    fn chunk_checksum_detects_any_byte_flip() {
        let codes = vec![1u8, 2, 3, 250];
        let scales = vec![0.5f32, 2.0];
        let good = chunk_checksum(&codes, &scales);
        for i in 0..codes.len() {
            let mut bad = codes.clone();
            bad[i] ^= 0x40;
            assert_ne!(chunk_checksum(&bad, &scales), good, "flip at byte {i}");
        }
        assert_ne!(chunk_checksum(&codes, &[0.5, 2.5]), good, "scale change");
    }

    #[test]
    fn checksum_retry_heals_transient_corruption() {
        // a seed whose draw sequence is corrupt-then-clean, mirroring
        // deliver_checked's draws (victim_byte consumes one when corrupt)
        let seed = (0u64..)
            .find(|s| {
                let mut f = LinkFaults::new(0.5, *s);
                f.corrupt_next() && {
                    f.victim_byte(8);
                    !f.corrupt_next()
                }
            })
            .expect("some seed draws corrupt-then-clean");
        let mut ring = Collective::ring(Topology::new(1, Transport::NvlinkRdma));
        let mut c = ring.pop().unwrap().with_link_faults(LinkFaults::new(0.5, seed));
        let data: Vec<f32> = (0..16).map(|i| i as f32 - 8.0).collect();
        let mut codes = vec![0u8; kernels::packed_len(data.len(), 8)];
        let mut scales = vec![0f32; 1];
        kernels::token_quantize_packed_into(&data, 1, data.len(), 8, &mut codes, &mut scales)
            .unwrap();
        let checksum = chunk_checksum(&codes, &scales);
        let payload = Payload::Quant {
            bits: 8,
            n: data.len(),
            codes: Arc::new(codes),
            scales: Arc::new(scales),
            checksum,
        };
        let healed = c.deliver_checked(payload, "test").expect("retry heals the chunk");
        assert_eq!(c.stats().retransmits, 1, "exactly one retransmit");
        match healed {
            Payload::Quant { checksum: cs, .. } => assert_eq!(cs, checksum),
            Payload::F32(_) => panic!("payload kind changed in delivery"),
        }
    }

    #[test]
    fn corrupt_link_ejects_and_survivors_rebuild() {
        let ring = Collective::ring(Topology::new(3, Transport::NvlinkRdma));
        let mut handles = Vec::new();
        for endpoint in ring {
            handles.push(std::thread::spawn(move || {
                let mut c = if endpoint.rank() == 1 {
                    endpoint.with_link_faults(LinkFaults::new(1.0, 7))
                } else {
                    endpoint
                };
                let rank = c.rank();
                let res = c.all_gather_q8(&[rank as f32; 8]);
                let stats = c.stats();
                // dropping c here is the eject: its channel endpoints
                // close, so the neighbors disconnect instead of timing out
                (res, stats)
            }));
        }
        let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        match &results[1].0 {
            Err(OpError::Corrupt { rank: 1, attempts, .. }) => {
                assert_eq!(*attempts, CHUNK_RETRY_LIMIT);
            }
            other => panic!("rank 1 should eject with Corrupt, got {other:?}"),
        }
        assert_eq!(results[1].1.retransmits, CHUNK_RETRY_LIMIT as u64);
        // rank 2 receives directly from the dead rank: its next recv is a
        // fast disconnect, not a timeout
        assert!(
            matches!(results[2].0, Err(OpError::Recv { .. })),
            "rank 2 should see the disconnect"
        );
        // rank 0 sat downstream of every forward already buffered before
        // the cut, so it drains them and completes deterministically
        let parts = results[0].0.as_ref().expect("rank 0 drains buffered forwards");
        assert_eq!(parts.len(), 3);
        // the survivors rebuild a smaller ring and the op goes through
        let redo = run_world(2, |mut c| c.all_gather_q8(&[c.rank() as f32; 8]).unwrap());
        assert_eq!(redo[0], redo[1]);
        assert_eq!(redo[0].len(), 2);
    }

    #[test]
    fn mixed_f32_and_quant_ops_keep_sequence() {
        let results = run_world(3, |mut c| {
            let a = c.all_gather(vec![c.rank() as f32]).unwrap();
            let b = c.all_reduce_sum_q(&[1.0, 2.0], 8).unwrap();
            let d = c.all_reduce_max(vec![c.rank() as f32]).unwrap();
            (a, b, d)
        });
        for (a, b, d) in results {
            assert_eq!(a.len(), 3);
            assert!((b[0] - 3.0).abs() < 0.05 && (b[1] - 6.0).abs() < 0.1);
            assert_eq!(d[0], 2.0);
        }
    }

    #[test]
    fn page_transfer_accounts_bytes_and_time() {
        let link = LinkModel::nvlink();
        let mut stats = CommStats::default();
        let codes: Vec<u8> = (0..1000u32).map(|i| (i % 251) as u8).collect();
        let params: Vec<f32> = (0..32).map(|i| i as f32 * 0.5).collect();
        let sent = transfer_quant_pages(&link, 3, None, &mut stats, 4, &[&codes], &[&params])
            .expect("clean link transfers");
        assert_eq!(sent, 1000 + 32 * 4);
        assert_eq!(stats.bytes_sent, sent);
        assert_eq!(stats.ops, 1);
        assert_eq!(stats.retransmits, 0);
        assert!(stats.sim_time_s > 0.0);
    }

    #[test]
    fn page_transfer_of_empty_lane_is_a_noop_op() {
        let link = LinkModel::tcp();
        let mut stats = CommStats::default();
        let sent = transfer_quant_pages(&link, 0, None, &mut stats, 8, &[], &[])
            .expect("nothing to ship is not an error");
        assert_eq!(sent, 0);
        assert_eq!(stats.bytes_sent, 0);
        assert_eq!(stats.ops, 1);
    }

    #[test]
    fn page_transfer_retry_heals_transient_corruption() {
        // a seed whose draw sequence is corrupt-then-clean, mirroring the
        // transfer's draws (victim_byte consumes one when corrupt)
        let seed = (0u64..)
            .find(|s| {
                let mut f = LinkFaults::new(0.5, *s);
                f.corrupt_next() && {
                    f.victim_byte(8);
                    !f.corrupt_next()
                }
            })
            .expect("some seed draws corrupt-then-clean");
        let link = LinkModel::nvlink();
        let mut stats = CommStats::default();
        let mut faults = LinkFaults::new(0.5, seed);
        let codes = vec![7u8; 64];
        let sent =
            transfer_quant_pages(&link, 0, Some(&mut faults), &mut stats, 8, &[&codes], &[])
                .expect("retry heals the chunk");
        assert_eq!(sent, 64);
        assert_eq!(stats.retransmits, 1, "exactly one retransmit");
        // wire bytes count the lane once; the retry re-pulls the original
        assert_eq!(stats.bytes_sent, 64);
    }

    #[test]
    fn page_transfer_ejects_on_persistent_corruption() {
        let link = LinkModel::nvlink();
        let mut stats = CommStats::default();
        let mut faults = LinkFaults::new(1.0, 7);
        let codes = vec![1u8; 128];
        let params = vec![0.5f32; 4];
        let err = transfer_quant_pages(
            &link,
            2,
            Some(&mut faults),
            &mut stats,
            8,
            &[&codes],
            &[&params],
        )
        .expect_err("permanent corruption must eject");
        match err {
            OpError::Corrupt { rank, op, attempts } => {
                assert_eq!(rank, 2);
                assert_eq!(op, "transfer_quant_pages");
                assert_eq!(attempts, CHUNK_RETRY_LIMIT);
            }
            other => panic!("expected Corrupt, got {other:?}"),
        }
        assert_eq!(stats.retransmits, CHUNK_RETRY_LIMIT as u64);
        // the transfer never completed: no op is recorded and callers
        // fall back to re-prefill on the destination
        assert_eq!(stats.ops, 0);
    }
}
