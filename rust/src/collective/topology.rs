//! Deployment topology: world size + transport selection with fallback.

use super::LinkModel;

/// Interconnect families the paper deploys over (§3.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Transport {
    /// NCCL over NVLink/RDMA ring (single-node multi-GPU)
    NvlinkRdma,
    /// NCCL over InfiniBand (multi-node HPC)
    Infiniband,
    /// TCP-based RPC fallback (edge / CPU-GPU hybrid)
    Tcp,
}

impl Transport {
    pub fn link(self) -> LinkModel {
        match self {
            Transport::NvlinkRdma => LinkModel::nvlink(),
            Transport::Infiniband => LinkModel::infiniband(),
            Transport::Tcp => LinkModel::tcp(),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Transport::NvlinkRdma => "nccl-nvlink",
            Transport::Infiniband => "nccl-ib",
            Transport::Tcp => "tcp-fallback",
        }
    }

    pub fn from_name(name: &str) -> Option<Self> {
        Some(match name {
            "nccl" | "nvlink" | "nccl-nvlink" => Transport::NvlinkRdma,
            "ib" | "infiniband" | "nccl-ib" => Transport::Infiniband,
            "tcp" | "tcp-fallback" => Transport::Tcp,
            _ => return None,
        })
    }
}

/// World description used by the coordinator and the cost model.
#[derive(Debug, Clone, Copy)]
pub struct Topology {
    pub world: usize,
    pub transport: Transport,
}

impl Topology {
    pub fn new(world: usize, transport: Transport) -> Self {
        assert!(world >= 1);
        Topology { world, transport }
    }

    /// The paper's headline testbed: 8xA100 over NVLink.
    pub fn single_node_8gpu() -> Self {
        Topology::new(8, Transport::NvlinkRdma)
    }

    /// Edge profile: one device, TCP to a host.
    pub fn edge() -> Self {
        Topology::new(1, Transport::Tcp)
    }

    pub fn link(&self) -> LinkModel {
        self.transport.link()
    }

    /// Transparent fallback (paper §3.3): NCCL paths degrade to TCP when
    /// the ring is unavailable (e.g. world size 1 on edge hardware keeps
    /// its transport; heterogeneous worlds drop to TCP).
    pub fn with_fallback(self, nccl_available: bool) -> Self {
        if nccl_available || self.transport == Transport::Tcp {
            self
        } else {
            Topology { transport: Transport::Tcp, ..self }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip() {
        for t in [Transport::NvlinkRdma, Transport::Infiniband, Transport::Tcp] {
            assert_eq!(Transport::from_name(t.name()), Some(t));
        }
    }

    #[test]
    fn fallback_switches_to_tcp() {
        let t = Topology::single_node_8gpu().with_fallback(false);
        assert_eq!(t.transport, Transport::Tcp);
        assert_eq!(t.world, 8);
        let kept = Topology::single_node_8gpu().with_fallback(true);
        assert_eq!(kept.transport, Transport::NvlinkRdma);
    }

    #[test]
    #[should_panic]
    fn zero_world_rejected() {
        Topology::new(0, Transport::Tcp);
    }
}
