//! Quantization backends — the Rust mirrors of every scheme in
//! `python/compile/quantizers.py` + the L1 kernels' offline halves.
//!
//! These run on the L3 side: static weight quantization when artifacts are
//! loaded (`prepare`), online activation/KV quantization in the serving hot
//! path (`ema`, `simquant` page re-encode), and the AWQ/GPTQ baselines for
//! the comparison tables. Rounding is half-to-even everywhere to stay
//! bit-identical with `jnp.round` (the golden files pin this).

mod awq;
mod ema;
mod gptq;
pub mod prepare;
mod schemes;

pub use awq::{awq_dequant, awq_quantize, AwqResult};
pub use ema::{EmaScaleTracker, EmaState};
pub use gptq::{gptq_dequant, gptq_quantize, GptqResult};
pub use schemes::*;

/// Signed symmetric integer range for a bitwidth: (qmin, qmax).
pub fn qrange(bits: u32) -> (i32, i32) {
    let qmax = (1i32 << (bits - 1)) - 1;
    (-qmax - 1, qmax)
}

/// `jnp.round` semantics: round half to even.
#[inline]
pub fn round_ties_even(x: f32) -> f32 {
    x.round_ties_even()
}

/// Quantization methods (paper §2 backends + baselines).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Variant {
    Fp,
    AbsMax,
    ZeroPoint,
    Sym8,
    Int8,
    Smooth,
    ZeroQuant,
    SimQuant,
    /// Baselines (weight prep only; served through the sym8 graphs).
    Awq,
    Gptq,
}

impl Variant {
    pub fn name(self) -> &'static str {
        match self {
            Variant::Fp => "fp",
            Variant::AbsMax => "absmax",
            Variant::ZeroPoint => "zeropoint",
            Variant::Sym8 => "sym8",
            Variant::Int8 => "int8",
            Variant::Smooth => "smooth",
            Variant::ZeroQuant => "zeroquant",
            Variant::SimQuant => "simquant",
            Variant::Awq => "awq",
            Variant::Gptq => "gptq",
        }
    }

    pub fn from_name(name: &str) -> Option<Self> {
        Some(match name {
            "fp" | "fp16" => Variant::Fp,
            "absmax" => Variant::AbsMax,
            "zeropoint" => Variant::ZeroPoint,
            "sym8" => Variant::Sym8,
            "int8" => Variant::Int8,
            "smooth" | "smoothquant" => Variant::Smooth,
            "zeroquant" => Variant::ZeroQuant,
            "simquant" => Variant::SimQuant,
            "awq" => Variant::Awq,
            "gptq" => Variant::Gptq,
            _ => return None,
        })
    }

    /// Which lowered graph family serves this variant. AWQ/GPTQ are
    /// weight-only: int8 codes in storage, dequantized f32 on the wire —
    /// they execute through the fp graphs.
    pub fn graph_variant(self) -> &'static str {
        match self {
            Variant::Awq | Variant::Gptq => "fp",
            v => v.name(),
        }
    }

    /// All method variants in table order.
    pub fn all() -> &'static [Variant] {
        &[
            Variant::Fp,
            Variant::AbsMax,
            Variant::ZeroPoint,
            Variant::Sym8,
            Variant::Int8,
            Variant::Smooth,
            Variant::ZeroQuant,
            Variant::SimQuant,
            Variant::Awq,
            Variant::Gptq,
        ]
    }

    /// Effective weight bits (for memory accounting).
    pub fn weight_bits(self) -> u32 {
        match self {
            Variant::Fp => 32,
            _ => 8,
        }
    }

    /// Whether activations are quantized on the fly (W8A8-style).
    pub fn quantizes_activations(self) -> bool {
        matches!(
            self,
            Variant::Int8 | Variant::Smooth | Variant::ZeroQuant | Variant::SimQuant
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qrange_values() {
        assert_eq!(qrange(8), (-128, 127));
        assert_eq!(qrange(4), (-8, 7));
        assert_eq!(qrange(2), (-2, 1));
    }

    #[test]
    fn ties_even() {
        assert_eq!(round_ties_even(0.5), 0.0);
        assert_eq!(round_ties_even(1.5), 2.0);
        assert_eq!(round_ties_even(-0.5), 0.0);
        assert_eq!(round_ties_even(2.5), 2.0);
    }

    #[test]
    fn variant_names_roundtrip() {
        for v in Variant::all() {
            assert_eq!(Variant::from_name(v.name()), Some(*v));
        }
    }

    #[test]
    fn baseline_graph_mapping() {
        assert_eq!(Variant::Awq.graph_variant(), "fp");
        assert_eq!(Variant::Gptq.graph_variant(), "fp");
        assert_eq!(Variant::Smooth.graph_variant(), "smooth");
    }
}
