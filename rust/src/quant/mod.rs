//! Quantization backends — the Rust mirrors of every scheme in
//! `python/compile/quantizers.py` + the L1 kernels' offline halves.
//!
//! These run on the L3 side: static weight quantization when artifacts are
//! loaded (`prepare`), online activation/KV quantization in the serving hot
//! path (`ema`, `simquant` page re-encode), and the AWQ/GPTQ baselines for
//! the comparison tables. Rounding is half-to-even everywhere to stay
//! bit-identical with `jnp.round` (the golden files pin this).
//!
//! # Hot-path architecture (`kernels`)
//!
//! The serving hot path never calls the tuple-returning schemes; it calls
//! the fused `_into` kernels in [`kernels`] with caller-owned buffers:
//!
//! * **Buffer-reuse contract** — `*_into(src, dims.., bits, out_codes,
//!   out_scales)` writes into exactly-sized caller buffers and allocates
//!   no O(K*N) memory. Callers keep the buffers alive across calls
//!   (`KvCache` encodes straight into its own code/param pages;
//!   `awq_quantize` reuses one scratch set across its whole alpha grid).
//!   Wrong buffer lengths and invalid bitwidths (signed schemes: 2..=8,
//!   since `bits == 1` makes `qmax == 0`; SimQuant's unsigned scheme:
//!   1..=8) are errors, not UB or `inf` scales.
//! * **Bit-exactness invariant** — the fast kernels are bit-identical to
//!   the pinned scalar reference (`quant::reference`, the Python-parity
//!   semantics) for every shape and every thread count. Per-element math
//!   is unchanged (half-to-even rounding, division — never a reciprocal
//!   multiply); parallel column reductions combine per-row-range partials
//!   in range order, which f32 min/max associativity makes exact.
//!   `tests/kernel_equivalence.rs` enforces this property-style; golden
//!   files pin the Python side.
//! * **Parallelism** — row ranges fan out over `util::pool`'s persistent
//!   parked-worker pool (no per-call thread spawn), capped by
//!   `LLEQ_THREADS` (default: available parallelism). Inputs under ~32K
//!   elements stay single-threaded.
//! * **Sub-byte packing** — the storage/wire layer packs 2/4-bit codes to
//!   their true width (`pack_i8_into` / `token_quantize_packed_into`);
//!   `packed_len` is the shared byte-accounting helper.
//!
//! Measure it with `cargo bench --bench perf_hotpath` (from `rust/`):
//! every row prints mean/p95 in µs and the run also writes
//! `BENCH_hotpath.json` at the repo root — `[{"name", "mean_us",
//! "p95_us"}, ...]` — so successive PRs can diff the perf trajectory.
//! Rows that need PJRT artifacts are skipped (with a note) unless the
//! crate is built with `--features xla`.

mod awq;
mod ema;
mod gptq;
pub mod kernels;
pub mod prepare;
mod schemes;

pub use awq::{awq_dequant, awq_quantize, AwqResult};
pub use ema::{EmaScaleTracker, EmaState};
pub use gptq::{gptq_dequant, gptq_quantize, GptqResult};
pub use kernels::reference;
pub use kernels::{
    pack_i8_into, pack_u8_into, packed_len, scale_rows_into, simquant_decode_into,
    simquant_encode_into, simquant_encode_into_threads, simquant_encode_with_params_into,
    symmetric_quantize_channel_into, symmetric_quantize_channel_into_threads,
    token_dequantize_packed_into, token_quantize_into, token_quantize_into_threads,
    token_quantize_packed_into, unpack_i8_into, unpack_u8_into, validate_bits,
    validate_pack_bits, validate_simquant_bits, zeroquant_group_quantize_into,
    zeroquant_group_quantize_into_threads,
};
pub use schemes::*;

/// Signed symmetric integer range for a bitwidth: (qmin, qmax).
pub fn qrange(bits: u32) -> (i32, i32) {
    let qmax = (1i32 << (bits - 1)) - 1;
    (-qmax - 1, qmax)
}

/// `jnp.round` semantics: round half to even.
#[inline]
pub fn round_ties_even(x: f32) -> f32 {
    x.round_ties_even()
}

/// Quantization methods (paper §2 backends + baselines).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Variant {
    Fp,
    AbsMax,
    ZeroPoint,
    Sym8,
    Int8,
    Smooth,
    ZeroQuant,
    SimQuant,
    /// Baselines (weight prep only; served through the sym8 graphs).
    Awq,
    Gptq,
}

impl Variant {
    pub fn name(self) -> &'static str {
        match self {
            Variant::Fp => "fp",
            Variant::AbsMax => "absmax",
            Variant::ZeroPoint => "zeropoint",
            Variant::Sym8 => "sym8",
            Variant::Int8 => "int8",
            Variant::Smooth => "smooth",
            Variant::ZeroQuant => "zeroquant",
            Variant::SimQuant => "simquant",
            Variant::Awq => "awq",
            Variant::Gptq => "gptq",
        }
    }

    pub fn from_name(name: &str) -> Option<Self> {
        Some(match name {
            "fp" | "fp16" => Variant::Fp,
            "absmax" => Variant::AbsMax,
            "zeropoint" => Variant::ZeroPoint,
            "sym8" => Variant::Sym8,
            "int8" => Variant::Int8,
            "smooth" | "smoothquant" => Variant::Smooth,
            "zeroquant" => Variant::ZeroQuant,
            "simquant" => Variant::SimQuant,
            "awq" => Variant::Awq,
            "gptq" => Variant::Gptq,
            _ => return None,
        })
    }

    /// Which lowered graph family serves this variant. AWQ/GPTQ are
    /// weight-only: int8 codes in storage, dequantized f32 on the wire —
    /// they execute through the fp graphs.
    pub fn graph_variant(self) -> &'static str {
        match self {
            Variant::Awq | Variant::Gptq => "fp",
            v => v.name(),
        }
    }

    /// All method variants in table order.
    pub fn all() -> &'static [Variant] {
        &[
            Variant::Fp,
            Variant::AbsMax,
            Variant::ZeroPoint,
            Variant::Sym8,
            Variant::Int8,
            Variant::Smooth,
            Variant::ZeroQuant,
            Variant::SimQuant,
            Variant::Awq,
            Variant::Gptq,
        ]
    }

    /// Effective weight bits (for memory accounting).
    pub fn weight_bits(self) -> u32 {
        match self {
            Variant::Fp => 32,
            _ => 8,
        }
    }

    /// Whether activations are quantized on the fly (W8A8-style).
    pub fn quantizes_activations(self) -> bool {
        matches!(
            self,
            Variant::Int8 | Variant::Smooth | Variant::ZeroQuant | Variant::SimQuant
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qrange_values() {
        assert_eq!(qrange(8), (-128, 127));
        assert_eq!(qrange(4), (-8, 7));
        assert_eq!(qrange(2), (-2, 1));
    }

    #[test]
    fn ties_even() {
        assert_eq!(round_ties_even(0.5), 0.0);
        assert_eq!(round_ties_even(1.5), 2.0);
        assert_eq!(round_ties_even(-0.5), 0.0);
        assert_eq!(round_ties_even(2.5), 2.0);
    }

    #[test]
    fn variant_names_roundtrip() {
        for v in Variant::all() {
            assert_eq!(Variant::from_name(v.name()), Some(*v));
        }
    }

    #[test]
    fn baseline_graph_mapping() {
        assert_eq!(Variant::Awq.graph_variant(), "fp");
        assert_eq!(Variant::Gptq.graph_variant(), "fp");
        assert_eq!(Variant::Smooth.graph_variant(), "smooth");
    }
}
