//! Static weight preparation: turn an f32 checkpoint + calibration stats
//! into the runtime input tensors each lowered graph expects.
//!
//! Mirrors `python/compile/quantizers.py::prepare_linear` bit-for-bit (the
//! golden contract test in `tests/golden_contract.rs` pins this). The AWQ
//! and GPTQ baselines store int8 codes but are *served* through the fp
//! graph with dequantized weights (weight-only quantization: storage is
//! 8-bit, compute is f32 — exactly how 4-bit weight-only methods deploy).

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

use crate::tensor::{DType, Tensor};

use super::{
    awq_dequant, awq_quantize, gptq_dequant, gptq_quantize, schemes, Variant,
};

/// One runtime graph input: name + shape + dtype (from the manifest).
#[derive(Debug, Clone, PartialEq)]
pub struct InputSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

/// Checkpoint + calibration container (contents of <model>.weights.bin).
pub struct Checkpoint {
    pub tensors: BTreeMap<String, Tensor>,
}

impl Checkpoint {
    pub fn new(tensors: BTreeMap<String, Tensor>) -> Self {
        Checkpoint { tensors }
    }

    pub fn f32(&self, name: &str) -> Result<Vec<f32>> {
        Ok(self.f32_view(name)?.to_vec())
    }

    /// Zero-copy borrow of an f32 tensor (the quantizers read weights and
    /// calibration stats in place; nothing in `prepare` needs a clone).
    pub fn f32_view(&self, name: &str) -> Result<&[f32]> {
        self.tensors
            .get(name)
            .ok_or_else(|| anyhow!("checkpoint missing tensor {name}"))?
            .f32_view()
    }

    pub fn shape(&self, name: &str) -> Result<&[usize]> {
        Ok(&self
            .tensors
            .get(name)
            .ok_or_else(|| anyhow!("checkpoint missing tensor {name}"))?
            .shape)
    }

    fn calib_view(&self, linear: &str, stat: &str) -> Result<&[f32]> {
        self.f32_view(&format!("calib.{linear}.{stat}"))
            .with_context(|| format!("calibration stats for {linear}"))
    }
}

/// Total parameter bytes a variant stores (weights only) — memory tables.
pub fn weight_storage_bytes(variant: Variant, specs: &[InputSpec]) -> usize {
    let mut total = 0usize;
    for s in specs {
        let elems: usize = s.shape.iter().product();
        total += match variant {
            // AWQ/GPTQ: int8 codes + per-column f32 scales stored host-side
            Variant::Awq | Variant::Gptq if s.name.ends_with(".w") => {
                elems + s.shape[s.shape.len() - 1] * 4
            }
            _ => elems * s.dtype.itemsize(),
        };
    }
    total
}

/// Prepare all graph inputs in manifest order.
pub fn prepare_inputs(
    variant: Variant,
    specs: &[InputSpec],
    ckpt: &Checkpoint,
    zq_group: usize,
    sq_alpha: f32,
) -> Result<Vec<Tensor>> {
    // cache per-linear preparation so qkv/fc1/... are quantized once even
    // though they contribute several entries
    let mut cache: BTreeMap<String, BTreeMap<String, Tensor>> = BTreeMap::new();
    let mut out = Vec::with_capacity(specs.len());
    for spec in specs {
        let parts: Vec<&str> = spec.name.split('.').collect();
        let tensor = if parts.len() <= 2 {
            // global embedding / norm / bias: straight f32 passthrough
            let t = ckpt
                .tensors
                .get(&spec.name)
                .ok_or_else(|| anyhow!("checkpoint missing {}", spec.name))?
                .clone();
            t.reshape(spec.shape.clone())?
        } else {
            let linear = format!("{}.{}", parts[0], parts[1]);
            let suffix = parts[2];
            if !cache.contains_key(&linear) {
                let prepared = prepare_linear(variant, &linear, ckpt, zq_group, sq_alpha)?;
                cache.insert(linear.clone(), prepared);
            }
            let t = cache[&linear]
                .get(suffix)
                .ok_or_else(|| anyhow!("{variant:?} produced no entry {suffix} for {linear}"))?
                .clone();
            t.reshape(spec.shape.clone())?
        };
        if tensor.dtype != spec.dtype {
            bail!(
                "dtype mismatch for {}: prepared {:?}, manifest wants {:?}",
                spec.name,
                tensor.dtype,
                spec.dtype
            );
        }
        out.push(tensor);
    }
    Ok(out)
}

/// Quantize one linear's weight for `variant`, producing its entry map.
pub fn prepare_linear(
    variant: Variant,
    linear: &str,
    ckpt: &Checkpoint,
    zq_group: usize,
    sq_alpha: f32,
) -> Result<BTreeMap<String, Tensor>> {
    let wname = format!("{linear}_w");
    let shape = ckpt.shape(&wname)?.to_vec();
    let (k, n) = (shape[0], shape[1]);
    // zero-copy borrow: the quantizers read the checkpoint weight in place
    let w = ckpt.f32_view(&wname)?;
    let mut m = BTreeMap::new();
    match variant {
        Variant::Fp => {
            m.insert("w".into(), Tensor::from_f32_slice(vec![k, n], w));
        }
        Variant::AbsMax => {
            let (q, delta) = schemes::absmax_quantize(w, 8)?;
            m.insert("w_q".into(), Tensor::from_i8(vec![k, n], q));
            m.insert("w_delta".into(), Tensor::from_f32(vec![1, n], vec![delta; n]));
        }
        Variant::ZeroPoint => {
            let (q, scale, zp) = schemes::zeropoint_quantize(w, 8)?;
            m.insert("w_q".into(), Tensor::from_i8(vec![k, n], q));
            m.insert("w_scale".into(), Tensor::from_f32(vec![1], vec![scale]));
            m.insert("w_zp".into(), Tensor::from_f32(vec![1], vec![zp]));
        }
        Variant::Sym8 | Variant::Int8 | Variant::SimQuant => {
            let (q, delta) = schemes::symmetric_quantize_channel(w, k, n, 8)?;
            m.insert("w_q".into(), Tensor::from_i8(vec![k, n], q));
            m.insert("w_delta".into(), Tensor::from_f32(vec![1, n], delta));
        }
        Variant::Smooth => {
            let absmax = ckpt.calib_view(linear, "absmax")?;
            let s = schemes::smoothquant_scales(absmax, w, k, n, sq_alpha);
            let mut ws = vec![0f32; k * n];
            super::kernels::scale_rows_into(w, &s, n, &mut ws);
            let (q, delta) = schemes::symmetric_quantize_channel(&ws, k, n, 8)?;
            m.insert("s".into(), Tensor::from_f32(vec![1, k], s));
            m.insert("w_q".into(), Tensor::from_i8(vec![k, n], q));
            m.insert("w_delta".into(), Tensor::from_f32(vec![1, n], delta));
        }
        Variant::ZeroQuant => {
            let g = if k % zq_group == 0 { zq_group } else { k };
            let (q, delta) = schemes::zeroquant_group_quantize(w, k, n, g, 8)?;
            m.insert("w_q".into(), Tensor::from_i8(vec![k, n], q));
            m.insert("g_delta".into(), Tensor::from_f32(vec![k / g, 1, n], delta));
        }
        Variant::Awq => {
            let meanabs = ckpt.calib_view(linear, "meanabs")?;
            let sqsum = ckpt.calib_view(linear, "sqsum")?;
            let count = ckpt
                .tensors
                .get(&format!("calib.{linear}.count"))
                .and_then(|t| t.as_i32().ok())
                .map(|v| v[0].max(1) as f32)
                .unwrap_or(1.0);
            let ex2: Vec<f32> = sqsum.iter().map(|s| s / count).collect();
            let r = awq_quantize(w, k, n, meanabs, &ex2, 8)?;
            m.insert("w".into(), Tensor::from_f32(vec![k, n], awq_dequant(&r, k, n)));
        }
        Variant::Gptq => {
            let sqsum = ckpt.calib_view(linear, "sqsum")?;
            let r = gptq_quantize(w, k, n, sqsum, 8, true)?;
            m.insert("w".into(), Tensor::from_f32(vec![k, n], gptq_dequant(&r, k, n)));
        }
    }
    Ok(m)
}

/// Reconstruct the effective f32 weight a prepared linear encodes — used by
/// the weight-distribution figure and error analyses.
pub fn effective_weight(
    variant: Variant,
    prepared: &BTreeMap<String, Tensor>,
    k: usize,
    n: usize,
    zq_group: usize,
) -> Result<Vec<f32>> {
    Ok(match variant {
        Variant::Fp | Variant::Awq | Variant::Gptq => prepared["w"].as_f32()?,
        Variant::AbsMax | Variant::Sym8 | Variant::Int8 | Variant::SimQuant => {
            let q = prepared["w_q"].i8_view()?;
            let delta = prepared["w_delta"].f32_view()?;
            schemes::symmetric_dequantize_channel(q, delta, k, n)
        }
        Variant::ZeroPoint => {
            let q = prepared["w_q"].i8_view()?;
            let scale = prepared["w_scale"].f32_view()?[0];
            let zp = prepared["w_zp"].f32_view()?[0];
            schemes::zeropoint_dequantize(q, scale, zp)
        }
        Variant::Smooth => {
            let q = prepared["w_q"].i8_view()?;
            let delta = prepared["w_delta"].f32_view()?;
            let s = prepared["s"].f32_view()?;
            let mut w = schemes::symmetric_dequantize_channel(q, delta, k, n);
            for (wrow, sv) in w.chunks_exact_mut(n).zip(s) {
                for v in wrow.iter_mut() {
                    *v /= sv;
                }
            }
            w
        }
        Variant::ZeroQuant => {
            let q = prepared["w_q"].i8_view()?;
            let delta = prepared["g_delta"].f32_view()?;
            let g = if k % zq_group == 0 { zq_group } else { k };
            schemes::zeroquant_group_dequantize(q, delta, k, n, g)
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::XorShift64Star;

    fn fake_ckpt(k: usize, n: usize) -> Checkpoint {
        let mut r = XorShift64Star::new(11);
        let w: Vec<f32> = (0..k * n).map(|_| r.next_normal() as f32 * 0.1).collect();
        let mut m = BTreeMap::new();
        m.insert("h0.qkv_w".into(), Tensor::from_f32(vec![k, n], w));
        m.insert(
            "calib.h0.qkv.absmax".into(),
            Tensor::from_f32(vec![k], (0..k).map(|i| 0.5 + i as f32 * 0.01).collect()),
        );
        m.insert(
            "calib.h0.qkv.meanabs".into(),
            Tensor::from_f32(vec![k], vec![0.3; k]),
        );
        m.insert(
            "calib.h0.qkv.sqsum".into(),
            Tensor::from_f32(vec![k], vec![10.0; k]),
        );
        m.insert("calib.h0.qkv.count".into(), Tensor::from_i32(vec![1], vec![128]));
        m.insert("wte".into(), Tensor::from_f32(vec![4, 2], vec![0.0; 8]));
        Checkpoint::new(m)
    }

    #[test]
    fn every_variant_prepares() {
        let ckpt = fake_ckpt(64, 32);
        for v in Variant::all() {
            let m = prepare_linear(*v, "h0.qkv", &ckpt, 64, 0.5).unwrap();
            assert!(!m.is_empty(), "{v:?}");
            let w = effective_weight(*v, &m, 64, 32, 64).unwrap();
            let orig = ckpt.f32("h0.qkv_w").unwrap();
            let max_err = w
                .iter()
                .zip(&orig)
                .map(|(a, b)| (a - b).abs())
                .fold(0f32, f32::max);
            assert!(max_err < 0.05, "{v:?} err {max_err}");
        }
    }

    #[test]
    fn prepare_inputs_orders_and_types() {
        let ckpt = fake_ckpt(64, 32);
        let specs = vec![
            InputSpec { name: "wte".into(), shape: vec![4, 2], dtype: DType::F32 },
            InputSpec { name: "h0.qkv.w_q".into(), shape: vec![64, 32], dtype: DType::I8 },
            InputSpec { name: "h0.qkv.w_delta".into(), shape: vec![1, 32], dtype: DType::F32 },
        ];
        let out = prepare_inputs(Variant::Sym8, &specs, &ckpt, 64, 0.5).unwrap();
        assert_eq!(out.len(), 3);
        assert_eq!(out[0].dtype, DType::F32);
        assert_eq!(out[1].dtype, DType::I8);
        assert_eq!(out[1].shape, vec![64, 32]);
    }

    #[test]
    fn missing_calib_fails_smooth() {
        let mut ckpt = fake_ckpt(8, 4);
        ckpt.tensors.remove("calib.h0.qkv.absmax");
        assert!(prepare_linear(Variant::Smooth, "h0.qkv", &ckpt, 64, 0.5).is_err());
    }

    #[test]
    fn storage_accounting_counts_int8() {
        let specs = vec![
            InputSpec { name: "h0.qkv.w_q".into(), shape: vec![64, 32], dtype: DType::I8 },
            InputSpec { name: "h0.qkv.w_delta".into(), shape: vec![1, 32], dtype: DType::F32 },
        ];
        assert_eq!(weight_storage_bytes(Variant::Sym8, &specs), 64 * 32 + 32 * 4);
        // fp stores the same linear as f32
        let fp_specs = vec![InputSpec {
            name: "h0.qkv.w".into(),
            shape: vec![64, 32],
            dtype: DType::F32,
        }];
        assert_eq!(weight_storage_bytes(Variant::Fp, &fp_specs), 64 * 32 * 4);
    }
}
