//! GPTQ baseline: error-compensated column rounding, diagonal Hessian.
//!
//! Mirrors `python/compile/quantizers.py::gptq_quantize` (the substitution
//! for full-Hessian GPTQ is documented in DESIGN.md §3): input channels are
//! processed in decreasing diag(X^T X) order; each channel's rounding
//! residual is carried onto the remaining channels proportionally to their
//! Hessian mass, preserving the error-feedback structure that separates
//! GPTQ from round-to-nearest.

use anyhow::Result;

use super::kernels::validate_bits;
use super::{qrange, round_ties_even};

#[derive(Debug, Clone)]
pub struct GptqResult {
    /// int8 codes, [K, N]
    pub q: Vec<i8>,
    /// per-output-channel scales, `[N]`
    pub delta: Vec<f32>,
    /// channel processing order, `[K]`
    pub order: Vec<usize>,
}

/// Quantize w `[K, N]` with diag-Hessian error feedback.
/// `h_diag` = `sum_t X[t,j]^2` from calibration (`[K]`).
pub fn gptq_quantize(
    w: &[f32],
    k: usize,
    n: usize,
    h_diag: &[f32],
    bits: u32,
    permute: bool,
) -> Result<GptqResult> {
    validate_bits(bits)?;
    let (qmin, qmax) = qrange(bits);
    let h: Vec<f32> = h_diag.iter().map(|v| v.max(1e-8)).collect();
    let mut order: Vec<usize> = (0..k).collect();
    if permute {
        order.sort_by(|&a, &b| h[b].partial_cmp(&h[a]).unwrap());
    }

    // per-output-channel scale from the original weights
    let mut delta = vec![0f32; n];
    for row in 0..k {
        for col in 0..n {
            delta[col] = delta[col].max(w[row * n + col].abs());
        }
    }
    for d in &mut delta {
        *d = d.max(1e-8) / qmax as f32;
    }

    let inv_h_total = 1.0 / order.iter().map(|&j| h[j]).sum::<f32>();
    let mut q = vec![0i8; k * n];
    let mut err_carry = vec![0f32; n];
    for &j in &order {
        let share = h[j] * inv_h_total;
        for col in 0..n {
            let wj = w[j * n + col] + err_carry[col] * share;
            let qj = round_ties_even(wj / delta[col]).clamp(qmin as f32, qmax as f32);
            q[j * n + col] = qj as i8;
            err_carry[col] += wj - qj * delta[col];
            err_carry[col] -= err_carry[col] * share;
        }
    }
    Ok(GptqResult { q, delta, order })
}

pub fn gptq_dequant(r: &GptqResult, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0f32; k * n];
    for row in 0..k {
        for col in 0..n {
            out[row * n + col] = r.q[row * n + col] as f32 * r.delta[col];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::XorShift64Star;

    fn weighted_err(w: &[f32], dw: &[f32], h: &[f32], k: usize, n: usize) -> f64 {
        let mut err = 0f64;
        for row in 0..k {
            for col in 0..n {
                let e = (w[row * n + col] - dw[row * n + col]) as f64;
                err += e * e * h[row] as f64;
            }
        }
        err
    }

    #[test]
    fn error_feedback_helps_at_low_bits() {
        let mut r = XorShift64Star::new(5);
        let (k, n) = (64, 16);
        let w: Vec<f32> = (0..k * n).map(|_| r.next_normal() as f32).collect();
        let h: Vec<f32> = (0..k).map(|_| (r.next_f64() * 10.0 + 0.1) as f32).collect();
        let g = gptq_quantize(&w, k, n, &h, 3, true).unwrap();
        let dw = gptq_dequant(&g, k, n);
        // round-to-nearest with the same scales
        let mut rtn = vec![0f32; k * n];
        for row in 0..k {
            for col in 0..n {
                let q = round_ties_even(w[row * n + col] / g.delta[col]).clamp(-4.0, 3.0);
                rtn[row * n + col] = q * g.delta[col];
            }
        }
        let e_gptq = weighted_err(&w, &dw, &h, k, n);
        let e_rtn = weighted_err(&w, &rtn, &h, k, n);
        // total (unweighted elementwise) error may grow, but the
        // Hessian-weighted objective must not be much worse, and typically
        // improves; allow slack for randomness
        assert!(e_gptq <= e_rtn * 1.05, "gptq {e_gptq} vs rtn {e_rtn}");
    }

    #[test]
    fn order_is_by_decreasing_hessian() {
        let w = vec![0f32; 4 * 2];
        let h = vec![1.0, 5.0, 3.0, 0.5];
        let g = gptq_quantize(&w, 4, 2, &h, 8, true).unwrap();
        assert_eq!(g.order, vec![1, 2, 0, 3]);
    }

    #[test]
    fn no_permute_keeps_natural_order() {
        let w = vec![0f32; 3 * 2];
        let g = gptq_quantize(&w, 3, 2, &[1.0, 2.0, 3.0], 8, false).unwrap();
        assert_eq!(g.order, vec![0, 1, 2]);
    }

    #[test]
    fn invalid_bits_rejected() {
        assert!(gptq_quantize(&[0.0; 4], 2, 2, &[1.0, 1.0], 1, true).is_err());
    }

    #[test]
    fn dequant_close_at_8bit() {
        let mut r = XorShift64Star::new(8);
        let (k, n) = (32, 8);
        let w: Vec<f32> = (0..k * n).map(|_| r.next_normal() as f32 * 0.05).collect();
        let h = vec![1.0f32; k];
        let g = gptq_quantize(&w, k, n, &h, 8, true).unwrap();
        let dw = gptq_dequant(&g, k, n);
        let max_err = w
            .iter()
            .zip(&dw)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        // 8-bit with error carry: worst case ~1.5 steps
        let max_step = g.delta.iter().cloned().fold(0f32, f32::max);
        assert!(max_err <= max_step * 2.0, "max_err {max_err}");
    }
}
