//! AWQ baseline: activation-aware weight quantization (Lin et al. 2024).
//!
//! Mirrors `python/compile/quantizers.py::awq_quantize`: grid-search the
//! scaling exponent alpha over per-channel factors s_j = meanabs_j^alpha,
//! quantize W*s per output channel, keep the alpha minimizing the
//! diagonal-covariance-weighted reconstruction error. The scaled weight,
//! code, and scale buffers are reused across the whole alpha grid via the
//! `_into` kernels (one allocation set instead of one per alpha).

use anyhow::Result;

use super::kernels::{scale_rows_into, symmetric_quantize_channel_into};

#[derive(Debug, Clone)]
pub struct AwqResult {
    /// int8 codes of W*s, [K, N]
    pub q: Vec<i8>,
    /// per-output-channel scales, `[N]`
    pub delta: Vec<f32>,
    /// per-input-channel smoothing factors, `[K]`
    pub s: Vec<f32>,
    /// chosen exponent
    pub alpha: f32,
    /// weighted reconstruction error at the chosen alpha
    pub err: f64,
}

const ALPHAS: [f32; 5] = [0.0, 0.25, 0.5, 0.75, 1.0];

/// Quantize w `[K, N]` given calibration meanabs `[K]` and `E[x^2]` proxy `[K]`.
pub fn awq_quantize(
    w: &[f32],
    k: usize,
    n: usize,
    act_meanabs: &[f32],
    act_ex2: &[f32],
    bits: u32,
) -> Result<AwqResult> {
    let mut ws = vec![0f32; k * n];
    let mut q = vec![0i8; k * n];
    let mut delta = vec![0f32; n];
    // track only (alpha, err, s) during the grid; re-encode the winner
    // once at the end instead of cloning the k*n codes per improvement
    let mut best: Option<(f32, f64, Vec<f32>)> = None;
    for &alpha in &ALPHAS {
        let s: Vec<f32> = act_meanabs
            .iter()
            .map(|m| m.max(1e-8).powf(alpha).max(1e-8))
            .collect();
        scale_rows_into(w, &s, n, &mut ws);
        symmetric_quantize_channel_into(&ws, k, n, bits, &mut q, &mut delta)?;
        // err = sum_jk (w_hat - w)^2 * E[x_j^2]
        let mut err = 0f64;
        for row in 0..k {
            for col in 0..n {
                let w_hat = q[row * n + col] as f32 * delta[col] / s[row];
                let e = (w_hat - w[row * n + col]) as f64;
                err += e * e * act_ex2[row] as f64;
            }
        }
        let improved = match &best {
            None => true,
            Some((_, best_err, _)) => err < *best_err,
        };
        if improved {
            best = Some((alpha, err, s));
        }
    }
    let (alpha, err, s) = best.expect("non-empty alpha grid");
    if alpha != *ALPHAS.last().expect("non-empty alpha grid") {
        // q/delta currently hold the last alpha's encode; redo the winner
        scale_rows_into(w, &s, n, &mut ws);
        symmetric_quantize_channel_into(&ws, k, n, bits, &mut q, &mut delta)?;
    }
    Ok(AwqResult { q, delta, s, alpha, err })
}

/// Reconstruct the effective f32 weight AWQ encodes.
pub fn awq_dequant(r: &AwqResult, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0f32; k * n];
    for row in 0..k {
        for col in 0..n {
            out[row * n + col] = r.q[row * n + col] as f32 * r.delta[col] / r.s[row];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::XorShift64Star;
    use crate::quant::schemes::symmetric_quantize_channel;

    fn setup(k: usize, n: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut r = XorShift64Star::new(seed);
        let w: Vec<f32> = (0..k * n).map(|_| r.next_normal() as f32 * 0.1).collect();
        // activation stats with one dominant channel (the AWQ motivation)
        let mut meanabs = vec![1.0f32; k];
        let mut ex2 = vec![1.0f32; k];
        meanabs[0] = 50.0;
        ex2[0] = 2500.0;
        (w, meanabs, ex2)
    }

    #[test]
    fn beats_plain_symmetric_on_outlier_channels() {
        let (w, meanabs, ex2) = setup(16, 8, 1);
        let r = awq_quantize(&w, 16, 8, &meanabs, &ex2, 4).unwrap(); // 4-bit stresses it
        // plain symmetric (alpha = 0)
        let (q0, d0) = symmetric_quantize_channel(&w, 16, 8, 4).unwrap();
        let mut err0 = 0f64;
        for row in 0..16 {
            for col in 0..8 {
                let w_hat = q0[row * 8 + col] as f32 * d0[col];
                let e = (w_hat - w[row * 8 + col]) as f64;
                err0 += e * e * ex2[row] as f64;
            }
        }
        assert!(r.err <= err0 + 1e-12, "awq {} vs plain {}", r.err, err0);
    }

    #[test]
    fn dequant_close_to_original() {
        let (w, meanabs, ex2) = setup(32, 16, 2);
        let r = awq_quantize(&w, 32, 16, &meanabs, &ex2, 8).unwrap();
        let dw = awq_dequant(&r, 32, 16);
        let max_err = w
            .iter()
            .zip(&dw)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(max_err < 0.01, "max_err {max_err}");
    }

    #[test]
    fn uniform_stats_picks_low_alpha_cost() {
        // with uniform activation stats, all alphas are near-equivalent;
        // just assert it runs and yields finite error
        let (w, _, _) = setup(8, 8, 3);
        let r = awq_quantize(&w, 8, 8, &[1.0; 8], &[1.0; 8], 8).unwrap();
        assert!(r.err.is_finite());
        assert!(ALPHAS.contains(&r.alpha));
    }

    #[test]
    fn invalid_bits_rejected() {
        let (w, meanabs, ex2) = setup(4, 4, 4);
        assert!(awq_quantize(&w, 4, 4, &meanabs, &ex2, 1).is_err());
    }
}
