//! Core quantization schemes over row-major f32 matrices [K, N].
//!
//! Bit-identical mirrors of `python/compile/kernels/ref.py` (same eps,
//! same clip-after-round order, half-to-even rounding). The matrix
//! schemes are thin allocate-then-encode wrappers over the fused,
//! thread-parallel `_into` kernels in `quant::kernels`; the hot path
//! calls those directly with reused buffers. Every quantize entry point
//! validates its bitwidth (2..=8) and returns a proper error instead of
//! silently producing `inf` scales (the `bits == 1` ⇒ `qmax == 0` trap).

use anyhow::Result;

use super::kernels::{
    simquant_decode_into, simquant_encode_into, symmetric_quantize_channel_into,
    token_quantize_into, validate_bits, zeroquant_group_quantize_into, EPS,
};
use super::{qrange, round_ties_even};

// ---------------------------------------------------------------------------
// AbsMax (per-tensor symmetric)
// ---------------------------------------------------------------------------

/// Per-tensor absmax scale: delta = max(absmax(x), eps) / qmax.
pub fn absmax_scale(x: &[f32], bits: u32) -> Result<f32> {
    validate_bits(bits)?;
    let (_, qmax) = qrange(bits);
    let amax = x.iter().fold(0f32, |a, v| a.max(v.abs()));
    Ok(amax.max(EPS) / qmax as f32)
}

/// Per-tensor absmax quantization. Returns (codes, delta).
pub fn absmax_quantize(x: &[f32], bits: u32) -> Result<(Vec<i8>, f32)> {
    // validate (via absmax_scale) before qrange: qrange(0) would underflow
    let delta = absmax_scale(x, bits)?;
    let (qmin, qmax) = qrange(bits);
    let q = x
        .iter()
        .map(|v| round_ties_even(v / delta).clamp(qmin as f32, qmax as f32) as i8)
        .collect();
    Ok((q, delta))
}

pub fn absmax_dequantize(q: &[i8], delta: f32) -> Vec<f32> {
    q.iter().map(|v| *v as f32 * delta).collect()
}

// ---------------------------------------------------------------------------
// ZeroPoint (per-tensor affine)
// ---------------------------------------------------------------------------

/// Affine params: scale = (max-min)/(qmax-qmin), zp = round(qmin - min/scale).
pub fn zeropoint_params(x: &[f32], bits: u32) -> Result<(f32, f32)> {
    validate_bits(bits)?;
    let (qmin, qmax) = qrange(bits);
    let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
    for v in x {
        lo = lo.min(*v);
        hi = hi.max(*v);
    }
    let scale = (hi - lo).max(EPS) / (qmax - qmin) as f32;
    let zp = round_ties_even(qmin as f32 - lo / scale);
    Ok((scale, zp))
}

/// Per-tensor affine quantization. Returns (codes, scale, zero_point).
pub fn zeropoint_quantize(x: &[f32], bits: u32) -> Result<(Vec<i8>, f32, f32)> {
    // validate (via zeropoint_params) before qrange: qrange(0) would underflow
    let (scale, zp) = zeropoint_params(x, bits)?;
    let (qmin, qmax) = qrange(bits);
    let q = x
        .iter()
        .map(|v| {
            (round_ties_even(v / scale) + zp).clamp(qmin as f32, qmax as f32) as i8
        })
        .collect();
    Ok((q, scale, zp))
}

pub fn zeropoint_dequantize(q: &[i8], scale: f32, zp: f32) -> Vec<f32> {
    q.iter().map(|v| (*v as f32 - zp) * scale).collect()
}

// ---------------------------------------------------------------------------
// Symmetric per-output-channel (axis=1 of [K, N])
// ---------------------------------------------------------------------------

/// Per-column symmetric quantization of w `[K, N]`. Returns `(codes, delta [N])`.
/// Allocates fresh outputs; the hot path uses
/// `symmetric_quantize_channel_into` with reused buffers.
pub fn symmetric_quantize_channel(
    w: &[f32],
    k: usize,
    n: usize,
    bits: u32,
) -> Result<(Vec<i8>, Vec<f32>)> {
    let mut q = vec![0i8; k * n];
    let mut delta = vec![0f32; n];
    symmetric_quantize_channel_into(w, k, n, bits, &mut q, &mut delta)?;
    Ok((q, delta))
}

pub fn symmetric_dequantize_channel(q: &[i8], delta: &[f32], k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(q.len(), k * n);
    let mut out = vec![0f32; k * n];
    for (qrow, orow) in q.chunks_exact(n).zip(out.chunks_exact_mut(n)) {
        for ((qv, dv), ov) in qrow.iter().zip(delta).zip(orow.iter_mut()) {
            *ov = *qv as f32 * dv;
        }
    }
    out
}

// ---------------------------------------------------------------------------
// ZeroQuant group-wise weights + token-wise activations
// ---------------------------------------------------------------------------

/// Group-wise symmetric quantization: rows in groups of `group`, one scale
/// per (group, column). Returns (codes [K,N], delta [K/group, N]).
pub fn zeroquant_group_quantize(
    w: &[f32],
    k: usize,
    n: usize,
    group: usize,
    bits: u32,
) -> Result<(Vec<i8>, Vec<f32>)> {
    validate_bits(bits)?;
    if group == 0 || k % group != 0 {
        anyhow::bail!("K={k} not divisible by group={group}");
    }
    let mut q = vec![0i8; k * n];
    let mut delta = vec![0f32; (k / group) * n];
    zeroquant_group_quantize_into(w, k, n, group, bits, &mut q, &mut delta)?;
    Ok((q, delta))
}

pub fn zeroquant_group_dequantize(
    q: &[i8],
    delta: &[f32],
    k: usize,
    n: usize,
    group: usize,
) -> Vec<f32> {
    let mut out = vec![0f32; k * n];
    for row in 0..k {
        let g = row / group;
        let dg = &delta[g * n..(g + 1) * n];
        for ((qv, dv), ov) in q[row * n..(row + 1) * n]
            .iter()
            .zip(dg)
            .zip(out[row * n..(row + 1) * n].iter_mut())
        {
            *ov = *qv as f32 * dv;
        }
    }
    out
}

/// Token-wise (row-wise) symmetric activation quantization of x `[T, D]`.
/// Returns `(codes, delta [T])`.
pub fn token_quantize(x: &[f32], t: usize, d: usize, bits: u32) -> Result<(Vec<i8>, Vec<f32>)> {
    let mut q = vec![0i8; t * d];
    let mut delta = vec![0f32; t];
    token_quantize_into(x, t, d, bits, &mut q, &mut delta)?;
    Ok((q, delta))
}

// ---------------------------------------------------------------------------
// SmoothQuant scales
// ---------------------------------------------------------------------------

/// s_j = max|X_j|^alpha / max|W_j|^(1-alpha) over w [K, N] rows (eps 1e-5,
/// matching ref.smoothquant_scales).
pub fn smoothquant_scales(
    act_absmax: &[f32],
    w: &[f32],
    k: usize,
    n: usize,
    alpha: f32,
) -> Vec<f32> {
    const SQ_EPS: f32 = 1e-5;
    (0..k)
        .map(|j| {
            let mut wmax = 0f32;
            for v in &w[j * n..(j + 1) * n] {
                wmax = wmax.max(v.abs());
            }
            let wmax = wmax.max(SQ_EPS);
            let amax = act_absmax[j].max(SQ_EPS);
            (amax.powf(alpha) / wmax.powf(1.0 - alpha)).max(SQ_EPS)
        })
        .collect()
}

// ---------------------------------------------------------------------------
// SimQuant: per-channel min/max affine (KV cache)
// ---------------------------------------------------------------------------

/// Per-channel (columns of x `[T, D]`) min/max encode to unsigned codes.
/// Returns `(codes u8, vmin [D], step [D])`. Thm. A.2 bound holds per channel.
pub fn simquant_encode(
    x: &[f32],
    t: usize,
    d: usize,
    bits: u32,
) -> Result<(Vec<u8>, Vec<f32>, Vec<f32>)> {
    let mut q = vec![0u8; t * d];
    let mut vmin = vec![0f32; d];
    let mut step = vec![0f32; d];
    simquant_encode_into(x, t, d, bits, &mut q, &mut vmin, &mut step)?;
    Ok((q, vmin, step))
}

pub fn simquant_decode(q: &[u8], vmin: &[f32], step: &[f32], t: usize, d: usize) -> Vec<f32> {
    let mut out = vec![0f32; t * d];
    simquant_decode_into(q, vmin, step, t, d, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::XorShift64Star;

    fn randn(n: usize, seed: u64) -> Vec<f32> {
        let mut r = XorShift64Star::new(seed);
        (0..n).map(|_| r.next_normal() as f32).collect()
    }

    #[test]
    fn absmax_roundtrip_error_bounded() {
        let x = randn(1000, 1);
        let (q, delta) = absmax_quantize(&x, 8).unwrap();
        let dx = absmax_dequantize(&q, delta);
        for (a, b) in x.iter().zip(&dx) {
            assert!((a - b).abs() <= delta * 0.5 + 1e-6);
        }
    }

    #[test]
    fn absmax_extreme_hits_qmax() {
        let x = vec![-3.0, 0.0, 3.0];
        let (q, _) = absmax_quantize(&x, 8).unwrap();
        assert_eq!(q, vec![-127, 0, 127]);
    }

    #[test]
    fn one_bit_rejected_not_inf() {
        assert!(absmax_scale(&[1.0, 2.0], 1).is_err());
        assert!(absmax_quantize(&[1.0], 1).is_err());
        assert!(zeropoint_quantize(&[1.0], 0).is_err());
        assert!(symmetric_quantize_channel(&[1.0; 4], 2, 2, 1).is_err());
        assert!(zeroquant_group_quantize(&[1.0; 4], 2, 2, 2, 9).is_err());
        assert!(token_quantize(&[1.0; 4], 2, 2, 1).is_err());
        // simquant's unsigned scheme is well-defined at 1 bit; only 0 and
        // > 8 are invalid there
        assert!(simquant_encode(&[1.0; 4], 2, 2, 1).is_ok());
        assert!(simquant_encode(&[1.0; 4], 2, 2, 0).is_err());
        assert!(simquant_encode(&[1.0; 4], 2, 2, 9).is_err());
    }

    #[test]
    fn zeropoint_roundtrip_error_bounded() {
        // shifted distribution — the case zeropoint handles better than absmax
        let x: Vec<f32> = randn(1000, 2).iter().map(|v| v + 5.0).collect();
        let (q, scale, zp) = zeropoint_quantize(&x, 8).unwrap();
        let dx = zeropoint_dequantize(&q, scale, zp);
        for (a, b) in x.iter().zip(&dx) {
            assert!((a - b).abs() <= scale * 0.75 + 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn symmetric_channel_scales_per_column() {
        // col 0 small range, col 1 large: per-channel must separate them
        let w = vec![0.01, 10.0, -0.02, -20.0]; // [2, 2]
        let (q, delta) = symmetric_quantize_channel(&w, 2, 2, 8).unwrap();
        assert!(delta[0] < delta[1] / 100.0);
        let dw = symmetric_dequantize_channel(&q, &delta, 2, 2);
        for (a, b) in w.iter().zip(&dw) {
            assert!((a - b).abs() <= delta[1] * 0.5 + 1e-6);
        }
    }

    #[test]
    fn zeroquant_groups_isolate_outliers() {
        // group 0 tiny values, group 1 contains an outlier; group scales
        // keep group 0's error tiny, unlike per-tensor
        let mut w = vec![0.01f32; 4 * 2];
        w[6] = 100.0;
        let (q, delta) = zeroquant_group_quantize(&w, 4, 2, 2, 8).unwrap();
        let dw = zeroquant_group_dequantize(&q, &delta, 4, 2, 2);
        assert!((dw[0] - 0.01).abs() < 1e-4);
        assert!((dw[6] - 100.0).abs() < 0.5);
    }

    #[test]
    fn token_quantize_rowwise() {
        let x = vec![1.0, -1.0, 100.0, -50.0]; // rows: [1,-1], [100,-50]
        let (q, delta) = token_quantize(&x, 2, 2, 8).unwrap();
        assert_eq!(q[0], 127);
        assert_eq!(q[2], 127);
        assert!(delta[1] > delta[0] * 50.0);
    }

    #[test]
    fn simquant_thm_a2_bound() {
        let x = randn(64 * 16, 3);
        let (q, vmin, step) = simquant_encode(&x, 64, 16, 8).unwrap();
        let dx = simquant_decode(&q, &vmin, &step, 64, 16);
        // per-channel bound: |x - dq| <= step/2 <= (max-min)/(2^b-1)
        for col in 0..16 {
            for row in 0..64 {
                let e = (x[row * 16 + col] - dx[row * 16 + col]).abs();
                assert!(e <= step[col] * 0.5 + 1e-6);
            }
        }
    }

    #[test]
    fn simquant_lower_bits_larger_error() {
        let x = randn(256, 4);
        let (q8, m8, s8) = simquant_encode(&x, 16, 16, 8).unwrap();
        let (q4, m4, s4) = simquant_encode(&x, 16, 16, 4).unwrap();
        let e8: f32 = simquant_decode(&q8, &m8, &s8, 16, 16)
            .iter()
            .zip(&x)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        let e4: f32 = simquant_decode(&q4, &m4, &s4, 16, 16)
            .iter()
            .zip(&x)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(e4 > e8 * 4.0, "e4={e4} e8={e8}");
    }

    #[test]
    fn smoothquant_balances_magnitudes() {
        // activation channel 0 has huge range, channel 1 small; weights the
        // reverse. alpha=0.5 scaling should even them out.
        let act = vec![100.0, 0.1];
        let w = vec![0.1, 0.1, 10.0, 10.0]; // [2, 2]
        let s = smoothquant_scales(&act, &w, 2, 2, 0.5);
        // s_0 = 100^.5 / .1^.5 = sqrt(1000); s_1 = .1^.5/10^.5 = sqrt(0.01)
        assert!((s[0] - 1000f32.sqrt()).abs() < 1e-2);
        assert!((s[1] - 0.01f32.sqrt()).abs() < 1e-4);
        // after migration, act/s and w*s have comparable absmax per channel
        let a0 = act[0] / s[0];
        let w0 = w[0] * s[0];
        assert!((a0 - w0).abs() / a0 < 0.01);
    }
}
