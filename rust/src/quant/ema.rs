//! Alg. 1 — asynchronous online scale tracking (Eq. 2, Eq. 9).
//!
//! Each worker shard owns one `EmaScaleTracker` per tracked tensor region;
//! `coordinator::scale_sync` gathers the per-shard states through the
//! collective layer so every shard quantizes with identical parameters
//! (Thm. 4 consistency).

use super::round_ties_even;

/// The synchronizable state: (delta, zero_point) for one tensor region.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EmaState {
    pub delta: f32,
    pub zero_point: f32,
}

/// EMA absmax tracker with a moving window for the std-based eps floor
/// (Eq. 9: eps_t = max(eps0, std(A))).
#[derive(Debug, Clone)]
pub struct EmaScaleTracker {
    alpha: f32,
    eps0: f32,
    delta: f32,
    mean: f32,
    window: Vec<f32>, // recent absmax observations (W_t)
    window_cap: usize,
    steps: u64,
}

impl EmaScaleTracker {
    pub fn new(alpha: f32, eps0: f32) -> Self {
        assert!((0.0..=1.0).contains(&alpha), "alpha must be in [0,1]");
        EmaScaleTracker {
            alpha,
            eps0,
            delta: eps0,
            mean: 0.0,
            window: Vec::new(),
            window_cap: 64,
            steps: 0,
        }
    }

    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Observe a batch of activations; update delta per Eq. 2 and the
    /// running mean used for the zero point (Alg. 1 line 4).
    pub fn observe(&mut self, x: &[f32]) -> EmaState {
        let r = x.iter().fold(0f32, |a, v| a.max(v.abs()));
        let mu = if x.is_empty() {
            0.0
        } else {
            x.iter().sum::<f32>() / x.len() as f32
        };
        if self.window.len() == self.window_cap {
            self.window.remove(0);
        }
        self.window.push(r);
        let eps_t = self.eps_floor();
        if self.steps == 0 {
            // first observation seeds the EMA (avoids a long eps0 warmup)
            self.delta = r.max(eps_t);
            self.mean = mu;
        } else {
            self.delta = self.alpha * self.delta + (1.0 - self.alpha) * r.max(eps_t);
            self.mean = self.alpha * self.mean + (1.0 - self.alpha) * mu;
        }
        self.steps += 1;
        self.state()
    }

    /// Eq. 9: eps floor lifted by the window's std.
    fn eps_floor(&self) -> f32 {
        if self.window.len() < 2 {
            return self.eps0;
        }
        let n = self.window.len() as f32;
        let m = self.window.iter().sum::<f32>() / n;
        let var = self.window.iter().map(|v| (v - m) * (v - m)).sum::<f32>() / n;
        self.eps0.max(var.sqrt())
    }

    pub fn state(&self) -> EmaState {
        let scale = self.delta / 127.0;
        let zp = if scale > 0.0 {
            -round_ties_even(self.mean / scale)
        } else {
            0.0
        };
        EmaState { delta: self.delta, zero_point: zp }
    }

    /// Overwrite local state with the globally synchronized one (Eq. 7-8).
    pub fn adopt(&mut self, s: EmaState) {
        self.delta = s.delta;
        // zero point is derived; reconstruct the mean it encodes
        self.mean = -s.zero_point * (s.delta / 127.0);
    }

    /// Alg. 1 AsyncQuant: observe + quantize in one call.
    pub fn quantize(&mut self, x: &[f32]) -> (Vec<i8>, EmaState) {
        let mut q = Vec::with_capacity(x.len());
        let st = self.quantize_into(x, &mut q);
        (q, st)
    }

    /// Observe + quantize into a caller-owned buffer (cleared and
    /// refilled) — the buffer-reuse variant of `quantize`, matching the
    /// `_into` contract of `quant::kernels`. The serving decode loop only
    /// observes (the lowered graphs quantize on-device); this is for
    /// online callers that consume codes host-side. (The quantized ring
    /// collectives in `collective::ops` encode per-chunk token scales
    /// through `token_quantize_packed_into` instead, so each chunk's
    /// scale is exact rather than EMA-smoothed.)
    pub fn quantize_into(&mut self, x: &[f32], out: &mut Vec<i8>) -> EmaState {
        let st = self.observe(x);
        let scale = (st.delta / 127.0).max(1e-12);
        out.clear();
        out.extend(x.iter().map(|v| {
            (round_ties_even(v / scale) + st.zero_point).clamp(-128.0, 127.0) as i8
        }));
        st
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeds_on_first_observation() {
        let mut t = EmaScaleTracker::new(0.9, 1e-6);
        let s = t.observe(&[2.0, -4.0, 1.0]);
        assert!((s.delta - 4.0).abs() < 1e-6);
    }

    #[test]
    fn ema_converges_to_stationary_absmax() {
        let mut t = EmaScaleTracker::new(0.9, 1e-6);
        for _ in 0..200 {
            t.observe(&[1.0, -3.0]);
        }
        assert!((t.state().delta - 3.0).abs() < 1e-3);
    }

    #[test]
    fn ema_smooths_spikes() {
        let mut t = EmaScaleTracker::new(0.95, 1e-6);
        for _ in 0..50 {
            t.observe(&[1.0]);
        }
        t.observe(&[100.0]); // one outlier batch
        let d = t.state().delta;
        assert!(d < 10.0, "spike should be damped, got {d}");
        assert!(d > 1.0);
    }

    #[test]
    fn zero_point_centers_shifted_data() {
        let mut t = EmaScaleTracker::new(0.5, 1e-6);
        let x: Vec<f32> = (0..100).map(|i| 5.0 + (i % 10) as f32 * 0.01).collect();
        for _ in 0..20 {
            t.observe(&x);
        }
        let s = t.state();
        assert!(s.zero_point < -50.0, "zp should shift: {:?}", s);
    }

    #[test]
    fn quantize_roundtrips_via_state() {
        let mut t = EmaScaleTracker::new(0.9, 1e-6);
        let x = vec![0.5, -0.25, 0.125, 0.0];
        let (q, st) = t.quantize(&x);
        let scale = st.delta / 127.0;
        for (v, c) in x.iter().zip(&q) {
            let back = (*c as f32 - st.zero_point) * scale;
            assert!((back - v).abs() <= scale, "{v} -> {back}");
        }
    }

    #[test]
    fn quantize_into_matches_quantize() {
        let x = vec![0.5, -0.25, 0.125, 0.0];
        let mut a = EmaScaleTracker::new(0.9, 1e-6);
        let mut b = a.clone();
        let (q, st) = a.quantize(&x);
        let mut buf = vec![7i8; 1]; // stale contents must be cleared
        let st2 = b.quantize_into(&x, &mut buf);
        assert_eq!(q, buf);
        assert_eq!(st, st2);
    }

    #[test]
    fn adopt_overrides_local() {
        let mut t = EmaScaleTracker::new(0.9, 1e-6);
        t.observe(&[1.0]);
        t.adopt(EmaState { delta: 7.0, zero_point: 3.0 });
        assert_eq!(t.state().delta, 7.0);
    }

    #[test]
    fn empty_batch_is_safe() {
        let mut t = EmaScaleTracker::new(0.9, 1e-3);
        let s = t.observe(&[]);
        assert!(s.delta >= 1e-3);
    }
}
