//! Fused, allocation-free, thread-parallel quantization kernels — the
//! `_into` layer every hot-path caller routes through.
//!
//! Contracts (see also the `quant` module docs):
//!
//! * **Buffer reuse** — callers own the output buffers; kernels never
//!   allocate O(K*N). The tuple-returning wrappers in `schemes` are thin
//!   allocate-then-`_into` conveniences kept for tables/tests.
//! * **Bit-exactness** — per-element math is byte-for-byte the scalar
//!   reference (`quant::reference`): half-to-even rounding and a division
//!   (never a reciprocal multiply) per element. Parallel column
//!   reductions compute per-row-range partials and combine them in range
//!   order on the calling thread; f32 `min`/`max` are associative, so the
//!   result is identical for any thread count. `tests/kernel_equivalence.rs`
//!   pins all of this property-style.
//! * **Traversal** — all passes walk the matrix row-major in
//!   bounds-check-free `chunks_exact` row slices; the per-(group, column)
//!   amax of ZeroQuant is fused with its encode per row-group so a group
//!   is read once while cache-hot.
//!
//! Thread fan-out uses `util::pool`'s persistent parked-worker pool
//! (no per-call thread spawn); inputs below ~32K elements stay
//! single-threaded.
//!
//! # Bit-packed sub-byte codes
//!
//! The storage/wire layer packs codes to their true width — two 4-bit or
//! four 2-bit codes per byte (`pack_i8_into` / `pack_u8_into`, plus the
//! fused `token_quantize_packed_into`). `packed_len` is the accounting
//! helper: `memsim`, `KvCache::storage_bytes`, and the collective byte
//! counters all price sub-byte tensors through it instead of assuming one
//! byte per code. Packing is little-endian within each byte (code *j*
//! occupies bits `(j % (8/bits)) * bits ..` of byte `j / (8/bits)`) and
//! round-trips bit-identically for every code the quantizers can emit.

use anyhow::{bail, Result};

use crate::util::pool;

use super::{qrange, round_ties_even};

/// Shared epsilon floor for scales (matches `python/compile/kernels/ref.py`).
pub(crate) const EPS: f32 = 1e-8;

/// Below this many elements the scoped-thread fan-out costs more than it
/// saves; kernels fall back to the single-chunk path.
const PAR_MIN_ELEMS: usize = 32 * 1024;

/// Validate a quantization bitwidth at the public entry points.
/// `bits == 1` would make `qmax == 0` and every scale `amax / 0 = inf`;
/// anything above 8 does not fit the i8/u8 code buffers.
pub fn validate_bits(bits: u32) -> Result<()> {
    if !(2..=8).contains(&bits) {
        bail!(
            "unsupported bitwidth {bits}: must be in 2..=8 \
             (bits=1 makes qmax 0 and every scale divide to inf)"
        );
    }
    Ok(())
}

/// SimQuant's unsigned min/max scheme is well-defined down to 1 bit
/// (levels = 2^bits - 1 >= 1, finite step), unlike the signed symmetric
/// schemes; only 0 and anything above 8 (codes no longer fit u8) are
/// invalid.
pub fn validate_simquant_bits(bits: u32) -> Result<()> {
    if !(1..=8).contains(&bits) {
        bail!("unsupported SimQuant bitwidth {bits}: must be in 1..=8 (u8 codes)");
    }
    Ok(())
}

fn check_len(what: &str, got: usize, want: usize) -> Result<()> {
    if got != want {
        bail!("{what} buffer holds {got} elements, kernel needs {want}");
    }
    Ok(())
}

fn row_chunks(rows: usize, width: usize, threads: usize) -> Vec<std::ops::Range<usize>> {
    let min_rows = (PAR_MIN_ELEMS / width.max(1)).max(1);
    pool::chunk_ranges(rows, threads, min_rows)
}

// ---------------------------------------------------------------------------
// Symmetric per-output-channel (axis=1 of [K, N])
// ---------------------------------------------------------------------------

/// Per-column symmetric quantization of `w` `[K, N]` into caller buffers:
/// `q` `[K, N]` codes, `delta` `[N]` scales. Parallel over row ranges with
/// `threads` workers; bit-identical to `reference::symmetric_quantize_channel`.
pub fn symmetric_quantize_channel_into_threads(
    w: &[f32],
    k: usize,
    n: usize,
    bits: u32,
    q: &mut [i8],
    delta: &mut [f32],
    threads: usize,
) -> Result<()> {
    validate_bits(bits)?;
    check_len("w", w.len(), k * n)?;
    check_len("q", q.len(), k * n)?;
    check_len("delta", delta.len(), n)?;
    if n == 0 {
        return Ok(()); // zero-width: nothing to write (reference parity)
    }
    let (qmin, qmax) = qrange(bits);
    let ranges = row_chunks(k, n, threads);

    // pass 1: per-column absmax, row-major, accumulated into `delta`
    if ranges.len() <= 1 {
        delta.fill(0.0);
        for wrow in w.chunks_exact(n) {
            for (a, v) in delta.iter_mut().zip(wrow) {
                *a = a.max(v.abs());
            }
        }
    } else {
        let mut partials = vec![0f32; ranges.len() * n];
        let tasks: Vec<pool::Task<'_>> = ranges
            .iter()
            .zip(partials.chunks_exact_mut(n))
            .map(|(r, part)| {
                let wb = &w[r.start * n..r.end * n];
                Box::new(move || {
                    for wrow in wb.chunks_exact(n) {
                        for (a, v) in part.iter_mut().zip(wrow) {
                            *a = a.max(v.abs());
                        }
                    }
                }) as pool::Task<'_>
            })
            .collect();
        pool::run(tasks);
        // combine in range order on the calling thread (deterministic)
        delta.fill(0.0);
        for part in partials.chunks_exact(n) {
            for (a, p) in delta.iter_mut().zip(part) {
                *a = a.max(*p);
            }
        }
    }
    for a in delta.iter_mut() {
        *a = a.max(EPS) / qmax as f32;
    }

    // pass 2: encode, row-parallel; division kept for jnp bit-exactness
    let scales: &[f32] = delta;
    let (lo, hi) = (qmin as f32, qmax as f32);
    let encode = |wb: &[f32], qb: &mut [i8]| {
        for (wrow, qrow) in wb.chunks_exact(n).zip(qb.chunks_exact_mut(n)) {
            for ((wv, dv), qv) in wrow.iter().zip(scales).zip(qrow.iter_mut()) {
                *qv = round_ties_even(wv / dv).clamp(lo, hi) as i8;
            }
        }
    };
    if ranges.len() <= 1 {
        encode(w, q);
    } else {
        let qblocks = pool::split_rows(q, &ranges, n);
        let tasks: Vec<pool::Task<'_>> = ranges
            .iter()
            .zip(qblocks)
            .map(|(r, qb)| {
                let wb = &w[r.start * n..r.end * n];
                let encode = &encode;
                Box::new(move || encode(wb, qb)) as pool::Task<'_>
            })
            .collect();
        pool::run(tasks);
    }
    Ok(())
}

/// [`symmetric_quantize_channel_into_threads`] at the process thread count.
pub fn symmetric_quantize_channel_into(
    w: &[f32],
    k: usize,
    n: usize,
    bits: u32,
    q: &mut [i8],
    delta: &mut [f32],
) -> Result<()> {
    symmetric_quantize_channel_into_threads(w, k, n, bits, q, delta, pool::max_threads())
}

// ---------------------------------------------------------------------------
// ZeroQuant group-wise weights
// ---------------------------------------------------------------------------

/// Group-wise symmetric quantization of `w` [K, N] into caller buffers:
/// `q` [K, N], `delta` [K/group, N]. The per-(group, column) amax pass is
/// row-major and fused with the encode pass per group (one cache-hot read
/// per group); groups are independent, so the fan-out splits group ranges.
#[allow(clippy::too_many_arguments)]
pub fn zeroquant_group_quantize_into_threads(
    w: &[f32],
    k: usize,
    n: usize,
    group: usize,
    bits: u32,
    q: &mut [i8],
    delta: &mut [f32],
    threads: usize,
) -> Result<()> {
    validate_bits(bits)?;
    if group == 0 || k % group != 0 {
        bail!("K={k} not divisible by group={group}");
    }
    let groups = k / group;
    check_len("w", w.len(), k * n)?;
    check_len("q", q.len(), k * n)?;
    check_len("delta", delta.len(), groups * n)?;
    if n == 0 {
        return Ok(()); // zero-width: nothing to write (reference parity)
    }
    let (qmin, qmax) = qrange(bits);
    let (lo, hi) = (qmin as f32, qmax as f32);

    let kernel = |wb: &[f32], qb: &mut [i8], db: &mut [f32]| {
        for ((wg, qg), dg) in wb
            .chunks_exact(group * n)
            .zip(qb.chunks_exact_mut(group * n))
            .zip(db.chunks_exact_mut(n))
        {
            dg.fill(0.0);
            for wrow in wg.chunks_exact(n) {
                for (a, v) in dg.iter_mut().zip(wrow) {
                    *a = a.max(v.abs());
                }
            }
            for a in dg.iter_mut() {
                *a = a.max(EPS) / qmax as f32;
            }
            let dgr: &[f32] = dg;
            for (wrow, qrow) in wg.chunks_exact(n).zip(qg.chunks_exact_mut(n)) {
                for ((wv, dv), qv) in wrow.iter().zip(dgr).zip(qrow.iter_mut()) {
                    *qv = round_ties_even(wv / dv).clamp(lo, hi) as i8;
                }
            }
        }
    };

    let ranges = row_chunks(groups, group * n, threads);
    if ranges.len() <= 1 {
        kernel(w, q, delta);
    } else {
        let qblocks = pool::split_rows(q, &ranges, group * n);
        let dblocks = pool::split_rows(delta, &ranges, n);
        let tasks: Vec<pool::Task<'_>> = ranges
            .iter()
            .zip(qblocks)
            .zip(dblocks)
            .map(|((r, qb), db)| {
                let wb = &w[r.start * group * n..r.end * group * n];
                let kernel = &kernel;
                Box::new(move || kernel(wb, qb, db)) as pool::Task<'_>
            })
            .collect();
        pool::run(tasks);
    }
    Ok(())
}

/// [`zeroquant_group_quantize_into_threads`] at the process thread count.
pub fn zeroquant_group_quantize_into(
    w: &[f32],
    k: usize,
    n: usize,
    group: usize,
    bits: u32,
    q: &mut [i8],
    delta: &mut [f32],
) -> Result<()> {
    zeroquant_group_quantize_into_threads(w, k, n, group, bits, q, delta, pool::max_threads())
}

// ---------------------------------------------------------------------------
// Token-wise (row-wise) activation quantization
// ---------------------------------------------------------------------------

/// Token-wise symmetric quantization of `x` `[T, D]` into caller buffers:
/// `q` `[T, D]`, `delta` `[T]`. Scale and encode passes are fused per row
/// (one read while the row is cache-hot); rows are independent, so the
/// fan-out splits row ranges.
pub fn token_quantize_into_threads(
    x: &[f32],
    t: usize,
    d: usize,
    bits: u32,
    q: &mut [i8],
    delta: &mut [f32],
    threads: usize,
) -> Result<()> {
    validate_bits(bits)?;
    check_len("x", x.len(), t * d)?;
    check_len("q", q.len(), t * d)?;
    check_len("delta", delta.len(), t)?;
    let (qmin, qmax) = qrange(bits);
    if d == 0 {
        // zero-width rows: the reference still emits the EPS-floor scale
        delta.fill(EPS / qmax as f32);
        return Ok(());
    }
    let (lo, hi) = (qmin as f32, qmax as f32);

    let kernel = |xb: &[f32], qb: &mut [i8], db: &mut [f32]| {
        for ((srow, qrow), dl_out) in xb
            .chunks_exact(d)
            .zip(qb.chunks_exact_mut(d))
            .zip(db.iter_mut())
        {
            let amax = srow.iter().fold(0f32, |a, v| a.max(v.abs())).max(EPS);
            let dl = amax / qmax as f32;
            *dl_out = dl;
            for (sv, qv) in srow.iter().zip(qrow.iter_mut()) {
                *qv = round_ties_even(sv / dl).clamp(lo, hi) as i8;
            }
        }
    };

    let ranges = row_chunks(t, d, threads);
    if ranges.len() <= 1 {
        kernel(x, q, delta);
    } else {
        let qblocks = pool::split_rows(q, &ranges, d);
        let dblocks = pool::split_rows(delta, &ranges, 1);
        let tasks: Vec<pool::Task<'_>> = ranges
            .iter()
            .zip(qblocks)
            .zip(dblocks)
            .map(|((r, qb), db)| {
                let xb = &x[r.start * d..r.end * d];
                let kernel = &kernel;
                Box::new(move || kernel(xb, qb, db)) as pool::Task<'_>
            })
            .collect();
        pool::run(tasks);
    }
    Ok(())
}

/// [`token_quantize_into_threads`] at the process thread count.
pub fn token_quantize_into(
    x: &[f32],
    t: usize,
    d: usize,
    bits: u32,
    q: &mut [i8],
    delta: &mut [f32],
) -> Result<()> {
    token_quantize_into_threads(x, t, d, bits, q, delta, pool::max_threads())
}

// ---------------------------------------------------------------------------
// SimQuant per-channel min/max affine (KV cache)
// ---------------------------------------------------------------------------

/// Per-channel min/max encode of `x` `[T, D]` into caller buffers: `q`
/// `[T, D]` unsigned codes, `vmin` `[D]`, `step` `[D]`. `step` doubles as the
/// vmax accumulator during the reduction pass, so the single-chunk path
/// allocates nothing. `t == 0` yields the reference's zeroed params.
#[allow(clippy::too_many_arguments)]
pub fn simquant_encode_into_threads(
    x: &[f32],
    t: usize,
    d: usize,
    bits: u32,
    q: &mut [u8],
    vmin: &mut [f32],
    step: &mut [f32],
    threads: usize,
) -> Result<()> {
    validate_simquant_bits(bits)?;
    check_len("x", x.len(), t * d)?;
    check_len("q", q.len(), t * d)?;
    check_len("vmin", vmin.len(), d)?;
    check_len("step", step.len(), d)?;
    if d == 0 {
        return Ok(()); // zero-width: nothing to write (reference parity)
    }
    let levels = ((1u32 << bits) - 1) as f32;
    let ranges = row_chunks(t, d, threads);

    // pass 1: per-column min into `vmin`, max into `step`
    if t == 0 {
        vmin.fill(0.0);
        step.fill(0.0);
    } else if ranges.len() <= 1 {
        vmin.fill(f32::INFINITY);
        step.fill(f32::NEG_INFINITY);
        for xrow in x.chunks_exact(d) {
            for ((mn, mx), v) in vmin.iter_mut().zip(step.iter_mut()).zip(xrow) {
                *mn = mn.min(*v);
                *mx = mx.max(*v);
            }
        }
    } else {
        // per-range partials: [min_0 | max_0 | min_1 | max_1 | ...]
        let mut partials = vec![0f32; ranges.len() * 2 * d];
        let tasks: Vec<pool::Task<'_>> = ranges
            .iter()
            .zip(partials.chunks_exact_mut(2 * d))
            .map(|(r, part)| {
                let xb = &x[r.start * d..r.end * d];
                Box::new(move || {
                    let (mn, mx) = part.split_at_mut(d);
                    mn.fill(f32::INFINITY);
                    mx.fill(f32::NEG_INFINITY);
                    for xrow in xb.chunks_exact(d) {
                        for ((pmn, pmx), v) in mn.iter_mut().zip(mx.iter_mut()).zip(xrow) {
                            *pmn = pmn.min(*v);
                            *pmx = pmx.max(*v);
                        }
                    }
                }) as pool::Task<'_>
            })
            .collect();
        pool::run(tasks);
        vmin.fill(f32::INFINITY);
        step.fill(f32::NEG_INFINITY);
        for part in partials.chunks_exact(2 * d) {
            let (mn, mx) = part.split_at(d);
            for ((gmn, gmx), (pmn, pmx)) in
                vmin.iter_mut().zip(step.iter_mut()).zip(mn.iter().zip(mx))
            {
                *gmn = gmn.min(*pmn);
                *gmx = gmx.max(*pmx);
            }
        }
    }
    // finalize: step currently holds vmax
    for (st, mn) in step.iter_mut().zip(vmin.iter()) {
        *st = (*st - mn).max(EPS) / levels;
    }

    // pass 2: encode, row-parallel
    let vmin_ro: &[f32] = vmin;
    let step_ro: &[f32] = step;
    let encode = |xb: &[f32], qb: &mut [u8]| {
        simquant_encode_with_params_into(xb, vmin_ro, step_ro, levels, qb)
    };
    if ranges.len() <= 1 {
        encode(x, q);
    } else {
        let qblocks = pool::split_rows(q, &ranges, d);
        let tasks: Vec<pool::Task<'_>> = ranges
            .iter()
            .zip(qblocks)
            .map(|(r, qb)| {
                let xb = &x[r.start * d..r.end * d];
                let encode = &encode;
                Box::new(move || encode(xb, qb)) as pool::Task<'_>
            })
            .collect();
        pool::run(tasks);
    }
    Ok(())
}

/// [`simquant_encode_into_threads`] at the process thread count.
pub fn simquant_encode_into(
    x: &[f32],
    t: usize,
    d: usize,
    bits: u32,
    q: &mut [u8],
    vmin: &mut [f32],
    step: &mut [f32],
) -> Result<()> {
    simquant_encode_into_threads(x, t, d, bits, q, vmin, step, pool::max_threads())
}

/// Encode rows of `x` with *given* per-channel params — the KV-cache
/// append / page re-encode path, and pass 2 of `simquant_encode_into`:
/// `out = round((x - vmin) / step)` clamped to `[0, levels]`. Panics on
/// mismatched buffer lengths (the caller misuse contract for the
/// infallible helpers; the fallible `_into` kernels return errors).
pub fn simquant_encode_with_params_into(
    x: &[f32],
    vmin: &[f32],
    step: &[f32],
    levels: f32,
    out: &mut [u8],
) {
    let d = vmin.len();
    assert_eq!(step.len(), d, "step length != vmin length");
    assert_eq!(x.len(), out.len(), "x/out length mismatch");
    if d == 0 {
        return;
    }
    assert_eq!(x.len() % d, 0, "x length not a multiple of d");
    for (xrow, qrow) in x.chunks_exact(d).zip(out.chunks_exact_mut(d)) {
        for (((xv, mn), st), qv) in xrow.iter().zip(vmin).zip(step).zip(qrow.iter_mut()) {
            *qv = round_ties_even((xv - mn) / st).clamp(0.0, levels) as u8;
        }
    }
}

/// Per-channel affine decode of `q` [T, D] into `out` [T, D] — the
/// buffer-reuse counterpart of `simquant_decode` (KV page re-encode and
/// `KvCache::decode_k_into` route through this). Panics on mismatched
/// buffer lengths.
pub fn simquant_decode_into(
    q: &[u8],
    vmin: &[f32],
    step: &[f32],
    t: usize,
    d: usize,
    out: &mut [f32],
) {
    assert_eq!(q.len(), t * d, "codes length != t*d");
    assert_eq!(out.len(), t * d, "out length != t*d");
    assert_eq!(vmin.len(), d, "vmin length != d");
    assert_eq!(step.len(), d, "step length != d");
    if d == 0 {
        return;
    }
    for (qrow, orow) in q.chunks_exact(d).zip(out.chunks_exact_mut(d)) {
        for (((qv, st), mn), ov) in qrow.iter().zip(step).zip(vmin).zip(orow.iter_mut()) {
            *ov = *qv as f32 * st + mn;
        }
    }
}

/// `out[r, :] = src[r, :] * scales[r]` — the per-row migration step
/// SmoothQuant and AWQ share before their symmetric encode; lives here so
/// the Python-parity math has exactly one Rust site. Panics on mismatched
/// buffer lengths.
pub fn scale_rows_into(src: &[f32], scales: &[f32], n: usize, out: &mut [f32]) {
    assert_eq!(src.len(), out.len(), "src/out length mismatch");
    assert_eq!(src.len(), scales.len() * n, "scales length != rows");
    if n == 0 {
        return;
    }
    for ((orow, srow), sv) in out
        .chunks_exact_mut(n)
        .zip(src.chunks_exact(n))
        .zip(scales)
    {
        for (o, v) in orow.iter_mut().zip(srow) {
            *o = v * sv;
        }
    }
}

// ---------------------------------------------------------------------------
// Bit-packed sub-byte codes (storage / wire format)
// ---------------------------------------------------------------------------

/// Widths the packed storage/wire format supports: the divisors of 8, so
/// a byte always holds a whole number of codes and no code straddles a
/// byte boundary.
pub fn validate_pack_bits(bits: u32) -> Result<()> {
    if !matches!(bits, 1 | 2 | 4 | 8) {
        bail!("unsupported packed bitwidth {bits}: must divide 8 (1, 2, 4, or 8)");
    }
    Ok(())
}

/// Bytes needed to store `elems` codes of `bits` bits each, packed — the
/// accounting helper `memsim`, `KvCache::storage_bytes`, and the
/// collective byte counters share (1 byte holds `8 / bits` codes; the
/// last byte may be partial).
pub fn packed_len(elems: usize, bits: u32) -> usize {
    (elems * bits as usize).div_ceil(8)
}

/// Pack signed codes to `bits` bits each (two's-complement truncation),
/// little-endian within each byte. Codes must fit `bits` bits (which
/// every `qrange(bits)`-clamped quantizer output does); wider values are
/// silently truncated.
pub fn pack_i8_into(codes: &[i8], bits: u32, out: &mut [u8]) -> Result<()> {
    validate_pack_bits(bits)?;
    check_len("packed", out.len(), packed_len(codes.len(), bits))?;
    let cpb = (8 / bits) as usize;
    let mask = ((1u16 << bits) - 1) as u8;
    for (ob, chunk) in out.iter_mut().zip(codes.chunks(cpb)) {
        let mut acc = 0u8;
        for (s, c) in chunk.iter().enumerate() {
            acc |= ((*c as u8) & mask) << (s as u32 * bits);
        }
        *ob = acc;
    }
    Ok(())
}

/// Unpack `out.len()` sign-extended codes from a [`pack_i8_into`] buffer.
pub fn unpack_i8_into(packed: &[u8], bits: u32, out: &mut [i8]) -> Result<()> {
    validate_pack_bits(bits)?;
    check_len("packed", packed.len(), packed_len(out.len(), bits))?;
    let cpb = (8 / bits) as usize;
    let shift = 8 - bits;
    for (pb, chunk) in packed.iter().zip(out.chunks_mut(cpb)) {
        for (s, o) in chunk.iter_mut().enumerate() {
            let v = (pb >> (s as u32 * bits)) << shift;
            *o = (v as i8) >> shift;
        }
    }
    Ok(())
}

/// Pack unsigned codes (SimQuant pages) to `bits` bits each,
/// little-endian within each byte.
pub fn pack_u8_into(codes: &[u8], bits: u32, out: &mut [u8]) -> Result<()> {
    validate_pack_bits(bits)?;
    check_len("packed", out.len(), packed_len(codes.len(), bits))?;
    let cpb = (8 / bits) as usize;
    let mask = ((1u16 << bits) - 1) as u8;
    for (ob, chunk) in out.iter_mut().zip(codes.chunks(cpb)) {
        let mut acc = 0u8;
        for (s, c) in chunk.iter().enumerate() {
            acc |= (c & mask) << (s as u32 * bits);
        }
        *ob = acc;
    }
    Ok(())
}

/// Unpack `out.len()` unsigned codes from a [`pack_u8_into`] buffer.
pub fn unpack_u8_into(packed: &[u8], bits: u32, out: &mut [u8]) -> Result<()> {
    validate_pack_bits(bits)?;
    check_len("packed", packed.len(), packed_len(out.len(), bits))?;
    let cpb = (8 / bits) as usize;
    let mask = ((1u16 << bits) - 1) as u8;
    for (pb, chunk) in packed.iter().zip(out.chunks_mut(cpb)) {
        for (s, o) in chunk.iter_mut().enumerate() {
            *o = (pb >> (s as u32 * bits)) & mask;
        }
    }
    Ok(())
}

/// Token-wise quantization of `x` `[T, D]` straight into a bit-packed code
/// buffer (`packed` `[packed_len(T*D, bits)]`) plus per-row scales `delta`
/// `[T]` — the ring collectives' send-endpoint encode. Per-element math is
/// byte-for-byte [`token_quantize_into`]'s (same scales, same codes
/// pre-pack), so unpacking yields exactly the reference's codes. The
/// code stream is packed contiguously row-major; rows are not
/// byte-aligned unless `d * bits % 8 == 0`.
pub fn token_quantize_packed_into(
    x: &[f32],
    t: usize,
    d: usize,
    bits: u32,
    packed: &mut [u8],
    delta: &mut [f32],
) -> Result<()> {
    validate_bits(bits)?;
    validate_pack_bits(bits)?;
    check_len("x", x.len(), t * d)?;
    check_len("packed", packed.len(), packed_len(t * d, bits))?;
    check_len("delta", delta.len(), t)?;
    let (qmin, qmax) = qrange(bits);
    if d == 0 {
        // zero-width rows: the reference still emits the EPS-floor scale
        delta.fill(EPS / qmax as f32);
        return Ok(());
    }
    let (lo, hi) = (qmin as f32, qmax as f32);
    let cpb = (8 / bits) as usize;
    let mask = ((1u16 << bits) - 1) as u8;
    packed.fill(0);
    for (r, (xrow, dl_out)) in x.chunks_exact(d).zip(delta.iter_mut()).enumerate() {
        let amax = xrow.iter().fold(0f32, |a, v| a.max(v.abs())).max(EPS);
        let dl = amax / qmax as f32;
        *dl_out = dl;
        for (c, v) in xrow.iter().enumerate() {
            let q = round_ties_even(v / dl).clamp(lo, hi) as i8;
            let j = r * d + c;
            packed[j / cpb] |= ((q as u8) & mask) << ((j % cpb) as u32 * bits);
        }
    }
    Ok(())
}

/// Decode a [`token_quantize_packed_into`] buffer back to f32:
/// `out[r, c] = code[r, c] * delta[r]` — the ring collectives'
/// receive-endpoint decode.
pub fn token_dequantize_packed_into(
    packed: &[u8],
    delta: &[f32],
    t: usize,
    d: usize,
    bits: u32,
    out: &mut [f32],
) -> Result<()> {
    validate_bits(bits)?;
    validate_pack_bits(bits)?;
    check_len("packed", packed.len(), packed_len(t * d, bits))?;
    check_len("delta", delta.len(), t)?;
    check_len("out", out.len(), t * d)?;
    if d == 0 {
        return Ok(());
    }
    let cpb = (8 / bits) as usize;
    let shift = 8 - bits;
    for (r, (orow, dl)) in out.chunks_exact_mut(d).zip(delta).enumerate() {
        for (c, o) in orow.iter_mut().enumerate() {
            let j = r * d + c;
            let v = (packed[j / cpb] >> ((j % cpb) as u32 * bits)) << shift;
            let code = (v as i8) >> shift;
            *o = code as f32 * dl;
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Pinned scalar reference
// ---------------------------------------------------------------------------

/// The original single-threaded, allocating implementations, kept
/// verbatim as the bit-exactness oracle for `tests/kernel_equivalence.rs`
/// (and as the plainest statement of the Python-parity semantics). Do not
/// "optimize" these: their value is that they never change.
pub mod reference {
    use super::{qrange, round_ties_even, EPS};

    /// See `quant::symmetric_quantize_channel`.
    pub fn symmetric_quantize_channel(
        w: &[f32],
        k: usize,
        n: usize,
        bits: u32,
    ) -> (Vec<i8>, Vec<f32>) {
        let (qmin, qmax) = qrange(bits);
        let mut amax = vec![0f32; n];
        for row in 0..k {
            for col in 0..n {
                amax[col] = amax[col].max(w[row * n + col].abs());
            }
        }
        let delta: Vec<f32> = amax.iter().map(|a| a.max(EPS) / qmax as f32).collect();
        let mut q = vec![0i8; k * n];
        for row in 0..k {
            for col in 0..n {
                q[row * n + col] = round_ties_even(w[row * n + col] / delta[col])
                    .clamp(qmin as f32, qmax as f32) as i8;
            }
        }
        (q, delta)
    }

    /// See `quant::zeroquant_group_quantize`.
    pub fn zeroquant_group_quantize(
        w: &[f32],
        k: usize,
        n: usize,
        group: usize,
        bits: u32,
    ) -> (Vec<i8>, Vec<f32>) {
        assert_eq!(k % group, 0, "K={k} not divisible by group={group}");
        let (qmin, qmax) = qrange(bits);
        let groups = k / group;
        let mut delta = vec![0f32; groups * n];
        for g in 0..groups {
            for col in 0..n {
                let mut amax = 0f32;
                for r in 0..group {
                    amax = amax.max(w[(g * group + r) * n + col].abs());
                }
                delta[g * n + col] = amax.max(EPS) / qmax as f32;
            }
        }
        let mut q = vec![0i8; k * n];
        for g in 0..groups {
            for r in 0..group {
                let row = g * group + r;
                for col in 0..n {
                    q[row * n + col] = round_ties_even(w[row * n + col] / delta[g * n + col])
                        .clamp(qmin as f32, qmax as f32) as i8;
                }
            }
        }
        (q, delta)
    }

    /// See `quant::token_quantize`.
    pub fn token_quantize(x: &[f32], t: usize, d: usize, bits: u32) -> (Vec<i8>, Vec<f32>) {
        let (qmin, qmax) = qrange(bits);
        let mut q = vec![0i8; t * d];
        let mut delta = vec![0f32; t];
        for row in 0..t {
            let srow = &x[row * d..(row + 1) * d];
            let amax = srow.iter().fold(0f32, |a, v| a.max(v.abs())).max(EPS);
            let dl = amax / qmax as f32;
            delta[row] = dl;
            for col in 0..d {
                q[row * d + col] =
                    round_ties_even(srow[col] / dl).clamp(qmin as f32, qmax as f32) as i8;
            }
        }
        (q, delta)
    }

    /// See `quant::simquant_encode`.
    pub fn simquant_encode(
        x: &[f32],
        t: usize,
        d: usize,
        bits: u32,
    ) -> (Vec<u8>, Vec<f32>, Vec<f32>) {
        let levels = ((1u32 << bits) - 1) as f32;
        let mut vmin = vec![f32::INFINITY; d];
        let mut vmax = vec![f32::NEG_INFINITY; d];
        for row in 0..t {
            for col in 0..d {
                let v = x[row * d + col];
                vmin[col] = vmin[col].min(v);
                vmax[col] = vmax[col].max(v);
            }
        }
        if t == 0 {
            vmin.iter_mut().for_each(|v| *v = 0.0);
            vmax.iter_mut().for_each(|v| *v = 0.0);
        }
        let step: Vec<f32> = vmin
            .iter()
            .zip(&vmax)
            .map(|(lo, hi)| (hi - lo).max(EPS) / levels)
            .collect();
        let mut q = vec![0u8; t * d];
        for row in 0..t {
            for col in 0..d {
                q[row * d + col] = round_ties_even((x[row * d + col] - vmin[col]) / step[col])
                    .clamp(0.0, levels) as u8;
            }
        }
        (q, vmin, step)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn invalid_bits_rejected() {
        for bits in [0u32, 1, 9, 16] {
            assert!(validate_bits(bits).is_err(), "bits={bits}");
            let x = vec![1.0f32; 8];
            let mut q = vec![0i8; 8];
            let mut delta = vec![0f32; 4];
            assert!(
                symmetric_quantize_channel_into(&x, 2, 4, bits, &mut q, &mut delta).is_err()
            );
            assert!(
                zeroquant_group_quantize_into(&x, 2, 4, 2, bits, &mut q, &mut delta).is_err()
            );
            let mut dt = vec![0f32; 2];
            assert!(token_quantize_into(&x, 2, 4, bits, &mut q, &mut dt).is_err());
        }
        // simquant accepts 1 bit (unsigned scheme), rejects 0 and > 8
        let x = vec![1.0f32; 8];
        let mut qu = vec![0u8; 8];
        let mut mn = vec![0f32; 4];
        let mut st = vec![0f32; 4];
        assert!(simquant_encode_into(&x, 2, 4, 1, &mut qu, &mut mn, &mut st).is_ok());
        for bits in [0u32, 9, 16] {
            assert!(simquant_encode_into(&x, 2, 4, bits, &mut qu, &mut mn, &mut st).is_err());
        }
    }

    #[test]
    fn buffer_length_mismatch_rejected() {
        let x = vec![1.0f32; 8];
        let mut q = vec![0i8; 7]; // wrong
        let mut delta = vec![0f32; 4];
        assert!(symmetric_quantize_channel_into(&x, 2, 4, 8, &mut q, &mut delta).is_err());
    }

    #[test]
    fn zeroquant_bad_group_rejected() {
        let x = vec![1.0f32; 12];
        let mut q = vec![0i8; 12];
        let mut delta = vec![0f32; 4];
        assert!(zeroquant_group_quantize_into(&x, 3, 4, 2, 8, &mut q, &mut delta).is_err());
        assert!(zeroquant_group_quantize_into(&x, 3, 4, 0, 8, &mut q, &mut delta).is_err());
    }

    #[test]
    fn invalid_pack_bits_rejected() {
        for bits in [0u32, 3, 5, 6, 7, 9, 16] {
            assert!(validate_pack_bits(bits).is_err(), "bits={bits}");
        }
        for bits in [1u32, 2, 4, 8] {
            assert!(validate_pack_bits(bits).is_ok(), "bits={bits}");
        }
        // signed packed quantize additionally excludes 1 bit (qmax == 0)
        let x = vec![1.0f32; 8];
        let mut packed = vec![0u8; 1];
        let mut delta = vec![0f32; 2];
        assert!(token_quantize_packed_into(&x, 2, 4, 1, &mut packed, &mut delta).is_err());
    }

    #[test]
    fn packed_len_counts_partial_bytes() {
        assert_eq!(packed_len(0, 4), 0);
        assert_eq!(packed_len(1, 4), 1);
        assert_eq!(packed_len(2, 4), 1);
        assert_eq!(packed_len(3, 4), 2);
        assert_eq!(packed_len(7, 2), 2);
        assert_eq!(packed_len(9, 1), 2);
        assert_eq!(packed_len(5, 8), 5);
    }

    #[test]
    fn pack_unpack_i8_identity_on_ragged_lengths() {
        for bits in [2u32, 4, 8] {
            let (qmin, qmax) = qrange(bits);
            for len in [0usize, 1, 2, 3, 5, 8, 17] {
                let codes: Vec<i8> = (0..len)
                    .map(|i| (qmin + (i as i32 % (qmax - qmin + 1))) as i8)
                    .collect();
                let mut packed = vec![0u8; packed_len(len, bits)];
                pack_i8_into(&codes, bits, &mut packed).unwrap();
                let mut back = vec![0i8; len];
                unpack_i8_into(&packed, bits, &mut back).unwrap();
                assert_eq!(back, codes, "bits={bits} len={len}");
            }
        }
    }

    #[test]
    fn pack_unpack_u8_identity_on_ragged_lengths() {
        for bits in [1u32, 2, 4, 8] {
            let levels = (1u32 << bits) - 1;
            for len in [0usize, 1, 3, 4, 9] {
                let codes: Vec<u8> = (0..len).map(|i| (i as u32 % (levels + 1)) as u8).collect();
                let mut packed = vec![0u8; packed_len(len, bits)];
                pack_u8_into(&codes, bits, &mut packed).unwrap();
                let mut back = vec![0u8; len];
                unpack_u8_into(&packed, bits, &mut back).unwrap();
                assert_eq!(back, codes, "bits={bits} len={len}");
            }
        }
    }

    #[test]
    fn simquant_empty_input_matches_reference() {
        let x: Vec<f32> = Vec::new();
        let mut q: Vec<u8> = Vec::new();
        let mut vmin = vec![9.0f32; 4];
        let mut step = vec![9.0f32; 4];
        simquant_encode_into(&x, 0, 4, 8, &mut q, &mut vmin, &mut step).unwrap();
        let (rq, rmin, rstep) = reference::simquant_encode(&x, 0, 4, 8);
        assert_eq!(q, rq);
        assert_eq!(vmin, rmin);
        assert_eq!(step, rstep);
    }
}
