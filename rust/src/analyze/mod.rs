//! Analysis utilities for the paper's visualization figures: weight
//! distribution featurization (Fig. 1) and exact t-SNE (Fig. 7).

mod features;
mod tsne;

pub use features::{weight_features, FEATURE_DIM};
pub use tsne::{tsne, TsneConfig};
