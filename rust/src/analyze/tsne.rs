//! Exact O(n^2) t-SNE (van der Maaten & Hinton 2008) for Fig. 7.
//!
//! Small-n (dozens of points: methods x layers) so the quadratic gradient
//! is fine. Implements perplexity-calibrated Gaussian affinities via
//! binary search on beta, symmetrized P, early exaggeration, and momentum
//! gradient descent on the KL objective.

use crate::corpus::XorShift64Star;

#[derive(Debug, Clone, Copy)]
pub struct TsneConfig {
    pub perplexity: f64,
    pub iterations: usize,
    pub learning_rate: f64,
    pub seed: u64,
}

impl Default for TsneConfig {
    fn default() -> Self {
        TsneConfig { perplexity: 8.0, iterations: 500, learning_rate: 100.0, seed: 42 }
    }
}

/// Embed `points` (n x dim, row-major) into 2-D. Returns n (x, y) pairs.
pub fn tsne(points: &[Vec<f64>], cfg: TsneConfig) -> Vec<(f64, f64)> {
    let n = points.len();
    if n == 0 {
        return Vec::new();
    }
    if n == 1 {
        return vec![(0.0, 0.0)];
    }
    let p = joint_probabilities(points, cfg.perplexity);

    // init from a deterministic small gaussian
    let mut rng = XorShift64Star::new(cfg.seed);
    let mut y: Vec<f64> = (0..2 * n).map(|_| rng.next_normal() * 1e-2).collect();
    let mut vel = vec![0f64; 2 * n];
    let mut gains = vec![1f64; 2 * n];

    for iter in 0..cfg.iterations {
        let exaggeration = if iter < 100 { 4.0 } else { 1.0 };
        let momentum = if iter < 250 { 0.5 } else { 0.8 };

        // low-dim affinities (student t, dof 1)
        let mut qnum = vec![0f64; n * n];
        let mut qsum = 0f64;
        for i in 0..n {
            for j in (i + 1)..n {
                let dx = y[2 * i] - y[2 * j];
                let dy = y[2 * i + 1] - y[2 * j + 1];
                let q = 1.0 / (1.0 + dx * dx + dy * dy);
                qnum[i * n + j] = q;
                qnum[j * n + i] = q;
                qsum += 2.0 * q;
            }
        }
        let qsum = qsum.max(1e-12);

        // gradient
        let mut grad = vec![0f64; 2 * n];
        for i in 0..n {
            for j in 0..n {
                if i == j {
                    continue;
                }
                let pij = p[i * n + j] * exaggeration;
                let qij = qnum[i * n + j] / qsum;
                let mult = 4.0 * (pij - qij) * qnum[i * n + j];
                grad[2 * i] += mult * (y[2 * i] - y[2 * j]);
                grad[2 * i + 1] += mult * (y[2 * i + 1] - y[2 * j + 1]);
            }
        }

        // adaptive gains + momentum update
        for k in 0..2 * n {
            gains[k] = if grad[k].signum() != vel[k].signum() {
                (gains[k] + 0.2).min(10.0)
            } else {
                (gains[k] * 0.8).max(0.01)
            };
            vel[k] = momentum * vel[k] - cfg.learning_rate * gains[k] * grad[k];
            y[k] += vel[k];
        }
        // re-center
        let (mx, my) = (
            y.iter().step_by(2).sum::<f64>() / n as f64,
            y.iter().skip(1).step_by(2).sum::<f64>() / n as f64,
        );
        for i in 0..n {
            y[2 * i] -= mx;
            y[2 * i + 1] -= my;
        }
    }
    (0..n).map(|i| (y[2 * i], y[2 * i + 1])).collect()
}

/// Symmetrized, perplexity-calibrated joint probabilities.
fn joint_probabilities(points: &[Vec<f64>], perplexity: f64) -> Vec<f64> {
    let n = points.len();
    let perplexity = perplexity.min((n as f64 - 1.0) / 3.0).max(1.0);
    let mut d2 = vec![0f64; n * n];
    for i in 0..n {
        for j in (i + 1)..n {
            let d: f64 = points[i]
                .iter()
                .zip(&points[j])
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            d2[i * n + j] = d;
            d2[j * n + i] = d;
        }
    }
    let target_h = perplexity.ln();
    let mut p = vec![0f64; n * n];
    for i in 0..n {
        // binary search beta for the row entropy
        let (mut lo, mut hi) = (1e-20f64, 1e20f64);
        let mut beta = 1.0;
        for _ in 0..64 {
            let mut sum = 0f64;
            let mut hsum = 0f64;
            for j in 0..n {
                if i == j {
                    continue;
                }
                let e = (-beta * d2[i * n + j]).exp();
                sum += e;
                hsum += beta * d2[i * n + j] * e;
            }
            let h = if sum > 0.0 { hsum / sum + sum.ln() } else { 0.0 };
            if (h - target_h).abs() < 1e-5 {
                break;
            }
            if h > target_h {
                lo = beta;
                beta = if hi >= 1e20 { beta * 2.0 } else { (beta + hi) / 2.0 };
            } else {
                hi = beta;
                beta = (beta + lo) / 2.0;
            }
        }
        let mut sum = 0f64;
        for j in 0..n {
            if i != j {
                p[i * n + j] = (-beta * d2[i * n + j]).exp();
                sum += p[i * n + j];
            }
        }
        for j in 0..n {
            p[i * n + j] /= sum.max(1e-12);
        }
    }
    // symmetrize
    let mut out = vec![0f64; n * n];
    for i in 0..n {
        for j in 0..n {
            out[i * n + j] = ((p[i * n + j] + p[j * n + i]) / (2.0 * n as f64)).max(1e-12);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::XorShift64Star;

    /// two well-separated gaussian clusters must stay separated in 2-D
    #[test]
    fn separates_clusters() {
        let mut r = XorShift64Star::new(7);
        let mut pts = Vec::new();
        for i in 0..20 {
            let center = if i < 10 { 0.0 } else { 50.0 };
            pts.push((0..8).map(|_| center + r.next_normal()).collect::<Vec<f64>>());
        }
        let emb = tsne(
            &pts,
            TsneConfig { iterations: 600, learning_rate: 50.0, ..Default::default() },
        );
        // mean intra-cluster distance << inter-cluster distance
        let d = |a: (f64, f64), b: (f64, f64)| ((a.0 - b.0).powi(2) + (a.1 - b.1).powi(2)).sqrt();
        let ca = (
            emb[..10].iter().map(|p| p.0).sum::<f64>() / 10.0,
            emb[..10].iter().map(|p| p.1).sum::<f64>() / 10.0,
        );
        let cb = (
            emb[10..].iter().map(|p| p.0).sum::<f64>() / 10.0,
            emb[10..].iter().map(|p| p.1).sum::<f64>() / 10.0,
        );
        let intra: f64 = emb[..10].iter().map(|p| d(*p, ca)).sum::<f64>() / 10.0;
        assert!(
            d(ca, cb) > intra * 2.0,
            "inter {} vs intra {intra}",
            d(ca, cb)
        );
    }

    #[test]
    fn deterministic_under_seed() {
        let pts: Vec<Vec<f64>> = (0..6).map(|i| vec![i as f64, (i * i) as f64]).collect();
        let a = tsne(&pts, TsneConfig::default());
        let b = tsne(&pts, TsneConfig::default());
        assert_eq!(a, b);
    }

    #[test]
    fn handles_degenerate_inputs() {
        assert!(tsne(&[], TsneConfig::default()).is_empty());
        assert_eq!(tsne(&[vec![1.0]], TsneConfig::default()), vec![(0.0, 0.0)]);
        // identical points do not blow up
        let pts = vec![vec![1.0, 1.0]; 4];
        let emb = tsne(&pts, TsneConfig { iterations: 50, ..Default::default() });
        assert!(emb.iter().all(|(x, y)| x.is_finite() && y.is_finite()));
    }
}
