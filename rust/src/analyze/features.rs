//! Weight-distribution feature vectors for the t-SNE embedding (Fig. 7).
//!
//! Each (method, layer) weight tensor maps to a fixed-length feature:
//! normalized 24-bin histogram + 8 moment/shape statistics. Distances in
//! this space reflect distributional similarity, which is what the
//! paper's Fig. 7 clusters.

use crate::metrics::Histogram;

pub const HIST_BINS: usize = 24;
pub const FEATURE_DIM: usize = HIST_BINS + 8;

/// Build the feature vector of one weight tensor.
pub fn weight_features(w: &[f32]) -> Vec<f64> {
    assert!(!w.is_empty());
    let n = w.len() as f64;
    let mean = w.iter().map(|v| *v as f64).sum::<f64>() / n;
    let var = w.iter().map(|v| (*v as f64 - mean).powi(2)).sum::<f64>() / n;
    let std = var.sqrt().max(1e-12);
    let m3 = w.iter().map(|v| ((*v as f64 - mean) / std).powi(3)).sum::<f64>() / n;
    let m4 = w.iter().map(|v| ((*v as f64 - mean) / std).powi(4)).sum::<f64>() / n;
    let absmax = w.iter().fold(0f32, |a, v| a.max(v.abs())) as f64;
    let meanabs = w.iter().map(|v| v.abs() as f64).sum::<f64>() / n;
    // standardized histogram over +-4 sigma (captures shape, not scale)
    let mut h = Histogram::new(-4.0, 4.0, HIST_BINS);
    for v in w {
        h.record((*v as f64 - mean) / std);
    }
    let mut out = h.densities();
    out.push(mean);
    out.push(std);
    out.push(m3); // skewness
    out.push(m4); // kurtosis
    out.push(absmax / std);
    out.push(meanabs / std);
    out.push(h.boundary_mass()); // saturation diagnostic
    out.push(h.entropy());
    debug_assert_eq!(out.len(), FEATURE_DIM);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::XorShift64Star;

    fn randn(n: usize, seed: u64, scale: f32) -> Vec<f32> {
        let mut r = XorShift64Star::new(seed);
        (0..n).map(|_| r.next_normal() as f32 * scale).collect()
    }

    fn dist(a: &[f64], b: &[f64]) -> f64 {
        a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>().sqrt()
    }

    #[test]
    fn fixed_dimension() {
        assert_eq!(weight_features(&randn(512, 1, 1.0)).len(), FEATURE_DIM);
    }

    #[test]
    fn scale_invariant_shape_features() {
        // same distribution at different scales -> close in feature space
        let a = weight_features(&randn(4096, 2, 1.0));
        let b = weight_features(&randn(4096, 3, 100.0));
        // drop the raw mean/std features (indices 24, 25) for this check
        let strip = |v: &[f64]| {
            let mut v = v.to_vec();
            v[HIST_BINS] = 0.0;
            v[HIST_BINS + 1] = 0.0;
            v
        };
        assert!(dist(&strip(&a), &strip(&b)) < 0.2);
    }

    #[test]
    fn distinguishes_clipped_from_gaussian() {
        let gauss = randn(4096, 4, 1.0);
        let clipped: Vec<f32> = gauss.iter().map(|v| v.clamp(-0.5, 0.5)).collect();
        let d = dist(&weight_features(&gauss), &weight_features(&clipped));
        let d_same = dist(&weight_features(&gauss), &weight_features(&randn(4096, 5, 1.0)));
        assert!(d > d_same * 3.0, "clipped {d} vs same-dist {d_same}");
    }
}
