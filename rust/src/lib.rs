//! LLMEasyQuant reproduction: scalable quantization for parallel and
//! distributed LLM inference (Rust + JAX + Pallas, AOT via XLA/PJRT).
//!
//! Architecture (DESIGN.md):
//!   L1/L2 — build-time Python (Pallas kernels + JAX model) lowered to
//!           `artifacts/*.hlo.txt`; never on the request path.
//!   L3    — this crate: the quantization serving runtime (coordinator,
//!           quantizers, collectives, KV manager) executing the artifacts
//!           through PJRT.

pub mod analyze;
pub mod bench_support;
pub mod collective;
pub mod coordinator;
pub mod corpus;
pub mod eval;
pub mod memsim;
pub mod metrics;
pub mod quant;
pub mod runtime;
pub mod serialize;
pub mod tensor;
pub mod util;
